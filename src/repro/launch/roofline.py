"""Roofline analysis (deliverable g): three terms per (arch x shape x mesh).

    compute term    = HLO_FLOPs_per_device / peak_FLOPs          [s]
    memory term     = HLO_bytes_per_device / HBM_bw              [s]
    collective term = collective_bytes_per_device / link_bw      [s]

cost_analysis() on the SPMD module is already per-device (verified in
EXPERIMENTS.md §Dry-run), so dividing the global formula by `chips` and
using per-device numbers are the same thing. FLOPs/bytes/collectives come
from the dry-run's depth-extrapolated accounting (scan bodies fully
counted).

MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference) with N = active params —
the useful-work reference; MODEL_FLOPS / (HLO_FLOPs * chips) measures how
much compiled compute is useful (catches remat + dispatch + replication
waste).

Memory is reported twice: raw HLO temp, and fused-attention corrected
(minus the materialized score tensors that the Pallas flash kernels never
write to HBM — the dry-run lowers the einsum path, see DESIGN.md §6).
"""
from __future__ import annotations

import argparse
import glob
import json
import os

import numpy as np

from .. import configs
from ..configs.base import SHAPES
from ..models import build
from ..models.transformer import layout

# TPU v5e-class hardware constants (per chip).
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
LINK_BW = 50e9               # bytes/s per ICI link
HBM_GIB = 16.0


def _shard_extent(spec, mesh_sizes) -> int:
    n = 1
    for ax in spec:
        if ax is None:
            continue
        axes = (ax,) if isinstance(ax, str) else ax
        for a in axes:
            n *= mesh_sizes.get(a, 1)
    return n


def tree_device_bytes(template, rules, dtype_size=2) -> float:
    """Per-device stored bytes of a P-template under the sharding rules."""
    import jax

    from ..models.common import P, pspec_tree
    specs = pspec_tree(template, rules)
    sizes = rules["_mesh_sizes"]
    total = 0.0
    for p, s in zip(
            jax.tree.leaves(template, is_leaf=lambda x: isinstance(x, P)),
            jax.tree.leaves(specs, is_leaf=lambda x: not isinstance(
                x, (dict, list)))):
        ds = {"float32": 4, "int32": 4, "bfloat16": 2}.get(
            str(p.dtype), dtype_size) if p.dtype is not None else dtype_size
        total += p.size * ds / _shard_extent(s, sizes)
    return total


def fused_memory_bytes(cfg, shape, mesh_sizes) -> float:
    """Analytic per-device HBM traffic per step, assuming fused kernels.

    The HLO 'bytes accessed' metric counts every op's operands pre-fusion —
    a loose upper bound. This model is the standard napkin roofline:
    weight reads per pass, optimizer-state read/write, one activation
    save + recompute per layer (full remat), cache read(+write) at decode.
    """
    from ..models import build
    from ..sharding.rules import make_rules

    class _M:
        shape = mesh_sizes
    rules = make_rules(cfg, _M())
    model = build(cfg, ep_degree=mesh_sizes.get("data", 1))
    p_dev = tree_device_bytes(model.template(), rules)
    chips = int(np.prod(list(mesh_sizes.values())))
    dp = mesh_sizes.get("pod", 1) * mesh_sizes.get("data", 1)
    tokens_dev = shape.global_batch * shape.seq_len / min(
        dp, shape.global_batch)
    act_unit = cfg.d_model * 2.0                     # bf16 per token

    if shape.kind == "train":
        from .specs import default_microbatches, opt_config

        class _Mesh:
            shape = mesh_sizes
        nm = default_microbatches(cfg, shape, _Mesh())
        st = 4 if opt_config(cfg).state_dtype == "float32" else 2
        w_traffic = (2 * nm + 2) * p_dev             # fwd+bwd reads, update
        opt_traffic = (4 * st / 2 + 2) * p_dev       # m,v rw + param rw
        act_traffic = cfg.n_layers * tokens_dev * act_unit * 8
        return w_traffic + opt_traffic + act_traffic
    if shape.kind == "prefill":
        cache_dev = tree_device_bytes(
            model.cache_template(shape.global_batch, shape.seq_len), rules)
        return 2 * p_dev + cfg.n_layers * tokens_dev * act_unit * 4 \
            + cache_dev
    # decode: weights + full cache read (+ small write)
    cache_dev = tree_device_bytes(
        model.cache_template(shape.global_batch, shape.seq_len), rules)
    return 2 * p_dev + cache_dev


def active_params(cfg) -> float:
    """Active (per-token) parameter count: total minus unused expert frac."""
    model = build(cfg, ep_degree=16)
    total = model.param_count()
    if not cfg.is_moe:
        return total
    # Routed expert params (wi_gate + wi_up + wo) per MoE layer.
    e_pad = cfg.padded_experts(16)
    per_expert = 3 * cfg.d_model * cfg.expert_d_ff
    n_moe_layers = sum(1 for i in range(cfg.n_layers)
                       if (cfg.moe_period == 1 or i % cfg.moe_period == 1))
    routed = n_moe_layers * e_pad * per_expert
    used = n_moe_layers * cfg.top_k * per_expert
    return total - routed + used


def model_flops(cfg, shape) -> float:
    """Global useful FLOPs per step: 6ND (train) / 2ND (inference)."""
    n = active_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch          # decode: 1 token/seq


def attention_score_bytes(cfg, shape, n_devices: int) -> float:
    """Per-device bytes of ONE layer's materialized f32 score tensor —
    the fused-attention memory correction (the layer scan reuses the same
    buffer, so peak temp carries one layer's scores). xLSTM's mLSTM
    parallel form is quadratic like attention, so it gets the same
    correction (its Pallas kernel tiles the decay matrix)."""
    dp = min(shape.global_batch, max(n_devices // 16, 1))
    b_local = max(shape.global_batch // max(dp, 1), 1)
    heads_local = max(cfg.n_heads // 16, 1) if cfg.n_heads % 16 == 0 \
        else cfg.n_heads
    s = shape.seq_len
    if shape.kind == "decode":
        return 2.0 * b_local * heads_local * s * 4
    return 2.0 * b_local * heads_local * float(s) * s * 4


def terms_from_record(rec: dict) -> dict:
    cfg = configs.get(rec["arch"])
    shape = SHAPES[rec["shape"]]
    chips = rec["n_devices"]
    mesh_sizes = ({"pod": 2, "data": 16, "model": 16} if chips == 512
                  else {"data": 16, "model": 16})
    ex = rec.get("extrapolated") or {
        "flops": rec["cost_full_hlo"]["flops"],
        "bytes": rec["cost_full_hlo"]["bytes"],
        "coll": rec["collectives_full_hlo"]["total_bytes"]}
    t_compute = ex["flops"] / PEAK_FLOPS
    t_memory_hlo = ex["bytes"] / HBM_BW          # pre-fusion upper bound
    t_memory = fused_memory_bytes(cfg, shape, mesh_sizes) / HBM_BW
    t_coll = ex["coll"] / LINK_BW
    dominant = max(("compute", t_compute), ("memory", t_memory),
                   ("collective", t_coll), key=lambda kv: kv[1])[0]
    mf = model_flops(cfg, shape)
    useful = mf / max(ex["flops"] * chips, 1e-9)
    bound = max(t_compute, t_memory, t_coll)
    # Roofline fraction: useful work at peak vs the achievable step time.
    frac = (mf / chips / PEAK_FLOPS) / max(bound, 1e-12)
    score_corr = attention_score_bytes(cfg, shape, chips) / 2**30
    mem = rec["memory"]
    per_chip_raw = mem["argument_gib"] + mem["temp_gib"]
    per_chip_fused = mem["argument_gib"] + max(
        mem["temp_gib"] - score_corr, 0.0)
    return {
        "arch": rec["arch"], "shape": rec["shape"],
        "mesh": rec.get("mesh_name", "single"), "chips": chips,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_memory_hlo_s": t_memory_hlo, "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": mf, "hlo_flops_per_dev": ex["flops"],
        "useful_fraction": useful, "roofline_fraction": frac,
        "mem_per_chip_raw_gib": per_chip_raw,
        "mem_per_chip_fused_gib": per_chip_fused,
        "fits_16gib_fused": per_chip_fused <= HBM_GIB,
    }


def suggestion(t: dict) -> str:
    if t["dominant"] == "collective":
        return ("reduce resharding: fuse all-gathers (FSDP prefetch), "
                "overlap collectives with compute, or compress grads")
    if t["dominant"] == "memory":
        if t["shape"].startswith("decode") or t["shape"].startswith("long"):
            return ("decode is cache-BW bound: shrink KV (MLA/GQA/quant) "
                    "or raise batch to amortize weight reads")
        return ("cut HBM traffic: fused attention kernel, tighter remat "
                "policy, bf16 activations end-to-end")
    return ("raise MXU utilization: bigger microbatches, fewer one-hot "
            "matmuls (MoE gather dispatch), lighter remat")


def build_table(dryrun_dir: str):
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        rec = json.load(open(path))
        if "skipped" in rec or "error" in rec:
            continue
        t = terms_from_record(rec)
        t["suggestion"] = suggestion(t)
        rows.append(t)
    return rows


def to_markdown(rows, title="Roofline") -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | "
           "dominant | useful | roofline | mem/chip (fused) |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    out = [f"### {title}\n", hdr]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"],
                                         r["mesh"])):
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute_s']:.3e} | {r['t_memory_s']:.3e} "
            f"| {r['t_collective_s']:.3e} | **{r['dominant']}** "
            f"| {r['useful_fraction']:.2f} | {r['roofline_fraction']:.2f} "
            f"| {r['mem_per_chip_fused_gib']:.1f} GiB |\n")
    return "".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="results/dryrun")
    ap.add_argument("--out", default="results/roofline")
    args = ap.parse_args()
    rows = build_table(args.dryrun)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out + ".json", "w") as f:
        json.dump(rows, f, indent=1)
    md = to_markdown(rows)
    with open(args.out + ".md", "w") as f:
        f.write(md)
    print(md)
    worst = sorted(rows, key=lambda r: r["roofline_fraction"])[:5]
    print("\nworst roofline fractions:")
    for r in worst:
        print(f"  {r['arch']} {r['shape']} {r['mesh']}: "
              f"{r['roofline_fraction']:.3f} ({r['dominant']}) -> "
              f"{r['suggestion']}")


if __name__ == "__main__":
    main()
