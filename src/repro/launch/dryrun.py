import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

For every (architecture x input-shape x mesh) cell: lower + compile the
production step with explicit shardings, record memory_analysis() and
cost_analysis(), and parse collective bytes from the optimized HLO.

Accounting notes (see EXPERIMENTS.md §Dry-run):
  * cost_analysis() on this backend reports **per-device** numbers and
    counts a lax.scan (while-loop) body ONCE. The production step scans
    over layer periods, so we compile the cell at period depth 1 and 2 and
    extrapolate linearly: total = f(1) + (n_periods - 1) * (f(2) - f(1)).
    Verified exact vs an unrolled compile for small configs
    (tests/test_dryrun_accounting.py).
  * The einsum ("ref") attention path materializes score tensors that the
    Pallas flash kernels never do; memory is reported both raw and with the
    analytic score-bytes correction.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
        --mesh both --out results/dryrun
"""
import argparse
import dataclasses
import json
import re
import time
import traceback

import jax
import numpy as np

from .. import configs
from ..configs.base import ALL_SHAPES, shape_supported
from .mesh import make_production_mesh
from .specs import plan_cell

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(bf16|f32|f16|f64|s32|u32|s8|u8|pred|s64)"
                       r"\[([0-9,]*)\]")
_BYTES = {"bf16": 2, "f16": 2, "f32": 4, "f64": 8, "s32": 4, "u32": 4,
          "s8": 1, "u8": 1, "pred": 1, "s64": 8}


def cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` normalized across jax versions: older
    releases return a list with one dict per program, newer ones a plain
    dict. Always returns a dict (empty when the backend reports nothing)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


def _type_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.groups()
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum per-op-type result bytes of every collective instruction."""
    out = {k: 0 for k in COLLECTIVES}
    counts = {k: 0 for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+)", s)
        if not m:
            continue
        rhs = m.group(1)
        for op in COLLECTIVES:
            # match the op name as the instruction (not in metadata)
            if re.search(rf"\b{op}(?:-start|-done)?\(", rhs):
                # result type(s) = text before the op name
                head = rhs.split(op)[0]
                out[op] += _type_bytes(head)
                counts[op] += 1
                break
    return {"bytes": out, "counts": counts,
            "total_bytes": int(sum(out.values()))}


def _reduced_depth(cfg, n_periods: int):
    """Config with the layer stack cut to n_periods periods."""
    from ..models.transformer import layout
    period, full = layout(cfg)
    plen = len(period)
    ch = {"n_layers": plen * n_periods}
    if cfg.enc_layers:
        ch["enc_layers"] = n_periods
        ch["n_layers"] = n_periods
    return dataclasses.replace(cfg, **ch), full


def measure_cell(cfg, shape, mesh, *, skip_extrapolation=False,
                 **plan_kwargs) -> dict:
    """Compile a cell and return the full accounting dict. ``plan_kwargs``
    (impl, mlstm_impl, rule_overrides, n_microbatches, ...) forward to
    plan_cell — the hillclimb harness varies them per iteration."""
    rec = {"arch": cfg.name, "shape": shape.name,
           "mesh": tuple(mesh.shape.values()),
           "n_devices": int(np.prod(list(mesh.shape.values())))}
    t0 = time.time()
    plan = plan_cell(cfg, shape, mesh, **plan_kwargs)
    lowered = plan.lower()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 1)

    ma = compiled.memory_analysis()
    rec["memory"] = {
        "argument_gib": ma.argument_size_in_bytes / 2**30,
        "output_gib": ma.output_size_in_bytes / 2**30,
        "temp_gib": ma.temp_size_in_bytes / 2**30,
        "alias_gib": ma.alias_size_in_bytes / 2**30,
    }
    ca = cost_analysis(compiled)
    rec["cost_full_hlo"] = {"flops": ca.get("flops", 0.0),
                            "bytes": ca.get("bytes accessed", 0.0)}
    rec["collectives_full_hlo"] = collective_bytes(compiled.as_text())
    rec["n_microbatches"] = getattr(plan, "n_microbatches", None)

    if skip_extrapolation:
        return rec

    # Two-depth extrapolation for scan-body accounting.
    from ..models.transformer import layout
    _, n_full = layout(cfg)
    vals = {}
    for depth in (1, 2):
        dcfg, _ = _reduced_depth(cfg, depth)
        # Probes run a single microbatch (= the full token count in one
        # unrolled pass) so the grad-accumulation scan cannot hide FLOPs;
        # memory realism comes from the full compile above, not the probes.
        probe_kwargs = dict(plan_kwargs)
        probe_kwargs["n_microbatches"] = 1
        dplan = plan_cell(dcfg, shape, mesh, **probe_kwargs)
        dcomp = dplan.lower().compile()
        dca = cost_analysis(dcomp)
        vals[depth] = {
            "flops": dca.get("flops", 0.0),
            "bytes": dca.get("bytes accessed", 0.0),
            "coll": collective_bytes(dcomp.as_text())["total_bytes"],
        }
    rec["extrapolated"] = {}
    for key in ("flops", "bytes", "coll"):
        slope = vals[2][key] - vals[1][key]
        rec["extrapolated"][key] = float(
            vals[1][key] + (n_full - 1) * slope)
    rec["depth_probe"] = vals
    rec["n_periods"] = n_full
    return rec


def iter_cells(arch_sel, shape_sel):
    for name, cfg in configs.ARCHS.items():
        if arch_sel != "all" and name != arch_sel:
            continue
        for shape in ALL_SHAPES:
            if shape_sel != "all" and shape.name != shape_sel:
                continue
            ok, reason = shape_supported(cfg, shape)
            if not ok:
                yield cfg, shape, reason
            else:
                yield cfg, shape, None


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--impl", default="ref")
    ap.add_argument("--fast", action="store_true",
                    help="skip depth extrapolation probes")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi", make_production_mesh(multi_pod=True)))

    n_ok = n_skip = n_fail = 0
    for cfg, shape, skip_reason in iter_cells(args.arch, args.shape):
        for mesh_name, mesh in meshes:
            cell = f"{cfg.name}__{shape.name}__{mesh_name}"
            path = os.path.join(args.out, cell + ".json")
            if skip_reason:
                rec = {"arch": cfg.name, "shape": shape.name,
                       "mesh": mesh_name, "skipped": skip_reason}
                n_skip += 1
                print(f"SKIP {cell}: {skip_reason}", flush=True)
            else:
                try:
                    rec = measure_cell(cfg, shape, mesh, impl=args.impl,
                                       skip_extrapolation=args.fast)
                    rec["mesh_name"] = mesh_name
                    n_ok += 1
                    print(f"OK   {cell}: compile={rec['compile_s']}s "
                          f"flops={rec['extrapolated']['flops'] if 'extrapolated' in rec else rec['cost_full_hlo']['flops']:.3e} "
                          f"coll={rec['collectives_full_hlo']['total_bytes']:.3e}B "
                          f"temp={rec['memory']['temp_gib']:.1f}GiB",
                          flush=True)
                except Exception as e:
                    rec = {"arch": cfg.name, "shape": shape.name,
                           "mesh": mesh_name, "error": str(e),
                           "traceback": traceback.format_exc()}
                    n_fail += 1
                    print(f"FAIL {cell}: {e}", flush=True)
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
    print(f"done: ok={n_ok} skip={n_skip} fail={n_fail}", flush=True)


if __name__ == "__main__":
    main()
