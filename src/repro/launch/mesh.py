"""Production mesh builders.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — required because the dry-run must set
XLA_FLAGS before any jax initialization.

:func:`make_mesh` is the version-compat front door: newer jax exposes
``jax.sharding.AxisType`` and ``jax.make_mesh(..., axis_types=...)``;
older releases (e.g. 0.4.x) have neither. Every mesh in the repo (and the
tier-1 tests) goes through this shim so the code runs on both.
"""
from __future__ import annotations

import jax


def make_mesh(axis_shapes, axis_names, *, axis_types=None, devices=None):
    """``jax.make_mesh`` with ``AxisType`` resolved per jax version.

    ``axis_types`` may be None (defaults to ``Auto`` on every axis when
    the running jax supports axis types), a tuple of
    ``jax.sharding.AxisType`` members, or a tuple of their lowercase
    names (``"auto"`` / ``"explicit"`` / ``"manual"``) so call sites can
    stay importable on jax versions without the enum. On a jax without
    ``AxisType`` the argument is dropped entirely — positional fallback
    — which matches the old default behavior.
    """
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    axis_type_cls = getattr(jax.sharding, "AxisType", None)
    if axis_type_cls is not None:
        if axis_types is None:
            axis_types = (axis_type_cls.Auto,) * len(tuple(axis_names))
        else:
            axis_types = tuple(
                getattr(axis_type_cls, t.capitalize())
                if isinstance(t, str) else t for t in axis_types)
        kwargs["axis_types"] = axis_types
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Tiny mesh over whatever devices exist (CPU tests / examples)."""
    n = len(jax.devices())
    data = max(n // model, 1)
    return make_mesh((data, model), ("data", "model"))
