"""Serving launcher: LBCD-controlled analytics service.

    PYTHONPATH=src python -m repro.launch.serve --streams 16 --epochs 8 \
        [--engine] [--islands 4]

On a real pod this drives per-island inference engines (one model replica
per 16-chip island); on CPU it runs the M/M/1 data plane or a reduced
real-model engine. The controller half is identical in both cases.
"""
from __future__ import annotations

import argparse

import numpy as np

from ..core import lbcd, profiles
from ..serving import AnalyticsService


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--streams", type=int, default=16)
    ap.add_argument("--islands", type=int, default=4)
    ap.add_argument("--epochs", type=int, default=8)
    ap.add_argument("--engine", action="store_true")
    ap.add_argument("--v", type=float, default=10.0)
    ap.add_argument("--p-min", type=float, default=0.7)
    ap.add_argument("--bandwidth-mhz", type=float, default=12.0)
    ap.add_argument("--tflops", type=float, default=15.0)
    args = ap.parse_args()

    system = profiles.EdgeSystem(
        n_cameras=args.streams, n_servers=args.islands,
        n_slots=max(args.epochs, 8),
        mean_bandwidth_hz=args.bandwidth_mhz * 1e6,
        mean_compute_flops=args.tflops * 1e12, seed=0)
    ctrl = lbcd.LBCDController(system, v=args.v, p_min=args.p_min)

    if args.engine:
        import jax

        from .. import configs
        from ..models import build
        from ..models.common import init_params
        from ..serving import Engine

        cfg = configs.get("qwen2.5-3b").reduced()
        model = build(cfg)
        params = init_params(model.template(), jax.random.PRNGKey(0))
        eng = Engine(model, params, n_lanes=8, max_len=96,
                     decode_tokens=2)
        svc = AnalyticsService(ctrl, mode="engine", engine=eng,
                               epoch_duration=3.0)
    else:
        svc = AnalyticsService(ctrl, mode="mm1", epoch_duration=1200.0)

    print("epoch  pred-AoPI  meas-AoPI  acc     q")
    for t in range(args.epochs):
        r = svc.run_epoch(t)
        print(f"{t:>5d}  {r.predicted_aopi:9.4f}  {r.measured_aopi:9.4f}"
              f"  {r.accuracy:5.3f}  {r.q:5.2f}")
    print(f"\nmean measured AoPI {svc.mean_measured:.4f} s "
          f"(predicted {svc.mean_predicted:.4f} s)")


if __name__ == "__main__":
    main()
