"""Training launcher: real steps on whatever devices exist.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b \
        --reduced --steps 50 --batch 8 --seq 128

On the CPU container this trains reduced configs end-to-end (the ~100M-class
example lives in examples/train_e2e.py); on a real pod the same entry point
takes the full config + production mesh. Features: checkpoint/restart,
straggler monitoring, deterministic data, loss/throughput logging.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from .. import configs
from ..data.pipeline import PipelineConfig, TokenPipeline
from ..models import build
from ..models.common import init_params
from ..sharding import ctx as shard_ctx
from ..sharding import rules as rules_mod
from ..training import checkpoint as ckpt_mod
from ..training import optimizer as opt_mod
from ..training.failure import StragglerMonitor
from ..training.train_step import make_train_step
from .mesh import make_host_mesh


def run(cfg, *, steps: int, batch: int, seq: int, ckpt_dir=None,
        ckpt_every: int = 0, n_microbatches: int = 1, lr: float = 3e-4,
        log_every: int = 10, resume: bool = False, seed: int = 0):
    model = build(cfg)
    mesh = make_host_mesh()
    rules = rules_mod.make_rules(cfg, mesh)
    key = jax.random.PRNGKey(seed)
    params = init_params(model.template(), key, jnp.dtype(cfg.dtype))
    ocfg = dataclasses.replace(opt_mod.AdamWConfig(), lr=lr,
                               total_steps=steps)
    opt_state = opt_mod.init(params, ocfg)
    start = 0
    if resume and ckpt_dir and ckpt_mod.latest_step(ckpt_dir) is not None:
        (params, opt_state), start = ckpt_mod.restore(
            ckpt_dir, (params, opt_state))
        print(f"resumed from step {start}")

    step_fn = make_train_step(model, ocfg, n_microbatches=n_microbatches)

    def wrapped(params, opt_state, batch_):
        with shard_ctx.activation_rules(rules):
            return step_fn(params, opt_state, batch_)

    jitted = jax.jit(wrapped, donate_argnums=(0, 1))
    pipe = TokenPipeline(PipelineConfig(cfg.vocab, seq, batch, seed=seed))
    monitor = StragglerMonitor(n_workers=1)
    losses = []
    t_start = time.time()
    with mesh:
        for step in range(start, steps):
            b = pipe.batch(step)
            batch_j = {k: jnp.asarray(v) for k, v in b.items()}
            if cfg.family == "vlm":
                batch_j["vision_embeds"] = jnp.asarray(pipe.modality_stub(
                    step, cfg.n_vision_tokens, cfg.d_model))
            if cfg.family == "audio":
                batch_j["audio_embeds"] = jnp.asarray(pipe.modality_stub(
                    step, seq, cfg.d_model, kind="audio"))
            t0 = time.time()
            params, opt_state, metrics = jitted(params, opt_state, batch_j)
            loss = float(metrics["loss"])
            monitor.observe([time.time() - t0])
            losses.append(loss)
            if log_every and step % log_every == 0:
                tok_s = batch * seq / max(time.time() - t0, 1e-9)
                print(f"step {step:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"tok/s {tok_s:,.0f}", flush=True)
            if ckpt_every and ckpt_dir and (step + 1) % ckpt_every == 0:
                ckpt_mod.save(ckpt_dir, step + 1, (params, opt_state))
    wall = time.time() - t_start
    return {"losses": losses, "wall_s": wall, "params": params,
            "opt_state": opt_state}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()
    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    out = run(cfg, steps=args.steps, batch=args.batch, seq=args.seq,
              ckpt_dir=args.ckpt, ckpt_every=args.ckpt_every,
              n_microbatches=args.microbatches, lr=args.lr,
              resume=args.resume)
    print(f"final loss {out['losses'][-1]:.4f} "
          f"({out['wall_s']:.1f}s total)")


if __name__ == "__main__":
    main()
