"""Cell planning: (arch x input-shape x mesh) -> jittable step + shardings.

``plan_cell`` is the single entry point used by the dry-run, the roofline
harness, and the real launchers. It builds the model, the sharding rules,
the abstract inputs (ShapeDtypeStruct stand-ins — weak-type-correct,
shardable, zero allocation), and the step function with explicit
in/out_shardings and donation.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from ..configs.base import InputShape, ModelConfig
from ..data.pipeline import batch_for
from ..models import build
from ..models.common import abstract_params, pspec_tree, tree_map
from ..sharding import ctx as shard_ctx
from ..sharding import rules as rules_mod
from ..training import optimizer as opt_mod
from ..training.train_step import make_train_step


def default_microbatches(cfg: ModelConfig, shape: InputShape, mesh) -> int:
    """Per-device activation budget heuristic: keep the live per-microbatch
    token count per chip near a target so layer activations + remat stash
    fit alongside params/optimizer (see DESIGN.md memory table)."""
    if shape.kind != "train":
        return 1
    dp = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    tokens_per_chip = shape.global_batch * shape.seq_len // dp
    target = 8192 if cfg.d_model <= 4096 else \
        4096 if cfg.d_model <= 7168 else 2048
    n = max(1, tokens_per_chip // target)
    # Must divide the per-shard batch.
    per_shard = max(shape.global_batch // dp, 1)
    while per_shard % n:
        n -= 1
    return max(n, 1)


def opt_config(cfg: ModelConfig) -> opt_mod.AdamWConfig:
    big = cfg.name in ("dbrx-132b", "jamba-1.5-large-398b")
    return opt_mod.AdamWConfig(
        state_dtype="bfloat16" if big else "float32")


@dataclasses.dataclass
class CellPlan:
    cfg: ModelConfig
    shape: InputShape
    mesh: Any
    rules: dict
    model: Any
    step_fn: Callable            # jittable
    args: tuple                  # abstract arguments (ShapeDtypeStructs)
    in_shardings: tuple
    out_shardings: Any
    donate: tuple
    kind: str

    def lower(self):
        jitted = jax.jit(self.step_fn, in_shardings=self.in_shardings,
                         out_shardings=self.out_shardings,
                         donate_argnums=self.donate)
        with self.mesh:
            return jitted.lower(*self.args)

    def compile(self):
        return self.lower().compile()


def _abstract(template, dtype):
    return abstract_params(template, dtype)


def _named_tree(mesh, template, rules):
    specs = pspec_tree(template, rules)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)


def _batch_abstract(cfg: ModelConfig, shape: InputShape, kind: str):
    gb, s = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    out = {}
    if kind == "decode":
        out["tokens"] = jax.ShapeDtypeStruct((gb,), jnp.int32)
        return out
    out["tokens"] = jax.ShapeDtypeStruct((gb, s), jnp.int32)
    if kind == "train":
        out["labels"] = jax.ShapeDtypeStruct((gb, s), jnp.int32)
    if cfg.family == "vlm":
        out["vision_embeds"] = jax.ShapeDtypeStruct(
            (gb, cfg.n_vision_tokens, cfg.d_model), dt)
    if cfg.family == "audio":
        out["audio_embeds"] = jax.ShapeDtypeStruct((gb, s, cfg.d_model), dt)
    return out


def _batch_shardings(cfg, mesh, rules, shape, kind: str):
    dp = rules["batch"]
    bs = NamedSharding(mesh, PartitionSpec(*rules_mod.spec_dims(
        (shape.global_batch,), ("batch",), rules)))
    seq_sh = NamedSharding(mesh, PartitionSpec(*rules_mod.spec_dims(
        (shape.global_batch, shape.seq_len), ("batch", "seq"), rules)))
    out = {}
    if kind == "decode":
        out["tokens"] = bs
        return out
    out["tokens"] = seq_sh
    if kind == "train":
        out["labels"] = seq_sh
    rep3 = lambda n: NamedSharding(mesh, PartitionSpec(*rules_mod.spec_dims(
        (shape.global_batch, n, cfg.d_model), ("batch", None, None), rules)))
    if cfg.family == "vlm":
        out["vision_embeds"] = rep3(cfg.n_vision_tokens)
    if cfg.family == "audio":
        out["audio_embeds"] = rep3(shape.seq_len)
    return out


def input_specs(cfg: ModelConfig, shape: InputShape, model=None,
                kind: Optional[str] = None):
    """Abstract inputs for a cell (the dry-run's ShapeDtypeStruct batch)."""
    kind = kind or shape.kind
    batch = _batch_abstract(cfg, shape, kind)
    if kind == "train":
        return batch
    model = model or build(cfg)
    dt = jnp.dtype(cfg.dtype)
    cache_tmpl = model.cache_template(shape.global_batch, shape.seq_len,
                                      dtype=dt)
    cache = _abstract(cache_tmpl, dt)
    return batch, cache


def plan_cell(cfg: ModelConfig, shape: InputShape, mesh, *,
              impl: str = "ref", ssm_impl: str = "chunked",
              mlstm_impl: str = "ref",
              rule_overrides: Optional[dict] = None,
              n_microbatches: Optional[int] = None,
              hoist_fsdp_gather: Optional[bool] = None) -> CellPlan:
    rules = rules_mod.make_rules(cfg, mesh, overrides=rule_overrides)
    ep = rules_mod.ep_degree(mesh)
    model = build(cfg, impl=impl, ssm_impl=ssm_impl,
                  mlstm_impl=mlstm_impl, ep_degree=ep)
    dt = jnp.dtype(cfg.dtype)
    tmpl = model.template()
    params_abs = _abstract(tmpl, dt)
    params_sh = _named_tree(mesh, tmpl, rules)
    kind = shape.kind

    def with_rules(fn):
        @functools.wraps(fn)
        def inner(*a):
            with shard_ctx.activation_rules(rules):
                return fn(*a)
        return inner

    if kind == "train":
        ocfg = opt_config(cfg)
        nm = n_microbatches or default_microbatches(cfg, shape, mesh)
        if hoist_fsdp_gather is None:
            # Auto: hoist when the TP-only (gathered) weights fit a modest
            # HBM slice — saves (nm-1) x weight-bytes of ICI per step
            # (EXPERIMENTS.md §Perf cell A iter 3).
            from .roofline import tree_device_bytes
            gr0 = dict(rules)
            gr0["embed"] = None
            gathered_gib = tree_device_bytes(tmpl, gr0) / 2**30
            hoist_fsdp_gather = nm > 1 and gathered_gib <= 6.0
        pre = None
        if hoist_fsdp_gather and cfg.fsdp:
            gr = dict(rules)
            gr["embed"] = None                    # TP-only layout (gathered)
            gathered_specs = pspec_tree(tmpl, gr)

            def pre(params, _specs=gathered_specs):
                return jax.tree.map(jax.lax.with_sharding_constraint,
                                    params, _specs)
        step = with_rules(make_train_step(model, ocfg, n_microbatches=nm,
                                          pre_constrain=pre))
        opt_abs = {
            "m": tree_map(lambda p: jax.ShapeDtypeStruct(
                p.shape, jnp.dtype(ocfg.state_dtype)), tmpl),
            "v": tree_map(lambda p: jax.ShapeDtypeStruct(
                p.shape, jnp.dtype(ocfg.state_dtype)), tmpl),
            "step": jax.ShapeDtypeStruct((), jnp.int32),
        }
        opt_sh = {"m": params_sh, "v": params_sh,
                  "step": NamedSharding(mesh, PartitionSpec())}
        batch_abs = _batch_abstract(cfg, shape, kind)
        batch_sh = _batch_shardings(cfg, mesh, rules, shape, kind)
        rep = NamedSharding(mesh, PartitionSpec())
        metrics_sh = {"loss": rep, "grad_norm": rep, "lr": rep}
        return CellPlan(
            cfg, shape, mesh, rules, model, step,
            args=(params_abs, opt_abs, batch_abs),
            in_shardings=(params_sh, opt_sh, batch_sh),
            out_shardings=(params_sh, opt_sh, metrics_sh),
            donate=(0, 1), kind=kind)

    cache_tmpl = model.cache_template(shape.global_batch, shape.seq_len,
                                      dtype=dt)
    cache_abs = _abstract(cache_tmpl, dt)
    cache_sh = _named_tree(mesh, cache_tmpl, rules)
    vocab_sh = NamedSharding(mesh, PartitionSpec(*rules_mod.spec_dims(
        (shape.global_batch, cfg.padded_vocab), ("batch", "vocab"), rules)))

    if kind == "prefill":
        @with_rules
        def step(params, batch, cache):
            return model.prefill(params, batch, cache)
        batch_abs = _batch_abstract(cfg, shape, kind)
        batch_sh = _batch_shardings(cfg, mesh, rules, shape, kind)
        logits_sh = NamedSharding(mesh, PartitionSpec(
            *rules_mod.spec_dims(
                (shape.global_batch, 1, cfg.padded_vocab),
                ("batch", None, "vocab"), rules)))
        return CellPlan(
            cfg, shape, mesh, rules, model, step,
            args=(params_abs, batch_abs, cache_abs),
            in_shardings=(params_sh, batch_sh, cache_sh),
            out_shardings=(logits_sh, cache_sh),
            donate=(2,), kind=kind)

    # decode
    @with_rules
    def step(params, tokens, cache):
        return model.decode_step(params, tokens, cache)

    tokens_abs = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
    tokens_sh = NamedSharding(mesh, PartitionSpec(*rules_mod.spec_dims(
        (shape.global_batch,), ("batch",), rules)))
    return CellPlan(
        cfg, shape, mesh, rules, model, step,
        args=(params_abs, tokens_abs, cache_abs),
        in_shardings=(params_sh, tokens_sh, cache_sh),
        out_shardings=(vocab_sh, cache_sh),
        donate=(2,), kind=kind)
