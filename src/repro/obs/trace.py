"""Span-based tracing for the plan/measure/replan loop.

``span("service.plan_window", policy="lbcd")`` opens a wall-clock span;
on exit one event dict is recorded with the span's duration, its parent
(spans nest per-thread, so events form a tree), and the merged label
context (:func:`label_context` — ``replay_suite`` sets ``family``/
``policy`` once and every span underneath inherits them). Completed
events stream to ``<run_dir>/trace.jsonl`` when a run directory is
configured and are kept in a bounded in-memory buffer either way, from
which :func:`chrome_trace` renders Chrome trace-event JSON (load it at
``ui.perfetto.dev``).

Inside every span the code also enters ``jax.named_scope`` and
``jax.profiler.TraceAnnotation`` with the span name, so a device profile
captured with ``jax.profiler.trace`` lines up against the host spans —
the host-side "plan_horizon took 40ms" and the device-side "which kernels
those 40ms were" views share names.

Timebase: ``time.perf_counter()`` relative to module import (the
``ts``/``dur`` fields are seconds on one monotonic clock, directly
subtractable); ``wall`` carries ``time.time()`` for cross-process
alignment.
"""
from __future__ import annotations

import contextlib
import contextvars
import json
import os
import threading
import time
from typing import Iterable

import jax

#: Events kept in memory (ring buffer) — enough for ~hours of control-
#: plane activity; the JSONL stream is the unbounded record.
MAX_EVENTS = 200_000

_T0 = time.perf_counter()
_EPOCH0 = time.time()

_labels: contextvars.ContextVar[dict] = contextvars.ContextVar(
    "repro_obs_labels", default={})


@contextlib.contextmanager
def label_context(**labels):
    """Merge ``labels`` into every span/event recorded inside the block
    (nested contexts stack; inner wins on conflict)."""
    merged = {**_labels.get(), **labels}
    token = _labels.set(merged)
    try:
        yield merged
    finally:
        _labels.reset(token)


def current_labels() -> dict:
    return dict(_labels.get())


class TraceBuffer:
    """Bounded event store + optional JSONL streaming."""

    def __init__(self):
        self._lock = threading.Lock()
        self._events: list[dict] = []
        self._dropped = 0
        self._path: str | None = None
        self._fh = None
        self._next_id = 0
        self._local = threading.local()

    # -- configuration -------------------------------------------------
    def set_stream(self, path: str | None) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.close()
                self._fh = None
            self._path = path
            if path is not None:
                os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
                self._fh = open(path, "a", buffering=1)

    @property
    def stream_path(self) -> str | None:
        return self._path

    # -- recording -----------------------------------------------------
    def _stack(self) -> list:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def new_id(self) -> int:
        with self._lock:
            self._next_id += 1
            return self._next_id

    def record(self, ev: dict) -> None:
        with self._lock:
            if len(self._events) >= MAX_EVENTS:
                # Drop the oldest half in one slice — amortized O(1).
                self._dropped += len(self._events) // 2
                self._events = self._events[len(self._events) // 2:]
            self._events.append(ev)
            if self._fh is not None:
                self._fh.write(json.dumps(ev) + "\n")

    def flush(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.flush()

    # -- reading -------------------------------------------------------
    def events(self) -> list[dict]:
        with self._lock:
            return list(self._events)

    @property
    def dropped(self) -> int:
        return self._dropped

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self._dropped = 0


class Span:
    """One wall-clock span; records an event on exit.

    Use through :func:`repro.obs.span` — entering also opens
    ``jax.named_scope``/``jax.profiler.TraceAnnotation`` so device
    profiles carry the same names.
    """

    __slots__ = ("name", "attrs", "buffer", "sid", "t0", "_cm", "_metric")

    def __init__(self, name: str, buffer: TraceBuffer, attrs: dict,
                 metric=None):
        self.name = name
        self.attrs = attrs
        self.buffer = buffer
        self.sid = buffer.new_id()
        self.t0 = 0.0
        self._cm = None
        self._metric = metric

    def __enter__(self) -> "Span":
        stack = self.buffer._stack()
        stack.append(self.sid)
        self._cm = contextlib.ExitStack()
        self._cm.enter_context(jax.named_scope(self.name))
        self._cm.enter_context(jax.profiler.TraceAnnotation(self.name))
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        # Exception-safe teardown: the span must pop off the per-thread
        # stack and record its event even when the body raised (or when
        # closing the jax scopes raises) — otherwise one raise corrupts
        # the span tree for everything recorded after it.
        t1 = time.perf_counter()
        try:
            self._cm.close()
        finally:
            stack = self.buffer._stack()
            if stack and stack[-1] == self.sid:
                stack.pop()
            elif self.sid in stack:
                stack.remove(self.sid)
            dur = t1 - self.t0
            args = {**current_labels(), **self.attrs}
            if exc and exc[0] is not None:
                args["error"] = 1
            ev = {"ph": "X", "name": self.name, "id": self.sid,
                  "parent": stack[-1] if stack else 0,
                  "ts": self.t0 - _T0, "dur": dur,
                  "wall": _EPOCH0 + (self.t0 - _T0),
                  "tid": threading.get_ident(),
                  "args": args}
            self.buffer.record(ev)
            if self._metric is not None:
                self._metric.observe(dur)

    def set(self, **attrs) -> "Span":
        """Attach attributes discovered mid-span."""
        self.attrs.update(attrs)
        return self


def record_event(name: str, buffer: TraceBuffer, attrs: dict) -> dict:
    """Record an instant (zero-duration) event at now."""
    stack = buffer._stack()
    t = time.perf_counter()
    ev = {"ph": "i", "name": name, "id": buffer.new_id(),
          "parent": stack[-1] if stack else 0,
          "ts": t - _T0, "dur": 0.0, "wall": _EPOCH0 + (t - _T0),
          "tid": threading.get_ident(),
          "args": {**current_labels(), **attrs}}
    buffer.record(ev)
    return ev


class _NoopSpan:
    """Disabled-path stand-in: a reusable context manager whose enter and
    exit do nothing (one shared instance, no allocation per span)."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        pass

    def set(self, **attrs):
        return self


NOOP_SPAN = _NoopSpan()


def chrome_trace(events: Iterable[dict]) -> dict:
    """Render recorded events as Chrome trace-event JSON (the format
    Perfetto / ``chrome://tracing`` loads): ``ph:"X"`` complete events
    with microsecond timestamps, one row per Python thread."""
    out = []
    for ev in events:
        ce = {"name": ev["name"], "cat": "repro",
              "ph": "X" if ev["ph"] == "X" else "i",
              "ts": ev["ts"] * 1e6, "pid": 0, "tid": ev["tid"],
              "args": {k: v for k, v in ev["args"].items()}}
        if ev["ph"] == "X":
            ce["dur"] = ev["dur"] * 1e6
        else:
            ce["s"] = "t"
        out.append(ce)
    return {"traceEvents": out, "displayTimeUnit": "ms"}
