"""``repro.obs`` — metrics, traces, and exporters for the timing story.

The repo's whole claim is temporal (AoPI is an age; LBCD wins by
replanning fast), so the plan/measure/replan loop measures itself:

  * **metrics** — a process-local registry of counters, gauges and
    log-bucketed histograms with label sets (``policy``, ``family``,
    ``delay_model``, ``solver_backend``), cheap enough to be on by
    default (:mod:`repro.obs.metrics`);
  * **traces** — nested wall-clock spans streaming to JSONL and
    renderable as Chrome trace-event JSON for Perfetto, with
    ``jax.named_scope``/``jax.profiler.TraceAnnotation`` entered inside
    every span so device profiles line up (:mod:`repro.obs.trace`);
  * **exporters** — Prometheus text exposition + JSONL + the
    ``python -m repro.obs.report <run_dir>`` dashboard
    (:mod:`repro.obs.export`, :mod:`repro.obs.report`).

Switches: ``REPRO_OBS=0`` disables everything (every instrumented call
collapses to one boolean check and a shared no-op object — verified
within noise by ``benchmarks/bench_overhead.py``); ``REPRO_OBS_DIR=dir``
streams trace events to ``dir/trace.jsonl`` and registers an atexit hook
writing the full artifact set there. Both are also runtime-settable via
:func:`configure`.

Typical use::

    from repro import obs

    obs.configure(run_dir="results/obs/run0")
    with obs.label_context(policy="lbcd", family="steady"):
        with obs.span("service.plan_window", reason="boundary"):
            plan = service.plan_horizon(8)
    obs.counter("service.early_replans", policy="lbcd").inc()
    print(obs.prometheus_text())
"""
from __future__ import annotations

import atexit
import os

from . import export as _export
from . import trace as _trace
from .metrics import (  # noqa: F401  (re-exported)
    BUCKET_BASE, Counter, Gauge, Histogram, NOOP_METRIC, Registry)
from .trace import (  # noqa: F401
    NOOP_SPAN, Span, chrome_trace, current_labels, label_context)


def _env_enabled() -> bool:
    return os.environ.get("REPRO_OBS", "1").lower() not in (
        "0", "false", "off", "no")


_enabled: bool = _env_enabled()
_registry = Registry()
_buffer = _trace.TraceBuffer()
_run_dir: str | None = None
_atexit_registered = False


def enabled() -> bool:
    """Whether instrumentation is live (the one branch on hot paths)."""
    return _enabled


def registry() -> Registry:
    return _registry


def buffer() -> _trace.TraceBuffer:
    return _buffer


def run_dir() -> str | None:
    return _run_dir


def _flush_at_exit() -> None:
    if _run_dir is not None:
        try:
            _export.write_artifacts(_run_dir, _registry, _buffer)
        except Exception:
            pass


def configure(enabled: bool | None = None,
              run_dir: str | None = None) -> None:
    """Runtime switchboard.

    ``enabled`` toggles all instrumentation; ``run_dir`` starts streaming
    trace events to ``<run_dir>/trace.jsonl`` and registers an atexit
    hook that writes the full artifact set (``metrics.prom``,
    ``metrics.jsonl``, ``trace.json``) there. Pass ``run_dir=""`` to stop
    streaming.
    """
    global _enabled, _run_dir, _atexit_registered
    if enabled is not None:
        _enabled = bool(enabled)
    if run_dir is not None:
        if run_dir == "":
            _run_dir = None
            _buffer.set_stream(None)
        else:
            _run_dir = run_dir
            _buffer.set_stream(os.path.join(run_dir, "trace.jsonl"))
            if not _atexit_registered:
                atexit.register(_flush_at_exit)
                _atexit_registered = True


def reset() -> None:
    """Drop all recorded state and re-read the environment switches
    (test isolation; streaming keeps whatever file it had open)."""
    global _enabled
    _registry.clear()
    _buffer.clear()
    _enabled = _env_enabled()


# Re-arm streaming from the environment at import.
if os.environ.get("REPRO_OBS_DIR"):
    configure(run_dir=os.environ["REPRO_OBS_DIR"])


# ---------------------------------------------------------------------
# Metric accessors — get-or-create on the default registry. Explicit
# labels are merged over the ambient label_context (string values only),
# so a counter bumped inside ``label_context(family="outage")`` lands on
# the ``family="outage"`` series without the call site knowing about
# families.
# ---------------------------------------------------------------------
def _metric_labels(attrs: dict) -> dict:
    """String-valued attrs + the label context become metric labels;
    numeric attrs (slot indices, sizes) stay span-only so they can't
    explode the series cardinality."""
    merged = {**current_labels(), **attrs}
    return {k: v for k, v in merged.items() if isinstance(v, str)}


def counter(name: str, **labels):
    if not _enabled:
        return NOOP_METRIC
    return _registry.counter(name, **_metric_labels(labels))


def gauge(name: str, **labels):
    if not _enabled:
        return NOOP_METRIC
    return _registry.gauge(name, **_metric_labels(labels))


def histogram(name: str, **labels):
    if not _enabled:
        return NOOP_METRIC
    return _registry.histogram(name, **_metric_labels(labels))


def span(name: str, **attrs):
    """Open a wall-clock span (context manager).

    On exit the event lands in the trace buffer/stream AND the duration
    is observed into the ``<name>.seconds`` histogram labeled with the
    string-valued attrs merged over the active :func:`label_context` —
    so every span series doubles as a latency histogram with streaming
    p50/p95/p99.
    """
    if not _enabled:
        return NOOP_SPAN
    metric = _registry.histogram(name + ".seconds", **_metric_labels(attrs))
    return _trace.Span(name, _buffer, attrs, metric=metric)


def event(name: str, **attrs):
    """Record an instant event (and bump the ``<name>.count`` counter)."""
    if not _enabled:
        return None
    _registry.counter(name + ".count", **_metric_labels(attrs)).inc()
    return _trace.record_event(name, _buffer, attrs)


def count_dispatch(name: str, **labels) -> None:
    """Dispatch counter for ``pallas_call``-bearing entry points: bumps
    ``obs.dispatch.count`` labeled by entry point (+ callers' labels).
    Called at trace/dispatch time, it complements the jaxpr-structure
    asserts in ``tests/test_slot_solver.py`` with live counts."""
    if not _enabled:
        return
    _registry.counter("obs.dispatch.count",
                      **_metric_labels({"entry": name, **labels})).inc()


# ---------------------------------------------------------------------
# Exports
# ---------------------------------------------------------------------
def prometheus_text() -> str:
    return _export.prometheus_text(_registry)


def metrics_jsonl() -> str:
    return _export.metrics_jsonl(_registry)


def snapshot() -> list[dict]:
    return _registry.snapshot()


def snapshot_summary() -> dict:
    """Compact provenance stamp (for ``benchmarks/common.run_metadata``):
    every counter/gauge total plus histogram count/p50/p99, aggregated
    over label sets — small enough to ride every ``BENCH_*.json``."""
    agg: dict[str, dict] = {}
    for m in _registry:
        if m.kind == "histogram":
            d = agg.setdefault(m.name, {"count": 0, "sum": 0.0})
            d["count"] += m.count
            d["sum"] += m.total
        else:
            d = agg.setdefault(m.name, {"total": 0.0})
            d["total"] = d.get("total", 0.0) + m.value
    return {"enabled": _enabled, "n_series": len(_registry),
            "n_trace_events": len(_buffer.events()), "metrics": agg}


def write_artifacts(run_dir: str | None = None) -> dict[str, str]:
    """Write ``metrics.prom`` / ``metrics.jsonl`` / ``trace.json`` into
    ``run_dir`` (defaults to the configured one)."""
    target = run_dir or _run_dir
    if target is None:
        raise ValueError("no run_dir: pass one or obs.configure(run_dir=)")
    return _export.write_artifacts(target, _registry, _buffer)


def flush() -> None:
    _buffer.flush()


def events() -> list[dict]:
    return _buffer.events()
