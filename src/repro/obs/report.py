"""``python -m repro.obs.report <run_dir>`` — the timing dashboard.

Reads the artifacts an instrumented run leaves behind
(``trace.jsonl`` streamed live, or the ``trace.json`` Chrome snapshot,
plus ``metrics.jsonl``) and prints the service-latency story per
``policy x family``:

  * plans/sec and p50/p99 ``plan_horizon`` latency, split into boundary
    plans vs divergence-triggered early replans (the p99 *replan*
    latency is the paper-relevant tail: how fast the control plane
    reacts when the model is wrong);
  * early-replan and divergence counters, reconciled against the span
    stream (the counts come from the same instrumented code paths as
    ``AnalyticsService.early_replans``);
  * data-plane measurement throughput (``gi_g1_window`` dispatches) and
    per-backend ``solve_slot`` dispatch timing.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys
from collections import defaultdict

PLAN_SPAN = "service.plan_window"
MEASURE_SPAN = "service.measure_window"
EPOCH_SPAN = "service.run_epoch"
REPLAN_EVENT = "service.early_replan"


def quantile(values: list[float], q: float) -> float:
    """Exact quantile of a list (offline — no bucketing needed)."""
    if not values:
        return 0.0
    s = sorted(values)
    idx = min(max(int(math.ceil(q * len(s))) - 1, 0), len(s) - 1)
    return s[idx]


def load_events(run_dir: str) -> list[dict]:
    """trace.jsonl (one event per line) preferred; fall back to the
    Chrome ``trace.json`` snapshot (converted back to seconds)."""
    jsonl = os.path.join(run_dir, "trace.jsonl")
    if os.path.exists(jsonl):
        events = []
        with open(jsonl) as f:
            for line in f:
                line = line.strip()
                if line:
                    events.append(json.loads(line))
        return events
    chrome = os.path.join(run_dir, "trace.json")
    if os.path.exists(chrome):
        with open(chrome) as f:
            doc = json.load(f)
        return [{"ph": ev.get("ph", "X"), "name": ev["name"],
                 "ts": ev["ts"] / 1e6, "dur": ev.get("dur", 0.0) / 1e6,
                 "args": ev.get("args", {})}
                for ev in doc.get("traceEvents", [])]
    raise FileNotFoundError(
        f"no trace.jsonl or trace.json under {run_dir!r} — run with "
        f"REPRO_OBS_DIR={run_dir} (or obs.configure(run_dir=...))")


def load_metrics(run_dir: str) -> list[dict]:
    path = os.path.join(run_dir, "metrics.jsonl")
    if not os.path.exists(path):
        return []
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def _group(ev: dict) -> tuple[str, str]:
    args = ev.get("args", {})
    return (str(args.get("policy", "?")), str(args.get("family", "?")))


def build_report(events: list[dict], metrics: list[dict]) -> str:
    plans = defaultdict(list)      # (policy, family) -> [dur]
    replans = defaultdict(list)    # early-replan-triggered plan spans
    epochs = defaultdict(int)
    measures = defaultdict(list)
    replan_events = defaultdict(int)
    for ev in events:
        key = _group(ev)
        name = ev["name"]
        if name == PLAN_SPAN:
            plans[key].append(ev["dur"])
            if ev.get("args", {}).get("reason") == "early":
                replans[key].append(ev["dur"])
        elif name == EPOCH_SPAN:
            epochs[key] += 1
        elif name == MEASURE_SPAN:
            measures[key].append(ev["dur"])
        elif name == REPLAN_EVENT:
            replan_events[key] += 1

    div_gauges = {}
    early_counters = {}
    for m in metrics:
        lbl = m.get("labels", {})
        key = (str(lbl.get("policy", "?")), str(lbl.get("family", "?")))
        if m["name"] == "service.divergence":
            div_gauges[key] = m.get("value", 0.0)
        elif m["name"] == REPLAN_EVENT + ".count":
            # One series per scenario — a family spanning several
            # scenarios reconciles against the SUM of its series.
            early_counters[key] = (early_counters.get(key, 0.0)
                                   + m.get("value", 0.0))

    keys = sorted(set(plans) | set(epochs) | set(replan_events)
                  | set(early_counters))
    lines = ["repro.obs report — plan/measure/replan loop", ""]
    hdr = (f"{'policy':<7s} {'family':<14s} {'plans':>6s} {'plans/s':>9s} "
           f"{'p50 plan':>10s} {'p99 plan':>10s} {'replans':>8s} "
           f"{'p99 replan':>11s} {'epochs':>7s} {'div':>8s}")
    lines += [hdr, "-" * len(hdr)]
    for key in keys:
        pol, fam = key
        durs = plans.get(key, [])
        total = sum(durs)
        rate = (len(durs) / total) if total > 0 else 0.0
        n_replan = replan_events.get(key, 0)
        counter_val = early_counters.get(key)
        mismatch = (counter_val is not None
                    and int(counter_val) != n_replan)
        lines.append(
            f"{pol:<7s} {fam:<14s} {len(durs):>6d} {rate:>9.2f} "
            f"{quantile(durs, 0.50) * 1e3:>8.2f}ms "
            f"{quantile(durs, 0.99) * 1e3:>8.2f}ms "
            f"{n_replan:>8d} "
            f"{quantile(replans.get(key, []), 0.99) * 1e3:>9.2f}ms "
            f"{epochs.get(key, 0):>7d} "
            f"{div_gauges.get(key, 0.0):>+8.2%}"
            + ("  [COUNTER MISMATCH]" if mismatch else ""))
    if not keys:
        lines.append("(no service spans recorded)")

    meas_all = [d for v in measures.values() for d in v]
    if meas_all:
        lines += ["", f"data plane: {len(meas_all)} measure_window "
                      f"dispatches, p50 {quantile(meas_all, .5) * 1e3:.2f}ms"
                      f", p99 {quantile(meas_all, .99) * 1e3:.2f}ms"]

    solve = [m for m in metrics if m["name"] == "bcd.solve_slot.seconds"]
    for m in solve:
        q = m.get("quantiles", {})
        lines.append(
            f"solve_slot[{m['labels'].get('solver_backend', '?')}]: "
            f"{m['count']} host dispatches, p50 "
            f"{float(q.get('0.5', 0.0)) * 1e3:.2f}ms, p99 "
            f"{float(q.get('0.99', 0.0)) * 1e3:.2f}ms")
    disp = [m for m in metrics if m["name"] == "obs.dispatch.count"]
    if disp:
        total = sum(m["value"] for m in disp)
        per = ", ".join(
            f"{m['labels'].get('entry', '?')}={m['value']:g}"
            for m in sorted(disp, key=lambda m: -m["value"])[:8])
        lines.append(f"kernel entry traces: {total:g} ({per})")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Text dashboard over a run directory's obs artifacts")
    ap.add_argument("run_dir", help="directory holding trace.jsonl / "
                                    "metrics.jsonl (REPRO_OBS_DIR)")
    args = ap.parse_args(argv)
    events = load_events(args.run_dir)
    metrics = load_metrics(args.run_dir)
    print(build_report(events, metrics))
    return 0


if __name__ == "__main__":
    sys.exit(main())
