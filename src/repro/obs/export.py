"""Exporters: Prometheus text exposition, JSONL dumps, run-dir artifacts.

Three formats, one registry:

  * :func:`prometheus_text` — the text exposition a Prometheus scrape
    (or a human with ``curl``) expects: counters as ``_total``,
    histograms as summaries with ``quantile`` labels plus ``_sum`` /
    ``_count``.
  * :func:`metrics_jsonl` — one JSON object per series, the
    machine-readable twin (this is what ``repro.obs.report`` reads).
  * :func:`write_artifacts` — drop everything into a run directory:
    ``metrics.prom``, ``metrics.jsonl``, ``trace.json`` (Chrome
    trace-event / Perfetto), next to the streamed ``trace.jsonl``.
"""
from __future__ import annotations

import json
import os
import re

from . import trace as trace_mod
from .metrics import Registry

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")
_PROM_TYPES = {"counter": "counter", "gauge": "gauge",
               "histogram": "summary"}


def _prom_name(name: str) -> str:
    return "repro_" + _NAME_RE.sub("_", name)


def _prom_labels(labels: dict, extra: dict | None = None) -> str:
    merged = {**labels, **(extra or {})}
    if not merged:
        return ""
    body = ",".join(
        f'{_NAME_RE.sub("_", str(k))}="{str(v).replace(chr(92), chr(92) * 2).replace(chr(34), chr(92) + chr(34))}"'
        for k, v in sorted(merged.items()))
    return "{" + body + "}"


def prometheus_text(registry: Registry) -> str:
    """Render the registry in Prometheus text exposition format."""
    by_name: dict[str, list] = {}
    kinds: dict[str, str] = {}
    for m in registry:
        by_name.setdefault(m.name, []).append(m)
        kinds[m.name] = m.kind
    lines = []
    for name in sorted(by_name):
        pname = _prom_name(name)
        kind = kinds[name]
        lines.append(f"# TYPE {pname} {_PROM_TYPES[kind]}")
        for m in by_name[name]:
            if kind == "counter":
                lines.append(
                    f"{pname}_total{_prom_labels(m.labels)} {m.value:g}")
            elif kind == "gauge":
                lines.append(
                    f"{pname}{_prom_labels(m.labels)} {m.value:g}")
            else:
                for q, v in m.quantiles().items():
                    lines.append(
                        f"{pname}{_prom_labels(m.labels, {'quantile': q})}"
                        f" {v:g}")
                lines.append(
                    f"{pname}_sum{_prom_labels(m.labels)} {m.total:g}")
                lines.append(
                    f"{pname}_count{_prom_labels(m.labels)} {m.count}")
    return "\n".join(lines) + ("\n" if lines else "")


def metrics_jsonl(registry: Registry) -> str:
    """One JSON object per line per series (``Metric.snapshot()``)."""
    return "".join(json.dumps(snap) + "\n"
                   for snap in registry.snapshot())


def write_artifacts(run_dir: str, registry: Registry,
                    buffer: trace_mod.TraceBuffer) -> dict[str, str]:
    """Write every export format into ``run_dir``; returns the paths.

    Safe to call repeatedly (snapshots overwrite; the streamed
    ``trace.jsonl`` is flushed, not rewritten).
    """
    os.makedirs(run_dir, exist_ok=True)
    paths = {
        "prometheus": os.path.join(run_dir, "metrics.prom"),
        "metrics_jsonl": os.path.join(run_dir, "metrics.jsonl"),
        "chrome_trace": os.path.join(run_dir, "trace.json"),
    }
    with open(paths["prometheus"], "w") as f:
        f.write(prometheus_text(registry))
    with open(paths["metrics_jsonl"], "w") as f:
        f.write(metrics_jsonl(registry))
    with open(paths["chrome_trace"], "w") as f:
        json.dump(trace_mod.chrome_trace(buffer.events()), f)
    buffer.flush()
    if buffer.stream_path:
        paths["trace_jsonl"] = buffer.stream_path
    return paths
