"""Process-local metric registry: counters, gauges, log-bucketed histograms.

The paper's argument is about *time* — AoPI is an age, LBCD wins by
replanning fast enough — so the repo needs to measure its own latency the
same way it measures the fleet's. This registry is the cheap, always-on
substrate: every metric is a plain Python object with a couple of dict
ops per update (no jax, no I/O on the hot path), so instrumented code
stays within noise of uninstrumented code, and ``REPRO_OBS=0`` swaps in
shared no-op singletons whose update methods do literally nothing.

Label sets are free-form keyword labels (``policy``, ``family``,
``delay_model``, ``solver_backend`` are the conventional ones); each
distinct ``(name, labels)`` pair is one time series, exactly the
Prometheus data model so :mod:`repro.obs.export` can emit text
exposition without translation.

Histograms are **log-bucketed**: observations land in geometric buckets
``base**i <= v < base**(i+1)`` with ``base = 2**(1/4)`` (~19% relative
resolution), so streaming p50/p95/p99 extraction is a cumulative walk
over a tiny dict — no reservoir, no sorting, O(1) memory in the number
of observations.
"""
from __future__ import annotations

import dataclasses
import math
import threading
from typing import Iterator

#: Geometric bucket base: 2**(1/4) keeps any quantile estimate within
#: ~9.5% of the true value (half a bucket) while a microsecond-to-hour
#: range still fits in ~90 buckets.
BUCKET_BASE = 2.0 ** 0.25
_LOG_BASE = math.log(BUCKET_BASE)

DEFAULT_QUANTILES = (0.5, 0.95, 0.99)


def _labels_key(labels: dict) -> tuple:
    return tuple(sorted(labels.items()))


class Counter:
    """Monotonic counter. ``inc()`` is one float add under the GIL."""

    __slots__ = ("name", "labels", "value")
    kind = "counter"

    def __init__(self, name: str, labels: dict):
        self.name = name
        self.labels = labels
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def snapshot(self) -> dict:
        return {"name": self.name, "type": self.kind,
                "labels": dict(self.labels), "value": self.value}


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("name", "labels", "value")
    kind = "gauge"

    def __init__(self, name: str, labels: dict):
        self.name = name
        self.labels = labels
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def snapshot(self) -> dict:
        return {"name": self.name, "type": self.kind,
                "labels": dict(self.labels), "value": self.value}


class Histogram:
    """Log-bucketed streaming histogram with quantile extraction.

    ``observe(v)`` costs one ``math.log`` and one dict increment.
    Non-positive observations (a zero-length span on a coarse clock)
    are tracked in a dedicated underflow bucket that quantile extraction
    treats as 0.0.
    """

    __slots__ = ("name", "labels", "buckets", "count", "total",
                 "vmin", "vmax", "zero_count")
    kind = "histogram"

    def __init__(self, name: str, labels: dict):
        self.name = name
        self.labels = labels
        self.buckets: dict[int, int] = {}
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf
        self.zero_count = 0

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v
        if v <= 0.0:
            self.zero_count += 1
            return
        idx = int(math.floor(math.log(v) / _LOG_BASE))
        self.buckets[idx] = self.buckets.get(idx, 0) + 1

    def observe_many(self, values) -> None:
        for v in values:
            self.observe(float(v))

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def quantile(self, q: float) -> float:
        """Streaming quantile: cumulative walk over the sorted buckets,
        returning the geometric midpoint of the bucket holding the
        q-th observation (exact endpoints clamp to observed min/max)."""
        if self.count == 0:
            return 0.0
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile {q} outside [0, 1]")
        target = q * self.count
        seen = self.zero_count
        if seen >= target and self.zero_count:
            return 0.0
        for idx in sorted(self.buckets):
            seen += self.buckets[idx]
            if seen >= target:
                mid = BUCKET_BASE ** (idx + 0.5)
                return min(max(mid, self.vmin), self.vmax)
        return self.vmax

    def quantiles(self, qs=DEFAULT_QUANTILES) -> dict[float, float]:
        return {q: self.quantile(q) for q in qs}

    def snapshot(self) -> dict:
        return {"name": self.name, "type": self.kind,
                "labels": dict(self.labels), "count": self.count,
                "sum": self.total,
                "min": self.vmin if self.count else 0.0,
                "max": self.vmax if self.count else 0.0,
                "quantiles": {str(q): v
                              for q, v in self.quantiles().items()}}


class _NoopMetric:
    """Shared do-nothing stand-in returned when obs is disabled — every
    update method is a constant-time no-op so the ``REPRO_OBS=0`` fast
    path costs one branch plus one call."""

    __slots__ = ()

    def inc(self, n: float = 1.0) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def observe(self, v: float) -> None:
        pass

    def observe_many(self, values) -> None:
        pass


NOOP_METRIC = _NoopMetric()


@dataclasses.dataclass
class Registry:
    """Get-or-create store of metrics keyed by ``(name, labels)``.

    Creation takes a lock (rare); updates go straight to the metric
    object (GIL-atomic dict/float ops). One process-wide default
    registry lives in :mod:`repro.obs` — tests may instantiate private
    ones.
    """

    _metrics: dict = dataclasses.field(default_factory=dict)
    _lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock)

    def _get(self, cls, name: str, labels: dict):
        key = (name, _labels_key(labels))
        m = self._metrics.get(key)
        if m is None:
            with self._lock:
                m = self._metrics.get(key)
                if m is None:
                    m = cls(name, labels)
                    self._metrics[key] = m
        if not isinstance(m, cls):
            raise TypeError(
                f"metric {name!r} already registered as {m.kind}, "
                f"not {cls.kind}")
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get(Histogram, name, labels)

    def __iter__(self) -> Iterator:
        return iter(list(self._metrics.values()))

    def __len__(self) -> int:
        return len(self._metrics)

    def get(self, name: str, **labels):
        """Lookup without creation (None when absent)."""
        return self._metrics.get((name, _labels_key(labels)))

    def collect(self, name: str) -> list:
        """Every series of ``name`` across label sets."""
        return [m for (n, _), m in self._metrics.items() if n == name]

    def total(self, name: str) -> float:
        """Sum of a counter/gauge over all label sets."""
        return sum(m.value for m in self.collect(name))

    def snapshot(self) -> list[dict]:
        return [m.snapshot() for m in self]

    def clear(self) -> None:
        with self._lock:
            self._metrics.clear()
