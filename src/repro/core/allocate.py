"""Bandwidth / computation resource allocators (Algorithm 1, lines 4-5).

Given fixed video configurations, problems (53)/(54) are separable convex
programs with one simplex (budget) constraint per edge server:

    min_b  sum_n A_n(lam_n(b_n), mu_n)   s.t.  sum_{n in s} b_n <= B_s
    min_c  sum_n A_n(lam_n, mu_n(c_n))   s.t.  sum_{n in s} c_n <= C_s

with lam_n = b_n * eff_n / size_n  (Eqs. 1-2) and mu_n = c_n / xi_n (Eq. 3).

Two solvers are provided:

  * ``waterfill_bandwidth`` / ``waterfill_compute`` — **beyond-paper** exact
    KKT water-filling. The per-camera marginal-value functions h_n are
    monotone, so the per-server dual nu_s is found by (log-domain) bisection
    and each camera's allocation by a closed form (LCFSP) or an inner
    bisection (FCFS). Fully vectorized over cameras and servers, jit-safe.

  * ``interior_point`` — the **paper-faithful** log-barrier damped-Newton
    interior-point method. The objective is separable, so the KKT system has
    a diagonal Hessian plus one dual variable per server and solves in
    closed form per iteration.

Both operate in normalized per-server units (x = allocation / budget) so all
quantities are O(1) in float32. Tests assert the two agree to <0.1%.

Constraint (10) (FCFS stability lam < mu) appears as an upper cap on
bandwidth (lam <= lam* < mu, the interior minimizer of the convex A_F) and a
lower floor on compute (mu >= lam * (1 + margin)).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import aopi

_LOG_NU_LO = -34.0   # dual-variable search window (log domain)
_LOG_NU_HI = 34.0
_EPS = 1e-12


# ---------------------------------------------------------------------------
# Marginal value functions  h = -dA/dx  in normalized allocation units.
# ---------------------------------------------------------------------------

def _h_bandwidth(u, lam_scale, mu, p, pol):
    """-dA/du at normalized bandwidth u (lam = lam_scale * u), >= 0 on the
    decreasing branch of A."""
    lam = jnp.maximum(lam_scale * u, _EPS)
    d_l = aopi.d_aopi_lcfsp_dlam(lam, mu, p)
    d_f = aopi.d_aopi_fcfs_dlam(jnp.minimum(lam, 0.999 * mu), mu, p)
    d = jnp.where(pol == aopi.LCFSP, d_l, d_f)
    return jnp.maximum(-d * lam_scale, 0.0)


def _h_compute(v, mu_scale, lam, p, pol):
    """-dA/dv at normalized compute v (mu = mu_scale * v), always >= 0."""
    mu = jnp.maximum(mu_scale * v, _EPS)
    d_l = aopi.d_aopi_lcfsp_dmu(lam, mu, p)
    d_f = aopi.d_aopi_fcfs_dmu(jnp.minimum(lam, 0.999 * mu), mu, p)
    d = jnp.where(pol == aopi.LCFSP, d_l, d_f)
    return jnp.maximum(-d * mu_scale, 0.0)


def _solve_h_equals_nu(h_fn, nu, lo, hi, iters: int = 20):
    """Per-camera inner bisection: largest x in [lo, hi] with h(x) >= nu.

    ``h_fn`` is elementwise-monotone decreasing in x; vectorized over
    cameras. Returns hi where h(hi) >= nu and lo where h(lo) <= nu.
    """
    def body(_, state):
        a, b = state
        mid = 0.5 * (a + b)
        go_up = h_fn(mid) >= nu
        return jnp.where(go_up, mid, a), jnp.where(go_up, b, mid)

    a, b = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return 0.5 * (a + b)


def _waterfill(h_fn, closed_form, lo, hi, server_id, n_servers,
               outer_iters: int = 16, inner_iters: int = 6,
               final_inner_iters: int = 20):
    """Generic per-server water-filling (Illinois outer, nested-bracket
    inner).

    Finds per-server duals nu_s such that sum_{n in s} x_n(nu_s) = 1 (in
    normalized units), where x_n(nu) = clip(solution of h_n(x)=nu, lo, hi).
    ``closed_form(nu)`` gives the exact solution where available (LCFSP);
    cameras with ``closed_form`` returning nan fall back to bisection.

    Two structural accelerations over a flat nested bisection (the whole
    T-slot rollout engine sits on this loop, so the constant factor
    matters):

      * outer: safeguarded false position (Illinois) on the log-dual —
        superlinear on the smooth stretches of the fill curve, bracketing
        always maintained, bisection fallback when the secant degenerates;
      * inner: because x(nu) is monotone decreasing in nu, the outer
        bracket's endpoint solutions (xa at the over-budget price, xb at
        the under-budget price) bracket every interior solution, so the
        per-camera root-find inherits a bracket that shrinks with the
        outer loop and needs only a few iterations per step (plus a pad
        absorbing the inherited bracket's own error).
    """
    def alloc_at(log_nu_s, blo, bhi, iters):
        nu = jnp.exp(log_nu_s)[server_id]
        x_cf = closed_form(nu)
        x_bi = _solve_h_equals_nu(h_fn, nu, blo, bhi, iters)
        x = jnp.where(jnp.isnan(x_cf), x_bi, x_cf)
        return jnp.clip(x, lo, hi)

    def bracket(xa, xb):
        pad = 0.25 * jnp.maximum(xa - xb, 0.0) + 1e-7
        return jnp.maximum(lo, xb - pad), jnp.minimum(hi, xa + pad)

    def fill_at(log_nu_s, xa, xb, iters):
        blo, bhi = bracket(xa, xb)
        x = alloc_at(log_nu_s, blo, bhi, iters)
        f = jax.ops.segment_sum(x, server_id,
                                num_segments=n_servers) - 1.0
        return x, f

    a0 = jnp.full((n_servers,), _LOG_NU_LO)
    b0 = jnp.full((n_servers,), _LOG_NU_HI)
    xa0, fa0 = fill_at(a0, hi, lo, inner_iters + 4)
    xb0, fb0 = fill_at(b0, hi, lo, inner_iters + 4)

    def body(_, state):
        a, b, fa, fb, xa, xb = state
        # Secant point between (a, fa) and (b, fb), clipped to stay well
        # inside the bracket; plain bisection when the secant degenerates.
        denom = fa - fb
        t = jnp.where(jnp.abs(denom) > 1e-12, fa / denom, 0.5)
        t = jnp.clip(t, 0.05, 0.95)
        mid = a + t * (b - a)
        x, f = fill_at(mid, xa, xb, inner_iters)
        over = f > 0.0             # over budget -> raise the price
        over_n = over[server_id]
        return (jnp.where(over, mid, a), jnp.where(over, b, mid),
                jnp.where(over, f, 0.5 * fa),    # Illinois halving of the
                jnp.where(over, 0.5 * fb, f),    # retained endpoint
                jnp.where(over_n, x, xa), jnp.where(over_n, xb, x))

    a, b, _, _, xa, xb = jax.lax.fori_loop(
        0, outer_iters, body, (a0, b0, fa0, fb0, xa0, xb0))
    blo, bhi = bracket(xa, xb)
    # If the total cap is below budget the constraint is slack: keep caps.
    return alloc_at(0.5 * (a + b), blo, bhi, final_inner_iters)


@functools.partial(jax.jit, static_argnames=("n_servers", "outer_iters",
                                             "inner_iters",
                                             "final_inner_iters"))
def waterfill_bandwidth(k, p, pol, mu, server_id, budgets, n_servers,
                        outer_iters: int = 16, inner_iters: int = 6,
                        final_inner_iters: int = 20, active=None):
    """Allocate bandwidth b[n] (Hz) per server budget.

    Args:
      k: lam-per-Hz coefficient, eff_n / size_n  [frames/s/Hz].
      p, pol, mu: per-camera accuracy, policy, fixed computation rate.
      server_id: int[n] in [0, n_servers).
      budgets: float[n_servers] available Hz per server.
      outer/inner/final_inner_iters: solver effort; the defaults reach
        float32 accuracy, Algorithm 1 uses a cheaper setting for its
        interior BCD iterations (only the final allocation must be tight).
      active: optional per-camera churn mask — inactive cameras (0) get
        **exactly** zero allocation (their box collapses to [0, 0], and
        ``_waterfill``'s final ``clip`` pins them there), so their budget
        share redistributes to the live cameras via the segment sums.
    """
    B = budgets[server_id]
    lam_scale = k * B                    # lam at full budget
    # FCFS cap: interior minimizer lam* of A_F; LCFSP cap: the full budget.
    lam_star = aopi.argmin_lam_fcfs(mu, p)
    hi = jnp.where(pol == aopi.LCFSP, 1.0,
                   jnp.minimum(lam_star / jnp.maximum(lam_scale, _EPS), 1.0))
    lo = jnp.full_like(hi, 1e-9)
    if active is not None:
        act = active > 0
        lo = jnp.where(act, lo, 0.0)
        hi = jnp.where(act, hi, 0.0)

    def h_fn(u):
        return _h_bandwidth(u, lam_scale, mu, p, pol)

    def closed_form(nu):
        # LCFSP: (1+1/p) * lam_scale / (lam_scale*u)^2 = nu
        u = jnp.sqrt((1.0 + 1.0 / p) / jnp.maximum(lam_scale * nu, _EPS))
        return jnp.where(pol == aopi.LCFSP, u, jnp.nan)

    u = _waterfill(h_fn, closed_form, lo, hi, server_id, n_servers,
                   outer_iters=outer_iters, inner_iters=inner_iters,
                   final_inner_iters=final_inner_iters)
    return u * B


@functools.partial(jax.jit, static_argnames=("n_servers", "outer_iters",
                                             "inner_iters",
                                             "final_inner_iters"))
def waterfill_compute(inv_xi, p, pol, lam, server_id, budgets, n_servers,
                      stability_margin: float = 1.05,
                      outer_iters: int = 16, inner_iters: int = 6,
                      final_inner_iters: int = 20, active=None):
    """Allocate computation c[n] (FLOPS) per server budget.

    Args:
      inv_xi: mu-per-FLOPS coefficient, 1 / xi(r, m)  [frames/s/FLOPS].
      lam: fixed per-camera transmission rates.
      active: optional per-camera churn mask — see
        :func:`waterfill_bandwidth`; inactive cameras get exactly zero
        compute and free their share for survivors.
    """
    C = budgets[server_id]
    mu_scale = inv_xi * C
    floor = jnp.where(pol == aopi.FCFS,
                      stability_margin * lam / jnp.maximum(mu_scale, _EPS),
                      1e-9)
    if active is not None:
        floor = jnp.where(active > 0, floor, 0.0)
    # Best effort if FCFS floors alone exceed a server's budget.
    floor_tot = jax.ops.segment_sum(floor, server_id, num_segments=n_servers)
    scale = jnp.minimum(1.0, 1.0 / jnp.maximum(floor_tot, _EPS))[server_id]
    floor = floor * scale
    lo = jnp.clip(floor, 1e-9, 1.0)
    hi = jnp.ones_like(lo)
    if active is not None:
        act = active > 0
        lo = jnp.where(act, lo, 0.0)
        hi = jnp.where(act, hi, 0.0)

    def h_fn(v):
        return _h_compute(v, mu_scale, lam, p, pol)

    def closed_form(nu):
        # LCFSP: mu_scale / (p * (mu_scale*v)^2) = nu
        v = jnp.sqrt(1.0 / jnp.maximum(p * mu_scale * nu, _EPS))
        return jnp.where(pol == aopi.LCFSP, v, jnp.nan)

    v = _waterfill(h_fn, closed_form, lo, hi, server_id, n_servers,
                   outer_iters=outer_iters, inner_iters=inner_iters,
                   final_inner_iters=final_inner_iters)
    return v * C


# ---------------------------------------------------------------------------
# Paper-faithful interior-point method (log-barrier + damped Newton).
# ---------------------------------------------------------------------------

def _kkt_step(g, h, x, server_id, n_servers, target_fill):
    """Equality-constrained Newton step with diagonal Hessian.

    Solves  [diag(h)  W^T; W  0] [dx; nu] = [-g; r]  where W is the
    camera->server indicator and r the budget residual.
    """
    h = jnp.maximum(h, 1e-8)
    inv_h = 1.0 / h
    g_over_h = jax.ops.segment_sum(g * inv_h, server_id,
                                   num_segments=n_servers)
    inv_sum = jax.ops.segment_sum(inv_h, server_id, num_segments=n_servers)
    fill = jax.ops.segment_sum(x, server_id, num_segments=n_servers)
    r = target_fill - fill
    nu = (-g_over_h - r) / jnp.maximum(inv_sum, 1e-8)
    dx = -(g + nu[server_id]) * inv_h
    return dx


def interior_point(score_elem, x0, lo, hi, server_id, n_servers,
                   t0: float = 4.0, t_mult: float = 6.0, n_outer: int = 7,
                   n_inner: int = 14):
    """Minimize sum_n score_elem(x_n, n) s.t. per-server sum == budget,
    lo <= x <= hi. The paper's Algorithm-1 interior-point step.

    ``score_elem(x, idx)`` must be per-element (separable) and convex in x.
    ``x0`` must be strictly feasible. All arguments in normalized
    per-server units — the budget enters only through the callers'
    normalization (x = allocation / budget), so no raw budgets are taken.
    """
    def phi_elem(x, idx, t):
        s = score_elem(x, idx)
        barrier = -jnp.log(jnp.maximum(x - lo[idx], _EPS)) \
                  -jnp.log(jnp.maximum(hi[idx] - x, _EPS))
        return t * s + barrier

    d1 = jax.vmap(jax.grad(phi_elem), in_axes=(0, 0, None))
    d2 = jax.vmap(jax.grad(jax.grad(phi_elem)), in_axes=(0, 0, None))
    idxs = jnp.arange(x0.shape[0])
    # The budget is an inequality; when the per-camera caps sum below it the
    # equality target is the (slightly interior) cap total instead.
    cap_tot = jax.ops.segment_sum(hi, server_id, num_segments=n_servers)
    target_fill = jnp.minimum(jnp.ones((n_servers,)), 0.999 * cap_tot)

    def total_phi(x, t):
        return jnp.sum(jax.vmap(phi_elem, in_axes=(0, 0, None))(x, idxs, t))

    def inner(x, t):
        def step(_, x):
            g = d1(x, idxs, t)
            h = d2(x, idxs, t)
            dx = _kkt_step(g, h, x, server_id, n_servers, target_fill)
            # Damped step: largest alpha in a geometric ladder that stays
            # strictly inside the box and does not increase phi.
            alphas = 2.0 ** -jnp.arange(8.0)
            cand = x[None, :] + alphas[:, None] * dx[None, :]
            feas = jnp.all((cand > lo[None, :] + _EPS) &
                           (cand < hi[None, :] - _EPS), axis=1)
            vals = jax.vmap(total_phi, in_axes=(0, None))(cand, t)
            vals = jnp.where(feas, vals, jnp.inf)
            best = jnp.argmin(vals)
            improved = vals[best] < total_phi(x, t)
            return jnp.where(improved, cand[best], x)
        return jax.lax.fori_loop(0, n_inner, step, x)

    def outer(i, x):
        t = t0 * t_mult ** i.astype(jnp.float32)
        return inner(x, t)

    return jax.lax.fori_loop(0, n_outer, outer, x0)


@functools.partial(jax.jit, static_argnames=("n_servers",))
def interior_point_bandwidth(k, p, pol, mu, server_id, budgets, n_servers):
    """Problem (53) via the paper's interior-point method."""
    B = budgets[server_id]
    lam_scale = k * B
    hi = jnp.where(pol == aopi.LCFSP, 1.0,
                   jnp.minimum(0.995 * mu / jnp.maximum(lam_scale, _EPS), 1.0))
    lo = jnp.full_like(hi, 1e-7)
    counts = jax.ops.segment_sum(jnp.ones_like(k), server_id,
                                 num_segments=n_servers)
    x0 = jnp.clip((1.0 / jnp.maximum(counts, 1.0))[server_id], lo + 1e-6,
                  hi - 1e-6)

    def score(x, idx):
        lam = lam_scale[idx] * x
        a_l = aopi.aopi_lcfsp(lam, mu[idx], p[idx])
        lam_c = jnp.minimum(lam, 0.999 * mu[idx])
        a_f = aopi.aopi_fcfs(lam_c, mu[idx], p[idx])
        return jnp.where(pol[idx] == aopi.LCFSP, a_l, a_f)

    u = interior_point(score, x0, lo, hi, server_id, n_servers)
    return u * B


@functools.partial(jax.jit, static_argnames=("n_servers",))
def interior_point_compute(inv_xi, p, pol, lam, server_id, budgets,
                           n_servers, stability_margin: float = 1.05):
    """Problem (54) via the paper's interior-point method."""
    C = budgets[server_id]
    mu_scale = inv_xi * C
    floor = jnp.where(pol == aopi.FCFS,
                      stability_margin * lam / jnp.maximum(mu_scale, _EPS),
                      1e-7)
    floor_tot = jax.ops.segment_sum(floor, server_id, num_segments=n_servers)
    scale = jnp.minimum(1.0, 1.0 / jnp.maximum(floor_tot, _EPS))[server_id]
    lo = jnp.clip(floor * scale, 1e-7, 1.0 - 1e-6)
    hi = jnp.ones_like(lo)
    counts = jax.ops.segment_sum(jnp.ones_like(lam), server_id,
                                 num_segments=n_servers)
    x0 = jnp.clip((1.0 / jnp.maximum(counts, 1.0))[server_id], lo + 1e-6,
                  hi - 1e-6)

    def score(x, idx):
        mu = mu_scale[idx] * x
        a_l = aopi.aopi_lcfsp(lam[idx], mu, p[idx])
        mu_c = jnp.maximum(mu, lam[idx] / 0.999)
        a_f = aopi.aopi_fcfs(lam[idx], mu_c, p[idx])
        return jnp.where(pol[idx] == aopi.LCFSP, a_l, a_f)

    v = interior_point(score, x0, lo, hi, server_id, n_servers)
    return v * C
