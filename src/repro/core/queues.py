"""Discrete-event AoPI simulators — the oracle for Theorems 1-3.

These reproduce the paper's frame-uploading model exactly (§III-A): the
camera uploads a new frame the instant the previous frame's transmission
finishes, so server inter-arrival times equal the (exponential) transmission
times. The edge server runs either an FCFS queue or an LCFS-with-preemption
(LCFSP) single server with exponential service. Each *completed* frame is
accurately recognized with independent probability ``p``.

AoPI(t) = t - generation time of the newest accurately recognized frame
whose result has been delivered by time t. We integrate the piecewise-linear
age curve and return its time average — the quantity Theorems 1 and 2 predict
in closed form. The simulators are fully vectorized numpy (no Python loop
over frames) so multi-million-frame runs used by the validation tests and
``benchmarks/bench_validation.py`` finish in milliseconds.

Generalized (non-exponential) delay draws are supported via the ``t_sampler``
/ ``o_sampler`` hooks, mirroring the paper's testbed observation (§III-B)
that real delays are "more evenly distributed than exponential".

Two implementations live here:

  * the per-stream **numpy oracle** (``simulate_fcfs`` / ``simulate_lcfsp``)
    — the reference the validation tests trust;
  * the **batched device-resident GI/G/1 engine** (``gi_g1_window``) — both
    closed-form recurrences as one jitted JAX program shaped
    ``[n_epochs, n_streams, n_frames]``, with pluggable delay families
    (``DELAY_MODELS``) keyed by collision-free folded ``jax.random`` keys
    and exact age integration truncated at the epoch horizon. One dispatch
    simulates a whole replay window; this is the serving data plane's hot
    path (``serving.service.measure_window``).
"""
from __future__ import annotations

import functools
import math
from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental import enable_x64

from .. import obs

Sampler = Callable[[np.random.Generator, int], np.ndarray]


def _exp_sampler(rate: float) -> Sampler:
    return lambda rng, n: rng.exponential(1.0 / rate, size=n)


@dataclass
class SimResult:
    mean_aopi: float
    horizon: float
    n_frames: int
    n_completed: int
    n_accurate: int

    @property
    def completion_rate(self) -> float:
        return self.n_completed / max(self.horizon, 1e-12)


def _integrate_age(gen_times: np.ndarray, done_times: np.ndarray,
                   accurate: np.ndarray, horizon: float) -> float:
    """Time-average of the age curve.

    ``gen_times[i]``/``done_times[i]``: generation & result-delivery instants
    of completed frames (done_times strictly increasing). Age resets to
    ``done - gen`` at each *accurate* completion and grows at slope 1
    otherwise. Age starts at 0 at t=0 (virtual accurate frame at the origin —
    a vanishing O(1/horizon) bias, identical to the paper's Fig. 2 setup).
    """
    d = done_times[accurate]
    g = gen_times[accurate]
    # Event boundaries: 0, accurate completions, horizon.
    t0 = np.concatenate(([0.0], d))          # segment starts
    age0 = np.concatenate(([0.0], d - g))    # age immediately after reset
    t1 = np.concatenate((d, [horizon]))      # segment ends
    seg = t1 - t0
    # Integral of (age0 + s) ds over each segment.
    area = np.sum(age0 * seg + 0.5 * seg * seg)
    return float(area / horizon)


def simulate_fcfs(lam: float, mu: float, p: float, n_frames: int = 1_000_000,
                  seed: int = 0, t_sampler: Optional[Sampler] = None,
                  o_sampler: Optional[Sampler] = None) -> SimResult:
    """FCFS (x=0) policy simulator.

    Service-start recurrence ``start_i = max(arrive_i, finish_{i-1})`` is
    solved in closed vectorized form: with S_i = cumsum(O)_i,
    finish_i = S_i + running_max_j(arrive_j - S_{j-1}).
    """
    rng = np.random.default_rng(seed)
    T = (t_sampler or _exp_sampler(lam))(rng, n_frames)
    O = (o_sampler or _exp_sampler(mu))(rng, n_frames)
    gen = np.concatenate(([0.0], np.cumsum(T)))[:-1]   # tau_i
    arrive = gen + T                                    # a_i = tau_{i+1}
    S = np.cumsum(O)
    slack = arrive - np.concatenate(([0.0], S[:-1]))
    finish = S + np.maximum.accumulate(slack)
    acc = rng.random(n_frames) < p
    horizon = float(finish[-1])
    mean_age = _integrate_age(gen, finish, acc, horizon)
    return SimResult(mean_age, horizon, n_frames, n_frames, int(acc.sum()))


def simulate_lcfsp(lam: float, mu: float, p: float, n_frames: int = 1_000_000,
                   seed: int = 0, t_sampler: Optional[Sampler] = None,
                   o_sampler: Optional[Sampler] = None) -> SimResult:
    """LCFSP (x=1) policy simulator.

    Every arriving frame immediately seizes the server, preempting (and
    discarding) any frame in service. Frame i (arriving at a_i = tau_{i+1})
    completes iff its service time O_i is shorter than the next frame's
    transmission time T_{i+1}.
    """
    rng = np.random.default_rng(seed)
    T = (t_sampler or _exp_sampler(lam))(rng, n_frames)
    O = (o_sampler or _exp_sampler(mu))(rng, n_frames)
    gen = np.concatenate(([0.0], np.cumsum(T)))[:-1]
    arrive = gen + T
    nxt = np.concatenate((T[1:], [np.inf]))  # T_{i+1}
    completed = O < nxt
    finish = arrive + O
    acc = completed & (rng.random(n_frames) < p)
    horizon = float(arrive[-1] + O[-1] * completed[-1])
    mean_age = _integrate_age(gen[completed], finish[completed],
                              acc[completed], horizon)
    return SimResult(mean_age, horizon, n_frames, int(completed.sum()),
                     int(acc.sum()))


def simulate(lam: float, mu: float, p: float, policy: int, **kw) -> SimResult:
    if lam <= 0.0 or mu <= 0.0:
        # Zero-rate stream (churned-out camera): no frames ever arrive or
        # complete. The samplers would divide by the rate, so short-circuit
        # with an exactly-zero masked result instead of inf/NaN.
        return SimResult(0.0, 0.0, 0, 0, 0)
    return (simulate_lcfsp if policy == 1 else simulate_fcfs)(lam, mu, p, **kw)


def uniform_sampler(mean: float, spread: float = 0.9) -> Sampler:
    """Uniform on [mean*(1-spread), mean*(1+spread)] — the 'more evenly
    distributed than exponential' testbed regime (§III-B / §VI-C1)."""
    lo, hi = mean * (1 - spread), mean * (1 + spread)
    return lambda rng, n: rng.uniform(lo, hi, size=n)


def gamma_sampler(mean: float, shape: float = 2.0) -> Sampler:
    return lambda rng, n: rng.gamma(shape, mean / shape, size=n)


def lognormal_sampler(mean: float, sigma: float | None = None) -> Sampler:
    """Heavy-tailed lognormal with the given mean: ``exp(N(m, sigma^2))``
    with ``m = ln(mean) - sigma^2/2`` so the mean matches the exponential
    model exactly while the tail is fatter (CV ~ 1.31 at sigma = 1)."""
    sigma = LOGNORMAL_SIGMA if sigma is None else sigma
    m = np.log(mean) - 0.5 * sigma * sigma
    return lambda rng, n: rng.lognormal(m, sigma, size=n)


def weibull_sampler(mean: float, shape: float | None = None) -> Sampler:
    """Heavy-tailed Weibull (shape < 1) with the given mean:
    ``scale * W(k)`` with ``scale = mean / Gamma(1 + 1/k)`` (CV ~ 1.46 at
    k = 0.7) — the sub-exponential tail regime where the §III-B testbed
    diverged hardest from M/M/1."""
    shape = WEIBULL_SHAPE if shape is None else shape
    scale = mean / math.gamma(1.0 + 1.0 / shape)
    return lambda rng, n: scale * rng.weibull(shape, size=n)


def oracle_samplers(delay_model: str, lam: float, mu: float) -> dict:
    """``t_sampler``/``o_sampler`` kwargs for :func:`simulate` matching a
    batched-engine ``delay_model`` — the single mapping the loop oracle,
    the engine-rung data plane, and the parity tests share (empty for
    "mm1": the simulators default to exponential draws)."""
    validate_delay_model(delay_model)
    if delay_model == "mm1":
        return {}
    makers = {"uniform": uniform_sampler, "gamma": gamma_sampler,
              "lognormal": lognormal_sampler, "weibull": weibull_sampler}
    make = makers[delay_model]
    return dict(t_sampler=make(1.0 / lam), o_sampler=make(1.0 / mu))


# ---------------------------------------------------------------------------
# Batched device-resident GI/G/1 engine (JAX)
# ---------------------------------------------------------------------------

#: Delay families of the batched engine. Means always match the numpy
#: ``Sampler`` helpers: "mm1" is exponential with mean 1/rate; the rest
#: keep that mean but change the shape — "uniform"/"gamma" are the
#: lighter-than-exponential §III-B testbed regime where Theorems 1-2
#: drift low, "lognormal"/"weibull" are the heavy-tail regime where
#: they drift high.
DELAY_MODELS = ("mm1", "uniform", "gamma", "lognormal", "weibull")
UNIFORM_SPREAD = 0.9     # matches uniform_sampler's default
GAMMA_SHAPE = 2.0        # matches gamma_sampler's default
LOGNORMAL_SIGMA = 1.0    # matches lognormal_sampler's default
WEIBULL_SHAPE = 0.7      # matches weibull_sampler's default (k < 1)

#: Families whose tails overflow the f32 fast path: a single 6-sigma
#: lognormal draw is ~1e2 x the mean, and the running age *area* squares
#: it, so heavy-tail windows always take the float64 branch regardless
#: of frame budget.
HEAVY_TAIL_MODELS = frozenset({"lognormal", "weibull"})

#: Sentinel accepted by the serving layer (`AnalyticsService`,
#: `replay_tables`): fit the family from observed delay telemetry via
#: :func:`fit_delay_model` instead of trusting a flag. The batched
#: engine itself never sees it — `gi_g1_window` requires a concrete
#: family.
AUTO_DELAY_MODEL = "auto"


def validate_delay_model(delay_model: str, *, allow_auto: bool = False) -> str:
    """The single gate every delay-model flag passes through (batched
    engine, oracle samplers, serving layer). Returns the validated name;
    raises ``ValueError`` listing the known families — and the ``"auto"``
    selector sentinel where the caller accepts it."""
    known = DELAY_MODELS + ((AUTO_DELAY_MODEL,) if allow_auto else ())
    if delay_model not in known:
        raise ValueError(
            f"unknown delay_model {delay_model!r}; known: {known}")
    return delay_model

#: Host-side dispatch counter: +1 per batched device call. The hot-path
#: tests assert the replay suite runs entirely through here (no per-stream
#: Python-loop simulation).
BATCH_DISPATCHES = 0


def stream_seed_sequence(seed: int, t: int, i: int) -> np.random.SeedSequence:
    """Collision-free numpy RNG stream for (epoch ``t``, stream ``i``).

    ``SeedSequence(entropy=seed, spawn_key=(t, i))`` hashes the pair into
    the stream key, so distinct ``(t, i)`` never collide — unlike the old
    ``seed + 7919 * t + i`` arithmetic (t=0,i=7919 == t=1,i=0)."""
    return np.random.SeedSequence(entropy=seed, spawn_key=(t, i))


def epoch_key(seed: int, t: int):
    """Folded jax.random key for epoch ``t``; streams fold in their index
    on top (``_window_sim``), so (epoch, stream) keys never collide."""
    return jax.random.fold_in(jax.random.key(seed), t)


def frames_budget(max_lam: float, horizon: float, frames_cap: int,
                  frames_floor: int = 200) -> int:
    """Frames to simulate so arrivals cover ``[0, horizon]`` w.h.p. for
    the fastest stream: ``lam*H`` plus a 2-sigma margin (a rare shortfall
    only shrinks the *measured* window ``h_eff`` — unbiased — instead of
    skewing the estimate), rounded up to a quarter-power-of-two bucket
    (bounds jit recompiles across windows at <= 25% overshoot), capped at
    ``frames_cap``. The floor keeps tiny epochs statistically meaningful;
    age integration truncates at the horizon regardless, so the floor
    never inflates measured AoPI past the epoch."""
    need = float(max_lam) * float(horizon)
    need = max(need + 2.0 * np.sqrt(max(need, 1.0)) + 16.0,
               float(frames_floor), 2.0)
    p2 = 2.0 ** np.floor(np.log2(need))
    for m in (1.0, 1.25, 1.5, 1.75, 2.0):
        if p2 * m >= need:
            return int(min(np.ceil(p2 * m), frames_cap))
    raise AssertionError("unreachable")


#: Compute dtype switch: short per-stream frame budgets run the whole
#: engine in float32 (sequential-sum error ~ n_frames^1.5 * eps stays
#: below 1e-2 of a mean delay up to ~1k frames), longer horizons switch
#: to float64 so multi-hour epochs keep sub-millisecond age resolution
#: (matching the numpy oracle). Deterministic per workload: the dtype is
#: a pure function of the frame budget.
F32_MAX_FRAMES = 1024


def _n_uniforms(delay_model: str) -> int:
    """Uniform variates consumed per frame: T + O + the accuracy coin.
    The Erlang-``k`` gamma family needs ``k`` uniforms per delay."""
    if delay_model == "gamma" and float(GAMMA_SHAPE) == int(GAMMA_SHAPE):
        return 2 * int(GAMMA_SHAPE) + 1
    return 3


def _delays_from_uniforms(u, mean, delay_model: str):
    """``u`` is ``[k, n]`` uniforms -> ``[n]`` positive delays with mean
    ``mean`` (matching the numpy ``Sampler`` helpers)."""
    if delay_model == "mm1":
        return -jnp.log1p(-u[0]) * mean
    if delay_model == "uniform":
        lo = mean * (1.0 - UNIFORM_SPREAD)
        return lo + u[0] * (2.0 * UNIFORM_SPREAD * mean)
    if delay_model == "gamma":
        # Integer shape -> Erlang: an exact sum of k exponentials. Orders
        # of magnitude faster than jax.random.gamma's rejection sampler
        # (a vmapped while_loop) on CPU at data-plane frame counts.
        k = int(GAMMA_SHAPE)
        if float(GAMMA_SHAPE) == k:
            return -jnp.log1p(-u).sum(axis=0) * (mean / GAMMA_SHAPE)
    if delay_model == "lognormal":
        # Inverse-CDF: exp(m + sigma * Phi^-1(u)) with the mean-matching
        # log-location m = ln(mean) - sigma^2/2. Clip u away from {0, 1}
        # so ndtri stays finite (u=0 would give a literal zero delay).
        uc = jnp.clip(u[0], 1e-7, 1.0 - 1e-7)
        m = jnp.log(mean) - 0.5 * LOGNORMAL_SIGMA * LOGNORMAL_SIGMA
        return jnp.exp(m + LOGNORMAL_SIGMA * jax.scipy.special.ndtri(uc))
    if delay_model == "weibull":
        # Inverse-CDF: scale * (-ln(1-u))^(1/k), mean-matched via
        # scale = mean / Gamma(1 + 1/k). k < 1 => sub-exponential tail.
        scale = mean / math.gamma(1.0 + 1.0 / WEIBULL_SHAPE)
        return scale * jnp.power(-jnp.log1p(-u[0]), 1.0 / WEIBULL_SHAPE)
    raise ValueError(
        f"unknown delay_model {delay_model!r}; known: {DELAY_MODELS}")


#: Streams per epoch whose raw transmission delays are surfaced when
#: ``collect_samples`` is set — enough for the CvM selector to pool a
#: few thousand draws without shipping the whole [E, N, F] tensor host-side.
SAMPLE_STREAM_CAP = 32


@functools.partial(jax.jit, static_argnames=(
    "n_frames", "delay_model", "collect_samples"))
def _window_sim(lam, mu, p, pol, keys, horizon, n_frames: int,
                delay_model: str, collect_samples: int = 0):
    """The fused data-plane program: ONE ``lax.scan`` over the frame axis
    with ``[E * N]``-wide vector carries.

    Single-pass recurrences (like the numpy oracle's cumsums, unlike
    XLA's O(n log n) associative cumulative ops) batched across every
    (epoch, stream) pair of the window, with the exact piecewise-linear
    age integral accumulated forward in the same pass — so the whole
    window is one dispatch whose per-step body is a handful of fused
    elementwise ops on the flattened stream vector.
    """
    e, n = lam.shape
    dtype = lam.dtype
    flat = lambda x: x.reshape(e * n)
    lam, mu, p = flat(lam), flat(mu), flat(p)
    is_lcfsp = flat(pol) == 1

    # Collision-free per-(epoch, stream) keys; all of a stream's variates
    # come from one bulk uniform draw under its own key.
    stream_keys = jax.vmap(
        lambda ke: jax.vmap(jax.random.fold_in, (None, 0))(
            ke, jnp.arange(n)))(keys)
    k = _n_uniforms(delay_model)
    ku, ko = k // 2, (k - 1) - k // 2

    def draw(key):
        u = jax.random.uniform(key, (k, n_frames), dtype)
        return u

    u = jax.vmap(draw)(stream_keys.reshape(e * n))       # [EN, k, F]
    T = _delays_from_uniforms(
        jnp.moveaxis(u[:, :ku], 0, -1), 1.0 / lam, delay_model)
    O = _delays_from_uniforms(
        jnp.moveaxis(u[:, ku:ku + ko], 0, -1), 1.0 / mu, delay_model)
    coin = jnp.moveaxis(u[:, -1], 0, -1)                 # [F, EN]
    # LCFSP completion needs the NEXT transmission time at each step.
    T_next = jnp.concatenate(
        [T[1:], jnp.full((1, e * n), jnp.inf, dtype)])
    # Effective horizon: the epoch, unless the frame budget (frames_cap)
    # ran out of arrivals first — then measure over the simulated window
    # instead of counting the uncovered tail as pure age growth.
    h_eff = jnp.minimum(jnp.asarray(horizon, dtype), T.sum(axis=0))
    zero = jnp.zeros(e * n, dtype)

    def step(carry, xs):
        a, s, m, last_t, age0, area, n_arr, n_done, n_acc = carry
        t_f, t_nxt, o_f, u_f = xs
        a = a + t_f                            # arrival a_i = tau_{i+1}
        gen = a - t_f                          # generation tau_i
        s = s + o_f                            # cumsum of service times
        m = jnp.maximum(m, a - (s - o_f))      # running max idle slack
        finish = jnp.where(is_lcfsp, a + o_f, s + m)
        completed = jnp.where(is_lcfsp, o_f < t_nxt, True)
        done = completed & (finish <= h_eff)
        valid = done & (u_f < p)
        # Age resets to finish - gen at each valid event; events are
        # nondecreasing in time, so accumulate the closed segment.
        seg = jnp.where(valid, finish - last_t, zero)
        area = area + age0 * seg + 0.5 * seg * seg
        last_t = jnp.where(valid, finish, last_t)
        age0 = jnp.where(valid, finish - gen, age0)
        n_arr = n_arr + (a <= h_eff)
        n_done = n_done + done
        n_acc = n_acc + valid
        return (a, s, m, last_t, age0, area, n_arr, n_done, n_acc), None

    init = (zero, zero, jnp.full(e * n, -jnp.inf, dtype), zero, zero,
            zero, zero, zero, zero)
    (a, s, m, last_t, age0, area, n_arr, n_done, n_acc), _ = lax.scan(
        step, init, (T, T_next, O, coin))
    # Final open segment up to the effective horizon.
    seg = jnp.maximum(h_eff - last_t, zero)
    area = area + age0 * seg + 0.5 * seg * seg
    shape = lambda x: x.reshape(e, n)
    out = {
        "aopi": shape(area / h_eff),
        "horizon": shape(h_eff),
        "n_frames": shape(n_arr),
        "n_completed": shape(n_done),
        "n_accurate": shape(n_acc),
    }
    if collect_samples:
        # Raw transmission delays for the fitted selector: the camera
        # uploads back-to-back (§III-A), so inter-arrival == transmission
        # times, i.e. the T draws ARE family-distributed observations.
        capf = min(int(collect_samples), n_frames)
        ns = min(n, SAMPLE_STREAM_CAP)
        samp = T[:capf].reshape(capf, e, n)[:, :, :ns]
        out["delay_samples"] = jnp.moveaxis(samp, 0, -1)   # [E, ns, capf]
    return out


def gi_g1_window(lam, mu, p, pol, *, seed: int = 0, t0: int = 0,
                 n_frames: int, horizon: float,
                 delay_model: str = "mm1", active=None,
                 collect_samples: int = 0) -> dict:
    """Simulate ``[E, N]`` GI/G/1 streams (E epochs x N streams) in ONE
    jitted device dispatch.

    Per (epoch ``t0+e``, stream ``i``): ``n_frames`` transmission/service
    delays are drawn from ``delay_model`` with means ``1/lam``/``1/mu``
    under the collision-free key ``fold_in(fold_in(key(seed), t), i)``,
    both queueing recurrences are solved in closed vectorized form, and
    the exact age integral is truncated at ``horizon`` seconds — measured
    AoPI reflects the epoch even when ``n_frames`` extends past it. If a
    stream's frame budget runs out *before* the horizon (``frames_cap``),
    the integral covers the simulated window instead (the per-stream
    effective horizon is returned).

    Dead streams — ``lam <= 0`` or ``mu <= 0``, or masked out by the
    optional ``active`` ``[E, N]`` fleet-churn mask — are simulated on
    rate-clamped stand-ins and then zeroed in every output array, so the
    window stays one fused dispatch and fleet reductions stay finite.
    Live lanes are bitwise identical to an unmasked call.

    ``collect_samples > 0`` additionally returns ``delay_samples``
    ``[E, min(N, SAMPLE_STREAM_CAP), collect_samples]`` — the raw
    transmission-delay draws (exactly family-distributed, since uploads
    are back-to-back) for the telemetry-fitted :func:`fit_delay_model`
    selector. Dead-lane samples are zeroed.

    One ``lax.scan`` over the frame axis carries every (epoch, stream)
    recurrence as an ``[E*N]`` vector — single-pass like the numpy
    oracle's cumsums, but batched across the whole window. Short frame
    budgets (<= ``F32_MAX_FRAMES``) run in float32; longer horizons
    switch to float64 (scoped ``enable_x64``) so multi-hour epochs keep
    sub-millisecond age resolution, matching the oracle. Returns host
    numpy: ``aopi``/``horizon``/``n_frames``/``n_completed``/
    ``n_accurate``, each ``[E, N]``.
    """
    validate_delay_model(delay_model)
    global BATCH_DISPATCHES
    n_frames = int(n_frames)
    # Heavy tails force the f64 branch: the f32 <= 1024-frames fast path
    # relies on delays staying within a few means of each other, which a
    # sub-exponential tail violates (see HEAVY_TAIL_MODELS).
    use_f64 = n_frames > F32_MAX_FRAMES or delay_model in HEAVY_TAIL_MODELS
    dtype = np.float64 if use_f64 else np.float32
    lam = np.atleast_2d(np.asarray(lam, dtype))
    mu_h = np.atleast_2d(np.asarray(mu, dtype))
    live = (lam > 0.0) & (mu_h > 0.0)
    if active is not None:
        live = live & (np.atleast_2d(np.asarray(active)) > 0.0)
    e, n = lam.shape
    obs.histogram("queues.batch_elems",
                  delay_model=delay_model).observe(e * n * n_frames)
    with obs.span("queues.gi_g1_window", delay_model=delay_model,
                  epochs=e, streams=n, n_frames=n_frames), enable_x64():
        keys = jax.vmap(jax.random.fold_in, (None, 0))(
            jax.random.key(int(seed)), jnp.arange(t0, t0 + e))
        out = _window_sim(
            jnp.asarray(np.maximum(lam, dtype(1e-6))),
            jnp.asarray(np.maximum(mu_h, dtype(1e-6))),
            jnp.asarray(np.clip(
                np.atleast_2d(np.asarray(p, dtype)), 1e-3, 1.0)),
            jnp.asarray(np.atleast_2d(np.asarray(pol, np.int32))),
            keys, float(horizon), n_frames, str(delay_model),
            int(collect_samples))
        out = {k: np.asarray(v, np.float64) for k, v in out.items()}
        if not live.all():
            # Dead lanes ran on clamped stand-in rates — zero them out.
            samples = out.pop("delay_samples", None)
            out = {k: np.where(live, v, 0.0) for k, v in out.items()}
            if samples is not None:
                ns = samples.shape[1]
                out["delay_samples"] = np.where(
                    live[:, :ns, None], samples, 0.0)
    BATCH_DISPATCHES += 1
    obs.counter("queues.batch_dispatches", delay_model=delay_model).inc()
    return out


# ---------------------------------------------------------------------------
# Telemetry-fitted delay-model selector
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class DelayFit:
    """Result of :func:`fit_delay_model`: the winning family plus the
    per-family Cramér–von Mises residuals it beat (smaller = closer)
    and the winner's fitted shape parameters (``{"sigma": ...}`` for
    lognormal, ``{"k": ...}`` for weibull, empty for the shape-free
    families)."""
    model: str
    residuals: dict
    n_samples: int
    params: dict = field(default_factory=dict)


#: CvM estimation grids for the shape-parameterized families: the fit
#: is a joint (family, shape) minimization, not just family selection.
#: The defaults (LOGNORMAL_SIGMA=1.0, WEIBULL_SHAPE=0.7) are grid
#: members, so default-parameter worlds round-trip exactly; the weibull
#: grid stays strictly below k=1 (k=1 IS the exponential — it belongs
#: to "mm1").
LOGNORMAL_SIGMA_GRID = (0.5, 0.75, 1.0, 1.25, 1.5)
WEIBULL_SHAPE_GRID = (0.5, 0.6, 0.7, 0.8, 0.9)

_FAMILY_GRIDS = {"lognormal": ("sigma", LOGNORMAL_SIGMA_GRID),
                 "weibull": ("k", WEIBULL_SHAPE_GRID)}


def _family_cdf(x: np.ndarray, delay_model: str,
                params: dict | None = None) -> np.ndarray:
    """CDF of the unit-mean member of ``delay_model`` evaluated at ``x``
    (x >= 0). Each family is parameterized exactly as the samplers /
    ``_delays_from_uniforms`` are, with the mean pinned to 1; ``params``
    overrides the shape (``sigma`` for lognormal, ``k`` for weibull),
    defaulting to the sampler constants."""
    params = params or {}
    if delay_model == "mm1":
        return -np.expm1(-x)
    if delay_model == "uniform":
        lo, width = 1.0 - UNIFORM_SPREAD, 2.0 * UNIFORM_SPREAD
        return np.clip((x - lo) / width, 0.0, 1.0)
    if delay_model == "gamma":
        # Erlang-k with mean 1 => rate k. Closed form for integer k.
        k = int(GAMMA_SHAPE)
        terms = sum((k * x) ** j / math.factorial(j) for j in range(k))
        return -np.expm1(-k * x) - np.exp(-k * x) * (terms - 1.0)
    if delay_model == "lognormal":
        from scipy.special import ndtr
        s = float(params.get("sigma", LOGNORMAL_SIGMA))
        m = -0.5 * s * s
        safe = np.maximum(x, 1e-300)
        return np.where(x > 0.0, ndtr((np.log(safe) - m) / s), 0.0)
    if delay_model == "weibull":
        k = float(params.get("k", WEIBULL_SHAPE))
        scale = 1.0 / math.gamma(1.0 + 1.0 / k)
        return -np.expm1(-np.power(np.maximum(x, 0.0) / scale, k))
    raise ValueError(
        f"unknown delay_model {delay_model!r}; known: {DELAY_MODELS}")


def family_cv2(delay_model: str, params: dict | None = None) -> float:
    """Squared coefficient of variation of a delay family (optionally at
    fitted shape ``params``) — the tail statistic that drives how far
    the exponential closed forms drift: 1 for mm1, < 1 for the light
    §III-B families, > 1 for the heavy tails."""
    validate_delay_model(delay_model)
    params = params or {}
    if delay_model == "mm1":
        return 1.0
    if delay_model == "uniform":
        return UNIFORM_SPREAD ** 2 / 3.0
    if delay_model == "gamma":
        return 1.0 / float(GAMMA_SHAPE)
    if delay_model == "lognormal":
        s = float(params.get("sigma", LOGNORMAL_SIGMA))
        return float(np.expm1(s * s))
    k = float(params.get("k", WEIBULL_SHAPE))
    g1 = math.gamma(1.0 + 1.0 / k)
    return math.gamma(1.0 + 2.0 / k) / (g1 * g1) - 1.0


def residual_prior(delay_model: str, params: dict | None = None) -> float:
    """Kingman-style residual scale prior for the planner: GI/G/1
    waiting time scales like ``(C_a^2 + C_s^2) / 2`` relative to M/M/1,
    so a fitted family's ``(1 + cv^2) / 2`` (both T and O drawn from the
    family) is the first-order correction to the exponential closed
    forms — exactly 1 for mm1, so seeding with it is a no-op when the
    world matches the paper's model."""
    return 0.5 * (1.0 + family_cv2(delay_model, params))


def fit_delay_model(samples, models: Sequence[str] = DELAY_MODELS,
                    min_samples: int = 8) -> DelayFit:
    """Pick the (delay family, shape parameters) with the smallest
    Cramér–von Mises residual against observed delay samples.

    ``samples`` is any array of positive delay observations (pooled
    inter-completion / transmission times from telemetry; zeros — masked
    dead-lane fill — are dropped). Each candidate family is mean-matched
    to the sample mean, its CDF evaluated at the sorted samples, and the
    mean squared distance to the empirical CDF ``(i - 0.5)/n`` taken as
    the residual; the shape-parameterized families (lognormal sigma,
    weibull k) additionally minimize over their estimation grids, and
    the winner's fitted shape is returned on ``DelayFit.params``. Falls
    back to "mm1" (the paper's modeling assumption) below
    ``min_samples`` observations.
    """
    x = np.asarray(samples, np.float64).ravel()
    x = x[np.isfinite(x) & (x > 0.0)]
    n = x.size
    if n < min_samples:
        return DelayFit("mm1", {}, n)
    x = np.sort(x) / x.mean()                 # mean-matched, unit scale
    ecdf = (np.arange(1, n + 1) - 0.5) / n
    cvm = lambda m, prm: float(np.mean((_family_cdf(x, m, prm) - ecdf) ** 2))
    residuals: dict = {}
    params: dict = {}
    for m in models:
        grid = _FAMILY_GRIDS.get(m)
        if grid is None:
            residuals[m], params[m] = cvm(m, None), {}
        else:
            pname, values = grid
            cand = {v: cvm(m, {pname: v}) for v in values}
            v = min(cand, key=cand.get)
            residuals[m], params[m] = cand[v], {pname: float(v)}
    best = min(residuals, key=residuals.get)
    return DelayFit(best, residuals, n, params[best])
