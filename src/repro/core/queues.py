"""Discrete-event AoPI simulators — the oracle for Theorems 1-3.

These reproduce the paper's frame-uploading model exactly (§III-A): the
camera uploads a new frame the instant the previous frame's transmission
finishes, so server inter-arrival times equal the (exponential) transmission
times. The edge server runs either an FCFS queue or an LCFS-with-preemption
(LCFSP) single server with exponential service. Each *completed* frame is
accurately recognized with independent probability ``p``.

AoPI(t) = t - generation time of the newest accurately recognized frame
whose result has been delivered by time t. We integrate the piecewise-linear
age curve and return its time average — the quantity Theorems 1 and 2 predict
in closed form. The simulators are fully vectorized numpy (no Python loop
over frames) so multi-million-frame runs used by the validation tests and
``benchmarks/bench_validation.py`` finish in milliseconds.

Generalized (non-exponential) delay draws are supported via the ``t_sampler``
/ ``o_sampler`` hooks, mirroring the paper's testbed observation (§III-B)
that real delays are "more evenly distributed than exponential".
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import numpy as np

Sampler = Callable[[np.random.Generator, int], np.ndarray]


def _exp_sampler(rate: float) -> Sampler:
    return lambda rng, n: rng.exponential(1.0 / rate, size=n)


@dataclass
class SimResult:
    mean_aopi: float
    horizon: float
    n_frames: int
    n_completed: int
    n_accurate: int

    @property
    def completion_rate(self) -> float:
        return self.n_completed / max(self.horizon, 1e-12)


def _integrate_age(gen_times: np.ndarray, done_times: np.ndarray,
                   accurate: np.ndarray, horizon: float) -> float:
    """Time-average of the age curve.

    ``gen_times[i]``/``done_times[i]``: generation & result-delivery instants
    of completed frames (done_times strictly increasing). Age resets to
    ``done - gen`` at each *accurate* completion and grows at slope 1
    otherwise. Age starts at 0 at t=0 (virtual accurate frame at the origin —
    a vanishing O(1/horizon) bias, identical to the paper's Fig. 2 setup).
    """
    d = done_times[accurate]
    g = gen_times[accurate]
    # Event boundaries: 0, accurate completions, horizon.
    t0 = np.concatenate(([0.0], d))          # segment starts
    age0 = np.concatenate(([0.0], d - g))    # age immediately after reset
    t1 = np.concatenate((d, [horizon]))      # segment ends
    seg = t1 - t0
    # Integral of (age0 + s) ds over each segment.
    area = np.sum(age0 * seg + 0.5 * seg * seg)
    return float(area / horizon)


def simulate_fcfs(lam: float, mu: float, p: float, n_frames: int = 1_000_000,
                  seed: int = 0, t_sampler: Optional[Sampler] = None,
                  o_sampler: Optional[Sampler] = None) -> SimResult:
    """FCFS (x=0) policy simulator.

    Service-start recurrence ``start_i = max(arrive_i, finish_{i-1})`` is
    solved in closed vectorized form: with S_i = cumsum(O)_i,
    finish_i = S_i + running_max_j(arrive_j - S_{j-1}).
    """
    rng = np.random.default_rng(seed)
    T = (t_sampler or _exp_sampler(lam))(rng, n_frames)
    O = (o_sampler or _exp_sampler(mu))(rng, n_frames)
    gen = np.concatenate(([0.0], np.cumsum(T)))[:-1]   # tau_i
    arrive = gen + T                                    # a_i = tau_{i+1}
    S = np.cumsum(O)
    slack = arrive - np.concatenate(([0.0], S[:-1]))
    finish = S + np.maximum.accumulate(slack)
    acc = rng.random(n_frames) < p
    horizon = float(finish[-1])
    mean_age = _integrate_age(gen, finish, acc, horizon)
    return SimResult(mean_age, horizon, n_frames, n_frames, int(acc.sum()))


def simulate_lcfsp(lam: float, mu: float, p: float, n_frames: int = 1_000_000,
                   seed: int = 0, t_sampler: Optional[Sampler] = None,
                   o_sampler: Optional[Sampler] = None) -> SimResult:
    """LCFSP (x=1) policy simulator.

    Every arriving frame immediately seizes the server, preempting (and
    discarding) any frame in service. Frame i (arriving at a_i = tau_{i+1})
    completes iff its service time O_i is shorter than the next frame's
    transmission time T_{i+1}.
    """
    rng = np.random.default_rng(seed)
    T = (t_sampler or _exp_sampler(lam))(rng, n_frames)
    O = (o_sampler or _exp_sampler(mu))(rng, n_frames)
    gen = np.concatenate(([0.0], np.cumsum(T)))[:-1]
    arrive = gen + T
    nxt = np.concatenate((T[1:], [np.inf]))  # T_{i+1}
    completed = O < nxt
    finish = arrive + O
    acc = completed & (rng.random(n_frames) < p)
    horizon = float(arrive[-1] + O[-1] * completed[-1])
    mean_age = _integrate_age(gen[completed], finish[completed],
                              acc[completed], horizon)
    return SimResult(mean_age, horizon, n_frames, int(completed.sum()),
                     int(acc.sum()))


def simulate(lam: float, mu: float, p: float, policy: int, **kw) -> SimResult:
    return (simulate_lcfsp if policy == 1 else simulate_fcfs)(lam, mu, p, **kw)


def uniform_sampler(mean: float, spread: float = 0.9) -> Sampler:
    """Uniform on [mean*(1-spread), mean*(1+spread)] — the 'more evenly
    distributed than exponential' testbed regime (§III-B / §VI-C1)."""
    lo, hi = mean * (1 - spread), mean * (1 + spread)
    return lambda rng, n: rng.uniform(lo, hi, size=n)


def gamma_sampler(mean: float, shape: float = 2.0) -> Sampler:
    return lambda rng, n: rng.gamma(shape, mean / shape, size=n)
