"""Algorithm 1 — block coordinate descent over the one-slot problem (P2).

Three blocks, iterated M times (paper §V-B):

  line 3: video configuration (r, x, m)  — vectorized exhaustive search over
          the (model x resolution x policy) grid, per camera;
  line 4: bandwidth allocation b         — convex, via water-filling or the
          paper's interior-point method (repro.core.allocate);
  line 5: computation allocation c       — same.

Everything is jit-compiled with static (N, M, R, S); the whole solve runs in
a few hundred microseconds for N=30 on CPU (benchmarks/bench_overhead.py).

Both per-camera blocks have two implementations behind
``solver_backend="jnp" | "pallas"``: the pure-jnp reference and the fused
``repro.kernels.slot_solver`` kernels (streaming config argmin, one-dispatch
on-chip water-filling) — float32-tolerance equivalent, benchmarked in
``benchmarks/bench_slot_solver.py``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from . import allocate, aopi
from ..kernels import slot_solver

# Fleet size at which the pallas kernels start winning. Below one 128-lane
# tile the kernels pad every camera vector up to 128 lanes and lose to the
# plain jnp path (BENCH_slot_solver.json: N=30 is 0.67x, N=300 is 1.2-1.6x),
# so ``solver_backend="auto"`` stays on jnp under this threshold.
AUTO_PALLAS_MIN_CAMERAS = 128

SOLVER_BACKENDS = ("jnp", "pallas", "auto")


def resolve_backend(solver_backend: str, n_cameras: int,
                    method: str = "waterfill") -> str:
    """Resolve ``solver_backend`` to a concrete backend for a fleet size.

    ``"auto"`` picks jnp below :data:`AUTO_PALLAS_MIN_CAMERAS` (lane-padding
    regime) and pallas at or above it; ``method="interior"`` is jnp-only so
    auto never selects pallas for it. Explicit backends pass through
    unchanged (including the pallas+interior error path in ``solve_slot``).
    """
    if solver_backend not in SOLVER_BACKENDS:
        raise ValueError(f"unknown solver_backend {solver_backend!r}; "
                         f"known: {SOLVER_BACKENDS}")
    if solver_backend != "auto":
        return solver_backend
    if method != "waterfill":
        return "jnp"
    return "pallas" if n_cameras >= AUTO_PALLAS_MIN_CAMERAS else "jnp"


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SlotDecision:
    """Output of one Algorithm-1 solve (all per-camera arrays)."""
    r_idx: jnp.ndarray        # resolution index into tables.size
    m_idx: jnp.ndarray        # model index
    pol: jnp.ndarray          # 0 FCFS / 1 LCFSP
    b: jnp.ndarray            # Hz
    c: jnp.ndarray            # FLOPS
    lam: jnp.ndarray          # frames/s
    mu: jnp.ndarray           # frames/s
    acc: jnp.ndarray          # recognition accuracy p_{n,t}
    aopi: jnp.ndarray         # closed-form per-camera AoPI
    score: jnp.ndarray        # scalar drift-plus-penalty value

    def as_numpy(self) -> "SlotDecision":
        return SlotDecision(*(np.asarray(v) for v in dataclasses.astuple(self)))


def _rates(b, c, r_idx, m_idx, eff, size, xi):
    lam = b * eff / size[r_idx]                       # Eqs. (1)-(2)
    mu = c / xi[m_idx, r_idx]                         # Eq. (3)
    return lam, mu


@functools.partial(jax.jit,
                   static_argnames=("n_servers", "n_iters", "method",
                                    "solver_effort", "solver_backend",
                                    "interpret"))
def solve_slot(acc, xi, size, eff, server_id, budgets_b, budgets_c, q, V,
               n_servers: int, n_iters: int = 4,
               method: Literal["waterfill", "interior"] = "waterfill",
               solver_effort: Literal["fast", "seed"] = "fast",
               solver_backend: Literal["jnp", "pallas", "auto"] = "jnp",
               interpret: bool | None = None):
    """Run Algorithm 1 and return a SlotDecision (of jnp arrays).

    Args:
      acc:  [N, M, R] profiled accuracy zeta_n^t(r, m).
      xi:   [M, R]    FLOPs per frame.
      size: [R]       bits per frame.
      eff:  [N]       link spectral efficiency (bits/s/Hz).
      server_id: [N]  camera -> server assignment (Algorithm 2's output).
      budgets_b/_c: [n_servers] available Hz / FLOPS.
      q, V: Lyapunov queue value and penalty weight.
      solver_effort: "fast" (default) uses cheap water-filling effort inside
        the BCD loop plus one full-precision re-allocation; "seed"
        reproduces the pre-refactor flat high-iteration effort (kept for
        benchmarks measuring what the rollout-stack rework bought).
      solver_backend: "jnp" (default) runs the pure-jnp config search and
        water-filling; "pallas" fuses both into the
        ``repro.kernels.slot_solver`` kernels (streaming config argmin, one
        on-chip water-fill dispatch per allocation); "auto" picks per fleet
        size via :func:`resolve_backend` (jnp below
        ``AUTO_PALLAS_MIN_CAMERAS``, pallas at/above). Pallas requires
        ``method="waterfill"``; agrees with "jnp" to float32 tolerance.
      interpret: pallas interpret-mode override (None = auto: interpret
        everywhere except on real TPUs — the CPU/CI path).
    """
    solver_backend = resolve_backend(solver_backend, acc.shape[0],
                                     method=method)
    use_pallas = solver_backend == "pallas"
    if use_pallas and method != "waterfill":
        raise ValueError("solver_backend='pallas' fuses the water-filling "
                         "solver; method='interior' only supports the jnp "
                         "backend")
    n = acc.shape[0]
    counts = jax.ops.segment_sum(jnp.ones((n,)), server_id,
                                 num_segments=n_servers)
    share = (1.0 / jnp.maximum(counts, 1.0))[server_id]
    b = budgets_b[server_id] * share
    c = budgets_c[server_id] * share

    if use_pallas:
        # One static layout per solve: the (possibly traced) assignment is
        # sorted/padded into per-server rows the kernel programs own.
        layout = slot_solver.server_layout(server_id, n_servers)
        config = functools.partial(slot_solver.config_argmin,
                                   backend="pallas", interpret=interpret)
        wf_b = functools.partial(slot_solver.waterfill_bandwidth,
                                 layout=layout, interpret=interpret)
        wf_c = functools.partial(slot_solver.waterfill_compute,
                                 layout=layout, interpret=interpret)
    else:
        config = functools.partial(slot_solver.config_argmin, backend="jnp")
        wf_b = allocate.waterfill_bandwidth
        wf_c = allocate.waterfill_compute

    polish = method == "waterfill" and solver_effort == "fast"
    if polish:
        # Cheap solver effort inside the BCD loop (it only has to steer the
        # discrete config selection); one accurate re-allocation afterwards.
        cheap = dict(outer_iters=10, inner_iters=3, final_inner_iters=5)
        fb = functools.partial(wf_b, **cheap)
        fc = functools.partial(wf_c, **cheap)
    elif method == "waterfill":
        # Pre-refactor effort: flat high-iteration water-filling each pass.
        seed_kw = dict(outer_iters=54, inner_iters=40, final_inner_iters=40)
        fb = functools.partial(wf_b, **seed_kw)
        fc = functools.partial(wf_c, **seed_kw)
    else:
        fb = allocate.interior_point_bandwidth
        fc = allocate.interior_point_compute

    def body(_, state):
        b, c, r_idx, m_idx, pol = state
        r_idx, m_idx, pol = config(b, c, acc, xi, size, eff, q, V, n)
        p = acc[jnp.arange(n), m_idx, r_idx]
        # line 4: bandwidth given (r, x, m, c).
        k = eff / size[r_idx]
        mu = c / xi[m_idx, r_idx]
        b = fb(k, p, pol, mu, server_id, budgets_b, n_servers)
        # line 5: compute given (r, x, m, b).
        lam = b * k
        inv_xi = 1.0 / xi[m_idx, r_idx]
        c = fc(inv_xi, p, pol, lam, server_id, budgets_c, n_servers)
        return b, c, r_idx, m_idx, pol

    z = jnp.zeros((n,), jnp.int32)
    b, c, r_idx, m_idx, pol = jax.lax.fori_loop(
        0, n_iters, body, (b, c, z, z, z))

    if polish:
        # Lines 4-5 once more at full precision for the final configuration.
        p = acc[jnp.arange(n), m_idx, r_idx]
        k = eff / size[r_idx]
        mu = c / xi[m_idx, r_idx]
        b = wf_b(k, p, pol, mu, server_id, budgets_b, n_servers)
        c = wf_c(1.0 / xi[m_idx, r_idx], p, pol, b * k, server_id,
                 budgets_c, n_servers)

    lam, mu = _rates(b, c, r_idx, m_idx, eff, size, xi)
    p = acc[jnp.arange(n), m_idx, r_idx]
    a = aopi.aopi(lam, mu, p, pol)
    score = -q * jnp.mean(p) + V * jnp.mean(a)
    return SlotDecision(r_idx, m_idx, pol, b, c, lam, mu, p, a, score)


def solve_slot_np(tables, server_id, budgets_b, budgets_c, q, V,
                  n_servers, **kw) -> SlotDecision:
    """Convenience wrapper taking a profiles.SlotTables, returning numpy."""
    dec = solve_slot(jnp.asarray(tables.acc, jnp.float32),
                     jnp.asarray(tables.xi, jnp.float32),
                     jnp.asarray(tables.size, jnp.float32),
                     jnp.asarray(tables.eff, jnp.float32),
                     jnp.asarray(server_id, jnp.int32),
                     jnp.asarray(budgets_b, jnp.float32),
                     jnp.asarray(budgets_c, jnp.float32),
                     jnp.float32(q), jnp.float32(V),
                     n_servers=int(n_servers), **kw)
    return dec.as_numpy()
