"""Algorithm 1 — block coordinate descent over the one-slot problem (P2).

Three blocks, iterated M times (paper §V-B):

  line 3: video configuration (r, x, m)  — vectorized exhaustive search over
          the (model x resolution x policy) grid, per camera;
  line 4: bandwidth allocation b         — convex, via water-filling or the
          paper's interior-point method (repro.core.allocate);
  line 5: computation allocation c       — same.

Everything is jit-compiled with static (N, M, R, S); the whole solve runs in
a few hundred microseconds for N=30 on CPU (benchmarks/bench_overhead.py).

Both per-camera blocks have two implementations behind
``solver_backend="jnp" | "pallas"``: the pure-jnp reference and the fused
``repro.kernels.slot_solver`` kernels (streaming config argmin, one-dispatch
on-chip water-filling) — float32-tolerance equivalent, benchmarked in
``benchmarks/bench_slot_solver.py``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from . import allocate, aopi
from .. import obs
from ..kernels import slot_solver

# Fleet size at which the pallas kernels start winning. Below one 128-lane
# tile the kernels pad every camera vector up to 128 lanes and lose to the
# plain jnp path (BENCH_slot_solver.json: N=30 is 0.4-0.7x, N=300 is
# 1.2-1.6x), so ``solver_backend="auto"`` stays on jnp under this threshold
# — everywhere the flag goes, including the grid/scenario vmap paths.
AUTO_PALLAS_MIN_CAMERAS = 128

# Fleet size at which "auto" switches the water-fills to the camera-tiled
# streaming kernel (default tile below): past this the single-program
# kernel's [S, Np] membership matrix + whole-fleet vectors start crowding
# VMEM, while one [2, 8, tile] double-buffered window always fits. The
# threshold sits where the streaming kernel measurably wins (~1.3x at
# 32k cameras in interpret mode, ~2x at 100k); below it the whole-fleet
# kernel is faster because it pays no per-sweep DMA machinery.
AUTO_TILE_MIN_CAMERAS = 32768
DEFAULT_TILE_N = 16384

SOLVER_BACKENDS = ("jnp", "pallas", "auto")


@dataclasses.dataclass(frozen=True)
class SolverSpec:
    """Parsed ``solver_backend`` spec: backend plus tiling/fusion knobs."""
    backend: str              # "jnp" | "pallas" | "auto" (pre-resolution)
    tile_n: int | None = None  # water-fill camera tile (None = untiled)
    fuse: bool = True          # one fused kernel for both water-fills


def parse_backend(solver_backend) -> SolverSpec:
    """Parse a ``solver_backend`` string into a :class:`SolverSpec`.

    Grammar: ``<backend>[:<knob>]*`` with knobs ``tile=<int>`` (camera
    tile for the streaming water-fill; ``tile=0`` pins the untiled
    single-program kernel even at auto-tile fleet sizes), ``fuse`` /
    ``nofuse`` (one vs two water-fill dispatches per BCD pass). Examples:
    ``"pallas"``, ``"auto"``, ``"pallas:tile=4096"``,
    ``"pallas:nofuse"``, ``"auto:tile=2048:nofuse"``.
    """
    if isinstance(solver_backend, SolverSpec):
        return solver_backend
    parts = str(solver_backend).split(":")
    if parts[0] not in SOLVER_BACKENDS:
        raise ValueError(f"unknown solver_backend {parts[0]!r}; "
                         f"known: {SOLVER_BACKENDS}")
    tile_n = None
    fuse = True
    for tok in parts[1:]:
        if tok == "fuse":
            fuse = True
        elif tok == "nofuse":
            fuse = False
        elif tok.startswith("tile="):
            tile_n = int(tok[len("tile="):])
        else:
            raise ValueError(f"unknown solver_backend knob {tok!r} in "
                             f"{solver_backend!r}; known: tile=<int>, "
                             "fuse, nofuse")
    return SolverSpec(parts[0], tile_n, fuse)


def resolve_spec(solver_backend, n_cameras: int,
                 method: str = "waterfill") -> SolverSpec:
    """Resolve a spec (or spec string) to concrete knobs for a fleet size.

    ``"auto"`` picks jnp below :data:`AUTO_PALLAS_MIN_CAMERAS`
    (lane-padding regime) and pallas at or above it, and — unless the
    spec pins ``tile=``— engages the tiled water-fill with
    :data:`DEFAULT_TILE_N` from :data:`AUTO_TILE_MIN_CAMERAS` cameras.
    ``method="interior"`` is jnp-only so auto never selects pallas for
    it. Explicit backends pass through unchanged (including the
    pallas+interior error path in ``solve_slot``). ``tile=0`` resolves
    to untiled, and so does any tile the whole fleet fits inside
    (``n_cameras <= tile_n``) — streaming a single tile would just be
    the whole-fleet kernel plus DMA overhead, and dropping the tile
    keeps the fused two-water-fill dispatch available. The resolved
    spec never carries backend ``"auto"``.
    """
    spec = parse_backend(solver_backend)
    backend = spec.backend
    if backend == "auto":
        if method != "waterfill" or n_cameras < AUTO_PALLAS_MIN_CAMERAS:
            backend = "jnp"
        else:
            backend = "pallas"
    tile_n = spec.tile_n
    if backend == "pallas":
        if tile_n is None and n_cameras >= AUTO_TILE_MIN_CAMERAS:
            tile_n = DEFAULT_TILE_N
        if tile_n == 0 or (tile_n is not None and n_cameras <= tile_n):
            tile_n = None
    else:
        tile_n = None
    return SolverSpec(backend, tile_n, spec.fuse)


def resolve_backend(solver_backend, n_cameras: int,
                    method: str = "waterfill") -> str:
    """Backend name only (see :func:`resolve_spec` for the full knobs)."""
    return resolve_spec(solver_backend, n_cameras, method=method).backend


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SlotDecision:
    """Output of one Algorithm-1 solve (all per-camera arrays)."""
    r_idx: jnp.ndarray        # resolution index into tables.size
    m_idx: jnp.ndarray        # model index
    pol: jnp.ndarray          # 0 FCFS / 1 LCFSP
    b: jnp.ndarray            # Hz
    c: jnp.ndarray            # FLOPS
    lam: jnp.ndarray          # frames/s
    mu: jnp.ndarray           # frames/s
    acc: jnp.ndarray          # recognition accuracy p_{n,t}
    aopi: jnp.ndarray         # closed-form per-camera AoPI
    score: jnp.ndarray        # scalar drift-plus-penalty value

    def as_numpy(self) -> "SlotDecision":
        return SlotDecision(*(np.asarray(v) for v in dataclasses.astuple(self)))


def _rates(b, c, r_idx, m_idx, eff, size, xi):
    lam = b * eff / size[r_idx]                       # Eqs. (1)-(2)
    mu = c / xi[m_idx, r_idx]                         # Eq. (3)
    return lam, mu


def solve_slot(acc, xi, size, eff, server_id, budgets_b, budgets_c, q, V,
               n_servers: int, n_iters: int = 4,
               method: Literal["waterfill", "interior"] = "waterfill",
               solver_effort: Literal["fast", "seed"] = "fast",
               solver_backend: str = "jnp",
               interpret: bool | None = None, active=None):
    """Run Algorithm 1 and return a SlotDecision (of jnp arrays).

    Args:
      acc:  [N, M, R] profiled accuracy zeta_n^t(r, m).
      xi:   [M, R]    FLOPs per frame.
      size: [R]       bits per frame.
      eff:  [N]       link spectral efficiency (bits/s/Hz).
      server_id: [N]  camera -> server assignment (Algorithm 2's output).
      budgets_b/_c: [n_servers] available Hz / FLOPS.
      q, V: Lyapunov queue value and penalty weight.
      solver_effort: "fast" (default) uses cheap water-filling effort inside
        the BCD loop plus one full-precision re-allocation; "seed"
        reproduces the pre-refactor flat high-iteration effort (kept for
        benchmarks measuring what the rollout-stack rework bought).
      solver_backend: "jnp" (default) runs the pure-jnp config search and
        water-filling; "pallas" fuses both into the
        ``repro.kernels.slot_solver`` kernels (streaming config argmin, by
        default one fused water-fill dispatch per BCD pass); "auto" picks
        per fleet size via :func:`resolve_spec` (jnp below
        ``AUTO_PALLAS_MIN_CAMERAS``, pallas at/above, camera-tiled
        streaming water-fills from ``AUTO_TILE_MIN_CAMERAS``). Knobs ride
        the string — ``"pallas:tile=4096"``, ``"pallas:nofuse"`` (see
        :func:`parse_backend`). Pallas requires ``method="waterfill"``;
        agrees with "jnp" to float32 tolerance.
      interpret: pallas interpret-mode override (None = auto: interpret
        everywhere except on real TPUs — the CPU/CI path).
      active: optional [N] fleet-churn mask (1 = live). Inactive cameras
        get exactly zero bandwidth/compute (their budget share
        redistributes to survivors) and are excluded from the drift-plus-
        penalty means. The masked path runs on the jnp backend (the
        pallas kernels take no mask — a masked solve silently forces
        jnp); ``active=None`` traces the identical program as before the
        parameter existed.
    """
    kwargs = dict(n_servers=n_servers, n_iters=n_iters, method=method,
                  solver_effort=solver_effort,
                  solver_backend=solver_backend, interpret=interpret)
    args = (acc, xi, size, eff, server_id, budgets_b, budgets_c, q, V)
    if active is not None:
        kwargs["active"] = active
    if obs.enabled():
        # Per-backend dispatch accounting: concrete (host) calls get a
        # timed span — dispatch through materialization of nothing, i.e.
        # host-side submit latency of the jitted program; traced calls
        # (inside rollout scans / vmaps) bump a per-backend trace counter
        # instead (wall time inside a trace measures tracing, not the
        # solver).
        spec = resolve_spec(solver_backend, acc.shape[0], method=method)
        backend = (spec.backend if spec.tile_n is None
                   else f"{spec.backend}:tiled")
        operands = args if active is None else args + (active,)
        if any(isinstance(a, jax.core.Tracer) for a in operands):
            obs.counter("bcd.solve_slot.traces",
                        solver_backend=backend).inc()
        else:
            with obs.span("bcd.solve_slot", solver_backend=backend,
                          n_cameras=int(acc.shape[0])):
                return _solve_slot(*args, **kwargs)
    return _solve_slot(*args, **kwargs)


@functools.partial(jax.jit,
                   static_argnames=("n_servers", "n_iters", "method",
                                    "solver_effort", "solver_backend",
                                    "interpret"))
def _solve_slot(acc, xi, size, eff, server_id, budgets_b, budgets_c, q, V,
                n_servers: int, n_iters: int = 4,
                method: Literal["waterfill", "interior"] = "waterfill",
                solver_effort: Literal["fast", "seed"] = "fast",
                solver_backend: str = "jnp",
                interpret: bool | None = None, active=None):
    spec = resolve_spec(solver_backend, acc.shape[0], method=method)
    if active is not None:
        if method == "interior":
            raise ValueError("method='interior' does not support a fleet-"
                             "churn mask; use method='waterfill'")
        # The pallas kernels take no churn mask — a masked solve runs on
        # the jnp reference path regardless of the requested backend.
        spec = SolverSpec("jnp", None, spec.fuse)
    use_pallas = spec.backend == "pallas"
    if use_pallas and method != "waterfill":
        raise ValueError("solver_backend='pallas' fuses the water-filling "
                         "solver; method='interior' only supports the jnp "
                         "backend")
    n = acc.shape[0]
    if active is not None:
        act = (active > 0).astype(acc.dtype)
        eff = eff * act           # lam = 0 for churned-out cameras
        counts = jax.ops.segment_sum(act, server_id,
                                     num_segments=n_servers)
        share = act * (1.0 / jnp.maximum(counts, 1.0))[server_id]
    else:
        act = None
        counts = jax.ops.segment_sum(jnp.ones((n,)), server_id,
                                     num_segments=n_servers)
        share = (1.0 / jnp.maximum(counts, 1.0))[server_id]
    b = budgets_b[server_id] * share
    c = budgets_c[server_id] * share

    if use_pallas:
        # One static layout per solve: the (possibly traced) assignment is
        # sorted/padded into per-server rows the kernel programs own.
        layout = slot_solver.server_layout(server_id, n_servers)
        config = functools.partial(slot_solver.config_argmin,
                                   backend="pallas", interpret=interpret,
                                   block_n=spec.tile_n or 1024)
        # The fused pair kernel holds the whole fleet in one program; the
        # camera-tiled water-fills stream it in two (bandwidth, compute).
        if spec.fuse and spec.tile_n is None:
            def make_pair(kw):
                def pair(k, p, pol, mu, inv_xi):
                    return slot_solver.waterfill_pair(
                        k, p, pol, mu, inv_xi, server_id, budgets_b,
                        budgets_c, n_servers, layout=layout,
                        interpret=interpret, **kw)
                return pair
        else:
            def make_pair(kw):
                def pair(k, p, pol, mu, inv_xi):
                    b = slot_solver.waterfill_bandwidth(
                        k, p, pol, mu, server_id, budgets_b, n_servers,
                        layout=layout, tile_n=spec.tile_n,
                        interpret=interpret, **kw)
                    c = slot_solver.waterfill_compute(
                        inv_xi, p, pol, b * k, server_id, budgets_c,
                        n_servers, layout=layout, tile_n=spec.tile_n,
                        interpret=interpret, **kw)
                    return b, c
                return pair
    else:
        config = functools.partial(slot_solver.config_argmin, backend="jnp")

        def make_pair(kw):
            def pair(k, p, pol, mu, inv_xi):
                b = allocate.waterfill_bandwidth(
                    k, p, pol, mu, server_id, budgets_b, n_servers,
                    active=act, **kw)
                c = allocate.waterfill_compute(
                    inv_xi, p, pol, b * k, server_id, budgets_c,
                    n_servers, active=act, **kw)
                return b, c
            return pair

    polish = method == "waterfill" and solver_effort == "fast"
    if polish:
        # Cheap solver effort inside the BCD loop (it only has to steer the
        # discrete config selection); one accurate re-allocation afterwards.
        pair_loop = make_pair(dict(outer_iters=10, inner_iters=3,
                                   final_inner_iters=5))
        pair_full = make_pair({})
    elif method == "waterfill":
        # Pre-refactor effort: flat high-iteration water-filling each pass.
        pair_loop = make_pair(dict(outer_iters=54, inner_iters=40,
                                   final_inner_iters=40))
    else:
        def pair_loop(k, p, pol, mu, inv_xi):
            b = allocate.interior_point_bandwidth(
                k, p, pol, mu, server_id, budgets_b, n_servers)
            c = allocate.interior_point_compute(
                inv_xi, p, pol, b * k, server_id, budgets_c, n_servers)
            return b, c

    def body(_, state):
        b, c, r_idx, m_idx, pol = state
        r_idx, m_idx, pol = config(b, c, acc, xi, size, eff, q, V, n)
        p = acc[jnp.arange(n), m_idx, r_idx]
        # lines 4-5: bandwidth given (r, x, m, c), then compute given the
        # fresh arrival rate lam = b * k.
        k = eff / size[r_idx]
        mu = c / xi[m_idx, r_idx]
        b, c = pair_loop(k, p, pol, mu, 1.0 / xi[m_idx, r_idx])
        return b, c, r_idx, m_idx, pol

    z = jnp.zeros((n,), jnp.int32)
    b, c, r_idx, m_idx, pol = jax.lax.fori_loop(
        0, n_iters, body, (b, c, z, z, z))

    if polish:
        # Lines 4-5 once more at full precision for the final configuration.
        p = acc[jnp.arange(n), m_idx, r_idx]
        k = eff / size[r_idx]
        mu = c / xi[m_idx, r_idx]
        b, c = pair_full(k, p, pol, mu, 1.0 / xi[m_idx, r_idx])

    lam, mu = _rates(b, c, r_idx, m_idx, eff, size, xi)
    p = acc[jnp.arange(n), m_idx, r_idx]
    if act is not None:
        # Masked evaluation: dead cameras contribute exactly 0 to every
        # per-camera array and the means run over the live count only.
        a = aopi.aopi_masked(lam, mu, p, pol, active=act)
        p = p * act
        n_live = jnp.maximum(jnp.sum(act), 1.0)
        score = -q * jnp.sum(p) / n_live + V * jnp.sum(a) / n_live
    else:
        a = aopi.aopi(lam, mu, p, pol)
        score = -q * jnp.mean(p) + V * jnp.mean(a)
    return SlotDecision(r_idx, m_idx, pol, b, c, lam, mu, p, a, score)


def solve_slot_np(tables, server_id, budgets_b, budgets_c, q, V,
                  n_servers, **kw) -> SlotDecision:
    """Convenience wrapper taking a profiles.SlotTables, returning numpy."""
    dec = solve_slot(jnp.asarray(tables.acc, jnp.float32),
                     jnp.asarray(tables.xi, jnp.float32),
                     jnp.asarray(tables.size, jnp.float32),
                     jnp.asarray(tables.eff, jnp.float32),
                     jnp.asarray(server_id, jnp.int32),
                     jnp.asarray(budgets_b, jnp.float32),
                     jnp.asarray(budgets_c, jnp.float32),
                     jnp.float32(q), jnp.float32(V),
                     n_servers=int(n_servers), **kw)
    return dec.as_numpy()
