"""Energy-aware LBCD — the paper's §VII future-work item, implemented.

Model: per-camera power draw is linear in the allocated resources,
``e_n = kappa_tx * b_n + kappa_c * c_n`` (radio power tracks occupied
bandwidth; server power tracks allocated FLOPS — the standard
linear-utilization model). The long-term constraint

    lim (1/T) sum_t mean_n e_{n,t} <= E_max

gets its own virtual queue  z(t+1) = max(z(t) - E_max + e_bar_t, 0)  and
the drift-plus-penalty objective gains  + z(t) * e_bar_t.

Because e is linear in (b, c), the KKT conditions of the allocation
subproblems only shift: the water-filling optimality condition
``-dA/db = nu`` becomes ``-dA/db = nu + z*kappa_tx/(V*N)`` — a per-camera
constant added to the dual. ``EnergyAwareLBCD`` wires that shift into the
config-selection grid and re-weights the virtual/real-server solves; the
provable O(1/V) structure of Theorem 4 carries over unchanged (two queues
instead of one in the same Lyapunov function).

The whole-horizon path (``rollout_energy``) runs the two-queue controller as
one jitted ``lax.scan``: per slot it vmaps the Algorithm-1 solve over the
budget-scale ladder, picks the energy-augmented argmin, and updates both
virtual queues on device. ``EnergyAwareLBCD.run`` uses it; ``step`` keeps the
legacy host loop for the serving/failover control planes.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import bcd, binpack, lyapunov, profiles
from .lbcd import LBCDController, RolloutResult, SlotRecord, summarize
from .lyapunov import VirtualQueue
from .profiles import HorizonTables


@dataclasses.dataclass
class EnergyModel:
    kappa_tx: float = 2e-8     # W per Hz of occupied bandwidth
    kappa_c: float = 2e-12     # W per FLOPS allocated
    e_max: float = 1.0         # long-term average W per camera

    def power(self, b, c) -> np.ndarray:
        return self.kappa_tx * np.asarray(b) + self.kappa_c * np.asarray(c)


@functools.partial(jax.jit, static_argnames=("n_scales", "n_bcd_iters",
                                             "method", "solver_effort",
                                             "solver_backend", "interpret"))
def rollout_energy(tables: HorizonTables, v, p_min, kappa_tx, kappa_c,
                   e_max, q0=0.0, z0=0.0, n_scales: int = 13,
                   scale_base: float = 0.75, n_bcd_iters: int = 4,
                   method: str = "waterfill",
                   solver_effort: str = "fast",
                   solver_backend: str = "jnp",
                   interpret: bool | None = None):
    """Whole-horizon two-queue (accuracy + energy) LBCD as one scan.

    Per slot, both Algorithm-1 solves are vmapped over the budget-scale
    ladder ``scale_base ** [0..n_scales)`` and the energy-augmented score
    ``dec.score + z * power`` picks the winner (ties resolve to the largest
    scale, matching the legacy z == 0 behaviour). While the energy queue is
    empty (z == 0) the ladder collapses to the single full-budget solve via
    ``lax.cond``, so a slack energy budget costs the same as plain LBCD.

    ``solver_backend`` threads verbatim into every ladder solve — spec
    strings with tiling/fusion knobs (``"pallas:tile=4096"``,
    ``"pallas:nofuse"``; see ``bcd.parse_backend``) work here too.

    Returns ``(RolloutResult, power[T], z[T])``.
    """
    n = tables.acc.shape[1]
    n_servers = tables.budgets_b.shape[1]
    virt_id = jnp.zeros((n,), jnp.int32)
    scales = scale_base ** jnp.arange(n_scales, dtype=jnp.float32)
    solve = functools.partial(bcd.solve_slot, n_iters=n_bcd_iters,
                              method=method, solver_effort=solver_effort,
                              solver_backend=solver_backend,
                              interpret=interpret)

    def solve_scaled(acc_t, eff_t, assign, bb, bc, q, z, n_srv):
        def at_scale(s):
            dec = solve(acc_t, tables.xi, tables.size, eff_t, assign,
                        bb * s, bc * s, q, v, n_servers=n_srv)
            power = jnp.mean(kappa_tx * dec.b + kappa_c * dec.c)
            return dec, power, dec.score + z * power

        def ladder(_):
            decs, powers, scores = jax.vmap(at_scale)(scales)
            i = jnp.argmin(scores)
            return jax.tree.map(lambda x: x[i], decs), powers[i]

        def single(_):
            dec, power, _ = at_scale(jnp.float32(1.0))
            return dec, power

        return jax.lax.cond(z > 0.0, ladder, single, None)

    def step(carry, xs):
        q, z = carry
        acc_t, eff_t, bb, bc = xs
        virt, _ = solve_scaled(acc_t, eff_t, virt_id, jnp.sum(bb)[None],
                               jnp.sum(bc)[None], q, z, 1)
        assign = binpack.first_fit_jax(virt.b, virt.c, bb, bc)
        dec, power = solve_scaled(acc_t, eff_t, assign, bb, bc, q, z,
                                  n_servers)
        q_next = lyapunov.queue_update(q, jnp.mean(dec.acc), p_min)
        z_next = jnp.maximum(z - e_max + power, 0.0)
        return (q_next, z_next), (dec, assign, q_next, z_next, power)

    carry0 = (jnp.asarray(q0, jnp.float32), jnp.asarray(z0, jnp.float32))
    _, (decs, assigns, qs, zs, powers) = jax.lax.scan(
        step, carry0, (tables.acc, profiles.eff_sequence(tables),
                       tables.budgets_b, tables.budgets_c))
    res = RolloutResult(aopi=decs.aopi, acc=decs.acc, q=qs, assign=assigns,
                        decision=decs)
    return res, powers, zs


class EnergyAwareLBCD(LBCDController):
    """LBCD with a second (energy) virtual queue.

    The energy price z(t) shrinks the *effective* resource budgets the
    allocator water-fills into: with objective V*A + z*(k_tx*b + k_c*c),
    marginal utility must exceed the energy price, which is equivalent to
    capping each server's fill at the point where -dA/db == z*k_tx/(V/N).
    We realize this with a bisection on a budget-scaling factor — simple,
    exact within tolerance, and it reuses the production allocators.
    """

    def __init__(self, system, energy: EnergyModel = None, **kw):
        super().__init__(system, **kw)
        self.energy = energy or EnergyModel()
        self.z_queue = VirtualQueue(p_min=0.0)      # reused as energy queue

    def _solve(self, tables, assign, budgets_b, budgets_c):
        """One Algorithm-1 solve under scaled budgets chosen so that the
        energy-augmented objective is minimized."""
        n = tables.n_cameras
        z = self.z_queue.q
        e = self.energy
        best = None
        # Scan budget scale (coarse outer minimization over the energy
        # price's effect; the inner problem stays the production solver).
        scales = [1.0] if z <= 0 else [0.75 ** i for i in range(13)]
        for s in scales:
            dec = bcd.solve_slot_np(
                tables, assign, budgets_b * s, budgets_c * s,
                self.queue.q, self.v, n_servers=len(budgets_b),
                n_iters=self.n_bcd_iters, method=self.method,
                solver_effort=self.solver_effort,
                solver_backend=self.solver_backend)
            power = e.power(dec.b, dec.c).mean()
            score = float(dec.score) + z * power
            if best is None or score < best[0]:
                best = (score, dec, power)
        return best[1], best[2]

    def step(self, t: int, tables=None) -> SlotRecord:
        sys = self.system
        budgets_b, budgets_c = sys.capacities(t)
        tables = tables if tables is not None else sys.tables(t)
        n = tables.n_cameras

        virt, _ = self._solve(tables, np.zeros(n, np.int32),
                              np.array([budgets_b.sum()]),
                              np.array([budgets_c.sum()]))
        assign = self.assign_fn(virt.b, virt.c, budgets_b, budgets_c)
        dec, power = self._solve(tables, assign, np.asarray(budgets_b),
                                 np.asarray(budgets_c))

        q = self.queue.update(float(np.mean(dec.acc)))
        # z(t+1) = max(z - E_max + e_bar, 0)
        self.z_queue.q = max(self.z_queue.q - self.energy.e_max + power,
                             0.0)
        rec = SlotRecord(t=t, aopi=dec.aopi, acc=dec.acc, q=q,
                         assign=assign, decision=dec)
        rec.power = power
        rec.z = self.z_queue.q
        return rec

    def run(self, n_slots: int, engine: str = "scan"):
        """Whole-horizon run on the scan engine (two queues carried on
        device); records gain ``.power`` / ``.z`` like the legacy path."""
        if engine != "scan" or self.assign_fn is not binpack.first_fit:
            records = [self.step(t) for t in range(n_slots)]
            from .lbcd import RunSummary
            return RunSummary(records, self.v, self.queue.p_min)
        tables = self.system.horizon(n_slots)
        e = self.energy
        res, powers, zs = rollout_energy(
            tables, self.v, self.queue.p_min, e.kappa_tx, e.kappa_c,
            e.e_max, q0=self.queue.q, z0=self.z_queue.q,
            n_bcd_iters=self.n_bcd_iters, method=self.method,
            solver_effort=self.solver_effort,
            solver_backend=self.solver_backend)
        self.queue.q = float(res.q[-1])
        self.z_queue.q = float(zs[-1])
        summary = summarize(res, self.v, self.queue.p_min)
        for rec, power, z in zip(summary.records, np.asarray(powers),
                                 np.asarray(zs)):
            rec.power = float(power)
            rec.z = float(z)
        return summary
