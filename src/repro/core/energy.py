"""Energy-aware LBCD — the paper's §VII future-work item, implemented.

Model: per-camera power draw is linear in the allocated resources,
``e_n = kappa_tx * b_n + kappa_c * c_n`` (radio power tracks occupied
bandwidth; server power tracks allocated FLOPS — the standard
linear-utilization model). The long-term constraint

    lim (1/T) sum_t mean_n e_{n,t} <= E_max

gets its own virtual queue  z(t+1) = max(z(t) - E_max + e_bar_t, 0)  and
the drift-plus-penalty objective gains  + z(t) * e_bar_t.

Because e is linear in (b, c), the KKT conditions of the allocation
subproblems only shift: the water-filling optimality condition
``-dA/db = nu`` becomes ``-dA/db = nu + z*kappa_tx/(V*N)`` — a per-camera
constant added to the dual. ``EnergyAwareLBCD`` wires that shift into the
config-selection grid and re-weights the virtual/real-server solves; the
provable O(1/V) structure of Theorem 4 carries over unchanged (two queues
instead of one in the same Lyapunov function).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from . import bcd
from .lbcd import LBCDController, SlotRecord
from .lyapunov import VirtualQueue


@dataclasses.dataclass
class EnergyModel:
    kappa_tx: float = 2e-8     # W per Hz of occupied bandwidth
    kappa_c: float = 2e-12     # W per FLOPS allocated
    e_max: float = 1.0         # long-term average W per camera

    def power(self, b, c) -> np.ndarray:
        return self.kappa_tx * np.asarray(b) + self.kappa_c * np.asarray(c)


class EnergyAwareLBCD(LBCDController):
    """LBCD with a second (energy) virtual queue.

    The energy price z(t) shrinks the *effective* resource budgets the
    allocator water-fills into: with objective V*A + z*(k_tx*b + k_c*c),
    marginal utility must exceed the energy price, which is equivalent to
    capping each server's fill at the point where -dA/db == z*k_tx/(V/N).
    We realize this with a bisection on a budget-scaling factor — simple,
    exact within tolerance, and it reuses the production allocators.
    """

    def __init__(self, system, energy: EnergyModel = None, **kw):
        super().__init__(system, **kw)
        self.energy = energy or EnergyModel()
        self.z_queue = VirtualQueue(p_min=0.0)      # reused as energy queue

    def _solve(self, tables, assign, budgets_b, budgets_c):
        """One Algorithm-1 solve under scaled budgets chosen so that the
        energy-augmented objective is minimized."""
        n = tables.n_cameras
        z = self.z_queue.q
        e = self.energy
        best = None
        # Scan budget scale (coarse outer minimization over the energy
        # price's effect; the inner problem stays the production solver).
        scales = [1.0] if z <= 0 else [0.75 ** i for i in range(13)]
        for s in scales:
            dec = bcd.solve_slot_np(
                tables, assign, budgets_b * s, budgets_c * s,
                self.queue.q, self.v, n_servers=len(budgets_b),
                n_iters=self.n_bcd_iters, method=self.method)
            power = e.power(dec.b, dec.c).mean()
            score = float(dec.score) + z * power
            if best is None or score < best[0]:
                best = (score, dec, power)
        return best[1], best[2]

    def step(self, t: int, tables=None) -> SlotRecord:
        sys = self.system
        budgets_b, budgets_c = sys.capacities(t)
        tables = tables if tables is not None else sys.tables(t)
        n = tables.n_cameras

        virt, _ = self._solve(tables, np.zeros(n, np.int32),
                              np.array([budgets_b.sum()]),
                              np.array([budgets_c.sum()]))
        assign = self.assign_fn(virt.b, virt.c, budgets_b, budgets_c)
        dec, power = self._solve(tables, assign, np.asarray(budgets_b),
                                 np.asarray(budgets_c))

        q = self.queue.update(float(np.mean(dec.acc)))
        # z(t+1) = max(z - E_max + e_bar, 0)
        self.z_queue.q = max(self.z_queue.q - self.energy.e_max + power,
                             0.0)
        rec = SlotRecord(t=t, aopi=dec.aopi, acc=dec.acc, q=q,
                         assign=assign, decision=dec)
        rec.power = power
        rec.z = self.z_queue.q
        return rec
