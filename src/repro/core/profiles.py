"""Accuracy / complexity / workload profiles (paper §III + §VI-A).

Two consumption granularities are provided:

  * ``EdgeSystem.tables(t)``   — one slot's profiles as host numpy arrays
    (the legacy per-slot path used by ``LBCDController.step``);
  * ``EdgeSystem.horizon(T)``  — a whole-horizon ``HorizonTables`` pytree
    (acc ``[T, N, M, R]``, capacity traces ``[T, S]``) built once on host
    and moved to device once, consumed by the ``lax.scan`` rollout engine
    (``repro.core.lbcd.rollout``) with zero per-slot host round trips.

Provides the substrate the controller consumes each slot:
  * zeta(r, m)  — concave, monotone-increasing recognition-accuracy profile
                  per (resolution, model), with per-slot content drift
                  (the paper profiles zeta at the start of every slot);
  * xi(r, m)    — convex FLOPs-per-frame profile, proportional to model size;
  * frame size  — alpha * r^2 bits (H.264-style, Eq. before Eq. 2);
  * Shannon-rate link model (Eq. 1) and linear pod-link model;
  * bandwidth / compute capacity traces shaped like the Ghent LTE and
    Bitbrains datacenter traces used in §VI-A (lognormal AR(1) modulation).

Two candidate pools ship out of the box:
  * ``paper_pool()``  — the paper's own ladder (YOLOv5n..x, FPN, U-Net,
    YOLACT, Mask R-CNN) with public FLOPs/params numbers;
  * ``lm_pool()``     — the assigned LM-architecture ladder, where a
    "frame" is a patch/token bundle and resolution maps to patch count.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

RESOLUTIONS = (384, 512, 640, 768, 896, 1024)
ALPHA_BITS_PER_PIXEL = 1.2          # frame size = alpha * r^2 bits
REF_RESOLUTION = 640


@dataclasses.dataclass(frozen=True)
class ModelCandidate:
    """One selectable recognition model (the paper's m in M)."""
    name: str
    params_m: float          # millions of parameters
    gflops_ref: float        # GFLOPs per frame at REF_RESOLUTION
    p_max: float             # asymptotic accuracy at infinite resolution
    r_knee: float            # resolution scale of the accuracy saturation
    task: str = "detection"

    def xi(self, r: np.ndarray) -> np.ndarray:
        """FLOPs per frame — convex (quadratic) in resolution, proportional
        to model cost (§III-B)."""
        return self.gflops_ref * 1e9 * (np.asarray(r, np.float64) /
                                        REF_RESOLUTION) ** 2

    def zeta(self, r: np.ndarray, drift: float = 1.0) -> np.ndarray:
        """Accuracy — concave, monotone increasing in r, scaled by a content
        drift factor in (0, 1]."""
        r = np.asarray(r, np.float64)
        base = self.p_max * (1.0 - np.exp(-r / self.r_knee))
        return np.clip(base * drift, 1e-3, 1.0)


def paper_pool() -> list[ModelCandidate]:
    """The paper's §VI-A candidates; FLOPs/params from the public model zoo
    (YOLOv5 release table @640, torchvision/paper numbers for the rest).
    The ladder spans ~50x compute, matching §III-B."""
    return [
        ModelCandidate("yolov5n", 1.9, 4.5, 0.62, 190.0),
        ModelCandidate("yolov5s", 7.2, 16.5, 0.72, 200.0),
        ModelCandidate("yolov5m", 21.2, 49.0, 0.80, 210.0),
        ModelCandidate("yolov5l", 46.5, 109.1, 0.85, 220.0),
        ModelCandidate("yolov5x", 86.7, 205.7, 0.88, 230.0),
        ModelCandidate("fpn", 23.0, 90.0, 0.82, 215.0, task="segmentation"),
        ModelCandidate("unet", 31.0, 120.0, 0.84, 220.0, task="segmentation"),
        ModelCandidate("yolact", 34.7, 61.6, 0.78, 210.0, task="instance"),
        ModelCandidate("mask_rcnn", 44.2, 134.0, 0.86, 225.0, task="instance"),
    ]


def lm_pool() -> list[ModelCandidate]:
    """Assigned-architecture ladder for pod-scale serving. xi is calibrated
    as 2 * N_active * tokens(r), tokens(r) = (r/16)^2 vision patches; the
    gflops_ref column folds that in at r=640 (1600 patches)."""
    def g(n_active_b):  # GFLOPs per frame at 640p (1600 tokens)
        return 2.0 * n_active_b * 1e9 * (640 / 16) ** 2 / 1e9

    return [
        ModelCandidate("qwen2.5-3b", 3_000, g(3.0), 0.74, 205.0, task="lm"),
        ModelCandidate("yi-6b", 6_000, g(6.0), 0.78, 210.0, task="lm"),
        ModelCandidate("minicpm3-4b", 4_000, g(4.0), 0.76, 208.0, task="lm"),
        ModelCandidate("qwen2-moe-a2.7b", 14_000, g(2.7), 0.75, 206.0,
                       task="lm"),
        ModelCandidate("llama-3.2-vision-11b", 11_000, g(11.0), 0.82, 215.0,
                       task="vlm"),
        ModelCandidate("yi-34b", 34_000, g(34.0), 0.87, 222.0, task="lm"),
        ModelCandidate("dbrx-132b", 132_000, g(36.0), 0.89, 226.0, task="lm"),
        ModelCandidate("jamba-1.5-large-398b", 398_000, g(98.0), 0.91, 230.0,
                       task="lm"),
    ]


# ---------------------------------------------------------------------------
# Link models
# ---------------------------------------------------------------------------

def shannon_efficiency(snr_db: np.ndarray) -> np.ndarray:
    """bits/s/Hz from Eq. (1): log2(1 + E*G/sigma)."""
    return np.log2(1.0 + 10.0 ** (np.asarray(snr_db, np.float64) / 10.0))


# ---------------------------------------------------------------------------
# Pure-functional trace machinery (shared with repro.scenarios generators)
# ---------------------------------------------------------------------------

def ar1_scan(u: np.ndarray, rho: float) -> np.ndarray:
    """Vectorized linear recursion x[t] = rho * x[t-1] + u[t], x[-1] = 0.

    Associative prefix scan with stride doubling — O(T log T) numpy work
    instead of a T-step python loop. The recursion composes as affine maps
    (A, B): x_out = A * x_in + B. Matches the sequential recursion up to
    float64 reassociation error (~1e-15 relative), not bitwise.
    """
    t_len = u.shape[0]
    coef = np.full(u.shape, rho, dtype=np.float64)
    out = np.asarray(u, np.float64).copy()
    d = 1
    while d < t_len:
        out[d:] = out[d:] + coef[d:] * out[:-d]
        coef[d:] = coef[d:] * coef[:-d]
        d *= 2
    return out


def lognormal_ar1_trace(rng: np.random.Generator, mean: float,
                        shape: tuple[int, int], rho: float = 0.85,
                        sigma: float = 0.25) -> np.ndarray:
    """Lognormal AR(1) capacity trace (Ghent LTE / Bitbrains shape).

    Pure in ``(rng state, mean, shape, rho, sigma)``; draws all noise in one
    call (same stream as the historical per-slot loop) and runs the AR(1)
    recursion via the vectorized ``ar1_scan`` (values match the loop to
    ~1e-15 relative, not bitwise).
    """
    e = rng.normal(0.0, sigma, shape)
    u = np.concatenate([e[:1], np.sqrt(1 - rho**2) * e[1:]], axis=0)
    x = ar1_scan(u, rho)
    return mean * np.exp(x - 0.5 * sigma**2)


def drift_path(seed: int, n_slots: int, n_cameras: int,
               rho: float = 0.9, pull: float = 0.1, sigma: float = 0.03,
               lo: float = 0.75, hi: float = 1.0,
               init: np.ndarray | None = None) -> np.ndarray:
    """Per-camera clipped-AR(1) content-drift path ``[T, N]``.

    Pure in ``(seed, n_slots, n_cameras, ...)`` — the functional twin of
    ``EdgeSystem.advance_drift`` (the clip makes the recursion nonlinear, so
    this one keeps the short T loop over a pre-drawn noise matrix).
    Matches what ``n_slots`` sequential ``advance_drift()`` calls on a fresh
    ``EdgeSystem(seed=seed - 1)`` would return.
    """
    rng = np.random.default_rng(seed)
    noise = rng.normal(0.0, sigma, (n_slots, n_cameras))
    state = np.ones(n_cameras) if init is None else np.asarray(init, float)
    out = np.empty((n_slots, n_cameras))
    for t in range(n_slots):
        state = np.clip(rho * state + pull * 1.0 + noise[t], lo, hi)
        out[t] = state
    return out


@dataclasses.dataclass
class SlotTables:
    """Everything the per-slot optimizer needs, as dense arrays.

    Shapes: N cameras, M models, R resolutions.
      acc[n, m, r]   accuracy zeta_n^t
      xi[m, r]       FLOPs per frame
      size[r]        bits per frame
      eff[n]         link spectral efficiency (bits/s/Hz); lam = b*eff/size
    """
    acc: np.ndarray
    xi: np.ndarray
    size: np.ndarray
    eff: np.ndarray

    @property
    def n_cameras(self) -> int:
        return self.acc.shape[0]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class HorizonTables:
    """Whole-horizon profiles + capacity traces as one device-resident pytree.

    Built once on host (``EdgeSystem.horizon``) and consumed by the
    ``lax.scan`` rollout engine; vmappable over a leading batch axis (e.g. a
    stack of scenarios with identical shapes).

    Shapes: T slots, N cameras, M models, R resolutions, S servers.
      acc[t, n, m, r]   profiled accuracy zeta_n^t (drift applied per slot)
      xi[m, r]          FLOPs per frame
      size[r]           bits per frame
      eff[n]            link spectral efficiency (bits/s/Hz); scenario
                        generators with camera mobility emit a time-varying
                        eff[t, n] instead — every scan engine accepts both
      budgets_b[t, s]   bandwidth capacity trace B_t^s (Hz)
      budgets_c[t, s]   compute capacity trace C_t^s (FLOPS)
      active[t, n]      optional fleet-churn mask (1.0 = camera live).
                        ``None`` (the default) means "all cameras live
                        for the whole horizon" and adds **no pytree
                        leaf**, so every maskless program traces to the
                        same jaxpr as before the field existed — the
                        bitwise ``faults=None`` no-op path.
    """
    acc: jnp.ndarray
    xi: jnp.ndarray
    size: jnp.ndarray
    eff: jnp.ndarray
    budgets_b: jnp.ndarray
    budgets_c: jnp.ndarray
    active: jnp.ndarray | None = None

    @property
    def n_slots(self) -> int:
        return self.acc.shape[-4]

    @property
    def n_cameras(self) -> int:
        return self.acc.shape[-3]

    @property
    def n_servers(self) -> int:
        return self.budgets_b.shape[-1]

    def slot(self, t: int) -> SlotTables:
        """One slot's profiles as host numpy (legacy SlotTables view)."""
        eff = self.eff if self.eff.ndim == 1 else self.eff[t]
        return SlotTables(acc=np.asarray(self.acc[t]),
                          xi=np.asarray(self.xi),
                          size=np.asarray(self.size),
                          eff=np.asarray(eff))

    def window(self, t0: int, t1: int) -> "HorizonTables":
        """Slots ``[t0, t1)`` of an (unbatched) horizon as a new
        ``HorizonTables`` — the serving planner's lookahead view. Static
        profile tables (``xi``/``size``) pass through; time-indexed leaves
        are sliced (``eff`` only when it is the time-varying ``[T, N]``
        form)."""
        if not 0 <= t0 < t1 <= self.n_slots:
            raise ValueError(f"window [{t0}, {t1}) outside horizon of "
                             f"{self.n_slots} slots")
        return HorizonTables(
            acc=self.acc[t0:t1], xi=self.xi, size=self.size,
            eff=self.eff if self.eff.ndim == 1 else self.eff[t0:t1],
            budgets_b=self.budgets_b[t0:t1],
            budgets_c=self.budgets_c[t0:t1],
            active=None if self.active is None else self.active[t0:t1])


def eff_sequence(tables: HorizonTables) -> jnp.ndarray:
    """The per-slot link-efficiency sequence ``[T, N]`` of an (unbatched)
    horizon — broadcasts a static ``eff[n]`` across slots, passes a
    time-varying ``eff[t, n]`` through. The scan engines feed this as a
    scanned input so SNR-mobility scenarios ride the same rollout."""
    n_slots = tables.acc.shape[0]
    if tables.eff.ndim == 1:
        return jnp.broadcast_to(tables.eff[None, :],
                                (n_slots, tables.eff.shape[0]))
    return tables.eff


def stack_horizons(tables: Sequence[HorizonTables]) -> HorizonTables:
    """Stack same-shape horizons along a new leading axis for vmapped /
    sharded rollouts (e.g. one scenario per entry of a suite).

    Raises ``ValueError`` naming the offending field and shapes when the
    horizons disagree (all leaves must match exactly — including whether
    ``eff`` is static ``[N]`` or time-varying ``[T, N]``)."""
    tables = list(tables)
    if not tables:
        raise ValueError("stack_horizons: need at least one horizon")
    # Mixed churn masks: densify the maskless horizons to all-ones so the
    # stacked pytree has a uniform structure. All-None stays None (the
    # maskless fast path is preserved for unperturbed suites).
    if any(t.active is not None for t in tables):
        tables = [
            t if t.active is not None else dataclasses.replace(
                t, active=jnp.ones((t.n_slots, t.n_cameras), t.acc.dtype))
            for t in tables]
    ref = tables[0]
    for i, tab in enumerate(tables[1:], start=1):
        for field in dataclasses.fields(HorizonTables):
            a = getattr(ref, field.name)
            b = getattr(tab, field.name)
            if a is None and b is None:
                continue
            if a.shape != b.shape:
                raise ValueError(
                    f"stack_horizons: shape mismatch on field "
                    f"{field.name!r}: horizons[0] has {a.shape}, "
                    f"horizons[{i}] has {b.shape} — all stacked horizons "
                    f"must share (T, N, M, R, S) and eff rank")
    return jax.tree.map(lambda *xs: jnp.stack(xs), *tables)


@dataclasses.dataclass
class EdgeSystem:
    """Scenario container: cameras, servers, traces, profiles (§VI-A)."""
    n_cameras: int = 30
    n_servers: int = 3
    n_slots: int = 200
    mean_bandwidth_hz: float = 30e6          # per server
    mean_compute_flops: float = 50e12        # per server
    pool: Sequence[ModelCandidate] = dataclasses.field(
        default_factory=paper_pool)
    resolutions: Sequence[int] = RESOLUTIONS
    alpha: float = ALPHA_BITS_PER_PIXEL
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # Camera SNRs: 12..22 dB (spectral efficiency ~4..7.3 bits/s/Hz).
        self.snr_db = rng.uniform(12.0, 22.0, size=self.n_cameras)
        # Per-camera content difficulty baseline + AR(1) drift (Cityscapes
        # profiling analog: accuracy functions vary per camera and per slot).
        self._difficulty = rng.uniform(0.88, 1.0, size=self.n_cameras)
        self._drift_state = np.ones(self.n_cameras)
        self._drift_rng = np.random.default_rng(self.seed + 1)
        self.bandwidth_trace = self._trace(
            rng, self.mean_bandwidth_hz, (self.n_slots, self.n_servers))
        self.compute_trace = self._trace(
            rng, self.mean_compute_flops, (self.n_slots, self.n_servers))

    @staticmethod
    def _trace(rng: np.random.Generator, mean: float,
               shape: tuple[int, int], rho: float = 0.85,
               sigma: float = 0.25) -> np.ndarray:
        """Lognormal AR(1) capacity trace — vectorized ``ar1_scan`` path
        (same noise stream + values as the historical per-slot loop, so long
        horizons T >= 10k are no longer host-loop bound)."""
        return lognormal_ar1_trace(rng, mean, shape, rho=rho, sigma=sigma)

    def reset(self) -> "EdgeSystem":
        """Restore the stateful drift RNG/state to the post-construction
        point, so the legacy per-slot ``tables(t)`` path replays the exact
        sequence a fresh system would produce."""
        self._drift_state = np.ones(self.n_cameras)
        self._drift_rng = np.random.default_rng(self.seed + 1)
        return self

    def advance_drift(self) -> np.ndarray:
        """One AR(1) step of per-camera content drift in [0.75, 1.0]."""
        noise = self._drift_rng.normal(0.0, 0.03, self.n_cameras)
        self._drift_state = np.clip(
            0.9 * self._drift_state + 0.1 * 1.0 + noise, 0.75, 1.0)
        return self._drift_state

    def tables(self, t: int, drift: np.ndarray | None = None) -> SlotTables:
        """Profile zeta/xi for slot t (Algorithm 3 line 3)."""
        if drift is None:
            drift = self.advance_drift()
        res = np.asarray(self.resolutions, np.float64)
        m_count = len(self.pool)
        acc = np.zeros((self.n_cameras, m_count, len(res)))
        xi = np.zeros((m_count, len(res)))
        for j, m in enumerate(self.pool):
            xi[j] = m.xi(res)
            zr = m.zeta(res)
            acc[:, j, :] = (self._difficulty * drift)[:, None] * zr[None, :]
        size = self.alpha * res**2
        eff = shannon_efficiency(self.snr_db)
        return SlotTables(acc=np.clip(acc, 1e-3, 1.0), xi=xi, size=size,
                          eff=eff)

    def capacities(self, t: int) -> tuple[np.ndarray, np.ndarray]:
        t = t % self.n_slots
        return self.bandwidth_trace[t], self.compute_trace[t]

    def horizon(self, n_slots: int | None = None,
                dtype=jnp.float32) -> HorizonTables:
        """Pregenerate ``n_slots`` of profiles + capacities as one pytree.

        Deterministic in ``(self.seed, n_slots)``: the drift path is
        computed by the pure ``drift_path`` without touching the stateful
        per-slot RNG, so two ``horizon()`` calls on the same system are
        bitwise identical, and a scan rollout reproduces what ``n_slots``
        sequential ``step(t)`` calls on a *fresh* system would have
        observed.
        """
        n_slots = self.n_slots if n_slots is None else n_slots
        drift = drift_path(self.seed + 1, n_slots, self.n_cameras)  # [T, N]
        res = np.asarray(self.resolutions, np.float64)
        zr = np.stack([m.zeta(res) for m in self.pool])        # [M, R]
        xi = np.stack([m.xi(res) for m in self.pool])          # [M, R]
        acc = (self._difficulty[None, :] * drift)[:, :, None, None] * \
            zr[None, None, :, :]                               # [T, N, M, R]
        acc = np.clip(acc, 1e-3, 1.0)
        size = self.alpha * res**2
        eff = shannon_efficiency(self.snr_db)
        idx = np.arange(n_slots) % self.n_slots
        return HorizonTables(
            acc=jnp.asarray(acc, dtype),
            xi=jnp.asarray(xi, dtype),
            size=jnp.asarray(size, dtype),
            eff=jnp.asarray(eff, dtype),
            budgets_b=jnp.asarray(self.bandwidth_trace[idx], dtype),
            budgets_c=jnp.asarray(self.compute_trace[idx], dtype))
