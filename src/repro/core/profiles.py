"""Accuracy / complexity / workload profiles (paper §III + §VI-A).

Two consumption granularities are provided:

  * ``EdgeSystem.tables(t)``   — one slot's profiles as host numpy arrays
    (the legacy per-slot path used by ``LBCDController.step``);
  * ``EdgeSystem.horizon(T)``  — a whole-horizon ``HorizonTables`` pytree
    (acc ``[T, N, M, R]``, capacity traces ``[T, S]``) built once on host
    and moved to device once, consumed by the ``lax.scan`` rollout engine
    (``repro.core.lbcd.rollout``) with zero per-slot host round trips.

Provides the substrate the controller consumes each slot:
  * zeta(r, m)  — concave, monotone-increasing recognition-accuracy profile
                  per (resolution, model), with per-slot content drift
                  (the paper profiles zeta at the start of every slot);
  * xi(r, m)    — convex FLOPs-per-frame profile, proportional to model size;
  * frame size  — alpha * r^2 bits (H.264-style, Eq. before Eq. 2);
  * Shannon-rate link model (Eq. 1) and linear pod-link model;
  * bandwidth / compute capacity traces shaped like the Ghent LTE and
    Bitbrains datacenter traces used in §VI-A (lognormal AR(1) modulation).

Two candidate pools ship out of the box:
  * ``paper_pool()``  — the paper's own ladder (YOLOv5n..x, FPN, U-Net,
    YOLACT, Mask R-CNN) with public FLOPs/params numbers;
  * ``lm_pool()``     — the assigned LM-architecture ladder, where a
    "frame" is a patch/token bundle and resolution maps to patch count.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

RESOLUTIONS = (384, 512, 640, 768, 896, 1024)
ALPHA_BITS_PER_PIXEL = 1.2          # frame size = alpha * r^2 bits
REF_RESOLUTION = 640


@dataclasses.dataclass(frozen=True)
class ModelCandidate:
    """One selectable recognition model (the paper's m in M)."""
    name: str
    params_m: float          # millions of parameters
    gflops_ref: float        # GFLOPs per frame at REF_RESOLUTION
    p_max: float             # asymptotic accuracy at infinite resolution
    r_knee: float            # resolution scale of the accuracy saturation
    task: str = "detection"

    def xi(self, r: np.ndarray) -> np.ndarray:
        """FLOPs per frame — convex (quadratic) in resolution, proportional
        to model cost (§III-B)."""
        return self.gflops_ref * 1e9 * (np.asarray(r, np.float64) /
                                        REF_RESOLUTION) ** 2

    def zeta(self, r: np.ndarray, drift: float = 1.0) -> np.ndarray:
        """Accuracy — concave, monotone increasing in r, scaled by a content
        drift factor in (0, 1]."""
        r = np.asarray(r, np.float64)
        base = self.p_max * (1.0 - np.exp(-r / self.r_knee))
        return np.clip(base * drift, 1e-3, 1.0)


def paper_pool() -> list[ModelCandidate]:
    """The paper's §VI-A candidates; FLOPs/params from the public model zoo
    (YOLOv5 release table @640, torchvision/paper numbers for the rest).
    The ladder spans ~50x compute, matching §III-B."""
    return [
        ModelCandidate("yolov5n", 1.9, 4.5, 0.62, 190.0),
        ModelCandidate("yolov5s", 7.2, 16.5, 0.72, 200.0),
        ModelCandidate("yolov5m", 21.2, 49.0, 0.80, 210.0),
        ModelCandidate("yolov5l", 46.5, 109.1, 0.85, 220.0),
        ModelCandidate("yolov5x", 86.7, 205.7, 0.88, 230.0),
        ModelCandidate("fpn", 23.0, 90.0, 0.82, 215.0, task="segmentation"),
        ModelCandidate("unet", 31.0, 120.0, 0.84, 220.0, task="segmentation"),
        ModelCandidate("yolact", 34.7, 61.6, 0.78, 210.0, task="instance"),
        ModelCandidate("mask_rcnn", 44.2, 134.0, 0.86, 225.0, task="instance"),
    ]


def lm_pool() -> list[ModelCandidate]:
    """Assigned-architecture ladder for pod-scale serving. xi is calibrated
    as 2 * N_active * tokens(r), tokens(r) = (r/16)^2 vision patches; the
    gflops_ref column folds that in at r=640 (1600 patches)."""
    def g(n_active_b):  # GFLOPs per frame at 640p (1600 tokens)
        return 2.0 * n_active_b * 1e9 * (640 / 16) ** 2 / 1e9

    return [
        ModelCandidate("qwen2.5-3b", 3_000, g(3.0), 0.74, 205.0, task="lm"),
        ModelCandidate("yi-6b", 6_000, g(6.0), 0.78, 210.0, task="lm"),
        ModelCandidate("minicpm3-4b", 4_000, g(4.0), 0.76, 208.0, task="lm"),
        ModelCandidate("qwen2-moe-a2.7b", 14_000, g(2.7), 0.75, 206.0,
                       task="lm"),
        ModelCandidate("llama-3.2-vision-11b", 11_000, g(11.0), 0.82, 215.0,
                       task="vlm"),
        ModelCandidate("yi-34b", 34_000, g(34.0), 0.87, 222.0, task="lm"),
        ModelCandidate("dbrx-132b", 132_000, g(36.0), 0.89, 226.0, task="lm"),
        ModelCandidate("jamba-1.5-large-398b", 398_000, g(98.0), 0.91, 230.0,
                       task="lm"),
    ]


# ---------------------------------------------------------------------------
# Link models
# ---------------------------------------------------------------------------

def shannon_efficiency(snr_db: np.ndarray) -> np.ndarray:
    """bits/s/Hz from Eq. (1): log2(1 + E*G/sigma)."""
    return np.log2(1.0 + 10.0 ** (np.asarray(snr_db, np.float64) / 10.0))


@dataclasses.dataclass
class SlotTables:
    """Everything the per-slot optimizer needs, as dense arrays.

    Shapes: N cameras, M models, R resolutions.
      acc[n, m, r]   accuracy zeta_n^t
      xi[m, r]       FLOPs per frame
      size[r]        bits per frame
      eff[n]         link spectral efficiency (bits/s/Hz); lam = b*eff/size
    """
    acc: np.ndarray
    xi: np.ndarray
    size: np.ndarray
    eff: np.ndarray

    @property
    def n_cameras(self) -> int:
        return self.acc.shape[0]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class HorizonTables:
    """Whole-horizon profiles + capacity traces as one device-resident pytree.

    Built once on host (``EdgeSystem.horizon``) and consumed by the
    ``lax.scan`` rollout engine; vmappable over a leading batch axis (e.g. a
    stack of scenarios with identical shapes).

    Shapes: T slots, N cameras, M models, R resolutions, S servers.
      acc[t, n, m, r]   profiled accuracy zeta_n^t (drift applied per slot)
      xi[m, r]          FLOPs per frame
      size[r]           bits per frame
      eff[n]            link spectral efficiency (bits/s/Hz)
      budgets_b[t, s]   bandwidth capacity trace B_t^s (Hz)
      budgets_c[t, s]   compute capacity trace C_t^s (FLOPS)
    """
    acc: jnp.ndarray
    xi: jnp.ndarray
    size: jnp.ndarray
    eff: jnp.ndarray
    budgets_b: jnp.ndarray
    budgets_c: jnp.ndarray

    @property
    def n_slots(self) -> int:
        return self.acc.shape[-4]

    @property
    def n_cameras(self) -> int:
        return self.acc.shape[-3]

    @property
    def n_servers(self) -> int:
        return self.budgets_b.shape[-1]

    def slot(self, t: int) -> SlotTables:
        """One slot's profiles as host numpy (legacy SlotTables view)."""
        return SlotTables(acc=np.asarray(self.acc[t]),
                          xi=np.asarray(self.xi),
                          size=np.asarray(self.size),
                          eff=np.asarray(self.eff))


def stack_horizons(tables: Sequence[HorizonTables]) -> HorizonTables:
    """Stack same-shape horizons along a new leading axis for vmapped
    rollouts (e.g. one scenario per swept bandwidth level)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *tables)


@dataclasses.dataclass
class EdgeSystem:
    """Scenario container: cameras, servers, traces, profiles (§VI-A)."""
    n_cameras: int = 30
    n_servers: int = 3
    n_slots: int = 200
    mean_bandwidth_hz: float = 30e6          # per server
    mean_compute_flops: float = 50e12        # per server
    pool: Sequence[ModelCandidate] = dataclasses.field(
        default_factory=paper_pool)
    resolutions: Sequence[int] = RESOLUTIONS
    alpha: float = ALPHA_BITS_PER_PIXEL
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # Camera SNRs: 12..22 dB (spectral efficiency ~4..7.3 bits/s/Hz).
        self.snr_db = rng.uniform(12.0, 22.0, size=self.n_cameras)
        # Per-camera content difficulty baseline + AR(1) drift (Cityscapes
        # profiling analog: accuracy functions vary per camera and per slot).
        self._difficulty = rng.uniform(0.88, 1.0, size=self.n_cameras)
        self._drift_state = np.ones(self.n_cameras)
        self._drift_rng = np.random.default_rng(self.seed + 1)
        self.bandwidth_trace = self._trace(
            rng, self.mean_bandwidth_hz, (self.n_slots, self.n_servers))
        self.compute_trace = self._trace(
            rng, self.mean_compute_flops, (self.n_slots, self.n_servers))

    @staticmethod
    def _trace(rng: np.random.Generator, mean: float,
               shape: tuple[int, int], rho: float = 0.85,
               sigma: float = 0.25) -> np.ndarray:
        """Lognormal AR(1) capacity trace (Ghent LTE / Bitbrains shape)."""
        t_len, s = shape
        x = np.zeros(shape)
        x[0] = rng.normal(0, sigma, s)
        for t in range(1, t_len):
            x[t] = rho * x[t - 1] + np.sqrt(1 - rho**2) * rng.normal(
                0, sigma, s)
        return mean * np.exp(x - 0.5 * sigma**2)

    def advance_drift(self) -> np.ndarray:
        """One AR(1) step of per-camera content drift in [0.75, 1.0]."""
        noise = self._drift_rng.normal(0.0, 0.03, self.n_cameras)
        self._drift_state = np.clip(
            0.9 * self._drift_state + 0.1 * 1.0 + noise, 0.75, 1.0)
        return self._drift_state

    def tables(self, t: int, drift: np.ndarray | None = None) -> SlotTables:
        """Profile zeta/xi for slot t (Algorithm 3 line 3)."""
        if drift is None:
            drift = self.advance_drift()
        res = np.asarray(self.resolutions, np.float64)
        m_count = len(self.pool)
        acc = np.zeros((self.n_cameras, m_count, len(res)))
        xi = np.zeros((m_count, len(res)))
        for j, m in enumerate(self.pool):
            xi[j] = m.xi(res)
            zr = m.zeta(res)
            acc[:, j, :] = (self._difficulty * drift)[:, None] * zr[None, :]
        size = self.alpha * res**2
        eff = shannon_efficiency(self.snr_db)
        return SlotTables(acc=np.clip(acc, 1e-3, 1.0), xi=xi, size=size,
                          eff=eff)

    def capacities(self, t: int) -> tuple[np.ndarray, np.ndarray]:
        t = t % self.n_slots
        return self.bandwidth_trace[t], self.compute_trace[t]

    def horizon(self, n_slots: int | None = None,
                dtype=jnp.float32) -> HorizonTables:
        """Pregenerate ``n_slots`` of profiles + capacities as one pytree.

        Advances the same stateful drift RNG ``tables(t)`` would, so a scan
        rollout over the result reproduces what ``n_slots`` sequential
        ``step(t)`` calls (t = 0..n_slots-1) would have observed.
        """
        n_slots = self.n_slots if n_slots is None else n_slots
        drift = np.stack([self.advance_drift().copy()
                          for _ in range(n_slots)])            # [T, N]
        res = np.asarray(self.resolutions, np.float64)
        zr = np.stack([m.zeta(res) for m in self.pool])        # [M, R]
        xi = np.stack([m.xi(res) for m in self.pool])          # [M, R]
        acc = (self._difficulty[None, :] * drift)[:, :, None, None] * \
            zr[None, None, :, :]                               # [T, N, M, R]
        acc = np.clip(acc, 1e-3, 1.0)
        size = self.alpha * res**2
        eff = shannon_efficiency(self.snr_db)
        idx = np.arange(n_slots) % self.n_slots
        return HorizonTables(
            acc=jnp.asarray(acc, dtype),
            xi=jnp.asarray(xi, dtype),
            size=jnp.asarray(size, dtype),
            eff=jnp.asarray(eff, dtype),
            budgets_b=jnp.asarray(self.bandwidth_trace[idx], dtype),
            budgets_c=jnp.asarray(self.compute_trace[idx], dtype))
