"""Core implementation of the paper's contribution.

AoPI closed forms (Theorems 1-3), discrete-event oracles, the Lyapunov
virtual-queue framework, Algorithm 1 (BCD over configuration + allocation),
Algorithm 2 (first-fit server selection), Algorithm 3 (the LBCD controller),
and the DOS/JCAB/MIN baselines.

Whole-horizon execution is device-resident: ``profiles.HorizonTables``
pregenerates T slots of profiles/capacities as one pytree, and
``lbcd.rollout`` / ``baselines.rollout_{min,dos,jcab}`` /
``energy.rollout_energy`` run Algorithm 3 as a single jitted ``lax.scan``
over it — vmappable over hyperparameter grids (``lbcd.rollout_grid``) and
stacked scenarios (``lbcd.rollout_scenarios`` + ``profiles.stack_horizons``).
"""
from . import (allocate, aopi, baselines, bcd, binpack, energy, lbcd,
               lyapunov, profiles, queues)

__all__ = ["allocate", "aopi", "baselines", "bcd", "binpack", "energy",
           "lbcd", "lyapunov", "profiles", "queues"]
