"""Core implementation of the paper's contribution.

AoPI closed forms (Theorems 1-3), discrete-event oracles, the Lyapunov
virtual-queue framework, Algorithm 1 (BCD over configuration + allocation),
Algorithm 2 (first-fit server selection), Algorithm 3 (the LBCD controller),
and the DOS/JCAB/MIN baselines.
"""
from . import (allocate, aopi, baselines, bcd, binpack, energy, lbcd,
               lyapunov, profiles, queues)

__all__ = ["allocate", "aopi", "baselines", "bcd", "binpack", "energy",
           "lbcd", "lyapunov", "profiles", "queues"]
