"""Algorithm 3 — the LBCD online controller.

Per slot t (paper §V-D):
  1. observe capacities (B_t^s, C_t^s) and profile zeta_n^t;
  2. solve (P2): Algorithm 2 (virtual server -> Algorithm 1 -> first-fit ->
     Algorithm 1 per real server);
  3. update the virtual accuracy queue q(t+1) (Eq. 44).

The controller is model-free w.r.t. the future (Lyapunov), and its per-slot
cost is dominated by two jitted Algorithm-1 solves (see
benchmarks/bench_overhead.py for the Fig.-12 analog).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

from . import bcd, binpack
from .lyapunov import VirtualQueue
from .profiles import EdgeSystem


@dataclasses.dataclass
class SlotRecord:
    t: int
    aopi: np.ndarray          # per-camera closed-form AoPI
    acc: np.ndarray           # per-camera accuracy
    q: float
    assign: np.ndarray        # camera -> server
    decision: bcd.SlotDecision

    @property
    def mean_aopi(self) -> float:
        return float(np.mean(self.aopi))

    @property
    def mean_acc(self) -> float:
        return float(np.mean(self.acc))


@dataclasses.dataclass
class RunSummary:
    records: list
    v: float
    p_min: float

    @property
    def mean_aopi(self) -> float:
        return float(np.mean([r.mean_aopi for r in self.records]))

    @property
    def mean_acc(self) -> float:
        return float(np.mean([r.mean_acc for r in self.records]))

    @property
    def aopi_series(self) -> np.ndarray:
        return np.array([r.mean_aopi for r in self.records])

    @property
    def acc_series(self) -> np.ndarray:
        return np.array([r.mean_acc for r in self.records])

    @property
    def q_series(self) -> np.ndarray:
        return np.array([r.q for r in self.records])


class LBCDController:
    """The paper's controller; also reused as the serving-runtime planner
    (repro.serving.service) and the island-failover mechanism
    (repro.training.failure)."""

    def __init__(self, system: EdgeSystem, v: float = 10.0,
                 p_min: float = 0.7, n_bcd_iters: int = 4,
                 method: str = "waterfill",
                 assign_fn: Optional[Callable] = None):
        self.system = system
        self.v = v
        self.queue = VirtualQueue(p_min=p_min)
        self.n_bcd_iters = n_bcd_iters
        self.method = method
        self.assign_fn = assign_fn or binpack.first_fit

    def step(self, t: int, tables=None) -> SlotRecord:
        sys = self.system
        budgets_b, budgets_c = sys.capacities(t)          # Alg. 3 line 2
        tables = tables if tables is not None else sys.tables(t)  # line 3
        n = tables.n_cameras

        # --- Algorithm 2 line 1-2: virtual server ideal demands.
        virt = bcd.solve_slot_np(
            tables, np.zeros(n, np.int32),
            np.array([budgets_b.sum()]), np.array([budgets_c.sum()]),
            self.queue.q, self.v, n_servers=1, n_iters=self.n_bcd_iters,
            method=self.method)

        # --- Algorithm 2 lines 3-9: first-fit placement.
        assign = self.assign_fn(virt.b, virt.c, budgets_b, budgets_c)

        # --- Algorithm 2 line 10: re-solve per real server.
        dec = bcd.solve_slot_np(
            tables, assign, budgets_b, budgets_c, self.queue.q, self.v,
            n_servers=len(budgets_b), n_iters=self.n_bcd_iters,
            method=self.method)

        q = self.queue.update(float(np.mean(dec.acc)))    # Alg. 3 line 5
        return SlotRecord(t=t, aopi=dec.aopi, acc=dec.acc, q=q,
                          assign=assign, decision=dec)

    def run(self, n_slots: int) -> RunSummary:
        records = [self.step(t) for t in range(n_slots)]
        return RunSummary(records, self.v, self.queue.p_min)
