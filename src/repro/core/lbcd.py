"""Algorithm 3 — the LBCD online controller and its scan rollout engine.

Per slot t (paper §V-D):
  1. observe capacities (B_t^s, C_t^s) and profile zeta_n^t;
  2. solve (P2): Algorithm 2 (virtual server -> Algorithm 1 -> first-fit ->
     Algorithm 1 per real server);
  3. update the virtual accuracy queue q(t+1) (Eq. 44).

Two execution engines share the same per-slot math:

  * ``rollout(tables, v, p_min)`` — the device-resident engine. A full
    T-slot run is **one jitted ``lax.scan``** over a pregenerated
    ``profiles.HorizonTables`` pytree: virtual-server solve -> jit-safe
    first-fit -> per-server solve -> Eq. 44 queue update, all on device,
    with zero per-slot host round trips. Pure in (tables, v, p_min, q0), so
    it vmaps over hyperparameter grids (``rollout_grid``) and over stacked
    same-shape scenarios (``rollout_scenarios``) — the substrate for every
    benchmark sweep and the future pmap/multi-fleet scale-out.

  * ``LBCDController`` — the stateful per-slot wrapper kept for the
    serving/failover control planes (they need ``step(t)`` against live,
    mutable capacities). ``run()`` delegates to the scan engine and
    materializes the legacy ``RunSummary``/``SlotRecord`` views; a custom
    ``assign_fn`` falls back to the per-slot python loop.

``benchmarks/bench_rollout.py`` measures engine vs legacy slots/sec.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import bcd, binpack, lyapunov, profiles
from .lyapunov import VirtualQueue
from .profiles import EdgeSystem, HorizonTables


@dataclasses.dataclass
class SlotRecord:
    t: int
    aopi: np.ndarray          # per-camera closed-form AoPI
    acc: np.ndarray           # per-camera accuracy
    q: float
    assign: np.ndarray        # camera -> server
    decision: bcd.SlotDecision

    @property
    def mean_aopi(self) -> float:
        return float(np.mean(self.aopi))

    @property
    def mean_acc(self) -> float:
        return float(np.mean(self.acc))


@dataclasses.dataclass
class RunSummary:
    records: list
    v: float
    p_min: float

    @property
    def mean_aopi(self) -> float:
        return float(np.mean([r.mean_aopi for r in self.records]))

    @property
    def mean_acc(self) -> float:
        return float(np.mean([r.mean_acc for r in self.records]))

    @property
    def aopi_series(self) -> np.ndarray:
        return np.array([r.mean_aopi for r in self.records])

    @property
    def acc_series(self) -> np.ndarray:
        return np.array([r.mean_acc for r in self.records])

    @property
    def q_series(self) -> np.ndarray:
        return np.array([r.q for r in self.records])


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class RolloutResult:
    """Stacked per-slot outputs of one scan rollout (leading axis = slot;
    extra leading axes appear under vmap)."""
    aopi: jnp.ndarray         # [T, N] per-camera closed-form AoPI
    acc: jnp.ndarray          # [T, N] per-camera accuracy
    q: jnp.ndarray            # [T]    virtual queue after the Eq. 44 update
    assign: jnp.ndarray       # [T, N] camera -> server
    decision: bcd.SlotDecision  # all fields stacked [T, ...]

    @property
    def mean_aopi(self) -> float:
        return float(jnp.mean(self.aopi))

    @property
    def mean_acc(self) -> float:
        return float(jnp.mean(self.acc))

    @property
    def aopi_series(self) -> np.ndarray:
        return np.asarray(self.aopi.mean(axis=-1))

    @property
    def acc_series(self) -> np.ndarray:
        return np.asarray(self.acc.mean(axis=-1))

    @property
    def q_series(self) -> np.ndarray:
        return np.asarray(self.q)


@functools.partial(jax.jit, static_argnames=("n_bcd_iters", "method",
                                             "solver_effort",
                                             "solver_backend", "interpret"))
def rollout(tables: HorizonTables, v, p_min, q0=0.0,
            n_bcd_iters: int = 4, method: str = "waterfill",
            solver_effort: str = "fast", solver_backend: str = "jnp",
            interpret: bool | None = None) -> RolloutResult:
    """Run Algorithm 3 for all T slots as one jitted ``lax.scan``.

    Args:
      tables: whole-horizon profiles/capacities (``EdgeSystem.horizon()``).
      v, p_min: Lyapunov penalty weight and accuracy floor (traced scalars,
        so the function vmaps over hyperparameter grids).
      q0: initial virtual-queue value.
      solver_backend: "jnp" | "pallas" | "auto" — Algorithm-1
        implementation (see ``bcd.solve_slot``; "auto" switches on fleet
        size), optionally with tiling/fusion knobs riding the string
        (``"pallas:tile=4096"``, ``"pallas:nofuse"`` — see
        ``bcd.parse_backend``); ``interpret`` is the pallas
        interpret-mode override (None = auto off-TPU).
    Returns a ``RolloutResult`` of device arrays.
    """
    n = tables.acc.shape[1]
    n_servers = tables.budgets_b.shape[1]
    virt_id = jnp.zeros((n,), jnp.int32)
    solve = functools.partial(bcd.solve_slot, n_iters=n_bcd_iters,
                              method=method, solver_effort=solver_effort,
                              solver_backend=solver_backend,
                              interpret=interpret)
    # ``tables.active is None`` is a static (trace-time) branch: the
    # maskless program below is byte-identical to the pre-churn engine.
    has_active = tables.active is not None

    def step(q, xs):
        if has_active:
            acc_t, eff_t, act_t, bb, bc = xs
        else:
            acc_t, eff_t, bb, bc = xs
            act_t = None
        # Algorithm 2 lines 1-2: virtual-server ideal demands.
        virt = solve(acc_t, tables.xi, tables.size, eff_t, virt_id,
                     jnp.sum(bb)[None], jnp.sum(bc)[None], q, v, n_servers=1,
                     active=act_t)
        # Algorithm 2 lines 3-9: first-fit placement (jit-safe).
        assign = binpack.first_fit_jax(virt.b, virt.c, bb, bc)
        # Algorithm 2 line 10: re-solve per real server.
        dec = solve(acc_t, tables.xi, tables.size, eff_t, assign,
                    bb, bc, q, v, n_servers=n_servers, active=act_t)
        if has_active:
            # Eq. 44 over the live fleet only — churned-out cameras must
            # not drag the accuracy constraint toward zero.
            acc_mean = jnp.sum(dec.acc) / jnp.maximum(jnp.sum(act_t), 1.0)
        else:
            acc_mean = jnp.mean(dec.acc)
        q_next = lyapunov.queue_update(q, acc_mean, p_min)  # Eq. 44
        return q_next, (dec, assign, q_next)

    xs = ((tables.acc, profiles.eff_sequence(tables), tables.active,
           tables.budgets_b, tables.budgets_c) if has_active else
          (tables.acc, profiles.eff_sequence(tables),
           tables.budgets_b, tables.budgets_c))
    _, (decs, assigns, qs) = jax.lax.scan(
        step, jnp.asarray(q0, jnp.float32), xs)
    return RolloutResult(aopi=decs.aopi, acc=decs.acc, q=qs, assign=assigns,
                         decision=decs)


@functools.partial(jax.jit, static_argnames=("n_bcd_iters", "method",
                                             "solver_backend", "interpret"))
def rollout_grid(tables: HorizonTables, v, p_min, q0=0.0,
                 n_bcd_iters: int = 4, method: str = "waterfill",
                 solver_backend: str = "jnp",
                 interpret: bool | None = None) -> RolloutResult:
    """One vmapped call over a (V, P_min) hyperparameter grid.

    ``v``/``p_min`` are 1-D arrays of equal length G; returns a
    ``RolloutResult`` with leading axis G."""
    fn = functools.partial(rollout, n_bcd_iters=n_bcd_iters, method=method,
                           solver_backend=solver_backend,
                           interpret=interpret)
    return jax.vmap(fn, in_axes=(None, 0, 0, None))(
        tables, jnp.asarray(v), jnp.asarray(p_min), q0)


@functools.partial(jax.jit, static_argnames=("n_bcd_iters", "method",
                                             "solver_backend", "interpret"))
def rollout_scenarios(tables: HorizonTables, v, p_min, q0=0.0,
                      n_bcd_iters: int = 4, method: str = "waterfill",
                      solver_backend: str = "jnp",
                      interpret: bool | None = None) -> RolloutResult:
    """One vmapped call over stacked same-shape scenarios
    (``profiles.stack_horizons``); shared scalar hyperparameters."""
    fn = functools.partial(rollout, n_bcd_iters=n_bcd_iters, method=method,
                           solver_backend=solver_backend,
                           interpret=interpret)
    return jax.vmap(fn, in_axes=(0, None, None, None))(
        tables, v, p_min, q0)


def summarize(res: RolloutResult, v: float, p_min: float) -> RunSummary:
    """Materialize a scan rollout into the legacy RunSummary/SlotRecord
    views (one host transfer for the whole horizon)."""
    res = jax.tree.map(np.asarray, res)
    records = [
        SlotRecord(t=t, aopi=res.aopi[t], acc=res.acc[t],
                   q=float(res.q[t]), assign=res.assign[t],
                   decision=jax.tree.map(lambda x, t=t: x[t], res.decision))
        for t in range(res.aopi.shape[0])
    ]
    return RunSummary(records, v, p_min)


class LBCDController:
    """The paper's controller; also reused as the serving-runtime planner
    (repro.serving.service) and the island-failover mechanism
    (repro.training.failure)."""

    def __init__(self, system: EdgeSystem, v: float = 10.0,
                 p_min: float = 0.7, n_bcd_iters: int = 4,
                 method: str = "waterfill",
                 assign_fn: Optional[Callable] = None,
                 solver_effort: str = "fast",
                 solver_backend: str = "jnp"):
        self.system = system
        self.v = v
        self.queue = VirtualQueue(p_min=p_min)
        self.n_bcd_iters = n_bcd_iters
        self.method = method
        self.assign_fn = assign_fn or binpack.first_fit
        self.solver_effort = solver_effort
        self.solver_backend = solver_backend

    def plan(self, tables: HorizonTables, q0: float | None = None
             ) -> RolloutResult:
        """Lookahead / what-if epochs for the serving planner: run the
        controller's hyperparameters over ``tables`` as ONE jitted scan
        (``rollout``) from the live virtual-queue state. Does *not* advance
        ``self.queue`` — the service commits epochs one at a time as the
        data plane actually executes them (``AnalyticsService.run_epoch``).
        """
        return rollout(tables, self.v, self.queue.p_min,
                       q0=self.queue.q if q0 is None else q0,
                       n_bcd_iters=self.n_bcd_iters, method=self.method,
                       solver_effort=self.solver_effort,
                       solver_backend=self.solver_backend)

    def step(self, t: int, tables=None) -> SlotRecord:
        sys = self.system
        budgets_b, budgets_c = sys.capacities(t)          # Alg. 3 line 2
        tables = tables if tables is not None else sys.tables(t)  # line 3
        n = tables.n_cameras

        # --- Algorithm 2 line 1-2: virtual server ideal demands.
        virt = bcd.solve_slot_np(
            tables, np.zeros(n, np.int32),
            np.array([budgets_b.sum()]), np.array([budgets_c.sum()]),
            self.queue.q, self.v, n_servers=1, n_iters=self.n_bcd_iters,
            method=self.method, solver_effort=self.solver_effort,
            solver_backend=self.solver_backend)

        # --- Algorithm 2 lines 3-9: first-fit placement.
        assign = self.assign_fn(virt.b, virt.c, budgets_b, budgets_c)

        # --- Algorithm 2 line 10: re-solve per real server.
        dec = bcd.solve_slot_np(
            tables, assign, budgets_b, budgets_c, self.queue.q, self.v,
            n_servers=len(budgets_b), n_iters=self.n_bcd_iters,
            method=self.method, solver_effort=self.solver_effort,
            solver_backend=self.solver_backend)

        q = self.queue.update(float(np.mean(dec.acc)))    # Alg. 3 line 5
        return SlotRecord(t=t, aopi=dec.aopi, acc=dec.acc, q=q,
                          assign=assign, decision=dec)

    def run(self, n_slots: int, engine: str = "scan") -> RunSummary:
        """Roll the controller forward ``n_slots`` slots.

        ``engine="scan"`` (default) pregenerates the horizon and runs the
        device-resident ``rollout``; ``engine="legacy"`` keeps the per-slot
        python loop. A custom ``assign_fn`` forces the legacy path (the scan
        engine is specialized to first-fit)."""
        if engine == "scan" and self.assign_fn is binpack.first_fit:
            tables = self.system.horizon(n_slots)
            res = rollout(tables, self.v, self.queue.p_min, q0=self.queue.q,
                          n_bcd_iters=self.n_bcd_iters, method=self.method,
                          solver_effort=self.solver_effort,
                          solver_backend=self.solver_backend)
            self.queue.q = float(res.q[-1])
            return summarize(res, self.v, self.queue.p_min)
        records = [self.step(t) for t in range(n_slots)]
        return RunSummary(records, self.v, self.queue.p_min)
