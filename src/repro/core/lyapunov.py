"""Lyapunov framework for the long-term accuracy constraint (paper §V-A).

The long-term constraint (9) ``avg_t avg_n p_{n,t} >= P_min`` is handled by a
virtual accuracy-debt queue

    q(t+1) = max(q(t) - Pbar_t + P_min, 0),                 (Eq. 44)

and each slot solves the drift-plus-penalty surrogate (problem (P2))

    min  -q(t) * Pbar_t + V * Abar_t.                        (Eq. 51)

Theorem 4 gives the O(1/V) optimality gap and the accuracy bound; the
benchmarks sweep V / P_min to reproduce Figs. 7-8.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass
class VirtualQueue:
    """Host-side accuracy-debt queue q(t) (Eq. 44)."""
    p_min: float
    q: float = 0.0

    def update(self, p_bar: float) -> float:
        self.q = max(self.q - float(p_bar) + self.p_min, 0.0)
        return self.q


def queue_update(q, p_bar, p_min):
    """Functional (jit-safe) form of Eq. 44."""
    return jnp.maximum(q - p_bar + p_min, 0.0)


def drift_plus_penalty(aopi, acc, q, V):
    """Per-slot objective of problem (P2), Eq. (51).

    ``aopi``/``acc`` are per-camera arrays; returns the scalar
    ``-q * mean(acc) + V * mean(aopi)``.
    """
    return -q * jnp.mean(acc) + V * jnp.mean(aopi)


def per_camera_score(aopi, acc, q, V, n):
    """Separable per-camera contribution to Eq. (51): the config-selection
    step of Algorithm 1 minimizes this independently per camera."""
    return (-q * acc + V * aopi) / n


def drift_bound(q, p_bar, p_min):
    """RHS of Lemma 1: 1/2 + q * (P_min - Pbar). Used by tests to check the
    implemented queue never violates the drift inequality in expectation."""
    return 0.5 + q * (p_min - p_bar)
