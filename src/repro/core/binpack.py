"""Algorithm 2 — edge-server selection as 2D first-fit bin packing.

Given the *virtual-server* resource demands (Algorithm 2 lines 1-2), cameras
are sized by Eq. (56), servers by Eq. (57), both sorted descending, and each
camera goes to the first server with enough remaining bandwidth AND compute;
if none fits, to the server with most remaining volume (lines 4-9).

Two implementations, semantically equivalent (asserted in tests):

  * ``first_fit``     — host-side numpy reference; O(N S) with tiny
    constants, used by the legacy per-slot controller path;
  * ``first_fit_jax`` — jit-safe (sort + ``fori_loop``) variant traced
    inside the ``lax.scan`` rollout engine so whole-horizon runs never
    leave the device.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def first_fit(b_hat: np.ndarray, c_hat: np.ndarray, budgets_b: np.ndarray,
              budgets_c: np.ndarray) -> np.ndarray:
    """Assign cameras to servers. Returns int[N] server ids.

    Args:
      b_hat, c_hat: ideal (virtual-server) per-camera demands, Alg. 2 line 2.
      budgets_b, budgets_c: per-server capacities B_t^s, C_t^s.
    """
    b_hat = np.asarray(b_hat, np.float64)
    c_hat = np.asarray(c_hat, np.float64)
    budgets_b = np.asarray(budgets_b, np.float64)
    budgets_c = np.asarray(budgets_c, np.float64)
    tot_b, tot_c = budgets_b.sum(), budgets_c.sum()

    phi = b_hat / tot_b + c_hat / tot_c                  # Eq. (56)
    psi = budgets_b / tot_b + budgets_c / tot_c          # Eq. (57)

    cam_order = np.argsort(-phi)                         # largest first
    srv_order = np.argsort(-psi)
    rem_b = budgets_b.copy()
    rem_c = budgets_c.copy()
    assign = np.zeros(b_hat.shape[0], np.int32)

    for n in cam_order:
        placed = False
        for s in srv_order:
            if rem_b[s] >= b_hat[n] and rem_c[s] >= c_hat[n]:
                assign[n] = s
                rem_b[s] -= b_hat[n]
                rem_c[s] -= c_hat[n]
                placed = True
                break
        if not placed:                                    # lines 6-8
            rem_vol = rem_b / tot_b + rem_c / tot_c
            s = int(np.argmax(rem_vol))
            assign[n] = s
            rem_b[s] = max(rem_b[s] - b_hat[n], 0.0)
            rem_c[s] = max(rem_c[s] - c_hat[n], 0.0)
    return assign


def first_fit_jax(b_hat: jnp.ndarray, c_hat: jnp.ndarray,
                  budgets_b: jnp.ndarray,
                  budgets_c: jnp.ndarray) -> jnp.ndarray:
    """Jit-safe Algorithm 2 placement, equivalent to ``first_fit``.

    Cameras/servers are sorted by the Eq. (56)/(57) volumes, then a
    ``fori_loop`` places one camera per iteration (vectorized over servers).
    Traceable under jit/vmap/scan; returns int32[N] server ids.
    """
    b_hat = jnp.asarray(b_hat)
    c_hat = jnp.asarray(c_hat)
    budgets_b = jnp.asarray(budgets_b)
    budgets_c = jnp.asarray(budgets_c)
    tot_b = budgets_b.sum()
    tot_c = budgets_c.sum()

    phi = b_hat / tot_b + c_hat / tot_c                  # Eq. (56)
    psi = budgets_b / tot_b + budgets_c / tot_c          # Eq. (57)
    cam_order = jnp.argsort(-phi)                        # largest first
    srv_order = jnp.argsort(-psi)

    def body(i, state):
        rem_b, rem_c, assign = state
        n = cam_order[i]
        bn, cn = b_hat[n], c_hat[n]
        fits = (rem_b[srv_order] >= bn) & (rem_c[srv_order] >= cn)
        s_fit = srv_order[jnp.argmax(fits)]              # first fit in order
        rem_vol = rem_b / tot_b + rem_c / tot_c          # lines 6-8
        s = jnp.where(fits.any(), s_fit, jnp.argmax(rem_vol))
        rem_b = jnp.maximum(rem_b.at[s].add(-bn), 0.0)
        rem_c = jnp.maximum(rem_c.at[s].add(-cn), 0.0)
        return rem_b, rem_c, assign.at[n].set(s.astype(jnp.int32))

    assign0 = jnp.zeros(b_hat.shape[0], jnp.int32)
    _, _, assign = jax.lax.fori_loop(
        0, b_hat.shape[0], body, (budgets_b, budgets_c, assign0))
    return assign


def hierarchical_first_fit(b_hat, c_hat, pod_budgets_b, pod_budgets_c,
                           islands_per_pod: int) -> np.ndarray:
    """Multi-pod variant (beyond paper, §Scale-out): first-fit over pods,
    then over islands inside the chosen pod. Island capacity = pod capacity /
    islands_per_pod. Returns global island ids ``pod * islands_per_pod + i``.
    """
    pod_budgets_b = np.asarray(pod_budgets_b, np.float64)
    pod_budgets_c = np.asarray(pod_budgets_c, np.float64)
    pods = first_fit(b_hat, c_hat, pod_budgets_b, pod_budgets_c)
    out = np.zeros_like(pods)
    for pod in range(pod_budgets_b.shape[0]):
        mask = pods == pod
        if not mask.any():
            continue
        ib = np.full(islands_per_pod, pod_budgets_b[pod] / islands_per_pod)
        ic = np.full(islands_per_pod, pod_budgets_c[pod] / islands_per_pod)
        local = first_fit(b_hat[mask], c_hat[mask], ib, ic)
        out[mask] = pod * islands_per_pod + local
    return out
