"""Closed-form Age-of-Processed-Information (AoPI) expressions.

Implements Theorems 1-3 of "Towards Timely Video Analytics Services at the
Network Edge" as vectorized, differentiable JAX functions.

Notation (per-slot, per-camera; subscripts dropped as in the paper §IV):
    lam : average transmission (frame upload) rate, 1/E[T]   [frames/s]
    mu  : average computation (recognition) rate, 1/E[O]     [frames/s]
    p   : per-frame recognition accuracy in (0, 1]

Both transmission and computation delays are modeled exponential. The FCFS
form (Theorem 1) is only finite in the stable region ``lam < mu``; outside it
we return +inf so that optimizers naturally avoid the unstable region
(constraint (10) of problem (P1)).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

FCFS = 0
LCFSP = 1

_BIG = jnp.inf


def aopi_fcfs(lam, mu, p):
    """Average AoPI under the FCFS policy (Theorem 1, Eq. 11).

    A_F = (1 + 1/p)/lam + 1/mu + (2 lam^3 + lam mu^2 - mu lam^2)
                                  / (mu^4 - mu^2 lam^2)

    Returns +inf where the M/M/1 queue is unstable (lam >= mu).
    """
    dtype = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32
    lam = jnp.asarray(lam, dtype)
    mu = jnp.asarray(mu, dtype)
    p = jnp.asarray(p, dtype)
    stable = lam < mu
    # Evaluate on a clamped-safe lam to avoid nan grads from the masked branch.
    lam_s = jnp.where(stable, lam, 0.5 * mu)
    queue = (2.0 * lam_s**3 + lam_s * mu**2 - mu * lam_s**2) / (
        mu**4 - mu**2 * lam_s**2)
    a = (1.0 + 1.0 / p) / lam_s + 1.0 / mu + queue
    return jnp.where(stable, a, _BIG)


def aopi_lcfsp(lam, mu, p):
    """Average AoPI under the LCFSP policy (Theorem 2, Eq. 23).

    A_L = (1 + 1/p)/lam + 1/(p mu).   Finite for all lam, mu > 0.
    """
    return (1.0 + 1.0 / p) / lam + 1.0 / (p * mu)


def aopi(lam, mu, p, policy):
    """Policy-dispatched AoPI. ``policy`` is 0 (FCFS) or 1 (LCFSP), may be an
    array (vectorized over cameras)."""
    policy = jnp.asarray(policy)
    return jnp.where(policy == LCFSP, aopi_lcfsp(lam, mu, p),
                     aopi_fcfs(lam, mu, p))


def policy_threshold(rho):
    """Theorem 3 (Eq. 43): FCFS AoPI exceeds LCFSP iff
    ``p >= (1 - rho^2) / (2 rho^3 - 2 rho^2 + rho + 1)`` with rho = lam/mu.

    For rho >= 1 FCFS is unstable, so the threshold is 0 (LCFSP always wins).
    """
    rho = jnp.asarray(rho)
    thr = (1.0 - rho**2) / (2.0 * rho**3 - 2.0 * rho**2 + rho + 1.0)
    return jnp.where(rho < 1.0, thr, 0.0)


def optimal_policy(lam, mu, p):
    """Per Theorem 3: returns LCFSP (1) where it achieves lower AoPI."""
    rho = lam / mu
    return jnp.where(p >= policy_threshold(rho), LCFSP, FCFS).astype(jnp.int32)


def aopi_best(lam, mu, p):
    """AoPI under the per-point optimal policy (envelope of Thm 1 and 2)."""
    return jnp.minimum(aopi_fcfs(lam, mu, p), aopi_lcfsp(lam, mu, p))


def aopi_masked(lam, mu, p, policy, active=None):
    """AoPI with the zero-rate corner masked out.

    A churned-out camera has ``lam = mu = 0`` (and ``active = 0`` when a
    fleet mask is threaded through) — Theorems 1-2 divide by both rates,
    so the raw expressions return inf/NaN there. This wrapper evaluates
    the closed forms on rate values substituted to a safe interior point
    for dead streams and returns exactly ``0.0`` for them, so fleet
    reductions (means, Lyapunov drift) stay finite. Live streams get the
    bit-exact ``aopi`` value (the substitution only touches dead lanes).
    """
    lam = jnp.asarray(lam)
    mu = jnp.asarray(mu)
    p = jnp.asarray(p)
    live = (lam > 0) & (mu > 0)
    if active is not None:
        live = live & (jnp.asarray(active) > 0)
    lam_s = jnp.where(live, lam, 1.0)
    mu_s = jnp.where(live, mu, 2.0)
    p_s = jnp.where(live, p, 0.5)
    return jnp.where(live, aopi(lam_s, mu_s, p_s, policy), 0.0)


# ---------------------------------------------------------------------------
# Analytic derivatives (used by allocator tests and for fast Newton steps;
# jax.grad of the functions above agrees — asserted in tests).
# ---------------------------------------------------------------------------

def d_aopi_lcfsp_dlam(lam, mu, p):
    return -(1.0 + 1.0 / p) / lam**2


def d_aopi_lcfsp_dmu(lam, mu, p):
    return -1.0 / (p * mu**2)


def d_aopi_fcfs_dlam(lam, mu, p):
    """dA_F/dlam, valid for lam < mu."""
    lam = jnp.asarray(lam)
    # d/dlam of queue term  q(lam) = (2 lam^3 + lam mu^2 - mu lam^2) /
    #                                (mu^4 - mu^2 lam^2)
    num = 2.0 * lam**3 + lam * mu**2 - mu * lam**2
    den = mu**4 - mu**2 * lam**2
    dnum = 6.0 * lam**2 + mu**2 - 2.0 * mu * lam
    dden = -2.0 * mu**2 * lam
    dq = (dnum * den - num * dden) / den**2
    return -(1.0 + 1.0 / p) / lam**2 + dq


def d_aopi_fcfs_dmu(lam, mu, p):
    mu = jnp.asarray(mu)
    num = 2.0 * lam**3 + lam * mu**2 - mu * lam**2
    den = mu**4 - mu**2 * lam**2
    dnum = 2.0 * lam * mu - lam**2
    dden = 4.0 * mu**3 - 2.0 * mu * lam**2
    dq = (dnum * den - num * dden) / den**2
    return -1.0 / mu**2 + dq


# ---------------------------------------------------------------------------
# Rate frontiers (Figs. 3 and 5): minimum lam (resp. mu) needed to meet an
# average-AoPI target given the other rate. Solved by bisection under jit.
# ---------------------------------------------------------------------------

def _bisect(fn, lo, hi, iters: int = 60):
    """Find root of monotone-decreasing ``fn`` on [lo, hi] by bisection."""
    def body(_, state):
        lo, hi = state
        mid = 0.5 * (lo + hi)
        below = fn(mid) > 0.0  # still above target -> need larger rate
        return jnp.where(below, mid, lo), jnp.where(below, hi, mid)
    lo, hi = jax.lax.fori_loop(0, iters, body, (jnp.asarray(lo), jnp.asarray(hi)))
    return 0.5 * (lo + hi)


def min_lam_for_target(target, mu, p, policy, hi: float = 1e6):
    """Minimum transmission rate s.t. AoPI(lam, mu, p, policy) <= target.

    Under FCFS, AoPI is convex in lam (Corollary 4.1) — the *left* branch is
    decreasing, so we bisect on it up to the interior minimizer.
    """
    policy = jnp.asarray(policy)

    def gap_l(lam):
        return aopi_lcfsp(lam, mu, p) - target

    def gap_f(lam):
        return aopi_fcfs(lam, mu, p) - target

    lam_star = argmin_lam_fcfs(mu, p)  # interior minimizer of the convex A_F
    lcfsp = _bisect(gap_l, 1e-9, hi)
    fcfs = _bisect(gap_f, 1e-9, lam_star)
    feasible_f = aopi_fcfs(lam_star, mu, p) <= target
    fcfs = jnp.where(feasible_f, fcfs, jnp.inf)
    return jnp.where(policy == LCFSP, lcfsp, fcfs)


def min_mu_for_target(target, lam, p, policy, hi: float = 1e6):
    """Minimum computation rate s.t. AoPI <= target (A is decreasing in mu)."""
    policy = jnp.asarray(policy)

    def gap(mu):
        return aopi(lam, mu, p, policy) - target

    feasible = aopi(lam, jnp.asarray(hi), p, policy) <= target
    return jnp.where(feasible, _bisect(gap, 1e-9, hi), jnp.inf)


def argmin_lam_fcfs(mu, p, iters: int = 26):
    """Interior minimizer lam* of the convex A_F(lam) on (0, mu).

    Found by bisection on the (increasing) derivative. Corollary 4.1
    guarantees a unique interior minimum; lam* decreases with p.
    """
    mu = jnp.asarray(mu)
    lo = jnp.full(jnp.shape(mu), 1e-9)
    hi = 0.999999 * mu

    def body(_, state):
        lo, hi = state
        mid = 0.5 * (lo + hi)
        neg = d_aopi_fcfs_dlam(mid, mu, p) < 0.0
        return jnp.where(neg, mid, lo), jnp.where(neg, hi, mid)

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return 0.5 * (lo + hi)
