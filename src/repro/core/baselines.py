"""State-of-the-art baselines (paper §VI-A): DOS, JCAB, MIN.

All baselines share LBCD's profiling substrate and (per the paper) the
computation policy and model are chosen via Theorem 3 given their own
resolution / allocation decisions; DOS additionally shares LBCD's server
selection. Evaluation (per-camera AoPI/accuracy) uses the same closed forms,
so comparisons isolate the *decision* quality.

Like LBCD, each baseline has two engines: a legacy per-slot ``step(t)`` and
a device-resident whole-horizon rollout (``rollout_min`` / ``rollout_dos`` /
``rollout_jcab`` — one jitted ``lax.scan`` over ``profiles.HorizonTables``,
vmappable over stacked scenarios). ``BaselineController.run`` uses the scan
engine and materializes the legacy ``RunSummary`` view.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import aopi, bcd, binpack, profiles
from .lbcd import RolloutResult, RunSummary, SlotRecord, summarize
from .profiles import EdgeSystem, HorizonTables
from ..kernels import slot_solver


def _evaluate(lam, mu, p, pol):
    lam = np.maximum(lam, 1e-9)
    mu = np.maximum(mu, 1e-9)
    a = np.where(pol == aopi.LCFSP,
                 np.asarray(aopi.aopi_lcfsp(lam, mu, p)),
                 np.asarray(aopi.aopi_fcfs(lam, mu, p)))
    return a


def _thm3_policy(lam, mu, p):
    return np.asarray(aopi.optimal_policy(lam, mu, p))


# ---------------------------------------------------------------------------
# Device-resident rollout engines (one lax.scan per horizon).
# ---------------------------------------------------------------------------

def _eval_decision(acc_t, xi, size, eff, r_idx, m_idx, b, c, active=None):
    """Theorem-3 policy + closed-form AoPI for a fixed configuration (the
    jit twin of ``_thm3_policy`` + ``_evaluate``). With a churn mask the
    dead cameras' outputs are forced to exactly 0 and the score is the
    live-fleet mean (the maskless path is trace-identical to pre-churn)."""
    n = acc_t.shape[0]
    lam = b * eff / size[r_idx]
    mu = c / xi[m_idx, r_idx]
    p = acc_t[jnp.arange(n), m_idx, r_idx]
    if active is not None:
        act = (active > 0).astype(acc_t.dtype)
        lam = lam * act
        mu = mu * act
        pol = aopi.optimal_policy(jnp.maximum(lam, 1e-9),
                                  jnp.maximum(mu, 1e-9), p)
        a = aopi.aopi_masked(lam, mu, p, pol, active=act)
        p = p * act
        b = b * act
        c = c * act
        n_live = jnp.maximum(jnp.sum(act), 1.0)
        return bcd.SlotDecision(r_idx, m_idx, pol, b, c, lam, mu, p, a,
                                jnp.sum(a) / n_live)
    pol = aopi.optimal_policy(lam, mu, p)
    lam_e = jnp.maximum(lam, 1e-9)
    mu_e = jnp.maximum(mu, 1e-9)
    a = jnp.where(pol == aopi.LCFSP, aopi.aopi_lcfsp(lam_e, mu_e, p),
                  aopi.aopi_fcfs(lam_e, mu_e, p))
    return bcd.SlotDecision(r_idx, m_idx, pol, b, c, lam, mu, p, a,
                            jnp.mean(a))


def _scan_result(step, tables: HorizonTables) -> RolloutResult:
    xs = (tables.acc, profiles.eff_sequence(tables),
          tables.budgets_b, tables.budgets_c)
    if tables.active is not None:
        xs = xs + (tables.active,)
    _, (decs, assigns, qs) = jax.lax.scan(step, jnp.float32(0.0), xs)
    return RolloutResult(aopi=decs.aopi, acc=decs.acc, q=qs, assign=assigns,
                         decision=decs)


@functools.partial(jax.jit, static_argnames=("n_bcd_iters", "method",
                                             "solver_effort",
                                             "solver_backend", "interpret"))
def rollout_min(tables: HorizonTables, v=10.0, n_bcd_iters: int = 4,
                method: str = "waterfill", solver_effort: str = "fast",
                solver_backend: str = "jnp",
                interpret: bool | None = None) -> RolloutResult:
    """MIN lower bound over the whole horizon: one pooled virtual server,
    no accuracy queue (q == 0), as a single scan."""
    n = tables.acc.shape[1]
    virt_id = jnp.zeros((n,), jnp.int32)
    has_active = tables.active is not None

    def step(q, xs):
        if has_active:
            acc_t, eff_t, bb, bc, act_t = xs
        else:
            acc_t, eff_t, bb, bc = xs
            act_t = None
        dec = bcd.solve_slot(acc_t, tables.xi, tables.size, eff_t,
                             virt_id, jnp.sum(bb)[None], jnp.sum(bc)[None],
                             jnp.float32(0.0), v, n_servers=1,
                             n_iters=n_bcd_iters, method=method,
                             solver_effort=solver_effort,
                             solver_backend=solver_backend,
                             interpret=interpret, active=act_t)
        return q, (dec, virt_id, q)

    return _scan_result(step, tables)


def _baseline_scan(solver_backend: str, n: int):
    """Resolve the DOS/JCAB config-scan backend and return the scan fn.

    The pallas path streams camera tiles through
    ``slot_solver.baseline_argmax`` so the ``[N, M, R]`` latency/score
    tensors are never materialized; indices are bitwise identical to the
    jnp path. ``"auto"`` follows the same fleet-size switch point as the
    Algorithm-1 solver (jnp below ``AUTO_PALLAS_MIN_CAMERAS``).
    """
    spec = bcd.resolve_spec(solver_backend, n)
    return functools.partial(slot_solver.baseline_argmax,
                             backend=spec.backend,
                             block_n=spec.tile_n or 1024)


@functools.partial(jax.jit, static_argnames=("solver_backend",))
def rollout_dos(tables: HorizonTables, weight=1.0,
                solver_backend: str = "jnp") -> RolloutResult:
    """DOS over the whole horizon as a single scan (same per-slot math as
    ``DOSController.step``, with the jit-safe first-fit).

    ``solver_backend`` selects the config-scan engine: "jnp" materializes
    the ``[N, M, R]`` score tensor, "pallas" streams camera tiles through
    the ``slot_solver.baseline_argmax`` kernel (bitwise-identical
    indices); "auto" switches on fleet size like ``bcd.solve_slot``."""
    n = tables.acc.shape[1]
    n_servers = tables.budgets_b.shape[1]
    xi, size = tables.xi, tables.size
    scan = _baseline_scan(solver_backend, n)
    has_active = tables.active is not None

    def step(q, xs):
        if has_active:
            acc_t, eff_t, bb, bc, act_t = xs
        else:
            acc_t, eff_t, bb, bc = xs
        b0 = jnp.sum(bb) / n
        c0 = jnp.sum(bc) / n
        m_idx, r_idx = scan(jnp.full((n,), b0), jnp.full((n,), c0), acc_t,
                            xi, size, eff_t, mode="dos", threshold=weight)

        w_b = jnp.sqrt(size[r_idx] / eff_t)
        w_c = jnp.sqrt(xi[m_idx, r_idx])
        if has_active:
            # Dead cameras carry zero weight, so their proportional share
            # of every server's budget flows to the survivors; the guards
            # keep all-dead servers at 0/eps = 0 instead of 0/0 = NaN.
            act = (act_t > 0).astype(w_b.dtype)
            w_b = w_b * act
            w_c = w_c * act
            eps = jnp.asarray(1e-30, w_b.dtype)
            assign = binpack.first_fit_jax(
                w_b / jnp.maximum(w_b.sum(), eps) * jnp.sum(bb),
                w_c / jnp.maximum(w_c.sum(), eps) * jnp.sum(bc), bb, bc)
            den_b = jnp.maximum(jax.ops.segment_sum(
                w_b, assign, num_segments=n_servers), eps)
            den_c = jnp.maximum(jax.ops.segment_sum(
                w_c, assign, num_segments=n_servers), eps)
            b = bb[assign] * w_b / den_b[assign]
            c = bc[assign] * w_c / den_c[assign]
            dec = _eval_decision(acc_t, xi, size, eff_t, r_idx, m_idx, b, c,
                                 active=act)
        else:
            assign = binpack.first_fit_jax(
                w_b / w_b.sum() * jnp.sum(bb),
                w_c / w_c.sum() * jnp.sum(bc), bb, bc)
            den_b = jax.ops.segment_sum(w_b, assign, num_segments=n_servers)
            den_c = jax.ops.segment_sum(w_c, assign, num_segments=n_servers)
            b = bb[assign] * w_b / den_b[assign]
            c = bc[assign] * w_c / den_c[assign]
            dec = _eval_decision(acc_t, xi, size, eff_t, r_idx, m_idx, b, c)
        return q, (dec, assign, q)

    return _scan_result(step, tables)


@functools.partial(jax.jit, static_argnames=("n_rounds", "solver_backend"))
def rollout_jcab(tables: HorizonTables, latency_cap=0.5,
                 n_rounds: int = 3,
                 solver_backend: str = "jnp") -> RolloutResult:
    """JCAB over the whole horizon as a single scan (same per-slot math as
    ``JCABController.step``; the round-robin assignment is static).

    ``solver_backend`` selects the config-scan engine exactly as in
    :func:`rollout_dos` (the cap check, -inf masking and min-latency
    fallback all run inside the streaming kernel on the pallas path)."""
    n = tables.acc.shape[1]
    n_servers = tables.budgets_b.shape[1]
    xi, size = tables.xi, tables.size
    scan = _baseline_scan(solver_backend, n)
    assign = (jnp.arange(n) % n_servers).astype(jnp.int32)
    counts = jax.ops.segment_sum(jnp.ones((n,)), assign,
                                 num_segments=n_servers)
    share = (1.0 / jnp.maximum(counts, 1.0))[assign]
    has_active = tables.active is not None

    def step(q, xs):
        if has_active:
            acc_t, eff_t, bb, bc, act_t = xs
            act = (act_t > 0).astype(bb.dtype)
            # Per-slot live share (the static round-robin assignment
            # stays, but a server splits its budget over live members).
            counts_t = jax.ops.segment_sum(act, assign,
                                           num_segments=n_servers)
            share_t = act * (1.0 / jnp.maximum(counts_t, 1.0))[assign]
            b = bb[assign] * share_t
            c = bc[assign] * share_t
        else:
            acc_t, eff_t, bb, bc = xs
            act = None
            b = bb[assign] * share
            c = bc[assign] * share
        m_idx = jnp.zeros((n,), jnp.int32)
        r_idx = jnp.zeros((n,), jnp.int32)
        for _ in range(n_rounds):
            m_idx, r_idx = scan(b, c, acc_t, xi, size, eff_t, mode="jcab",
                                threshold=latency_cap)
            size_n = size[r_idx]
            xi_n = xi[m_idx, r_idx]
            if has_active:
                size_n = size_n * act
                xi_n = xi_n * act
                eps = jnp.asarray(1e-30, size_n.dtype)
                den_b = jnp.maximum(jax.ops.segment_sum(
                    size_n, assign, num_segments=n_servers), eps)
                den_c = jnp.maximum(jax.ops.segment_sum(
                    xi_n, assign, num_segments=n_servers), eps)
            else:
                den_b = jax.ops.segment_sum(size_n, assign,
                                            num_segments=n_servers)
                den_c = jax.ops.segment_sum(xi_n, assign,
                                            num_segments=n_servers)
            b = bb[assign] * size_n / den_b[assign]
            c = bc[assign] * xi_n / den_c[assign]
        dec = _eval_decision(acc_t, xi, size, eff_t, r_idx, m_idx, b, c,
                             active=act)
        return q, (dec, assign, q)

    return _scan_result(step, tables)


@dataclasses.dataclass
class BaselineController:
    system: EdgeSystem
    name: str = "base"

    def run(self, n_slots: int, engine: str = "scan") -> RunSummary:
        if engine == "scan":
            res = self._rollout(self.system.horizon(n_slots))
            return summarize(res, v=0.0, p_min=0.0)
        records = [self.step(t) for t in range(n_slots)]
        return RunSummary(records, v=0.0, p_min=0.0)

    def _rollout(self, tables: HorizonTables) -> RolloutResult:
        raise NotImplementedError


class MINController(BaselineController):
    """Lower bound: one virtual server, no accuracy requirement (q == 0)."""

    def __init__(self, system: EdgeSystem, v: float = 10.0, **kw):
        super().__init__(system, name="MIN")
        self.v = v
        self.kw = kw

    def step(self, t: int, tables=None) -> SlotRecord:
        sys = self.system
        budgets_b, budgets_c = sys.capacities(t)
        tables = tables if tables is not None else sys.tables(t)
        n = tables.n_cameras
        dec = bcd.solve_slot_np(
            tables, np.zeros(n, np.int32), np.array([budgets_b.sum()]),
            np.array([budgets_c.sum()]), 0.0, self.v, n_servers=1, **self.kw)
        return SlotRecord(t=t, aopi=dec.aopi, acc=dec.acc, q=0.0,
                          assign=np.zeros(n, np.int32), decision=dec)

    def _rollout(self, tables: HorizonTables) -> RolloutResult:
        known = {"n_iters", "method", "solver_effort", "solver_backend"}
        unknown = set(self.kw) - known
        if unknown:
            raise TypeError(
                f"MIN scan rollout does not support kwargs {sorted(unknown)};"
                " use run(..., engine='legacy')")
        return rollout_min(tables, self.v,
                           n_bcd_iters=self.kw.get("n_iters", 4),
                           method=self.kw.get("method", "waterfill"),
                           solver_effort=self.kw.get("solver_effort",
                                                     "fast"),
                           solver_backend=self.kw.get("solver_backend",
                                                      "jnp"))


class DOSController(BaselineController):
    """DOS [47]: maximize (accuracy - latency).

    Per camera it picks the (r, m) maximizing ``zeta - (1/lam + 1/mu)`` under
    an equal split, then allocates resources to minimize total expected
    latency (sqrt water-filling — latency-optimal but AoPI-blind, which is
    exactly the behaviour §VI-B2 reports: it collapses to the lightest
    configuration). Server selection is shared with LBCD (first-fit on its
    demands), per §VI-A.
    """

    def __init__(self, system: EdgeSystem, weight: float = 1.0,
                 solver_backend: str = "jnp"):
        super().__init__(system, name="DOS")
        self.weight = weight
        self.solver_backend = solver_backend

    def step(self, t: int, tables=None) -> SlotRecord:
        sys = self.system
        budgets_b, budgets_c = sys.capacities(t)
        tables = tables if tables is not None else sys.tables(t)
        n, m_count, r_count = tables.acc.shape

        # Equal-split provisional rates.
        b0 = budgets_b.sum() / n
        c0 = budgets_c.sum() / n
        lam0 = b0 * tables.eff[:, None, None] / tables.size[None, None, :]
        mu0 = c0 / tables.xi[None, :, :]
        latency = 1.0 / np.maximum(lam0, 1e-9) + 1.0 / np.maximum(mu0, 1e-9)
        score = tables.acc - self.weight * latency
        flat = score.reshape(n, -1)
        best = flat.argmax(1)
        m_idx = (best // r_count).astype(np.int32)
        r_idx = (best % r_count).astype(np.int32)

        # Latency-minimizing allocation: b ~ sqrt(size/eff), c ~ sqrt(xi).
        size_n = tables.size[r_idx]
        xi_n = tables.xi[m_idx, r_idx]
        w_b = np.sqrt(size_n / tables.eff)
        w_c = np.sqrt(xi_n)
        assign = binpack.first_fit(w_b / w_b.sum() * budgets_b.sum(),
                                   w_c / w_c.sum() * budgets_c.sum(),
                                   budgets_b, budgets_c)
        b = np.zeros(n)
        c = np.zeros(n)
        for s in range(len(budgets_b)):
            mask = assign == s
            if not mask.any():
                continue
            b[mask] = budgets_b[s] * w_b[mask] / w_b[mask].sum()
            c[mask] = budgets_c[s] * w_c[mask] / w_c[mask].sum()

        lam = b * tables.eff / size_n
        mu = c / xi_n
        p = tables.acc[np.arange(n), m_idx, r_idx]
        pol = _thm3_policy(lam, mu, p)
        a = _evaluate(lam, mu, p, pol)
        dec = bcd.SlotDecision(r_idx, m_idx, pol, b, c, lam, mu, p, a,
                               np.float32(a.mean()))
        return SlotRecord(t=t, aopi=a, acc=p, q=0.0, assign=assign,
                          decision=dec)

    def _rollout(self, tables: HorizonTables) -> RolloutResult:
        return rollout_dos(tables, self.weight,
                           solver_backend=self.solver_backend)


class JCABController(BaselineController):
    """JCAB [3]: maximize accuracy s.t. total latency <= latency_cap, with
    computation allocated proportional to the configuration's xi [48]."""

    def __init__(self, system: EdgeSystem, latency_cap: float = 0.5,
                 n_rounds: int = 3, solver_backend: str = "jnp"):
        super().__init__(system, name="JCAB")
        self.latency_cap = latency_cap
        self.n_rounds = n_rounds
        self.solver_backend = solver_backend

    def step(self, t: int, tables=None) -> SlotRecord:
        sys = self.system
        budgets_b, budgets_c = sys.capacities(t)
        tables = tables if tables is not None else sys.tables(t)
        n, m_count, r_count = tables.acc.shape
        assign = np.asarray([i % len(budgets_b) for i in range(n)], np.int32)

        b = np.zeros(n)
        c = np.zeros(n)
        for s in range(len(budgets_b)):
            mask = assign == s
            b[mask] = budgets_b[s] / max(mask.sum(), 1)
            c[mask] = budgets_c[s] / max(mask.sum(), 1)

        m_idx = np.zeros(n, np.int32)
        r_idx = np.zeros(n, np.int32)
        for _ in range(self.n_rounds):
            # Highest-accuracy config meeting the latency cap.
            lam = b[:, None, None] * tables.eff[:, None, None] / \
                tables.size[None, None, :]
            mu = c[:, None, None] / tables.xi[None, :, :]
            latency = 1.0 / np.maximum(lam, 1e-9) + 1.0 / np.maximum(mu, 1e-9)
            ok = latency <= self.latency_cap
            score = np.where(ok, tables.acc, -np.inf)
            flat = score.reshape(n, -1)
            best = flat.argmax(1)
            none_ok = ~ok.reshape(n, -1).any(1)
            # If nothing meets the cap, take the min-latency config.
            fallback = latency.reshape(n, -1).argmin(1)
            best = np.where(none_ok, fallback, best)
            m_idx = (best // r_count).astype(np.int32)
            r_idx = (best % r_count).astype(np.int32)
            # Re-allocate: bandwidth ~ frame size (equalizes lam), compute
            # ~ xi (per [48]).
            size_n = tables.size[r_idx]
            xi_n = tables.xi[m_idx, r_idx]
            for s in range(len(budgets_b)):
                mask = assign == s
                if not mask.any():
                    continue
                b[mask] = budgets_b[s] * size_n[mask] / size_n[mask].sum()
                c[mask] = budgets_c[s] * xi_n[mask] / xi_n[mask].sum()

        lam = b * tables.eff / tables.size[r_idx]
        mu = c / tables.xi[m_idx, r_idx]
        p = tables.acc[np.arange(n), m_idx, r_idx]
        pol = _thm3_policy(lam, mu, p)
        a = _evaluate(lam, mu, p, pol)
        dec = bcd.SlotDecision(r_idx, m_idx, pol, b, c, lam, mu, p, a,
                               np.float32(a.mean()))
        return SlotRecord(t=t, aopi=a, acc=p, q=0.0, assign=assign,
                          decision=dec)

    def _rollout(self, tables: HorizonTables) -> RolloutResult:
        return rollout_jcab(tables, self.latency_cap,
                            n_rounds=self.n_rounds,
                            solver_backend=self.solver_backend)


def make(name: str, system: EdgeSystem, **kw):
    name = name.upper()
    if name == "MIN":
        return MINController(system, **kw)
    if name == "DOS":
        return DOSController(system, **kw)
    if name == "JCAB":
        return JCABController(system, **kw)
    raise ValueError(f"unknown baseline {name!r}")
