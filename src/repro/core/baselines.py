"""State-of-the-art baselines (paper §VI-A): DOS, JCAB, MIN.

All baselines share LBCD's profiling substrate and (per the paper) the
computation policy and model are chosen via Theorem 3 given their own
resolution / allocation decisions; DOS additionally shares LBCD's server
selection. Evaluation (per-camera AoPI/accuracy) uses the same closed forms,
so comparisons isolate the *decision* quality.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from . import aopi, bcd, binpack
from .lbcd import RunSummary, SlotRecord
from .profiles import EdgeSystem


def _evaluate(lam, mu, p, pol):
    lam = np.maximum(lam, 1e-9)
    mu = np.maximum(mu, 1e-9)
    a = np.where(pol == aopi.LCFSP,
                 np.asarray(aopi.aopi_lcfsp(lam, mu, p)),
                 np.asarray(aopi.aopi_fcfs(lam, mu, p)))
    return a


def _thm3_policy(lam, mu, p):
    return np.asarray(aopi.optimal_policy(lam, mu, p))


@dataclasses.dataclass
class BaselineController:
    system: EdgeSystem
    name: str = "base"

    def run(self, n_slots: int) -> RunSummary:
        records = [self.step(t) for t in range(n_slots)]
        return RunSummary(records, v=0.0, p_min=0.0)


class MINController(BaselineController):
    """Lower bound: one virtual server, no accuracy requirement (q == 0)."""

    def __init__(self, system: EdgeSystem, v: float = 10.0, **kw):
        super().__init__(system, name="MIN")
        self.v = v
        self.kw = kw

    def step(self, t: int, tables=None) -> SlotRecord:
        sys = self.system
        budgets_b, budgets_c = sys.capacities(t)
        tables = tables if tables is not None else sys.tables(t)
        n = tables.n_cameras
        dec = bcd.solve_slot_np(
            tables, np.zeros(n, np.int32), np.array([budgets_b.sum()]),
            np.array([budgets_c.sum()]), 0.0, self.v, n_servers=1, **self.kw)
        return SlotRecord(t=t, aopi=dec.aopi, acc=dec.acc, q=0.0,
                          assign=np.zeros(n, np.int32), decision=dec)


class DOSController(BaselineController):
    """DOS [47]: maximize (accuracy - latency).

    Per camera it picks the (r, m) maximizing ``zeta - (1/lam + 1/mu)`` under
    an equal split, then allocates resources to minimize total expected
    latency (sqrt water-filling — latency-optimal but AoPI-blind, which is
    exactly the behaviour §VI-B2 reports: it collapses to the lightest
    configuration). Server selection is shared with LBCD (first-fit on its
    demands), per §VI-A.
    """

    def __init__(self, system: EdgeSystem, weight: float = 1.0):
        super().__init__(system, name="DOS")
        self.weight = weight

    def step(self, t: int, tables=None) -> SlotRecord:
        sys = self.system
        budgets_b, budgets_c = sys.capacities(t)
        tables = tables if tables is not None else sys.tables(t)
        n, m_count, r_count = tables.acc.shape

        # Equal-split provisional rates.
        b0 = budgets_b.sum() / n
        c0 = budgets_c.sum() / n
        lam0 = b0 * tables.eff[:, None, None] / tables.size[None, None, :]
        mu0 = c0 / tables.xi[None, :, :]
        latency = 1.0 / np.maximum(lam0, 1e-9) + 1.0 / np.maximum(mu0, 1e-9)
        score = tables.acc - self.weight * latency
        flat = score.reshape(n, -1)
        best = flat.argmax(1)
        m_idx = (best // r_count).astype(np.int32)
        r_idx = (best % r_count).astype(np.int32)

        # Latency-minimizing allocation: b ~ sqrt(size/eff), c ~ sqrt(xi).
        size_n = tables.size[r_idx]
        xi_n = tables.xi[m_idx, r_idx]
        w_b = np.sqrt(size_n / tables.eff)
        w_c = np.sqrt(xi_n)
        assign = binpack.first_fit(w_b / w_b.sum() * budgets_b.sum(),
                                   w_c / w_c.sum() * budgets_c.sum(),
                                   budgets_b, budgets_c)
        b = np.zeros(n)
        c = np.zeros(n)
        for s in range(len(budgets_b)):
            mask = assign == s
            if not mask.any():
                continue
            b[mask] = budgets_b[s] * w_b[mask] / w_b[mask].sum()
            c[mask] = budgets_c[s] * w_c[mask] / w_c[mask].sum()

        lam = b * tables.eff / size_n
        mu = c / xi_n
        p = tables.acc[np.arange(n), m_idx, r_idx]
        pol = _thm3_policy(lam, mu, p)
        a = _evaluate(lam, mu, p, pol)
        dec = bcd.SlotDecision(r_idx, m_idx, pol, b, c, lam, mu, p, a,
                               np.float32(a.mean()))
        return SlotRecord(t=t, aopi=a, acc=p, q=0.0, assign=assign,
                          decision=dec)


class JCABController(BaselineController):
    """JCAB [3]: maximize accuracy s.t. total latency <= latency_cap, with
    computation allocated proportional to the configuration's xi [48]."""

    def __init__(self, system: EdgeSystem, latency_cap: float = 0.5,
                 n_rounds: int = 3):
        super().__init__(system, name="JCAB")
        self.latency_cap = latency_cap
        self.n_rounds = n_rounds

    def step(self, t: int, tables=None) -> SlotRecord:
        sys = self.system
        budgets_b, budgets_c = sys.capacities(t)
        tables = tables if tables is not None else sys.tables(t)
        n, m_count, r_count = tables.acc.shape
        assign = np.asarray([i % len(budgets_b) for i in range(n)], np.int32)

        b = np.zeros(n)
        c = np.zeros(n)
        for s in range(len(budgets_b)):
            mask = assign == s
            b[mask] = budgets_b[s] / max(mask.sum(), 1)
            c[mask] = budgets_c[s] / max(mask.sum(), 1)

        m_idx = np.zeros(n, np.int32)
        r_idx = np.zeros(n, np.int32)
        for _ in range(self.n_rounds):
            # Highest-accuracy config meeting the latency cap.
            lam = b[:, None, None] * tables.eff[:, None, None] / \
                tables.size[None, None, :]
            mu = c[:, None, None] / tables.xi[None, :, :]
            latency = 1.0 / np.maximum(lam, 1e-9) + 1.0 / np.maximum(mu, 1e-9)
            ok = latency <= self.latency_cap
            score = np.where(ok, tables.acc, -np.inf)
            flat = score.reshape(n, -1)
            best = flat.argmax(1)
            none_ok = ~ok.reshape(n, -1).any(1)
            # If nothing meets the cap, take the min-latency config.
            fallback = latency.reshape(n, -1).argmin(1)
            best = np.where(none_ok, fallback, best)
            m_idx = (best // r_count).astype(np.int32)
            r_idx = (best % r_count).astype(np.int32)
            # Re-allocate: bandwidth ~ frame size (equalizes lam), compute
            # ~ xi (per [48]).
            size_n = tables.size[r_idx]
            xi_n = tables.xi[m_idx, r_idx]
            for s in range(len(budgets_b)):
                mask = assign == s
                if not mask.any():
                    continue
                b[mask] = budgets_b[s] * size_n[mask] / size_n[mask].sum()
                c[mask] = budgets_c[s] * xi_n[mask] / xi_n[mask].sum()

        lam = b * tables.eff / tables.size[r_idx]
        mu = c / tables.xi[m_idx, r_idx]
        p = tables.acc[np.arange(n), m_idx, r_idx]
        pol = _thm3_policy(lam, mu, p)
        a = _evaluate(lam, mu, p, pol)
        dec = bcd.SlotDecision(r_idx, m_idx, pol, b, c, lam, mu, p, a,
                               np.float32(a.mean()))
        return SlotRecord(t=t, aopi=a, acc=p, q=0.0, assign=assign,
                          decision=dec)


def make(name: str, system: EdgeSystem, **kw):
    name = name.upper()
    if name == "MIN":
        return MINController(system, **kw)
    if name == "DOS":
        return DOSController(system, **kw)
    if name == "JCAB":
        return JCABController(system, **kw)
    raise ValueError(f"unknown baseline {name!r}")
