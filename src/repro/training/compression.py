"""Gradient compression (int8 error-bounded, blockwise-scaled).

Two entry points:

  * ``compress_grads``   — round-trip blockwise int8 quantization applied to
    the grad pytree inside the (GSPMD) train step. It models the numerics of
    an int8 wire format; under GSPMD the data-parallel reduction itself is
    inserted by the compiler, so the bandwidth saving is accounted in the
    roofline's collective term (bytes / 4 vs f32) rather than by a literal
    int8 collective in the HLO.

  * ``compressed_psum``  — the explicit shard_map building block: syncs a
    shared blockwise scale (psum-max), quantizes to int8, accumulates in
    int32, dequantizes. This is the path a NIC/ICI-bound deployment wires
    into an explicit-collective train step; tests/test_training.py checks
    its error bound vs a plain psum.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _blockwise(x, block: int):
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % block
    flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, block), pad


def quantize(x, block: int = 256):
    """x -> (int8 codes, f32 per-block scales, pad)."""
    blocks, pad = _blockwise(x.astype(jnp.float32), block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale, pad


def dequantize(q, scale, pad, shape):
    x = (q.astype(jnp.float32) * scale).reshape(-1)
    if pad:
        x = x[:-pad]
    return x.reshape(shape)


def roundtrip(x, block: int = 256):
    q, s, pad = quantize(x, block)
    return dequantize(q, s, pad, x.shape)


def compress_grads(grads, dp_axes, block: int = 256):
    """Round-trip int8 quantization over the grad pytree."""
    return jax.tree.map(lambda g: roundtrip(g, block), grads)


def compressed_psum(x, axis_name: str, block: int = 256):
    """Explicit compressed all-reduce for shard_map code paths.

    Wire format: one psum-max for the shared scales (f32, 1/block of the
    payload) + one int32-accumulated psum of int8 codes. Returns the mean
    across the axis.
    """
    blocks, pad = _blockwise(x.astype(jnp.float32), block)
    local_max = jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
    gmax = jax.lax.pmax(local_max, axis_name)
    scale = jnp.maximum(gmax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.int32), axis_name)
    mean = total.astype(jnp.float32) * scale / n.astype(jnp.float32)
    out = mean.reshape(-1)
    if pad:
        out = out[:-pad]
    return out.reshape(x.shape)
