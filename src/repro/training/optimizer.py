"""AdamW with dtype-configurable state (pure pytree, no optax).

For >=100B-class models the first/second moments default to bf16 so the
optimizer state fits the per-chip HBM budget (DESIGN.md memory table);
the update math always runs in f32.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"        # bfloat16 for very large models
    warmup_steps: int = 100
    schedule: str = "cosine"            # cosine | constant
    total_steps: int = 10000


def lr_at(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        return cfg.lr * warm
    frac = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def init(params, cfg: AdamWConfig):
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros_like(p, dtype=dt)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(params, grads, state, cfg: AdamWConfig):
    """One AdamW step. Returns (params, state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip else 1.0
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t
    lr = lr_at(cfg, step)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = m32 / bc1
        vhat = v32 / bc2
        step_ = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay:
            step_ = step_ + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * step_
        return (new_p.astype(p.dtype), m32.astype(m.dtype),
                v32.astype(v.dtype))

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t3: t3[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t3: t3[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t3: t3[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"m": new_m, "v": new_v, "step": step}, metrics
