"""The jitted train step: microbatch-accumulated grads + AdamW.

Gradient accumulation runs as a ``lax.scan`` over microbatches (remat
happens inside the model's own per-period checkpointing); the f32 grad
accumulator is sharded exactly like the params, so its HBM cost is
4 bytes / param / chip-shard.

Optional int8 error-feedback gradient compression wraps the DP all-reduce
(repro.training.compression) — off by default, enabled per config for
bandwidth-constrained interconnects.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import optimizer as opt_mod
from .compression import compress_grads


def split_microbatches(batch: dict, n: int):
    """[gb, ...] -> [n, gb/n, ...] for every leaf."""
    def sp(x):
        gb = x.shape[0]
        assert gb % n == 0, (gb, n)
        return x.reshape(n, gb // n, *x.shape[1:])
    return jax.tree.map(sp, batch)


def make_train_step(model, opt_cfg: opt_mod.AdamWConfig,
                    n_microbatches: int = 1, compression: bool = False,
                    dp_axes: Optional[tuple] = None,
                    pre_constrain: Optional[callable] = None):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics). Pure function of its inputs — jit it with shardings at the
    launcher level (launch/train.py, launch/dryrun.py).

    ``pre_constrain``: optional params->params resharding applied ONCE
    before the microbatch scan. With FSDP weights this hoists the
    all-gather out of the gradient-accumulation loop (otherwise GSPMD
    re-gathers every microbatch — a ~n_microbatches x collective-bytes
    waste, EXPERIMENTS.md §Perf cell A); the backward pass reshards
    gradients back with a single reduce-scatter automatically.
    """

    def loss_fn(params, mb):
        return model.loss(params, mb)

    grad_fn = jax.value_and_grad(loss_fn)

    def compute_grads(params, batch):
        if n_microbatches == 1:
            loss, grads = grad_fn(params, batch)
            return loss, jax.tree.map(lambda g: g.astype(jnp.float32),
                                      grads)
        mbs = split_microbatches(batch, n_microbatches)
        acc0 = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def body(carry, mb):
            loss_acc, gacc = carry
            loss, grads = grad_fn(params, mb)
            gacc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), gacc, grads)
            return (loss_acc + loss, gacc), None

        if n_microbatches <= 2:
            # Unrolled (straight-line HLO) — used by the dry-run's
            # accounting probes so per-microbatch collectives are counted.
            carry = (jnp.zeros(()), acc0)
            for i in range(n_microbatches):
                carry, _ = body(carry, jax.tree.map(lambda t: t[i], mbs))
            loss, gacc = carry
        else:
            (loss, gacc), _ = jax.lax.scan(body, (jnp.zeros(()), acc0),
                                           mbs)
        inv = 1.0 / n_microbatches
        return loss * inv, jax.tree.map(lambda g: g * inv, gacc)

    def train_step(params, opt_state, batch):
        gparams = pre_constrain(params) if pre_constrain else params
        loss, grads = compute_grads(gparams, batch)
        if compression and dp_axes:
            grads = compress_grads(grads, dp_axes)
        params, opt_state, metrics = opt_mod.update(params, grads,
                                                    opt_state, opt_cfg)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def make_eval_step(model):
    def eval_step(params, batch):
        return model.loss(params, batch)
    return eval_step
