"""Fault tolerance: straggler detection + island failover.

The paper's edge-server-selection subproblem doubles as the failover
mechanism at pod scale (DESIGN.md §2): an island (model-parallel subgroup)
that dies or degrades is an edge server whose capacity dropped to ~0, and
LBCD's first-fit re-solve migrates its streams on the next controller epoch.

``StragglerMonitor`` implements the step-time EWMA outlier detector used by
the training loop: a chip/host whose step times exceed mean + k*sigma is
flagged; the runbook response is (1) micro-rebalance (shrink its microbatch
share), then (2) treat as failed (checkpoint-restore on the survivor mesh —
repro.training.checkpoint restores across topologies).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class StragglerMonitor:
    """Per-worker EWMA step-time tracker."""
    n_workers: int
    alpha: float = 0.1
    k_sigma: float = 3.0
    warmup: int = 8

    def __post_init__(self):
        self.mean = np.zeros(self.n_workers)
        self.var = np.zeros(self.n_workers)
        self.count = 0

    def observe(self, step_times) -> np.ndarray:
        """Record one step's per-worker times; returns bool straggler mask."""
        t = np.asarray(step_times, np.float64)
        if self.count == 0:
            self.mean[:] = t
        d = t - self.mean
        self.mean += self.alpha * d
        self.var = (1 - self.alpha) * (self.var + self.alpha * d * d)
        self.count += 1
        if self.count < self.warmup:
            return np.zeros(self.n_workers, bool)
        pop_mean = self.mean.mean()
        pop_std = max(np.sqrt(self.var.mean()), 1e-9)
        return self.mean > pop_mean + self.k_sigma * pop_std

    def rebalance_weights(self) -> np.ndarray:
        """Microbatch share proportional to measured speed (1/EWMA)."""
        inv = 1.0 / np.maximum(self.mean, 1e-9)
        return inv / inv.sum()


def fail_islands(budgets_b: np.ndarray, budgets_c: np.ndarray,
                 dead: np.ndarray):
    """Zero the capacities of dead islands (input to the LBCD re-solve)."""
    b = np.asarray(budgets_b, np.float64).copy()
    c = np.asarray(budgets_c, np.float64).copy()
    b[dead] = 0.0
    c[dead] = 0.0
    return b, c


def failover_assignment(controller, t: int, dead: np.ndarray):
    """One controller epoch with dead islands masked out.

    ``controller``: repro.core.lbcd.LBCDController. Streams on dead islands
    are re-placed by the same first-fit machinery (Algorithm 2); returns the
    new SlotRecord.
    """
    sys = controller.system
    orig = sys.capacities

    def masked(tt):
        b, c = orig(tt)
        return fail_islands(b, c, dead)

    sys.capacities = masked
    try:
        rec = controller.step(t)
    finally:
        sys.capacities = orig
    assert not np.asarray(dead)[rec.assign].any(), \
        "failover left a stream on a dead island"
    return rec
