"""Atomic, topology-independent checkpointing with elastic restore.

Layout (one directory per step)::

    <dir>/step_000123.tmp-<nonce>/     # written first
        meta.json                      # tree structure, shapes, dtypes, hash
        leaf_00000.npy ...             # one file per pytree leaf
    <dir>/step_000123/                 # atomic rename when complete

Writes are crash-safe: a partially-written checkpoint never shadows a
complete one (rename is atomic on POSIX); restore verifies content hashes.
Arrays are stored unsharded (gathered), so restore can re-shard onto ANY
mesh topology — ``restore(..., shardings=...)`` device_puts each leaf with
the new NamedSharding (elastic resharding; tests/test_checkpoint.py moves a
checkpoint across mesh shapes).

At real pod scale the same format extends to per-shard chunk files keyed by
(leaf, shard-index) with the identical atomic-rename protocol; the gathered
writer here is the single-host degenerate case.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import uuid
from typing import Any, Optional

import jax
import numpy as np


def _tree_paths(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(path: str, step: int, tree, keep: int = 3) -> str:
    """Atomically write a checkpoint; returns the final directory."""
    os.makedirs(path, exist_ok=True)
    final = os.path.join(path, f"step_{step:09d}")
    tmp = final + f".tmp-{uuid.uuid4().hex[:8]}"
    os.makedirs(tmp)
    leaves, treedef = _tree_paths(tree)
    meta = {"step": step, "treedef": str(treedef), "leaves": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        meta["leaves"].append({
            "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype),
            "sha256": hashlib.sha256(arr.tobytes()).hexdigest(),
        })
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    if os.path.isdir(final):
        # A complete checkpoint for this step already exists (e.g. a
        # restarted run re-reaching the same step): keep it, drop ours.
        shutil.rmtree(tmp, ignore_errors=True)
        _cleanup(path, keep)
        return final
    os.rename(tmp, final)                         # atomic commit
    _cleanup(path, keep)
    return final


def latest_step(path: str) -> Optional[int]:
    if not os.path.isdir(path):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(path)
             if d.startswith("step_") and ".tmp" not in d]
    return max(steps) if steps else None


def restore(path: str, tree_like, step: Optional[int] = None,
            shardings: Any = None, verify: bool = True):
    """Load into the structure of ``tree_like``; optionally reshard.

    ``shardings``: pytree of NamedSharding matching tree_like — each leaf is
    device_put with its (possibly different-topology) sharding.
    """
    step = latest_step(path) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {path}")
    d = os.path.join(path, f"step_{step:09d}")
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)
    leaves_like, treedef = _tree_paths(tree_like)
    assert len(leaves_like) == len(meta["leaves"]), \
        "checkpoint/tree structure mismatch"
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(leaves_like))
    out = []
    for like, info, shard in zip(leaves_like, meta["leaves"], shard_leaves):
        arr = np.load(os.path.join(d, info["file"]))
        if verify:
            h = hashlib.sha256(arr.tobytes()).hexdigest()
            if h != info["sha256"]:
                raise IOError(f"corrupt leaf {info['file']}")
        if shard is not None:
            out.append(jax.device_put(arr, shard))
        else:
            out.append(jax.device_put(arr))
    return jax.tree.unflatten(treedef, out), step


def _cleanup(path: str, keep: int):
    steps = sorted(d for d in os.listdir(path)
                   if d.startswith("step_") and ".tmp" not in d)
    for d in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(path, d), ignore_errors=True)
    # Garbage-collect orphaned tmp dirs from crashed writers.
    for d in os.listdir(path):
        if ".tmp-" in d:
            shutil.rmtree(os.path.join(path, d), ignore_errors=True)
