from . import checkpoint, compression, failure, optimizer, train_step
from .optimizer import AdamWConfig
from .train_step import make_train_step

__all__ = ["checkpoint", "compression", "failure", "optimizer",
           "train_step", "AdamWConfig", "make_train_step"]
