"""Fleet sweep driver: all policies x all scenarios, sharded over devices.

``sweep`` executes the LBCD controller and the MIN/DOS/JCAB baselines over
a stacked scenario axis (a :class:`registry.Suite` or raw stacked
``HorizonTables``) in one device-resident call per policy. Three backends:

  * ``"shard_map"`` (default on >= 2 devices) — the scenario axis is
    padded to a multiple of the device count and partitioned with
    ``shard_map`` over a 1-D ``("scenario",)`` mesh; each device vmaps the
    scan rollout over its local shard. Embarrassingly parallel — no
    collectives. Caveat: XLA compiles a distinct ``num_partitions > 1``
    module whose floating-point rounding can differ from the single-device
    program by ~1 ulp, and the controller's discrete first-fit can amplify
    a knife-edge tie into a visibly different (equally valid) allocation —
    so cross-backend parity is statistical, not bitwise.
  * ``"fleet"`` — the same padded blocks dispatched asynchronously to each
    device through one shared jitted block function (JAX async dispatch
    keeps all devices busy). Every device runs a plain single-partition
    program, so results agree with the vmap fallback to float32 ulp (the
    block batch size differs from the full-K vmap call, so XLA may fuse
    final reductions slightly differently — but no ``num_partitions > 1``
    rewrite is involved and no decision flips have been observed). This
    is the backend the tight parity tests pin.
  * ``"vmap"`` (default on 1 device) — plain ``vmap`` over the scenario
    axis.

Each rollout is reduced on device to per-slot fleet means (AoPI, accuracy,
queue), so the host only ever sees ``[K, T]`` summaries no matter how many
cameras a scenario carries. ``report.robustness`` turns a
:class:`SweepResult` into the per-family worst-case/percentile table.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from .. import obs
from ..core import baselines, lbcd
from ..core.profiles import HorizonTables
from .registry import Suite

POLICIES = ("lbcd", "min", "dos", "jcab")
BACKENDS = ("vmap", "shard_map", "fleet")


def divergence_series(measured: np.ndarray,
                      predicted: np.ndarray) -> np.ndarray:
    """Per-scenario relative divergence of horizon-mean measured vs
    predicted AoPI (``measured/predicted - 1`` over matched epochs) — the
    single definition shared by ``SweepResult`` and
    ``serving.replay.ReplayResult``. [K, T] x [K, T] -> [K]."""
    return (measured.mean(axis=1) /
            np.maximum(predicted.mean(axis=1), 1e-12) - 1.0)


@dataclasses.dataclass
class SweepResult:
    """Per-scenario per-policy slot series (fleet means) + metadata.

    ``aopi``/``acc``/``q`` map policy name -> ``[K, T]`` numpy arrays
    aligned with ``names``/``families``. When the sweep ran with
    ``dataplane=True``, ``measured_aopi`` holds the data-plane
    measurement per epoch (``[K, T_replay]``, possibly fewer slots than
    the closed-form series when the replay was truncated) and
    ``predicted_aopi`` the matching planner prediction — both for the
    *primary* (first) delay model. ``delay_models`` lists every replayed
    delay family; ``measured_by_model``/``predicted_by_model`` map
    model -> policy -> ``[K, T_replay]`` for all of them.
    """
    names: list[str]
    families: list[str]
    policies: list[str]
    v: float
    p_min: float
    backend: str
    aopi: dict[str, np.ndarray]
    acc: dict[str, np.ndarray]
    q: dict[str, np.ndarray]
    measured_aopi: dict[str, np.ndarray] | None = None
    predicted_aopi: dict[str, np.ndarray] | None = None
    delay_models: tuple[str, ...] | None = None
    measured_by_model: dict[str, dict[str, np.ndarray]] | None = None
    predicted_by_model: dict[str, dict[str, np.ndarray]] | None = None
    #: Rung-3 real-engine series for the primary delay model (replay with
    #: ``dataplane_params={"mode": "engine"}``): policy -> [K, T_replay].
    engine_aopi: dict[str, np.ndarray] | None = None
    engine_by_model: dict[str, dict[str, np.ndarray]] | None = None
    #: policy -> repr of the exception that killed its closed-form sweep
    #: (series NaN-filled); merged with the replay's per-cell errors
    #: under ("<scenario>", "<policy>") keys when dataplane=True.
    errors: dict = dataclasses.field(default_factory=dict)
    #: Fault-plane records from the primary-model replay (dataplane=True
    #: with a fault plan): policy -> [K] lists, as on ReplayResult.
    fallbacks: dict | None = None
    degraded: dict | None = None

    def mean_aopi(self, policy: str) -> np.ndarray:
        """Per-scenario mean AoPI over the horizon. [K]"""
        return self.aopi[policy].mean(axis=1)

    def pct_aopi(self, policy: str, pct: float = 95.0) -> np.ndarray:
        """Per-scenario tail (percentile over slots) AoPI. [K]"""
        return np.percentile(self.aopi[policy], pct, axis=1)

    def worst_aopi(self, policy: str) -> np.ndarray:
        """Per-scenario worst slot AoPI. [K]"""
        return self.aopi[policy].max(axis=1)

    def mean_acc(self, policy: str) -> np.ndarray:
        return self.acc[policy].mean(axis=1)

    def divergence(self, policy: str,
                   delay_model: str | None = None) -> np.ndarray:
        """Per-scenario measured/predicted - 1 over the replayed epochs
        (requires ``dataplane=True``). ``delay_model=None`` uses the
        primary model; pass a name from ``delay_models`` for another. [K]
        """
        if self.measured_aopi is None:
            raise ValueError("sweep ran without dataplane=True; no "
                             "measured series to diverge against")
        if delay_model is None:
            return divergence_series(self.measured_aopi[policy],
                                     self.predicted_aopi[policy])
        if (self.measured_by_model is None
                or delay_model not in self.measured_by_model):
            raise ValueError(
                f"delay model {delay_model!r} was not replayed; "
                f"available: {self.delay_models}")
        return divergence_series(self.measured_by_model[delay_model][policy],
                                 self.predicted_by_model[delay_model][policy])


def _reduced_policy(name: str, n_bcd_iters: int, solver_backend: str):
    """One scenario's rollout -> [T] fleet means, with every policy knob a
    traced scalar so one compiled program serves all knob values."""
    def fn(tables: HorizonTables, v, p_min, dos_weight, jcab_cap):
        if name == "lbcd":
            res = lbcd.rollout(tables, v, p_min, n_bcd_iters=n_bcd_iters,
                               solver_backend=solver_backend)
        elif name == "min":
            res = baselines.rollout_min(tables, v,
                                        n_bcd_iters=n_bcd_iters,
                                        solver_backend=solver_backend)
        elif name == "dos":
            res = baselines.rollout_dos(tables, dos_weight,
                                        solver_backend=solver_backend)
        elif name == "jcab":
            res = baselines.rollout_jcab(tables, jcab_cap,
                                         solver_backend=solver_backend)
        else:
            raise ValueError(
                f"unknown policy {name!r}; known: {POLICIES}")
        if tables.active is not None:
            # Churn-masked fleet: dead cameras carry exact zeros, so the
            # fleet mean divides by the live count, not N.
            n_live = jnp.maximum(tables.active.sum(axis=-1), 1.0)
            return {"aopi": res.aopi.sum(axis=-1) / n_live,
                    "acc": res.acc.sum(axis=-1) / n_live,
                    "q": res.q}
        return {"aopi": res.aopi.mean(axis=-1),
                "acc": res.acc.mean(axis=-1),
                "q": res.q}
    return fn


@functools.lru_cache(maxsize=None)
def _vmapped(name: str, n_bcd_iters: int, solver_backend: str):
    """The shared block program: vmap over scenarios, scalars broadcast.
    Cached so repeat sweeps (and the fleet backend's per-device dispatch)
    reuse one compiled executable per (policy, shapes)."""
    return jax.jit(jax.vmap(
        _reduced_policy(name, n_bcd_iters, solver_backend),
        in_axes=(0, None, None, None, None)))


@functools.lru_cache(maxsize=None)
def _sharded(name: str, n_bcd_iters: int, solver_backend: str,
             devices: tuple):
    mesh = Mesh(np.asarray(devices), ("scenario",))
    # check_rep=False: jax has no replication rule for pallas_call; the
    # sweep has no collectives, so the check adds nothing here.
    return jax.jit(shard_map(
        jax.vmap(_reduced_policy(name, n_bcd_iters, solver_backend),
                 in_axes=(0, None, None, None, None)),
        mesh=mesh, in_specs=(P("scenario"), P(), P(), P(), P()),
        out_specs=P("scenario"), check_rep=False))


def _pad_scenarios(tables: HorizonTables, pad: int) -> HorizonTables:
    """Repeat the last scenario ``pad`` times so K divides the mesh."""
    if pad == 0:
        return tables
    return jax.tree.map(
        lambda x: jnp.concatenate(
            [x, jnp.repeat(x[-1:], pad, axis=0)], axis=0), tables)


def _run_shard_map(name, n_bcd_iters, solver_backend, tables, knobs,
                   n_scenarios, devices) -> dict:
    pad = (-n_scenarios) % len(devices)
    fn = _sharded(name, n_bcd_iters, solver_backend, tuple(devices))
    out = fn(_pad_scenarios(tables, pad), *knobs)
    return {k: np.asarray(x)[:n_scenarios] for k, x in out.items()}


def _run_fleet(name, n_bcd_iters, solver_backend, tables, knobs,
               n_scenarios, devices) -> dict:
    """The vmap block program, one async dispatch per device."""
    n_dev = len(devices)
    pad = (-n_scenarios) % n_dev
    padded = _pad_scenarios(tables, pad)
    block_len = (n_scenarios + pad) // n_dev
    block_fn = _vmapped(name, n_bcd_iters, solver_backend)
    futures = []
    for i, dev in enumerate(devices):
        block = jax.tree.map(
            lambda x: jax.device_put(
                x[i * block_len:(i + 1) * block_len], dev), padded)
        futures.append(block_fn(block, *knobs))  # async — all devices busy
    keys = futures[0].keys()
    return {k: np.concatenate([np.asarray(f[k]) for f in futures],
                              axis=0)[:n_scenarios] for k in keys}


def _run_vmap(name, n_bcd_iters, solver_backend, tables, knobs) -> dict:
    out = _vmapped(name, n_bcd_iters, solver_backend)(tables, *knobs)
    return {k: np.asarray(x) for k, x in out.items()}


def sweep(suite_or_tables: Suite | HorizonTables, v: float = 10.0,
          p_min: float = 0.7, policies: Sequence[str] = POLICIES,
          devices: Sequence | None = None, backend: str | None = None,
          policy_params: Mapping | None = None,
          solver_backend: str = "jnp", dataplane: bool = False,
          dataplane_params: Mapping | None = None) -> SweepResult:
    """Run every policy over every stacked scenario; one sharded (or
    vmapped) device-resident call per policy.

    ``backend=None`` picks ``"shard_map"`` on >= 2 devices and ``"vmap"``
    on one; pass ``"fleet"`` for the bitwise-reproducible multi-device
    path (see module docstring). ``solver_backend`` selects the
    Algorithm-1 implementation inside LBCD/MIN and the config-scan engine
    inside DOS/JCAB ("jnp" | "pallas" | "auto" plus tiling/fusion knobs
    like ``"pallas:tile=4096"`` — see ``bcd.parse_backend``).

    ``dataplane=True`` additionally replays every (policy, scenario) pair
    through the batched GI/G/1 data plane
    (``repro.serving.replay_suite``) and attaches *measured* per-epoch
    AoPI (plus the matching planner predictions) to the result —
    ``report.robustness`` then emits the two-column predicted-vs-measured
    table with a divergence column per replayed delay model.
    ``dataplane_params`` forwards replay knobs (``n_epochs``,
    ``epoch_duration``, ``frames_cap``, ``seed``, ``telemetry_gain``,
    ``plan_window``, ``replan_threshold``, ``faults`` — a
    ``repro.faults.FaultPlan`` applied to every cell, with
    ``plan_retries``/``plan_deadline`` tuning the degradation ladder —
    and ``delay_model`` — a name
    from ``queues.DELAY_MODELS`` or a tuple of them; the first is the
    primary model backing ``measured_aopi``/``divergence()``, the rest
    land in ``measured_by_model`` — see ``serving.replay.replay_tables``).
    ``mode="engine"`` climbs to the truth ladder's third rung: every cell
    also replays through the engine rung, and the rung-3 series land in
    ``engine_aopi``/``engine_by_model`` (``engine_params={"backend":
    "des"|"scan"|"auto", "frames_cap": ...}`` picks the rung's plane —
    the event-by-event Engine replay or the batched tick-scan at
    full-suite budgets — and bounds work per epoch; ``true_delay_model``
    picks the plane's generating family when ``delay_model="auto"`` runs
    the fitted selector).
    Each extra delay model is a full extra replay, planner included
    (telemetry feedback couples planning to the plane, and at
    ``telemetry_gain > 0`` the per-model plans genuinely differ);
    compiled planner executables are reused across models, so the
    repeated cost is execution, not compilation.
    """
    if isinstance(suite_or_tables, Suite):
        tables = suite_or_tables.tables
        names = list(suite_or_tables.names)
        fams = list(suite_or_tables.families)
    else:
        tables = suite_or_tables
        if tables.acc.ndim != 5:
            raise ValueError(
                f"sweep() needs a *stacked* scenario axis (acc of rank 5, "
                f"[K, T, N, M, R]); got acc{tuple(tables.acc.shape)}. "
                f"Stack horizons with profiles.stack_horizons or pass a "
                f"scenarios.suite(...)")
        k = int(tables.acc.shape[0])
        names = [f"scenario_{i}" for i in range(k)]
        fams = ["unknown"] * k
    n_scenarios = int(tables.acc.shape[0])
    devices = list(devices) if devices is not None else jax.devices()
    # Never spread K scenarios over more than K devices — a mesh larger
    # than the batch axis just pads (and a num_partitions >> K module,
    # e.g. under --xla_force_host_platform_device_count=512, takes
    # pathologically long to compile for zero parallelism gain).
    devices = devices[:max(n_scenarios, 1)]
    if backend is None:
        backend = "shard_map" if len(devices) > 1 else "vmap"
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; known: {BACKENDS}")
    params = dict(policy_params or {})
    n_bcd_iters = int(params.get("n_bcd_iters", 4))
    knobs = (jnp.float32(v), jnp.float32(p_min),
             jnp.float32(params.get("dos_weight", 1.0)),
             jnp.float32(params.get("jcab_latency_cap", 0.5)))

    series = {}
    errors: dict = {}
    n_slots = int(tables.acc.shape[1])
    for name in policies:
        if name not in POLICIES:
            raise ValueError(f"unknown policy {name!r}; known: {POLICIES}")
        sb = solver_backend
        # One span per policy: it wraps the full sharded/vmapped dispatch
        # INCLUDING host materialization (the _run_* helpers np.asarray
        # their outputs), so the duration is honest end-to-end sweep time.
        try:
            with obs.span("sweep.policy", policy=name, backend=backend,
                          solver_backend=str(solver_backend),
                          n_scenarios=n_scenarios, n_devices=len(devices)):
                if backend == "shard_map" and len(devices) > 1:
                    series[name] = _run_shard_map(name, n_bcd_iters, sb,
                                                  tables, knobs,
                                                  n_scenarios, devices)
                elif backend == "fleet" and len(devices) > 1:
                    series[name] = _run_fleet(name, n_bcd_iters, sb, tables,
                                              knobs, n_scenarios, devices)
                else:
                    series[name] = _run_vmap(name, n_bcd_iters, sb, tables,
                                             knobs)
        except Exception as e:  # noqa: BLE001 — isolate the policy cell
            # One failing policy must not abort the whole sweep: record
            # the failure, NaN-fill its series, and keep sweeping.
            errors[name] = f"{type(e).__name__}: {e}"
            obs.event("sweep.policy_failed", policy=name, backend=backend)
            nan = np.full((n_scenarios, n_slots), np.nan)
            series[name] = {"aopi": nan, "acc": nan.copy(),
                            "q": np.full((n_scenarios, n_slots), np.nan)}
            continue
        if obs.enabled():
            # Per-(policy, family) AoPI histograms: the [T] fleet-mean
            # slot series of every scenario, so exporters can quote
            # p50/p95/p99 closed-form AoPI next to the timing series.
            for ki, fam in enumerate(fams):
                obs.histogram("sweep.aopi", policy=name, family=fam
                              ).observe_many(series[name]["aopi"][ki])

    measured = predicted = None
    delay_models = None
    measured_by_model = predicted_by_model = None
    engine_aopi = engine_by_model = None
    fallbacks = degraded = None
    if dataplane:
        # Lazy import: repro.serving pulls the model/engine stack, and
        # importing it here (not at module load) also keeps the
        # scenarios <-> serving dependency one-directional per call.
        from ..serving import replay as _replay
        dp = dict(dataplane_params or {})
        known = {"n_epochs", "epoch_duration", "frames_cap", "seed",
                 "plan_window", "telemetry_gain", "delay_model",
                 "true_delay_model", "mode", "engine_params",
                 "replan_threshold", "faults", "plan_retries",
                 "plan_deadline"}
        unknown = sorted(set(dp) - known)
        if unknown:
            raise ValueError(f"unknown dataplane_params {unknown}; "
                             f"known: {sorted(known)}")
        models = dp.get("delay_model", "mm1")
        if isinstance(models, str):
            models = (models,)
        delay_models = tuple(models)
        mode = str(dp.get("mode", "mm1"))
        measured_by_model, predicted_by_model = {}, {}
        engine_by_model = {}
        for dm in delay_models:
            rres = _replay.replay_suite(
                suite_or_tables, policies=list(policies), v=v, p_min=p_min,
                policy_params=policy_params, solver_backend=solver_backend,
                n_epochs=dp.get("n_epochs"),
                epoch_duration=float(dp.get("epoch_duration", 300.0)),
                frames_cap=int(dp.get("frames_cap", 200_000)),
                seed=int(dp.get("seed", 0)),
                plan_window=dp.get("plan_window"),
                telemetry_gain=float(dp.get("telemetry_gain", 0.0)),
                delay_model=dm,
                true_delay_model=dp.get("true_delay_model"),
                mode=mode, engine_params=dp.get("engine_params"),
                replan_threshold=dp.get("replan_threshold"),
                faults=dp.get("faults"),
                plan_retries=int(dp.get("plan_retries", 2)),
                plan_deadline=dp.get("plan_deadline"))
            measured_by_model[dm] = rres.measured
            predicted_by_model[dm] = rres.predicted
            if rres.engine:
                engine_by_model[dm] = rres.engine
            if dm == delay_models[0]:
                fallbacks, degraded = rres.fallbacks, rres.degraded
                errors.update(rres.errors)
        measured = measured_by_model[delay_models[0]]
        predicted = predicted_by_model[delay_models[0]]
        engine_aopi = engine_by_model.get(delay_models[0])

    tag = backend if len(devices) > 1 or backend == "vmap" else "vmap"
    backend_str = (f"{tag}[{len(devices)}]" if tag != "vmap" else "vmap")
    return SweepResult(
        names=names, families=fams, policies=list(policies),
        v=v, p_min=p_min, backend=backend_str,
        aopi={p: s["aopi"] for p, s in series.items()},
        acc={p: s["acc"] for p, s in series.items()},
        q={p: s["q"] for p, s in series.items()},
        measured_aopi=measured, predicted_aopi=predicted,
        delay_models=delay_models, measured_by_model=measured_by_model,
        predicted_by_model=predicted_by_model,
        engine_aopi=engine_aopi, engine_by_model=engine_by_model or None,
        errors=errors, fallbacks=fallbacks, degraded=degraded)
