"""Robustness reporting: per-policy, per-family tail behaviour.

The headline claims of the paper are means over one trace; what a
deployment cares about is how each policy degrades under each *kind* of
dynamics. :func:`robustness` folds a :class:`runner.SweepResult` into a
per-(policy, family) table of mean / tail-percentile / worst-case AoPI
(aggregated over the family's scenarios and slots), plus the policy's
worst family — the number a capacity planner would provision against.

When the sweep ran with ``dataplane=True`` the table grows a second
column set: the *measured* AoPI from the M/M/1 data-plane replay
(``repro.serving.replay``) with the same mean/percentile/worst
aggregation, and the relative divergence ``measured/predicted - 1`` —
the model-vs-measurement gap where config-adaptation policies break.
With ``dataplane_params={"mode": "engine"}`` a third column set appears:
the real continuous-batching engine's AoPI (the truth ladder's third
rung) with per-rung divergences against both the GI/G/1 plane
(``div:gi``) and the closed forms (``div:cf``).

:func:`degradation` is the fault-plane counterpart: it replays a suite
clean and once per fault kind (``repro.faults``) and tabulates, per
(policy, fault kind), measured AoPI under faults vs fault-free, the
recovery time in epochs after the fault window clears, and the fallback /
degraded-epoch counts from the service's graceful-degradation ladder.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from .. import faults as fault_plane
from .runner import POLICIES, SweepResult


@dataclasses.dataclass
class FamilyStats:
    mean_aopi: float          # mean over the family's scenarios x slots
    pct_aopi: float           # tail percentile of slot-mean AoPI
    worst_aopi: float         # worst slot across the family
    mean_acc: float
    # Data-plane (measured) columns — None unless dataplane=True replayed
    # the sweep. ``mean_predicted`` is the planner prediction over the
    # *replayed* epochs (the replay may cover fewer slots than the
    # closed-form sweep), so divergence compares like with like.
    measured_mean: Optional[float] = None
    measured_pct: Optional[float] = None
    measured_worst: Optional[float] = None
    mean_predicted: Optional[float] = None
    # model name -> family-mean divergence, one entry per replayed delay
    # family (the primary model's entry equals ``divergence``).
    divergence_models: Optional[dict] = None
    # Rung-3 (real continuous-batching engine) columns — None unless the
    # replay ran with ``mode="engine"``. In that mode the ``measured_*``
    # block is the rung-2 GI/G/1 plane at the same truth rates, so the
    # three rungs of the truth ladder sit side by side per family.
    engine_mean: Optional[float] = None
    engine_pct: Optional[float] = None
    engine_worst: Optional[float] = None

    @property
    def divergence(self) -> Optional[float]:
        """Relative measured-vs-predicted gap of the family mean
        (``measured/predicted - 1``); None without a data-plane replay."""
        if self.measured_mean is None:
            return None
        return self.measured_mean / max(self.mean_predicted, 1e-12) - 1.0

    @property
    def engine_vs_gi(self) -> Optional[float]:
        """Rung 3 vs rung 2: ``engine/measured - 1`` (real engine against
        the GI/G/1 plane); None without an engine replay."""
        if self.engine_mean is None or self.measured_mean is None:
            return None
        return self.engine_mean / max(self.measured_mean, 1e-12) - 1.0

    @property
    def engine_vs_predicted(self) -> Optional[float]:
        """Rung 3 vs rung 1: ``engine/predicted - 1`` (real engine against
        the closed-form AoPI); None without an engine replay."""
        if self.engine_mean is None or self.mean_predicted is None:
            return None
        return self.engine_mean / max(self.mean_predicted, 1e-12) - 1.0


@dataclasses.dataclass
class RobustnessReport:
    policies: list[str]
    families: list[str]
    pct: float
    table: dict            # policy -> family -> FamilyStats
    # Slot coverage: the closed-form columns always span ``total_slots``;
    # the measured block spans the first ``replay_slots`` of them (a
    # truncated replay is flagged in ``__str__`` — compare truncated
    # measured columns only through ``divergence``, which is computed
    # against the predictions of the *same* epochs).
    total_slots: int = 0
    replay_slots: int = 0
    # Replayed delay families (first = primary, backing the ``diverge``
    # column); extra models add one ``div:<model>`` column each.
    delay_models: tuple = ()

    @property
    def has_measured(self) -> bool:
        return any(s.measured_mean is not None
                   for row in self.table.values() for s in row.values())

    @property
    def has_engine(self) -> bool:
        """True when the replay climbed to the truth ladder's third rung
        (``dataplane_params={"mode": "engine"}``)."""
        return any(s.engine_mean is not None
                   for row in self.table.values() for s in row.values())

    def worst_family(self, policy: str) -> tuple[str, FamilyStats]:
        fam = max(self.families,
                  key=lambda f: self.table[policy][f].worst_aopi)
        return fam, self.table[policy][fam]

    def worst_divergence(self, policy: str) -> tuple[str, float]:
        """The family where the data plane diverges most from the model
        (largest absolute relative gap). Requires a dataplane sweep."""
        if not self.has_measured:
            raise ValueError("report has no measured columns; run "
                             "sweep(..., dataplane=True)")
        fam = max(self.families,
                  key=lambda f: abs(self.table[policy][f].divergence))
        return fam, self.table[policy][fam].divergence

    @property
    def _extra_models(self) -> tuple:
        """Replayed delay families beyond the primary one."""
        return tuple(self.delay_models[1:]) if self.delay_models else ()

    def rows(self) -> list[list]:
        """Flat rows (benchmarks): [policy, family, mean, pXX, worst, acc]
        plus [measured_mean, measured_pXX, measured_worst, divergence]
        when the sweep was replayed through the data plane, plus one
        divergence per extra replayed delay model, plus
        [engine_mean, engine_pXX, engine_worst, engine_vs_gi,
        engine_vs_predicted] when the replay ran ``mode="engine"``."""
        out = []
        for p in self.policies:
            for f in self.families:
                s = self.table[p][f]
                row = [p, f, s.mean_aopi, s.pct_aopi, s.worst_aopi,
                       s.mean_acc]
                if self.has_measured:
                    row += [s.measured_mean, s.measured_pct,
                            s.measured_worst, s.divergence]
                    row += [s.divergence_models[dm]
                            for dm in self._extra_models]
                if self.has_engine:
                    row += [s.engine_mean, s.engine_pct, s.engine_worst,
                            s.engine_vs_gi, s.engine_vs_predicted]
                out.append(row)
        return out

    def __str__(self) -> str:
        w = max(len(f) for f in self.families)
        head = (f"{'policy':<6} {'family':<{w}} {'mean':>9} "
                f"{f'p{self.pct:.0f}':>9} {'worst':>9} {'acc':>6}")
        measured = self.has_measured
        engine = self.has_engine
        extra = self._extra_models
        lines = []
        if measured:
            head += (f" | {'measured':>9} {f'p{self.pct:.0f}':>9} "
                     f"{'worst':>9} {'diverge':>8}")
            for dm in extra:
                head += f" {'div:' + dm:>12}"
            if len(self.delay_models) > 1 or (
                    self.delay_models and self.delay_models[0] != "mm1"):
                lines.append("# data plane delay model(s): "
                             + ", ".join(self.delay_models)
                             + " (measured block = "
                             + self.delay_models[0] + ")")
            if 0 < self.replay_slots < self.total_slots:
                lines.append(
                    f"# measured block covers the first {self.replay_slots}"
                    f"/{self.total_slots} slots; 'diverge' compares those "
                    f"same slots' predictions")
        if engine:
            head += (f" | {'engine':>9} {f'p{self.pct:.0f}':>9} "
                     f"{'worst':>9} {'div:gi':>8} {'div:cf':>8}")
            lines.append("# truth ladder: closed-form (rung 1) | GI/G/1 "
                         "measured (rung 2) | real engine (rung 3); "
                         "div:gi = engine vs GI/G/1, div:cf = engine vs "
                         "closed form")
        lines.append(head)
        for p in self.policies:
            for f in self.families:
                s = self.table[p][f]
                line = (f"{p:<6} {f:<{w}} {s.mean_aopi:>9.4f} "
                        f"{s.pct_aopi:>9.4f} {s.worst_aopi:>9.4f} "
                        f"{s.mean_acc:>6.3f}")
                if measured:
                    line += (f" | {s.measured_mean:>9.4f} "
                             f"{s.measured_pct:>9.4f} "
                             f"{s.measured_worst:>9.4f} "
                             f"{s.divergence:>+8.2%}")
                    for dm in extra:
                        line += f" {s.divergence_models[dm]:>+12.2%}"
                if engine:
                    line += (f" | {s.engine_mean:>9.4f} "
                             f"{s.engine_pct:>9.4f} "
                             f"{s.engine_worst:>9.4f} "
                             f"{s.engine_vs_gi:>+8.2%} "
                             f"{s.engine_vs_predicted:>+8.2%}")
                lines.append(line)
        return "\n".join(lines)


def robustness(result: SweepResult, pct: float = 95.0) -> RobustnessReport:
    """Aggregate a sweep into per-(policy, family) AoPI robustness stats.

    Predicted (closed-form) columns always; measured columns when the
    sweep carries a data-plane replay (``dataplane=True``)."""
    fams = sorted(set(result.families))
    measured_aopi = getattr(result, "measured_aopi", None)
    predicted_aopi = getattr(result, "predicted_aopi", None)
    delay_models = getattr(result, "delay_models", None) or ()
    measured_by_model = getattr(result, "measured_by_model", None) or {}
    predicted_by_model = getattr(result, "predicted_by_model", None) or {}
    engine_aopi = getattr(result, "engine_aopi", None)
    total_slots = next(iter(result.aopi.values())).shape[1]
    replay_slots = (next(iter(measured_aopi.values())).shape[1]
                    if measured_aopi else 0)
    table = {}
    for policy in result.policies:
        aopi = result.aopi[policy]                       # [K, T]
        acc = result.acc[policy]
        table[policy] = {}
        for fam in fams:
            idx = [i for i, f in enumerate(result.families) if f == fam]
            a = aopi[idx]
            stats = FamilyStats(
                mean_aopi=float(a.mean()),
                pct_aopi=float(np.percentile(a, pct)),
                worst_aopi=float(a.max()),
                mean_acc=float(acc[idx].mean()))
            if measured_aopi is not None:
                m = measured_aopi[policy][idx]
                pr = (predicted_aopi[policy][idx]
                      if predicted_aopi is not None else a)
                stats.measured_mean = float(m.mean())
                stats.measured_pct = float(np.percentile(m, pct))
                stats.measured_worst = float(m.max())
                stats.mean_predicted = float(pr.mean())
                stats.divergence_models = {
                    dm: float(measured_by_model[dm][policy][idx].mean() /
                              max(predicted_by_model[dm][policy][idx]
                                  .mean(), 1e-12) - 1.0)
                    for dm in delay_models}
            if engine_aopi is not None and policy in engine_aopi:
                e = engine_aopi[policy][idx]
                stats.engine_mean = float(np.nanmean(e))
                stats.engine_pct = float(np.nanpercentile(e, pct))
                stats.engine_worst = float(np.nanmax(e))
            table[policy][fam] = stats
    return RobustnessReport(policies=list(result.policies), families=fams,
                            pct=pct, table=table, total_slots=total_slots,
                            replay_slots=replay_slots,
                            delay_models=tuple(delay_models))


# ---------------------------------------------------------------------------
# Degraded-mode report (fault plane)
# ---------------------------------------------------------------------------

#: Fault kinds :func:`degradation` replays by default — one structural,
#: one capacity, one correlated, one telemetry, one solver kind.
DEFAULT_FAULT_KINDS = ("camera_churn", "server_crash", "correlated_fade",
                       "telemetry_drop", "solver_nonconverge")


@dataclasses.dataclass
class DegradedStats:
    """One (policy, fault kind) cell of the degradation table."""
    clean_aopi: float         # fault-free measured mean AoPI
    faulted_aopi: float       # measured mean AoPI under the injection
    recovery_epochs: float    # mean epochs to re-converge after clearing
    fallbacks: int            # ladder engagements across the suite
    degraded_epochs: int      # epochs run on a fallback plan
    errors: int = 0           # cells that failed outright

    @property
    def ratio(self) -> float:
        """Faulted / clean measured AoPI (1.0 = no degradation)."""
        return self.faulted_aopi / max(self.clean_aopi, 1e-12)


@dataclasses.dataclass
class DegradationReport:
    policies: list[str]
    fault_kinds: list[str]
    table: dict               # policy -> kind -> DegradedStats
    fault_window: tuple[int, int]
    tolerance: float

    def rows(self) -> list[list]:
        """Flat rows (benchmarks/CI): [policy, kind, clean, faulted,
        ratio, recovery_epochs, fallbacks, degraded_epochs, errors]."""
        out = []
        for p in self.policies:
            for k in self.fault_kinds:
                s = self.table[p][k]
                out.append([p, k, s.clean_aopi, s.faulted_aopi, s.ratio,
                            s.recovery_epochs, s.fallbacks,
                            s.degraded_epochs, s.errors])
        return out

    def __str__(self) -> str:
        w = max(len(k) for k in self.fault_kinds)
        lines = [f"# fault window: slots [{self.fault_window[0]}, "
                 f"{self.fault_window[1]}); recovery tolerance "
                 f"{self.tolerance:.0%}",
                 f"{'policy':<6} {'fault':<{w}} {'clean':>9} "
                 f"{'faulted':>9} {'ratio':>7} {'recov':>6} "
                 f"{'fallbk':>6} {'degr':>5}"]
        for p in self.policies:
            for k in self.fault_kinds:
                s = self.table[p][k]
                lines.append(
                    f"{p:<6} {k:<{w}} {s.clean_aopi:>9.4f} "
                    f"{s.faulted_aopi:>9.4f} {s.ratio:>7.3f} "
                    f"{s.recovery_epochs:>6.1f} {s.fallbacks:>6d} "
                    f"{s.degraded_epochs:>5d}")
        return "\n".join(lines)


def _plan_for_kind(kind: str, t0: int, length: int,
                   seed: int) -> fault_plane.FaultPlan:
    """One-kind plan with parameters strong enough that the injection is
    visible (solver kinds exhaust the retry budget so the ladder's
    fallback rungs — not just retries — engage)."""
    params: dict = {}
    if kind == "camera_churn":
        params = {"fraction": 0.4, "leave_prob": 0.1, "join_prob": 0.3}
    elif kind == "server_crash":
        params = {"server": 0, "depth": 1.0}
    elif kind == "correlated_fade":
        params = {"fraction": 1.0, "depth": 0.7, "corr": 0.9}
    elif kind in fault_plane.SOLVER_KINDS:
        params = {"attempts": 64}
    return fault_plane.FaultPlan(
        (fault_plane.FaultSpec(kind, t0=t0, duration=length,
                               params=params),), seed=seed)


def degradation(suite_or_tables,
                fault_kinds: Sequence[str] = DEFAULT_FAULT_KINDS,
                policies: Sequence[str] = POLICIES, *,
                n_epochs: int | None = None, fault_t0: int | None = None,
                fault_len: int | None = None, seed: int = 0,
                tolerance: float = 0.10,
                **replay_kw) -> DegradationReport:
    """Measured AoPI under faults vs fault-free, per (policy, fault kind).

    Replays the suite once clean and once per fault kind (same seeds, so
    the clean run is the exact counterfactual), injecting that kind over
    slots ``[fault_t0, fault_t0 + fault_len)`` (defaults: the middle
    third). Recovery time is the number of epochs after the window clears
    until the faulted measured series re-enters ``tolerance`` of the
    clean series (per scenario, then averaged; the remaining horizon
    counts in full when a scenario never recovers). Plans that fail
    planning exercise the service ladder, so fallback / degraded-epoch
    counts come straight from ``ReplayResult``. Extra ``replay_kw``
    (``plan_window``, ``telemetry_gain``, ...) forward to
    ``replay_suite``.
    """
    from ..serving import replay as _replay  # lazy: keep deps one-way
    for kind in fault_kinds:
        if kind not in fault_plane.FAULT_KINDS:
            raise ValueError(f"unknown fault kind {kind!r}; known: "
                             f"{fault_plane.FAULT_KINDS}")
    clean = _replay.replay_suite(suite_or_tables, policies=list(policies),
                                 n_epochs=n_epochs, seed=seed, **replay_kw)
    t_len = next(iter(clean.measured.values())).shape[1]
    t0 = max(1, t_len // 3) if fault_t0 is None else int(fault_t0)
    length = max(1, t_len // 3) if fault_len is None else int(fault_len)
    t1 = min(t0 + length, t_len)
    table: dict = {p: {} for p in policies}
    for kind in fault_kinds:
        # Solver faults only bite at planning epochs; by default start
        # their window at slot 0 so the guaranteed first plan (and every
        # replan before ``t1``) falls inside it regardless of how the
        # plan-window boundaries align with the middle third.
        k_t0 = (0 if fault_t0 is None and kind in fault_plane.SOLVER_KINDS
                else t0)
        plan = _plan_for_kind(kind, k_t0, t1 - k_t0, seed)
        faulted = _replay.replay_suite(
            suite_or_tables, policies=list(policies), n_epochs=n_epochs,
            seed=seed, faults=plan, **replay_kw)
        for p in policies:
            c = clean.measured[p]                         # [K, T]
            f = faulted.measured[p]
            rec = []
            for k in range(c.shape[0]):
                tail = np.abs(f[k, t1:] - c[k, t1:]) <= \
                    tolerance * np.maximum(c[k, t1:], 1e-12)
                hit = np.flatnonzero(tail)
                rec.append(float(hit[0]) if hit.size else float(t_len - t1))
            n_fb = sum(len(x) for x in faulted.fallbacks.get(p, []))
            n_dg = sum(len(x) for x in faulted.degraded.get(p, []))
            n_err = sum(1 for (_, pol) in faulted.errors if pol == p)
            table[p][kind] = DegradedStats(
                clean_aopi=float(np.nanmean(c)),
                faulted_aopi=float(np.nanmean(f)),
                recovery_epochs=float(np.mean(rec)) if rec else 0.0,
                fallbacks=int(n_fb), degraded_epochs=int(n_dg),
                errors=int(n_err))
    return DegradationReport(policies=list(policies),
                             fault_kinds=list(fault_kinds), table=table,
                             fault_window=(t0, t1), tolerance=tolerance)
