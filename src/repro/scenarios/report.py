"""Robustness reporting: per-policy, per-family tail behaviour.

The headline claims of the paper are means over one trace; what a
deployment cares about is how each policy degrades under each *kind* of
dynamics. :func:`robustness` folds a :class:`runner.SweepResult` into a
per-(policy, family) table of mean / tail-percentile / worst-case AoPI
(aggregated over the family's scenarios and slots), plus the policy's
worst family — the number a capacity planner would provision against.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .runner import SweepResult


@dataclasses.dataclass
class FamilyStats:
    mean_aopi: float          # mean over the family's scenarios x slots
    pct_aopi: float           # tail percentile of slot-mean AoPI
    worst_aopi: float         # worst slot across the family
    mean_acc: float


@dataclasses.dataclass
class RobustnessReport:
    policies: list[str]
    families: list[str]
    pct: float
    table: dict            # policy -> family -> FamilyStats

    def worst_family(self, policy: str) -> tuple[str, FamilyStats]:
        fam = max(self.families,
                  key=lambda f: self.table[policy][f].worst_aopi)
        return fam, self.table[policy][fam]

    def rows(self) -> list[list]:
        """Flat [policy, family, mean, pXX, worst, acc] rows (benchmarks)."""
        return [[p, f, s.mean_aopi, s.pct_aopi, s.worst_aopi, s.mean_acc]
                for p in self.policies
                for f, s in ((f, self.table[p][f]) for f in self.families)]

    def __str__(self) -> str:
        w = max(len(f) for f in self.families)
        lines = [f"{'policy':<6} {'family':<{w}} {'mean':>9} "
                 f"{f'p{self.pct:.0f}':>9} {'worst':>9} {'acc':>6}"]
        for p in self.policies:
            for f in self.families:
                s = self.table[p][f]
                lines.append(f"{p:<6} {f:<{w}} {s.mean_aopi:>9.4f} "
                             f"{s.pct_aopi:>9.4f} {s.worst_aopi:>9.4f} "
                             f"{s.mean_acc:>6.3f}")
        return "\n".join(lines)


def robustness(result: SweepResult, pct: float = 95.0) -> RobustnessReport:
    """Aggregate a sweep into per-(policy, family) AoPI robustness stats."""
    fams = sorted(set(result.families))
    table = {}
    for policy in result.policies:
        aopi = result.aopi[policy]                       # [K, T]
        acc = result.acc[policy]
        table[policy] = {}
        for fam in fams:
            idx = [i for i, f in enumerate(result.families) if f == fam]
            a = aopi[idx]
            table[policy][fam] = FamilyStats(
                mean_aopi=float(a.mean()),
                pct_aopi=float(np.percentile(a, pct)),
                worst_aopi=float(a.max()),
                mean_acc=float(acc[idx].mean()))
    return RobustnessReport(policies=list(result.policies), families=fams,
                            pct=pct, table=table)
