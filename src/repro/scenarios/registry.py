"""Scenario registry: named generators -> built horizons -> stacked suites.

  register(name, family=..., **defaults)   decorator used by generators.py
  names() / families()                     what is registered
  spec_for(name, overrides)                the resolved ScenarioSpec
  build(name, overrides)                   one ``HorizonTables``
  suite(names=None, ...)                   a :class:`Suite` — all (or the
                                           named) scenarios built with
                                           shared dimensions and stacked
                                           via ``profiles.stack_horizons``
                                           for vmapped/sharded sweeps.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Mapping, Sequence

from ..core import profiles
from ..core.profiles import HorizonTables
from .base import Components, ScenarioSpec, assemble

_REGISTRY: dict[str, tuple[Callable[[ScenarioSpec], Components],
                           str, dict]] = {}


def register(name: str, family: str | None = None, **defaults):
    """Register ``fn(spec) -> Components`` under ``name``; stackable to
    register one generator under several names with different defaults."""
    def deco(fn):
        if name in _REGISTRY:
            raise ValueError(f"scenario {name!r} already registered")
        _REGISTRY[name] = (fn, family or name, dict(defaults))
        return fn
    return deco


def _ensure_loaded() -> None:
    if not _REGISTRY:                     # pragma: no cover - import order
        from . import generators          # noqa: F401  (registers on import)


def names() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


def families() -> list[str]:
    _ensure_loaded()
    return sorted({fam for _, fam, _ in _REGISTRY.values()})


def family_of(name: str) -> str:
    _ensure_loaded()
    return _REGISTRY[name][1]


def spec_for(name: str, overrides: Mapping | None = None,
             **kw) -> ScenarioSpec:
    """The fully-resolved spec ``build(name, ...)`` would use."""
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown scenario {name!r}; registered: {names()}")
    _, family, defaults = _REGISTRY[name]
    spec = ScenarioSpec(name=name, family=family, params=dict(defaults))
    return spec.with_overrides(overrides, **kw)


def build(name: str, overrides: Mapping | None = None,
          **kw) -> HorizonTables:
    """Build one scenario's ``HorizonTables``.

    ``overrides``/keyword args may set any ``ScenarioSpec`` field
    (``n_cameras``, ``n_slots``, ``seed``, ...); unknown keys become
    generator params (e.g. ``flash_depth``). Deterministic: the same
    ``(name, overrides)`` rebuilds bitwise-identical tables.
    """
    spec = spec_for(name, overrides, **kw)
    fn = _REGISTRY[name][0]
    return assemble(spec, fn(spec))


@dataclasses.dataclass
class Suite:
    """A stacked scenario suite: ``tables`` has a leading scenario axis K
    aligned with ``names``/``families``."""
    tables: HorizonTables
    names: list[str]
    families: list[str]
    specs: list[ScenarioSpec]

    @property
    def n_scenarios(self) -> int:
        return len(self.names)


def suite(scenario_names: Sequence[str] | None = None,
          overrides: Mapping | None = None, **kw) -> Suite:
    """Build every (or the named) registered scenario with shared
    dimensions and stack them for one vmapped/sharded sweep."""
    scenario_names = list(scenario_names or names())
    specs = [spec_for(n, overrides, **kw) for n in scenario_names]
    tables = [build(n, overrides, **kw) for n in scenario_names]
    return Suite(tables=profiles.stack_horizons(tables),
                 names=scenario_names,
                 families=[s.family for s in specs],
                 specs=specs)
