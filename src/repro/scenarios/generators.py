"""The scenario families: adversarial/diverse dynamics for the suite.

Each generator is a pure function ``ScenarioSpec -> Components`` registered
under a family name. All start from the steady AR(1) world
(``base.default_components`` — the seed ``EdgeSystem`` scenario) and
perturb one axis, so sweeps isolate which *kind* of dynamics breaks a
policy:

  steady_ar1       the seed world — lognormal AR(1) capacity, mild drift;
  gilbert_elliott  Markov-modulated (good/bad) bandwidth channels, the
                   classic bursty-wireless model;
  diurnal_flash    diurnal sinusoid capacity + flash-crowd depressions
                   (background load spikes steal backhaul and compute);
  server_outage    per-server hard-degradation windows (failures/maintenance);
  snr_mobility     per-camera random-walk SNR with handover jumps
                   (time-varying link efficiency);
  content_burst    content-difficulty bursts (scene changes crush accuracy,
                   then recover);
  camera_churn     fleet churn — cameras leave/join mid-horizon via the
                   ``active[T, N]`` mask (``repro.faults`` Markov chain);
  correlated_fade  correlated multi-server bandwidth fades (one shared
                   shock + idiosyncratic noise), generalizing
                   server_outage beyond independent single-server windows.

Knobs ride ``spec.params`` with the defaults below; ``registry.build``
merges per-call overrides in.
"""
from __future__ import annotations

import numpy as np

from ..faults import FaultPlan, FaultSpec
from .base import (Components, ScenarioSpec, base_drift, base_snr,
                   default_capacity, default_components, rng)
from .registry import register


@register("steady_ar1", family="steady")
def steady_ar1(spec: ScenarioSpec) -> Components:
    """The seed EdgeSystem world, unperturbed (calibration anchor)."""
    return default_components(spec)


def _gilbert_elliott_states(spec: ScenarioSpec, p_gb: float,
                            p_bg: float) -> np.ndarray:
    """Two-state Markov chain per server: 1 = good, 0 = bad. [T, S]."""
    u = rng(spec, "ge_chain").uniform(size=(spec.n_slots, spec.n_servers))
    state = np.ones(spec.n_servers, bool)
    out = np.empty((spec.n_slots, spec.n_servers), bool)
    for t in range(spec.n_slots):
        flip = np.where(state, u[t] < p_gb, u[t] < p_bg)
        state = state ^ flip
        out[t] = state
    return out


@register("gilbert_elliott", family="gilbert_elliott")
@register("gilbert_elliott_harsh", family="gilbert_elliott",
          p_gb=0.15, p_bg=0.12, bad_gain=0.15)
def gilbert_elliott(spec: ScenarioSpec) -> Components:
    """Markov-modulated bandwidth: each server's backhaul flips between a
    good state (~``good_gain`` x mean) and a deep-fade bad state
    (~``bad_gain`` x mean), with small AR(1) jitter on top."""
    p_gb = spec.param("p_gb", 0.08)          # good -> bad per slot
    p_bg = spec.param("p_bg", 0.25)          # bad -> good per slot
    good = spec.param("good_gain", 1.15)
    bad = spec.param("bad_gain", 0.30)
    states = _gilbert_elliott_states(spec, p_gb, p_bg)
    gain = np.where(states, good, bad)
    jitter = default_capacity(spec, 1.0, "ge_jitter", rho=0.6, sigma=0.08)
    return Components(
        bandwidth=spec.mean_bandwidth_hz * gain * jitter,
        compute=default_capacity(spec, spec.mean_compute_flops, "comp"),
        snr_db=base_snr(spec),
        drift=base_drift(spec))


@register("diurnal_flash", family="diurnal_flash")
def diurnal_flash(spec: ScenarioSpec) -> Components:
    """Diurnal sinusoid on both capacities + flash-crowd windows where
    background demand steals a ``flash_depth`` fraction of capacity, with
    linear recovery over ``flash_len`` slots."""
    period = spec.param("period", 96)
    amp = spec.param("amp", 0.35)
    n_flash = spec.param("n_flash", 3)
    depth = spec.param("flash_depth", 0.55)
    length = spec.param("flash_len", 8)
    comps = default_components(spec)
    r = rng(spec, "flash")
    phase = r.uniform(0.0, 2 * np.pi, spec.n_servers)
    t = np.arange(spec.n_slots)[:, None]
    diurnal = 1.0 + amp * np.sin(2 * np.pi * t / period + phase[None, :])
    env = np.ones(spec.n_slots)
    for t0 in r.integers(0, max(spec.n_slots - length, 1), n_flash):
        dip = 1.0 - depth * (1.0 - np.arange(length) / length)
        env[t0:t0 + length] = np.minimum(env[t0:t0 + length],
                                         dip[:spec.n_slots - t0])
    shape = diurnal * env[:, None]
    comps.bandwidth = comps.bandwidth * shape
    comps.compute = comps.compute * shape
    return comps


@register("server_outage", family="server_outage")
def server_outage(spec: ScenarioSpec) -> Components:
    """Per-server outage/degradation windows: a random server keeps only a
    ``degrade`` fraction of both capacities for ``outage_len`` slots
    (floored at 1e-6 x mean so allocators never see a zero budget)."""
    n_outages = spec.param("n_outages", 2)
    length = spec.param("outage_len", 12)
    degrade = spec.param("degrade", 0.05)
    comps = default_components(spec)
    r = rng(spec, "outage")
    factor = np.ones((spec.n_slots, spec.n_servers))
    for _ in range(n_outages):
        s = int(r.integers(0, spec.n_servers))
        t0 = int(r.integers(0, max(spec.n_slots - length, 1)))
        factor[t0:t0 + length, s] = degrade
    comps.bandwidth = np.maximum(comps.bandwidth * factor,
                                 spec.mean_bandwidth_hz * 1e-6)
    comps.compute = np.maximum(comps.compute * factor,
                               spec.mean_compute_flops * 1e-6)
    return comps


@register("snr_mobility", family="snr_mobility")
def snr_mobility(spec: ScenarioSpec) -> Components:
    """Camera mobility: per-camera SNR random walk (``walk_sigma`` dB/slot)
    with Bernoulli handover jumps of +-``handover_jump`` dB, clipped to
    [``snr_lo``, ``snr_hi``] — a time-varying ``eff[t, n]``."""
    walk = spec.param("walk_sigma", 0.4)
    rate = spec.param("handover_rate", 0.02)
    jump = spec.param("handover_jump", 6.0)
    lo = spec.param("snr_lo", 5.0)
    hi = spec.param("snr_hi", 25.0)
    r = rng(spec, "mobility")
    steps = r.normal(0.0, walk, (spec.n_slots, spec.n_cameras))
    jumps = (r.uniform(size=(spec.n_slots, spec.n_cameras)) < rate)
    signs = np.where(r.uniform(size=jumps.shape) < 0.5, -1.0, 1.0)
    snr = np.empty((spec.n_slots, spec.n_cameras))
    # same "snr0" stream as base_snr, so the walk starts from the static
    # draw the other families use
    state = rng(spec, "snr0").uniform(12.0, 22.0, spec.n_cameras)
    for t in range(spec.n_slots):
        state = np.clip(state + steps[t] + jump * jumps[t] * signs[t],
                        lo, hi)
        snr[t] = state
    return Components(
        bandwidth=default_capacity(spec, spec.mean_bandwidth_hz, "bw"),
        compute=default_capacity(spec, spec.mean_compute_flops, "comp"),
        snr_db=snr,
        drift=base_drift(spec))


@register("content_burst", family="content_burst")
def content_burst(spec: ScenarioSpec) -> Components:
    """Content-difficulty bursts: scene changes drop the per-camera drift
    multiplier by ``burst_depth`` and recover linearly over ``burst_len``
    slots, on top of the mild baseline drift."""
    n_bursts = spec.param("n_bursts",
                          max(3, spec.n_slots * spec.n_cameras // 400))
    depth = spec.param("burst_depth", 0.45)
    length = spec.param("burst_len", 12)
    comps = default_components(spec)
    r = rng(spec, "burst")
    env = np.ones((spec.n_slots, spec.n_cameras))
    t0s = r.integers(0, max(spec.n_slots - 1, 1), n_bursts)
    cams = r.integers(0, spec.n_cameras, n_bursts)
    ramp = 1.0 - depth * (1.0 - np.arange(length) / length)
    for t0, cam in zip(t0s, cams):
        seg = min(length, spec.n_slots - t0)
        env[t0:t0 + seg, cam] = np.minimum(env[t0:t0 + seg, cam],
                                           ramp[:seg])
    comps.drift = np.clip(comps.drift * env, 0.05, 1.0)
    return comps


@register("camera_churn", family="camera_churn")
@register("camera_churn_heavy", family="camera_churn",
          churn_fraction=0.6, leave_prob=0.15, join_prob=0.15)
def camera_churn(spec: ScenarioSpec) -> Components:
    """Fleet churn: cameras leave and rejoin mid-horizon.

    The steady AR(1) world plus an ``active[T, N]`` mask from the
    ``repro.faults`` churn chain — at ``churn_t0`` a ``churn_fraction`` of
    the fleet drops out, then per slot live cameras leave w.p.
    ``leave_prob`` and dead ones rejoin w.p. ``join_prob`` (at least one
    camera is always live). Inactive cameras get exactly zero allocation;
    their bandwidth/compute shares water-fill to the survivors.
    """
    comps = default_components(spec)
    plan = FaultPlan(
        (FaultSpec(
            "camera_churn",
            t0=int(spec.param("churn_t0", max(1, spec.n_slots // 10))),
            duration=spec.param("churn_len", None),
            params={"fraction": spec.param("churn_fraction", 0.3),
                    "leave_prob": spec.param("leave_prob", 0.05),
                    "join_prob": spec.param("join_prob", 0.1)}),),
        seed=int(rng(spec, "churn").integers(2**31)))
    comps.active = plan.camera_active(spec.n_slots, spec.n_cameras)
    return comps


@register("correlated_fade", family="correlated_fade")
@register("correlated_fade_deep", family="correlated_fade",
          fade_depth=0.85, fade_corr=0.95)
def correlated_fade(spec: ScenarioSpec) -> Components:
    """Correlated multi-server bandwidth fades (generalizing
    ``server_outage``): a shared Gaussian shock plus per-server noise,
    mixed by ``fade_corr`` and squashed into ``(1 - fade_depth, 1)``,
    multiplies the backhaul of a ``fade_fraction`` of servers at once —
    the weather-front / backhaul-congestion regime where per-server
    independence assumptions fail. Floored at 1e-6 x mean like
    ``server_outage`` so allocators never see a zero budget.
    """
    comps = default_components(spec)
    plan = FaultPlan(
        (FaultSpec(
            "correlated_fade",
            t0=int(spec.param("fade_t0", 0)),
            duration=spec.param("fade_len", None),
            params={"fraction": spec.param("fade_fraction", 1.0),
                    "depth": spec.param("fade_depth", 0.6),
                    "corr": spec.param("fade_corr", 0.8)}),),
        seed=int(rng(spec, "fade").integers(2**31)))
    factor = plan.capacity_factor(spec.n_slots, spec.n_servers)
    comps.bandwidth = np.maximum(comps.bandwidth * factor,
                                 spec.mean_bandwidth_hz * 1e-6)
    return comps
