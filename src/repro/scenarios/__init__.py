"""repro.scenarios — workload diversity at fleet scale.

A library of composable, adversarial scenario generators (Markov-modulated
channels, diurnal + flash-crowd load, server outages, camera mobility,
content bursts), each emitting the same ``profiles.HorizonTables`` pytree
the scan rollout engine consumes, plus a sweep runner that executes
LBCD/MIN/DOS/JCAB over the stacked scenario axis — ``shard_map``-partitioned
across every available device, vmapped on one.

Quickstart::

    from repro import scenarios
    s = scenarios.suite(n_cameras=16, n_slots=60, n_servers=3)
    result = scenarios.sweep(s, v=10.0, p_min=0.7)
    print(scenarios.robustness(result))
"""
from . import generators  # noqa: F401  (populates the registry on import)
from .base import Components, ScenarioSpec, assemble
from .registry import (Suite, build, families, family_of, names, register,
                       spec_for, suite)
from .report import (DegradationReport, DegradedStats, FamilyStats,
                     RobustnessReport, degradation, robustness)
from .runner import BACKENDS, POLICIES, SweepResult, sweep

__all__ = [
    "Components", "ScenarioSpec", "assemble",
    "Suite", "build", "families", "family_of", "names", "register",
    "spec_for", "suite",
    "DegradationReport", "DegradedStats", "FamilyStats",
    "RobustnessReport", "degradation", "robustness",
    "BACKENDS", "POLICIES", "SweepResult", "sweep",
]
