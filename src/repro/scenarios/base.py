"""Scenario assembly: pure trace components -> ``HorizonTables``.

A scenario is a :class:`ScenarioSpec` (dimensions + seed + free-form
``params``) plus a *generator* — a pure function ``spec -> Components``
that produces the four time-varying ingredients of a horizon:

  bandwidth[T, S]   per-server bandwidth capacity trace (Hz)
  compute[T, S]     per-server compute capacity trace (FLOPS)
  snr_db[T, N]      per-camera uplink SNR path (dB)
  drift[T, N]       per-camera content-difficulty multiplier in (0, 1]

:func:`assemble` folds these with the model pool's accuracy/FLOPs profiles
into the same ``profiles.HorizonTables`` pytree the PR-1 scan engine
consumes (``lbcd.rollout``, ``baselines.rollout_*``), with a time-varying
``eff[T, N]`` so SNR-mobility scenarios ride the unchanged rollouts.

Determinism: every random draw comes from ``rng(spec, tag)`` — a
``numpy`` Generator keyed by ``(spec.seed, crc32(spec.name), crc32(tag))``
— so the same registry name + seed rebuilds bitwise-identical tables, and
distinct components (bandwidth vs drift vs SNR) never share a stream.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Mapping, Sequence

import jax.numpy as jnp
import numpy as np

from ..core import profiles
from ..core.profiles import HorizonTables


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """Dimensions + seed + per-family knobs for one scenario instance."""
    name: str
    family: str
    n_cameras: int = 30
    n_servers: int = 3
    n_slots: int = 200
    mean_bandwidth_hz: float = 30e6
    mean_compute_flops: float = 50e12
    seed: int = 0
    pool: str = "paper"                  # "paper" | "lm"
    resolutions: Sequence[int] = profiles.RESOLUTIONS
    alpha: float = profiles.ALPHA_BITS_PER_PIXEL
    params: Mapping = dataclasses.field(default_factory=dict)

    def param(self, key: str, default):
        return self.params.get(key, default)

    def with_overrides(self, overrides: Mapping | None = None,
                       **kw) -> "ScenarioSpec":
        """New spec with field overrides; unknown keys land in ``params``."""
        merged = dict(overrides or {}, **kw)
        fields = {f.name for f in dataclasses.fields(ScenarioSpec)}
        field_kw = {k: v for k, v in merged.items()
                    if k in fields and k != "params"}
        params = dict(self.params)
        params.update({k: v for k, v in merged.items() if k not in fields})
        params.update(merged.get("params", {}))
        return dataclasses.replace(self, params=params, **field_kw)


@dataclasses.dataclass
class Components:
    """The four time-varying ingredients a generator emits (plus the
    optional fleet-churn mask of the ``camera_churn`` family)."""
    bandwidth: np.ndarray        # [T, S] Hz
    compute: np.ndarray          # [T, S] FLOPS
    snr_db: np.ndarray           # [T, N] dB
    drift: np.ndarray            # [T, N] in (0, 1]
    #: Optional [T, N] fleet mask (1 live / 0 churned out). ``None`` — the
    #: default for every non-churn family — assembles tables WITHOUT an
    #: ``active`` leaf, keeping existing scenarios bitwise unchanged.
    active: np.ndarray | None = None


def rng(spec: ScenarioSpec, tag: str) -> np.random.Generator:
    """Independent, reproducible stream per (scenario, component)."""
    return np.random.default_rng(
        [spec.seed, zlib.crc32(spec.name.encode()),
         zlib.crc32(tag.encode())])


# ---------------------------------------------------------------------------
# Shared building blocks (the EdgeSystem defaults, in pure form)
# ---------------------------------------------------------------------------

def default_capacity(spec: ScenarioSpec, mean: float, tag: str,
                     rho: float = 0.85, sigma: float = 0.25) -> np.ndarray:
    """The seed scenario family's lognormal AR(1) capacity trace [T, S]."""
    return profiles.lognormal_ar1_trace(
        rng(spec, tag), mean, (spec.n_slots, spec.n_servers),
        rho=rho, sigma=sigma)


def base_snr(spec: ScenarioSpec) -> np.ndarray:
    """Static per-camera SNR draw (12..22 dB), tiled to [T, N]."""
    snr0 = rng(spec, "snr0").uniform(12.0, 22.0, spec.n_cameras)
    return np.broadcast_to(snr0, (spec.n_slots, spec.n_cameras)).copy()


def base_drift(spec: ScenarioSpec) -> np.ndarray:
    """Mild clipped-AR(1) content drift [T, N] (the EdgeSystem default)."""
    return profiles.drift_path(
        int(rng(spec, "drift").integers(0, 2**31)),
        spec.n_slots, spec.n_cameras)


def default_components(spec: ScenarioSpec) -> Components:
    """The steady AR(1) world every family perturbs along one axis."""
    return Components(
        bandwidth=default_capacity(spec, spec.mean_bandwidth_hz, "bw"),
        compute=default_capacity(spec, spec.mean_compute_flops, "comp"),
        snr_db=base_snr(spec),
        drift=base_drift(spec))


def pool_for(spec: ScenarioSpec) -> list[profiles.ModelCandidate]:
    if spec.pool == "paper":
        return profiles.paper_pool()
    if spec.pool == "lm":
        return profiles.lm_pool()
    raise ValueError(f"unknown pool {spec.pool!r} (expected 'paper'|'lm')")


def assemble(spec: ScenarioSpec, comps: Components,
             dtype=jnp.float32) -> HorizonTables:
    """Fold components + model-pool profiles into one ``HorizonTables``.

    Mirrors ``EdgeSystem.horizon`` (per-camera difficulty baseline x drift
    x pool accuracy ladder), but with a time-varying ``eff[T, N]`` from the
    SNR path so mobility scenarios work with the unchanged scan engines.
    """
    t_len, n = comps.snr_db.shape
    if comps.drift.shape != (t_len, n):
        raise ValueError(f"drift shape {comps.drift.shape} != snr shape "
                         f"{comps.snr_db.shape}")
    if comps.bandwidth.shape != (t_len, spec.n_servers):
        raise ValueError(f"bandwidth shape {comps.bandwidth.shape} != "
                         f"(T={t_len}, S={spec.n_servers})")
    if comps.compute.shape != (t_len, spec.n_servers):
        raise ValueError(f"compute shape {comps.compute.shape} != "
                         f"(T={t_len}, S={spec.n_servers})")
    if comps.active is not None and comps.active.shape != (t_len, n):
        raise ValueError(f"active shape {comps.active.shape} != "
                         f"(T={t_len}, N={n})")
    pool = pool_for(spec)
    res = np.asarray(spec.resolutions, np.float64)
    difficulty = rng(spec, "difficulty").uniform(0.88, 1.0, n)
    zr = np.stack([m.zeta(res) for m in pool])              # [M, R]
    xi = np.stack([m.xi(res) for m in pool])                # [M, R]
    acc = (difficulty[None, :] * comps.drift)[:, :, None, None] * \
        zr[None, None, :, :]                                # [T, N, M, R]
    return HorizonTables(
        acc=jnp.asarray(np.clip(acc, 1e-3, 1.0), dtype),
        xi=jnp.asarray(xi, dtype),
        size=jnp.asarray(spec.alpha * res**2, dtype),
        eff=jnp.asarray(profiles.shannon_efficiency(comps.snr_db), dtype),
        budgets_b=jnp.asarray(comps.bandwidth, dtype),
        budgets_c=jnp.asarray(comps.compute, dtype),
        active=(None if comps.active is None
                else jnp.asarray(comps.active, dtype)))
