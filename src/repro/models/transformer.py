"""Model assemblies: decoder-only / MoE / VLM / hybrid / xLSTM / enc-dec.

Every architecture is a *period* of layer specs repeated n_periods times
(jamba: [attn, mamba x7] x 9; llama-vision: [self x3, cross, self] x 8;
dense: [attn] x L, ...). Parameters for one period are stacked along a
leading LAYERS dim and the stack is driven by ``lax.scan`` — this keeps the
lowered HLO O(period) instead of O(L) (dry-run compile time) and is the
production remat unit.

Caches mirror the same stacking, so prefill/decode scan over
(params, cache) together.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import attention as attn_mod
from . import mla as mla_mod
from . import moe as moe_mod
from . import ssm as ssm_mod
from . import xlstm as xlstm_mod
from .common import (BATCH, EMBED, LAYERS, P, stack_template, tree_map)
from .layers import (embed, embedding_template, gelu_mlp, gelu_mlp_template,
                     layernorm, layernorm_template, rmsnorm,
                     rmsnorm_template, softmax_xent, swiglu, swiglu_template,
                     unembed, unembed_template)


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: str                  # attn | mla | cross | mamba | mlstm | slstm
    ffn: str                    # dense | moe | none
    cross_sub: bool = False     # extra cross-attn sublayer (enc-dec decoder)


def layout(cfg: ModelConfig, role: str = "decoder"):
    """Return (period: list[LayerSpec], n_periods) for an arch config."""
    if role == "encoder":
        assert cfg.enc_layers
        return [LayerSpec("attn", "dense")], cfg.enc_layers
    if cfg.enc_layers:                                     # enc-dec decoder
        return [LayerSpec("attn", "dense", cross_sub=True)], cfg.n_layers

    if cfg.family == "hybrid":                             # jamba
        period = []
        for i in range(cfg.attn_period):
            mixer = "attn" if i == 0 else "mamba"
            ffn = "moe" if (i % cfg.moe_period == 1 or cfg.moe_period == 1) \
                else "dense"
            period.append(LayerSpec(mixer, ffn))
        assert cfg.n_layers % cfg.attn_period == 0
        return period, cfg.n_layers // cfg.attn_period

    if cfg.family == "ssm":                                # xlstm
        sp = cfg.slstm_period
        period = [LayerSpec("mlstm", "none") for _ in range(sp - 1)]
        period.append(LayerSpec("slstm", "none"))
        assert cfg.n_layers % sp == 0
        return period, cfg.n_layers // sp

    if cfg.family == "vlm":                                # llama-vision
        cp = cfg.cross_attn_period
        period = [LayerSpec("attn", "dense") for _ in range(cp)]
        period[cp - 2] = LayerSpec("cross", "dense")
        assert cfg.n_layers % cp == 0
        return period, cfg.n_layers // cp

    mixer = "mla" if cfg.attn_type == "mla" else "attn"
    ffn = "moe" if (cfg.is_moe and cfg.moe_period == 1) else "dense"
    if cfg.is_moe and cfg.moe_period > 1:
        period = []
        for i in range(cfg.moe_period):
            period.append(LayerSpec(
                mixer, "moe" if i % cfg.moe_period == 1 else "dense"))
        return period, cfg.n_layers // cfg.moe_period
    return [LayerSpec(mixer, ffn)], cfg.n_layers


# ---------------------------------------------------------------------------
# Single block
# ---------------------------------------------------------------------------

def _norm_template(cfg):
    return (layernorm_template if cfg.norm == "layernorm"
            else rmsnorm_template)(cfg.d_model)


def _norm(cfg, params, x):
    return (layernorm if cfg.norm == "layernorm" else rmsnorm)(params, x)


def block_template(cfg: ModelConfig, spec: LayerSpec,
                   n_experts_padded: Optional[int] = None):
    t = {"norm1": _norm_template(cfg)}
    if spec.mixer in ("attn", "cross"):
        t["mixer"] = attn_mod.gqa_template(cfg)
    elif spec.mixer == "mla":
        t["mixer"] = mla_mod.mla_template(cfg)
    elif spec.mixer == "mamba":
        t["mixer"] = ssm_mod.mamba_template(cfg)
    elif spec.mixer == "mlstm":
        t["mixer"] = xlstm_mod.mlstm_template(cfg)
    elif spec.mixer == "slstm":
        t["mixer"] = xlstm_mod.slstm_template(cfg)
    else:
        raise ValueError(spec.mixer)
    if spec.cross_sub:
        t["norm_x"] = _norm_template(cfg)
        t["cross"] = attn_mod.gqa_template(cfg)
    if spec.ffn != "none":
        t["norm2"] = _norm_template(cfg)
        if spec.ffn == "moe":
            t["ffn"] = moe_mod.moe_template(cfg, n_experts_padded)
        elif cfg.family == "audio":
            t["ffn"] = gelu_mlp_template(cfg.d_model, cfg.d_ff)
        else:
            t["ffn"] = swiglu_template(cfg.d_model, cfg.d_ff)
    return t


def block_cache_template(cfg, spec: LayerSpec, batch: int, max_len: int,
                         kv_source_len: int, dtype=None):
    """Per-layer decode cache matching block_template's spec."""
    c = {}
    if spec.mixer == "attn":
        c["self"] = attn_mod.cache_template(cfg, batch, max_len, dtype)
    elif spec.mixer == "mla":
        c["self"] = mla_mod.mla_cache_template(cfg, batch, max_len, dtype)
    elif spec.mixer == "cross":
        c["enc"] = attn_mod.cache_template(cfg, batch, kv_source_len, dtype)
    elif spec.mixer == "mamba":
        c["state"] = ssm_mod.mamba_state_template(cfg, batch, dtype)
    elif spec.mixer == "mlstm":
        c["state"] = xlstm_mod.mlstm_state_template(cfg, batch, dtype)
    elif spec.mixer == "slstm":
        c["state"] = xlstm_mod.slstm_state_template(cfg, batch, dtype)
    if spec.cross_sub:
        c["enc"] = attn_mod.cache_template(cfg, batch, kv_source_len, dtype)
    return c


def block_apply(params, x, cfg, spec: LayerSpec, *, positions=None,
                causal=True, kv_embeds=None, impl="ref", ssm_impl="chunked",
                mlstm_impl="ref", cache=None):
    """Full-sequence block (train / prefill when cache given).

    Returns (x, new_cache, aux_loss).
    """
    aux = jnp.zeros((), jnp.float32)
    new_cache = dict(cache) if cache is not None else None
    h = _norm(cfg, params["norm1"], x)

    if spec.mixer == "attn":
        sub = None if cache is None else cache["self"]
        out = attn_mod.gqa_apply(params["mixer"], h, cfg,
                                 positions=positions, causal=causal,
                                 impl=impl, cache=sub)
        if sub is not None:
            out, new_cache["self"] = out
    elif spec.mixer == "mla":
        sub = None if cache is None else cache["self"]
        out = mla_mod.mla_apply(params["mixer"], h, cfg,
                                positions=positions, causal=causal,
                                cache=sub, impl=impl)
        if sub is not None:
            out, new_cache["self"] = out
    elif spec.mixer == "cross":
        out = attn_mod.gqa_apply(params["mixer"], h, cfg, kv_x=kv_embeds,
                                 impl=impl)
        if cache is not None:
            k, v = attn_mod.encode_kv(params["mixer"], cfg, kv_embeds)
            new_cache["enc"] = {"k": k.astype(cache["enc"]["k"].dtype),
                                "v": v.astype(cache["enc"]["v"].dtype)}
    elif spec.mixer == "mamba":
        st = None if cache is None else cache["state"]
        out = ssm_mod.mamba_apply(params["mixer"], h, cfg, state=st,
                                  impl=ssm_impl)
        if st is not None:
            out, new_cache["state"] = out
    elif spec.mixer == "mlstm":
        out = xlstm_mod.mlstm_apply(params["mixer"], h, cfg,
                                    impl=mlstm_impl)
        if cache is not None:
            # Recompute final state recurrently is wasteful; derive it by
            # replaying the last token through the step fn after prefill is
            # handled at the engine level. Here we run the parallel form and
            # rebuild the state with a short scan over the sequence.
            new_cache["state"] = _mlstm_state_from_seq(
                params["mixer"], h, cfg, cache["state"])
    elif spec.mixer == "slstm":
        st = None if cache is None else cache["state"]
        out = xlstm_mod.slstm_apply(params["mixer"], h, cfg, state=st)
        if st is not None:
            out, new_cache["state"] = out
    else:
        raise ValueError(spec.mixer)
    x = x + out

    if spec.cross_sub:
        h = _norm(cfg, params["norm_x"], x)
        out = attn_mod.gqa_apply(params["cross"], h, cfg, kv_x=kv_embeds,
                                 impl=impl)
        x = x + out
        if cache is not None:
            k, v = attn_mod.encode_kv(params["cross"], cfg, kv_embeds)
            new_cache["enc"] = {"k": k.astype(cache["enc"]["k"].dtype),
                                "v": v.astype(cache["enc"]["v"].dtype)}

    if spec.ffn != "none":
        h = _norm(cfg, params["norm2"], x)
        if spec.ffn == "moe":
            out, aux = moe_mod.moe_apply(params["ffn"], h, cfg)
        elif cfg.family == "audio":
            out = gelu_mlp(params["ffn"], h)
        else:
            out = swiglu(params["ffn"], h)
        x = x + out
    # Sequence-parallel residual (opt-in via rules override
    # {"act_seq": "model"}): converts the TP activation all-reduces into
    # reduce-scatter + all-gather pairs around each block (Korthikanti-
    # style SP) — EXPERIMENTS.md §Perf cell A iteration 5.
    from ..sharding import ctx as _ctx
    x = _ctx.constrain(x, ("batch", "act_seq", None))
    return x, new_cache, aux


def _mlstm_state_from_seq(params, h_seq, cfg, state):
    """Rebuild mLSTM carry states after a parallel-form prefill by scanning
    the gate/kv projections (cheap: no d x d matmuls per step beyond the
    outer products)."""
    q, k, v, ig, fg = xlstm_mod._mlstm_qkvif(
        params, jnp.split(jnp.einsum("bsd,di->bsi", h_seq,
                                     params["up_proj"]), 2, axis=-1)[0])

    def step(carry, t):
        C, n, m = carry
        _, (C, n, m) = xlstm_mod.mlstm_step(
            q[:, t], k[:, t], v[:, t], ig[:, t], fg[:, t], C, n, m)
        return (C, n, m), None

    init = (state["C"], state["n"],
            jnp.full_like(state["m"], -1e30))
    (C, n, m), _ = jax.lax.scan(step, init, jnp.arange(h_seq.shape[1]))
    return {"C": C, "n": n, "m": m}


def block_decode(params, x, cfg, spec: LayerSpec, cache, lens, *,
                 impl="ref"):
    """Single-token decode through one block. x: [b, 1, d]."""
    new_cache = dict(cache)
    h = _norm(cfg, params["norm1"], x)
    if spec.mixer == "attn":
        out, new_cache["self"] = attn_mod.gqa_decode(
            params["mixer"], h, cfg, cache["self"], lens, impl=impl)
    elif spec.mixer == "mla":
        out, new_cache["self"] = mla_mod.mla_decode(
            params["mixer"], h, cfg, cache["self"], lens, impl=impl)
    elif spec.mixer == "cross":
        out = attn_mod.cross_decode(params["mixer"], h, cfg,
                                    cache["enc"]["k"], cache["enc"]["v"],
                                    impl=impl)
    elif spec.mixer == "mamba":
        out, new_cache["state"] = ssm_mod.mamba_decode(
            params["mixer"], h, cfg, cache["state"])
    elif spec.mixer == "mlstm":
        out, new_cache["state"] = xlstm_mod.mlstm_decode(
            params["mixer"], h, cfg, cache["state"])
    elif spec.mixer == "slstm":
        xg = jnp.einsum("bsd,dghe->bsghe", h, params["mixer"]["w_x"])[:, 0]
        h_out, new_cache["state"] = xlstm_mod._slstm_cell(
            params["mixer"], xg, cache["state"])
        b = x.shape[0]
        y = h_out.reshape(b, 1, cfg.d_model).astype(x.dtype)
        y = jnp.einsum("bsd,df->bsf", y, params["mixer"]["ffn_up"])
        y = jax.nn.gelu(y.astype(jnp.float32)).astype(x.dtype)
        out = jnp.einsum("bsf,fd->bsd", y, params["mixer"]["ffn_down"])
    else:
        raise ValueError(spec.mixer)
    x = x + out

    if spec.cross_sub:
        h = _norm(cfg, params["norm_x"], x)
        out = attn_mod.cross_decode(params["cross"], h, cfg,
                                    cache["enc"]["k"], cache["enc"]["v"],
                                    impl=impl)
        x = x + out

    if spec.ffn != "none":
        h = _norm(cfg, params["norm2"], x)
        if spec.ffn == "moe":
            # Dropless capacity at decode (capacity == tokens): the decode
            # batch is small, and inference must not drop tokens.
            out, _ = moe_mod.moe_apply(
                params["ffn"], h, cfg,
                capacity_factor=cfg.n_experts / max(cfg.top_k, 1))
        elif cfg.family == "audio":
            out = gelu_mlp(params["ffn"], h)
        else:
            out = swiglu(params["ffn"], h)
        x = x + out
    return x, new_cache


# ---------------------------------------------------------------------------
# Stacked scan
# ---------------------------------------------------------------------------

def _remat(fn, cfg):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def stack_apply(stacked, x, cfg, period, *, positions=None, causal=True,
                kv_embeds=None, impl="ref", ssm_impl="chunked",
                mlstm_impl="ref", caches=None):
    """Scan the period stack. ``stacked``/``caches``: {"p{i}": tree} with a
    leading n_periods dim on every leaf. Returns (x, new_caches, aux)."""
    has_cache = caches is not None
    # Small stacks (the dry-run's depth-1/2 accounting probes) unroll into
    # straight-line HLO so cost_analysis counts every period; production
    # depths keep lax.scan for compile-time and remat structure.
    n_periods = jax.tree.leaves(stacked)[0].shape[0]
    unroll = n_periods <= 2

    if has_cache:
        def body(x, xs):
            layer_params, layer_cache = xs
            aux = jnp.zeros((), jnp.float32)
            new_cache = {}
            for i, spec in enumerate(period):
                x, nc, a = block_apply(
                    layer_params[f"p{i}"], x, cfg, spec,
                    positions=positions, causal=causal,
                    kv_embeds=kv_embeds, impl=impl, ssm_impl=ssm_impl,
                    mlstm_impl=mlstm_impl, cache=layer_cache[f"p{i}"])
                new_cache[f"p{i}"] = nc
                aux = aux + a
            return x, (new_cache, aux)

        if unroll:
            ncs, auxs = [], []
            for li in range(n_periods):
                take = lambda t, li=li: jax.tree.map(lambda a: a[li], t)
                x, (nc, a) = _remat(body, cfg)(x, (take(stacked),
                                                   take(caches)))
                ncs.append(nc)
                auxs.append(a)
            new_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *ncs)
            return x, new_caches, jnp.sum(jnp.stack(auxs))
        x, (new_caches, auxs) = jax.lax.scan(
            _remat(body, cfg), x, (stacked, caches))
        return x, new_caches, jnp.sum(auxs)

    def body_nc(x, layer_params):
        aux = jnp.zeros((), jnp.float32)
        for i, spec in enumerate(period):
            x, _, a = block_apply(
                layer_params[f"p{i}"], x, cfg, spec, positions=positions,
                causal=causal, kv_embeds=kv_embeds, impl=impl,
                ssm_impl=ssm_impl, mlstm_impl=mlstm_impl, cache=None)
            aux = aux + a
        return x, aux

    if unroll:
        auxs = []
        for li in range(n_periods):
            x, a = _remat(body_nc, cfg)(
                x, jax.tree.map(lambda t: t[li], stacked))
            auxs.append(a)
        return x, None, jnp.sum(jnp.stack(auxs))
    x, auxs = jax.lax.scan(_remat(body_nc, cfg), x, stacked)
    return x, None, jnp.sum(auxs)


def stack_decode(stacked, x, cfg, period, caches, lens, *, impl="ref"):
    def body(x, xs):
        layer_params, layer_cache = xs
        new_cache = {}
        for i, spec in enumerate(period):
            x, nc = block_decode(layer_params[f"p{i}"], x, cfg, spec,
                                 layer_cache[f"p{i}"], lens, impl=impl)
            new_cache[f"p{i}"] = nc
        return x, new_cache

    n_periods = jax.tree.leaves(stacked)[0].shape[0]
    if n_periods <= 2:                       # accounting probes: unroll
        ncs = []
        for li in range(n_periods):
            take = lambda t, li=li: jax.tree.map(lambda a: a[li], t)
            x, nc = body(x, (take(stacked), take(caches)))
            ncs.append(nc)
        new_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *ncs)
        return x, new_caches
    x, new_caches = jax.lax.scan(body, x, (stacked, caches))
    return x, new_caches
