"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(scalar memory, strictly sequential — documented in DESIGN.md: no Pallas
kernel is warranted, the recurrence has no MXU workload and its FLOPs are
negligible vs the mLSTM layers).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels.mlstm import mlstm, mlstm_step
from .common import (EMBED, HEADS, HEAD_DIM, MLP, SSM_INNER, P)
from .layers import rmsnorm, rmsnorm_template


# ---------------------------------------------------------------------------
# mLSTM block (projection factor 2, as xlstm-1.3b with d_ff = 0)
# ---------------------------------------------------------------------------

def mlstm_template(cfg):
    d = cfg.d_model
    inner = 2 * d
    h = cfg.n_heads
    hd = inner // h
    return {
        "up_proj": P((d, 2 * inner), (EMBED, SSM_INNER)),
        # Block-diagonal per-head q/k/v (the official mLSTM layout — a full
        # inner x inner projection would triple the parameter budget).
        "wq": P((h, hd, hd), (HEADS, None, HEAD_DIM)),
        "wk": P((h, hd, hd), (HEADS, None, HEAD_DIM)),
        "wv": P((h, hd, hd), (HEADS, None, HEAD_DIM)),
        "w_if": P((inner, 2, h), (SSM_INNER, None, HEADS), init="normal",
                  scale=0.02),
        "b_if": P((2, h), (None, HEADS), init="zeros"),
        "out_norm": rmsnorm_template(inner),
        "down_proj": P((inner, d), (SSM_INNER, EMBED)),
    }


def mlstm_state_template(cfg, batch: int, dtype=None):
    inner = 2 * cfg.d_model
    h = cfg.n_heads
    hd = inner // h
    return {
        "C": P((batch, h, hd, hd), ("batch", HEADS, HEAD_DIM, HEAD_DIM),
               init="zeros", dtype=jnp.float32),
        "n": P((batch, h, hd), ("batch", HEADS, HEAD_DIM), init="zeros",
               dtype=jnp.float32),
        "m": P((batch, h), ("batch", HEADS), init="zeros",
               dtype=jnp.float32),
    }


def _mlstm_qkvif(params, xu):
    b, s, inner = xu.shape
    h = params["wq"].shape[0]
    xh = xu.reshape(b, s, h, inner // h)
    q = jnp.einsum("bshe,hek->bshk", xh, params["wq"])
    k = jnp.einsum("bshe,hek->bshk", xh, params["wk"])
    v = jnp.einsum("bshe,hek->bshk", xh, params["wv"])
    gates = jnp.einsum("bsi,igh->bsgh", xu, params["w_if"]) + params["b_if"]
    return q, k, v, gates[:, :, 0, :], gates[:, :, 1, :] + 3.0


def mlstm_apply(params, x, cfg, *, impl="ref"):
    """Full-sequence mLSTM block. x: [b, s, d]."""
    b, s, d = x.shape
    xz = jnp.einsum("bsd,di->bsi", x, params["up_proj"])
    xu, z = jnp.split(xz, 2, axis=-1)
    q, k, v, ig, fg = _mlstm_qkvif(params, xu)
    h = mlstm(q, k, v, ig, fg, impl=impl)                   # [b,s,h,hd]
    h = h.reshape(b, s, -1)
    h = rmsnorm(params["out_norm"], h)
    h = h * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("bsi,id->bsd", h, params["down_proj"])


def mlstm_decode(params, x, cfg, state):
    """Single-token step. x: [b, 1, d]."""
    b = x.shape[0]
    xz = jnp.einsum("bsd,di->bsi", x, params["up_proj"])
    xu, z = jnp.split(xz, 2, axis=-1)
    q, k, v, ig, fg = _mlstm_qkvif(params, xu)
    h, (C, n, m) = mlstm_step(q[:, 0], k[:, 0], v[:, 0], ig[:, 0], fg[:, 0],
                              state["C"], state["n"], state["m"])
    h = h.reshape(b, 1, -1)
    h = rmsnorm(params["out_norm"], h)
    h = h * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bsi,id->bsd", h, params["down_proj"])
    return out, {"C": C, "n": n, "m": m}


# ---------------------------------------------------------------------------
# sLSTM block (scalar memory, exp gating, per-head recurrent weights)
# ---------------------------------------------------------------------------

def slstm_template(cfg):
    d = cfg.d_model
    h = cfg.n_heads
    hd = d // h
    ff = max((4 * d) // 3 // 128 * 128, 128)
    return {
        # 4 gates (z, i, f, o) from input and recurrent h (block-diagonal).
        "w_x": P((d, 4, h, hd), (EMBED, None, HEADS, HEAD_DIM)),
        "r_h": P((h, hd, 4, hd), (HEADS, HEAD_DIM, None, HEAD_DIM),
                 init="normal", scale=0.02),
        "bias": P((4, h, hd), (None, HEADS, HEAD_DIM), init="zeros"),
        "ffn_up": P((d, ff), (EMBED, MLP)),
        "ffn_down": P((ff, d), (MLP, EMBED)),
    }


def slstm_state_template(cfg, batch: int, dtype=None):
    h = cfg.n_heads
    hd = cfg.d_model // h
    z = lambda: P((batch, h, hd), ("batch", HEADS, HEAD_DIM), init="zeros",
                  dtype=jnp.float32)
    return {"c": z(), "n": z(), "h": z(),
            "m": P((batch, h), ("batch", HEADS), init="zeros",
                   dtype=jnp.float32)}


def _slstm_cell(params, xt, state):
    """One sLSTM step. xt: [b, 4, h, hd] pre-computed input projection."""
    c, n, hh, m = state["c"], state["n"], state["h"], state["m"]
    rec = jnp.einsum("bhd,hdge->bghe", hh.astype(xt.dtype), params["r_h"])
    g = (xt + rec + params["bias"]).astype(jnp.float32)
    z_t = jnp.tanh(g[:, 0])
    i_t = g[:, 1]
    f_t = g[:, 2] + 3.0
    o_t = jax.nn.sigmoid(g[:, 3])
    # Stabilized exponential gating (per head: shared max state m).
    i_max = jnp.max(i_t, axis=-1)
    f_max = jnp.max(f_t, axis=-1)
    m_new = jnp.maximum(f_max + m, i_max)
    ip = jnp.exp(i_t - m_new[..., None])
    fp = jnp.exp(f_t + (m - m_new)[..., None])
    c_new = fp * c + ip * z_t
    n_new = fp * n + ip
    h_new = o_t * c_new / jnp.maximum(n_new, 1e-6)
    return h_new, {"c": c_new, "n": n_new, "h": h_new, "m": m_new}


def slstm_apply(params, x, cfg, *, state=None):
    """Full-sequence sLSTM (lax.scan over time). x: [b, s, d]."""
    b, s, d = x.shape
    h = cfg.n_heads
    xg = jnp.einsum("bsd,dghe->bsghe", x, params["w_x"])    # [b,s,4,h,hd]
    st = state
    if st is None:
        hd = d // h
        zero = jnp.zeros((b, h, hd), jnp.float32)
        st = {"c": zero, "n": zero, "h": zero,
              "m": jnp.zeros((b, h), jnp.float32)}

    def step(carry, xt):
        h_out, new = _slstm_cell(params, xt, carry)
        return new, h_out

    new_state, hs = jax.lax.scan(step, st, xg.swapaxes(0, 1))
    y = hs.swapaxes(0, 1).reshape(b, s, d).astype(x.dtype)
    y = jnp.einsum("bsd,df->bsf", y, params["ffn_up"])
    y = jax.nn.gelu(y.astype(jnp.float32)).astype(x.dtype)
    y = jnp.einsum("bsf,fd->bsd", y, params["ffn_down"])
    if state is not None:
        return y, new_state
    return y


def slstm_decode(params, x, cfg, state):
    """Single-token step. x: [b, 1, d]."""
    b, _, d = x.shape
    xg = jnp.einsum("bsd,dghe->bsghe", x, params["w_x"])[:, 0]
    h_out, new_state = _slstm_cell(params, xg, state)
    y = h_out.reshape(b, 1, d).astype(x.dtype)
    y = jnp.einsum("bsd,df->bsf", y, params["ffn_up"])
    y = jax.nn.gelu(y.astype(jnp.float32)).astype(x.dtype)
    y = jnp.einsum("bsf,fd->bsd", y, params["ffn_down"])
    return y, new_state
