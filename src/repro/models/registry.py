"""Model classes and the ``--arch`` registry.

``TransformerLM`` covers dense / MoE / VLM / hybrid / xLSTM (any period
layout); ``EncDecLM`` covers seamless-m4t (audio encoder stub + causal
decoder with cross-attention). Both expose the same surface:

    template() / cache_template()      -> P-trees (init or abstract)
    forward(params, batch)             -> (logits, aux)
    loss(params, batch)                -> scalar
    prefill(params, batch, cache)      -> (last_logits, cache)
    decode_step(params, tokens, cache) -> (logits, cache)

``batch`` dict keys: tokens, labels, and for stub modalities
vision_embeds / audio_embeds (precomputed frontend outputs, per spec).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .common import P, stack_template, tree_map
from .layers import (embed, embedding_template, layernorm,
                     layernorm_template, rmsnorm, rmsnorm_template,
                     softmax_xent, unembed, unembed_template)
from .transformer import (LayerSpec, block_cache_template, block_template,
                          layout, stack_apply, stack_decode)


def _norm_pair(cfg):
    if cfg.norm == "layernorm":
        return layernorm_template(cfg.d_model), layernorm
    return rmsnorm_template(cfg.d_model), rmsnorm


def _stacked_block_template(cfg, period, n_periods, ep_pad):
    per = {f"p{i}": block_template(cfg, spec, ep_pad)
           for i, spec in enumerate(period)}
    return stack_template(per, n_periods)


def _stacked_cache_template(cfg, period, n_periods, batch, max_len,
                            kv_source_len, dtype=None):
    per = {f"p{i}": block_cache_template(cfg, spec, batch, max_len,
                                         kv_source_len, dtype)
           for i, spec in enumerate(period)}
    return stack_template(per, n_periods)


class TransformerLM:
    """Decoder-only family (dense / moe / vlm / hybrid / ssm)."""

    def __init__(self, cfg: ModelConfig, impl: str = "ref",
                 ssm_impl: str = "chunked", mlstm_impl: str = "ref",
                 ep_degree: int = 1):
        self.cfg = cfg
        self.impl = impl
        self.ssm_impl = ssm_impl
        self.mlstm_impl = mlstm_impl
        self.ep_pad = cfg.padded_experts(ep_degree) or None
        self.period, self.n_periods = layout(cfg)

    # -- templates ---------------------------------------------------------
    def template(self):
        cfg = self.cfg
        t = {"embed": embedding_template(cfg.padded_vocab, cfg.d_model),
             "blocks": _stacked_block_template(cfg, self.period,
                                               self.n_periods, self.ep_pad),
             "final_norm": _norm_pair(cfg)[0]}
        if not cfg.tie_embeddings:
            t["unembed"] = unembed_template(cfg.d_model, cfg.padded_vocab)
        return t

    def cache_template(self, batch: int, max_len: int, dtype=None):
        cfg = self.cfg
        kv_src = cfg.n_vision_tokens if cfg.family == "vlm" else max_len
        return {
            "blocks": _stacked_cache_template(cfg, self.period,
                                              self.n_periods, batch,
                                              max_len, kv_src, dtype),
            "len": P((batch,), ("batch",), init="zeros", dtype=jnp.int32),
        }

    # -- forward paths -----------------------------------------------------
    def _logits(self, params, x):
        cfg = self.cfg
        x = _norm_pair(cfg)[1](params["final_norm"], x)
        if cfg.tie_embeddings:
            return jnp.einsum("...d,vd->...v", x, params["embed"]["table"])
        return unembed(params["unembed"], x)

    def forward(self, params, batch):
        cfg = self.cfg
        x = embed(params["embed"], batch["tokens"]).astype(cfg.dtype)
        kv = batch.get("vision_embeds")
        if kv is not None:
            kv = kv.astype(cfg.dtype)
        x, _, aux = stack_apply(params["blocks"], x, cfg, self.period,
                                causal=True, kv_embeds=kv, impl=self.impl,
                                ssm_impl=self.ssm_impl,
                                mlstm_impl=self.mlstm_impl)
        return self._logits(params, x), aux

    def loss(self, params, batch):
        logits, aux = self.forward(params, batch)
        ce = softmax_xent(logits, batch["labels"], self.cfg.vocab)
        return ce + 0.01 * aux

    def prefill(self, params, batch, cache):
        cfg = self.cfg
        tokens = batch["tokens"]
        x = embed(params["embed"], tokens).astype(cfg.dtype)
        kv = batch.get("vision_embeds")
        if kv is not None:
            kv = kv.astype(cfg.dtype)
        x, blocks_cache, _ = stack_apply(
            params["blocks"], x, cfg, self.period, causal=True,
            kv_embeds=kv, impl=self.impl, ssm_impl=self.ssm_impl,
            mlstm_impl=self.mlstm_impl, caches=cache["blocks"])
        new_cache = {"blocks": blocks_cache,
                     "len": jnp.full_like(cache["len"], tokens.shape[1])}
        return self._logits(params, x[:, -1:]), new_cache

    def decode_step(self, params, tokens, cache):
        """tokens: [b] -> (logits [b, vocab], cache)."""
        cfg = self.cfg
        x = embed(params["embed"], tokens[:, None]).astype(cfg.dtype)
        lens = cache["len"]
        x, blocks_cache = stack_decode(params["blocks"], x, cfg,
                                       self.period, cache["blocks"], lens,
                                       impl=self.impl)
        new_cache = {"blocks": blocks_cache, "len": lens + 1}
        return self._logits(params, x)[:, 0], new_cache

    # -- bookkeeping ---------------------------------------------------
    def param_count(self) -> int:
        from .common import count_params
        return count_params(self.template())


class EncDecLM:
    """Encoder-decoder (seamless-m4t): audio-embed encoder stub input +
    causal text decoder with cross-attention."""

    def __init__(self, cfg: ModelConfig, impl: str = "ref"):
        self.cfg = cfg
        self.impl = impl
        self.enc_period, self.enc_n = layout(cfg, role="encoder")
        self.dec_period, self.dec_n = layout(cfg, role="decoder")

    def template(self):
        cfg = self.cfg
        return {
            "enc_in": {"w": P((cfg.d_model, cfg.d_model),
                              ("embed", "embed"))},
            "enc_blocks": _stacked_block_template(cfg, self.enc_period,
                                                  self.enc_n, None),
            "enc_norm": _norm_pair(cfg)[0],
            "embed": embedding_template(cfg.padded_vocab, cfg.d_model),
            "dec_blocks": _stacked_block_template(cfg, self.dec_period,
                                                  self.dec_n, None),
            "final_norm": _norm_pair(cfg)[0],
            "unembed": unembed_template(cfg.d_model, cfg.padded_vocab),
        }

    def cache_template(self, batch: int, max_len: int, dtype=None,
                       enc_len: Optional[int] = None):
        cfg = self.cfg
        enc_len = enc_len or max_len
        return {
            "blocks": _stacked_cache_template(cfg, self.dec_period,
                                              self.dec_n, batch, max_len,
                                              enc_len, dtype),
            "len": P((batch,), ("batch",), init="zeros", dtype=jnp.int32),
        }

    def encode(self, params, audio_embeds):
        cfg = self.cfg
        x = jnp.einsum("bsd,de->bse", audio_embeds.astype(cfg.dtype),
                       params["enc_in"]["w"])
        x, _, _ = stack_apply(params["enc_blocks"], x, cfg,
                              self.enc_period, causal=False,
                              impl=self.impl)
        return _norm_pair(cfg)[1](params["enc_norm"], x)

    def _logits(self, params, x):
        x = _norm_pair(self.cfg)[1](params["final_norm"], x)
        return unembed(params["unembed"], x)

    def forward(self, params, batch):
        cfg = self.cfg
        enc = self.encode(params, batch["audio_embeds"])
        x = embed(params["embed"], batch["tokens"]).astype(cfg.dtype)
        x, _, aux = stack_apply(params["dec_blocks"], x, cfg,
                                self.dec_period, causal=True, kv_embeds=enc,
                                impl=self.impl)
        return self._logits(params, x), aux

    def loss(self, params, batch):
        logits, aux = self.forward(params, batch)
        return softmax_xent(logits, batch["labels"], self.cfg.vocab) \
            + 0.01 * aux

    def prefill(self, params, batch, cache):
        cfg = self.cfg
        enc = self.encode(params, batch["audio_embeds"])
        tokens = batch["tokens"]
        x = embed(params["embed"], tokens).astype(cfg.dtype)
        x, blocks_cache, _ = stack_apply(
            params["dec_blocks"], x, cfg, self.dec_period, causal=True,
            kv_embeds=enc, impl=self.impl, caches=cache["blocks"])
        new_cache = {"blocks": blocks_cache,
                     "len": jnp.full_like(cache["len"], tokens.shape[1])}
        return self._logits(params, x[:, -1:]), new_cache

    def decode_step(self, params, tokens, cache):
        cfg = self.cfg
        x = embed(params["embed"], tokens[:, None]).astype(cfg.dtype)
        lens = cache["len"]
        x, blocks_cache = stack_decode(params["dec_blocks"], x, cfg,
                                       self.dec_period, cache["blocks"],
                                       lens, impl=self.impl)
        new_cache = {"blocks": blocks_cache, "len": lens + 1}
        return self._logits(params, x)[:, 0], new_cache

    def param_count(self) -> int:
        from .common import count_params
        return count_params(self.template())


def build(cfg: ModelConfig, impl: str = "ref", ssm_impl: str = "chunked",
          mlstm_impl: str = "ref", ep_degree: int = 1):
    if cfg.enc_layers:
        return EncDecLM(cfg, impl=impl)
    return TransformerLM(cfg, impl=impl, ssm_impl=ssm_impl,
                         mlstm_impl=mlstm_impl, ep_degree=ep_degree)
