"""Parameter-template machinery shared by every model in the zoo.

A model is described by a *template*: a nested dict whose leaves are
:class:`P` — pure metadata (shape, logical axes, initializer). Templates
can be

  * materialized   -> ``init_params``      (real arrays, for training/tests)
  * abstracted     -> ``abstract_params``  (ShapeDtypeStruct, for the
                       multi-pod dry-run — never touches a device)
  * sharded        -> ``pspec_tree``       (logical axes -> PartitionSpec via
                       the per-arch sharding rules in repro.sharding)

so the exact same definition serves smoke tests, full-scale lowering, and
the serving/training runtimes.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

# Logical axis vocabulary (see repro/sharding/rules.py for the mesh mapping).
BATCH = "batch"
SEQ = "seq"
EMBED = "embed"
HEADS = "heads"
KV_HEADS = "kv_heads"
HEAD_DIM = "head_dim"
MLP = "mlp"
VOCAB = "vocab"
EXPERTS = "experts"
EXPERT_MLP = "expert_mlp"
LAYERS = "layers"          # stacked scan dimension — never sharded
CACHE_SEQ = "cache_seq"
SSM_INNER = "ssm_inner"
SSM_STATE = "ssm_state"
CONV = "conv"
LORA = "lora"              # MLA low-rank dims — never sharded


@dataclasses.dataclass(frozen=True)
class P:
    """A parameter leaf template."""
    shape: tuple
    axes: tuple                 # logical axis name (or None) per dim
    init: str = "fan_in"        # fan_in | normal | zeros | ones | embed
    scale: Optional[float] = None
    dtype: Optional[Any] = None

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)

    @property
    def size(self) -> int:
        return int(np.prod(self.shape))


def is_leaf(x) -> bool:
    return isinstance(x, P)


def tree_map(fn: Callable, template, *rest):
    return jax.tree.map(fn, template, *rest, is_leaf=is_leaf)


def _initializer(p: P, key, dtype):
    dtype = p.dtype or dtype
    if p.init == "zeros":
        return jnp.zeros(p.shape, dtype)
    if p.init == "ones":
        return jnp.ones(p.shape, dtype)
    if p.init == "embed":
        scale = p.scale if p.scale is not None else 1.0
        return (jax.random.normal(key, p.shape, jnp.float32) *
                scale).astype(dtype)
    if p.init == "normal":
        scale = p.scale if p.scale is not None else 0.02
        return (jax.random.normal(key, p.shape, jnp.float32) *
                scale).astype(dtype)
    if p.init == "s4d":
        # S4D-real A_log init: log(1..n) broadcast over inner (+layers).
        n = p.shape[-1]
        row = jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32))
        return jnp.broadcast_to(row, p.shape).astype(dtype)
    if p.init == "s4d_dt":
        # dt bias: inverse-softplus of dt ~ U[1e-3, 1e-1] (log-uniform).
        u = jax.random.uniform(key, p.shape, jnp.float32)
        dt = jnp.exp(u * (jnp.log(0.1) - jnp.log(1e-3)) + jnp.log(1e-3))
        return jnp.log(jnp.expm1(dt)).astype(dtype)
    if p.init == "fan_in":
        fan_in = p.shape[0] if len(p.shape) == 1 else int(
            np.prod(p.shape[:-1]))
        # Stacked-layer templates carry a leading LAYERS dim that is not a
        # contraction dim; exclude it from fan-in.
        if p.axes and p.axes[0] == LAYERS and len(p.shape) > 2:
            fan_in = int(np.prod(p.shape[1:-1]))
        scale = p.scale if p.scale is not None else 1.0
        std = scale / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, p.shape, jnp.float32) * std
                ).astype(dtype)
    raise ValueError(f"unknown init {p.init!r}")


def init_params(template, key, dtype=jnp.float32):
    """Materialize a template with per-leaf folded keys (path-stable)."""
    leaves, treedef = jax.tree.flatten(template, is_leaf=is_leaf)
    keys = jax.random.split(key, max(len(leaves), 1))
    vals = [_initializer(p, k, dtype) for p, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(template, dtype=jnp.float32):
    """ShapeDtypeStruct tree — the dry-run stand-in, no allocation."""
    return tree_map(
        lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype or dtype), template)


def pspec_tree(template, rules: dict):
    """Logical axes -> jax.sharding.PartitionSpec using ``rules``.

    ``rules[axis]`` is a mesh-axis name, a tuple of mesh axes, or None.
    Logical axes absent from ``rules`` are unsharded. Dims whose size does
    not divide the mapped mesh-axis extent are left unsharded (the rules
    module pre-validates, this is the final guard).
    """
    from jax.sharding import PartitionSpec

    from ..sharding.spec import spec_dims

    def spec_for(p: P):
        return PartitionSpec(*spec_dims(p.shape, p.axes, rules))

    return tree_map(spec_for, template)


def count_params(template) -> int:
    return sum(p.size for p in jax.tree.leaves(template, is_leaf=is_leaf))


def stack_template(template, n: int):
    """Add a leading LAYERS dim of extent n to every leaf (scan stacking)."""
    return tree_map(
        lambda p: P((n,) + tuple(p.shape), (LAYERS,) + tuple(p.axes),
                    p.init, p.scale, p.dtype), template)


def zeros_template(shape, axes, dtype=None):
    return P(tuple(shape), tuple(axes), init="zeros", dtype=dtype)
