"""Multi-head latent attention (MiniCPM3 / DeepSeek-V2 style).

KV is compressed into a small latent c_kv (kv_lora dims) plus a shared
rotary key (rope_dim dims): the decode cache is [b, t, kv_lora + rope_dim]
— ~20x smaller than GQA at these dims.

Prefill/train use the naive expanded form. Decode uses the **absorbed**
form (beyond-paper perf note, DESIGN.md): k_up is folded into the query and
v_up applied after attention, so per-step work is O(h * (nope*lora)) and the
cache is read once — this is what makes minicpm3's decode roofline latent-
bound instead of KV-bound.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels.flash_attention.ref import mha_ref
from .common import (EMBED, HEADS, HEAD_DIM, LORA, CACHE_SEQ, P)
from .layers import apply_rope, rmsnorm, rmsnorm_template

NEG_INF = -1e30


def mla_template(cfg):
    d, h = cfg.d_model, cfg.n_heads
    m = cfg.mla
    return {
        "q_down": P((d, m.q_lora), (EMBED, LORA)),
        "q_norm": rmsnorm_template(m.q_lora),
        "q_up": P((m.q_lora, h, m.nope_dim + m.rope_dim),
                  (LORA, HEADS, HEAD_DIM)),
        "kv_down": P((d, m.kv_lora + m.rope_dim), (EMBED, LORA)),
        "kv_norm": rmsnorm_template(m.kv_lora),
        "k_up": P((m.kv_lora, h, m.nope_dim), (LORA, HEADS, HEAD_DIM)),
        "v_up": P((m.kv_lora, h, m.v_dim), (LORA, HEADS, HEAD_DIM)),
        "wo": P((h, m.v_dim, d), (HEADS, HEAD_DIM, EMBED)),
    }


def mla_cache_template(cfg, batch: int, max_len: int, dtype=None):
    m = cfg.mla
    return {"ckv": P((batch, max_len, m.kv_lora),
                     ("batch", CACHE_SEQ, LORA), init="zeros", dtype=dtype),
            "krope": P((batch, max_len, m.rope_dim),
                       ("batch", CACHE_SEQ, HEAD_DIM), init="zeros",
                       dtype=dtype)}


def _project(params, x, cfg, positions):
    m = cfg.mla
    cq = rmsnorm(params["q_norm"], jnp.einsum("bsd,dq->bsq", x,
                                              params["q_down"]))
    q = jnp.einsum("bsq,qhk->bshk", cq, params["q_up"])
    q_nope, q_rope = q[..., :m.nope_dim], q[..., m.nope_dim:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    ckv_full = jnp.einsum("bsd,dq->bsq", x, params["kv_down"])
    ckv = rmsnorm(params["kv_norm"], ckv_full[..., :m.kv_lora])
    k_rope = ckv_full[..., m.kv_lora:]
    # Shared-across-heads rotary key: treat as a 1-head rope input.
    k_rope = apply_rope(k_rope[:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0, :]
    return q_nope, q_rope, ckv, k_rope


def mla_apply(params, x, cfg, *, positions=None, causal=True, cache=None,
              impl="ref"):
    """Full-sequence MLA (naive expanded form)."""
    m = cfg.mla
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q_nope, q_rope, ckv, k_rope = _project(params, x, cfg, positions)
    k_nope = jnp.einsum("btq,qhk->bthk", ckv, params["k_up"])
    v = jnp.einsum("btq,qhk->bthk", ckv, params["v_up"])
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (b, s, cfg.n_heads, m.rope_dim))],
        axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    out = mha_ref(q, k, v, causal=causal,
                  scale=(m.nope_dim + m.rope_dim) ** -0.5)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    if cache is not None:
        new_cache = dict(cache)
        new_cache["ckv"] = jax.lax.dynamic_update_slice_in_dim(
            cache["ckv"], ckv.astype(cache["ckv"].dtype), 0, axis=1)
        new_cache["krope"] = jax.lax.dynamic_update_slice_in_dim(
            cache["krope"], k_rope.astype(cache["krope"].dtype), 0, axis=1)
        return y, new_cache
    return y


def mla_decode(params, x, cfg, cache, lens, *, impl="ref"):
    """Absorbed-form single-token decode. x: [b, 1, d]."""
    m = cfg.mla
    b = x.shape[0]
    pos = lens[:, None]
    q_nope, q_rope, ckv_new, k_rope_new = _project(params, x, cfg, pos)

    from .attention import scatter_kv
    new_cache = dict(cache)
    new_cache["ckv"] = scatter_kv(cache["ckv"], ckv_new[:, 0], lens)
    new_cache["krope"] = scatter_kv(cache["krope"], k_rope_new[:, 0], lens)

    # Absorb k_up into the query: q_eff [b, h, kv_lora].
    q_eff = jnp.einsum("bhk,qhk->bhq", q_nope[:, 0], params["k_up"])
    ckv_c = new_cache["ckv"].astype(jnp.float32)
    kr_c = new_cache["krope"].astype(jnp.float32)
    scale = (m.nope_dim + m.rope_dim) ** -0.5
    scores = (jnp.einsum("bhq,btq->bht", q_eff.astype(jnp.float32), ckv_c)
              + jnp.einsum("bhk,btk->bht",
                           q_rope[:, 0].astype(jnp.float32), kr_c)) * scale
    t = ckv_c.shape[1]
    valid = jnp.arange(t)[None, None, :] < (lens + 1)[:, None, None]
    scores = jnp.where(valid, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bht,btq->bhq", probs, ckv_c)          # latent context
    out = jnp.einsum("bhq,qhk->bhk", ctx.astype(x.dtype), params["v_up"])
    y = jnp.einsum("bhk,hkd->bd", out, params["wo"])[:, None]
    return y, new_cache
