"""GQA self-attention and cross-attention blocks (templates + apply)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels.decode_attention import decode_attention
from ..kernels.flash_attention import attention as attn_op
from .common import (EMBED, HEADS, HEAD_DIM, KV_HEADS, CACHE_SEQ, P)
from .layers import apply_rope


def gqa_template(cfg, cross: bool = False):
    d, h, kvh = cfg.d_model, cfg.padded_heads, cfg.n_kv_heads
    hd = cfg.resolved_head_dim
    t = {
        "wq": P((d, h, hd), (EMBED, HEADS, HEAD_DIM)),
        "wk": P((d, kvh, hd), (EMBED, KV_HEADS, HEAD_DIM)),
        "wv": P((d, kvh, hd), (EMBED, KV_HEADS, HEAD_DIM)),
        "wo": P((h, hd, d), (HEADS, HEAD_DIM, EMBED)),
    }
    if cfg.qkv_bias:
        t["bq"] = P((h, hd), (HEADS, HEAD_DIM), init="zeros")
        t["bk"] = P((kvh, hd), (KV_HEADS, HEAD_DIM), init="zeros")
        t["bv"] = P((kvh, hd), (KV_HEADS, HEAD_DIM), init="zeros")
    return t


def cache_template(cfg, batch: int, max_len: int, dtype=None):
    kvh, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    return {
        "k": P((batch, max_len, kvh, hd),
               ("batch", CACHE_SEQ, KV_HEADS, HEAD_DIM), init="zeros",
               dtype=dtype),
        "v": P((batch, max_len, kvh, hd),
               ("batch", CACHE_SEQ, KV_HEADS, HEAD_DIM), init="zeros",
               dtype=dtype),
    }


def _qkv(params, x, kv_x, cfg):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("btd,dhk->bthk", kv_x, params["wk"])
    v = jnp.einsum("btd,dhk->bthk", kv_x, params["wv"])
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    return q, k, v


def _out(params, ctx):
    return jnp.einsum("bshk,hkd->bsd", ctx, params["wo"])


def gqa_apply(params, x, cfg, *, positions=None, causal=True, kv_x=None,
              impl="ref", cache=None):
    """Full-sequence attention (train / prefill).

    ``kv_x``: cross-attention source ([b, t, d]); rope skipped for cross.
    ``cache``: when given (prefill), k/v are written at offset 0 and the
    updated cache is returned alongside the output.
    """
    cross = kv_x is not None
    q, k, v = _qkv(params, x, kv_x if cross else x, cfg)
    if not cross:
        if positions is None:
            positions = jnp.arange(x.shape[1])[None, :]
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    out = attn_op(q, k, v, causal=causal and not cross, impl=impl)
    y = _out(params, out)
    if cache is not None:
        s = k.shape[1]
        new_cache = dict(cache)
        new_cache["k"] = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), 0, axis=1)
        new_cache["v"] = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), 0, axis=1)
        return y, new_cache
    return y


def scatter_kv(cache_arr, new, lens):
    """Write new [b, ...] at per-sequence positions ``lens`` [b] into
    cache [b, t, ...].

    Under a sharding context the update is a one-hot select: GSPMD
    partitions it cleanly even when the cache's seq dim is sharded, whereas
    a batched scatter triggers an involuntary full rematerialization
    (all-gather of the whole cache per layer — found via the dry-run
    collective audit, EXPERIMENTS.md §Perf iteration 1). On TPU the real
    engine path uses in-place updates inside the decode kernel; the extra
    cache read/write of the one-hot form is corrected for in the roofline's
    fused-memory estimate.
    """
    from ..sharding import ctx
    if ctx.current() is None:
        b = cache_arr.shape[0]
        return cache_arr.at[jnp.arange(b), lens].set(
            new.astype(cache_arr.dtype), mode="drop")
    t = cache_arr.shape[1]
    oh = (jnp.arange(t)[None, :] == lens[:, None])           # [b, t]
    oh = oh.reshape(oh.shape + (1,) * (cache_arr.ndim - 2))
    return jnp.where(oh, new[:, None].astype(cache_arr.dtype), cache_arr)


def gqa_decode(params, x, cfg, cache, lens, *, impl="ref"):
    """Single-token decode. x: [b, 1, d]; lens: [b] current cache fill.

    Returns (y [b, 1, d], new_cache). Attention spans cache[:lens]+new.
    """
    q, k, v = _qkv(params, x, x, cfg)
    pos = lens[:, None]                                    # [b, 1]
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    new_cache = dict(cache)
    new_cache["k"] = scatter_kv(cache["k"], k[:, 0], lens)
    new_cache["v"] = scatter_kv(cache["v"], v[:, 0], lens)
    out = decode_attention(q[:, 0], new_cache["k"], new_cache["v"],
                           lens + 1, impl=impl)
    return _out(params, out[:, None]), new_cache


def cross_decode(params, x, cfg, enc_k, enc_v, *, impl="ref", enc_len=None):
    """Cross-attention during decode: static encoder KV, no cache update."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    if cfg.qkv_bias:
        q = q + params["bq"]
    t = enc_k.shape[1]
    lens = (jnp.full((x.shape[0],), t, jnp.int32)
            if enc_len is None else enc_len)
    out = decode_attention(q[:, 0], enc_k, enc_v, lens, impl=impl)
    return _out(params, out[:, None])


def encode_kv(params, cfg, kv_x):
    """Precompute cross-attention KV from encoder output / vision embeds."""
    k = jnp.einsum("btd,dhk->bthk", kv_x, params["wk"])
    v = jnp.einsum("btd,dhk->bthk", kv_x, params["wv"])
    if cfg.qkv_bias:
        k = k + params["bk"]
        v = v + params["bv"]
    return k, v
