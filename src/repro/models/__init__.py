"""Model zoo: pure-JAX templates + applies for all assigned architectures."""
from . import (attention, common, layers, mla, moe, registry, ssm,
               transformer, xlstm)
from .registry import build

__all__ = ["attention", "common", "layers", "mla", "moe", "registry",
           "ssm", "transformer", "xlstm", "build"]
