"""Primitive layers: norms, rotary embeddings, MLPs, embeddings, logits."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..sharding import ctx
from .common import (EMBED, MLP, VOCAB, P)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_template(d: int):
    return {"scale": P((d,), (EMBED,), init="ones")}


def rmsnorm(params, x, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dtype)


def layernorm_template(d: int):
    return {"scale": P((d,), (EMBED,), init="ones"),
            "bias": P((d,), (EMBED,), init="zeros")}


def layernorm(params, x, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
    y = (x - mean) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(
        jnp.float32)
    return y.astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float = 1e4):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float = 1e4, dims: int | None = None):
    """Rotate the first ``dims`` features of ``x`` [..., seq, heads, hd].

    ``positions``: int32 [..., seq] absolute positions (supports caches).
    """
    hd = x.shape[-1]
    dims = dims or hd
    freqs = rope_frequencies(dims, theta)                   # [dims/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..,s,d/2]
    cos = jnp.cos(angles)[..., None, :]                     # [..,s,1,d/2]
    sin = jnp.sin(angles)[..., None, :]
    rot, keep = x[..., :dims], x[..., dims:]
    x1, x2 = jnp.split(rot.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), keep], axis=-1) \
        if dims < hd else out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def swiglu_template(d: int, ff: int):
    return {"wi_gate": P((d, ff), (EMBED, MLP)),
            "wi_up": P((d, ff), (EMBED, MLP)),
            "wo": P((ff, d), (MLP, EMBED))}


def _mlp_axes(ndim):
    return ("batch",) + (None,) * (ndim - 2) + ("mlp",)


def swiglu(params, x):
    gate = jnp.einsum("...d,df->...f", x, params["wi_gate"])
    up = jnp.einsum("...d,df->...f", x, params["wi_up"])
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    h = ctx.constrain(h, _mlp_axes(h.ndim))
    return jnp.einsum("...f,fd->...d", h, params["wo"])


def gelu_mlp_template(d: int, ff: int):
    return {"wi": P((d, ff), (EMBED, MLP)),
            "bi": P((ff,), (MLP,), init="zeros"),
            "wo": P((ff, d), (MLP, EMBED)),
            "bo": P((d,), (EMBED,), init="zeros")}


def gelu_mlp(params, x):
    h = jnp.einsum("...d,df->...f", x, params["wi"]) + params["bi"]
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    h = ctx.constrain(h, _mlp_axes(h.ndim))
    return jnp.einsum("...f,fd->...d", h, params["wo"]) + params["bo"]


# ---------------------------------------------------------------------------
# Embedding / logits
# ---------------------------------------------------------------------------

def embedding_template(vocab: int, d: int):
    return {"table": P((vocab, d), (VOCAB, EMBED), init="embed", scale=0.02)}


def embed(params, tokens):
    out = jnp.take(params["table"], tokens, axis=0)
    return ctx.constrain(out, ("batch",) + (None,) * (out.ndim - 1))


def unembed_template(d: int, vocab: int):
    return {"w": P((d, vocab), (EMBED, VOCAB), init="fan_in")}


def unembed(params, x):
    out = jnp.einsum("...d,dv->...v", x, params["w"])
    return ctx.constrain(out, ("batch",) + (None,) * (out.ndim - 2)
                         + ("vocab",))


def softmax_xent(logits, labels, vocab_real: int, z_loss: float = 1e-4):
    """Cross-entropy with padded-vocab masking and optional z-loss.

    ``vocab_real``: true vocabulary size; logits beyond it (padding added
    for TP divisibility) are masked to -inf. Returns per-token loss mean.
    """
    v = logits.shape[-1]
    if vocab_real < v:
        mask = jnp.arange(v) < vocab_real
        logits = jnp.where(mask, logits, -1e30)
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None],
                             axis=-1).squeeze(-1)
    loss = logz - ll
    if z_loss:
        loss = loss + z_loss * jnp.square(logz)
    return jnp.mean(loss)
