"""Mamba (S6) block: template, full-sequence apply, and decode step."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels.selective_scan import selective_scan, selective_step
from ..sharding import ctx
from .common import (CONV, EMBED, LORA, SSM_INNER, SSM_STATE, P)


def mamba_template(cfg):
    d = cfg.d_model
    inner = cfg.ssm_expand * d
    dtr = cfg.resolved_dt_rank
    n = cfg.ssm_state
    return {
        "in_proj": P((d, 2 * inner), (EMBED, SSM_INNER)),
        "conv_w": P((cfg.ssm_conv, inner), (CONV, SSM_INNER),
                    init="normal", scale=0.1),
        "conv_b": P((inner,), (SSM_INNER,), init="zeros"),
        "x_proj": P((inner, dtr + 2 * n), (SSM_INNER, LORA)),
        "dt_proj": P((dtr, inner), (LORA, SSM_INNER)),
        "dt_bias": P((inner,), (SSM_INNER,), init="s4d_dt"),
        "A_log": P((inner, n), (SSM_INNER, SSM_STATE), init="s4d"),
        "D": P((inner,), (SSM_INNER,), init="ones"),
        "out_proj": P((inner, d), (SSM_INNER, EMBED)),
    }


def mamba_state_template(cfg, batch: int, dtype=None):
    inner = cfg.ssm_expand * cfg.d_model
    return {
        "h": P((batch, inner, cfg.ssm_state),
               ("batch", SSM_INNER, SSM_STATE), init="zeros",
               dtype=jnp.float32),
        "conv": P((batch, cfg.ssm_conv - 1, inner),
                  ("batch", CONV, SSM_INNER), init="zeros", dtype=dtype),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv via shifted adds. x: [b, s, inner];
    w: [conv, inner]."""
    conv = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (conv - 1, 0), (0, 0)))
    out = sum(pad[:, j:j + x.shape[1], :] * w[j] for j in range(conv))
    return out + b


def _dt_bc(params, xc, cfg):
    dtr, n = cfg.resolved_dt_rank, cfg.ssm_state
    dbc = jnp.einsum("...i,ir->...r", xc, params["x_proj"])
    dt_low, B, C = jnp.split(dbc, [dtr, dtr + n], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("...r,ri->...i", dt_low, params["dt_proj"]).astype(
            jnp.float32) + params["dt_bias"].astype(jnp.float32))
    return dt.astype(xc.dtype), B, C


def mamba_apply(params, x, cfg, *, state=None, impl="chunked"):
    """Full-sequence apply. Returns y, or (y, new_state) when ``state``
    is given (prefill)."""
    xz = jnp.einsum("bsd,di->bsi", x, params["in_proj"])
    xz = ctx.constrain(xz, ("batch", None, "ssm_inner"))
    x_in, z = jnp.split(xz, 2, axis=-1)
    xc = jax.nn.silu(_causal_conv(x_in, params["conv_w"],
                                  params["conv_b"]).astype(jnp.float32)
                     ).astype(x.dtype)
    dt, B, C = _dt_bc(params, xc, cfg)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    h0 = None if state is None else state["h"]
    y, h_last = selective_scan(xc, dt, A, B, C, params["D"], h0=h0,
                               impl=impl)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bsi,id->bsd", y, params["out_proj"])
    if state is not None:
        new_state = {"h": h_last,
                     "conv": x_in[:, -(cfg.ssm_conv - 1):, :]}
        return out, new_state
    return out


def mamba_decode(params, x, cfg, state):
    """Single-token step. x: [b, 1, d]; state: mamba_state_template tree."""
    xz = jnp.einsum("bsd,di->bsi", x, params["in_proj"])
    x_in, z = jnp.split(xz, 2, axis=-1)                     # [b, 1, inner]
    window = jnp.concatenate([state["conv"],
                              x_in.astype(state["conv"].dtype)], axis=1)
    w = params["conv_w"]
    xc = sum(window[:, j, :] * w[j] for j in range(cfg.ssm_conv)) \
        + params["conv_b"]
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(x.dtype)   # [b, inner]
    dt, B, C = _dt_bc(params, xc, cfg)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    y, h_new = selective_step(xc, dt, A, B, C, params["D"], state["h"])
    y = y * jax.nn.silu(z[:, 0].astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bi,id->bd", y, params["out_proj"])[:, None]
    new_state = {"h": h_new, "conv": window[:, 1:, :]}
    return out, new_state
