"""Mixture-of-experts FFN with GShard-style capacity dispatch.

Layout follows the canonical GSPMD expert-parallel pattern:

  tokens   [b(data), s, d]
  dispatch [b(data), s, E, C]      C = capacity PER SEQUENCE (cf * s * k/E)
  xin      [E(data), b, C, d]      <- all-to-all (batch-shard -> expert-shard)
  expert   [E(data), d, f(model)]  matmuls
  combine  back to [b(data), s, d] <- all-to-all

Capacity is per-sequence, not global: with a global capacity the one-hot
dispatch einsum costs T_global * E * C_global * d per device — the dry-run
FLOP audit showed this inflating jamba's compute 50x (EXPERIMENTS.md §Perf
iteration 0). Per-sequence capacity keeps dispatch at ~3% of expert FLOPs.

Experts are zero-padded to a multiple of the EP degree (qwen2-moe: 60->64);
the router masks padded experts so no token routes there. Shared experts
(qwen2-moe) are a plain always-on SwiGLU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..sharding import ctx
from .common import (EMBED, EXPERTS, EXPERT_MLP, P)
from .layers import swiglu, swiglu_template


def moe_template(cfg, n_experts_padded: int | None = None):
    d = cfg.d_model
    e = n_experts_padded or cfg.n_experts
    eff = cfg.expert_d_ff
    t = {
        "router": P((d, e), (EMBED, EXPERTS), init="normal", scale=0.02),
        "wi_gate": P((e, d, eff), (EXPERTS, EMBED, EXPERT_MLP)),
        "wi_up": P((e, d, eff), (EXPERTS, EMBED, EXPERT_MLP)),
        "wo": P((e, eff, d), (EXPERTS, EXPERT_MLP, EMBED)),
    }
    if cfg.n_shared_experts:
        t["shared"] = swiglu_template(d, cfg.n_shared_experts * eff)
    return t


def _routing(params, x, cfg, capacity):
    """Shared routing math: returns (dispatch, combine, aux) — all local to
    whatever batch shard ``x`` is (capacity is per-sequence, so routing is
    identical under any batch partitioning)."""
    b, s, d = x.shape
    e = params["router"].shape[1]
    k = cfg.top_k
    logits = jnp.einsum("bsd,de->bse", x, params["router"])
    logits = logits.astype(jnp.float32)
    if e > cfg.n_experts:
        pad_mask = jnp.arange(e) < cfg.n_experts
        logits = jnp.where(pad_mask, logits, -1e30)
    gates_all = jax.nn.softmax(logits, axis=-1)
    top_vals, top_idx = jax.lax.top_k(gates_all, k)
    top_vals = top_vals / jnp.maximum(
        jnp.sum(top_vals, axis=-1, keepdims=True), 1e-9)
    oh = jax.nn.one_hot(top_idx, e, dtype=jnp.float32)
    ohf = oh.reshape(b, s * k, e)
    pos = jnp.cumsum(ohf, axis=1) - ohf
    pos = jnp.einsum("bfe,bfe->bf", pos, ohf).reshape(b, s, k)
    keep = pos < capacity
    gate_kept = jnp.where(keep, top_vals, 0.0)
    pos_cl = jnp.where(keep, pos, 0).astype(jnp.int32)
    pos_oh = jax.nn.one_hot(pos_cl, capacity, dtype=jnp.float32)
    sel = oh * keep[..., None]
    dispatch = jnp.einsum("bske,bskc->bsec", sel, pos_oh)
    combine = jnp.einsum("bske,bskc,bsk->bsec", oh, pos_oh, gate_kept)
    frac_tokens = jnp.mean(oh[:, :, 0, :], axis=(0, 1))
    mean_prob = jnp.mean(gates_all, axis=(0, 1))
    aux = cfg.n_experts * jnp.sum(frac_tokens * mean_prob)
    return dispatch, combine, aux


def _experts(params, xin, dtype):
    """Expert matmuls on [e, ..., d] buffers (weights [e, d, f])."""
    g = jnp.einsum("e...d,edf->e...f", xin, params["wi_gate"])
    u = jnp.einsum("e...d,edf->e...f", xin, params["wi_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(dtype) * u
    return jnp.einsum("e...f,efd->e...d", h, params["wo"])


MOE_GROUP = 2048     # group-limited routing: capacity & dispatch one-hots
#                      are per group of <=2048 tokens, not per sequence —
#                      at 32k the per-seq dispatch tensor is 16x larger in
#                      both bytes and dispatch FLOPs (EXPERIMENTS.md §Perf
#                      iteration MoE-4).


def moe_apply(params, x, cfg, *, capacity_factor: float | None = None):
    """x: [b, s, d] -> ([b, s, d], aux_loss). Dispatches to the explicit
    shard_map all-to-all path when expert parallelism is active."""
    from ..sharding import ctx as shard_ctx
    rules = shard_ctx.current()
    b0, s0, d = x.shape
    if s0 > MOE_GROUP and s0 % MOE_GROUP == 0:
        x = x.reshape(b0 * s0 // MOE_GROUP, MOE_GROUP, d)
    b, s, d = x.shape
    e = params["router"].shape[1]
    k = cfg.top_k
    cap_f = capacity_factor or cfg.capacity_factor
    capacity = max(int(cap_f * s * k / e), 1)
    capacity = min(capacity, s * k)

    def ungroup(out):
        y, aux = out
        return (y.reshape(b0, s0, d), aux) if s != s0 else (y, aux)

    if rules is not None and rules.get("_mesh") is not None:
        ep_ax = rules.get("experts")
        dp = rules.get("batch")
        dp_axes = (dp,) if isinstance(dp, str) else tuple(dp or ())
        mesh = rules["_mesh"]
        ep = mesh.shape.get(ep_ax, 1) if isinstance(ep_ax, str) else 1
        dp_extent = 1
        for a in dp_axes:
            dp_extent *= mesh.shape.get(a, 1)
        if (ep > 1 and e % ep == 0 and b % dp_extent == 0
                and ep_ax in dp_axes):
            return ungroup(_moe_apply_a2a(params, x, cfg, capacity, mesh,
                                          ep_ax, dp_axes, rules))

    dispatch, combine, aux = _routing(params, x, cfg, capacity)
    xin = jnp.einsum("bsec,bsd->ebcd", dispatch.astype(x.dtype), x)
    yout = _experts(params, xin, x.dtype)
    y = jnp.einsum("bsec,ebcd->bsd", combine.astype(x.dtype), yout)
    y = ctx.constrain(y, ("batch", None, None))

    if "shared" in params:
        y = y + swiglu(params["shared"], x)
    return ungroup((y, aux))


def _moe_apply_a2a(params, x, cfg, capacity, mesh, ep_ax, dp_axes, rules):
    """Expert parallelism with explicit all-to-alls (shard_map).

    The pure-einsum GSPMD path resolves the batch-shard -> expert-shard
    layout change by ALL-GATHERING the activations over batch (25.8 GiB
    f32 per device per MoE layer for dbrx train — found by the collective
    audit, EXPERIMENTS.md §Perf iteration MoE-2). Production MoE does a
    local dispatch followed by an all-to-all of the compact expert buffers;
    the compiler's partitioner does not find that form from constraints, so
    it is written explicitly here. Routing math is per-sequence and hence
    bit-identical to the einsum path.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as Ps

    ep = mesh.shape[ep_ax]
    dtype = x.dtype
    other_dp = tuple(a for a in dp_axes if a != ep_ax)

    w_specs = {
        "router": Ps(None, None),
        "wi_gate": Ps(ep_ax, None, "model"),
        "wi_up": Ps(ep_ax, None, "model"),
        "wo": Ps(ep_ax, "model", None),
    }
    expert_params = {k: params[k] for k in w_specs}

    def local(xl, wl):
        # xl: [b_loc, s, d] (this device's batch shard).
        dispatch, combine, aux = _routing(
            {"router": wl["router"]}, xl, cfg, capacity)
        xin = jnp.einsum("bsec,bsd->ebcd", dispatch.astype(dtype), xl)
        # [E, b_loc, c, d] -> [E/ep, b_loc*ep, c, d]: the EP all-to-all.
        xin = jax.lax.all_to_all(xin, ep_ax, split_axis=0, concat_axis=1,
                                 tiled=True)
        yo = _experts(wl, xin, dtype)
        # Reduce-scatter the TP partial sums over d instead of a full psum:
        # the return all-to-all and the combine then run on d/TP, and only
        # the final (much smaller) y is gathered — measured -41% collective
        # bytes AND -42% HLO flops on qwen2-moe train (EXPERIMENTS.md
        # §Perf iteration MoE-3).
        yo = jax.lax.psum_scatter(yo, "model", scatter_dimension=3,
                                  tiled=True)
        yo = jax.lax.all_to_all(yo, ep_ax, split_axis=1, concat_axis=0,
                                tiled=True)      # back to [E, b_loc, c, d/TP]
        y = jnp.einsum("bsec,ebcd->bsd", combine.astype(dtype), yo)
        y = jax.lax.all_gather(y, "model", axis=2, tiled=True)
        aux = jax.lax.pmean(aux, dp_axes)
        return y, aux

    all_axes = tuple(mesh.axis_names)
    batch_spec = Ps(dp_axes, None, None)
    mapped = shard_map(
        local, mesh=mesh,
        in_specs=(batch_spec, w_specs),
        out_specs=(batch_spec, Ps()),
        check_rep=False)
    y, aux = mapped(x, expert_params)

    if "shared" in params:
        y = y + swiglu(params["shared"], x)
    return y, aux
