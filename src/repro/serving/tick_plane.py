"""Tick-scan engine plane: the engine rung as ONE jitted ``lax.scan``.

PR 9's ``engine_plane`` closed the truth ladder's third rung with a
host-side discrete-event replay of the real continuous-batching
:class:`~.engine.Engine` — correct, but ~3 orders of magnitude slower
per frame than the batched GI/G/1 plane, so ``mode="engine"`` was capped
at smoke-sized frame budgets. This module is the batched, device-resident
equivalent: because the replay plane pins **one lane per stream**
(``n_lanes >= n_streams``), lanes never contend, and the whole DES —
admit/prefill, batched decode ticks, LCFSP preemption with version
invalidation, FCFS backlog, epoch-end drain, ``h_eff`` truncation —
collapses to per-lane recurrences that a single ``lax.scan`` over decode
ticks (one tick per frame index, all ``E*N`` lanes advanced together)
replays **bitwise-compatibly** with the DES:

  * identical pre-drawn T/O/coin streams (``stream_seed_sequence`` +
    ``oracle_samplers`` — shared via ``engine_plane.draw_streams``);
  * FCFS service start is the sequential ``max(a_k, fin_{k-1})``
    recurrence in float64 — the same op-for-op float chain the DES heap
    produces (NOT the cumsum/running-max algebraic form ``gi_g1_window``
    uses, which is only algebraically equal);
  * LCFSP completion wins time ties with the next arrival
    (``fin <= a_next``): the DES pushes the completion event before the
    arrival that could preempt it, so equal timestamps pop completion
    first. A preemption is counted iff the next arrival was actually
    scheduled (``a_k <= h_eff``) and strictly beats the finish;
  * the carried lane state (service-finish front, last-update time,
    sampled age, arrival/completion/accuracy/preempt counts, busy time)
    is exactly the DES bookkeeping, vectorized ``[E*N]``-wide; the scan
    *is* the version counter — a preempted finish simply never updates
    the carry, which is what invalidation does in the DES;
  * the age-area polynomial terms (``age0*seg`` and ``0.5*seg*seg``) are
    *emitted* per tick and summed on the host in DES event order rather
    than accumulated in the carry: XLA's CPU codegen contracts any
    multiply-feeding-add into an FMA inside a fused loop (1-2 ulp drift
    the DES's numpy arithmetic never sees, immune to
    ``optimization_barrier``), while a bare multiply rounds identically
    everywhere. Pure products on device, order-preserving sums on host
    => bitwise-identical ``aopi``.

Everything the DES counts inside the effective horizon is reproduced
bitwise (``aopi``/``n_frames``/``n_completed``/``n_accurate``/
``preempts`` and the (stream, frame, completion-time) trace — pinned by
``tests/test_engine_plane.py`` for all five delay families). What is
*not* replayed is the stub model's token arithmetic: the DES drives real
admits and decode dispatches, the scan reproduces their timing algebra.
Use the DES when lane bookkeeping itself is under test; use the scan
when the engine rung must run at full-suite scale.

``delay_samples`` for the fitted selector come straight off the host-side
pre-draws — zero extra device transfers; all per-stream outputs leave the
device in one ``device_get``.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import enable_x64

from .. import obs
from ..core import queues
from . import engine_plane

#: Engine-rung backend grammar (``AnalyticsService``, ``replay_tables``,
#: ``sweep`` ``engine_params={"backend": ...}``). "des" is the PR-9
#: host-side discrete-event replay of the real Engine; "scan" is this
#: module's batched device-resident replay; "auto" keeps the DES at
#: small scale (the real engine's lane bookkeeping stays exercised) and
#: switches to the scan once the epoch's frame volume would make the DES
#: the bottleneck.
ENGINE_BACKENDS = ("des", "scan", "auto")

#: "auto" keeps the DES while ``n_streams * frames_cap`` is at most this
#: many frame events per epoch (~a few hundred ms of host DES), and
#: switches to the tick-scan above it.
AUTO_DES_MAX_FRAMES = 4096


def resolve_engine_backend(backend: str, *, n_streams: int,
                           frames_cap: int) -> str:
    """Validate ``backend`` and resolve ``"auto"`` by epoch frame volume."""
    if backend not in ENGINE_BACKENDS:
        raise ValueError(
            f"unknown engine_backend {backend!r}; known: {ENGINE_BACKENDS}")
    if backend != "auto":
        return backend
    return ("des" if int(n_streams) * int(frames_cap) <= AUTO_DES_MAX_FRAMES
            else "scan")


def _tick_scan_impl(t_f, o_f, u_f, a, a_nxt, p, is_lcfsp, h_eff, live,
                    collect_trace=False):
    """One epoch of every lane as a single scan over decode ticks.

    All array args are float64 (bools for ``is_lcfsp``/``live``); the
    tick axis is leading on the ``[F, S]`` inputs, ``S = E*N`` lanes.
    Returns (out dict of ``[S]`` stats, optional ``[F, S]`` trace ys).
    """
    zero = jnp.zeros((), t_f.dtype)
    init = tuple(jnp.zeros(p.shape[0], t_f.dtype) for _ in range(8))

    def step(carry, x):
        fin_prev, last_t, age0, n_arr, n_done, n_acc, n_pre, busy = carry
        tk, ok, uk, ak, nk = x
        gen = ak - tk
        # FCFS seizes at arrival or queues behind the finish front;
        # LCFSP always seizes at arrival (preempting the front).
        start = jnp.where(is_lcfsp, ak, jnp.maximum(ak, fin_prev))
        fin = start + ok
        arrived = ak <= h_eff
        # LCFSP completion survives iff it beats the next arrival;
        # ties go to the completion (DES heap pushes it first). The
        # preempting arrival only exists if it was scheduled, i.e. the
        # current arrival was still inside the effective horizon.
        completed = jnp.where(is_lcfsp, fin <= nk, True)
        preempted = is_lcfsp & (fin > nk) & arrived
        done = completed & (fin <= h_eff) & live
        valid = done & (uk < p)
        seg = jnp.where(valid, fin - last_t, zero)
        # Age-area polynomial terms. Emitted as scan outputs — NOT
        # summed in the carry — so the device only performs the bare
        # multiplies (which round identically to numpy); the host sums
        # them in event order. An in-carry ``age0*seg + 0.5*seg*seg``
        # gets FMA-contracted by the CPU codegen and drifts 1-2 ulp off
        # the DES.
        t1 = age0 * seg
        t2 = 0.5 * seg * seg
        # Busy time (batch occupancy): service runs from its start to
        # finish — or to the preempting arrival under LCFSP — clipped
        # to the effective horizon.
        nxt_gate = jnp.where(arrived, nk, jnp.inf)
        end_s = jnp.where(is_lcfsp, jnp.minimum(fin, nxt_gate), fin)
        busy_seg = jnp.maximum(
            jnp.minimum(end_s, h_eff) - jnp.minimum(start, h_eff), zero)
        carry = (fin,
                 jnp.where(valid, fin, last_t),
                 jnp.where(valid, fin - gen, age0),
                 n_arr + arrived,
                 n_done + done,
                 n_acc + valid,
                 n_pre + preempted,
                 busy + busy_seg)
        ys = ((t1, t2, fin, done) if collect_trace else (t1, t2))
        return carry, ys

    carry, ys = lax.scan(step, init, (t_f, o_f, u_f, a, a_nxt))
    _, last_t, age0, n_arr, n_done, n_acc, n_pre, busy = carry
    safe_h = jnp.maximum(h_eff, 1e-12)
    out = {
        "n_frames": jnp.where(live, n_arr, zero),
        "n_completed": jnp.where(live, n_done, zero),
        "n_accurate": jnp.where(live, n_acc, zero),
        "preempts": jnp.where(live, n_pre, zero),
        "occupancy": jnp.where(live, busy / safe_h, zero),
    }
    return out, (last_t, age0), ys


_tick_scan = jax.jit(_tick_scan_impl, static_argnames=("collect_trace",))


def measure_engine_window_scan(lam, mu, p, pol, *, epoch_duration: float,
                               seed: int = 0, t0: int = 0,
                               delay_model: str = "mm1", active=None,
                               frames_cap: int =
                               engine_plane.ENGINE_FRAMES_CAP,
                               collect_samples: int = 0,
                               collect_trace: bool = False) -> dict:
    """Replay ``[E, N]`` engine epochs in ONE jitted scan dispatch.

    Each (epoch ``t0+e``, stream ``i``) lane replays the exact stochastic
    process the DES would run for that epoch (same
    ``stream_seed_sequence(seed, t0+e, i)`` pre-draws), all ``E*N`` lanes
    carried together. Returns the ``gi_g1_window``-shaped stat dict
    (``[E, N]`` values) plus ``preempts``/``occupancy`` ``[E, N]``,
    scalar ``engine_steps`` (scan ticks), optional ``delay_samples``
    ``[E, N, collect_samples]`` and, under ``collect_trace``, ``trace``:
    a list of ``(epoch, stream, frame, t_done)`` completion events in
    canonical ``(t_done, stream, frame)`` order per epoch.
    """
    queues.validate_delay_model(delay_model)
    lam = np.atleast_2d(np.asarray(lam, np.float64))
    mu = np.atleast_2d(np.asarray(mu, np.float64))
    p = np.clip(np.atleast_2d(np.asarray(p, np.float64)), 1e-3, 1.0)
    pol = np.atleast_2d(np.asarray(pol, np.int64))
    e, n = lam.shape
    live = (lam > 0.0) & (mu > 0.0)
    if active is not None:
        live = live & (np.atleast_2d(np.asarray(active, np.float64)) > 0.0)
    f = int(frames_cap)
    s = e * n
    T = np.zeros((s, f))
    O = np.zeros((s, f))
    coin = np.ones((s, f))
    for ei in range(e):
        Te, Oe, Ce = engine_plane.draw_streams(
            lam[ei], mu[ei], live[ei], delay_model=delay_model,
            seed=seed, t=t0 + ei, frames_cap=f)
        T[ei * n:(ei + 1) * n] = Te
        O[ei * n:(ei + 1) * n] = Oe
        coin[ei * n:(ei + 1) * n] = Ce
    arrive = np.cumsum(T, axis=1)                 # a_k; gen_k = a_k - T_k
    live_f = live.ravel()
    h_eff = np.where(live_f, np.minimum(float(epoch_duration),
                                        arrive[:, -1]), 0.0)
    a_nxt = np.concatenate([arrive[:, 1:], np.full((s, 1), np.inf)], axis=1)

    with obs.span("tick_plane.window", delay_model=delay_model,
                  epochs=e, streams=n, n_frames=f), enable_x64():
        out, fin_state, ys = _tick_scan(
            jnp.asarray(T.T), jnp.asarray(O.T), jnp.asarray(coin.T),
            jnp.asarray(arrive.T), jnp.asarray(a_nxt.T),
            jnp.asarray(p.ravel()), jnp.asarray(pol.ravel() == 1),
            jnp.asarray(h_eff), jnp.asarray(live_f),
            collect_trace=collect_trace)
        # One transfer per window: stats + final lane state + tick ys.
        out, (last_t, age0), ys = jax.device_get((out, fin_state, ys))

    # Order-preserving age-area reduction (see module docstring): the
    # device emits the exact products per tick, the host adds them in
    # the DES's event order — bitwise identical to the heap replay.
    t1, t2 = np.asarray(ys[0]), np.asarray(ys[1])     # [F, S]
    area = np.zeros(s)
    for k in range(f):
        area += t1[k] + t2[k]
    seg = np.maximum(h_eff - last_t, 0.0)             # DES drain point
    area += age0 * seg + 0.5 * seg * seg
    safe_h = np.maximum(h_eff, 1e-12)
    out["aopi"] = np.where(live_f, area / safe_h, 0.0)

    occ = out["occupancy"][live_f]
    out = {k: np.asarray(v, np.float64).reshape(e, n)
           for k, v in out.items()}
    out["horizon"] = h_eff.reshape(e, n)
    out["engine_steps"] = float(f)
    if collect_samples:
        cap = min(int(collect_samples), f)
        out["delay_samples"] = np.where(
            live_f[:, None], T[:, :cap], 0.0).reshape(e, n, cap)
    if collect_trace:
        fin, done = np.asarray(ys[2]), np.asarray(ys[3])   # [F, S]
        kk, ss = np.nonzero(done)
        ev = zip((ss // n).tolist(), (ss % n).tolist(), kk.tolist(),
                 fin[kk, ss].tolist())
        out["trace"] = sorted(ev, key=lambda r: (r[0], r[3], r[1], r[2]))
    obs.counter("engine.ticks", backend="scan",
                delay_model=delay_model).inc(float(f))
    obs.counter("engine.preempts", backend="scan").inc(
        float(out["preempts"].sum()))
    if occ.size:
        obs.histogram("engine.occupancy", backend="scan").observe_many(occ)
    return out


def measure_engine_epoch_scan(lam, mu, p, pol, *, epoch_duration: float,
                              seed: int = 0, t: int = 0,
                              delay_model: str = "mm1", active=None,
                              frames_cap: int =
                              engine_plane.ENGINE_FRAMES_CAP,
                              collect_samples: int = 0,
                              collect_trace: bool = False) -> dict:
    """Single-epoch tick-scan replay: the drop-in batched equivalent of
    ``engine_plane.measure_engine_epoch`` (same ``[N]`` stat dict, same
    draws, bitwise-identical counted statistics — no Engine instance
    required)."""
    out = measure_engine_window_scan(
        np.asarray(lam, np.float64).ravel()[None, :],
        np.asarray(mu, np.float64).ravel()[None, :],
        np.asarray(p, np.float64).ravel()[None, :],
        np.asarray(pol, np.int64).ravel()[None, :],
        epoch_duration=epoch_duration, seed=seed, t0=t,
        delay_model=delay_model,
        active=None if active is None
        else np.asarray(active, np.float64).ravel()[None, :],
        frames_cap=frames_cap, collect_samples=collect_samples,
        collect_trace=collect_trace)
    trace = out.pop("trace", None)
    steps = out.pop("engine_steps")
    out = {k: v[0] for k, v in out.items()}
    out["engine_steps"] = steps
    if trace is not None:
        out["trace"] = [(i, k, td) for _, i, k, td in trace]
    return out


def measure_epoch(lam, mu, p, pol, *, backend: str = "auto", engine=None,
                  frames_cap: int = engine_plane.ENGINE_FRAMES_CAP,
                  **kw) -> dict:
    """Backend-dispatching engine-rung epoch measurement.

    Resolves ``backend`` (``ENGINE_BACKENDS``) against the epoch's frame
    volume and runs either the DES replay on ``engine`` (required for
    ``"des"``) or the tick-scan. Both return the same stat dict over the
    same pre-drawn stochastic process.
    """
    n = np.asarray(lam).ravel().size
    resolved = resolve_engine_backend(backend, n_streams=n,
                                      frames_cap=frames_cap)
    if resolved == "scan":
        return measure_engine_epoch_scan(lam, mu, p, pol,
                                         frames_cap=frames_cap, **kw)
    if engine is None:
        raise ValueError("engine_backend 'des' needs an Engine instance "
                         "(make_replay_engine)")
    return engine_plane.measure_engine_epoch(engine, lam, mu, p, pol,
                                             frames_cap=frames_cap, **kw)
