"""Per-stream frame queues (FCFS / LCFSP) + online AoPI tracking.

This is the paper's computation-policy layer mapped onto a serving
scheduler: each stream (camera) owns a frame queue; under FCFS frames are
processed in arrival order, under LCFSP a newly-arrived frame *preempts*
the stream's in-flight frame at the next step boundary (TPUs cannot abort
an MXU op mid-flight — preemption granularity is one engine step, the
assumption change recorded in DESIGN.md §2).

``AoPITracker`` integrates the exact piecewise-linear age curve online —
the measured counterpart of Theorems 1-2, compared against the closed forms
in tests/test_serving.py and examples/serve_e2e.py.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import numpy as np

FCFS, LCFSP = 0, 1


@dataclasses.dataclass
class StreamTelemetry:
    """Measured per-stream data-plane rates for one epoch.

    This is what re-enters ``HorizonTables`` for the next planning window
    (``AnalyticsService``): the planner's profiled accuracy and link
    efficiency are multiplicatively corrected toward what the data plane
    actually delivered (Chameleon/AWStream-style profile-then-measure
    adaptation).
    """
    acc_hat: np.ndarray      # accurate fraction among completed frames
    lam_hat: np.ndarray      # measured frame arrival rate (frames/s)
    mu_hat: np.ndarray       # measured frame completion rate (frames/s)
    n_frames: np.ndarray     # frames offered to each stream's queue
    n_completed: np.ndarray  # frames whose result was delivered
    aopi_hat: np.ndarray = None  # measured per-stream AoPI over the epoch
    #: Raw per-stream transmission-delay draws [streams, cap] (zero-padded;
    #: only set when the service runs the fitted delay-model selector).
    delay_samples: Optional[np.ndarray] = None

    @staticmethod
    def empty(n_streams: int) -> "StreamTelemetry":
        z = np.zeros(n_streams)
        return StreamTelemetry(z.copy(), z.copy(), z.copy(),
                               z.copy(), z.copy(), z.copy())


@dataclasses.dataclass
class Frame:
    stream_id: int
    gen_time: float            # capture instant at the camera
    arrive_time: float         # transmission finished (enters the queue)
    tokens: int = 64           # payload size (resolution analog)
    seq: int = 0


class StreamQueue:
    """One camera's frame queue with the slot's computation policy."""

    def __init__(self, stream_id: int, policy: int = FCFS):
        self.stream_id = stream_id
        self.policy = policy
        self.pending: deque = deque()
        self.preempt_requested = False

    def on_arrival(self, frame: Frame) -> bool:
        """Returns True if the scheduler must preempt this stream's
        in-flight frame (LCFSP semantics)."""
        if self.policy == LCFSP:
            self.pending.clear()
            self.pending.append(frame)
            self.preempt_requested = True
            return True
        self.pending.append(frame)
        return False

    def pop(self) -> Optional[Frame]:
        self.preempt_requested = False
        return self.pending.popleft() if self.pending else None

    def __len__(self):
        return len(self.pending)


class AoPITracker:
    """Exact online integration of the AoPI curve per stream."""

    def __init__(self, n_streams: int, t0: float = 0.0):
        self.last_acc_gen = [t0] * n_streams   # virtual accurate frame at 0
        self.area = [0.0] * n_streams
        self.last_t = [t0] * n_streams
        self.t0 = t0

    def _advance(self, s: int, t: float):
        dt = t - self.last_t[s]
        if dt > 0:
            a0 = self.last_t[s] - self.last_acc_gen[s]
            self.area[s] += a0 * dt + 0.5 * dt * dt
            self.last_t[s] = t

    def on_result(self, s: int, gen_time: float, accurate: bool,
                  t_done: float):
        self._advance(s, t_done)
        if accurate and gen_time > self.last_acc_gen[s]:
            self.last_acc_gen[s] = gen_time

    def mean_aopi(self, s: int, t_now: float) -> float:
        self._advance(s, t_now)
        horizon = t_now - self.t0
        return self.area[s] / max(horizon, 1e-12)

    def overall(self, t_now: float) -> float:
        vals = [self.mean_aopi(s, t_now) for s in range(len(self.area))]
        return sum(vals) / len(vals)
