"""The AoPI-tracked analytics service: LBCD in the serving control plane.

Per controller epoch (= the paper's 5-minute slot):
  1. LBCD solves (P2) from live telemetry -> per-stream (model candidate,
     fidelity/resolution, FCFS/LCFSP policy, island assignment, ingest +
     compute-share allocation);
  2. the data plane runs: frames arrive per the transmission model, are
     queued per-policy, and processed with the allocated compute rate;
  3. measured AoPI (exact age integration) and accuracy feed the virtual
     queue and the next epoch's profiles.

Two data planes ship:
  * ``mode="mm1"``  — event-driven M/M/1 execution (the paper's model;
    validates Theorems 1-2 at scale, used by benchmarks);
  * ``mode="engine"`` — a real continuous-batching Engine on a small model
    (examples/serve_e2e.py), with LCFSP preemption at step boundaries.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from ..core import queues
from ..core.lbcd import LBCDController
from .scheduler import AoPITracker, Frame, StreamQueue


@dataclasses.dataclass
class EpochReport:
    t: int
    predicted_aopi: float       # closed-form, from the controller
    measured_aopi: float        # data-plane measurement
    accuracy: float
    q: float
    per_stream_measured: np.ndarray
    per_stream_predicted: np.ndarray


class AnalyticsService:
    def __init__(self, controller: LBCDController, *, mode: str = "mm1",
                 epoch_duration: float = 300.0, engine=None,
                 frames_cap: int = 200_000, seed: int = 0):
        self.controller = controller
        self.mode = mode
        self.engine = engine
        self.epoch_duration = epoch_duration
        self.frames_cap = frames_cap
        self.seed = seed
        self.reports: list = []

    def run_epoch(self, t: int) -> EpochReport:
        rec = self.controller.step(t)
        dec = rec.decision
        n = len(dec.lam)
        measured = np.zeros(n)
        if self.mode == "mm1":
            for i in range(n):
                lam = max(float(dec.lam[i]), 1e-6)
                n_frames = int(min(lam * self.epoch_duration,
                                   self.frames_cap))
                n_frames = max(n_frames, 200)
                sim = queues.simulate(
                    lam, max(float(dec.mu[i]), 1e-6),
                    float(np.clip(dec.acc[i], 1e-3, 1.0)),
                    int(dec.pol[i]), n_frames=n_frames,
                    seed=self.seed + 7919 * t + i)
                measured[i] = sim.mean_aopi
        else:
            measured = self._run_engine_epoch(rec)
        rep = EpochReport(
            t=t, predicted_aopi=float(np.mean(dec.aopi)),
            measured_aopi=float(np.mean(measured)),
            accuracy=float(np.mean(dec.acc)), q=rec.q,
            per_stream_measured=measured,
            per_stream_predicted=np.asarray(dec.aopi))
        self.reports.append(rep)
        return rep

    # ------------------------------------------------------------------
    def _run_engine_epoch(self, rec) -> np.ndarray:
        """Real-engine data plane (small scale; examples/serve_e2e.py)."""
        assert self.engine is not None
        dec = rec.decision
        n = len(dec.lam)
        rng = np.random.default_rng(self.seed + 7919 * rec.t)
        tracker = AoPITracker(n)
        qs = [StreamQueue(i, int(dec.pol[i])) for i in range(n)]
        # Frame arrival times per stream (exponential inter-arrivals).
        events = []
        for i in range(n):
            lam = max(float(dec.lam[i]), 1e-6)
            k = max(int(lam * self.epoch_duration), 1)
            gaps = rng.exponential(1.0 / lam, size=k)
            ts = np.cumsum(gaps)
            gen = np.concatenate(([0.0], ts))[:-1]
            for g_t, a_t in zip(gen, ts):
                if a_t < self.epoch_duration:
                    events.append(Frame(i, g_t, a_t))
        events.sort(key=lambda f: f.arrive_time)
        step_time = self.epoch_duration / max(
            len(events) * self.engine.decode_tokens, 1)
        now, ei = 0.0, 0
        while now < self.epoch_duration:
            while ei < len(events) and events[ei].arrive_time <= now:
                f = events[ei]
                if qs[f.stream_id].on_arrival(f):
                    self.engine.preempt_stream(f.stream_id)
                ei += 1
            for q in qs:
                while len(q) and self.engine.free_lanes():
                    f = q.pop()
                    toks = rng.integers(
                        2, 200, size=f.tokens).astype(np.int32)
                    self.engine.admit(f, toks)
            for res in self.engine.decode_tick():
                p = float(np.clip(dec.acc[res.stream_id], 1e-3, 1.0))
                acc = bool(rng.random() < p)
                tracker.on_result(res.stream_id, res.frame.gen_time, acc,
                                  now)
            now += step_time
        return np.array([tracker.mean_aopi(i, self.epoch_duration)
                         for i in range(n)])

    def run(self, n_epochs: int):
        return [self.run_epoch(t) for t in range(n_epochs)]

    @property
    def mean_measured(self) -> float:
        return float(np.mean([r.measured_aopi for r in self.reports]))

    @property
    def mean_predicted(self) -> float:
        return float(np.mean([r.predicted_aopi for r in self.reports]))
