"""The AoPI-tracked analytics service: LBCD in the serving control plane.

Per controller epoch (= the paper's 5-minute slot):
  1. the *planner* decides per-stream (model candidate, fidelity/resolution,
     FCFS/LCFSP policy, island assignment, ingest + compute-share
     allocation) by solving (P2);
  2. the data plane runs: frames arrive per the transmission model, are
     queued per-policy, and processed with the allocated compute rate;
  3. measured AoPI (exact age integration) and per-stream telemetry
     (accurate fraction, arrival/completion rates) feed the virtual queue
     and the next planning window's profiles.

Two planners:
  * ``planner="scan"`` (default) — lookahead windows of ``plan_window``
    epochs are solved as ONE jitted ``lax.scan`` (``lbcd.rollout`` for the
    LBCD controller, the ``baselines.rollout_*`` engines for MIN/DOS/JCAB)
    over a ``profiles.HorizonTables`` window; ``plan_horizon(k)`` exposes
    the same call for what-if queries. ``solver_backend`` (including
    ``"auto"``/``"pallas"`` and spec strings like ``"pallas:tile=4096"``
    or ``"pallas:nofuse"``) threads through from the controller, so
    kernel-backed replay rides the fused — and, at large N, camera-tiled
    — slot solver.
  * ``planner="step"`` — the legacy per-slot ``controller.step(t)`` path
    (kept for custom ``assign_fn`` controllers and failover experiments).

Two data planes ship:
  * ``mode="mm1"``  — the batched device-resident GI/G/1 engine
    (``queues.gi_g1_window``): every stream of a whole plan window is
    simulated in ONE jitted dispatch shaped ``[E, N, F]``, with
    ``delay_model`` selecting exponential ("mm1", the paper's model that
    validates Theorems 1-2 at scale), uniform, or gamma delays (the
    §III-B testbed regime where the closed forms drift). The plane
    executes against the *unscaled* scenario truth: measured accuracy
    uses the raw profile table and the true link efficiency, while the
    planner sees the telemetry-corrected beliefs — exactly the
    model-vs-measurement split where config-adaptation policies break.
    ``replan_threshold`` arms divergence-triggered replanning: a mid-
    window drift past the threshold cuts the window and replans early.
  * ``mode="engine"`` — a real continuous-batching Engine on a small model
    (examples/serve_e2e.py), with LCFSP preemption at step boundaries.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .. import faults as fault_plane
from .. import obs
from ..core import baselines, binpack, lbcd, queues
from ..core.lbcd import LBCDController
from ..core.profiles import HorizonTables
from .scheduler import AoPITracker, Frame, StreamQueue, StreamTelemetry


def _policy_label(controller) -> str:
    """Metric/span ``policy`` label for a controller (the sweep names
    where recognizable, the class name otherwise)."""
    names = {"LBCDController": "lbcd", "MINController": "min",
             "DOSController": "dos", "JCABController": "jcab"}
    cls = type(controller).__name__
    return names.get(cls, cls.lower())


#: Element budget (epochs x streams x frames) of one batched data-plane
#: dispatch; larger windows are chunked along the epoch axis so peak
#: device memory stays bounded (~a few hundred MB of f64 intermediates).
MAX_BATCH_ELEMS = 1 << 25


def measure_window(lam, mu, p, pol, *, epoch_duration: float = 300.0,
                   frames_cap: int = 200_000, frames_floor: int = 200,
                   seed: int = 0, t0: int = 0, delay_model: str = "mm1",
                   collect_samples: int = 0
                   ) -> tuple[np.ndarray, list[StreamTelemetry]]:
    """Measure epochs ``[t0, t0+E)`` of an N-stream data plane in ONE
    batched device dispatch (``queues.gi_g1_window``; chunked along the
    epoch axis only past ``MAX_BATCH_ELEMS``).

    ``lam``/``mu``/``p``/``pol`` are ``[E, N]``: per stream, ``delay_model``
    transmissions with mean ``1/lam[e, i]``, service with mean
    ``1/mu[e, i]``, Bernoulli(``p[e, i]``) recognition, FCFS/LCFSP per
    ``pol[e, i]`` — the frame-uploading model of §III-A, generalized to
    the GI/G/1 delay families of ``queues.DELAY_MODELS``. Deterministic in
    ``(seed, t, i)`` via collision-free folded keys; age integration is
    truncated at ``epoch_duration`` so measured AoPI reflects the epoch
    even for low-rate streams padded up to the frame floor.

    Returns ``(measured_aopi[E, N], [StreamTelemetry] * E)``.
    """
    lam = np.atleast_2d(np.asarray(lam, np.float64))
    mu = np.atleast_2d(np.asarray(mu, np.float64))
    p = np.atleast_2d(np.asarray(p, np.float64))
    pol = np.atleast_2d(np.asarray(pol))
    n_epochs, n = lam.shape
    horizon = float(epoch_duration)
    n_frames = queues.frames_budget(max(lam.max(), 1e-6), horizon,
                                    frames_cap, frames_floor)
    e_chunk = max(int(MAX_BATCH_ELEMS // max(n * n_frames, 1)), 1)
    measured = np.zeros((n_epochs, n))
    tels: list[StreamTelemetry] = []
    for e0 in range(0, n_epochs, e_chunk):
        e1 = min(e0 + e_chunk, n_epochs)
        out = queues.gi_g1_window(
            lam[e0:e1], mu[e0:e1], p[e0:e1], pol[e0:e1],
            seed=seed, t0=t0 + e0, n_frames=n_frames, horizon=horizon,
            delay_model=delay_model, collect_samples=collect_samples)
        measured[e0:e1] = out["aopi"]
        samples = out.get("delay_samples")
        for j in range(e1 - e0):
            h_eff = np.maximum(out["horizon"][j], 1e-9)
            tels.append(StreamTelemetry(
                acc_hat=out["n_accurate"][j] /
                np.maximum(out["n_completed"][j], 1),
                lam_hat=out["n_frames"][j] / h_eff,
                mu_hat=out["n_completed"][j] / h_eff,
                n_frames=out["n_frames"][j].astype(np.float64),
                n_completed=out["n_completed"][j].astype(np.float64),
                aopi_hat=out["aopi"][j].copy(),
                delay_samples=(None if samples is None
                               else samples[j])))
    return measured, tels


def measure_mm1(lam, mu, p, pol, *, epoch_duration: float = 300.0,
                frames_cap: int = 200_000, frames_floor: int = 200,
                seed: int = 0, t: int = 0, delay_model: str = "mm1"
                ) -> tuple[np.ndarray, StreamTelemetry]:
    """One epoch of the event-driven data plane for N streams — a single
    batched device dispatch (see :func:`measure_window`; the historical
    name survives because "mm1" is still the default delay family).

    Returns ``(measured_aopi[N], StreamTelemetry)``.
    """
    lam = np.asarray(lam, np.float64)
    measured, tels = measure_window(
        lam[None], np.asarray(mu, np.float64)[None],
        np.asarray(p, np.float64)[None], np.asarray(pol)[None],
        epoch_duration=epoch_duration, frames_cap=frames_cap,
        frames_floor=frames_floor, seed=seed, t0=t,
        delay_model=delay_model)
    return measured[0], tels[0]


def measure_mm1_loop(lam, mu, p, pol, *, epoch_duration: float = 300.0,
                     frames_cap: int = 200_000, frames_floor: int = 200,
                     seed: int = 0, t: int = 0, delay_model: str = "mm1"
                     ) -> tuple[np.ndarray, StreamTelemetry]:
    """The PR-4 per-stream numpy loop — kept as the parity reference for
    the batched engine (``tests/test_dataplane.py``) and the baseline of
    ``benchmarks/bench_dataplane.py``. Seeded with collision-free
    ``SeedSequence(entropy=seed, spawn_key=(t, i))`` streams (the old
    ``seed + 7919*t + i`` arithmetic collided across (t, i) pairs). Note
    the loop integrates age over the *simulated* horizon (the historical
    semantics), not the truncated epoch."""
    lam = np.asarray(lam, np.float64)
    mu = np.asarray(mu, np.float64)
    p = np.asarray(p, np.float64)
    pol = np.asarray(pol)
    n = len(lam)
    measured = np.zeros(n)
    tel = StreamTelemetry.empty(n)
    for i in range(n):
        lam_i = max(float(lam[i]), 1e-6)
        mu_i = max(float(mu[i]), 1e-6)
        n_frames = int(min(lam_i * epoch_duration, frames_cap))
        n_frames = max(n_frames, frames_floor)
        samplers = queues.oracle_samplers(delay_model, lam_i, mu_i)
        sim = queues.simulate(
            lam_i, mu_i, float(np.clip(p[i], 1e-3, 1.0)),
            int(pol[i]), n_frames=n_frames,
            seed=queues.stream_seed_sequence(seed, t, i), **samplers)
        measured[i] = sim.mean_aopi
        horizon = max(sim.horizon, 1e-9)
        tel.acc_hat[i] = sim.n_accurate / max(sim.n_completed, 1)
        tel.lam_hat[i] = sim.n_frames / horizon
        tel.mu_hat[i] = sim.n_completed / horizon
        tel.n_frames[i] = sim.n_frames
        tel.n_completed[i] = sim.n_completed
        tel.aopi_hat[i] = sim.mean_aopi
    return measured, tel


@dataclasses.dataclass
class EpochReport:
    t: int
    predicted_aopi: float       # closed-form, from the planner
    measured_aopi: float        # data-plane measurement
    accuracy: float
    q: float
    per_stream_measured: np.ndarray
    per_stream_predicted: np.ndarray
    telemetry: Optional[StreamTelemetry] = None
    # Engine mode only: the rung-2 GI/G/1 measurement of the same epoch
    # (measured_aopi is then the rung-3 engine measurement), so one run
    # yields all three truth-ladder rungs.
    model_aopi: Optional[float] = None
    per_stream_model: Optional[np.ndarray] = None
    #: Family the fitted selector chose for this epoch (delay_model="auto").
    fitted_model: Optional[str] = None
    #: Its fitted shape parameters (sigma/k), when the winner has any.
    fitted_params: Optional[dict] = None


class AnalyticsService:
    def __init__(self, controller, *, mode: str = "mm1",
                 epoch_duration: float = 300.0, engine=None,
                 frames_cap: int = 200_000, seed: int = 0,
                 planner: str = "scan", plan_window: int = 8,
                 tables: HorizonTables | None = None,
                 telemetry_gain: float = 0.0,
                 delay_model: str = "mm1",
                 true_delay_model: str | None = None,
                 engine_frames_cap: int | None = None,
                 engine_backend: str = "auto",
                 replan_threshold: float | None = None,
                 faults: "fault_plane.FaultPlan | None" = None,
                 plan_retries: int = 2,
                 retry_backoff: float = 0.0,
                 plan_deadline: float | None = None):
        """``controller`` is an ``LBCDController`` or one of the
        ``baselines`` controllers (anything with ``step(t)`` and either
        ``plan(tables)`` or ``_rollout(tables)``).

        ``tables`` replays a prebuilt horizon (e.g. a ``repro.scenarios``
        build) instead of the controller's live ``EdgeSystem``;
        ``telemetry_gain`` > 0 lets measured accuracy / arrival rates /
        AoPI correct the next planning window's beliefs (EWMA weight).
        ``delay_model`` selects the data plane's delay family
        (``queues.DELAY_MODELS``; "mm1" keeps the paper's exponential
        model, "uniform"/"gamma" the lighter-tailed §III-B testbed
        regime, "lognormal"/"weibull" the heavy-tail regime) — or
        ``"auto"``, which fits the family from observed transmission
        delays each epoch (``queues.fit_delay_model``) and uses the
        fitted label for observability and, in engine mode, for the
        GI/G/1 model rung. ``true_delay_model`` pins the *generating*
        family of the plane (the world); it defaults to ``delay_model``
        when that is concrete, to "mm1" under "auto". ``replan_threshold``
        (relative
        measured-vs-predicted divergence, e.g. 0.1) arms
        divergence-triggered replanning: when an epoch's divergence
        crosses it mid-window, the remaining plan window is cut and
        ``plan_horizon`` re-runs from the next epoch with fresh telemetry
        instead of waiting for the fixed ``plan_window`` boundary.

        ``engine_backend`` selects the engine-rung measurement plane in
        ``mode="engine"`` (``tick_plane.ENGINE_BACKENDS``): "des" replays
        the real continuous-batching Engine event by event, "scan" runs
        the bitwise-compatible batched tick-scan (no Engine instance
        needed, full-suite frame budgets), "auto" — the default — keeps
        the DES at smoke scale and switches to the scan above
        ``tick_plane.AUTO_DES_MAX_FRAMES`` frame events per epoch.
        ``engine_frames_cap`` defaults per backend: the DES keeps the
        smoke-sized ``engine_plane.ENGINE_FRAMES_CAP``; the scan gets
        ``frames_cap`` (GI/G/1-rung parity) — either way the effective
        per-epoch budget passes through ``queues.frames_budget``.

        ``faults`` (a :class:`repro.faults.FaultPlan`) arms the service's
        *behavioral* fault injections — telemetry drops/delays/corruption
        gate the EWMA filter, and ``solver_*`` kinds drive the graceful-
        degradation ladder on the scan planner: each planning attempt gets
        ``plan_retries`` retries (exponential ``retry_backoff`` sleep, a
        ``plan_deadline``-second watchdog); exhausted retries fall back to
        the last good plan re-projected onto the surviving fleet, then to
        a MIN-baseline plan. Structural faults (churn, capacity) must be
        baked into ``tables`` first via ``faults.apply_plan``.
        ``faults=None`` is the bitwise no-op path.
        """
        if planner not in ("scan", "step"):
            raise ValueError(f"unknown planner {planner!r}; "
                             "known: ('scan', 'step')")
        if mode not in ("mm1", "engine"):
            raise ValueError(f"unknown mode {mode!r}; "
                             "known: ('mm1', 'engine')")
        queues.validate_delay_model(delay_model, allow_auto=True)
        if true_delay_model is None:
            true_delay_model = (delay_model
                                if delay_model != queues.AUTO_DELAY_MODEL
                                else "mm1")
        queues.validate_delay_model(true_delay_model)
        # Scan planning needs a whole-horizon engine on the controller AND
        # a horizon source (replay tables, or a system that can pregenerate
        # one); duck-typed systems exposing only capacities(t)/tables(t)
        # keep the legacy per-slot path.
        if planner == "scan" and not (
                self._supports_scan(controller) and
                (tables is not None or
                 hasattr(controller.system, "horizon"))):
            planner = "step"
        self.controller = controller
        self.mode = mode
        self.engine = engine
        self.epoch_duration = epoch_duration
        self.frames_cap = frames_cap
        self.seed = seed
        self.planner = planner
        self.plan_window = max(int(plan_window), 1)
        self.tables = tables
        self.telemetry_gain = float(telemetry_gain)
        self.delay_model = delay_model
        self.true_delay_model = true_delay_model
        self._auto = delay_model == queues.AUTO_DELAY_MODEL
        self._fitted_model: str | None = None
        self._fitted_params: dict = {}       # winner's shape, e.g. sigma/k
        self.fitted_models: list[tuple[int, str]] = []  # (t, fitted family)
        self._delay_buf: list[np.ndarray] = []  # unit-mean pooled samples
        self.replan_threshold = (None if replan_threshold is None
                                 else float(replan_threshold))
        self.reports: list = []
        # Legacy list attributes (kept for API compatibility); the same
        # series also flow through the obs registry/trace stream — the
        # counters and the lists are written by the same statements, so
        # they reconcile exactly (tests/test_obs.py pins this).
        self.divergences: list[float] = []   # per-epoch measured/pred - 1
        self.early_replans: list[int] = []   # epochs where a window was cut
        self.fallbacks: list[tuple[int, str]] = []   # (t, ladder rung)
        self.degraded_epochs: list[int] = []  # epochs run on a fallback plan
        self.telemetry_gaps: list[int] = []   # epochs whose telemetry held
        self.plan_failures: list[tuple[int, int, str]] = []  # (t, attempt, err)
        self.faults = faults
        self.plan_retries = max(int(plan_retries), 0)
        self.retry_backoff = float(retry_backoff)
        self.plan_deadline = (None if plan_deadline is None
                              else float(plan_deadline))
        self._policy = _policy_label(controller)
        self._replan_pending = False         # next plan is an early replan
        self._plan_degraded: str | None = None  # ladder rung of current plan
        self._last_plan = None               # last validated plan (stale src)
        self._gap_streak = 0                 # consecutive telemetry gaps
        self._delayed_tel: dict = {}         # arrival epoch -> [(dec, tel)]
        n = self._n_streams()
        self._acc_scale = np.ones(n)
        self._eff_scale = np.ones(n)
        self._aopi_scale = np.ones(n)        # measured/closed-form residual
        self._base_cache: HorizonTables | None = tables
        self._plan = None
        self._plan_t0 = 0
        self._plan_meas = None               # window-batched measurements
        from . import engine_plane, tick_plane
        # Resolve "auto" against the DES-sized budget (the question auto
        # answers is "is the event-by-event DES still affordable here?"),
        # then default the cap per backend: DES keeps the smoke-sized
        # ENGINE_FRAMES_CAP, the scan runs at GI/G/1-rung parity.
        des_cap = int(engine_plane.ENGINE_FRAMES_CAP
                      if engine_frames_cap is None else engine_frames_cap)
        self.engine_backend = tick_plane.resolve_engine_backend(
            engine_backend, n_streams=n, frames_cap=des_cap)
        if engine_frames_cap is None and self.engine_backend == "scan":
            self.engine_frames_cap = int(frames_cap)
        else:
            self.engine_frames_cap = des_cap
        if (self.mode == "engine" and self.engine is None
                and self.engine_backend == "des"):
            # Replay-grade default: the deterministic stub-model engine
            # with one lane per stream (see engine_plane). The scan
            # backend needs no Engine instance at all.
            from .engine import make_replay_engine
            self.engine = make_replay_engine(n, seed=seed)

    # ------------------------------------------------------------------
    # Planner: lookahead windows as one jitted scan
    # ------------------------------------------------------------------
    @staticmethod
    def _supports_scan(controller) -> bool:
        if isinstance(controller, LBCDController):
            # The scan engine is specialized to first-fit placement.
            return controller.assign_fn is binpack.first_fit
        # A _rollout *override* — the abstract BaselineController._rollout
        # raises NotImplementedError, so step()-only controllers must fall
        # back to the legacy planner.
        rollout = getattr(type(controller), "_rollout", None)
        return (rollout is not None and
                rollout is not baselines.BaselineController._rollout)

    def _n_streams(self) -> int:
        if self.tables is not None:
            return self.tables.n_cameras
        return self.controller.system.n_cameras

    def _base_window(self, t0: int, t1: int) -> HorizonTables:
        """Slots [t0, t1) of the *uncorrected* source horizon (the truth
        the data plane executes against)."""
        if self._base_cache is None or self._base_cache.n_slots < t1:
            # EdgeSystem.horizon is deterministic and prefix-stable in
            # n_slots, so growing the cache never changes earlier slots;
            # geometric growth keeps total generation work O(T) over a
            # long-running service. Bounded systems (TableSystem) reject
            # the over-request — retry with exactly what is needed.
            cur = 0 if self._base_cache is None else self._base_cache.n_slots
            try:
                self._base_cache = self.controller.system.horizon(
                    max(t1, 2 * cur))
            except ValueError:
                self._base_cache = self.controller.system.horizon(t1)
        return self._base_cache.window(t0, t1)

    def _window_tables(self, t0: int, t1: int) -> HorizonTables:
        """The planner's view: source horizon with the telemetry
        corrections (accuracy / link-efficiency scales) applied."""
        base = self._base_window(t0, t1)
        if self.telemetry_gain <= 0.0:
            return base
        acc = jnp.clip(
            base.acc * self._acc_scale[None, :, None, None], 1e-3, 1.0)
        scale = (self._eff_scale if base.eff.ndim == 1
                 else self._eff_scale[None, :])
        return dataclasses.replace(base, acc=acc, eff=base.eff * scale)

    def plan_horizon(self, k: int, t0: int = 0) -> lbcd.RolloutResult:
        """Plan epochs ``[t0, t0 + k)`` as ONE jitted ``lax.scan`` over the
        (telemetry-corrected) horizon window — no per-epoch Python loop.

        Pure lookahead: neither the controller's virtual queue nor the data
        plane advances; ``run_epoch`` commits epochs as they execute.
        """
        tables = self._window_tables(t0, t0 + k)
        ctrl = self.controller
        if isinstance(ctrl, LBCDController):
            return ctrl.plan(tables)
        return ctrl._rollout(tables)

    def _slot_record(self, t: int) -> lbcd.SlotRecord:
        if self.planner != "scan":
            with obs.span("service.plan_window", policy=self._policy,
                          reason="boundary", t0=t, k=1):
                return self.controller.step(t)
        if self._plan is None or not (
                self._plan_t0 <= t < self._plan_t0 + self._plan.q.shape[0]):
            k = self.plan_window
            if self.tables is not None:
                k = min(k, self.tables.n_slots - t)
            if k < 1:
                raise ValueError(
                    f"epoch {t} is past the replayed horizon of "
                    f"{self.tables.n_slots} slots")
            # The span covers dispatch AND materialization (np.asarray
            # blocks on the device work), so its duration is the honest
            # end-to-end planning latency; ``reason`` distinguishes
            # divergence-triggered early replans from window boundaries.
            reason = "early" if self._replan_pending else "boundary"
            self._replan_pending = False
            with obs.span("service.plan_window", policy=self._policy,
                          reason=reason, t0=t, k=k):
                self._plan = self._plan_with_ladder(t, k)
            self._plan_t0 = t
            self._plan_meas = None           # re-measure the new window
        j = t - self._plan_t0
        res = self._plan
        q = float(res.q[j])
        if isinstance(self.controller, LBCDController):
            self.controller.queue.q = q      # commit Eq. 44 for this epoch
        return lbcd.SlotRecord(
            t=t, aopi=res.aopi[j], acc=res.acc[j], q=q,
            assign=res.assign[j],
            decision=jax.tree.map(lambda x: x[j], res.decision))

    # ------------------------------------------------------------------
    # Graceful-degradation ladder (scan planner)
    # ------------------------------------------------------------------
    def _plan_attempt(self, t: int, k: int, attempt: int):
        """One planning attempt: consult the fault plan's solver
        injections, run the scan planner under the watchdog deadline, and
        validate the result (NaN anywhere in the plan is a failure — the
        ``solver_nan`` injection and genuine numerical poisoning take the
        same path)."""
        kind = (None if self.faults is None
                else self.faults.solver_fault(t, attempt))
        if kind == "solver_nonconverge":
            raise fault_plane.InjectedSolverFault("solver_nonconverge")
        start = time.perf_counter()
        plan = jax.tree.map(np.asarray, self.plan_horizon(k, t))
        elapsed = time.perf_counter() - start
        if kind == "solver_nan":
            plan = dataclasses.replace(
                plan, aopi=np.full_like(np.asarray(plan.aopi, float),
                                        np.nan))
        if kind == "solver_timeout":
            raise fault_plane.InjectedSolverFault("solver_timeout")
        if self.plan_deadline is not None and elapsed > self.plan_deadline:
            raise TimeoutError(
                f"plan window at t={t} took {elapsed:.3f}s "
                f"(deadline {self.plan_deadline:.3f}s)")
        for name in ("aopi", "q"):
            if np.isnan(np.asarray(getattr(plan, name), float)).any():
                raise FloatingPointError(f"plan.{name} contains NaN")
        for name in ("b", "c"):
            if np.isnan(np.asarray(getattr(plan.decision, name),
                                   float)).any():
                raise FloatingPointError(
                    f"plan.decision.{name} contains NaN")
        return plan

    def _plan_with_ladder(self, t: int, k: int):
        """Plan with retries, then degrade gracefully.

        Rungs: (1) up to ``plan_retries`` retries with exponential
        ``retry_backoff``; (2) the last good plan's final slot tiled over
        the window and re-projected onto the surviving fleet; (3) a fresh
        MIN-baseline plan on the current (telemetry-corrected) window.
        Each failed attempt and each fallback appends to the legacy list
        *and* emits the matching ``repro.obs`` event in the same block, so
        counters and lists reconcile exactly.
        """
        for attempt in range(self.plan_retries + 1):
            try:
                plan = self._plan_attempt(t, k, attempt)
                self._plan_degraded = None
                self._last_plan = plan
                return plan
            except Exception as e:  # noqa: BLE001 — every rung must engage
                err = f"{type(e).__name__}: {e}"
                self.plan_failures.append((t, attempt, err))
                obs.event("service.plan_retry", policy=self._policy,
                          t=t, attempt=attempt, error=err)
                if self.retry_backoff > 0.0 and attempt < self.plan_retries:
                    time.sleep(self.retry_backoff * (2.0 ** attempt))
        plan = self._stale_plan(t, k)
        reason = "stale_plan"
        if plan is None:
            plan = jax.tree.map(
                np.asarray,
                baselines.rollout_min(self._window_tables(t, t + k),
                                      solver_backend="jnp"))
            reason = "min_fallback"
        self.fallbacks.append((t, reason))
        obs.event("service.fallback", policy=self._policy, t=t,
                  reason=reason)
        self._plan_degraded = reason
        return plan

    def _stale_plan(self, t: int, k: int):
        """Rung 2: tile the last good plan's final slot over ``[t, t+k)``
        and re-project it onto the surviving fleet (zero every per-camera
        quantity of cameras that have since churned out — their bandwidth
        and compute shares are simply forfeited until the next good
        plan). Returns ``None`` when no good plan exists yet."""
        if self._last_plan is None:
            return None
        res = jax.tree.map(
            lambda x: np.repeat(np.asarray(x)[-1:], k, axis=0),
            self._last_plan)
        act = self._active_window(t, t + k)
        if act is not None:
            d = res.decision
            d = dataclasses.replace(
                d, b=d.b * act, c=d.c * act, lam=d.lam * act,
                mu=d.mu * act, acc=d.acc * act, aopi=d.aopi * act)
            res = dataclasses.replace(
                res, aopi=res.aopi * act, acc=res.acc * act, decision=d)
        return res

    def _active_window(self, t0: int, t1: int):
        """``[t1-t0, N]`` numpy fleet mask for the replayed horizon, or
        ``None`` when no churn mask is attached (the no-op path)."""
        if self.tables is None or self.tables.active is None:
            return None
        return np.asarray(self.tables.active[t0:t1], np.float64)

    def _active_at(self, t: int):
        act = self._active_window(t, t + 1)
        return None if act is None else act[0]

    # ------------------------------------------------------------------
    # Data plane
    # ------------------------------------------------------------------
    #: Per-stream delay samples surfaced per epoch / pooled for the fit.
    SAMPLE_CAP = 64
    SAMPLE_POOL = 8192

    def _obs_model(self) -> str:
        """The ``delay_model`` obs label: under "auto" it is the *fitted*
        per-window family (or the bare sentinel until enough samples)."""
        if self._auto:
            return self._fitted_model or queues.AUTO_DELAY_MODEL
        return self.delay_model

    def _measure_model(self) -> str:
        """Family the GI/G/1 *model* rung measures under in engine mode:
        the fitted family when the selector is armed (the EWMA-corrected
        planner then measures under what telemetry says the world is)."""
        if self._auto:
            return self._fitted_model or "mm1"
        return self.delay_model

    def _update_fit(self, t: int, tel: StreamTelemetry | None):
        """Fold this epoch's raw delay samples into the pooled buffer
        (per-stream mean-normalized so streams with different rates share
        one shape) and re-fit the family."""
        if not self._auto or tel is None or tel.delay_samples is None:
            return
        for row in np.asarray(tel.delay_samples, np.float64):
            row = row[row > 0.0]
            if row.size >= 4:
                self._delay_buf.append(row / row.mean())
        while (sum(a.size for a in self._delay_buf) > self.SAMPLE_POOL
               and len(self._delay_buf) > 1):
            self._delay_buf.pop(0)
        pooled = (np.concatenate(self._delay_buf) if self._delay_buf
                  else np.empty(0))
        fit = queues.fit_delay_model(pooled)
        if fit.residuals:                 # enough samples to trust
            changed = (fit.model != self._fitted_model
                       or dict(fit.params) != self._fitted_params)
            self._fitted_model = fit.model
            self._fitted_params = dict(fit.params)
            if changed:
                # Feed the fitted (family, shape) into the planner's
                # residual calibration, not just the labels: seed the
                # AoPI residual scale halfway toward the family's
                # Kingman prior (1 + cv^2)/2. Exactly 1 for mm1 — a
                # no-op when the world matches the paper's model — and
                # the telemetry EWMA keeps refining from there.
                prior = queues.residual_prior(fit.model, fit.params)
                self._aopi_scale = np.clip(
                    0.5 * (self._aopi_scale + prior), 0.25, 4.0)
        self.fitted_models.append((t, self._fitted_model or "mm1"))
        obs.event("service.delay_fit", policy=self._policy, t=t,
                  model=self._fitted_model or "unfit",
                  n_samples=fit.n_samples,
                  **{k: float(v) for k, v in fit.params.items()})

    def _plane_rates(self, t: int, dec) -> tuple[np.ndarray, np.ndarray]:
        """True arrival rate and accuracy of the chosen configs — from the
        *uncorrected* tables (the planner may be acting on telemetry-scaled
        beliefs; the plane executes against the world)."""
        n = len(dec.lam)
        r_idx = np.asarray(dec.r_idx)
        m_idx = np.asarray(dec.m_idx)
        try:
            base = self._base_window(t, t + 1)
        except AttributeError:
            # No horizon source (bare controller on a custom system) —
            # fall back to the planner's own beliefs. A ValueError (epoch
            # past a bounded horizon) propagates: that is a real misuse,
            # not a missing capability.
            return np.asarray(dec.lam), np.asarray(dec.acc)
        eff = np.asarray(base.eff if base.eff.ndim == 1 else base.eff[0])
        size = np.asarray(base.size)
        lam_true = np.asarray(dec.b) * eff / size[r_idx]
        p_true = np.asarray(base.acc[0])[np.arange(n), m_idx, r_idx]
        return lam_true, p_true

    def _plane_rates_window(self, t0: int, n_epochs: int,
                            dec) -> tuple[np.ndarray, np.ndarray]:
        """Vectorized ``_plane_rates`` for a whole plan window: ``dec``
        holds stacked ``[E, N]`` decision arrays."""
        n = dec.lam.shape[-1]
        r_idx = np.asarray(dec.r_idx)
        m_idx = np.asarray(dec.m_idx)
        try:
            base = self._base_window(t0, t0 + n_epochs)
        except AttributeError:
            return np.asarray(dec.lam), np.asarray(dec.acc)
        eff = np.asarray(base.eff)
        if eff.ndim == 1:
            eff = np.broadcast_to(eff, (n_epochs, n))
        size = np.asarray(base.size)
        lam_true = np.asarray(dec.b) * eff / size[r_idx]
        acc = np.asarray(base.acc)                       # [E, N, M, R]
        p_true = acc[np.arange(n_epochs)[:, None],
                     np.arange(n)[None, :], m_idx, r_idx]
        return lam_true, p_true

    def _measure_plan_window(self):
        """Measure every epoch of the current plan window in ONE batched
        device dispatch — the plane's inputs (planned configs + unscaled
        truth tables) are fully known the moment the window is planned."""
        res, t0 = self._plan, self._plan_t0
        n_epochs = int(res.q.shape[0])
        dec = res.decision
        lam_true, p_true = self._plane_rates_window(t0, n_epochs, dec)
        with obs.span("service.measure_window", policy=self._policy,
                      delay_model=self._obs_model(), t0=t0,
                      epochs=n_epochs, streams=int(lam_true.shape[-1])):
            return measure_window(
                lam_true, np.asarray(dec.mu), p_true, np.asarray(dec.pol),
                epoch_duration=self.epoch_duration,
                frames_cap=self.frames_cap, seed=self.seed, t0=t0,
                delay_model=self.true_delay_model,
                collect_samples=self.SAMPLE_CAP if self._auto else 0)

    def _measure_epoch(self, t: int, dec):
        """Measured AoPI + telemetry for epoch ``t``. On the scan path the
        whole plan window is measured in one batched dispatch and cached;
        the step path measures the epoch as one ``[1, N]`` dispatch.
        Armed divergence replanning (``replan_threshold``) also measures
        per epoch: a tripped threshold discards the rest of the window,
        so eagerly simulating it would be wasted work in exactly the
        badly-modeled regime replanning exists for."""
        if (self.planner == "scan" and self._plan is not None
                and self.replan_threshold is None):
            if self._plan_meas is None:
                self._plan_meas = self._measure_plan_window()
            measured_w, tels = self._plan_meas
            j = t - self._plan_t0
            return measured_w[j], tels[j]
        lam_true, p_true = self._plane_rates(t, dec)
        with obs.span("service.measure_window", policy=self._policy,
                      delay_model=self._obs_model(), t0=t, epochs=1,
                      streams=int(np.asarray(lam_true).shape[-1])):
            measured, tels = measure_window(
                lam_true[None], np.asarray(dec.mu)[None], p_true[None],
                np.asarray(dec.pol)[None],
                epoch_duration=self.epoch_duration,
                frames_cap=self.frames_cap, seed=self.seed, t0=t,
                delay_model=self.true_delay_model,
                collect_samples=self.SAMPLE_CAP if self._auto else 0)
            return measured[0], tels[0]

    def _ingest_telemetry(self, t: int, dec, tel: StreamTelemetry):
        """Gate the epoch's measurement through the fault plan before the
        EWMA. Drops and corruption become telemetry *gaps* — the belief
        scales hold their last value and the effective replan threshold
        widens by 50% per consecutive gap — instead of feeding garbage;
        delayed samples are stashed and folded in on arrival."""
        for d_dec, d_tel in self._delayed_tel.pop(t, ()):
            self._apply_telemetry(t, d_dec, d_tel)
        spec = (None if self.faults is None
                else self.faults.telemetry_fault(t))
        if spec is not None:
            if spec.kind == "telemetry_drop":
                self._telemetry_gap(t, "drop")
                return
            if spec.kind == "telemetry_delay":
                d = max(int(spec.params.get("delay", 1)), 1)
                self._delayed_tel.setdefault(t + d, []).append((dec, tel))
                self._telemetry_gap(t, "delay")
                return
            if spec.kind == "telemetry_corrupt":
                tel = dataclasses.replace(
                    tel, acc_hat=np.full_like(
                        np.asarray(tel.acc_hat, np.float64), np.nan))
        self._apply_telemetry(t, dec, tel)

    def _apply_telemetry(self, t: int, dec, tel: StreamTelemetry):
        """Validated EWMA ingest: a non-finite measurement (corruption,
        injected or genuine) is rejected as a gap — garbage never reaches
        the belief scales."""
        finite = all(
            np.isfinite(np.asarray(x, np.float64)).all()
            for x in (tel.acc_hat, tel.lam_hat, tel.mu_hat, tel.aopi_hat))
        if not finite:
            self._telemetry_gap(t, "corrupt")
            return
        self._update_telemetry(dec, tel)
        self._gap_streak = 0

    def _telemetry_gap(self, t: int, why: str):
        self.telemetry_gaps.append(t)
        self._gap_streak += 1
        obs.event("service.telemetry_gap", policy=self._policy, t=t,
                  reason=why)

    def _update_telemetry(self, dec, tel: StreamTelemetry):
        """Fold measured rates back into the planner's belief scales
        (EWMA toward measured/believed, clipped to [0.5, 2]) and the
        AoPI residual scale (measured/closed-form, clipped to [0.25, 4])
        that calibrates predictions under non-exponential delays."""
        g = self.telemetry_gain
        if g <= 0.0:
            return
        seen = tel.n_completed > 0
        ratio_acc = np.where(
            seen, tel.acc_hat / np.maximum(np.asarray(dec.acc), 1e-3), 1.0)
        ratio_lam = np.where(
            tel.n_frames > 0,
            tel.lam_hat / np.maximum(np.asarray(dec.lam), 1e-9), 1.0)
        # Residual of the *calibrated* prediction, so the scale's fixed
        # point is measured == aopi_scale * closed_form.
        pred = self._aopi_scale * np.asarray(dec.aopi)
        ratio_aopi = np.where(
            (tel.aopi_hat > 0) & np.isfinite(pred) & (pred > 0),
            tel.aopi_hat / np.maximum(pred, 1e-9), 1.0)
        self._acc_scale = np.clip(
            (1 - g) * self._acc_scale + g * self._acc_scale * ratio_acc,
            0.5, 2.0)
        self._eff_scale = np.clip(
            (1 - g) * self._eff_scale + g * self._eff_scale * ratio_lam,
            0.5, 2.0)
        self._aopi_scale = np.clip(
            (1 - g) * self._aopi_scale + g * self._aopi_scale * ratio_aopi,
            0.25, 4.0)

    def run_epoch(self, t: int) -> EpochReport:
        with obs.span("service.run_epoch", policy=self._policy, t=t):
            return self._run_epoch(t)

    def _run_epoch(self, t: int) -> EpochReport:
        rec = self._slot_record(t)
        dec = rec.decision
        if self._plan_degraded is not None and self.planner == "scan":
            # This epoch executes a fallback plan — list append and obs
            # event in the same block so they reconcile exactly.
            self.degraded_epochs.append(t)
            obs.event("service.degraded_epoch", policy=self._policy,
                      t=t, reason=self._plan_degraded)
        # The reported prediction is the *calibrated* belief: closed form
        # times the telemetry AoPI residual (identity at gain 0). Taken
        # BEFORE this epoch's telemetry folds in — the scale only carries
        # information from epochs < t, so divergence is out-of-sample.
        predicted = self._aopi_scale * np.asarray(dec.aopi)
        tel = None
        model_meas = None
        if self.mode == "mm1":
            measured, tel = self._measure_epoch(t, dec)
            self._ingest_telemetry(t, dec, tel)
            self._update_fit(t, tel)
        else:
            measured, tel = self._run_engine_epoch(rec)
            self._ingest_telemetry(t, dec, tel)
            self._update_fit(t, tel)
            # Rung 2 of the same epoch, measured under the (possibly
            # fitted) model family — one engine run yields all three
            # truth-ladder columns.
            model_meas = self._measure_model_rung(t, dec)
        act = self._active_at(t)
        if act is None:
            pred_mean = float(np.mean(predicted))
            meas_mean = float(np.mean(measured))
            acc_mean = float(np.mean(dec.acc))
        else:
            # Fleet means over the *surviving* cameras only — churned-out
            # streams carry exact zeros and must not dilute the average.
            n_live = max(float(act.sum()), 1.0)
            pred_mean = float(np.sum(predicted * act) / n_live)
            meas_mean = float(np.sum(measured * act) / n_live)
            acc_mean = float(np.sum(np.asarray(dec.acc) * act) / n_live)
        if act is None:
            model_mean = (None if model_meas is None
                          else float(np.mean(model_meas)))
        else:
            model_mean = (None if model_meas is None else float(
                np.sum(model_meas * act) / max(float(act.sum()), 1.0)))
        rep = EpochReport(
            t=t, predicted_aopi=pred_mean,
            measured_aopi=meas_mean,
            accuracy=acc_mean, q=rec.q,
            per_stream_measured=measured,
            per_stream_predicted=predicted,
            telemetry=tel,
            model_aopi=model_mean,
            per_stream_model=model_meas,
            fitted_model=self._fitted_model if self._auto else None,
            fitted_params=(dict(self._fitted_params)
                           if self._auto and self._fitted_params else None))
        self.reports.append(rep)
        div = rep.measured_aopi / max(rep.predicted_aopi, 1e-12) - 1.0
        self.divergences.append(div)
        obs.gauge("service.divergence", policy=self._policy).set(div)
        obs.histogram("service.divergence.abs",
                      policy=self._policy).observe(abs(div))
        obs.counter("service.epochs", policy=self._policy).inc()
        self._maybe_replan(t, div)
        return rep

    def _effective_replan_threshold(self) -> float | None:
        """Consecutive telemetry gaps widen the replan threshold (+50%
        per held epoch): with stale beliefs a large divergence is
        expected, and replanning on it would churn plans on no new
        information. Identity when no gap is open."""
        if self.replan_threshold is None:
            return None
        return self.replan_threshold * (1.0 + 0.5 * self._gap_streak)

    def _maybe_replan(self, t: int, div: float):
        """Divergence-triggered replanning: cut the rest of the plan
        window when the data plane drifted past ``replan_threshold`` from
        the (calibrated) prediction, so ``plan_horizon`` re-runs at
        ``t + 1`` with fresh telemetry instead of waiting for the fixed
        ``plan_window`` boundary."""
        threshold = self._effective_replan_threshold()
        if (threshold is None or self.mode != "mm1"
                or self.planner != "scan" or self._plan is None
                or abs(div) <= threshold):
            return
        remaining = self._plan_t0 + int(self._plan.q.shape[0]) - (t + 1)
        if remaining > 0:
            self._plan = None
            self._plan_meas = None
            self.early_replans.append(t + 1)
            self._replan_pending = True
            # One instant event (and counter bump) per list append — the
            # registry, the trace stream, and the legacy attribute stay
            # reconciled by construction.
            obs.event("service.early_replan", policy=self._policy,
                      t=t + 1, divergence=float(div))

    # ------------------------------------------------------------------
    def _run_engine_epoch(self, rec
                          ) -> tuple[np.ndarray, StreamTelemetry]:
        """Rung 3: the engine-rung measurement plane at the *unscaled*
        truth rates — the same model-vs-measurement split as the batched
        plane. ``engine_backend="des"`` replays the real
        continuous-batching Engine event by event
        (``engine_plane.measure_engine_epoch``: real admits, decode
        ticks, preemptions on the lanes); ``"scan"`` runs the
        bitwise-compatible batched tick-scan
        (``tick_plane.measure_engine_epoch_scan``) so the rung scales to
        full-suite frame budgets."""
        from . import engine_plane, tick_plane
        dec = rec.decision
        t = rec.t
        lam_true, p_true = self._plane_rates(t, dec)
        act = self._active_at(t)
        # Budget the epoch's frame volume against the backend cap: for
        # smoke-sized DES caps this resolves to the cap itself; for the
        # scan's full-suite cap it is the same arrival-coverage budget
        # the GI/G/1 rung runs on.
        max_lam = float(np.max(lam_true)) if np.size(lam_true) else 1.0
        if not np.isfinite(max_lam):
            max_lam = 1.0
        frames = queues.frames_budget(max_lam, self.epoch_duration,
                                      self.engine_frames_cap)
        kw = dict(epoch_duration=self.epoch_duration, seed=self.seed,
                  t=t, delay_model=self.true_delay_model, active=act,
                  frames_cap=frames,
                  collect_samples=self.SAMPLE_CAP if self._auto else 0)
        with obs.span("service.measure_engine", policy=self._policy,
                      delay_model=self._obs_model(), t0=t,
                      backend=self.engine_backend,
                      streams=int(np.asarray(lam_true).shape[-1])):
            if self.engine_backend == "scan":
                out = tick_plane.measure_engine_epoch_scan(
                    lam_true, np.asarray(dec.mu), p_true,
                    np.asarray(dec.pol), **kw)
            else:
                assert self.engine is not None
                out = engine_plane.measure_engine_epoch(
                    self.engine, lam_true, np.asarray(dec.mu), p_true,
                    np.asarray(dec.pol), **kw)
        h_eff = np.maximum(out["horizon"], 1e-9)
        tel = StreamTelemetry(
            acc_hat=out["n_accurate"] / np.maximum(out["n_completed"], 1),
            lam_hat=out["n_frames"] / h_eff,
            mu_hat=out["n_completed"] / h_eff,
            n_frames=out["n_frames"].astype(np.float64),
            n_completed=out["n_completed"].astype(np.float64),
            aopi_hat=out["aopi"].copy(),
            delay_samples=out.get("delay_samples"))
        return out["aopi"], tel

    def _measure_model_rung(self, t: int, dec) -> np.ndarray:
        """Rung 2 in engine mode: the batched GI/G/1 plane at the same
        truth rates, under the measurement family (fitted when
        ``delay_model="auto"``)."""
        lam_true, p_true = self._plane_rates(t, dec)
        with obs.span("service.measure_window", policy=self._policy,
                      delay_model=self._obs_model(), t0=t, epochs=1,
                      streams=int(np.asarray(lam_true).shape[-1])):
            measured, _ = measure_mm1(
                lam_true, np.asarray(dec.mu), p_true, np.asarray(dec.pol),
                epoch_duration=self.epoch_duration,
                frames_cap=self.frames_cap, seed=self.seed, t=t,
                delay_model=self._measure_model())
        return measured

    def run(self, n_epochs: int):
        return [self.run_epoch(t) for t in range(n_epochs)]

    @property
    def mean_measured(self) -> float:
        return float(np.mean([r.measured_aopi for r in self.reports]))

    @property
    def mean_predicted(self) -> float:
        return float(np.mean([r.predicted_aopi for r in self.reports]))
