"""Data-plane scenario replay: measured AoPI for every scenario family.

The robustness story of ``repro.scenarios`` is closed-form: ``sweep``
scores policies with the Theorem 1/2 AoPI expressions and never executes a
data plane. This module replays a scenario's ``HorizonTables`` through
``AnalyticsService`` (``mode="mm1"`` — the event-driven M/M/1 plane that
validates Theorems 1-2), so every (policy, scenario) pair produces
*measured* per-epoch AoPI next to the closed-form prediction:

  * :class:`TableSystem` — an ``EdgeSystem`` facade over prebuilt
    ``HorizonTables``, so the stateful controllers (and the service's
    scan planner) consume scenario data instead of live traces;
  * :func:`replay_tables` — one (policy, scenario) replay; the planner is
    the jitted ``lbcd.rollout`` / ``baselines.rollout_*`` scan engine
    (whole horizon in one dispatch by default), the data plane is the
    batched GI/G/1 engine, one ``service.measure_window`` dispatch per
    plan window (``delay_model`` selects any ``queues.DELAY_MODELS``
    family, or ``"auto"`` for the telemetry-fitted selector); with
    ``mode="engine"`` every epoch additionally runs on the REAL
    continuous-batching Engine (rung 3 of the truth ladder);
  * :func:`replay_suite` — the full stacked suite -> :class:`ReplayResult`
    with ``[K, T]`` predicted and measured fleet-mean AoPI per policy.

``scenarios.sweep(..., dataplane=True)`` calls :func:`replay_suite` to
attach measured series to its ``SweepResult``; ``scenarios.robustness``
then reports predicted vs measured per (policy, family) with divergence.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import jax
import numpy as np

from .. import faults as fault_plane
from .. import obs
from ..core import baselines
from ..core.lbcd import LBCDController
from ..core.profiles import HorizonTables
# The policy roster and the divergence definition are owned by the sweep
# runner — one source of truth for closed-form and replayed results.
# (scenarios.runner imports this module only lazily inside sweep(), so
# the module-level import here is acyclic.)
from ..scenarios.runner import POLICIES, divergence_series
from .service import AnalyticsService


class TableSystem:
    """``EdgeSystem`` facade over prebuilt ``HorizonTables`` (one scenario).

    Provides the three entry points the controllers and the service
    planner use — ``capacities(t)`` / ``tables(t)`` for the legacy
    per-slot path and ``horizon(n)`` for the scan engines — backed by the
    scenario's pregenerated data instead of live stateful traces.
    """

    def __init__(self, tables: HorizonTables):
        if tables.acc.ndim != 4:
            raise ValueError(
                f"TableSystem wraps ONE scenario's horizon (acc rank 4, "
                f"[T, N, M, R]); got acc{tuple(tables.acc.shape)}. Index "
                f"a stacked suite first (jax.tree.map(lambda x: x[k], ...))")
        self._tables = tables
        self.n_cameras = tables.n_cameras
        self.n_servers = tables.n_servers
        self.n_slots = tables.n_slots

    def capacities(self, t: int) -> tuple[np.ndarray, np.ndarray]:
        t = t % self.n_slots
        return (np.asarray(self._tables.budgets_b[t]),
                np.asarray(self._tables.budgets_c[t]))

    def tables(self, t: int):
        return self._tables.slot(t % self.n_slots)

    def horizon(self, n_slots: int | None = None) -> HorizonTables:
        n = self.n_slots if n_slots is None else n_slots
        if n > self.n_slots:
            raise ValueError(f"replay horizon {n} exceeds the scenario's "
                             f"{self.n_slots} slots")
        return self._tables.window(0, n)


def make_controller(policy: str, system, *, v: float = 10.0,
                    p_min: float = 0.7,
                    policy_params: Mapping | None = None,
                    solver_backend: str = "jnp"):
    """The sweep-aligned controller for ``policy`` over ``system``."""
    params = dict(policy_params or {})
    n_bcd_iters = int(params.get("n_bcd_iters", 4))
    if policy == "lbcd":
        return LBCDController(system, v=v, p_min=p_min,
                              n_bcd_iters=n_bcd_iters,
                              solver_backend=solver_backend)
    if policy == "min":
        return baselines.MINController(system, v=v, n_iters=n_bcd_iters,
                                       solver_backend=solver_backend)
    if policy == "dos":
        return baselines.DOSController(
            system, weight=float(params.get("dos_weight", 1.0)),
            solver_backend=solver_backend)
    if policy == "jcab":
        return baselines.JCABController(
            system, latency_cap=float(params.get("jcab_latency_cap", 0.5)),
            solver_backend=solver_backend)
    raise ValueError(f"unknown policy {policy!r}; known: {POLICIES}")


@dataclasses.dataclass
class ScenarioReplay:
    """One (policy, scenario) replay: per-epoch fleet means + the service
    (whose ``reports`` hold per-stream detail and telemetry).

    ``measured`` is always the GI/G/1 model rung; under ``mode="engine"``
    ``engine`` additionally carries the real-engine rung of the same
    epochs (``None`` in mm1 mode), and ``fitted`` the per-epoch family
    the selector chose when ``delay_model="auto"``."""
    predicted: np.ndarray     # [T] fleet-mean calibrated-prediction AoPI
    measured: np.ndarray      # [T] fleet-mean measured AoPI per epoch
    acc: np.ndarray           # [T] fleet-mean planned accuracy
    service: AnalyticsService
    delay_model: str = "mm1"
    engine: np.ndarray | None = None   # [T] rung-3 engine AoPI
    fitted: list | None = None         # [T] fitted family per epoch


def replay_tables(tables: HorizonTables, policy: str = "lbcd", *,
                  n_epochs: int | None = None, v: float = 10.0,
                  p_min: float = 0.7, policy_params: Mapping | None = None,
                  epoch_duration: float = 300.0, frames_cap: int = 200_000,
                  seed: int = 0, plan_window: int | None = None,
                  solver_backend: str = "jnp",
                  telemetry_gain: float = 0.0,
                  delay_model: str = "mm1",
                  true_delay_model: str | None = None,
                  mode: str = "mm1",
                  engine_params: Mapping | None = None,
                  replan_threshold: float | None = None,
                  faults: "fault_plane.FaultPlan | None" = None,
                  plan_retries: int = 2,
                  plan_deadline: float | None = None) -> ScenarioReplay:
    """Replay one scenario's horizon through the batched data plane.

    The planner runs the policy's scan engine over whole lookahead
    windows in one jitted dispatch each. ``plan_window=None`` resolves to
    the full horizon (one dispatch) when ``telemetry_gain`` is 0, and to
    ``min(8, n_epochs)`` otherwise — telemetry can only re-enter the
    planner at window boundaries, so a feedback replay must replan.
    The data plane measures each plan window in ONE batched GI/G/1
    dispatch (``service.measure_window``); ``delay_model`` picks the
    delay family (``queues.DELAY_MODELS``, or ``"auto"`` for the fitted
    selector — ``true_delay_model`` then pins the generating family),
    and ``replan_threshold`` arms divergence-triggered early replanning
    (see ``AnalyticsService``). ``mode="engine"`` swaps the data plane
    for the real continuous-batching Engine (rung 3 of the truth
    ladder): every epoch is replayed on the engine rung AND measured on
    the GI/G/1 plane, so the returned ``ScenarioReplay`` carries both
    the ``engine`` and ``measured`` series; ``engine_params`` tunes the
    engine rung — ``{"backend": "des"|"scan"|"auto", "frames_cap": int}``
    (see ``tick_plane.ENGINE_BACKENDS``: "des" drives the real
    stub-model Engine event by event, "scan" the bitwise-compatible
    batched tick-scan at full-suite frame budgets).
    Bitwise deterministic in ``(seed, tables, n_epochs)``.

    ``faults`` (a :class:`repro.faults.FaultPlan`) injects the plan's
    structural faults into the tables *before* the controller sees them
    (churn mask, capacity fades) and arms the service's behavioral
    injections and degradation ladder (``plan_retries``/``plan_deadline``).
    ``faults=None`` is the bitwise no-op path: the tables object is passed
    through untouched and every downstream trace is byte-identical.
    """
    tables = fault_plane.apply_plan(faults, tables)
    system = TableSystem(tables)
    n_epochs = system.n_slots if n_epochs is None else n_epochs
    if n_epochs > system.n_slots:
        raise ValueError(f"n_epochs={n_epochs} exceeds the scenario's "
                         f"{system.n_slots} slots")
    if plan_window is None:
        plan_window = (n_epochs if telemetry_gain <= 0.0
                       else min(8, n_epochs))
    ctrl = make_controller(policy, system, v=v, p_min=p_min,
                           policy_params=policy_params,
                           solver_backend=solver_backend)
    engine_params = dict(engine_params or {})
    svc = AnalyticsService(
        ctrl, mode=mode, epoch_duration=epoch_duration,
        frames_cap=frames_cap, seed=seed, plan_window=plan_window,
        tables=system.horizon(n_epochs), telemetry_gain=telemetry_gain,
        delay_model=delay_model, true_delay_model=true_delay_model,
        engine_frames_cap=engine_params.get("frames_cap"),
        engine_backend=engine_params.get("backend", "auto"),
        replan_threshold=replan_threshold,
        faults=faults, plan_retries=plan_retries,
        plan_deadline=plan_deadline)
    # Every span/metric the service emits below here carries the policy
    # and delay-model labels (replay_suite adds family/scenario on top).
    with obs.label_context(policy=policy, delay_model=delay_model), \
            obs.span("replay.scenario", n_epochs=n_epochs, mode=mode):
        reps = svc.run(n_epochs)
    if mode == "engine":
        # measured stays the GI/G/1 model rung (back-compat); the real
        # engine's series rides the new column.
        measured = np.array([r.model_aopi for r in reps])
        engine_series = np.array([r.measured_aopi for r in reps])
    else:
        measured = np.array([r.measured_aopi for r in reps])
        engine_series = None
    return ScenarioReplay(
        predicted=np.array([r.predicted_aopi for r in reps]),
        measured=measured,
        acc=np.array([r.accuracy for r in reps]),
        service=svc, delay_model=delay_model, engine=engine_series,
        fitted=([r.fitted_model for r in reps]
                if delay_model == "auto" else None))


@dataclasses.dataclass
class ReplayResult:
    """Suite-wide replay: per-(policy, scenario) epoch series.

    ``predicted``/``measured``/``acc`` map policy -> ``[K, T]`` arrays
    aligned with ``names``/``families`` (the measured twins of
    ``runner.SweepResult``'s closed-form series).
    """
    names: list[str]
    families: list[str]
    policies: list[str]
    v: float
    p_min: float
    epoch_duration: float
    predicted: dict[str, np.ndarray]
    measured: dict[str, np.ndarray]
    acc: dict[str, np.ndarray]
    delay_model: str = "mm1"
    mode: str = "mm1"
    #: policy -> [K, T] real-engine AoPI series (rung 3); empty unless the
    #: suite replayed with ``mode="engine"``.
    engine: dict[str, np.ndarray] = dataclasses.field(default_factory=dict)
    #: policy -> [K] lists of the service's (t, reason) fallback records /
    #: degraded-epoch indices (empty when no fault plan was armed).
    fallbacks: dict[str, list] = dataclasses.field(default_factory=dict)
    degraded: dict[str, list] = dataclasses.field(default_factory=dict)
    #: (scenario name, policy) -> repr of the exception that killed that
    #: cell; its series are NaN-filled instead of aborting the suite.
    errors: dict[tuple, str] = dataclasses.field(default_factory=dict)

    def divergence(self, policy: str) -> np.ndarray:
        """Per-scenario relative divergence of horizon-mean measured vs
        predicted AoPI (``runner.divergence_series``). [K]"""
        return divergence_series(self.measured[policy],
                                 self.predicted[policy])

    def engine_divergence(self, policy: str,
                          against: str = "measured") -> np.ndarray:
        """Per-scenario divergence of the engine rung vs ``against``
        ("measured" = the GI/G/1 rung, "predicted" = closed form). [K]"""
        ref = (self.measured if against == "measured"
               else self.predicted)[policy]
        return divergence_series(self.engine[policy], ref)


def replay_suite(suite_or_tables, policies: Sequence[str] = POLICIES, *,
                 v: float = 10.0, p_min: float = 0.7,
                 policy_params: Mapping | None = None,
                 n_epochs: int | None = None,
                 epoch_duration: float = 300.0, frames_cap: int = 200_000,
                 seed: int = 0, plan_window: int | None = None,
                 solver_backend: str = "jnp",
                 telemetry_gain: float = 0.0,
                 delay_model: str = "mm1",
                 true_delay_model: str | None = None,
                 mode: str = "mm1",
                 engine_params: Mapping | None = None,
                 replan_threshold: float | None = None,
                 faults: "fault_plane.FaultPlan | None" = None,
                 plan_retries: int = 2,
                 plan_deadline: float | None = None) -> ReplayResult:
    """Replay every scenario of a suite through the data plane, for every
    policy — the measured counterpart of ``scenarios.sweep``.

    Accepts a ``scenarios.Suite`` or raw stacked ``HorizonTables``
    (leading scenario axis). One scan-engine plan + T measured epochs per
    (policy, scenario); compiled planner executables are shared across
    scenarios of identical shape. ``faults`` applies the same fault plan
    to every cell (see :func:`replay_tables`). A cell that raises is
    recorded in ``ReplayResult.errors`` with NaN series instead of
    aborting the rest of the suite.
    """
    if hasattr(suite_or_tables, "tables"):
        tables = suite_or_tables.tables
        names = list(suite_or_tables.names)
        fams = list(suite_or_tables.families)
    else:
        tables = suite_or_tables
        if tables.acc.ndim != 5:
            raise ValueError(
                f"replay_suite needs a stacked scenario axis (acc rank 5); "
                f"got acc{tuple(tables.acc.shape)} — use replay_tables for "
                f"a single scenario")
        k = int(tables.acc.shape[0])
        names = [f"scenario_{i}" for i in range(k)]
        fams = ["unknown"] * k
    k = int(tables.acc.shape[0])
    for policy in policies:
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; known: {POLICIES}")

    predicted: dict[str, list] = {p: [] for p in policies}
    measured: dict[str, list] = {p: [] for p in policies}
    acc: dict[str, list] = {p: [] for p in policies}
    engine: dict[str, list] = {p: [] for p in policies}
    fallbacks: dict[str, list] = {p: [] for p in policies}
    degraded: dict[str, list] = {p: [] for p in policies}
    errors: dict[tuple, str] = {}
    for i in range(k):
        one = jax.tree.map(lambda x, i=i: x[i], tables)
        t_len = int(one.acc.shape[0]) if n_epochs is None else int(n_epochs)
        for policy in policies:
            try:
                with obs.label_context(family=fams[i], scenario=names[i]):
                    rep = replay_tables(
                        one, policy, n_epochs=n_epochs, v=v, p_min=p_min,
                        policy_params=policy_params,
                        epoch_duration=epoch_duration,
                        frames_cap=frames_cap, seed=seed,
                        plan_window=plan_window,
                        solver_backend=solver_backend,
                        telemetry_gain=telemetry_gain,
                        delay_model=delay_model,
                        true_delay_model=true_delay_model,
                        mode=mode, engine_params=engine_params,
                        replan_threshold=replan_threshold,
                        faults=faults, plan_retries=plan_retries,
                        plan_deadline=plan_deadline)
            except Exception as e:  # noqa: BLE001 — isolate the cell
                # One bad (scenario, policy) cell must not abort the
                # suite: record the failure, NaN-fill its series, and
                # keep replaying the remaining cells.
                errors[(names[i], policy)] = f"{type(e).__name__}: {e}"
                obs.event("replay.cell_failed", policy=policy,
                          scenario=names[i], family=fams[i])
                nan = np.full(t_len, np.nan)
                predicted[policy].append(nan)
                measured[policy].append(nan.copy())
                acc[policy].append(nan.copy())
                if mode == "engine":
                    engine[policy].append(nan.copy())
                fallbacks[policy].append([])
                degraded[policy].append([])
                continue
            predicted[policy].append(rep.predicted)
            measured[policy].append(rep.measured)
            acc[policy].append(rep.acc)
            if mode == "engine":
                engine[policy].append(rep.engine)
            fallbacks[policy].append(list(rep.service.fallbacks))
            degraded[policy].append(list(rep.service.degraded_epochs))
    return ReplayResult(
        names=names, families=fams, policies=list(policies),
        v=v, p_min=p_min, epoch_duration=epoch_duration,
        predicted={p: np.stack(s) for p, s in predicted.items()},
        measured={p: np.stack(s) for p, s in measured.items()},
        acc={p: np.stack(s) for p, s in acc.items()},
        delay_model=delay_model, mode=mode,
        engine=({p: np.stack(s) for p, s in engine.items()}
                if mode == "engine" else {}),
        fallbacks=fallbacks, degraded=degraded,
        errors=errors)
