"""Engine-rung measurement plane: the real continuous-batching Engine
driven by a discrete-event replay of the paper's frame-uploading model.

This is the third rung of the truth ladder (closed-form Theorems 1-2 ->
batched GI/G/1 plane -> *this*). Per stream, transmission and service
delays are pre-drawn from the configured ``delay_model`` family under
the collision-free ``stream_seed_sequence(seed, t, i)`` streams, and a
single event loop replays them against a live :class:`~.engine.Engine`:

  * every frame is **actually admitted** — prefill into its pinned lane,
    batched ``decode_tick`` steps across all busy lanes, real
    ``preempt_stream`` calls on LCFSP arrivals — so lane bookkeeping,
    admission contention, and churn all exercise the production path;
  * frame *timing* comes from the sampled draws (virtual completion =
    admit time + sampled service), not the stub model's FLOPs, so the
    rung measures the same stochastic process the other two rungs model
    and statistical parity is meaningful.

Each stream owns one lane (``n_lanes >= n_streams``), making every
stream an exact single-server GI/G/1 system: FCFS queues pending frames,
LCFSP preempts the in-flight frame on arrival. The age integral is
truncated at the per-stream effective horizon ``min(epoch, last
arrival)`` — the same unbiased truncation ``queues.gi_g1_window`` uses
when the frame budget runs out.

Epoch end **drains every in-flight lane**. Without the drain, a stream
that churns out between epochs (PR 8's ``active`` mask) left its lane
DECODING forever — the leaked-lane bug this module fixes; inactive
streams additionally get no arrivals and zeroed outputs, matching the
batched plane's dead-lane contract.
"""
from __future__ import annotations

import heapq
import itertools
from typing import Optional

import numpy as np

from .. import obs
from ..core import queues
from .engine import DECODING, Engine
from .scheduler import Frame

ARRIVAL, COMPLETION = 0, 1

#: Default per-stream frame budget for engine replay. Real admits are
#: ~3 orders of magnitude costlier than the batched plane's scan steps;
#: the h_eff truncation keeps a capped window unbiased (just shorter).
ENGINE_FRAMES_CAP = 192


def _frame_tokens(stream: int, k: int, vocab: int,
                  seq: int = 6) -> np.ndarray:
    """Deterministic per-(stream, frame) prefill tokens."""
    return ((stream * 131 + k * 17 + np.arange(seq)) % vocab).astype(
        np.int32)


def draw_streams(lam, mu, live, *, delay_model: str, seed: int, t: int,
                 frames_cap: int) -> tuple:
    """Pre-draw every live stream's (T, O, coin) ``[N, frames_cap]``
    arrays from its collision-free ``stream_seed_sequence(seed, t, i)``
    stream (identical sampler mapping to the loop oracle). This is THE
    shared source of randomness for the engine rung: both the DES replay
    and the tick-scan backend (``tick_plane``) consume these exact draws,
    which is what makes their traces bitwise-comparable."""
    n = lam.size
    frames_cap = int(frames_cap)
    T = np.zeros((n, frames_cap))
    O = np.zeros((n, frames_cap))
    coin = np.ones((n, frames_cap))
    for i in np.flatnonzero(live):
        rng = np.random.default_rng(
            queues.stream_seed_sequence(int(seed), int(t), int(i)))
        kw = queues.oracle_samplers(delay_model, lam[i], mu[i])
        ts = kw.get("t_sampler") or (
            lambda r, m, s=1.0 / lam[i]: r.exponential(s, size=m))
        os_ = kw.get("o_sampler") or (
            lambda r, m, s=1.0 / mu[i]: r.exponential(s, size=m))
        T[i] = ts(rng, frames_cap)
        O[i] = os_(rng, frames_cap)
        coin[i] = rng.random(frames_cap)
    return T, O, coin


def measure_engine_epoch(engine: Engine, lam, mu, p, pol, *,
                         epoch_duration: float, seed: int = 0, t: int = 0,
                         delay_model: str = "mm1", active=None,
                         frames_cap: int = ENGINE_FRAMES_CAP,
                         collect_samples: int = 0,
                         collect_trace: bool = False) -> dict:
    """Measure one epoch of ``N`` streams on the real engine.

    Returns the same per-stream stat dict as ``queues.gi_g1_window``
    (each value ``[N]``): ``aopi``/``horizon``/``n_frames``/
    ``n_completed``/``n_accurate``, plus ``preempts`` (LCFSP arrival
    preemptions per stream, drain excluded), ``engine_steps`` (batched
    decode dispatches actually executed) and, when
    ``collect_samples > 0``, ``delay_samples`` ``[N, collect_samples]``
    of raw transmission draws (zero-padded) for the fitted delay-model
    selector. ``collect_trace`` additionally returns ``trace``: the
    counted completion events as ``(stream, frame, t_done)`` tuples in
    canonical ``(t_done, stream, frame)`` order — the bitwise parity
    surface shared with the tick-scan backend.
    """
    queues.validate_delay_model(delay_model)
    lam = np.asarray(lam, np.float64).ravel()
    mu = np.asarray(mu, np.float64).ravel()
    p = np.clip(np.asarray(p, np.float64).ravel(), 1e-3, 1.0)
    pol = np.asarray(pol, np.int64).ravel()
    n = lam.size
    if engine.n_lanes < n:
        raise ValueError(
            f"engine has {engine.n_lanes} lanes < {n} streams; the "
            "replay plane pins one lane per stream")
    live = (lam > 0.0) & (mu > 0.0)
    if active is not None:
        live = live & (np.asarray(active, np.float64).ravel() > 0.0)
    vocab = int(getattr(engine.model, "vocab", 32))
    frames_cap = int(frames_cap)

    T, O, coin = draw_streams(lam, mu, live, delay_model=delay_model,
                              seed=seed, t=t, frames_cap=frames_cap)
    arrive = np.cumsum(T, axis=1)                 # a_k; gen_k = a_k - T_k
    h_eff = np.where(live, np.minimum(float(epoch_duration),
                                      arrive[:, -1]), 0.0)

    # Per-stream DES + exact age-integration state.
    last_t = np.zeros(n)
    age0 = np.zeros(n)
    area = np.zeros(n)
    n_arr = np.zeros(n)
    n_done = np.zeros(n)
    n_acc = np.zeros(n)
    n_pre = np.zeros(n)            # LCFSP arrival preemptions (no drain)
    trace: list[tuple] = []        # counted completions (i, k, t_done)
    steps0 = engine._steps
    in_service: list[Optional[int]] = [None] * n  # frame idx on the lane
    version = [0] * n              # invalidates preempted completions
    pending: list[list[int]] = [[] for _ in range(n)]   # FCFS backlog
    stash: dict[int, np.ndarray] = {}   # early engine results by stream
    counter = itertools.count()
    heap: list = []

    # Streams that churned out between epochs may still hold a DECODING
    # lane from the previous window — release them before replaying.
    for i in np.flatnonzero(~live):
        engine.preempt_stream(i)
        stash.pop(i, None)

    def pull_result(i: int) -> np.ndarray:
        """Drive batched decode ticks until stream ``i``'s tokens exist
        (early completions of other lanes are stashed for their own
        completion events)."""
        while i not in stash:
            if engine.lanes[i].status != DECODING:
                raise RuntimeError(
                    f"lane {i} lost its in-flight frame (leaked lane?)")
            for r in engine.decode_tick():
                stash[r.stream_id] = r.tokens
        return stash.pop(i)

    def admit(i: int, k: int, start: float) -> None:
        frame = Frame(stream_id=i, gen_time=arrive[i, k] - T[i, k],
                      arrive_time=arrive[i, k], seq=k)
        if not engine.admit(frame, _frame_tokens(i, k, vocab), lane=i):
            raise RuntimeError(f"lane {i} busy at admit (leaked lane?)")
        in_service[i] = k
        version[i] += 1
        heapq.heappush(heap, (start + O[i, k], next(counter),
                              COMPLETION, i, (k, version[i])))

    for i in np.flatnonzero(live):
        heapq.heappush(heap, (arrive[i, 0], next(counter), ARRIVAL, i, 0))

    while heap:
        now, _, kind, i, payload = heapq.heappop(heap)
        if kind == ARRIVAL:
            k = payload
            if now <= h_eff[i]:
                n_arr[i] += 1
            if pol[i] == 1:                       # LCFSP: preempt + seize
                if in_service[i] is not None:
                    engine.preempt_stream(i)
                    stash.pop(i, None)
                    version[i] += 1               # invalidate completion
                    in_service[i] = None
                    n_pre[i] += 1
                admit(i, k, now)
            else:                                 # FCFS: queue or seize
                if in_service[i] is None:
                    admit(i, k, now)
                else:
                    pending[i].append(k)
            if k + 1 < frames_cap and now <= h_eff[i]:
                heapq.heappush(heap, (arrive[i, k + 1], next(counter),
                                      ARRIVAL, i, k + 1))
        else:                                     # COMPLETION
            k, ver = payload
            if ver != version[i]:
                continue                          # preempted — stale event
            pull_result(i)                        # real engine tokens
            in_service[i] = None
            if now <= h_eff[i]:
                n_done[i] += 1
                if collect_trace:
                    trace.append((i, k, now))
                if coin[i, k] < p[i]:
                    n_acc[i] += 1
                    gen = arrive[i, k] - T[i, k]
                    seg = now - last_t[i]
                    area[i] += age0[i] * seg + 0.5 * seg * seg
                    last_t[i] = now
                    age0[i] = now - gen
            if pending[i] and now <= h_eff[i]:    # FCFS: next in line
                admit(i, pending[i].pop(0), now)

    # Epoch-end drain: free every in-flight lane so churned-out streams
    # can't leak a DECODING lane into the next epoch (the PR 8 bug).
    for i in range(n):
        engine.preempt_stream(i)
    stash.clear()

    seg = np.maximum(h_eff - last_t, 0.0)
    area += age0 * seg + 0.5 * seg * seg
    safe_h = np.maximum(h_eff, 1e-12)
    out = {
        "aopi": np.where(live, area / safe_h, 0.0),
        "horizon": h_eff,
        "n_frames": np.where(live, n_arr, 0.0),
        "n_completed": np.where(live, n_done, 0.0),
        "n_accurate": np.where(live, n_acc, 0.0),
        "preempts": np.where(live, n_pre, 0.0),
        "engine_steps": float(engine._steps),
    }
    if collect_samples:
        cap = min(int(collect_samples), frames_cap)
        out["delay_samples"] = np.where(live[:, None], T[:, :cap], 0.0)
    if collect_trace:
        out["trace"] = sorted(trace, key=lambda r: (r[2], r[0], r[1]))
    obs.counter("engine_plane.epochs", delay_model=delay_model).inc()
    obs.histogram("engine_plane.frames").observe(float(n_arr.sum()))
    obs.counter("engine.ticks", backend="des",
                delay_model=delay_model).inc(float(engine._steps - steps0))
    obs.counter("engine.preempts", backend="des").inc(float(n_pre.sum()))
    return out
