"""Continuous-batching inference engine with step-boundary preemption.

Lanes hold per-sequence KV/state cache slots inside one batched cache tree;
``decode_tick`` advances every active lane with a single jitted decode step
(ragged lengths via the cache's per-lane ``len``). LCFSP preemption frees a
lane between steps — the scheduler (repro.serving.scheduler) decides when.

A "frame analysis" request = prefill(frame tokens) + ``decode_tokens``
decode steps (the recognition head of the paper's detection task mapped to
autoregressive analysis output).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.common import init_params
from .scheduler import Frame

FREE, DECODING = 0, 2


@dataclasses.dataclass
class LaneState:
    status: int = FREE
    stream_id: int = -1
    frame: Optional[Frame] = None
    remaining: int = 0


@dataclasses.dataclass
class Result:
    stream_id: int
    frame: Frame
    tokens: np.ndarray
    t_done: float = 0.0


def _insert_lane(batched, single, lane: int):
    """Copy a 1-lane cache into lane ``lane`` of the batched cache.

    Block-stack leaves carry a leading n_periods dim ([P, lanes, ...]); the
    top-level ``len`` leaf is [lanes]. Dispatch on rank difference."""
    def ins(b, s):
        if b.ndim == s.ndim and b.shape[0] == s.shape[0] and b.ndim >= 2:
            return b.at[:, lane].set(s[:, 0])      # [P, lanes, ...]
        return b.at[lane].set(s[0])                # [lanes, ...]
    return jax.tree.map(ins, batched, single)


class Engine:
    def __init__(self, model, params, n_lanes: int = 8, max_len: int = 256,
                 decode_tokens: int = 8, key=None):
        self.model = model
        self.params = params
        self.n_lanes = n_lanes
        self.max_len = max_len
        self.decode_tokens = decode_tokens
        key = key if key is not None else jax.random.PRNGKey(0)
        self.cache = init_params(
            model.cache_template(n_lanes, max_len), key)
        self.lanes: List[LaneState] = [LaneState() for _ in range(n_lanes)]
        self._decode = jax.jit(
            lambda p, t, c: model.decode_step(p, t, c))
        self._prefill = jax.jit(
            lambda p, b, c: model.prefill(p, b, c))
        # Fused admit: prefill + lane insert + first-token argmax in ONE
        # dispatch (dynamic lane index), over a memoized single-lane
        # cache — per-admit init_params dominated replay-plane runtime.
        self._single_cache = init_params(
            model.cache_template(1, max_len), jax.random.PRNGKey(0))

        def _admit_fused(p, tokens, batched, single, lane):
            logits, single = model.prefill(p, {"tokens": tokens}, single)

            def ins(b, s):
                if (b.ndim == s.ndim and b.shape[0] == s.shape[0]
                        and b.ndim >= 2):
                    return b.at[:, lane].set(s[:, 0])
                return b.at[lane].set(s[0])
            return jnp.argmax(logits[0, -1]), jax.tree.map(
                ins, batched, single)

        self._admit_fused = jax.jit(_admit_fused)

        def _decode_next(p, t, c):
            logits, c = model.decode_step(p, t, c)
            return jnp.argmax(logits, axis=-1), c

        self._decode_next = jax.jit(_decode_next)
        self._steps = 0

    # ------------------------------------------------------------------
    def free_lanes(self) -> List[int]:
        return [i for i, l in enumerate(self.lanes) if l.status == FREE]

    def preempt_stream(self, stream_id: int) -> int:
        """Abort any in-flight lane of this stream (LCFSP). Returns count."""
        n = 0
        for lane in self.lanes:
            if lane.status != FREE and lane.stream_id == stream_id:
                self._release(lane)
                n += 1
        return n

    def _release(self, lane: LaneState) -> None:
        """Return a lane to the free pool with no stale bookkeeping: a
        freed-but-dirty lane (leftover ``remaining``/``out``/``stream_id``
        from a churned-out stream) must not leak into the next admit or
        show up as busy in ``utilization``."""
        lane.status = FREE
        lane.stream_id = -1
        lane.frame = None
        lane.remaining = 0
        lane.out = []

    def admit(self, frame: Frame, tokens: np.ndarray,
              lane: Optional[int] = None) -> bool:
        """Prefill a frame into a free lane. tokens: int32 [seq].

        ``lane`` pins the request to a specific free lane (the engine
        replay plane keeps one lane per stream); default picks the first
        free lane. Returns False when no (or the pinned) lane is busy."""
        if lane is None:
            free = self.free_lanes()
            if not free:
                return False
            lane = free[0]
        elif self.lanes[lane].status != FREE:
            return False
        first, self.cache = self._admit_fused(
            self.params, jnp.asarray(tokens, jnp.int32)[None],
            self.cache, self._single_cache, lane)
        st = self.lanes[lane]
        st.status = DECODING
        st.stream_id = frame.stream_id
        st.frame = frame
        st.remaining = self.decode_tokens
        st.out = [int(first)]
        return True

    def decode_tick(self) -> List[Result]:
        """One batched decode step across all lanes; returns completions."""
        active = [i for i, l in enumerate(self.lanes) if l.status ==
                  DECODING]
        if not active:
            return []
        last = np.zeros((self.n_lanes,), np.int32)
        for i in active:
            last[i] = self.lanes[i].out[-1]
        nxt, self.cache = self._decode_next(self.params,
                                            jnp.asarray(last), self.cache)
        nxt = np.asarray(nxt)
        self._steps += 1
        done = []
        for i in active:
            lane = self.lanes[i]
            lane.out.append(int(nxt[i]))
            lane.remaining -= 1
            if lane.remaining <= 0:
                done.append(Result(lane.stream_id, lane.frame,
                                   np.asarray(lane.out)))
                self._release(lane)
        return done

    @property
    def utilization(self) -> float:
        busy = sum(1 for l in self.lanes if l.status != FREE)
        return busy / self.n_lanes


# ---------------------------------------------------------------------------
# Replay stub model
# ---------------------------------------------------------------------------

class NullAnalyticsModel:
    """Tiny deterministic recognition head for engine-rung replay.

    The truth-ladder engine rung needs the *batching/lane mechanics* of a
    real continuous-batching engine — admit/prefill/decode_tick/preempt —
    at suite scale, where timing comes from sampled service draws, not
    model FLOPs. This stub satisfies the model protocol (``template`` /
    ``cache_template`` / ``prefill`` / ``decode_step``) with a cumsum-
    embed recurrent cell small enough that thousands of frames cost
    milliseconds, while staying fully deterministic under a fixed key.
    """

    def __init__(self, d: int = 8, vocab: int = 32):
        self.d = d
        self.vocab = vocab

    def template(self):
        from ..models import common as c
        return {"emb": c.P((self.vocab, self.d), (c.VOCAB, c.EMBED),
                           init="embed"),
                "out": c.P((self.d, self.vocab), (c.EMBED, c.VOCAB))}

    def cache_template(self, lanes: int, max_len: int):
        from ..models import common as c
        # Leading extent-1 dim on "state" takes _insert_lane's stacked
        # ([P, lanes, ...]) path; "len" takes the flat [lanes] path.
        return {"len": c.P((lanes,), (None,), init="zeros",
                           dtype=jnp.int32),
                "state": c.P((1, lanes, self.d), (None, None, c.EMBED),
                             init="zeros")}

    def prefill(self, params, batch, cache):
        tok = batch["tokens"]                       # [B, S]
        emb = params["emb"][tok]                    # [B, S, d]
        states = jnp.tanh(jnp.cumsum(emb, axis=1))  # [B, S, d]
        logits = states @ params["out"]             # [B, S, V]
        cache = {"len": jnp.full_like(cache["len"], tok.shape[1]),
                 "state": jnp.swapaxes(states[:, -1:], 0, 1)}
        return logits, cache

    def decode_step(self, params, tokens, cache):
        emb = params["emb"][tokens]                 # [lanes, d]
        state = jnp.tanh(cache["state"][0] + emb)
        logits = state @ params["out"]              # [lanes, V]
        cache = {"len": cache["len"] + 1, "state": state[None]}
        return logits, cache


def make_replay_engine(n_lanes: int, *, max_len: int = 64,
                       decode_tokens: int = 4, seed: int = 0) -> Engine:
    """Engine over :class:`NullAnalyticsModel` for the replay plane —
    deterministic under ``seed``, one lane per replayed stream."""
    model = NullAnalyticsModel()
    params = init_params(model.template(), jax.random.PRNGKey(seed))
    return Engine(model, params, n_lanes=n_lanes, max_len=max_len,
                  decode_tokens=decode_tokens, key=jax.random.PRNGKey(seed))
