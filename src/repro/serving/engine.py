"""Continuous-batching inference engine with step-boundary preemption.

Lanes hold per-sequence KV/state cache slots inside one batched cache tree;
``decode_tick`` advances every active lane with a single jitted decode step
(ragged lengths via the cache's per-lane ``len``). LCFSP preemption frees a
lane between steps — the scheduler (repro.serving.scheduler) decides when.

A "frame analysis" request = prefill(frame tokens) + ``decode_tokens``
decode steps (the recognition head of the paper's detection task mapped to
autoregressive analysis output).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.common import init_params
from .scheduler import Frame

FREE, DECODING = 0, 2


@dataclasses.dataclass
class LaneState:
    status: int = FREE
    stream_id: int = -1
    frame: Optional[Frame] = None
    remaining: int = 0


@dataclasses.dataclass
class Result:
    stream_id: int
    frame: Frame
    tokens: np.ndarray
    t_done: float = 0.0


def _insert_lane(batched, single, lane: int):
    """Copy a 1-lane cache into lane ``lane`` of the batched cache.

    Block-stack leaves carry a leading n_periods dim ([P, lanes, ...]); the
    top-level ``len`` leaf is [lanes]. Dispatch on rank difference."""
    def ins(b, s):
        if b.ndim == s.ndim and b.shape[0] == s.shape[0] and b.ndim >= 2:
            return b.at[:, lane].set(s[:, 0])      # [P, lanes, ...]
        return b.at[lane].set(s[0])                # [lanes, ...]
    return jax.tree.map(ins, batched, single)


class Engine:
    def __init__(self, model, params, n_lanes: int = 8, max_len: int = 256,
                 decode_tokens: int = 8, key=None):
        self.model = model
        self.params = params
        self.n_lanes = n_lanes
        self.max_len = max_len
        self.decode_tokens = decode_tokens
        key = key if key is not None else jax.random.PRNGKey(0)
        self.cache = init_params(
            model.cache_template(n_lanes, max_len), key)
        self.lanes: List[LaneState] = [LaneState() for _ in range(n_lanes)]
        self._decode = jax.jit(
            lambda p, t, c: model.decode_step(p, t, c))
        self._prefill = jax.jit(
            lambda p, b, c: model.prefill(p, b, c))
        self._steps = 0

    # ------------------------------------------------------------------
    def free_lanes(self) -> List[int]:
        return [i for i, l in enumerate(self.lanes) if l.status == FREE]

    def preempt_stream(self, stream_id: int) -> int:
        """Abort any in-flight lane of this stream (LCFSP). Returns count."""
        n = 0
        for lane in self.lanes:
            if lane.status != FREE and lane.stream_id == stream_id:
                lane.status = FREE
                lane.frame = None
                n += 1
        return n

    def admit(self, frame: Frame, tokens: np.ndarray) -> bool:
        """Prefill a frame into a free lane. tokens: int32 [seq]."""
        free = self.free_lanes()
        if not free:
            return False
        lane = free[0]
        seq = int(tokens.shape[0])
        single_cache = init_params(
            self.model.cache_template(1, self.max_len),
            jax.random.PRNGKey(0))
        batch = {"tokens": jnp.asarray(tokens, jnp.int32)[None]}
        logits, single_cache = self._prefill(self.params, batch,
                                             single_cache)
        self.cache = _insert_lane(self.cache, single_cache, lane)
        st = self.lanes[lane]
        st.status = DECODING
        st.stream_id = frame.stream_id
        st.frame = frame
        st.remaining = self.decode_tokens
        st.out = [int(jnp.argmax(logits[0, -1]))]
        return True

    def decode_tick(self) -> List[Result]:
        """One batched decode step across all lanes; returns completions."""
        active = [i for i, l in enumerate(self.lanes) if l.status ==
                  DECODING]
        if not active:
            return []
        last = np.zeros((self.n_lanes,), np.int32)
        for i in active:
            last[i] = self.lanes[i].out[-1]
        logits, self.cache = self._decode(self.params,
                                          jnp.asarray(last), self.cache)
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        self._steps += 1
        done = []
        for i in active:
            lane = self.lanes[i]
            lane.out.append(int(nxt[i]))
            lane.remaining -= 1
            if lane.remaining <= 0:
                done.append(Result(lane.stream_id, lane.frame,
                                   np.asarray(lane.out)))
                lane.status = FREE
                lane.frame = None
        return done

    @property
    def utilization(self) -> float:
        busy = sum(1 for l in self.lanes if l.status != FREE)
        return busy / self.n_lanes
