from .engine import Engine, NullAnalyticsModel, Result, make_replay_engine
from .engine_plane import measure_engine_epoch
from .replay import (ReplayResult, ScenarioReplay, TableSystem,
                     make_controller, replay_suite, replay_tables)
from .scheduler import (FCFS, LCFSP, AoPITracker, Frame, StreamQueue,
                        StreamTelemetry)
from .service import (AnalyticsService, EpochReport, measure_mm1,
                      measure_mm1_loop, measure_window)
from .tick_plane import (ENGINE_BACKENDS, measure_engine_epoch_scan,
                         measure_engine_window_scan, measure_epoch,
                         resolve_engine_backend)

__all__ = ["Engine", "NullAnalyticsModel", "Result", "make_replay_engine",
           "measure_engine_epoch", "FCFS", "LCFSP", "AoPITracker", "Frame",
           "StreamQueue", "StreamTelemetry", "AnalyticsService",
           "EpochReport", "measure_mm1", "measure_mm1_loop",
           "measure_window", "ReplayResult", "ScenarioReplay",
           "TableSystem", "make_controller", "replay_suite",
           "replay_tables", "ENGINE_BACKENDS", "measure_engine_epoch_scan",
           "measure_engine_window_scan", "measure_epoch",
           "resolve_engine_backend"]
