from .engine import Engine, Result
from .scheduler import FCFS, LCFSP, AoPITracker, Frame, StreamQueue
from .service import AnalyticsService, EpochReport

__all__ = ["Engine", "Result", "FCFS", "LCFSP", "AoPITracker", "Frame",
           "StreamQueue", "AnalyticsService", "EpochReport"]
