from .engine import Engine, Result
from .replay import (ReplayResult, ScenarioReplay, TableSystem,
                     make_controller, replay_suite, replay_tables)
from .scheduler import (FCFS, LCFSP, AoPITracker, Frame, StreamQueue,
                        StreamTelemetry)
from .service import (AnalyticsService, EpochReport, measure_mm1,
                      measure_mm1_loop, measure_window)

__all__ = ["Engine", "Result", "FCFS", "LCFSP", "AoPITracker", "Frame",
           "StreamQueue", "StreamTelemetry", "AnalyticsService",
           "EpochReport", "measure_mm1", "measure_mm1_loop",
           "measure_window", "ReplayResult", "ScenarioReplay",
           "TableSystem", "make_controller", "replay_suite",
           "replay_tables"]
