"""Declarative fault-injection plane for the plan/measure/replan loop.

A :class:`FaultPlan` is a tuple of timed :class:`FaultSpec` injections plus a
seed; every fault kind draws from its own deterministic RNG stream
(``default_rng([seed, crc32(kind), index])``), so adding a fade never
perturbs the churn trajectory and a plan is fully reproducible from
``(specs, seed)``.

Fault kinds split into three delivery mechanisms:

* **structural** (``camera_churn``, ``server_crash``, ``correlated_fade``)
  are baked into :class:`~repro.core.profiles.HorizonTables` by
  :func:`apply_plan` *before* the controller ever sees them — churn becomes
  the ``active[T, N]`` fleet mask threaded through the rollout engines and
  the water-fill, capacity faults scale ``budgets_b``/``budgets_c`` (floored
  at ``1e-6 x`` the mean so the solvers stay finite);
* **telemetry** (``telemetry_drop``/``delay``/``corrupt``) are consulted by
  :class:`~repro.serving.service.AnalyticsService` per measurement epoch and
  gate what the EWMA telemetry filter is allowed to ingest;
* **solver** (``solver_nan``/``nonconverge``/``timeout``) are consulted per
  planning *attempt* and drive the graceful-degradation ladder
  (retry -> stale plan -> MIN fallback).

``faults=None`` everywhere is the bitwise no-op path: no ``active`` leaf is
attached, no budget is touched, and every downstream trace is byte-identical
to a pre-fault-plane build (pinned by ``tests/test_faults.py``).
"""
from __future__ import annotations

import dataclasses
import zlib
from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

#: Every injectable fault kind, grouped by delivery mechanism below.
FAULT_KINDS = (
    "camera_churn",        # cameras leave/join mid-horizon (active mask)
    "server_crash",        # one server loses its budgets for a window
    "correlated_fade",     # correlated multi-server capacity fade
    "telemetry_drop",      # a measurement epoch is lost entirely
    "telemetry_delay",     # a measurement arrives k epochs late
    "telemetry_corrupt",   # a measurement arrives non-finite
    "solver_nan",          # planner output poisoned with NaN
    "solver_nonconverge",  # planner raises (non-convergence)
    "solver_timeout",      # planner blows its watchdog deadline
)

STRUCTURAL_KINDS = ("camera_churn", "server_crash", "correlated_fade")
TELEMETRY_KINDS = ("telemetry_drop", "telemetry_delay", "telemetry_corrupt")
SOLVER_KINDS = ("solver_nan", "solver_nonconverge", "solver_timeout")


class InjectedSolverFault(RuntimeError):
    """Raised (or synthesized) by the service when a ``solver_*`` injection
    fires on a planning attempt; carries the fault kind as ``args[0]``."""


@dataclass(frozen=True)
class FaultSpec:
    """One timed injection: ``kind`` active on slots ``[t0, t0+duration)``
    (``duration=None`` = until the end of the horizon), with kind-specific
    ``params`` (see :func:`storm_plan` for the full vocabulary)."""

    kind: str
    t0: int = 0
    duration: int | None = None
    params: dict = field(default_factory=dict)

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; known: {FAULT_KINDS}")
        if self.duration is not None and self.duration < 0:
            raise ValueError(f"duration must be >= 0, got {self.duration}")

    def window(self, n_slots: int) -> tuple[int, int]:
        """Clipped ``[t0, t1)`` slot window within an ``n_slots`` horizon."""
        t0 = max(int(self.t0), 0)
        t1 = n_slots if self.duration is None else min(
            int(self.t0) + int(self.duration), n_slots)
        return t0, max(t1, t0)

    def active_at(self, t: int) -> bool:
        if t < self.t0:
            return False
        return self.duration is None or t < self.t0 + self.duration


@dataclass(frozen=True)
class FaultPlan:
    """A reproducible set of timed injections over one replay horizon."""

    specs: tuple = ()
    seed: int = 0

    def __post_init__(self):
        object.__setattr__(self, "specs", tuple(self.specs))

    @property
    def kinds(self) -> tuple:
        return tuple(dict.fromkeys(s.kind for s in self.specs))

    def by_kind(self, *kinds: str) -> tuple:
        return tuple(s for s in self.specs if s.kind in kinds)

    def _rng(self, kind: str, index: int = 0) -> np.random.Generator:
        """Per-(kind, index) RNG stream; independent across kinds so one
        injection never perturbs another's trajectory."""
        return np.random.default_rng(
            [int(self.seed), zlib.crc32(kind.encode()), int(index)])

    # -- structural faults --------------------------------------------------

    def camera_active(self, n_slots: int, n_cameras: int):
        """``[T, N]`` fleet mask from the plan's ``camera_churn`` specs, or
        ``None`` when the plan has no churn (the bitwise no-op path).

        Inside each churn window a two-state Markov chain drives every
        camera: at ``t0`` a ``fraction`` of the fleet drops out, then each
        slot a live camera leaves w.p. ``leave_prob`` and a dead one
        rejoins w.p. ``join_prob``. At least one camera is guaranteed live
        in every slot (a rotating survivor) so fleet reductions and the
        water-fill always have a live member.
        """
        specs = self.by_kind("camera_churn")
        if not specs:
            return None
        mask = np.ones((n_slots, n_cameras), np.float32)
        for idx, spec in enumerate(specs):
            rng = self._rng("camera_churn", idx)
            frac = float(spec.params.get("fraction", 0.3))
            p_leave = float(spec.params.get("leave_prob", 0.05))
            p_join = float(spec.params.get("join_prob", 0.1))
            t0, t1 = spec.window(n_slots)
            if t1 <= t0 or n_cameras < 1:
                continue
            gone = np.zeros(n_cameras, bool)
            n_out = min(n_cameras - 1,
                        max(1, int(round(frac * n_cameras)))) \
                if n_cameras > 1 else 0
            if n_out > 0:
                gone[rng.choice(n_cameras, size=n_out, replace=False)] = True
            for t in range(t0, t1):
                mask[t] *= ~gone
                u = rng.random(n_cameras)
                gone = np.where(gone, u >= p_join, u < p_leave)
        for t in range(n_slots):
            if mask[t].sum() == 0:
                mask[t, t % n_cameras] = 1.0
        return mask

    def capacity_factor(self, n_slots: int, n_servers: int):
        """``[T, S]`` multiplicative capacity factor from ``server_crash``
        and ``correlated_fade`` specs, or ``None`` when there are none.

        A crash zeroes one server's factor (``depth=1``) for its window; a
        fade draws a Gaussian factor model — one shared shock plus per-
        server idiosyncratic noise mixed by ``corr`` — squashed through a
        logistic into ``(1 - depth, 1)`` across a ``fraction`` of servers.
        """
        specs = self.by_kind("server_crash", "correlated_fade")
        if not specs:
            return None
        factor = np.ones((n_slots, n_servers), np.float64)
        for idx, spec in enumerate(specs):
            rng = self._rng(spec.kind, idx)
            t0, t1 = spec.window(n_slots)
            if t1 <= t0 or n_servers < 1:
                continue
            if spec.kind == "server_crash":
                server = int(spec.params.get(
                    "server", rng.integers(n_servers))) % n_servers
                depth = float(spec.params.get("depth", 1.0))
                factor[t0:t1, server] *= 1.0 - depth
            else:
                frac = float(spec.params.get("fraction", 0.5))
                depth = float(spec.params.get("depth", 0.7))
                corr = min(max(float(spec.params.get("corr", 0.8)), 0.0), 1.0)
                k = min(n_servers, max(1, int(round(frac * n_servers))))
                hit = rng.choice(n_servers, size=k, replace=False)
                shared = rng.standard_normal((t1 - t0, 1))
                own = rng.standard_normal((t1 - t0, k))
                z = np.sqrt(corr) * shared + np.sqrt(1.0 - corr) * own
                fade = 1.0 - depth / (1.0 + np.exp(-z))
                factor[t0:t1, hit] *= fade
        return factor

    # -- behavioral faults (consulted by the service at runtime) ------------

    def telemetry_fault(self, t: int):
        """The :class:`FaultSpec` hitting measurement epoch ``t`` (first
        match wins), or ``None``. ``prob`` params fire the fault on an
        independent per-epoch coin from the kind's RNG stream."""
        for idx, spec in enumerate(self.by_kind(*TELEMETRY_KINDS)):
            if not spec.active_at(t):
                continue
            prob = float(spec.params.get("prob", 1.0))
            if prob >= 1.0 or \
                    self._rng(spec.kind, (idx + 1) * 1_000_003 + t).random() < prob:
                return spec
        return None

    def solver_fault(self, t: int, attempt: int = 0):
        """Fault kind to inject into planning attempt ``attempt`` of the
        window planned at epoch ``t``, or ``None``. A spec fails the first
        ``params['attempts']`` attempts (default 1), so a lone injection
        exercises the retry path while ``attempts >= plan_retries + 1``
        pushes the service down the fallback ladder."""
        for spec in self.by_kind(*SOLVER_KINDS):
            if spec.active_at(t) and attempt < int(spec.params.get("attempts", 1)):
                return spec.kind
        return None


def apply_plan(plan, tables):
    """Bake a plan's *structural* faults into ``tables``.

    Returns ``tables`` unchanged (same object) when ``plan`` is ``None`` or
    carries no structural specs — the bitwise no-op guarantee. Otherwise a
    copy with the churn ``active`` mask attached (intersected with any
    existing mask) and capacity factors multiplied into the budgets, floored
    at ``1e-6 x`` the pre-fault mean so zeroed servers stay solver-safe.
    """
    if plan is None:
        return tables
    n_slots, n_cameras = int(tables.n_slots), int(tables.n_cameras)
    n_servers = int(tables.budgets_b.shape[-1])
    out = tables
    act = plan.camera_active(n_slots, n_cameras)
    if act is not None:
        active = jnp.asarray(act, tables.acc.dtype)
        if tables.active is not None:
            active = active * jnp.asarray(tables.active, tables.acc.dtype)
        out = dataclasses.replace(out, active=active)
    factor = plan.capacity_factor(n_slots, n_servers)
    if factor is not None:
        bb = np.asarray(out.budgets_b, np.float64)
        bc = np.asarray(out.budgets_c, np.float64)
        bb = np.maximum(bb * factor, 1e-6 * max(float(bb.mean()), 1e-30))
        bc = np.maximum(bc * factor, 1e-6 * max(float(bc.mean()), 1e-30))
        out = dataclasses.replace(
            out,
            budgets_b=jnp.asarray(bb, tables.budgets_b.dtype),
            budgets_c=jnp.asarray(bc, tables.budgets_c.dtype))
    return out


def storm_plan(n_slots: int, *, seed: int = 0,
               solver: bool = True) -> FaultPlan:
    """Every fault kind at once over an ``n_slots`` horizon — the CI
    fault-storm preset. The solver faults are staged so every rung of the
    degradation ladder engages on the default ``plan_retries=2``: a
    retry-exhausting ``solver_timeout`` at ``t=0`` (no good plan exists
    yet, so the service lands on the MIN-fallback rung), a single-attempt
    ``solver_nonconverge`` band over the middle third (retry succeeds),
    and a retry-exhausting ``solver_nan`` band over the final third
    (stale-plan rung, re-projected on the churned fleet)."""
    third = max(1, n_slots // 3)
    specs = [
        FaultSpec("camera_churn", t0=1, duration=max(2, n_slots - 2),
                  params={"fraction": 0.4, "leave_prob": 0.1,
                          "join_prob": 0.3}),
        FaultSpec("server_crash", t0=third, duration=third,
                  params={"server": 0, "depth": 1.0}),
        FaultSpec("correlated_fade", t0=0, duration=None,
                  params={"fraction": 1.0, "depth": 0.6, "corr": 0.9}),
        FaultSpec("telemetry_drop", t0=1, duration=2),
        FaultSpec("telemetry_corrupt", t0=2 * third, duration=1),
        FaultSpec("telemetry_delay", t0=2 * third + 1, duration=1,
                  params={"delay": 1}),
    ]
    if solver:
        specs += [
            FaultSpec("solver_timeout", t0=0, duration=1,
                      params={"attempts": 8}),
            FaultSpec("solver_nonconverge", t0=third, duration=third),
            FaultSpec("solver_nan", t0=2 * third, duration=None,
                      params={"attempts": 8}),
        ]
    return FaultPlan(tuple(specs), seed=seed)
