"""Dispatch for the mLSTM: pallas | interpret | ref."""
from __future__ import annotations

from . import kernel, ref


def mlstm(q, k, v, i_gate, f_gate, *, impl: str = "ref",
          block_q: int = 128, block_k: int = 128, chunk: int = 512):
    if impl == "ref":
        return ref.mlstm_parallel_ref(q, k, v, i_gate, f_gate)
    if impl == "chunkwise":
        return ref.mlstm_chunkwise_xla(q, k, v, i_gate, f_gate, chunk=chunk)
    return kernel.mlstm_chunkwise(q, k, v, i_gate, f_gate,
                                  block_q=block_q, block_k=block_k,
                                  interpret=(impl == "interpret"))


mlstm_step = ref.mlstm_step
