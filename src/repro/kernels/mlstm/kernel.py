"""Pallas TPU chunkwise mLSTM kernel.

Same VMEM-tiled online schedule as flash attention, with softmax replaced by
the xLSTM exponential-gating decay: the running statistic is the row max of
the decay matrix D~ (not of the scores), the denominator is a *signed* sum
of decayed scores (clamped at e^{-m}), and cumulative forget-gate sums F are
precomputed outside the kernel (one cheap cumsum) so each tile's decay is
F_t - F_s + logi_s — a rank-1 broadcast in VMEM. Grid and scratch layout
are identical to kernels/flash_attention.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _mlstm_kernel_impl(q_ref, k_ref, v_ref, fq_ref, fk_ref, i_ref, o_ref,
                       m_ref, den_ref, acc_ref, *, block_q: int,
                       block_k: int, kv_blocks: int, scale: float,
                       kv_total: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        den_ref[...] = jnp.zeros_like(den_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    run = (ki * block_k) <= (qi * block_q + block_q - 1)

    @pl.when(run)
    def _step():
        q_pos = qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        k_pos = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        causal = q_pos >= k_pos

        Ft = fq_ref[0, :, 0].astype(jnp.float32)           # [bq]
        Fs = fk_ref[0, :, 0].astype(jnp.float32)           # [bk]
        li = i_ref[0, :, 0].astype(jnp.float32)            # [bk]
        dtil = Ft[:, None] - Fs[None, :] + li[None, :]
        dtil = jnp.where(causal & (k_pos < kv_total), dtil, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(dtil, axis=1))
        alpha = jnp.exp(m_prev - m_new)

        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale
        k = k_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        S = s * jnp.exp(dtil - m_new[:, None])

        den_ref[...] = den_ref[...] * alpha + jnp.sum(S, axis=1)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        v_row = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, v.shape, 0)
        v = jnp.where(v_row < kv_total, v, 0.0)
        pv = jax.lax.dot_general(S, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + pv
        m_ref[...] = m_new

    @pl.when(ki == kv_blocks - 1)
    def _finish():
        den = jnp.maximum(jnp.abs(den_ref[...]), jnp.exp(-m_ref[...]))
        o_ref[0, :, 0, :] = (acc_ref[...] / den[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k",
                                             "interpret"))
def mlstm_chunkwise(q, k, v, i_gate, f_gate, *, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False):
    """q,k,v: [b,s,h,d]; gates: [b,s,h] pre-activations -> h [b,s,h,d]."""
    b, s, h, d = q.shape
    scale = d ** -0.5
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    q_blocks = pl.cdiv(s, block_q)
    kv_blocks = pl.cdiv(s, block_k)

    F = jnp.cumsum(jax.nn.log_sigmoid(f_gate.astype(jnp.float32)), axis=1)
    logi = i_gate.astype(jnp.float32)

    kern = functools.partial(_mlstm_kernel_impl, block_q=block_q,
                             block_k=block_k, kv_blocks=kv_blocks,
                             scale=scale, kv_total=s)

    return pl.pallas_call(
        kern,
        grid=(b, h, q_blocks, kv_blocks),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, d),
                         lambda bi, hi, qi, ki: (bi, qi, hi, 0)),   # q
            pl.BlockSpec((1, block_k, 1, d),
                         lambda bi, hi, qi, ki: (bi, ki, hi, 0)),   # k
            pl.BlockSpec((1, block_k, 1, d),
                         lambda bi, hi, qi, ki: (bi, ki, hi, 0)),   # v
            pl.BlockSpec((1, block_q, 1),
                         lambda bi, hi, qi, ki: (bi, qi, hi)),      # F_t
            pl.BlockSpec((1, block_k, 1),
                         lambda bi, hi, qi, ki: (bi, ki, hi)),      # F_s
            pl.BlockSpec((1, block_k, 1),
                         lambda bi, hi, qi, ki: (bi, ki, hi)),      # logi
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, d),
                               lambda bi, hi, qi, ki: (bi, qi, hi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, F, F, logi)
