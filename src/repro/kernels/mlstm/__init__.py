from .ops import mlstm, mlstm_step
from .ref import mlstm_chunkwise_xla, mlstm_parallel_ref
from .kernel import mlstm_chunkwise

__all__ = ["mlstm", "mlstm_step", "mlstm_parallel_ref", "mlstm_chunkwise",
           "mlstm_chunkwise_xla"]
