"""Pure-jnp oracles for the mLSTM (xLSTM's matrix-memory cell).

Recurrent definition (per head, stabilized with max-state m_t):

    logf_t = logsigmoid(f~_t),  logi_t = i~_t
    m_t = max(logf_t + m_{t-1}, logi_t)
    C_t = e^{logf_t + m_{t-1} - m_t} C_{t-1} + e^{logi_t - m_t} v_t k'_t^T
    n_t = e^{logf_t + m_{t-1} - m_t} n_{t-1} + e^{logi_t - m_t} k'_t
    h_t = (C_t q_t) / max(|n_t . q_t|, e^{-m_t})        k' = k / sqrt(d)

The *parallel form* (used for training, quadratic like attention):

    D~[t,s] = F_t - F_s + logi_s  (s <= t, F = cumsum logf),  m_t = max_s D~
    S = (q k'^T) * exp(D~ - m_t)
    h_t = S v / max(|sum_s S[t,s]|, e^{-m_t})

Both agree step-for-step (tests/test_kernels.py asserts it).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def mlstm_parallel_ref(q, k, v, i_gate, f_gate):
    """q,k,v: [b,s,h,d]; i_gate, f_gate: [b,s,h] pre-activations.
    Returns h: [b,s,h,d]."""
    b, s, h, d = q.shape
    logf = jax.nn.log_sigmoid(f_gate.astype(jnp.float32))     # [b,s,h]
    logi = i_gate.astype(jnp.float32)
    F = jnp.cumsum(logf, axis=1)
    dtil = F[:, :, None, :] - F[:, None, :, :] + logi[:, None, :, :]
    tpos = jnp.arange(s)
    causal = tpos[:, None] >= tpos[None, :]
    dtil = jnp.where(causal[None, :, :, None], dtil, NEG_INF)  # [b,t,s,h]
    m = jnp.max(dtil, axis=2)                                  # [b,t,h]
    dec = jnp.exp(dtil - m[:, :, None, :])
    qk = jnp.einsum("bthd,bshd->btsh", q.astype(jnp.float32),
                    k.astype(jnp.float32)) * (d ** -0.5)
    S = qk * dec
    den = jnp.sum(S, axis=2)                                   # [b,t,h]
    den = jnp.maximum(jnp.abs(den), jnp.exp(-m))
    out = jnp.einsum("btsh,bshd->bthd", S, v.astype(jnp.float32))
    return (out / den[..., None]).astype(q.dtype)


def mlstm_chunkwise_xla(q, k, v, i_gate, f_gate, chunk: int = 256):
    """Chunkwise-parallel mLSTM in pure XLA (beyond-paper perf path).

    The parallel form is quadratic in sequence length; chunking makes it
    s*(chunk + 2*hd) per head instead of s^2 — a ~13x FLOP cut at 32k with
    chunk=512 — and bounds the decay-matrix transient to [chunk, chunk].
    lax.scan carries the (C, n, m) running state between chunks; intra-chunk
    uses the parallel form, the carried state enters with decay exp(F_t+m0).
    Matches mlstm_parallel_ref exactly (tests/test_kernels.py).
    """
    b, s, h, d = q.shape
    if s % chunk != 0 or s <= chunk:
        return mlstm_parallel_ref(q, k, v, i_gate, f_gate)
    nc = s // chunk
    scale = d ** -0.5

    logf = jax.nn.log_sigmoid(f_gate.astype(jnp.float32))
    logi = i_gate.astype(jnp.float32)

    def split(t):
        return t.reshape(b, nc, chunk, *t.shape[2:]).swapaxes(0, 1)

    qs, ks, vs = split(q), split(k), split(v)
    lfs, lis = split(logf), split(logi)

    tpos = jnp.arange(chunk)
    causal = tpos[:, None] >= tpos[None, :]

    def body(carry, xs):
        C0, n0, m0 = carry                       # [b,h,d,d],[b,h,d],[b,h]
        qc, kc, vc, lf, li = xs                  # [b,chunk,...]
        F = jnp.cumsum(lf, axis=1)               # [b,chunk,h]
        # intra-chunk decay
        dtil = F[:, :, None, :] - F[:, None, :, :] + li[:, None, :, :]
        dtil = jnp.where(causal[None, :, :, None], dtil, NEG_INF)
        m_intra = jnp.max(dtil, axis=2)          # [b,t,h]
        # inter-chunk (carried state) decay: F_t + m0
        m_inter = F + m0[:, None, :]
        m_t = jnp.maximum(m_intra, m_inter)

        qf = qc.astype(jnp.float32)
        kf = kc.astype(jnp.float32) * scale
        vf = vc.astype(jnp.float32)
        S = jnp.einsum("bthd,bshd->btsh", qf, kf) * \
            jnp.exp(dtil - m_t[:, :, None, :])
        num = jnp.einsum("btsh,bshd->bthd", S, vf)
        den = jnp.sum(S, axis=2)
        w_inter = jnp.exp(m_inter - m_t)         # [b,t,h]
        # C0[d, e] = v_d k'_e : the query contracts the key index (e).
        num = num + jnp.einsum("bthe,bhde->bthd", qf * w_inter[..., None],
                               C0)
        den = den + jnp.einsum("bthd,bhd->bth", qf * w_inter[..., None],
                               n0)
        out = num / jnp.maximum(jnp.abs(den),
                                jnp.exp(-m_t))[..., None]

        # state update to the chunk end (position chunk-1).
        Fc = F[:, -1, :]                         # [b,h]
        m1 = jnp.maximum(Fc + m0, jnp.max(Fc[:, None, :] - F + li,
                                          axis=1))
        wv = jnp.exp(Fc[:, None, :] - F + li - m1[:, None, :])  # [b,s,h]
        C1 = C0 * jnp.exp(Fc + m0 - m1)[..., None, None] + \
            jnp.einsum("bsh,bshd,bshe->bhde", wv, vf, kf)
        n1 = n0 * jnp.exp(Fc + m0 - m1)[..., None] + \
            jnp.einsum("bsh,bshd->bhd", wv, kf)
        return (C1, n1, m1), out.astype(q.dtype)

    C0 = jnp.zeros((b, h, d, d), jnp.float32)
    n0 = jnp.zeros((b, h, d), jnp.float32)
    m0 = jnp.full((b, h), NEG_INF)
    _, outs = jax.lax.scan(body, (C0, n0, m0), (qs, ks, vs, lfs, lis))
    return outs.swapaxes(0, 1).reshape(b, s, h, d)


def mlstm_step(q, k, v, i_gate, f_gate, C, n, m):
    """Single decode step. q,k,v: [b,h,d]; gates: [b,h];
    states C: [b,h,d,d], n: [b,h,d], m: [b,h]. Returns (h, (C,n,m))."""
    d = q.shape[-1]
    logf = jax.nn.log_sigmoid(f_gate.astype(jnp.float32))
    logi = i_gate.astype(jnp.float32)
    m_new = jnp.maximum(logf + m, logi)
    fp = jnp.exp(logf + m - m_new)[..., None]
    ip = jnp.exp(logi - m_new)[..., None]
    kp = k.astype(jnp.float32) * (d ** -0.5)
    C_new = fp[..., None] * C + ip[..., None] * \
        jnp.einsum("bhd,bhe->bhde", v.astype(jnp.float32), kp)
    n_new = fp * n + ip * kp
    q32 = q.astype(jnp.float32)
    num = jnp.einsum("bhde,bhe->bhd", C_new, q32)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n_new, q32)),
                      jnp.exp(-m_new))
    return (num / den[..., None]).astype(q.dtype), (C_new, n_new, m_new)
