"""Dispatch layer for the fused slot solver: jnp | pallas | interpret.

``ServerLayout`` is the static-shape bridge between the per-camera arrays
Algorithm 1 works with and the sorted per-server blocks the water-filling
kernel owns: cameras are stably sorted by ``server_id`` into contiguous
per-server blocks (plus a ``[S, C]`` row view, ``C`` = per-server
capacity, default N so overflow is impossible) with sentinel-padded
gather tables, so building it is jit-safe even when the assignment is a
traced value (first-fit output inside the rollout scan). ``gather_flat``
is the kernel's single HBM read per operand; ``scatter_flat`` its single
write back to camera order; ``member()`` the static membership matrix the
kernel reduces over per server.

``waterfill_bandwidth`` / ``waterfill_compute`` mirror the signatures of
``repro.core.allocate.waterfill_*`` so ``bcd.solve_slot`` can swap the
backend behind one flag; ``config_argmin`` dispatches Algorithm 1 line 3
between the reference (materialized ``[N, M, R, 2]``) and the streaming
kernel. ``interpret=None`` auto-selects interpret mode off-TPU, which is
the CPU/CI path.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from ... import obs
from ...core import aopi
from . import kernel, ref

_EPS = 1e-12
_LANE = 128          # pad per-server rows to the TPU lane width


def _resolve_interpret(interpret):
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ServerLayout:
    """Cameras stably sorted/padded into per-server blocks (static shapes).

    Two views of the same permutation:

      * ``order[s, j]`` — the original index of the j-th camera assigned to
        server s (ascending original order — stable sort), or the sentinel
        ``n_cameras`` on padding slots; ``mask`` is 1.0 on real slots and
        ``counts[s]`` the number of cameras on server s (overflow beyond
        the capacity is dropped — impossible at the default capacity of N).
      * ``flat_order[j]`` — the same cameras as one lane-padded ``[Np]``
        vector of contiguous per-server blocks (``flat_sid`` holding each
        slot's server, ``n_servers`` on padding). ``member`` derives the
        ``[S, Np]`` 0/1 membership matrix the water-filling kernel uses
        for its on-chip per-server reductions.
    """
    order: jnp.ndarray        # [S, C]  int32
    mask: jnp.ndarray         # [S, C]  float32
    counts: jnp.ndarray       # [S]     int32
    flat_order: jnp.ndarray   # [Np]    int32
    flat_sid: jnp.ndarray     # [Np]    int32
    flat_mask: jnp.ndarray    # [Np]    float32

    @property
    def n_servers(self) -> int:
        return self.order.shape[0]

    @property
    def capacity(self) -> int:
        return self.order.shape[1]

    def gather(self, x, fill=0.0):
        """Per-camera ``[N]`` -> per-server rows ``[S, C]`` (one read)."""
        padded = jnp.concatenate(
            [x, jnp.asarray([fill], x.dtype)])
        return padded[self.order]

    def scatter(self, rows, n_cameras: int):
        """Per-server rows ``[S, C]`` -> per-camera ``[N]`` (one write)."""
        vals = (rows * self.mask.astype(rows.dtype)).reshape(-1)
        return jnp.zeros((n_cameras + 1,), rows.dtype).at[
            self.order.reshape(-1)].set(vals)[:n_cameras]

    def gather_flat(self, x, fill=0.0):
        """Per-camera ``[N]`` -> sorted flat ``[Np]`` (one read)."""
        padded = jnp.concatenate([x, jnp.asarray([fill], x.dtype)])
        return padded[self.flat_order]

    def scatter_flat(self, vec, n_cameras: int):
        """Sorted flat ``[Np]`` -> per-camera ``[N]`` (one write)."""
        vals = vec * self.flat_mask.astype(vec.dtype)
        return jnp.zeros((n_cameras + 1,), vec.dtype).at[
            self.flat_order].set(vals)[:n_cameras]

    def member(self):
        """``[S, Np]`` 0/1 server-membership matrix (padding: all-zero)."""
        servers = jnp.arange(self.n_servers, dtype=self.flat_sid.dtype)
        return (self.flat_sid[None, :] == servers[:, None]).astype(
            jnp.float32)


def server_layout(server_id, n_servers: int,
                  capacity: int | None = None) -> ServerLayout:
    """Build a :class:`ServerLayout` from a (possibly traced) assignment.

    ``capacity`` bounds the per-server ``order`` rows only (the flat view
    always holds every camera) and is rounded up to the 128-lane width, so
    values <= 128 are equivalent; a server holding more cameras than the
    rounded capacity silently drops the overflow from its row — only pass
    a sub-N capacity with a known assignment bound. The default (N) makes
    overflow impossible.
    """
    n = server_id.shape[0]
    cap = n if capacity is None else int(capacity)
    cap = max(_LANE, -(-cap // _LANE) * _LANE)
    n_pad = max(_LANE, -(-n // _LANE) * _LANE)
    sort_idx = jnp.argsort(server_id, stable=True).astype(jnp.int32)
    sid_sorted = server_id[sort_idx].astype(jnp.int32)
    counts = jax.ops.segment_sum(jnp.ones((n,), jnp.int32), server_id,
                                 num_segments=n_servers)
    start = jnp.cumsum(counts) - counts
    pos = jnp.arange(n) - start[sid_sorted]
    order = jnp.full((n_servers, cap), n, jnp.int32).at[
        sid_sorted, pos].set(sort_idx, mode="drop")
    mask = (order < n).astype(jnp.float32)
    flat_order = jnp.concatenate(
        [sort_idx, jnp.full((n_pad - n,), n, jnp.int32)])
    flat_sid = jnp.concatenate(
        [sid_sorted, jnp.full((n_pad - n,), n_servers, jnp.int32)])
    return ServerLayout(order=order, mask=mask, counts=counts,
                        flat_order=flat_order, flat_sid=flat_sid,
                        flat_mask=(flat_order < n).astype(jnp.float32))


# ---------------------------------------------------------------------------
# Config selection (Algorithm 1 line 3)
# ---------------------------------------------------------------------------

def config_argmin(b, c, acc, xi, size, eff, q, v, n_total: int,
                  backend: str = "jnp", interpret: bool | None = None,
                  block_n: int = 1024):
    """Per-camera (r_idx, m_idx, pol) minimizing the drift-plus-penalty
    score over the (model x resolution x policy) grid."""
    if backend == "jnp":
        return ref.config_argmin_ref(b, c, acc, xi, size, eff, q, v, n_total)
    if backend != "pallas":
        raise ValueError(f"unknown solver backend {backend!r};"
                         " known: ('jnp', 'pallas')")
    obs.count_dispatch("config_argmin")
    return kernel.config_argmin(b, c, acc, xi, size, eff, q, v,
                                n_total=n_total, block_n=block_n,
                                interpret=_resolve_interpret(interpret))


def baseline_argmax(b, c, acc, xi, size, eff, *, mode: str, threshold,
                    backend: str = "jnp", interpret: bool | None = None,
                    block_n: int = 1024):
    """Streaming DOS/JCAB config scan; returns per-camera ``(m_idx, r_idx)``.

    The jnp backend materializes the ``[N, M, R]`` latency/score tensors
    (:func:`ref.baseline_argmax_ref`); the pallas backend streams camera
    tiles through :func:`kernel.baseline_argmax` so they never exist.
    Indices are bitwise identical between the two.
    """
    if backend == "jnp":
        return ref.baseline_argmax_ref(b, c, acc, xi, size, eff, mode=mode,
                                       threshold=threshold)
    if backend != "pallas":
        raise ValueError(f"unknown solver backend {backend!r};"
                         " known: ('jnp', 'pallas')")
    obs.count_dispatch("baseline_argmax", mode=str(mode))
    return kernel.baseline_argmax(b, c, acc, xi, size, eff, mode=mode,
                                  threshold=threshold, block_n=block_n,
                                  interpret=_resolve_interpret(interpret))


# ---------------------------------------------------------------------------
# Water-filling (Algorithm 1 lines 4/5)
# ---------------------------------------------------------------------------

def _round_tile(tile_n: int) -> int:
    return max(_LANE, -(-int(tile_n) // _LANE) * _LANE)


def _pack_tiled(layout, scale, p, pol, other, lo, hi, cf, tile: int):
    """Gather the water-fill vectors into the packed ``[8, Np]`` block the
    tiled kernel streams (``kernel.TILE_FIELDS`` row order), padding the
    lane-padded layout width up to a multiple of ``tile``."""
    cap = layout.flat_order.shape[0]
    np_to = -(-cap // tile) * tile
    is_l = (layout.gather_flat(pol, fill=jnp.int32(aopi.LCFSP))
            == aopi.LCFSP).astype(jnp.float32)
    rows = [
        (layout.gather_flat(scale, fill=1.0), 1.0),
        (layout.gather_flat(p, fill=0.5), 0.5),
        (is_l, 1.0),
        (layout.gather_flat(other, fill=1.0), 1.0),
        (layout.gather_flat(lo, fill=1e-9), 1e-9),
        (layout.gather_flat(hi, fill=1e-9), 1e-9),
        (layout.gather_flat(cf, fill=1.0), 1.0),
        (layout.flat_sid.astype(jnp.float32), float(layout.n_servers)),
    ]
    pad = np_to - cap
    return jnp.stack([
        jnp.concatenate([v.astype(jnp.float32),
                         jnp.full((pad,), fill, jnp.float32)])
        if pad else v.astype(jnp.float32) for v, fill in rows])


def _run_waterfill(layout, scale, p, pol, other, lo, hi, cf, mode,
                   outer_iters, inner_iters, final_inner_iters, interpret,
                   tile_n=None):
    n = scale.shape[0]
    cap = layout.flat_order.shape[0]
    tile = None if tile_n is None else _round_tile(tile_n)
    if tile is not None and cap > tile:
        obs.count_dispatch("waterfill_tiled", mode=str(mode))
        block = _pack_tiled(layout, scale, p, pol, other, lo, hi, cf, tile)
        vec = kernel.waterfill_tiled(
            block, mode=mode, n_servers=layout.n_servers, tile=tile,
            outer_iters=outer_iters, inner_iters=inner_iters,
            final_inner_iters=final_inner_iters,
            interpret=_resolve_interpret(interpret))
        return layout.scatter_flat(vec[:cap], n)
    obs.count_dispatch("waterfill", mode=str(mode))
    vec = kernel.waterfill(
        layout.gather_flat(scale, fill=1.0),
        layout.gather_flat(p, fill=0.5),
        layout.gather_flat(pol, fill=jnp.int32(aopi.LCFSP)),
        layout.gather_flat(other, fill=1.0),
        layout.gather_flat(lo, fill=1e-9),
        layout.gather_flat(hi, fill=1e-9),
        layout.gather_flat(cf, fill=1.0),
        layout.member(), mode=mode, outer_iters=outer_iters,
        inner_iters=inner_iters, final_inner_iters=final_inner_iters,
        interpret=_resolve_interpret(interpret))
    return layout.scatter_flat(vec, n)


def waterfill_bandwidth(k, p, pol, mu, server_id, budgets, n_servers: int,
                        outer_iters: int = 16, inner_iters: int = 6,
                        final_inner_iters: int = 20, *,
                        layout: ServerLayout | None = None,
                        tile_n: int | None = None,
                        interpret: bool | None = None):
    """Fused twin of ``allocate.waterfill_bandwidth`` (same signature plus
    an optional precomputed layout); returns b[n] in Hz. ``tile_n``
    switches to the camera-tiled streaming kernel when the padded fleet
    exceeds one tile (rounded up to the 128-lane width)."""
    if layout is None:
        layout = server_layout(server_id, n_servers)
    B = budgets[server_id]
    lam_scale = k * B
    lam_star = aopi.argmin_lam_fcfs(mu, p)
    hi = jnp.where(pol == aopi.LCFSP, 1.0,
                   jnp.minimum(lam_star / jnp.maximum(lam_scale, _EPS), 1.0))
    lo = jnp.full_like(hi, 1e-9)
    cf = 1.0 + 1.0 / p       # LCFSP closed form: u = sqrt(cf / (scale * nu))
    u = _run_waterfill(layout, lam_scale, p, pol, mu, lo, hi, cf,
                       "bandwidth", outer_iters, inner_iters,
                       final_inner_iters, interpret, tile_n=tile_n)
    return u * B


def waterfill_compute(inv_xi, p, pol, lam, server_id, budgets,
                      n_servers: int, stability_margin: float = 1.05,
                      outer_iters: int = 16, inner_iters: int = 6,
                      final_inner_iters: int = 20, *,
                      layout: ServerLayout | None = None,
                      tile_n: int | None = None,
                      interpret: bool | None = None):
    """Fused twin of ``allocate.waterfill_compute``; returns c[n] in FLOPS."""
    if layout is None:
        layout = server_layout(server_id, n_servers)
    C = budgets[server_id]
    mu_scale = inv_xi * C
    floor = jnp.where(pol == aopi.FCFS,
                      stability_margin * lam / jnp.maximum(mu_scale, _EPS),
                      1e-9)
    # Best effort if FCFS floors alone exceed a server's budget. This runs
    # in plain XLA outside the kernel, so the O(N) segment_sum (identical
    # to the jnp twin's) beats a dense membership reduction.
    floor_tot = jax.ops.segment_sum(floor, server_id,
                                    num_segments=layout.n_servers)
    scale_fac = jnp.minimum(1.0, 1.0 / jnp.maximum(floor_tot, _EPS))
    lo = jnp.clip(floor * scale_fac[server_id], 1e-9, 1.0)
    hi = jnp.ones_like(lo)
    cf = 1.0 / p             # LCFSP closed form: v = sqrt(cf / (scale * nu))
    v = _run_waterfill(layout, mu_scale, p, pol, lam, lo, hi, cf,
                       "compute", outer_iters, inner_iters,
                       final_inner_iters, interpret, tile_n=tile_n)
    return v * C


def waterfill_pair(k, p, pol, mu, inv_xi, server_id, budgets_b, budgets_c,
                   n_servers: int, stability_margin: float = 1.05,
                   outer_iters: int = 16, inner_iters: int = 6,
                   final_inner_iters: int = 20, *,
                   layout: ServerLayout | None = None,
                   interpret: bool | None = None):
    """Both BCD water-fills (Algorithm 1 lines 4+5) in one kernel dispatch.

    Equivalent (to float32 tolerance) to ``waterfill_bandwidth`` followed
    by ``waterfill_compute`` at ``lam = b * k``: the FCFS floors and the
    intermediate arrival rate are derived on-chip from the in-register
    bandwidth result, so only the packed inputs and the two allocation
    vectors cross HBM. Returns ``(b, c)`` in Hz / FLOPS.
    """
    if layout is None:
        layout = server_layout(server_id, n_servers)
    n = k.shape[0]
    B = budgets_b[server_id]
    C = budgets_c[server_id]
    lam_scale = k * B
    lam_star = aopi.argmin_lam_fcfs(mu, p)
    hi_b = jnp.where(pol == aopi.LCFSP, 1.0,
                     jnp.minimum(lam_star / jnp.maximum(lam_scale, _EPS),
                                 1.0))
    obs.count_dispatch("waterfill_pair")
    u, v = kernel.waterfill_pair(
        layout.gather_flat(lam_scale, fill=1.0),
        layout.gather_flat(p, fill=0.5),
        layout.gather_flat(pol, fill=jnp.int32(aopi.LCFSP)),
        layout.gather_flat(mu, fill=1.0),
        layout.gather_flat(jnp.full_like(hi_b, 1e-9), fill=1e-9),
        layout.gather_flat(hi_b, fill=1e-9),
        layout.gather_flat(1.0 + 1.0 / p, fill=1.0),
        layout.gather_flat(inv_xi * C, fill=1.0),
        layout.member(), stability_margin=stability_margin,
        outer_iters=outer_iters, inner_iters=inner_iters,
        final_inner_iters=final_inner_iters,
        interpret=_resolve_interpret(interpret))
    return (layout.scatter_flat(u, n) * B, layout.scatter_flat(v, n) * C)
