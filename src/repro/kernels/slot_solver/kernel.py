"""Pallas TPU kernels for the Algorithm-1 slot-solver hot path.

Four kernels, all pure VPU work (no MXU):

  * ``config_argmin`` — Algorithm 1 line 3. The jnp backend materializes the
    ``[N, M, R, 2]`` FCFS/LCFSP score tensor in HBM once per BCD pass (and
    again for every vmap lane of a grid/scenario sweep). Here the camera
    axis is tiled across the grid and the model axis is a static on-chip
    loop: each program holds one ``[block_n, R]`` score slice in VMEM,
    folds it into a running per-camera ``(best_value, best_flat_index)``
    pair, and writes only the three ``[N]`` index vectors back to HBM. Tie
    breaking matches the reference's flat argmin exactly (first index in
    (m, r, policy) order, strict-``<`` fold over models).

  * ``waterfill`` — Algorithm 1 lines 4/5. The grid program owns the whole
    fleet: cameras arrive stably sorted into contiguous per-server blocks
    and lane-padded to a ``[Np]`` vector (``ops.ServerLayout``), together
    with the layout's static ``[S, Np]`` server-membership matrix. The
    entire Illinois outer loop on the log-duals plus the bracketed inner
    bisection runs on-chip: per-server duals/brackets/fill residuals are
    ``[S, 1]`` registers, the per-camera allocation vectors live in VMEM,
    and the two cross-camera couplings (per-server fill sums, dual
    broadcast back to cameras) are membership-masked reductions — so the
    per-camera h-evaluations stay O(N), not O(S*N). HBM traffic is one
    read of the seven input vectors + membership and one write of the
    allocation vector — the jnp path instead pays ~``outer_iters``
    sequential ``segment_sum``/gather dispatches through HBM per solve.
    The math (h-functions, closed forms, iteration budgets, Illinois
    halving) mirrors ``repro.core.allocate._waterfill`` so the two
    backends agree to float32 tolerance.

  * ``waterfill_pair`` — lines 4 *and* 5 in one dispatch. The bandwidth
    solve, the FCFS stability floors for the compute step, and the compute
    solve share one program, so a BCD pass costs one kernel launch instead
    of two and the intermediate ``lam`` never round-trips through HBM.

  * ``waterfill_tiled`` — the same Illinois search with the camera axis
    streamed through VMEM one tile at a time (double-buffered manual DMA
    out of HBM), for fleets past the single-program VMEM ceiling. The
    per-server Illinois state stays in ``[S, 1]`` registers across tiles;
    per-camera brackets persist in an HBM scratch between dual
    evaluations, and each dual evaluation is one sweep over the tiles.
    The per-tile math is identical to ``waterfill``; only the order of
    the per-server fill-sum accumulation differs (tile partial sums), so
    tiled-vs-untiled agreement is near-bitwise rather than exact.

  * ``baseline_argmax`` — the DOS/JCAB config scans (``core.baselines``).
    Same camera-tiled streaming fold as ``config_argmin`` but maximizing
    the baselines' scores (DOS: ``acc - w * latency``; JCAB: accuracy
    under a latency cap with a min-latency fallback), so the baselines'
    ``[N, M, R]`` score/latency tensors are never materialized. The
    elementwise score math matches the jnp references operation for
    operation, so the returned indices are bitwise identical.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...core import aopi

_LOG_NU_LO = -34.0   # dual-variable search window (log domain)
_LOG_NU_HI = 34.0
_EPS = 1e-12


# ---------------------------------------------------------------------------
# Streaming config argmin (Algorithm 1 line 3)
# ---------------------------------------------------------------------------

def _config_kernel(qv_ref, b_ref, c_ref, eff_ref, acc_ref, xi_ref, size_ref,
                   r_ref, m_ref, pol_ref, *, n_total: int, n_m: int,
                   n_r: int):
    q = qv_ref[0, 0]
    v = qv_ref[0, 1]
    b = b_ref[...]
    c = c_ref[...]
    eff = eff_ref[...]
    size = size_ref[...]
    bn = b.shape[0]
    lam = (b * eff)[:, None] / size[None, :]               # [bn, R]
    r_iota = jax.lax.broadcasted_iota(jnp.int32, (bn, n_r), 1)

    best_val = jnp.full((bn,), jnp.inf, jnp.float32)
    best_flat = jnp.zeros((bn,), jnp.int32)
    for m in range(n_m):                                   # static on-chip loop
        mu = c[:, None] / xi_ref[m, :][None, :]            # [bn, R]
        acc_m = acc_ref[:, m, :]                           # [bn, R]
        p = jnp.maximum(acc_m, 1e-3)
        s_f = (v * aopi.aopi_fcfs(lam, mu, p) - q * acc_m) / n_total
        s_l = (v * aopi.aopi_lcfsp(lam, mu, p) - q * acc_m) / n_total
        # Per resolution, LCFSP only wins a tie-free strict comparison —
        # flat order is (r, policy), FCFS first, matching the reference.
        l_wins = s_l < s_f
        val = jnp.where(l_wins, s_l, s_f)                  # [bn, R]
        pol_r = l_wins.astype(jnp.int32)
        min_val = jnp.min(val, axis=1, keepdims=True)
        first_r = jnp.min(jnp.where(val == min_val, r_iota, n_r), axis=1)
        sel = r_iota == first_r[:, None]
        loc_val = jnp.sum(jnp.where(sel, val, 0.0), axis=1)
        loc_pol = jnp.sum(jnp.where(sel, pol_r, 0), axis=1)
        loc_flat = m * (n_r * 2) + first_r * 2 + loc_pol
        take = loc_val < best_val                          # keeps earliest m
        best_val = jnp.where(take, loc_val, best_val)
        best_flat = jnp.where(take, loc_flat, best_flat)

    m_ref[...] = best_flat // (n_r * 2)
    r_ref[...] = (best_flat // 2) % n_r
    pol_ref[...] = best_flat % 2


@functools.partial(jax.jit, static_argnames=("n_total", "block_n",
                                             "interpret"))
def config_argmin(b, c, acc, xi, size, eff, q, v, *, n_total: int,
                  block_n: int = 1024, interpret: bool = False):
    """Streaming (m, r, policy) argmin; returns ``(r_idx, m_idx, pol)``."""
    n, n_m, n_r = acc.shape
    block_n = min(block_n, n)
    grid = (pl.cdiv(n, block_n),)
    qv = jnp.stack([jnp.asarray(q, jnp.float32),
                    jnp.asarray(v, jnp.float32)]).reshape(1, 2)
    kernel = functools.partial(_config_kernel, n_total=n_total, n_m=n_m,
                               n_r=n_r)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 2), lambda i: (0, 0),
                         memory_space=pltpu.SMEM),               # q, V
            pl.BlockSpec((block_n,), lambda i: (i,)),            # b
            pl.BlockSpec((block_n,), lambda i: (i,)),            # c
            pl.BlockSpec((block_n,), lambda i: (i,)),            # eff
            pl.BlockSpec((block_n, n_m, n_r), lambda i: (i, 0, 0)),  # acc
            pl.BlockSpec((n_m, n_r), lambda i: (0, 0)),          # xi
            pl.BlockSpec((n_r,), lambda i: (0,)),                # size
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.int32)] * 3,
        interpret=interpret,
    )(qv, b, c, eff, acc, xi, size)
    return tuple(out)


# ---------------------------------------------------------------------------
# Per-server on-chip water-filling (Algorithm 1 lines 4/5)
# ---------------------------------------------------------------------------

def _h_fn(x, scale, p, is_l, other, mode):
    """Marginal-AoPI water-level function h(x) (shared by every variant)."""
    if mode == "bandwidth":
        lam = jnp.maximum(scale * x, _EPS)
        d_l = aopi.d_aopi_lcfsp_dlam(lam, other, p)
        d_f = aopi.d_aopi_fcfs_dlam(jnp.minimum(lam, 0.999 * other),
                                    other, p)
    else:
        mu = jnp.maximum(scale * x, _EPS)
        d_l = aopi.d_aopi_lcfsp_dmu(other, mu, p)
        d_f = aopi.d_aopi_fcfs_dmu(jnp.minimum(other, 0.999 * mu),
                                   mu, p)
    d = jnp.where(is_l, d_l, d_f)
    return jnp.maximum(-d * scale, 0.0)


def _illinois_waterfill(scale, p, is_l, other, lo, hi, cf, member, *,
                        mode: str, outer_iters: int, inner_iters: int,
                        final_inner_iters: int):
    """On-chip Illinois dual search over whole-fleet vectors; returns x.

    This is the body shared by the single-mode ``waterfill`` kernel and
    the fused ``waterfill_pair`` kernel — plain array-in/array-out so it
    can run twice inside one program.
    """

    def h_fn(x):
        return _h_fn(x, scale, p, is_l, other, mode)

    def solve_h_equals_nu(nu, blo, bhi, iters):
        def body(_, state):
            a, b = state
            mid = 0.5 * (a + b)
            go_up = h_fn(mid) >= nu
            return jnp.where(go_up, mid, a), jnp.where(go_up, b, mid)
        a, b = jax.lax.fori_loop(0, iters, body, (blo, bhi))
        return 0.5 * (a + b)

    n_servers = member.shape[0]

    def per_camera(v_s):
        """Broadcast a per-server [S, 1] value to cameras [Np] (zero on
        padding slots, whose membership column is all-zero)."""
        return jnp.sum(member * v_s, axis=0)

    def alloc_at(log_nu_s, blo, bhi, iters):
        nu = per_camera(jnp.exp(log_nu_s))                # [Np] duals
        x_cl = jnp.sqrt(cf / jnp.maximum(scale * nu, _EPS))
        x_bi = solve_h_equals_nu(nu, blo, bhi, iters)
        return jnp.clip(jnp.where(is_l, x_cl, x_bi), lo, hi)

    def bracket(xa, xb):
        pad = 0.25 * jnp.maximum(xa - xb, 0.0) + 1e-7
        return jnp.maximum(lo, xb - pad), jnp.minimum(hi, xa + pad)

    def fill_at(log_nu_s, xa, xb, iters):
        blo, bhi = bracket(xa, xb)
        x = alloc_at(log_nu_s, blo, bhi, iters)
        f = jnp.sum(member * x[None, :], axis=1,
                    keepdims=True) - 1.0                  # [S, 1]
        return x, f

    a0 = jnp.full((n_servers, 1), _LOG_NU_LO, jnp.float32)
    b0 = jnp.full((n_servers, 1), _LOG_NU_HI, jnp.float32)
    xa0, fa0 = fill_at(a0, hi, lo, inner_iters + 4)
    xb0, fb0 = fill_at(b0, hi, lo, inner_iters + 4)

    def body(_, state):
        a, b, fa, fb, xa, xb = state
        denom = fa - fb
        t = jnp.where(jnp.abs(denom) > 1e-12, fa / denom, 0.5)
        t = jnp.clip(t, 0.05, 0.95)
        mid = a + t * (b - a)
        x, f = fill_at(mid, xa, xb, inner_iters)
        over = f > 0.0             # over budget -> raise the price
        over_n = per_camera(over.astype(jnp.float32)) > 0.5
        return (jnp.where(over, mid, a), jnp.where(over, b, mid),
                jnp.where(over, f, 0.5 * fa),    # Illinois halving of the
                jnp.where(over, 0.5 * fb, f),    # retained endpoint
                jnp.where(over_n, x, xa), jnp.where(over_n, xb, x))

    a, b, _, _, xa, xb = jax.lax.fori_loop(
        0, outer_iters, body, (a0, b0, fa0, fb0, xa0, xb0))
    blo, bhi = bracket(xa, xb)
    # If the total cap is below budget the constraint is slack: keep caps.
    return alloc_at(0.5 * (a + b), blo, bhi, final_inner_iters)


def _waterfill_kernel(scale_ref, p_ref, pol_ref, other_ref, lo_ref, hi_ref,
                      cf_ref, member_ref, x_ref, *, mode: str,
                      outer_iters: int, inner_iters: int,
                      final_inner_iters: int):
    x_ref[...] = _illinois_waterfill(
        scale_ref[...], p_ref[...], pol_ref[...] == aopi.LCFSP,
        other_ref[...], lo_ref[...], hi_ref[...], cf_ref[...],
        member_ref[...], mode=mode, outer_iters=outer_iters,
        inner_iters=inner_iters, final_inner_iters=final_inner_iters)


@functools.partial(jax.jit, static_argnames=("mode", "outer_iters",
                                             "inner_iters",
                                             "final_inner_iters",
                                             "interpret"))
def waterfill(scale, p, pol, other, lo, hi, cf, member, *, mode: str,
              outer_iters: int = 16, inner_iters: int = 6,
              final_inner_iters: int = 20, interpret: bool = False):
    """Run the fused water-fill on flat layout vectors.

    The seven per-camera vectors are ``[Np]`` in the layout's sorted
    (contiguous-per-server, lane-padded) order and ``member`` is the
    layout's ``[S, Np]`` membership matrix (``ops.ServerLayout.member``).
    Returns normalized allocations ``[Np]`` in the same order. One grid
    program holds the whole fleet in VMEM (~9 f32 vectors + the
    membership matrix — N up to ~10^5 at edge-scale server counts).
    """
    cap = scale.shape[0]
    n_servers = member.shape[0]
    kernel = functools.partial(_waterfill_kernel, mode=mode,
                               outer_iters=outer_iters,
                               inner_iters=inner_iters,
                               final_inner_iters=final_inner_iters)
    vec = pl.BlockSpec((cap,), lambda: (0,))
    return pl.pallas_call(
        kernel,
        grid=(),
        in_specs=[vec] * 7 + [pl.BlockSpec((n_servers, cap),
                                           lambda: (0, 0))],
        out_specs=vec,
        out_shape=jax.ShapeDtypeStruct((cap,), jnp.float32),
        interpret=interpret,
    )(scale, p, pol, other, lo, hi, cf, member)


# ---------------------------------------------------------------------------
# Fused bandwidth+compute water-fill (Algorithm 1 lines 4 and 5 together)
# ---------------------------------------------------------------------------

def _pair_kernel(margin_ref, scale_b_ref, p_ref, pol_ref, mu_ref, lo_b_ref,
                 hi_b_ref, cf_b_ref, mu_scale_ref, member_ref, u_ref, v_ref,
                 *, outer_iters: int, inner_iters: int,
                 final_inner_iters: int):
    margin = margin_ref[0, 0]                             # FCFS stability
    scale_b = scale_b_ref[...]                            # k * B  [Np]
    p = p_ref[...]
    is_l = pol_ref[...] == aopi.LCFSP
    member = member_ref[...]                              # [S, Np] 0/1

    # Line 4: bandwidth water-fill, identical to the single-mode kernel.
    u = _illinois_waterfill(
        scale_b, p, is_l, mu_ref[...], lo_b_ref[...], hi_b_ref[...],
        cf_b_ref[...], member, mode="bandwidth", outer_iters=outer_iters,
        inner_iters=inner_iters, final_inner_iters=final_inner_iters)

    # Line 5 prologue, on-chip: the arrival rate implied by the fresh b and
    # the FCFS stability floors (the jnp twin computes these between the
    # two dispatches; here they never leave VMEM). The floor rescale uses a
    # membership reduction instead of the twin's segment_sum.
    lam = scale_b * u
    mu_scale = mu_scale_ref[...]                          # inv_xi * C
    floor = jnp.where(is_l, 1e-9,
                      margin * lam / jnp.maximum(mu_scale, _EPS))
    floor_tot = jnp.sum(member * floor[None, :], axis=1,
                        keepdims=True)                    # [S, 1]
    scale_fac = jnp.minimum(1.0, 1.0 / jnp.maximum(floor_tot, _EPS))
    lo_c = jnp.clip(floor * jnp.sum(member * scale_fac, axis=0), 1e-9, 1.0)

    v = _illinois_waterfill(
        mu_scale, p, is_l, lam, lo_c, jnp.ones_like(lo_c), 1.0 / p, member,
        mode="compute", outer_iters=outer_iters, inner_iters=inner_iters,
        final_inner_iters=final_inner_iters)
    u_ref[...] = u
    v_ref[...] = v


@functools.partial(jax.jit, static_argnames=("outer_iters", "inner_iters",
                                             "final_inner_iters",
                                             "interpret"))
def waterfill_pair(scale_b, p, pol, mu, lo_b, hi_b, cf_b, mu_scale, member,
                   *, stability_margin: float = 1.05, outer_iters: int = 16,
                   inner_iters: int = 6, final_inner_iters: int = 20,
                   interpret: bool = False):
    """One dispatch for both water-fills of a BCD pass.

    Bandwidth inputs are as for ``waterfill(mode="bandwidth")``;
    ``mu_scale`` is the compute-side scale ``inv_xi * C``. The compute
    bounds/coefficient (FCFS stability floors, unit cap, ``1/p``) are
    derived on-chip from the in-register bandwidth result. Returns
    normalized ``(u, v)`` allocations in layout order.
    """
    cap = scale_b.shape[0]
    n_servers = member.shape[0]
    kernel = functools.partial(_pair_kernel, outer_iters=outer_iters,
                               inner_iters=inner_iters,
                               final_inner_iters=final_inner_iters)
    vec = pl.BlockSpec((cap,), lambda: (0,))
    mg = jnp.asarray(stability_margin, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        kernel,
        grid=(),
        in_specs=[pl.BlockSpec((1, 1), lambda: (0, 0),
                               memory_space=pltpu.SMEM)] + [vec] * 8 +
                 [pl.BlockSpec((n_servers, cap), lambda: (0, 0))],
        out_specs=[vec, vec],
        out_shape=[jax.ShapeDtypeStruct((cap,), jnp.float32)] * 2,
        interpret=interpret,
    )(mg, scale_b, p, pol, mu, lo_b, hi_b, cf_b, mu_scale, member)


# ---------------------------------------------------------------------------
# Camera-tiled streaming water-fill (fleets past the VMEM ceiling)
# ---------------------------------------------------------------------------

# Row order of the packed [8, Np] input block (built by ops._run_waterfill).
TILE_FIELDS = ("scale", "p", "is_l", "other", "lo", "hi", "cf", "sid")


def _tiled_waterfill_kernel(in_hbm, x_hbm, st_hbm, *, mode: str,
                            n_servers: int, n_tiles: int, tile: int,
                            outer_iters: int, inner_iters: int,
                            final_inner_iters: int):
    """Illinois dual search with the camera axis streamed tile by tile.

    The whole fleet lives in HBM as one packed ``[8, Np]`` block; VMEM
    holds a double-buffered ``[2, 8, tile]`` window of it. Per-server
    Illinois state (duals, residuals, the deferred bracket decision) stays
    in ``[S, 1]`` registers across the sweep; per-camera brackets
    ``(xa, xb, x_last)`` persist in a ``[3, Np]`` HBM scratch between
    sweeps, so VMEM holds only O(tile) state no matter the fleet size.
    One dual evaluation = one sweep over the tiles accumulating the
    per-server fill sums.

    The bracket update is *deferred*: sweep k applies sweep k-1's
    over/under decision to the stored brackets before allocating — exactly
    the untiled kernel's carried ``(xa, xb)`` update, one evaluation late
    never (the untiled kernel also applies the decision only when the
    *next* evaluation reads the brackets).
    """

    def body(in_scr, st_scr, out_scr, in_sems, st_sem, out_sem):
        srv = jax.lax.broadcasted_iota(jnp.float32, (n_servers, tile), 0)

        def in_dma(slot, t):
            return pltpu.make_async_copy(
                in_hbm.at[:, pl.ds(t * tile, tile)], in_scr.at[slot],
                in_sems.at[slot])

        def sweep(log_nu, over_prev, iters, phase):
            """One streamed dual evaluation. phase: 0 = init (log_nu is the
            (a0, b0) endpoint pair, brackets seeded from (hi, lo)), 1 =
            Illinois step at log_nu, 2 = final allocation (writes x)."""

            def tile_step(t, fs):
                slot = t % 2

                @pl.when(t + 1 < n_tiles)
                def _():
                    in_dma((t + 1) % 2, t + 1).start()

                in_dma(slot, t).wait()
                blk = in_scr[slot]                        # [8, tile]
                scale, p = blk[0], blk[1]
                is_l = blk[2] > 0.5
                other, lo, hi, cf, sid = (blk[3], blk[4], blk[5], blk[6],
                                          blk[7])
                member = (sid[None, :] == srv).astype(jnp.float32)

                def per_camera(v_s):
                    return jnp.sum(member * v_s, axis=0)

                def alloc_at(log_nu_s, blo, bhi, it):
                    nu = per_camera(jnp.exp(log_nu_s))
                    x_cl = jnp.sqrt(cf / jnp.maximum(scale * nu, _EPS))

                    def bstep(_, state):
                        a_, b_ = state
                        mid = 0.5 * (a_ + b_)
                        go_up = _h_fn(mid, scale, p, is_l, other,
                                      mode) >= nu
                        return (jnp.where(go_up, mid, a_),
                                jnp.where(go_up, b_, mid))

                    a_, b_ = jax.lax.fori_loop(0, it, bstep, (blo, bhi))
                    return jnp.clip(jnp.where(is_l, x_cl, 0.5 * (a_ + b_)),
                                    lo, hi)

                def bracket(xa, xb):
                    pad = 0.25 * jnp.maximum(xa - xb, 0.0) + 1e-7
                    return (jnp.maximum(lo, xb - pad),
                            jnp.minimum(hi, xa + pad))

                def fill_of(x):
                    return jnp.sum(member * x[None, :], axis=1,
                                   keepdims=True)          # [S, 1]

                if phase == 0:
                    la, lb = log_nu
                    blo, bhi = bracket(hi, lo)
                    xa = alloc_at(la, blo, bhi, iters)
                    xb = alloc_at(lb, blo, bhi, iters)
                    st_scr[0, :] = xa
                    st_scr[1, :] = xb
                    st_scr[2, :] = xb
                    wr = pltpu.make_async_copy(
                        st_scr, st_hbm.at[:, pl.ds(t * tile, tile)], st_sem)
                    wr.start()
                    wr.wait()
                    return fs[0] + fill_of(xa), fs[1] + fill_of(xb)

                rd = pltpu.make_async_copy(
                    st_hbm.at[:, pl.ds(t * tile, tile)], st_scr, st_sem)
                rd.start()
                rd.wait()
                # Apply the previous evaluation's over/under decision to
                # the stored brackets (same update as the untiled carry).
                ov = per_camera(over_prev) > 0.5
                xa = jnp.where(ov, st_scr[2], st_scr[0])
                xb = jnp.where(ov, st_scr[1], st_scr[2])
                blo, bhi = bracket(xa, xb)
                x = alloc_at(log_nu, blo, bhi, iters)
                if phase == 1:
                    st_scr[0, :] = xa
                    st_scr[1, :] = xb
                    st_scr[2, :] = x
                    wr = pltpu.make_async_copy(
                        st_scr, st_hbm.at[:, pl.ds(t * tile, tile)], st_sem)
                    wr.start()
                    wr.wait()
                    return fs[0] + fill_of(x), fs[1]
                out_scr[0, :] = x
                wr = pltpu.make_async_copy(
                    out_scr, x_hbm.at[:, pl.ds(t * tile, tile)], out_sem)
                wr.start()
                wr.wait()
                return fs

            in_dma(0, 0).start()
            z = jnp.zeros((n_servers, 1), jnp.float32)
            return jax.lax.fori_loop(0, n_tiles, tile_step, (z, z))

        a0 = jnp.full((n_servers, 1), _LOG_NU_LO, jnp.float32)
        b0 = jnp.full((n_servers, 1), _LOG_NU_HI, jnp.float32)
        zero = jnp.zeros((n_servers, 1), jnp.float32)
        fa0, fb0 = sweep((a0, b0), zero, inner_iters + 4, phase=0)
        fa0 = fa0 - 1.0
        fb0 = fb0 - 1.0

        def outer(_, state):
            a, b, fa, fb, over_prev = state
            denom = fa - fb
            t = jnp.where(jnp.abs(denom) > 1e-12, fa / denom, 0.5)
            t = jnp.clip(t, 0.05, 0.95)
            mid = a + t * (b - a)
            f, _ = sweep(mid, over_prev, inner_iters, phase=1)
            f = f - 1.0
            over = f > 0.0
            return (jnp.where(over, mid, a), jnp.where(over, b, mid),
                    jnp.where(over, f, 0.5 * fa),
                    jnp.where(over, 0.5 * fb, f),
                    over.astype(jnp.float32))

        a, b, _, _, over_prev = jax.lax.fori_loop(
            0, outer_iters, outer, (a0, b0, fa0, fb0, zero))
        sweep(0.5 * (a + b), over_prev, final_inner_iters, phase=2)

    return body


@functools.partial(jax.jit, static_argnames=("mode", "n_servers", "tile",
                                             "outer_iters", "inner_iters",
                                             "final_inner_iters",
                                             "interpret"))
def waterfill_tiled(block, *, mode: str, n_servers: int, tile: int,
                    outer_iters: int = 16, inner_iters: int = 6,
                    final_inner_iters: int = 20, interpret: bool = False):
    """Camera-tiled streaming water-fill on a packed ``[8, Np]`` block.

    ``block`` rows follow :data:`TILE_FIELDS` (the seven ``waterfill``
    vectors plus the per-slot server id as f32; ``is_l`` is the 0/1
    LCFSP indicator); ``Np`` must be a multiple of ``tile``. Padding
    slots carry the sentinel sid ``n_servers`` so no membership row
    picks them up. Returns the normalized allocation ``[Np]``.
    """
    f, np_ = block.shape
    assert f == len(TILE_FIELDS) and np_ % tile == 0

    def kernel(in_hbm, x_hbm, st_hbm):
        inner = _tiled_waterfill_kernel(
            in_hbm, x_hbm, st_hbm, mode=mode, n_servers=n_servers,
            n_tiles=np_ // tile, tile=tile, outer_iters=outer_iters,
            inner_iters=inner_iters, final_inner_iters=final_inner_iters)
        pl.run_scoped(
            inner,
            in_scr=pltpu.VMEM((2, f, tile), jnp.float32),
            st_scr=pltpu.VMEM((3, tile), jnp.float32),
            out_scr=pltpu.VMEM((1, tile), jnp.float32),
            in_sems=pltpu.SemaphoreType.DMA((2,)),
            st_sem=pltpu.SemaphoreType.DMA,
            out_sem=pltpu.SemaphoreType.DMA,
        )

    x, _ = pl.pallas_call(
        kernel,
        grid=(),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=[pl.BlockSpec(memory_space=pltpu.ANY)] * 2,
        out_shape=[jax.ShapeDtypeStruct((1, np_), jnp.float32),
                   jax.ShapeDtypeStruct((3, np_), jnp.float32)],
        interpret=interpret,
    )(block)
    return x[0]


# ---------------------------------------------------------------------------
# Streaming DOS/JCAB config scans (core.baselines)
# ---------------------------------------------------------------------------

def _baseline_kernel(sc_ref, b_ref, c_ref, eff_ref, acc_ref, xi_ref,
                     size_ref, m_ref, r_ref, *, mode: str, n_m: int,
                     n_r: int):
    thresh = sc_ref[0, 0]           # DOS latency weight / JCAB latency cap
    b = b_ref[...]
    c = c_ref[...]
    eff = eff_ref[...]
    size = size_ref[...]
    bn = b.shape[0]
    lam = (b * eff)[:, None] / size[None, :]               # [bn, R]
    inv_lam = 1.0 / jnp.maximum(lam, 1e-9)
    r_iota = jax.lax.broadcasted_iota(jnp.int32, (bn, n_r), 1)

    best_val = jnp.full((bn,), -jnp.inf, jnp.float32)
    best_flat = jnp.zeros((bn,), jnp.int32)
    # JCAB fallback: the overall min-latency config, tracked alongside.
    lat_best = jnp.full((bn,), jnp.inf, jnp.float32)
    lat_flat = jnp.zeros((bn,), jnp.int32)
    for m in range(n_m):                                   # static on-chip
        mu = c[:, None] / xi_ref[m, :][None, :]            # [bn, R]
        latency = inv_lam + 1.0 / jnp.maximum(mu, 1e-9)
        acc_m = acc_ref[:, m, :]                           # [bn, R]
        if mode == "dos":
            val = acc_m - thresh * latency
        else:
            val = jnp.where(latency <= thresh, acc_m, -jnp.inf)
        # First-max within the row, then strict-> across models: the fold
        # keeps the earliest flat index, matching jnp.argmax exactly.
        row_max = jnp.max(val, axis=1, keepdims=True)
        first_r = jnp.min(jnp.where(val == row_max, r_iota, n_r), axis=1)
        take = row_max[:, 0] > best_val
        best_val = jnp.where(take, row_max[:, 0], best_val)
        best_flat = jnp.where(take, m * n_r + first_r, best_flat)
        if mode == "jcab":
            lat_min = jnp.min(latency, axis=1, keepdims=True)
            first_l = jnp.min(jnp.where(latency == lat_min, r_iota, n_r),
                              axis=1)
            lt = lat_min[:, 0] < lat_best
            lat_best = jnp.where(lt, lat_min[:, 0], lat_best)
            lat_flat = jnp.where(lt, m * n_r + first_l, lat_flat)

    if mode == "jcab":
        # No config met the cap anywhere: min-latency fallback (the jnp
        # twin's argmax over all -inf also lands on flat index 0, so the
        # met-somewhere case needs no special handling).
        best_flat = jnp.where(jnp.isneginf(best_val), lat_flat, best_flat)
    m_ref[...] = best_flat // n_r
    r_ref[...] = best_flat % n_r


@functools.partial(jax.jit, static_argnames=("mode", "block_n", "interpret"))
def baseline_argmax(b, c, acc, xi, size, eff, *, mode: str, threshold,
                    block_n: int = 1024, interpret: bool = False):
    """Streaming DOS/JCAB config argmax; returns ``(m_idx, r_idx)``.

    ``mode="dos"`` maximizes ``acc - threshold * latency``;
    ``mode="jcab"`` maximizes accuracy among configs with
    ``latency <= threshold`` and falls back to the min-latency config
    when none qualifies. Bitwise-identical indices to the materialized
    jnp scans (same elementwise ops, same first-index tie-breaks).
    """
    n, n_m, n_r = acc.shape
    block_n = min(block_n, n)
    grid = (pl.cdiv(n, block_n),)
    sc = jnp.asarray(threshold, jnp.float32).reshape(1, 1)
    kernel = functools.partial(_baseline_kernel, mode=mode, n_m=n_m,
                               n_r=n_r)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0),
                         memory_space=pltpu.SMEM),               # threshold
            pl.BlockSpec((block_n,), lambda i: (i,)),            # b
            pl.BlockSpec((block_n,), lambda i: (i,)),            # c
            pl.BlockSpec((block_n,), lambda i: (i,)),            # eff
            pl.BlockSpec((block_n, n_m, n_r), lambda i: (i, 0, 0)),  # acc
            pl.BlockSpec((n_m, n_r), lambda i: (0, 0)),          # xi
            pl.BlockSpec((n_r,), lambda i: (0,)),                # size
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.int32)] * 2,
        interpret=interpret,
    )(sc, b, c, eff, acc, xi, size)
    return tuple(out)
