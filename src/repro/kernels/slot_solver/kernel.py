"""Pallas TPU kernels for the Algorithm-1 slot-solver hot path.

Two kernels, both pure VPU work (no MXU):

  * ``config_argmin`` — Algorithm 1 line 3. The jnp backend materializes the
    ``[N, M, R, 2]`` FCFS/LCFSP score tensor in HBM once per BCD pass (and
    again for every vmap lane of a grid/scenario sweep). Here the camera
    axis is tiled across the grid and the model axis is a static on-chip
    loop: each program holds one ``[block_n, R]`` score slice in VMEM,
    folds it into a running per-camera ``(best_value, best_flat_index)``
    pair, and writes only the three ``[N]`` index vectors back to HBM. Tie
    breaking matches the reference's flat argmin exactly (first index in
    (m, r, policy) order, strict-``<`` fold over models).

  * ``waterfill`` — Algorithm 1 lines 4/5. The grid program owns the whole
    fleet: cameras arrive stably sorted into contiguous per-server blocks
    and lane-padded to a ``[Np]`` vector (``ops.ServerLayout``), together
    with the layout's static ``[S, Np]`` server-membership matrix. The
    entire Illinois outer loop on the log-duals plus the bracketed inner
    bisection runs on-chip: per-server duals/brackets/fill residuals are
    ``[S, 1]`` registers, the per-camera allocation vectors live in VMEM,
    and the two cross-camera couplings (per-server fill sums, dual
    broadcast back to cameras) are membership-masked reductions — so the
    per-camera h-evaluations stay O(N), not O(S*N). HBM traffic is one
    read of the seven input vectors + membership and one write of the
    allocation vector — the jnp path instead pays ~``outer_iters``
    sequential ``segment_sum``/gather dispatches through HBM per solve.
    The math (h-functions, closed forms, iteration budgets, Illinois
    halving) mirrors ``repro.core.allocate._waterfill`` so the two
    backends agree to float32 tolerance.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...core import aopi

_LOG_NU_LO = -34.0   # dual-variable search window (log domain)
_LOG_NU_HI = 34.0
_EPS = 1e-12


# ---------------------------------------------------------------------------
# Streaming config argmin (Algorithm 1 line 3)
# ---------------------------------------------------------------------------

def _config_kernel(qv_ref, b_ref, c_ref, eff_ref, acc_ref, xi_ref, size_ref,
                   r_ref, m_ref, pol_ref, *, n_total: int, n_m: int,
                   n_r: int):
    q = qv_ref[0, 0]
    v = qv_ref[0, 1]
    b = b_ref[...]
    c = c_ref[...]
    eff = eff_ref[...]
    size = size_ref[...]
    bn = b.shape[0]
    lam = (b * eff)[:, None] / size[None, :]               # [bn, R]
    r_iota = jax.lax.broadcasted_iota(jnp.int32, (bn, n_r), 1)

    best_val = jnp.full((bn,), jnp.inf, jnp.float32)
    best_flat = jnp.zeros((bn,), jnp.int32)
    for m in range(n_m):                                   # static on-chip loop
        mu = c[:, None] / xi_ref[m, :][None, :]            # [bn, R]
        acc_m = acc_ref[:, m, :]                           # [bn, R]
        p = jnp.maximum(acc_m, 1e-3)
        s_f = (v * aopi.aopi_fcfs(lam, mu, p) - q * acc_m) / n_total
        s_l = (v * aopi.aopi_lcfsp(lam, mu, p) - q * acc_m) / n_total
        # Per resolution, LCFSP only wins a tie-free strict comparison —
        # flat order is (r, policy), FCFS first, matching the reference.
        l_wins = s_l < s_f
        val = jnp.where(l_wins, s_l, s_f)                  # [bn, R]
        pol_r = l_wins.astype(jnp.int32)
        min_val = jnp.min(val, axis=1, keepdims=True)
        first_r = jnp.min(jnp.where(val == min_val, r_iota, n_r), axis=1)
        sel = r_iota == first_r[:, None]
        loc_val = jnp.sum(jnp.where(sel, val, 0.0), axis=1)
        loc_pol = jnp.sum(jnp.where(sel, pol_r, 0), axis=1)
        loc_flat = m * (n_r * 2) + first_r * 2 + loc_pol
        take = loc_val < best_val                          # keeps earliest m
        best_val = jnp.where(take, loc_val, best_val)
        best_flat = jnp.where(take, loc_flat, best_flat)

    m_ref[...] = best_flat // (n_r * 2)
    r_ref[...] = (best_flat // 2) % n_r
    pol_ref[...] = best_flat % 2


@functools.partial(jax.jit, static_argnames=("n_total", "block_n",
                                             "interpret"))
def config_argmin(b, c, acc, xi, size, eff, q, v, *, n_total: int,
                  block_n: int = 1024, interpret: bool = False):
    """Streaming (m, r, policy) argmin; returns ``(r_idx, m_idx, pol)``."""
    n, n_m, n_r = acc.shape
    block_n = min(block_n, n)
    grid = (pl.cdiv(n, block_n),)
    qv = jnp.stack([jnp.asarray(q, jnp.float32),
                    jnp.asarray(v, jnp.float32)]).reshape(1, 2)
    kernel = functools.partial(_config_kernel, n_total=n_total, n_m=n_m,
                               n_r=n_r)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 2), lambda i: (0, 0),
                         memory_space=pltpu.SMEM),               # q, V
            pl.BlockSpec((block_n,), lambda i: (i,)),            # b
            pl.BlockSpec((block_n,), lambda i: (i,)),            # c
            pl.BlockSpec((block_n,), lambda i: (i,)),            # eff
            pl.BlockSpec((block_n, n_m, n_r), lambda i: (i, 0, 0)),  # acc
            pl.BlockSpec((n_m, n_r), lambda i: (0, 0)),          # xi
            pl.BlockSpec((n_r,), lambda i: (0,)),                # size
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.int32)] * 3,
        interpret=interpret,
    )(qv, b, c, eff, acc, xi, size)
    return tuple(out)


# ---------------------------------------------------------------------------
# Per-server on-chip water-filling (Algorithm 1 lines 4/5)
# ---------------------------------------------------------------------------

def _waterfill_kernel(scale_ref, p_ref, pol_ref, other_ref, lo_ref, hi_ref,
                      cf_ref, member_ref, x_ref, *, mode: str,
                      outer_iters: int, inner_iters: int,
                      final_inner_iters: int):
    scale = scale_ref[...]                                # [Np]
    p = p_ref[...]
    is_l = pol_ref[...] == aopi.LCFSP
    other = other_ref[...]                                # mu (bw) / lam (c)
    lo = lo_ref[...]
    hi = hi_ref[...]
    cf = cf_ref[...]                                      # closed-form coeff
    member = member_ref[...]                              # [S, Np] 0/1

    def h_fn(x):
        if mode == "bandwidth":
            lam = jnp.maximum(scale * x, _EPS)
            d_l = aopi.d_aopi_lcfsp_dlam(lam, other, p)
            d_f = aopi.d_aopi_fcfs_dlam(jnp.minimum(lam, 0.999 * other),
                                        other, p)
        else:
            mu = jnp.maximum(scale * x, _EPS)
            d_l = aopi.d_aopi_lcfsp_dmu(other, mu, p)
            d_f = aopi.d_aopi_fcfs_dmu(jnp.minimum(other, 0.999 * mu),
                                       mu, p)
        d = jnp.where(is_l, d_l, d_f)
        return jnp.maximum(-d * scale, 0.0)

    def solve_h_equals_nu(nu, blo, bhi, iters):
        def body(_, state):
            a, b = state
            mid = 0.5 * (a + b)
            go_up = h_fn(mid) >= nu
            return jnp.where(go_up, mid, a), jnp.where(go_up, b, mid)
        a, b = jax.lax.fori_loop(0, iters, body, (blo, bhi))
        return 0.5 * (a + b)

    n_servers = member.shape[0]

    def per_camera(v_s):
        """Broadcast a per-server [S, 1] value to cameras [Np] (zero on
        padding slots, whose membership column is all-zero)."""
        return jnp.sum(member * v_s, axis=0)

    def alloc_at(log_nu_s, blo, bhi, iters):
        nu = per_camera(jnp.exp(log_nu_s))                # [Np] duals
        x_cl = jnp.sqrt(cf / jnp.maximum(scale * nu, _EPS))
        x_bi = solve_h_equals_nu(nu, blo, bhi, iters)
        return jnp.clip(jnp.where(is_l, x_cl, x_bi), lo, hi)

    def bracket(xa, xb):
        pad = 0.25 * jnp.maximum(xa - xb, 0.0) + 1e-7
        return jnp.maximum(lo, xb - pad), jnp.minimum(hi, xa + pad)

    def fill_at(log_nu_s, xa, xb, iters):
        blo, bhi = bracket(xa, xb)
        x = alloc_at(log_nu_s, blo, bhi, iters)
        f = jnp.sum(member * x[None, :], axis=1,
                    keepdims=True) - 1.0                  # [S, 1]
        return x, f

    a0 = jnp.full((n_servers, 1), _LOG_NU_LO, jnp.float32)
    b0 = jnp.full((n_servers, 1), _LOG_NU_HI, jnp.float32)
    xa0, fa0 = fill_at(a0, hi, lo, inner_iters + 4)
    xb0, fb0 = fill_at(b0, hi, lo, inner_iters + 4)

    def body(_, state):
        a, b, fa, fb, xa, xb = state
        denom = fa - fb
        t = jnp.where(jnp.abs(denom) > 1e-12, fa / denom, 0.5)
        t = jnp.clip(t, 0.05, 0.95)
        mid = a + t * (b - a)
        x, f = fill_at(mid, xa, xb, inner_iters)
        over = f > 0.0             # over budget -> raise the price
        over_n = per_camera(over.astype(jnp.float32)) > 0.5
        return (jnp.where(over, mid, a), jnp.where(over, b, mid),
                jnp.where(over, f, 0.5 * fa),    # Illinois halving of the
                jnp.where(over, 0.5 * fb, f),    # retained endpoint
                jnp.where(over_n, x, xa), jnp.where(over_n, xb, x))

    a, b, _, _, xa, xb = jax.lax.fori_loop(
        0, outer_iters, body, (a0, b0, fa0, fb0, xa0, xb0))
    blo, bhi = bracket(xa, xb)
    # If the total cap is below budget the constraint is slack: keep caps.
    x_ref[...] = alloc_at(0.5 * (a + b), blo, bhi, final_inner_iters)


@functools.partial(jax.jit, static_argnames=("mode", "outer_iters",
                                             "inner_iters",
                                             "final_inner_iters",
                                             "interpret"))
def waterfill(scale, p, pol, other, lo, hi, cf, member, *, mode: str,
              outer_iters: int = 16, inner_iters: int = 6,
              final_inner_iters: int = 20, interpret: bool = False):
    """Run the fused water-fill on flat layout vectors.

    The seven per-camera vectors are ``[Np]`` in the layout's sorted
    (contiguous-per-server, lane-padded) order and ``member`` is the
    layout's ``[S, Np]`` membership matrix (``ops.ServerLayout.member``).
    Returns normalized allocations ``[Np]`` in the same order. One grid
    program holds the whole fleet in VMEM (~9 f32 vectors + the
    membership matrix — N up to ~10^5 at edge-scale server counts).
    """
    cap = scale.shape[0]
    n_servers = member.shape[0]
    kernel = functools.partial(_waterfill_kernel, mode=mode,
                               outer_iters=outer_iters,
                               inner_iters=inner_iters,
                               final_inner_iters=final_inner_iters)
    vec = pl.BlockSpec((cap,), lambda: (0,))
    return pl.pallas_call(
        kernel,
        grid=(),
        in_specs=[vec] * 7 + [pl.BlockSpec((n_servers, cap),
                                           lambda: (0, 0))],
        out_specs=vec,
        out_shape=jax.ShapeDtypeStruct((cap,), jnp.float32),
        interpret=interpret,
    )(scale, p, pol, other, lo, hi, cf, member)
