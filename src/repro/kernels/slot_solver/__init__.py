from .ops import (ServerLayout, config_argmin, server_layout,
                  waterfill_bandwidth, waterfill_compute)
from .ref import (config_argmin_ref, waterfill_bandwidth_ref,
                  waterfill_compute_ref)

__all__ = ["ServerLayout", "server_layout", "config_argmin",
           "waterfill_bandwidth", "waterfill_compute", "config_argmin_ref",
           "waterfill_bandwidth_ref", "waterfill_compute_ref"]
