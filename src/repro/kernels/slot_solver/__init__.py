from .ops import (ServerLayout, baseline_argmax, config_argmin,
                  server_layout, waterfill_bandwidth, waterfill_compute,
                  waterfill_pair)
from .ref import (baseline_argmax_ref, config_argmin_ref,
                  waterfill_bandwidth_ref, waterfill_compute_ref)

__all__ = ["ServerLayout", "server_layout", "config_argmin",
           "baseline_argmax", "waterfill_bandwidth", "waterfill_compute",
           "waterfill_pair", "config_argmin_ref", "baseline_argmax_ref",
           "waterfill_bandwidth_ref", "waterfill_compute_ref"]
