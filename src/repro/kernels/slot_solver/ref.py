"""Pure-jnp oracles for the fused slot-solver kernels.

``config_argmin_ref`` is the Algorithm-1 line-3 exhaustive search exactly as
the jnp backend runs it — it materializes the full ``[N, M, R, 2]``
config-score tensor (the HBM traffic the streaming Pallas kernel exists to
avoid) and takes one flat argmin per camera. ``waterfill_bandwidth_ref`` /
``waterfill_compute_ref`` re-export the production water-filling allocators
(Illinois outer loop + bracketed inner root-find over ``segment_sum``
round trips) so parity tests compare the kernel against the code the jnp
backend actually dispatches.
"""
from __future__ import annotations

import jax.numpy as jnp

from ...core import allocate, aopi

waterfill_bandwidth_ref = allocate.waterfill_bandwidth
waterfill_compute_ref = allocate.waterfill_compute


def config_argmin_ref(b, c, acc, xi, size, eff, q, v, n_total):
    """Algorithm 1 line 3: exhaustive search over (m, r, policy).

    Returns per-camera ``(r_idx, m_idx, pol)`` minimizing the
    drift-plus-penalty score ``(V * AoPI - q * acc) / n_total`` over the
    full config grid. Ties break to the first flat index in
    (m-major, r, policy) order — the Pallas kernel replicates this.
    """
    # lam[n, r]: resolution changes frame size; mu[n, m, r]: both change xi.
    lam = (b * eff)[:, None] / size[None, :]
    mu = c[:, None, None] / xi[None, :, :]
    lam_b = lam[:, None, :]                            # [n, 1, r]
    a_f = aopi.aopi_fcfs(jnp.broadcast_to(lam_b, mu.shape), mu,
                         jnp.maximum(acc, 1e-3))
    a_l = aopi.aopi_lcfsp(jnp.broadcast_to(lam_b, mu.shape), mu,
                          jnp.maximum(acc, 1e-3))
    a = jnp.stack([a_f, a_l], axis=-1)                 # [n, m, r, 2]
    score = (v * a - q * acc[..., None]) / n_total
    flat = score.reshape(score.shape[0], -1)
    best = jnp.argmin(flat, axis=1)
    n_r = xi.shape[1]
    m_idx = (best // (n_r * 2)).astype(jnp.int32)
    r_idx = ((best // 2) % n_r).astype(jnp.int32)
    pol = (best % 2).astype(jnp.int32)
    return r_idx, m_idx, pol


def baseline_argmax_ref(b, c, acc, xi, size, eff, *, mode, threshold):
    """DOS/JCAB config scans exactly as the materialized jnp baselines run
    them: build the full ``[N, M, R]`` latency/score tensors and take one
    flat (m-major) argmax per camera. Returns ``(m_idx, r_idx)``.
    """
    n = acc.shape[0]
    n_r = xi.shape[1]
    lam = (b * eff)[:, None, None] / size[None, None, :]
    mu = c[:, None, None] / xi[None, :, :]
    latency = 1.0 / jnp.maximum(lam, 1e-9) + 1.0 / jnp.maximum(mu, 1e-9)
    if mode == "dos":
        score = acc - threshold * latency
        best = jnp.argmax(score.reshape(n, -1), axis=1)
    elif mode == "jcab":
        ok = latency <= threshold
        score = jnp.where(ok, acc, -jnp.inf)
        best = jnp.argmax(score.reshape(n, -1), axis=1)
        none_ok = ~ok.reshape(n, -1).any(axis=1)
        fallback = jnp.argmin(latency.reshape(n, -1), axis=1)
        best = jnp.where(none_ok, fallback, best)
    else:
        raise ValueError(f"unknown baseline scan mode {mode!r}")
    return (best // n_r).astype(jnp.int32), (best % n_r).astype(jnp.int32)
