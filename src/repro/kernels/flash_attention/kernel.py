"""Pallas TPU flash attention (training / prefill).

TPU-native adaptation: online-softmax tiling with explicit VMEM BlockSpecs.
Grid = (batch, q_heads, q_blocks, kv_blocks); the kv_blocks axis is the
innermost (sequential on TPU), so the running max / denominator / output
accumulator live in VMEM scratch that persists across kv steps — the
canonical MXU-friendly flash schedule (block sizes are multiples of 128 to
match the 128x128 systolic array; accumulation in f32).

GQA is handled in the index map (kv head = q head // group) so KV tiles are
fetched once per group from HBM, never materialized repeated.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 scale: float, causal: bool, block_q: int, block_k: int,
                 kv_blocks: int, q_offset: int, kv_total: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0) + q_offset
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)

    run = True
    if causal:
        # Skip fully-masked kv blocks (upper triangle).
        run = (ki * block_k) <= (qi * block_q + q_offset + block_q - 1)

    @pl.when(run)
    def _step():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale
        k = k_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        # Mask padded kv rows (when t % block_k != 0 the tail block reads
        # garbage — without the select, 0 * NaN poisons the accumulator).
        kv_valid = k_pos < kv_total
        s = jnp.where(kv_valid, s, NEG_INF)
        if causal:
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        v_row = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, v.shape, 0)
        v = jnp.where(v_row < kv_total, v, 0.0)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + pv
        m_ref[...] = m_new

    @pl.when(ki == kv_blocks - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "interpret", "scale",
                     "q_offset"))
def flash_attention(q, k, v, *, causal: bool = True,
                    scale: float | None = None, q_offset: int | None = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q: [b, s, h, d]; k, v: [b, t, kvh, d] -> [b, s, h, d]."""
    b, s, h, d = q.shape
    t, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    scale = scale if scale is not None else d ** -0.5
    q_offset = (t - s) if q_offset is None else q_offset
    block_q = min(block_q, s)
    block_k = min(block_k, t)
    q_blocks = pl.cdiv(s, block_q)
    kv_blocks = pl.cdiv(t, block_k)

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, block_q=block_q,
        block_k=block_k, kv_blocks=kv_blocks, q_offset=q_offset,
        kv_total=t)

    return pl.pallas_call(
        kernel,
        grid=(b, h, q_blocks, kv_blocks),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, d),
                         lambda bi, hi, qi, ki: (bi, qi, hi, 0)),
            pl.BlockSpec((1, block_k, 1, d),
                         lambda bi, hi, qi, ki, g=g: (bi, ki, hi // g, 0)),
            pl.BlockSpec((1, block_k, 1, d),
                         lambda bi, hi, qi, ki, g=g: (bi, ki, hi // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, d),
                               lambda bi, hi, qi, ki: (bi, qi, hi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),    # running max
            pltpu.VMEM((block_q,), jnp.float32),    # denominator
            pltpu.VMEM((block_q, d), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
