from .ops import attention
from .ref import mha_ref
from .kernel import flash_attention

__all__ = ["attention", "mha_ref", "flash_attention"]
