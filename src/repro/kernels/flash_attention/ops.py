"""Jit'd dispatch for attention: pallas | interpret | ref.

``ref`` (grouped-einsum jnp) is the GSPMD path used for CPU runs and the
multi-pod dry-run; ``pallas`` targets TPU; ``interpret`` executes the Pallas
kernel body in Python on CPU (correctness validation, used by tests).
"""
from __future__ import annotations

import jax

from . import kernel, ref

_IMPLS = ("ref", "pallas", "interpret")


def attention(q, k, v, *, causal: bool = True, scale=None, q_offset=None,
              kv_len=None, impl: str = "ref", block_q: int = 128,
              block_k: int = 128):
    """Unified attention entry point. See ref.mha_ref for semantics."""
    if impl not in _IMPLS:
        raise ValueError(f"impl={impl!r} not in {_IMPLS}")
    if impl == "ref" or kv_len is not None:
        # Ragged kv_len is only supported on the ref path (serving engine).
        return ref.mha_ref(q, k, v, causal=causal, scale=scale,
                           q_offset=q_offset, kv_len=kv_len)
    return kernel.flash_attention(
        q, k, v, causal=causal, scale=scale, q_offset=q_offset,
        block_q=block_q, block_k=block_k, interpret=(impl == "interpret"))
