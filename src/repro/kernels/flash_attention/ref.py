"""Pure-jnp oracle for (grouped-query) causal attention.

This is also the GSPMD path used by the multi-pod dry-run. KV heads are
broadcast to the full head count before the score einsum: the broadcast is
free under XLA fusion, and it keeps a clean ``heads`` dim that GSPMD can
shard 16-way end-to-end (the grouped-reshape formulation loses the head
sharding through the (h -> kvh, g) split and silently replicates attention
across the model axis — found via the dry-run FLOP audit, EXPERIMENTS.md
§Perf iteration 0).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...sharding import ctx

NEG_INF = -1e30


def _expand_kv(k, h):
    kvh = k.shape[2]
    if kvh == h:
        return k
    g = h // kvh
    k = jnp.repeat(k, g, axis=2)
    return k


def mha_ref(q, k, v, *, causal: bool = True, scale: float | None = None,
            q_offset: int | jnp.ndarray | None = None,
            kv_len: jnp.ndarray | None = None):
    """Grouped-query attention.

    Args:
      q: [b, s, h, d];  k, v: [b, t, kvh, d]  (h % kvh == 0).
      causal: apply a causal mask with q positions offset by ``q_offset``
        (default t - s, the prefill/decode-with-cache convention).
      kv_len: optional [b] valid cache lengths; keys at index >= kv_len are
        masked out (ragged decode batches).
    Returns: [b, s, h, dv] in q.dtype.
    """
    b, s, h, d = q.shape
    t = k.shape[1]
    scale = scale if scale is not None else d ** -0.5

    act = ("batch", None, "heads", None)
    q = ctx.constrain(q, act)
    k = ctx.constrain(_expand_kv(k, h), act)
    v = ctx.constrain(_expand_kv(v, h), act)

    scores = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32) * scale,
                        k.astype(jnp.float32))
    scores = ctx.constrain(scores, ("batch", "heads", None, None))

    if causal:
        off = (t - s) if q_offset is None else q_offset
        q_pos = jnp.arange(s)[:, None] + off               # [s, 1]
        k_pos = jnp.arange(t)[None, :]                     # [1, t]
        scores = jnp.where(q_pos >= k_pos, scores, NEG_INF)
    if kv_len is not None:
        valid = jnp.arange(t)[None, :] < kv_len[:, None]   # [b, t]
        scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)

    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", probs, v.astype(jnp.float32))
    return ctx.constrain(out.astype(q.dtype), act)
