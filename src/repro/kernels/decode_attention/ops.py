"""Dispatch for decode attention: pallas | interpret | ref."""
from __future__ import annotations

from . import kernel, ref


def decode_attention(q, k_cache, v_cache, kv_len, *, impl: str = "ref",
                     block_k: int = 512):
    if impl == "ref":
        return ref.decode_ref(q, k_cache, v_cache, kv_len)
    return kernel.flash_decode(q, k_cache, v_cache, kv_len,
                               block_k=block_k,
                               interpret=(impl == "interpret"))
