"""Pallas TPU flash-decode: one query token vs a long KV cache.

Decode is memory-bound (the roofline term is the cache read), so the kernel
streams KV tiles HBM->VMEM once, keeping partial max/denominator/accumulator
in VMEM scratch across the sequential cache-block grid axis. All q heads of
one KV group are processed together (shape [g, d], g = h/kvh) so each cache
tile is read exactly once — the TPU analogue of flash-decoding's KV-split,
with the split mapped onto the sequential grid instead of SM blocks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                   acc_ref, *, scale: float, block_k: int, kv_blocks: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_len = len_ref[pl.program_id(0)]
    base = ki * block_k
    run = base < kv_len

    @pl.when(run)
    def _step():
        q = q_ref[0, 0, :, :].astype(jnp.float32) * scale     # [g, d]
        k = k_ref[0, :, 0, :].astype(jnp.float32)             # [bk, d]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        pos = base + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < kv_len, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        v = v_ref[0, :, 0, :].astype(jnp.float32)             # [bk, d]
        v_row = base + jax.lax.broadcasted_iota(jnp.int32, v.shape, 0)
        v = jnp.where(v_row < kv_len, v, 0.0)   # padded-tail garbage guard
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + pv
        m_ref[...] = m_new

    @pl.when(ki == kv_blocks - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0, :, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_k", "interpret"))
def flash_decode(q, k_cache, v_cache, kv_len, *, block_k: int = 512,
                 interpret: bool = False):
    """q: [b, h, d]; caches: [b, t, kvh, d]; kv_len: int32 [b] -> [b, h, d]."""
    b, h, d = q.shape
    t, kvh = k_cache.shape[1], k_cache.shape[2]
    g = h // kvh
    scale = d ** -0.5
    block_k = min(block_k, t)
    kv_blocks = pl.cdiv(t, block_k)
    q4 = q.reshape(b, kvh, g, d)

    kernel = functools.partial(_decode_kernel, scale=scale, block_k=block_k,
                               kv_blocks=kv_blocks)

    out = pl.pallas_call(
        kernel,
        grid=(b, kvh, kv_blocks),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),   # kv_len (prefetch)
            pl.BlockSpec((1, 1, g, d), lambda bi, hi, ki: (bi, hi, 0, 0)),
            pl.BlockSpec((1, block_k, 1, d),
                         lambda bi, hi, ki: (bi, ki, hi, 0)),
            pl.BlockSpec((1, block_k, 1, d),
                         lambda bi, hi, ki: (bi, ki, hi, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda bi, hi, ki: (bi, hi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        interpret=interpret,
    )(kv_len, q4, k_cache, v_cache)
    return out.reshape(b, h, d)
