from .ops import decode_attention
from .ref import decode_ref
from .kernel import flash_decode

__all__ = ["decode_attention", "decode_ref", "flash_decode"]
