"""Pure-jnp oracle for single-token decode attention over a KV cache.

Same repeat-KV formulation as flash_attention.ref (GSPMD head sharding);
when kv_heads cannot shard over the model axis the cache is sequence-
sharded instead and the softmax reduction becomes a split-KV partial
reduction — exactly the flash-decoding schedule, inserted by GSPMD.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...sharding import ctx

NEG_INF = -1e30


def _seq_sharded(t: int) -> bool:
    rules = ctx.current()
    if not rules:
        return False
    from ...sharding.spec import spec_dims
    return spec_dims((t,), ("cache_seq",), rules)[0] is not None


def decode_ref(q, k_cache, v_cache, kv_len):
    """q: [b, h, d]; caches: [b, t, kvh, d]; kv_len: [b] valid lengths.

    Returns [b, h, dv]. Keys at index >= kv_len are masked.

    When the cache is sequence-sharded (kv_heads < TP archs) the compute is
    explicitly split-KV: every model-rank scores its cache shard for ALL
    heads and the softmax reduces partials across ranks — tiny [b, h(, d)]
    collectives. Without these constraints GSPMD keeps q heads-sharded and
    all-gathers the whole f32 cache per layer (~2 GiB/layer at 32k —
    EXPERIMENTS.md §Perf cell C iteration 2).
    """
    b, h, d = q.shape
    t, kvh = k_cache.shape[1], k_cache.shape[2]
    g = h // kvh
    scale = d ** -0.5
    split_kv = _seq_sharded(t)
    q_axes = ("batch", None, None) if split_kv else ("batch", "heads", None)
    q = ctx.constrain(q, q_axes)
    cache_axes = ("batch", "cache_seq", "kv_heads", None)
    k_cache = ctx.constrain(k_cache, cache_axes)
    v_cache = ctx.constrain(v_cache, cache_axes)
    if g > 1:
        k_cache = jnp.repeat(k_cache, g, axis=2)
        v_cache = jnp.repeat(v_cache, g, axis=2)
    scores = jnp.einsum("bhd,bthd->bht", q.astype(jnp.float32) * scale,
                        k_cache.astype(jnp.float32))
    if split_kv:
        scores = ctx.constrain(scores, ("batch", None, "cache_seq"))
    valid = jnp.arange(t)[None, :] < kv_len[:, None]       # [b, t]
    scores = jnp.where(valid[:, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bht,bthd->bhd", probs,
                     v_cache.astype(jnp.float32))
    return ctx.constrain(out.astype(q.dtype), q_axes)
