"""Pure-jnp oracle for the Mamba selective scan (S6).

    h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t * x_t
    y_t = C_t . h_t + D * x_t

The reference materializes the full [b, s, inner, state] state trajectory
via an associative scan — exact but memory-hungry; ``chunked`` bounds the
transient to one chunk (what the Pallas kernel does in VMEM).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _scan_combine(c1, c2):
    a1, b1 = c1
    a2, b2 = c2
    return a1 * a2, a2 * b1 + b2


def selective_scan_ref(x, dt, A, B, C, D, h0=None):
    """x, dt: [b, s, inner]; A: [inner, state]; B, C: [b, s, state];
    D: [inner]. Returns (y [b, s, inner], h_last [b, inner, state])."""
    x32 = x.astype(jnp.float32)
    dt32 = dt.astype(jnp.float32)
    deltaA = jnp.exp(dt32[..., None] * A[None, None])          # [b,s,i,n]
    deltaBx = dt32[..., None] * B[:, :, None, :].astype(jnp.float32) \
        * x32[..., None]
    a, h = jax.lax.associative_scan(_scan_combine, (deltaA, deltaBx),
                                    axis=1)
    if h0 is not None:
        h = a * h0[:, None].astype(jnp.float32) + h
    y = jnp.einsum("bsin,bsn->bsi", h, C.astype(jnp.float32)) \
        + D[None, None].astype(jnp.float32) * x32
    return y.astype(x.dtype), h[:, -1]


def selective_scan_chunked(x, dt, A, B, C, D, h0=None, chunk: int = 256):
    """Chunked variant: lax.scan over chunks, associative scan inside.

    Bounds the materialized state to [b, chunk, inner, state] — the
    GSPMD/dry-run path for full-scale shapes.
    """
    b, s, inner = x.shape
    n = A.shape[1]
    if s % chunk != 0:
        return selective_scan_ref(x, dt, A, B, C, D, h0)
    nc = s // chunk
    h0 = (jnp.zeros((b, inner, n), jnp.float32) if h0 is None
          else h0.astype(jnp.float32))

    def body(h, args):
        xc, dtc, Bc, Cc = args
        yc, h_new = selective_scan_ref(xc, dtc, A, Bc, Cc, D, h0=h)
        return h_new, yc

    def split(t):
        return t.reshape(b, nc, chunk, *t.shape[2:]).swapaxes(0, 1)

    h_last, ys = jax.lax.scan(
        body, h0, (split(x), split(dt), split(B), split(C)))
    y = ys.swapaxes(0, 1).reshape(b, s, inner)
    return y, h_last


def selective_step(x, dt, A, B, C, D, h):
    """Single decode step. x, dt: [b, inner]; B, C: [b, state];
    h: [b, inner, state]. Returns (y [b, inner], h_new)."""
    x32 = x.astype(jnp.float32)
    dt32 = dt.astype(jnp.float32)
    dA = jnp.exp(dt32[..., None] * A[None])
    h_new = dA * h.astype(jnp.float32) \
        + dt32[..., None] * B[:, None, :].astype(jnp.float32) * x32[..., None]
    y = jnp.einsum("bin,bn->bi", h_new, C.astype(jnp.float32)) \
        + D[None].astype(jnp.float32) * x32
    return y.astype(x.dtype), h_new
