from .ops import selective_scan, selective_step
from .ref import (selective_scan_chunked, selective_scan_ref)
__all__ = ["selective_scan", "selective_step", "selective_scan_ref",
           "selective_scan_chunked"]
