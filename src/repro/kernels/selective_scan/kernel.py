"""Pallas TPU selective-scan kernel.

TPU adaptation of the Mamba CUDA kernel's core insight — never materialize
the [b, s, inner, state] state trajectory in HBM. The CUDA version fuses the
recurrence into registers per thread; on TPU we tile ``inner`` across the
grid and keep the running state h [block_i, state] in VMEM scratch while
marching sequentially over sequence chunks (innermost grid axis). All
elementwise VPU work; the only HBM traffic is the O(b * s * inner) inputs
and outputs — the same bytes a single elementwise op would touch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, h0_ref,
                 y_ref, hout_ref, h_ref, *, chunk: int, seq_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = h0_ref[0].astype(jnp.float32)

    a = a_ref[...].astype(jnp.float32)                    # [bi, n]
    d = d_ref[...].astype(jnp.float32)                    # [bi]

    def step(t, h):
        xt = x_ref[0, t, :].astype(jnp.float32)           # [bi]
        dtt = dt_ref[0, t, :].astype(jnp.float32)         # [bi]
        bt = b_ref[0, t, :].astype(jnp.float32)           # [n]
        ct = c_ref[0, t, :].astype(jnp.float32)           # [n]
        da = jnp.exp(dtt[:, None] * a)                    # [bi, n]
        h = da * h + (dtt * xt)[:, None] * bt[None, :]
        y = jnp.sum(h * ct[None, :], axis=1) + d * xt
        y_ref[0, t, :] = y.astype(y_ref.dtype)
        return h

    h_ref[...] = jax.lax.fori_loop(0, chunk, step, h_ref[...])

    @pl.when(ci == seq_chunks - 1)
    def _finish():
        hout_ref[0] = h_ref[...].astype(hout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "block_i",
                                             "interpret"))
def selective_scan(x, dt, A, B, C, D, h0=None, *, chunk: int = 256,
                   block_i: int = 512, interpret: bool = False):
    """Fused selective scan. Shapes as in ref.selective_scan_ref."""
    b, s, inner = x.shape
    n = A.shape[1]
    chunk = min(chunk, s)
    block_i = min(block_i, inner)
    seq_chunks = pl.cdiv(s, chunk)
    i_blocks = pl.cdiv(inner, block_i)
    if h0 is None:
        h0 = jnp.zeros((b, inner, n), jnp.float32)

    kernel = functools.partial(_scan_kernel, chunk=chunk,
                               seq_chunks=seq_chunks)

    y, h_last = pl.pallas_call(
        kernel,
        grid=(b, i_blocks, seq_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, block_i),
                         lambda bi, ii, ci: (bi, ci, ii)),     # x
            pl.BlockSpec((1, chunk, block_i),
                         lambda bi, ii, ci: (bi, ci, ii)),     # dt
            pl.BlockSpec((block_i, n), lambda bi, ii, ci: (ii, 0)),  # A
            pl.BlockSpec((1, chunk, n),
                         lambda bi, ii, ci: (bi, ci, 0)),      # B
            pl.BlockSpec((1, chunk, n),
                         lambda bi, ii, ci: (bi, ci, 0)),      # C
            pl.BlockSpec((block_i,), lambda bi, ii, ci: (ii,)),     # D
            pl.BlockSpec((1, block_i, n),
                         lambda bi, ii, ci: (bi, ii, 0)),      # h0
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_i),
                         lambda bi, ii, ci: (bi, ci, ii)),     # y
            pl.BlockSpec((1, block_i, n),
                         lambda bi, ii, ci: (bi, ii, 0)),      # h_last
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, inner), x.dtype),
            jax.ShapeDtypeStruct((b, inner, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_i, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B, C, D, h0)
    return y, h_last
