"""Dispatch for the selective scan: pallas | interpret | ref | chunked."""
from __future__ import annotations

from . import kernel, ref


def selective_scan(x, dt, A, B, C, D, h0=None, *, impl: str = "chunked",
                   chunk: int = 256, block_i: int = 512):
    if impl == "ref":
        return ref.selective_scan_ref(x, dt, A, B, C, D, h0)
    if impl == "chunked":
        return ref.selective_scan_chunked(x, dt, A, B, C, D, h0, chunk=chunk)
    return kernel.selective_scan(x, dt, A, B, C, D, h0, chunk=chunk,
                                 block_i=block_i,
                                 interpret=(impl == "interpret"))


selective_step = ref.selective_step
