"""Architecture / run configuration.

Every assigned architecture is one ``ModelConfig`` (exact public dims) plus a
``reduced()`` variant for CPU smoke tests. Input shapes are the four assigned
cells (train_4k / prefill_32k / decode_32k / long_500k); each cell records
which step it lowers (train_step vs serve_step) and whether the arch family
supports it (long_500k needs sub-quadratic attention; decode needs a
decoder). See DESIGN.md §Arch-applicability.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head latent attention dims (MiniCPM3 / DeepSeek-style)."""
    q_lora: int = 768
    kv_lora: int = 256
    nope_dim: int = 64       # per-head non-rotary dims
    rope_dim: int = 32       # shared rotary dims
    v_dim: int = 64


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0      # qwen2-moe style always-on experts
    expert_d_ff: int = 0
    moe_period: int = 1            # every k-th layer uses MoE
    capacity_factor: float = 1.25
    # --- attention flavour ---
    attn_type: str = "gqa"         # gqa | mla
    qkv_bias: bool = False
    mla: Optional[MLAConfig] = None
    rope_theta: float = 1e4
    # --- hybrid (jamba) ---
    attn_period: int = 0           # attn every k-th layer, rest SSM
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0           # 0 -> d_model // 16
    # --- xLSTM ---
    slstm_period: int = 0          # sLSTM every k-th layer, rest mLSTM
    # --- enc-dec (seamless) ---
    enc_layers: int = 0            # 0 -> decoder-only
    # --- vlm ---
    cross_attn_period: int = 0     # cross-attn every k-th layer
    n_vision_tokens: int = 1601    # stub frontend: precomputed patch embeds
    # --- numerics / training ---
    dtype: str = "bfloat16"
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    tie_embeddings: bool = False
    remat: str = "full"            # full | dots | none
    fsdp: bool = True              # shard weights over the data axis too
    # --- divisibility padding (TP) ---
    vocab_pad_to: int = 256
    expert_pad_to: int = 1         # set to EP degree at mesh-build time
    pad_heads_to: int = 0          # perf opt-in: pad q-heads for TP (e.g.
    #                                yi-34b 56 -> 64; extra heads are live
    #                                capacity — see EXPERIMENTS.md §Perf)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_heads(self) -> int:
        return max(self.n_heads, self.pad_heads_to) if self.pad_heads_to \
            else self.n_heads

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab, self.vocab_pad_to)

    @property
    def dec_layers(self) -> int:
        return self.n_layers if self.enc_layers == 0 else self.n_layers

    @property
    def resolved_dt_rank(self) -> int:
        return self.ssm_dt_rank or max(self.d_model // 16, 1)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (SSM / hybrid — O(1) or tiny KV state)."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True   # all assigned archs autoregress (enc-dec has decoder)

    def padded_experts(self, ep: int) -> int:
        """Experts padded to a multiple of the expert-parallel degree."""
        return _round_up(self.n_experts, ep) if self.n_experts else 0

    def reduced(self) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        changes = dict(
            n_layers=max(2, min(4, self.attn_period or 2) * 2)
            if self.attn_period else (4 if self.enc_layers else 2),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads <
            self.n_heads else 4,
            d_ff=0 if self.d_ff == 0 else 128,
            vocab=256,
            head_dim=16,
            n_vision_tokens=8,
            remat="none",
            fsdp=False,
            dtype="float32",
        )
        if self.is_moe:
            changes.update(n_experts=4, top_k=2, expert_d_ff=64,
                           n_shared_experts=min(self.n_shared_experts, 1))
        if self.mla is not None:
            changes.update(mla=MLAConfig(q_lora=32, kv_lora=16, nope_dim=8,
                                         rope_dim=8, v_dim=8))
        if self.enc_layers:
            changes.update(enc_layers=2, n_layers=2)
        if self.attn_period:
            changes.update(attn_period=4, n_layers=8)
        if self.slstm_period:
            changes.update(slstm_period=2, n_layers=4, head_dim=16)
        if self.cross_attn_period:
            changes.update(cross_attn_period=2, n_layers=4)
        return dataclasses.replace(self, **changes)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


TRAIN_4K = InputShape("train_4k", 4096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32768, 128, "decode")
LONG_500K = InputShape("long_500k", 524288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES = {s.name: s for s in ALL_SHAPES}


def shape_supported(cfg: ModelConfig, shape: InputShape) -> Tuple[bool, str]:
    """Whether (arch, shape) is a valid cell; reason recorded in DESIGN.md."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full attention: 500k-token KV prefill is quadratic " \
                      "(skip per spec; run for ssm/hybrid)"
    return True, ""


def smoke_shape(cfg: ModelConfig) -> InputShape:
    return InputShape("smoke", 32, 2, "train")
