"""dbrx-132b [moe] — 40L d6144 48H (GQA kv=8) ff10752 v100352, 16e top-4.

Fine-grained MoE in every layer. [hf:databricks/dbrx-base; unverified]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=10752,
    vocab=100352, head_dim=128, rope_theta=500000.0,
    n_experts=16, top_k=4, expert_d_ff=10752, moe_period=1,
)
