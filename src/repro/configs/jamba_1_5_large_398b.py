"""jamba-1.5-large-398b [hybrid] — 72L d8192 64H (GQA kv=8) ff24576 v65536.

Mamba + attention at 1:7 interleave (attn every 8th layer), MoE 16e top-2 on
every other layer. Sub-quadratic -> runs long_500k (9 attn layers hold the
KV, sharded over the model axis). [arXiv:2403.19887; hf]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=24576,
    vocab=65536, head_dim=128,
    n_experts=16, top_k=2, expert_d_ff=24576, moe_period=2,
    attn_period=8, ssm_state=16, ssm_conv=4, ssm_expand=2,
)
