"""xlstm-1.3b [ssm] — 48L d2048 4H d_ff=0 v50304 — sLSTM + mLSTM blocks.

Period-8 stacks: 7 mLSTM (matrix memory, chunkwise-parallel) + 1 sLSTM
(scalar memory, sequential scan). d_ff=0: blocks carry their own up/down
projections. O(1) state per token -> runs long_500k. [arXiv:2405.04517]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab=50304, head_dim=512, slstm_period=8,
)
