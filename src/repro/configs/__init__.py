"""Assigned-architecture configs (--arch <id>) + the paper's edge profile."""
from . import base
from .base import (ALL_SHAPES, SHAPES, InputShape, ModelConfig,
                   shape_supported, smoke_shape)

from .llama_3_2_vision_11b import CONFIG as LLAMA_32_VISION_11B
from .dbrx_132b import CONFIG as DBRX_132B
from .qwen2_moe_a2_7b import CONFIG as QWEN2_MOE_A27B
from .yi_34b import CONFIG as YI_34B
from .qwen2_5_3b import CONFIG as QWEN25_3B
from .yi_6b import CONFIG as YI_6B
from .minicpm3_4b import CONFIG as MINICPM3_4B
from .xlstm_1_3b import CONFIG as XLSTM_13B
from .jamba_1_5_large_398b import CONFIG as JAMBA_15_LARGE_398B
from .seamless_m4t_large_v2 import CONFIG as SEAMLESS_M4T_LARGE_V2

ARCHS = {c.name: c for c in [
    LLAMA_32_VISION_11B, DBRX_132B, QWEN2_MOE_A27B, YI_34B, QWEN25_3B,
    YI_6B, MINICPM3_4B, XLSTM_13B, JAMBA_15_LARGE_398B,
    SEAMLESS_M4T_LARGE_V2,
]}


def get(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]
