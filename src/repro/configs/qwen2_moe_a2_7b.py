"""qwen2-moe-a2.7b [moe] — 24L d2048 16H (kv=16) expert-ff1408 v151936.

4 shared + 60 routed experts, top-4, every layer. QKV bias (Qwen1.5 family).
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408,
    vocab=151936, head_dim=128, qkv_bias=True, rope_theta=1e6,
    n_experts=60, top_k=4, n_shared_experts=4, expert_d_ff=1408,
    moe_period=1,
)
