"""seamless-m4t-large-v2 [audio] — enc-dec 24L d1024 16H ff8192 v256206.

Encoder-decoder; the audio frontend is a STUB (input_specs() provides
precomputed frame embeddings). 24 encoder + 24 decoder layers; vocab padded
256206 -> 256256 for TP divisibility. [arXiv:2308.11596; hf]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=8192,
    vocab=256206, head_dim=64, enc_layers=24, norm="layernorm",
)
