"""minicpm3-4b [dense] — 62L d2560 40H (kv=40) ff6400 v73448 — MLA.

Multi-head latent attention: KV compressed to a 256-d latent + 32 shared
rope dims; decode uses the absorbed-matmul form (see models/mla.py).
[hf:openbmb/MiniCPM3-4B; hf]
"""
from .base import MLAConfig, ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b", family="dense",
    n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40, d_ff=6400,
    vocab=73448, head_dim=64, attn_type="mla",
    mla=MLAConfig(q_lora=768, kv_lora=256, nope_dim=64, rope_dim=32,
                  v_dim=64),
)
