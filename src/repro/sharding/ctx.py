"""Activation-sharding hints, threaded to model code via a context var.

Model code calls ``constrain(x, ("batch", None, "heads", None))`` at
partition-critical points (post-projection QKV, scores, MoE dispatch, ...).
Outside a plan context (CPU smoke tests, kernels) it is a no-op; inside
``activation_rules(rules)`` (launch/specs.py wraps every step function) it
emits with_sharding_constraint with the mesh mapping resolved by the same
divisibility-guarded rules as the parameters — this is what keeps GSPMD
from replicating attention when logical dims do not propagate through
reshapes.
"""
from __future__ import annotations

import contextlib
import contextvars

import jax
from jax.sharding import PartitionSpec

from .spec import spec_dims

_RULES = contextvars.ContextVar("activation_rules", default=None)


@contextlib.contextmanager
def activation_rules(rules: dict):
    tok = _RULES.set(rules)
    try:
        yield
    finally:
        _RULES.reset(tok)


def current() -> dict | None:
    return _RULES.get()


def constrain(x, axes):
    rules = _RULES.get()
    if rules is None:
        return x
    spec = PartitionSpec(*spec_dims(x.shape, axes, rules))
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except RuntimeError:
        # No mesh in context (rules active outside a launcher) — no-op.
        return x
