from .rules import (array_sharding, batch_shardings, data_axes, ep_degree,
                    make_rules, named)

__all__ = ["array_sharding", "batch_shardings", "data_axes", "ep_degree",
           "make_rules", "named"]
