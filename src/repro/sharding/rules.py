"""Logical-axis -> mesh-axis rules (the per-arch sharding policy).

Mesh axes: ("data", "model") single pod, ("pod", "data", "model") multi-pod.

  DP/FSDP : batch over (pod, data); weight EMBED dim over data (ZeRO-3
            style — GSPMD inserts the all-gathers) when cfg.fsdp.
  TP      : heads / mlp / expert_mlp / vocab / ssm_inner over model.
  EP      : experts over data (padded to the EP degree).
  SP      : decode KV-cache sequence over model when kv_heads cannot be
            sharded 16-way (kv_heads < 16 archs); partial-softmax reductions
            are inserted by GSPMD (flash-decode-style split-KV).

Divisibility and duplicate-mesh-axis conflicts are resolved per-leaf by
models.common.spec_dims (first dim wins); anything unresolvable falls back
to replication — visible in the roofline, which is the point.
"""
from __future__ import annotations

from jax.sharding import NamedSharding, PartitionSpec

from ..configs.base import ModelConfig
from .spec import spec_dims


def data_axes(mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def ep_degree(mesh) -> int:
    return mesh.shape["data"]


def make_rules(cfg: ModelConfig, mesh, *, shard_cache_seq=None,
               overrides: dict | None = None) -> dict:
    sizes = dict(mesh.shape)
    tp = sizes.get("model", 1)
    dp = data_axes(mesh)
    kv_shardable = cfg.n_kv_heads % tp == 0
    if shard_cache_seq is None:
        shard_cache_seq = not kv_shardable
    rules = {
        "_mesh_sizes": sizes,
        # Real Mesh object (when available) — used by the explicit
        # shard_map paths (MoE all-to-all). Fake meshes (tests) skip it.
        "_mesh": mesh if hasattr(mesh, "devices") else None,
        "batch": dp,
        "seq": None,
        "embed": "data" if cfg.fsdp else None,
        "heads": "model",
        "kv_heads": "model" if kv_shardable else None,
        "head_dim": None,
        "mlp": "model",
        "vocab": "model",
        "experts": "data",
        "expert_mlp": "model",
        "cache_seq": "model" if shard_cache_seq else None,
        "ssm_inner": "model",
        "ssm_state": None,
        "conv": None,
        "lora": None,
        "layers": None,
    }
    if overrides:
        rules.update(overrides)
    return rules


def named(mesh, template_tree, rules):
    """P-template tree -> NamedSharding tree."""
    from ..models.common import pspec_tree, tree_map
    specs = pspec_tree(template_tree, rules)
    import jax
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)


def array_sharding(mesh, shape, axes, rules) -> NamedSharding:
    """NamedSharding for a plain array described by logical axes."""
    return NamedSharding(mesh, PartitionSpec(*spec_dims(shape, axes, rules)))


def batch_shardings(cfg: ModelConfig, mesh, rules, shape, kind: str):
    """Shardings for the input batch dict of a given shape cell."""
    gb, s = shape.global_batch, shape.seq_len
    out = {}
    if kind == "decode":
        out["tokens"] = array_sharding(mesh, (gb,), ("batch",), rules)
    else:
        out["tokens"] = array_sharding(mesh, (gb, s), ("batch", "seq"),
                                       rules)
        out["labels"] = out["tokens"]
    if cfg.family == "vlm" and kind != "decode":
        out["vision_embeds"] = array_sharding(
            mesh, (gb, cfg.n_vision_tokens, cfg.d_model),
            ("batch", "seq", "embed_act"), rules)
    if cfg.family == "audio" and kind != "decode":
        out["audio_embeds"] = array_sharding(
            mesh, (gb, s, cfg.d_model), ("batch", "seq", "embed_act"),
            rules)
    if kind == "decode":
        out.pop("labels", None)
    return out
