"""Dependency-free logical-axis -> PartitionSpec dim resolution."""
from __future__ import annotations

import numpy as np


def spec_dims(shape, axes, rules: dict):
    """Per-dim mesh assignment with divisibility + no-duplicate guards.

    A mesh axis may appear at most once in a PartitionSpec; when two logical
    dims map to the same mesh axis the earlier dim wins (templates order
    EXPERTS before EMBED etc. so the intended winner comes first).
    """
    mesh_sizes = rules.get("_mesh_sizes", {})
    used: set = set()
    out = []
    for dim, ax in zip(shape, axes):
        m = rules.get(ax) if ax is not None else None
        if m is None:
            out.append(None)
            continue
        maxes = (m,) if isinstance(m, str) else tuple(m)
        extent = int(np.prod([mesh_sizes.get(a, 1) for a in maxes]))
        if extent <= 1 or dim % extent != 0 or any(a in used for a in maxes):
            out.append(None)
            continue
        used.update(maxes)
        out.append(m)
    return out
