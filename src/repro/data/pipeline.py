"""Synthetic data pipeline.

Deterministic per (seed, step) so restarts resume mid-epoch without state
files; per-host slicing mirrors a production loader (each host materializes
only its shard of the global batch). Token streams are Zipf-distributed
with document boundaries (EOS resets) — enough structure for loss curves to
be meaningful in examples/train_e2e.py.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class PipelineConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.3
    doc_len_mean: int = 512
    eos_id: int = 1


class TokenPipeline:
    def __init__(self, cfg: PipelineConfig, host_id: int = 0,
                 n_hosts: int = 1):
        assert cfg.global_batch % n_hosts == 0
        self.cfg = cfg
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.host_batch = cfg.global_batch // n_hosts

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            (self.cfg.seed, step, self.host_id))

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = self._rng(step)
        n = self.host_batch * (cfg.seq_len + 1)
        toks = rng.zipf(cfg.zipf_a, size=n).astype(np.int64)
        toks = (toks % (cfg.vocab - 2)) + 2          # reserve 0=pad, 1=eos
        # Document boundaries.
        n_docs = max(n // cfg.doc_len_mean, 1)
        cuts = rng.integers(0, n, size=n_docs)
        toks[cuts] = cfg.eos_id
        toks = toks.reshape(self.host_batch, cfg.seq_len + 1)
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def modality_stub(self, step: int, n_tokens: int, d_model: int,
                      kind: str = "vision") -> np.ndarray:
        """Precomputed frontend embeddings (the [vlm]/[audio] stub)."""
        rng = self._rng(step * 7919 + (0 if kind == "vision" else 1))
        return rng.normal(0.0, 0.3, size=(
            self.host_batch, n_tokens, d_model)).astype(np.float32)


def batch_for(cfg, shape, step: int = 0, seed: int = 0,
              reduced_batch: Optional[int] = None) -> dict:
    """Full batch dict for (arch config, input shape) — used by examples
    and smoke tests. ``reduced_batch`` overrides global_batch for CPU."""
    gb = reduced_batch or shape.global_batch
    pipe = TokenPipeline(PipelineConfig(cfg.vocab, shape.seq_len, gb,
                                        seed=seed))
    b = pipe.batch(step)
    if cfg.family == "vlm":
        b["vision_embeds"] = pipe.modality_stub(step, cfg.n_vision_tokens,
                                                cfg.d_model)
    if cfg.family == "audio":
        b["audio_embeds"] = pipe.modality_stub(step, shape.seq_len,
                                               cfg.d_model, kind="audio")
    return b
