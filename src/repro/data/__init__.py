from .pipeline import PipelineConfig, TokenPipeline, batch_for

__all__ = ["PipelineConfig", "TokenPipeline", "batch_for"]
