import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys; sys.path.insert(0, "src")
import dataclasses, json
from repro import configs
from repro.configs.base import SHAPES
from repro.launch.dryrun import measure_cell
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import terms_from_record

mesh = make_production_mesh(multi_pod=False)
out_dir = "results/hillclimb"

RUNS = [
    # A iter 3: hoist the FSDP all-gather out of the microbatch loop.
    ("A_yi34b_train__pad64_dots_hoist",
     dataclasses.replace(configs.get("yi-34b"), pad_heads_to=64,
                         remat="dots"),
     "train_4k", {"hoist_fsdp_gather": True}),
    # B iter 2: chunkwise + TP-only weights at inference (no per-layer
    # FSDP gathers inside the period scan).
    ("B_xlstm_prefill__chunk_nofsdp", configs.get("xlstm-1.3b"),
     "prefill_32k",
     {"mlstm_impl": "chunkwise", "rule_overrides": {"embed": None}}),
    # C iter 2: split-KV decode attention constraints (+ TP-only weights).
    ("C_dbrx_decode__splitkv", configs.get("dbrx-132b"), "decode_32k",
     {"rule_overrides": {"embed": None}}),
]

for name, cfg, shape_name, kw in RUNS:
    path = f"{out_dir}/{name}.json"
    try:
        rec = measure_cell(cfg, SHAPES[shape_name], mesh, **kw)
        rec["mesh_name"] = "single"
        rec["variant"] = name
        t = terms_from_record(rec)
        rec["terms"] = t
        print(f"{name}: flops={rec['extrapolated']['flops']:.3e} "
              f"coll={rec['extrapolated']['coll']:.3e} "
              f"tC={t['t_compute_s']:.3e} tM={t['t_memory_s']:.3e} "
              f"tX={t['t_collective_s']:.3e} dom={t['dominant']} "
              f"frac={t['roofline_fraction']:.3f}", flush=True)
    except Exception as e:
        import traceback
        rec = {"variant": name, "error": str(e),
               "traceback": traceback.format_exc()}
        print(f"{name}: FAIL {e}", flush=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
