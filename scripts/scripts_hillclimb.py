import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys; sys.path.insert(0, "src")
import dataclasses, json
from repro import configs
from repro.configs.base import SHAPES
from repro.launch.dryrun import measure_cell
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import terms_from_record

mesh = make_production_mesh(multi_pod=False)
out_dir = "results/hillclimb"

RUNS = [
    # Cell A: yi-34b train_4k — worst train roofline (heads 56 unshardable)
    ("A_yi34b_train__baseline", configs.get("yi-34b"), "train_4k", {}),
    ("A_yi34b_train__pad_heads64",
     dataclasses.replace(configs.get("yi-34b"), pad_heads_to=64),
     "train_4k", {}),
    ("A_yi34b_train__pad_heads64_remat_dots",
     dataclasses.replace(configs.get("yi-34b"), pad_heads_to=64,
                         remat="dots"),
     "train_4k", {}),
    # Cell B: xlstm prefill_32k — quadratic mLSTM parallel form
    ("B_xlstm_prefill__baseline", configs.get("xlstm-1.3b"),
     "prefill_32k", {}),
    ("B_xlstm_prefill__chunkwise", configs.get("xlstm-1.3b"),
     "prefill_32k", {"mlstm_impl": "chunkwise"}),
    # Cell C: dbrx decode_32k — collective-bound MoE serving cell
    ("C_dbrx_decode__baseline", configs.get("dbrx-132b"), "decode_32k", {}),
    ("C_dbrx_decode__no_fsdp", configs.get("dbrx-132b"), "decode_32k",
     {"rule_overrides": {"embed": None}}),
]

name_filter = sys.argv[1] if len(sys.argv) > 1 else ""
for name, cfg, shape_name, kw in RUNS:
    if name_filter and name_filter not in name:
        continue
    path = f"{out_dir}/{name}.json"
    if os.path.exists(path):
        print("skip (exists)", name); continue
    try:
        rec = measure_cell(cfg, SHAPES[shape_name], mesh, **kw)
        rec["mesh_name"] = "single"
        rec["variant"] = name
        t = terms_from_record(rec)
        rec["terms"] = t
        print(f"{name}: flops={rec['extrapolated']['flops']:.3e} "
              f"coll={rec['extrapolated']['coll']:.3e} "
              f"tC={t['t_compute_s']:.3e} tM={t['t_memory_s']:.3e} "
              f"tX={t['t_collective_s']:.3e} dom={t['dominant']} "
              f"frac={t['roofline_fraction']:.3f}", flush=True)
    except Exception as e:
        import traceback
        rec = {"variant": name, "error": str(e),
               "traceback": traceback.format_exc()}
        print(f"{name}: FAIL {e}", flush=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
