import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys; sys.path.insert(0, "src")
import dataclasses, json
import jax
from repro import configs
from repro.configs.base import SHAPES
from repro.launch.dryrun import measure_cell
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import terms_from_record

mesh = make_production_mesh(multi_pod=False)
island = jax.make_mesh((16, 1), ("data", "model"),
                       axis_types=(jax.sharding.AxisType.Auto,) * 2)
out_dir = "results/hillclimb"

yi = dataclasses.replace(configs.get("yi-34b"), pad_heads_to=64,
                         remat="dots")
RUNS = [
    # A iter 4: measure per-microbatch collective slope at nm=1 vs nm=2
    # (unrolled) with and without the hoisted gather.
    ("A_yi34b_train__pad64_dots_nm2", yi, "train_4k",
     {"n_microbatches": 2}, mesh),
    ("A_yi34b_train__pad64_dots_nm2_hoist", yi, "train_4k",
     {"n_microbatches": 2, "hoist_fsdp_gather": True}, mesh),
    # A iter 5: sequence-parallel residual.
    ("A_yi34b_train__pad64_dots_sp", yi, "train_4k",
     {"rule_overrides": {"act_seq": "model"}}, mesh),
    # B iter 3: island serving — one (16,1) replica; aggregate = 16x.
    ("B_xlstm_prefill__chunk_island", configs.get("xlstm-1.3b"),
     "prefill_32k", {"mlstm_impl": "chunkwise"}, island),
]

for name, cfg, shape_name, kw, m in RUNS:
    path = f"{out_dir}/{name}.json"
    try:
        rec = measure_cell(cfg, SHAPES[shape_name], m, **kw)
        rec["mesh_name"] = "island" if m is island else "single"
        rec["variant"] = name
        t = terms_from_record(rec)
        rec["terms"] = t
        print(f"{name}: flops={rec['extrapolated']['flops']:.3e} "
              f"coll={rec['extrapolated']['coll']:.3e} "
              f"tC={t['t_compute_s']:.3e} tM={t['t_memory_s']:.3e} "
              f"tX={t['t_collective_s']:.3e} dom={t['dominant']} "
              f"frac={t['roofline_fraction']:.3f}", flush=True)
    except Exception as e:
        import traceback
        rec = {"variant": name, "error": str(e),
               "traceback": traceback.format_exc()}
        print(f"{name}: FAIL {e}", flush=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
