"""``repro.obs``: registry/span/exporter units + the reconciliation
contract — ``early_replans``/``divergences`` emitted through the obs
registry must match the trace-event stream AND the legacy list
attributes across a forced-replan replay of every scenario family."""
import json

import jax
import numpy as np
import pytest

from repro import obs, scenarios
from repro.obs import export, metrics, report
from repro.serving import replay

DIMS = dict(n_cameras=4, n_slots=6, n_servers=2,
            mean_bandwidth_hz=15e6, mean_compute_flops=20e12)


@pytest.fixture(autouse=True)
def _isolated_obs():
    """Each test gets an empty registry/buffer and leaves none behind."""
    obs.reset()
    obs.configure(enabled=True)
    yield
    obs.configure(run_dir="")
    obs.reset()


# ---------------------------------------------------------------------------
# Registry + metric primitives
# ---------------------------------------------------------------------------

def test_registry_label_sets_are_distinct_series():
    r = metrics.Registry()
    r.counter("plans", policy="lbcd").inc()
    r.counter("plans", policy="lbcd").inc(2)
    r.counter("plans", policy="min").inc()
    assert r.counter("plans", policy="lbcd").value == 3.0
    assert r.counter("plans", policy="min").value == 1.0
    assert len(r.collect("plans")) == 2
    assert r.total("plans") == 4.0
    assert r.get("plans", policy="dos") is None
    assert len(r) == 2


def test_registry_rejects_kind_conflicts():
    r = metrics.Registry()
    r.counter("x", a="1")
    with pytest.raises(TypeError, match="already registered as counter"):
        r.gauge("x", a="1")
    # Same name under a different kind is still a conflict per-series
    # only — a different label set is a fresh key.
    with pytest.raises(TypeError):
        r.histogram("x", a="1")


def test_histogram_quantiles_within_bucket_resolution():
    h = metrics.Histogram("lat", {})
    rng = np.random.default_rng(0)
    vals = np.exp(rng.normal(size=5000))
    h.observe_many(vals)
    assert h.count == 5000
    assert h.total == pytest.approx(float(vals.sum()))
    for q in (0.5, 0.95, 0.99, 1.0):
        exact = float(np.quantile(vals, q))
        # Geometric buckets with base 2**0.25 -> estimate within half a
        # bucket (~10%) of the true quantile.
        assert h.quantile(q) == pytest.approx(exact, rel=0.12)
    assert h.vmin <= h.quantile(0.0) <= h.quantile(1.0) <= h.vmax


def test_histogram_underflow_bucket_and_empty():
    h = metrics.Histogram("d", {})
    assert h.quantile(0.5) == 0.0
    h.observe(0.0)
    h.observe(-1.0)
    h.observe(4.0)
    assert h.count == 3 and h.zero_count == 2
    assert h.quantile(0.5) == 0.0          # 2/3 of mass at <= 0
    assert h.quantile(1.0) == pytest.approx(4.0)


# ---------------------------------------------------------------------------
# Disabled fast path
# ---------------------------------------------------------------------------

def test_disabled_path_is_shared_noop_singletons():
    obs.configure(enabled=False)
    assert obs.counter("c") is metrics.NOOP_METRIC
    assert obs.gauge("g") is metrics.NOOP_METRIC
    assert obs.histogram("h") is metrics.NOOP_METRIC
    assert obs.span("s") is obs.NOOP_SPAN
    with obs.span("s", policy="lbcd"):
        obs.counter("c").inc()
        obs.event("e", t=3)
        obs.count_dispatch("k")
    assert len(obs.registry()) == 0
    assert obs.events() == []
    obs.configure(enabled=True)
    obs.counter("c").inc()
    assert obs.registry().total("c") == 1.0


# ---------------------------------------------------------------------------
# Spans, nesting, label context
# ---------------------------------------------------------------------------

def test_span_nesting_builds_parent_tree_and_inherits_labels():
    with obs.label_context(policy="lbcd", family="steady_ar1"):
        with obs.span("outer", k=2) as outer:
            with obs.span("inner"):
                obs.event("tick", t=7)
    evs = {e["name"]: e for e in obs.events()}
    assert set(evs) == {"outer", "inner", "tick"}
    assert evs["outer"]["parent"] == 0
    assert evs["inner"]["parent"] == outer.sid
    assert evs["tick"]["parent"] == evs["inner"]["id"]
    assert evs["tick"]["ph"] == "i"
    for e in evs.values():
        assert e["args"]["policy"] == "lbcd"
        assert e["args"]["family"] == "steady_ar1"
    assert evs["outer"]["args"]["k"] == 2
    assert evs["outer"]["dur"] >= evs["inner"]["dur"] >= 0.0


def test_span_exception_closes_records_and_flags_error():
    with pytest.raises(RuntimeError, match="boom"):
        with obs.span("outer"):
            with obs.span("inner"):
                raise RuntimeError("boom")
    evs = {e["name"]: e for e in obs.events()}
    # Both spans recorded despite the raise, error attr on each, and the
    # parent tree stayed intact.
    assert set(evs) == {"outer", "inner"}
    assert evs["inner"]["args"]["error"] == 1
    assert evs["outer"]["args"]["error"] == 1
    assert evs["inner"]["parent"] != 0 and evs["outer"]["parent"] == 0
    # The per-thread stack fully unwound: a fresh span is a root again.
    with obs.span("after"):
        pass
    assert obs.events()[-1]["parent"] == 0
    # The latency histogram still observed the failed spans.
    h = obs.registry().get("inner.seconds")
    assert h is not None and h.count == 1


def test_label_context_restored_after_exception():
    from repro.obs.trace import current_labels
    with pytest.raises(ValueError):
        with obs.label_context(policy="lbcd"):
            with obs.label_context(family="storm"):
                assert current_labels() == {"policy": "lbcd",
                                            "family": "storm"}
                raise ValueError("x")
    assert current_labels() == {}
    obs.event("clean")
    assert "policy" not in obs.events()[-1]["args"]


def test_span_success_has_no_error_attr():
    with obs.span("fine"):
        pass
    assert "error" not in obs.events()[0]["args"]


def test_span_duration_feeds_latency_histogram_with_string_labels_only():
    with obs.span("plan", policy="lbcd", t0=3):
        pass
    h = obs.registry().get("plan.seconds", policy="lbcd")  # t0 not a label
    assert h is not None and h.count == 1
    assert obs.events()[0]["args"] == {"policy": "lbcd", "t0": 3}


def test_event_bumps_count_counter():
    with obs.label_context(family="outage"):
        obs.event("service.early_replan", policy="lbcd", t=4)
        obs.event("service.early_replan", policy="lbcd", t=5)
    c = obs.registry().get("service.early_replan.count",
                           policy="lbcd", family="outage")
    assert c is not None and c.value == 2.0


# ---------------------------------------------------------------------------
# Exporters + artifacts + report round trip
# ---------------------------------------------------------------------------

def test_prometheus_text_exposition():
    obs.counter("plan.count", policy="lbcd").inc(3)
    obs.gauge("service.divergence", policy="lbcd").set(-0.25)
    obs.histogram("plan.seconds", policy="lbcd").observe_many(
        [0.01, 0.02, 0.04])
    txt = obs.prometheus_text()
    assert 'repro_plan_count_total{policy="lbcd"} 3' in txt
    assert 'repro_service_divergence{policy="lbcd"} -0.25' in txt
    assert 'repro_plan_seconds_count{policy="lbcd"} 3' in txt
    assert 'quantile="0.99"' in txt
    assert "# TYPE repro_plan_seconds summary" in txt
    # Every line is `# ...` or `name{labels} value`.
    for line in txt.strip().splitlines():
        if not line.startswith("#"):
            name_part, val = line.rsplit(" ", 1)
            float(val)
            assert name_part.startswith("repro_")


def test_artifacts_and_report_round_trip(tmp_path):
    run_dir = str(tmp_path / "run0")
    obs.configure(run_dir=run_dir)
    with obs.label_context(policy="lbcd", family="steady_ar1"):
        for reason in ("boundary", "early"):
            with obs.span("service.plan_window", reason=reason):
                pass
        with obs.span("service.run_epoch"):
            pass
        obs.event("service.early_replan", t=1)
        obs.gauge("service.divergence").set(0.1)
    paths = obs.write_artifacts()
    # Streamed JSONL and the snapshot artifacts agree.
    streamed = [json.loads(line)
                for line in open(paths["trace_jsonl"]) if line.strip()]
    assert [e["name"] for e in streamed] == \
        [e["name"] for e in obs.events()]
    chrome = json.load(open(paths["chrome_trace"]))
    assert len(chrome["traceEvents"]) == len(streamed)
    assert all(ev["ts"] >= 0 for ev in chrome["traceEvents"])
    for line in open(paths["metrics_jsonl"]):
        json.loads(line)
    assert "repro_service_plan_window_seconds" in \
        open(paths["prometheus"]).read()
    # The module dashboard renders from the files alone.
    txt = report.build_report(report.load_events(run_dir),
                              report.load_metrics(run_dir))
    assert "lbcd" in txt and "steady_ar1" in txt
    assert "plans/s" in txt and "p99 replan" in txt
    assert "COUNTER MISMATCH" not in txt


def test_report_flags_counter_mismatch():
    events = [{"ph": "i", "name": "service.early_replan", "ts": 0.0,
               "dur": 0.0, "args": {"policy": "lbcd", "family": "f"}}]
    mets = [{"name": "service.early_replan.count", "type": "counter",
             "labels": {"policy": "lbcd", "family": "f"}, "value": 3.0}]
    assert "[COUNTER MISMATCH]" in report.build_report(events, mets)
    mets[0]["value"] = 1.0
    assert "MISMATCH" not in report.build_report(events, mets)


# ---------------------------------------------------------------------------
# Hot-path instrumentation: solve_slot host dispatches
# ---------------------------------------------------------------------------

def test_solve_slot_concrete_dispatch_records_timed_span():
    from repro.core import lbcd, profiles
    system = profiles.EdgeSystem(n_cameras=3, n_servers=2, n_slots=4,
                                 seed=0)
    ctrl = lbcd.LBCDController(system, v=10.0, p_min=0.6)
    ctrl.step(0)                    # virtual + per-server solve: 2 calls
    h = obs.registry().get("bcd.solve_slot.seconds", solver_backend="jnp")
    assert h is not None and h.count == 2
    spans = [e for e in obs.events() if e["name"] == "bcd.solve_slot"]
    assert len(spans) == 2
    assert all(e["args"]["n_cameras"] == 3 for e in spans)


# ---------------------------------------------------------------------------
# The reconciliation contract (tentpole acceptance)
# ---------------------------------------------------------------------------

def test_forced_replan_reconciles_obs_with_legacy_lists_all_families():
    """Forced-replan replay (hair-trigger ``replan_threshold``) over every
    registered family: the ``service.early_replan`` counter, the instant
    trace events, the ``reason="early"`` plan spans, and the legacy
    ``AnalyticsService.early_replans`` list must agree exactly — and the
    divergence series through the registry must match ``svc.divergences``.
    """
    s = scenarios.suite(**DIMS)
    fams = sorted(set(s.families))
    assert len(fams) >= 6
    n_epochs = 4
    reps = {}
    for i in range(s.n_scenarios):
        one = jax.tree.map(lambda x, i=i: x[i], s.tables)
        with obs.label_context(family=s.families[i], scenario=s.names[i]):
            reps[(s.families[i], s.names[i])] = replay.replay_tables(
                one, "lbcd", n_epochs=n_epochs, plan_window=2,
                replan_threshold=1e-9, epoch_duration=300.0)

    events = obs.events()
    reg = obs.registry()
    total_replans = 0
    for (fam, name), rep in reps.items():
        svc = rep.service
        n = len(svc.early_replans)
        assert n > 0, f"{name}: threshold 1e-9 must force replans"
        total_replans += n
        labels = dict(policy="lbcd", delay_model="mm1",
                      family=fam, scenario=name)
        evs = [e for e in events if e["args"].get("scenario") == name]

        # 1. instant events == legacy list (same epochs, same order)
        replan_evs = [e for e in evs
                      if e["name"] == report.REPLAN_EVENT]
        assert [e["args"]["t"] for e in replan_evs] == svc.early_replans

        # 2. registry counter == trace stream == legacy list
        c = reg.get(report.REPLAN_EVENT + ".count", **labels)
        assert c is not None and c.value == len(replan_evs) == n

        # 3. the NEXT plan span after each trigger carries reason="early"
        plan_spans = [e for e in evs if e["name"] == report.PLAN_SPAN]
        early = [e for e in plan_spans
                 if e["args"].get("reason") == "early"]
        assert len(early) == n
        assert plan_spans[0]["args"]["reason"] == "boundary"

        # 4. divergence series through the registry matches the list
        divs = svc.divergences
        assert reg.get("service.epochs", **labels).value == len(divs) \
            == n_epochs
        assert len([e for e in evs
                    if e["name"] == report.EPOCH_SPAN]) == n_epochs
        h = reg.get("service.divergence.abs", **labels)
        assert h.count == len(divs)
        assert h.total == pytest.approx(float(np.abs(divs).sum()))
        g = reg.get("service.divergence", **labels)
        assert g.value == pytest.approx(float(divs[-1]))

    assert reg.total(report.REPLAN_EVENT + ".count") == total_replans

    # The dashboard renders this run with per policy x family rows and no
    # reconciliation flag (the acceptance criterion's report source).
    txt = report.build_report(events, reg.snapshot())
    assert "COUNTER MISMATCH" not in txt
    for fam in fams:
        assert fam in txt
    row = [ln for ln in txt.splitlines() if fams[0] in ln][0]
    assert "ms" in row                     # plan latency columns rendered


def test_run_metadata_carries_obs_snapshot():
    import benchmarks.common as common
    obs.counter("queues.batch_dispatches", delay_model="mm1").inc(4)
    meta = common.run_metadata()
    assert meta["obs"]["enabled"] is True
    m = meta["obs"]["metrics"]["queues.batch_dispatches"]
    assert m["total"] == 4.0
    assert json.dumps(meta, default=float)   # JSON-serializable stamp
