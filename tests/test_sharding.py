"""Sharding rules: divisibility guards, per-arch policies, spec trees."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec

from repro import configs
from repro.models import build
from repro.models.common import P, pspec_tree
from repro.sharding.spec import spec_dims


RULES = {"_mesh_sizes": {"data": 16, "model": 16, "pod": 2},
         "batch": ("pod", "data"), "embed": "data", "heads": "model",
         "mlp": "model", "experts": "data", "expert_mlp": "model",
         "vocab": "model"}


def test_divisibility_guard():
    # 56 heads cannot shard over model=16 -> None
    assert spec_dims((7168, 56, 128), ("embed", "heads", None), RULES) == \
        ["data", None, None]
    assert spec_dims((7168, 64, 128), ("embed", "heads", None), RULES) == \
        ["data", "model", None]


def test_duplicate_axis_guard():
    # experts and embed both want "data": first dim wins.
    out = spec_dims((16, 6144, 10752), ("experts", "embed", "expert_mlp"),
                    RULES)
    assert out == ["data", None, "model"]


def test_tuple_axis_batch():
    assert spec_dims((256, 4096), ("batch", None), RULES) == \
        [("pod", "data"), None]
    # batch=1 cannot shard 32-way
    assert spec_dims((1, 4096), ("batch", None), RULES) == [None, None]


def test_pspec_tree_structure():
    tmpl = {"w": P((64, 128), ("embed", "mlp")),
            "b": P((128,), ("mlp",))}
    specs = pspec_tree(tmpl, RULES)
    assert specs["w"] == PartitionSpec("data", "model")
    assert specs["b"] == PartitionSpec("model")


@pytest.mark.parametrize("arch", sorted(configs.ARCHS))
def test_rules_cover_every_param(arch):
    """Every full-config param leaf gets a valid PartitionSpec under the
    production rules (no divisibility violations -> lowering can't fail on
    param sharding)."""
    from repro.sharding.rules import make_rules

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    cfg = configs.get(arch)
    rules = make_rules(cfg, FakeMesh())
    model = build(cfg, ep_degree=16)
    tmpl = model.template()
    specs = pspec_tree(tmpl, rules)
    leaves_t = jax.tree.leaves(tmpl, is_leaf=lambda x: isinstance(x, P))
    leaves_s = jax.tree.leaves(specs,
                               is_leaf=lambda s: isinstance(
                                   s, PartitionSpec))
    assert len(leaves_t) == len(leaves_s)
    for p, s in zip(leaves_t, leaves_s):
        for dim, ax in zip(p.shape, s):
            if ax is None:
                continue
            axes = (ax,) if isinstance(ax, str) else ax
            extent = int(np.prod([{"data": 16, "model": 16}[a]
                                  for a in axes]))
            assert dim % extent == 0, (arch, p.shape, s)


def test_big_models_are_sharded_small_enough():
    """Param bytes per chip under the production rules fit the HBM plan."""
    from repro.sharding.rules import make_rules

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    budgets = {"jamba-1.5-large-398b": 8.0, "dbrx-132b": 4.0,
               "yi-34b": 2.0}
    for arch, max_gib in budgets.items():
        cfg = configs.get(arch)
        rules = make_rules(cfg, FakeMesh())
        model = build(cfg, ep_degree=16)
        tmpl = model.template()
        specs = pspec_tree(tmpl, rules)

        total = 0.0
        for p, s in zip(
                jax.tree.leaves(tmpl, is_leaf=lambda x: isinstance(x, P)),
                jax.tree.leaves(specs, is_leaf=lambda s: isinstance(
                    s, PartitionSpec))):
            shard = 1
            for ax in s:
                if ax is None:
                    continue
                axes = (ax,) if isinstance(ax, str) else ax
                for a in axes:
                    shard *= {"data": 16, "model": 16}[a]
            total += p.size * 2 / shard          # bf16
        assert total / 2**30 <= max_gib, (arch, total / 2**30)
