"""Roofline helpers: useful-FLOPs model, sharded byte counting, terms."""
import numpy as np
import pytest

from repro import configs
from repro.configs.base import SHAPES
from repro.launch import roofline as rl
from repro.models import build
from repro.sharding.rules import make_rules


class FakeMesh:
    shape = {"data": 16, "model": 16}


def test_active_params_dense_equals_total():
    cfg = configs.get("yi-6b")
    assert rl.active_params(cfg) == build(cfg).param_count()


def test_active_params_moe_counts_topk_only():
    cfg = configs.get("dbrx-132b")
    total = build(cfg, ep_degree=16).param_count()
    active = rl.active_params(cfg)
    assert active < total
    # dbrx: 16 experts top-4 -> expert share shrinks ~4x.
    routed = 40 * 16 * 3 * cfg.d_model * cfg.expert_d_ff
    assert active == pytest.approx(total - routed + routed * 4 / 16,
                                   rel=1e-6)


def test_model_flops_scales_with_kind():
    cfg = configs.get("qwen2.5-3b")
    tr = rl.model_flops(cfg, SHAPES["train_4k"])
    pf = rl.model_flops(cfg, SHAPES["prefill_32k"])
    de = rl.model_flops(cfg, SHAPES["decode_32k"])
    n = rl.active_params(cfg)
    assert tr == pytest.approx(6 * n * 256 * 4096)
    assert pf == pytest.approx(2 * n * 32 * 32768)
    assert de == pytest.approx(2 * n * 128)


def test_tree_device_bytes_respects_sharding():
    cfg = configs.get("yi-6b")
    rules = make_rules(cfg, FakeMesh())
    model = build(cfg)
    per_dev = rl.tree_device_bytes(model.template(), rules)
    total = model.param_count() * 2
    # FSDP x TP shards most big tensors 256-way; allow norm/replicated slack
    assert total / 256 <= per_dev <= total / 64


def test_terms_from_record_dominant():
    rec = {
        "arch": "yi-6b", "shape": "train_4k", "mesh_name": "single",
        "n_devices": 256,
        "extrapolated": {"flops": 2e14, "bytes": 5e12, "coll": 1e9},
        "cost_full_hlo": {"flops": 0, "bytes": 0},
        "collectives_full_hlo": {"total_bytes": 0},
        "memory": {"argument_gib": 1.0, "temp_gib": 2.0,
                   "output_gib": 0, "alias_gib": 0},
    }
    t = rl.terms_from_record(rec)
    assert t["dominant"] == "compute"
    assert 0 < t["roofline_fraction"] <= 1.5
    assert t["t_compute_s"] == pytest.approx(2e14 / rl.PEAK_FLOPS)


def test_fused_memory_decode_is_weights_plus_cache():
    cfg = configs.get("yi-6b")
    sizes = {"data": 16, "model": 16}
    b = rl.fused_memory_bytes(cfg, SHAPES["decode_32k"], sizes)
    rules = make_rules(cfg, FakeMesh())
    model = build(cfg)
    p_dev = rl.tree_device_bytes(model.template(), rules)
    assert b > 2 * p_dev          # weights read + cache read
    assert b < 2 * p_dev + 10 * 2**30
