"""Energy-aware LBCD (the paper's §VII future-work item)."""
import numpy as np
import pytest

from repro.core import profiles
from repro.core.energy import EnergyAwareLBCD, EnergyModel
from repro.core.lbcd import LBCDController


def _system():
    return profiles.EdgeSystem(n_cameras=12, n_servers=2, n_slots=40,
                               seed=0, mean_bandwidth_hz=15e6,
                               mean_compute_flops=15e12)


def test_energy_queue_drives_power_toward_budget():
    em = EnergyModel(e_max=0.25)
    ea = EnergyAwareLBCD(_system(), energy=em, v=10.0, p_min=0.6)
    recs = [ea.step(t) for t in range(60)]
    pws = np.array([r.power for r in recs])

    # Plain LBCD power under the same model (no energy awareness).
    base = LBCDController(_system(), v=10.0, p_min=0.6).run(20)
    base_p = np.mean([em.power(r.decision.b, r.decision.c).mean()
                      for r in base.records])

    assert pws[20:].mean() < base_p / 5          # large power reduction
    # Monotone convergence toward the cap (Lyapunov asymptotics).
    w = [pws[i:i + 20].mean() for i in (0, 20, 40)]
    assert w[0] > w[1] > w[2]
    assert w[2] < em.e_max * 2.0
    # Price rises while above budget (queue doing its job).
    assert recs[-1].z > recs[10].z


def test_energy_queue_idle_when_budget_loose():
    em = EnergyModel(e_max=100.0)                # effectively unconstrained
    ea = EnergyAwareLBCD(_system(), energy=em, v=10.0, p_min=0.6)
    recs = [ea.step(t) for t in range(5)]
    assert recs[-1].z == 0.0
    # and behaves like plain LBCD (same decisions at scale 1.0)
    base = LBCDController(_system(), v=10.0, p_min=0.6)
    rb = [base.step(t) for t in range(5)]
    np.testing.assert_allclose(recs[0].aopi, rb[0].aopi, rtol=1e-5)


def test_energy_accuracy_still_tracked():
    em = EnergyModel(e_max=0.3)
    ea = EnergyAwareLBCD(_system(), energy=em, v=5.0, p_min=0.55)
    recs = [ea.step(t) for t in range(40)]
    accs = np.array([r.mean_acc for r in recs])
    assert accs[20:].mean() >= 0.5               # accuracy floor respected
