"""Theorems 1-3 vs the discrete-event oracles + structural corollaries."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aopi, queues

GRID = [
    # (lam, mu, p)
    (2.0, 10.0, 0.9), (5.0, 10.0, 0.8), (8.0, 10.0, 0.6),
    (3.0, 4.0, 0.95), (1.0, 20.0, 0.3), (9.5, 10.0, 0.9),
]


@pytest.mark.parametrize("lam,mu,p", GRID)
def test_theorem1_fcfs_matches_simulation(lam, mu, p):
    th = float(aopi.aopi_fcfs(lam, mu, p))
    # High load (rho -> 1) mixes slowly; use a longer run there.
    n = 4_000_000 if lam / mu > 0.9 else 400_000
    sim = queues.simulate_fcfs(lam, mu, p, n_frames=n, seed=1)
    assert sim.mean_aopi == pytest.approx(th, rel=0.06)


@pytest.mark.parametrize("lam,mu,p", GRID + [(15.0, 10.0, 0.8)])
def test_theorem2_lcfsp_matches_simulation(lam, mu, p):
    th = float(aopi.aopi_lcfsp(lam, mu, p))
    sim = queues.simulate_lcfsp(lam, mu, p, n_frames=400_000, seed=2)
    assert sim.mean_aopi == pytest.approx(th, rel=0.05)


def test_fcfs_unstable_region_is_inf():
    assert np.isinf(float(aopi.aopi_fcfs(10.0, 10.0, 0.9)))
    assert np.isinf(float(aopi.aopi_fcfs(12.0, 10.0, 0.9)))


def test_corollary_41_convex_interior_minimum():
    """A_F first decreases then increases in lam (convex)."""
    mu, p = 10.0, 0.8
    lam = np.linspace(0.1, 9.9, 300)
    a = np.asarray(aopi.aopi_fcfs(lam, mu, p))
    d2 = np.diff(a, 2)
    assert (d2 > -1e-5).all()                      # convex
    i = a.argmin()
    assert 0 < i < len(a) - 1                      # interior minimum
    lam_star = float(aopi.argmin_lam_fcfs(mu, p))
    assert abs(lam_star - lam[i]) < 0.1


def test_lam_star_decreases_with_p():
    """Optimal transmission rate decreases with accuracy (§IV-A)."""
    mu = 10.0
    stars = [float(aopi.argmin_lam_fcfs(mu, p))
             for p in (0.2, 0.4, 0.6, 0.8, 0.99)]
    assert all(a > b for a, b in zip(stars, stars[1:]))


def test_corollary_42_decreasing_in_mu():
    lam, p = 5.0, 0.8
    mu = np.linspace(5.5, 50.0, 200)
    a = np.asarray(aopi.aopi_fcfs(lam, mu, p))
    assert (np.diff(a) < 0).all()
    d2 = np.diff(a, 2)
    assert (d2 > -1e-7).all()


def test_theorem3_threshold_matches_crossover():
    """Eq. 43: A_F >= A_L iff p >= threshold(rho)."""
    mu = 10.0
    for rho in (0.2, 0.5, 0.8, 0.95):
        lam = rho * mu
        thr = float(aopi.policy_threshold(rho))
        for p in (thr - 0.05, thr + 0.05):
            if not 0 < p <= 1:
                continue
            af = float(aopi.aopi_fcfs(lam, mu, p))
            al = float(aopi.aopi_lcfsp(lam, mu, p))
            if p > thr:
                assert af >= al - 1e-6
            else:
                assert af <= al + 1e-6


def test_optimal_policy_phase_diagram():
    """Fig. 6: LCFSP wins at high load + high accuracy."""
    mu = 10.0
    assert int(aopi.optimal_policy(9.0, mu, 0.95)) == aopi.LCFSP
    assert int(aopi.optimal_policy(2.0, mu, 0.1)) == aopi.FCFS


def test_analytic_derivatives_match_autodiff():
    lam, mu, p = 4.0, 9.0, 0.7
    g = jax.grad(lambda x: aopi.aopi_fcfs(x, mu, p))(jnp.float32(lam))
    assert float(g) == pytest.approx(
        float(aopi.d_aopi_fcfs_dlam(lam, mu, p)), rel=1e-3)
    g = jax.grad(lambda x: aopi.aopi_fcfs(lam, x, p))(jnp.float32(mu))
    assert float(g) == pytest.approx(
        float(aopi.d_aopi_fcfs_dmu(lam, mu, p)), rel=1e-3)
    g = jax.grad(lambda x: aopi.aopi_lcfsp(x, mu, p))(jnp.float32(lam))
    assert float(g) == pytest.approx(
        float(aopi.d_aopi_lcfsp_dlam(lam, mu, p)), rel=1e-3)


def test_min_rate_frontiers():
    """Figs. 3/5: the minimum-rate frontier actually meets the target."""
    target = 0.5
    for pol in (aopi.FCFS, aopi.LCFSP):
        mu, p = 20.0, 0.8
        lam_min = float(aopi.min_lam_for_target(target, mu, p, pol))
        a = float(aopi.aopi(lam_min, mu, p, pol))
        assert a == pytest.approx(target, rel=1e-2)
        lam = 6.0
        mu_min = float(aopi.min_mu_for_target(target, lam, p, pol))
        a = float(aopi.aopi(lam, mu_min, p, pol))
        assert a == pytest.approx(target, rel=1e-2)


def test_lcfsp_frontier_monotone():
    """§IV-B: under LCFSP min-lam decreases with reserved mu."""
    p, target = 0.8, 0.5
    mus = np.array([5.0, 10.0, 20.0, 40.0])
    lams = [float(aopi.min_lam_for_target(target, m, p, aopi.LCFSP))
            for m in mus]
    assert all(a >= b for a, b in zip(lams, lams[1:]))


def test_fcfs_min_mu_nonmonotone_in_lam():
    """Fig. 3b: FCFS min computation rate first falls then rises with the
    reserved transmission rate (queueing kicks in)."""
    p, target = 0.9, 0.5
    lams = np.linspace(3.0, 30.0, 25)
    mus = np.array([float(aopi.min_mu_for_target(target, l, p, aopi.FCFS))
                    for l in lams])
    i = mus.argmin()
    assert 0 < i < len(mus) - 1


def test_nonexponential_delays_keep_ranking():
    """§VI-C1: with uniform (more even) delays the theory still ranks
    configurations correctly even if absolute values drift."""
    cases = [(5.0, 10.0, 0.9), (5.0, 10.0, 0.4), (2.0, 10.0, 0.7)]
    th = [float(aopi.aopi_fcfs(*c)) for c in cases]
    sim = [queues.simulate_fcfs(
        lam, mu, p, n_frames=150_000, seed=3,
        t_sampler=queues.uniform_sampler(1.0 / lam),
        o_sampler=queues.uniform_sampler(1.0 / mu)).mean_aopi
        for lam, mu, p in cases]
    assert np.argsort(th).tolist() == np.argsort(sim).tolist()
