"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import decode_ref, flash_decode
from repro.kernels.flash_attention import flash_attention, mha_ref
from repro.kernels.mlstm import (mlstm_chunkwise, mlstm_parallel_ref,
                                 mlstm_step)
from repro.kernels.selective_scan import (selective_scan_chunked,
                                          selective_scan_ref)
from repro.kernels.selective_scan.kernel import selective_scan as ss_pallas

KEY = jax.random.PRNGKey(0)


def tol(dtype):
    return 5e-2 if dtype == jnp.bfloat16 else 2e-5


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,t,h,kvh,d", [
    (2, 256, 256, 4, 2, 64),
    (1, 128, 384, 8, 8, 128),
    (2, 256, 256, 4, 1, 128),
    (1, 192, 192, 6, 2, 64),      # non-128-multiple seq
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, s, t, h, kvh, d, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, d), dtype)
    k = jax.random.normal(ks[1], (b, t, kvh, d), dtype)
    v = jax.random.normal(ks[2], (b, t, kvh, d), dtype)
    ref = mha_ref(q, k, v, causal=True)
    out = flash_attention(q, k, v, causal=True, interpret=True,
                          block_q=128, block_k=128)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol(dtype), rtol=tol(dtype))


def test_flash_attention_noncausal():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 128, 4, 64))
    k = jax.random.normal(ks[1], (1, 256, 4, 64))
    v = jax.random.normal(ks[2], (1, 256, 4, 64))
    ref = mha_ref(q, k, v, causal=False)
    out = flash_attention(q, k, v, causal=False, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


# ---------------------------------------------------------------------------
# flash decode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,t,h,kvh,d,blk", [
    (2, 512, 8, 2, 64, 128),
    (4, 1024, 4, 4, 128, 512),
    (1, 384, 8, 1, 128, 128),
    (3, 640, 16, 8, 64, 256),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_sweep(b, t, h, kvh, d, blk, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, h, d), dtype)
    kc = jax.random.normal(ks[1], (b, t, kvh, d), dtype)
    vc = jax.random.normal(ks[2], (b, t, kvh, d), dtype)
    kv_len = jnp.asarray([t // 2 + 37 * i for i in range(b)], jnp.int32)
    ref = decode_ref(q, kc, vc, kv_len)
    out = flash_decode(q, kc, vc, kv_len, block_k=blk, interpret=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol(dtype), rtol=tol(dtype))


# ---------------------------------------------------------------------------
# selective scan
# ---------------------------------------------------------------------------

def _ss_inputs(b, s, inner, n, dtype=jnp.float32, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    x = jax.random.normal(ks[0], (b, s, inner), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, inner)) - 1.0
                         ).astype(dtype)
    A = -jnp.exp(jax.random.normal(ks[2], (inner, n)) * 0.5)
    B = jax.random.normal(ks[3], (b, s, n), dtype)
    C = jax.random.normal(ks[4], (b, s, n), dtype)
    D = jax.random.normal(ks[5], (inner,))
    return x, dt, A, B, C, D


@pytest.mark.parametrize("b,s,inner,n,chunk,bi", [
    (2, 128, 64, 16, 64, 32),
    (1, 256, 128, 16, 128, 128),
    (2, 96, 32, 8, 32, 32),
])
def test_selective_scan_sweep(b, s, inner, n, chunk, bi):
    x, dt, A, B, C, D = _ss_inputs(b, s, inner, n)
    y0, h0 = selective_scan_ref(x, dt, A, B, C, D)
    y1, h1 = selective_scan_chunked(x, dt, A, B, C, D, chunk=chunk)
    y2, h2 = ss_pallas(x, dt, A, B, C, D, chunk=chunk, block_i=bi,
                       interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0), atol=1e-4)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y0), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h0), atol=1e-4)


def test_selective_scan_carries_state():
    """Scanning two halves with carried state == one full scan."""
    x, dt, A, B, C, D = _ss_inputs(1, 128, 32, 8, seed=3)
    y_full, h_full = selective_scan_ref(x, dt, A, B, C, D)
    y1, h1 = selective_scan_ref(x[:, :64], dt[:, :64], A, B[:, :64],
                                C[:, :64], D)
    y2, h2 = selective_scan_ref(x[:, 64:], dt[:, 64:], A, B[:, 64:],
                                C[:, 64:], D, h0=h1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full),
                               atol=1e-4)


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def _mlstm_inputs(b, s, h, d, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, h, d))
    v = jax.random.normal(ks[2], (b, s, h, d))
    ig = jax.random.normal(ks[3], (b, s, h)) * 0.5
    fg = jax.random.normal(ks[4], (b, s, h)) + 2.0
    return q, k, v, ig, fg


@pytest.mark.parametrize("b,s,h,d,bq,bk", [
    (2, 128, 2, 64, 64, 64),
    (1, 256, 4, 128, 128, 128),
    (2, 192, 2, 64, 64, 64),
])
def test_mlstm_kernel_sweep(b, s, h, d, bq, bk):
    q, k, v, ig, fg = _mlstm_inputs(b, s, h, d, seed=s)
    ref = mlstm_parallel_ref(q, k, v, ig, fg)
    out = mlstm_chunkwise(q, k, v, ig, fg, block_q=bq, block_k=bk,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-3, rtol=1e-3)


def test_mlstm_parallel_equals_recurrent():
    b, s, h, d = 2, 64, 2, 32
    q, k, v, ig, fg = _mlstm_inputs(b, s, h, d, seed=9)
    ref = mlstm_parallel_ref(q, k, v, ig, fg)
    C = jnp.zeros((b, h, d, d))
    n = jnp.zeros((b, h, d))
    m = jnp.full((b, h), -1e30)
    outs = []
    for t in range(s):
        o, (C, n, m) = mlstm_step(q[:, t], k[:, t], v[:, t], ig[:, t],
                                  fg[:, t], C, n, m)
        outs.append(o)
    rec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(rec), np.asarray(ref), atol=1e-4)


def test_mlstm_chunkwise_xla_matches_parallel():
    """The beyond-paper XLA chunkwise form (EXPERIMENTS §Perf B1)."""
    from repro.kernels.mlstm import mlstm_chunkwise_xla
    for (b, s, h, d, c) in [(2, 256, 2, 32, 64), (1, 512, 4, 64, 128),
                            (2, 384, 2, 32, 128)]:
        q, k, v, ig, fg = _mlstm_inputs(b, s, h, d, seed=s + 1)
        ref = mlstm_parallel_ref(q, k, v, ig, fg)
        out = mlstm_chunkwise_xla(q, k, v, ig, fg, chunk=c)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=5e-4, rtol=1e-3)


def test_mlstm_chunkwise_xla_fallback_short_seq():
    from repro.kernels.mlstm import mlstm_chunkwise_xla
    q, k, v, ig, fg = _mlstm_inputs(1, 64, 2, 16, seed=3)
    out = mlstm_chunkwise_xla(q, k, v, ig, fg, chunk=256)  # s < chunk
    ref = mlstm_parallel_ref(q, k, v, ig, fg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)
