"""Batched device-resident GI/G/1 data plane (``queues.gi_g1_window`` /
``service.measure_window``): parity with the numpy oracle and Theorems 1-2,
collision-free key streams, epoch-horizon truncation, and determinism."""
import numpy as np
import pytest

from repro.core import aopi, queues
from repro.serving import service


def _measure(lam, mu, p, pol, *, seed=0, t=0, horizon=20_000.0,
             delay_model="mm1", frames_cap=400_000):
    n_frames = queues.frames_budget(lam, horizon, frames_cap)
    out = queues.gi_g1_window([lam], [mu], [p], [pol], seed=seed, t0=t,
                              n_frames=n_frames, horizon=horizon,
                              delay_model=delay_model)
    return {k: v[0, 0] for k, v in out.items()}


# ---------------------------------------------------------------------------
# Parity: batched engine == Theorems 1-2 (mm1) == numpy oracle (all models)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rho,pol,p", [
    (0.5, aopi.FCFS, 0.8), (0.5, aopi.LCFSP, 0.8),
    (0.75, aopi.FCFS, 0.6), (0.25, aopi.LCFSP, 0.9)])
def test_batched_engine_matches_closed_forms(rho, pol, p):
    mu = 10.0
    out = _measure(rho * mu, mu, p, pol, seed=11)
    assert out["aopi"] == pytest.approx(
        float(aopi.aopi(rho * mu, mu, p, pol)), rel=0.1)


@pytest.mark.parametrize("delay_model", queues.DELAY_MODELS)
@pytest.mark.parametrize("pol", [aopi.FCFS, aopi.LCFSP])
def test_batched_engine_matches_numpy_oracle(delay_model, pol):
    """Same delay family, independent draws: the batched engine and the
    per-stream numpy oracle estimate the same steady-state mean AoPI."""
    lam, mu, p = 5.0, 10.0, 0.8
    out = _measure(lam, mu, p, pol, seed=2, delay_model=delay_model)
    sim = queues.simulate(lam, mu, p, pol, n_frames=150_000, seed=7,
                          **queues.oracle_samplers(delay_model, lam, mu))
    assert out["aopi"] == pytest.approx(sim.mean_aopi, rel=0.1)


def test_batched_engine_matches_oracle_hypothesis():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=8, deadline=None)
    @given(st.sampled_from([0.25, 0.5, 0.75]),
           st.sampled_from([aopi.FCFS, aopi.LCFSP]),
           st.sampled_from(queues.DELAY_MODELS),
           st.integers(0, 10_000))
    def inner(rho, pol, delay_model, seed):
        mu, p = 10.0, 0.7
        lam = rho * mu
        out = _measure(lam, mu, p, pol, seed=seed, horizon=15_000.0,
                       delay_model=delay_model)
        sim = queues.simulate(
            lam, mu, p, pol, n_frames=120_000, seed=seed + 1,
            **queues.oracle_samplers(delay_model, lam, mu))
        assert out["aopi"] == pytest.approx(sim.mean_aopi, rel=0.12)

    inner()


def test_non_exponential_models_drift_from_theorems():
    """The §III-B regime: same means, different shape -> Theorems 1-2 are
    biased (less delay variance means less waiting, so measured < theory
    under FCFS; heavy tails push the other way)."""
    lam, mu, p = 5.0, 10.0, 0.8
    th = float(aopi.aopi(lam, mu, p, aopi.FCFS))
    for dm in ("uniform", "gamma"):
        out = _measure(lam, mu, p, aopi.FCFS, seed=4, delay_model=dm)
        assert out["aopi"] < th * 0.95
    for dm in queues.HEAVY_TAIL_MODELS:
        out = _measure(lam, mu, p, aopi.FCFS, seed=4, delay_model=dm)
        assert out["aopi"] > th * 1.05


def test_heavy_tail_samplers_match_target_mean_and_shape():
    """Mean-matched heavy tails: sampler mean == 1/rate for lognormal and
    weibull, with the coefficient of variation the family's parameters
    imply (sigma=1 lognormal: CV = sqrt(e - 1); k=0.7 weibull:
    CV ~ 1.46) — well above exponential's CV = 1."""
    import math
    rng = np.random.default_rng(3)
    mean = 0.4
    ln = queues.lognormal_sampler(mean)(rng, 400_000)
    assert ln.mean() == pytest.approx(mean, rel=0.02)
    assert ln.std() / ln.mean() == pytest.approx(
        np.sqrt(np.e - 1.0), rel=0.05)
    wb = queues.weibull_sampler(mean)(rng, 400_000)
    assert wb.mean() == pytest.approx(mean, rel=0.02)
    k = queues.WEIBULL_SHAPE
    cv = math.sqrt(math.gamma(1 + 2 / k) / math.gamma(1 + 1 / k) ** 2 - 1)
    assert wb.std() / wb.mean() == pytest.approx(cv, rel=0.05)
    assert (ln > 0).all() and (wb > 0).all()


def test_heavy_tail_samplers_hypothesis():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=10, deadline=None)
    @given(st.floats(0.05, 5.0), st.integers(0, 10_000),
           st.sampled_from(sorted(queues.HEAVY_TAIL_MODELS)))
    def inner(mean, seed, dm):
        rng = np.random.default_rng(seed)
        maker = (queues.lognormal_sampler if dm == "lognormal"
                 else queues.weibull_sampler)
        x = maker(mean)(rng, 200_000)
        assert x.mean() == pytest.approx(mean, rel=0.05)
        assert (x > 0).all()
        assert x.std() > x.mean()      # heavier-tailed than exponential

    inner()


# ---------------------------------------------------------------------------
# Telemetry-fitted delay-model selector
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dm", queues.DELAY_MODELS)
def test_fit_delay_model_round_trips_every_family(dm):
    rng = np.random.default_rng(17)
    mean = 0.4
    if dm == "mm1":
        samples = rng.exponential(mean, 4096)
    else:
        samples = queues.oracle_samplers(
            dm, 1.0 / mean, 10.0)["t_sampler"](rng, 4096)
    fit = queues.fit_delay_model(samples)
    assert fit.model == dm, fit
    assert fit.n_samples == 4096
    assert fit.residuals[dm] == min(fit.residuals.values())


def test_fit_delay_model_falls_back_below_min_samples():
    fit = queues.fit_delay_model(np.array([1.0, 2.0]))
    assert fit.model == "mm1" and fit.residuals == {}
    assert queues.fit_delay_model(np.zeros(64)).model == "mm1"


def test_validate_delay_model_lists_auto_sentinel():
    queues.validate_delay_model("auto", allow_auto=True)
    with pytest.raises(ValueError, match="auto"):
        queues.validate_delay_model("pareto", allow_auto=True)
    with pytest.raises(ValueError, match="delay_model"):
        queues.validate_delay_model("auto")


@pytest.mark.parametrize("dm,pname,truth", [
    ("lognormal", "sigma", 1.25), ("weibull", "k", 0.5)])
def test_fit_delay_model_estimates_shape_parameters(dm, pname, truth):
    """The fitted selector also estimates the family's shape parameter
    from the CvM grid — off-default shapes are recovered exactly (the
    grid contains the truth)."""
    rng = np.random.default_rng(23)
    mean = 0.4
    if dm == "lognormal":
        samples = rng.lognormal(np.log(mean) - truth ** 2 / 2.0, truth,
                                8192)
    else:
        from math import gamma as _g
        samples = mean / _g(1.0 + 1.0 / truth) * rng.weibull(truth, 8192)
    fit = queues.fit_delay_model(samples)
    assert fit.model == dm
    assert fit.params == {pname: truth}


def test_fit_delay_model_default_shapes_and_mm1_have_params():
    rng = np.random.default_rng(5)
    fit = queues.fit_delay_model(rng.exponential(0.3, 4096))
    assert fit.model == "mm1" and fit.params == {}
    ln = queues.fit_delay_model(
        queues.oracle_samplers("lognormal", 2.5, 10.0)["t_sampler"](
            rng, 4096))
    assert ln.model == "lognormal" and "sigma" in ln.params


def test_family_cv2_and_residual_prior():
    """Squared CoV per family and the Kingman-style residual prior
    ``(1 + cv^2) / 2`` the planner seeds its AoPI scale from."""
    assert queues.family_cv2("mm1") == pytest.approx(1.0)
    assert queues.residual_prior("mm1") == pytest.approx(1.0)
    # uniform on [0.5m, 1.5m]: cv^2 = spread^2 / 3 < 1 -> prior < 1.
    assert queues.residual_prior("uniform") < 1.0
    # heavy tails: cv^2 > 1 -> prior > 1, monotone in sigma.
    assert queues.residual_prior("weibull", {"k": 0.5}) > \
        queues.residual_prior("weibull", {"k": 0.9})
    # lognormal cv^2 = expm1(sigma^2): monotone, crosses 1 at sigma ~ 0.83.
    assert queues.family_cv2("lognormal", {"sigma": 1.5}) > 1.0 > \
        queues.family_cv2("lognormal", {"sigma": 0.5})


# ---------------------------------------------------------------------------
# Determinism + key streams
# ---------------------------------------------------------------------------

def test_batched_window_is_bitwise_deterministic():
    lam = np.array([[4.0, 6.0], [5.0, 3.0]])
    mu = np.full((2, 2), 12.0)
    p = np.full((2, 2), 0.8)
    pol = np.array([[0, 1], [1, 0]])
    kw = dict(n_frames=4096, horizon=300.0)
    a = queues.gi_g1_window(lam, mu, p, pol, seed=5, t0=3, **kw)
    b = queues.gi_g1_window(lam, mu, p, pol, seed=5, t0=3, **kw)
    np.testing.assert_array_equal(a["aopi"], b["aopi"])
    c = queues.gi_g1_window(lam, mu, p, pol, seed=6, t0=3, **kw)
    d = queues.gi_g1_window(lam, mu, p, pol, seed=5, t0=4, **kw)
    assert not np.array_equal(a["aopi"], c["aopi"])
    assert not np.array_equal(a["aopi"], d["aopi"])


def test_epoch_stream_keys_never_collide():
    """Regression for the old ``seed + 7919*t + i`` scheme, which collided
    (t=0, i=7919) with (t=1, i=0). Folded jax keys and SeedSequence spawn
    keys are pairwise distinct for N up to 10k across epochs."""
    import jax
    import jax.numpy as jnp

    n = 10_000
    seen = set()
    for t in (0, 1, 2):
        keys = jax.vmap(jax.random.fold_in, (None, 0))(
            queues.epoch_key(seed=0, t=t), jnp.arange(n))
        for kd in np.asarray(jax.random.key_data(keys)):
            seen.add(tuple(int(x) for x in kd))
    assert len(seen) == 3 * n
    # The numpy loop oracle's streams: the historic collision pair plus a
    # broad uniqueness sweep.
    s_old = queues.stream_seed_sequence(0, t=0, i=7919).generate_state(4)
    s_new = queues.stream_seed_sequence(0, t=1, i=0).generate_state(4)
    assert not np.array_equal(s_old, s_new)
    states = {
        tuple(queues.stream_seed_sequence(0, t, i).generate_state(2))
        for t in (0, 1) for i in range(2000)}
    assert len(states) == 2 * 2000


def test_window_batching_invariance():
    """One [E, N] window dispatch == E single-epoch dispatches at the same
    frame budget: per-(epoch, stream) keys depend only on (seed, t, i),
    not on how the window was batched."""
    rng = np.random.default_rng(0)
    lam = rng.uniform(3, 8, size=(3, 4))
    mu = np.full((3, 4), 15.0)
    p = np.full((3, 4), 0.8)
    pol = rng.integers(0, 2, size=(3, 4))
    kw = dict(n_frames=2048, horizon=200.0, seed=9)
    win = queues.gi_g1_window(lam, mu, p, pol, t0=2, **kw)
    for e in range(3):
        one = queues.gi_g1_window(lam[e], mu[e], p[e], pol[e], t0=2 + e,
                                  **kw)
        np.testing.assert_allclose(win["aopi"][e], one["aopi"][0],
                                   rtol=1e-9)
        np.testing.assert_array_equal(win["n_frames"][e],
                                      one["n_frames"][0])
    # The service-level window shares ONE budget across its epochs (from
    # the window's max rate), so its telemetry is per-epoch complete.
    meas, tels = service.measure_window(lam, mu, p, pol,
                                        epoch_duration=200.0, seed=9, t0=2)
    assert meas.shape == (3, 4) and len(tels) == 3
    assert all(np.isfinite(t.aopi_hat).all() for t in tels)


# ---------------------------------------------------------------------------
# Epoch-horizon truncation (frames_floor overshoot fix)
# ---------------------------------------------------------------------------

def test_frames_floor_no_longer_overshoots_epoch():
    """A low-rate stream (floor >> lam * epoch) must be measured over the
    epoch, not the floor's ~200,000 s simulated horizon: with ~no frames
    arriving in the epoch, AoPI -> epoch/2 (age of the virtual frame at
    t=0). The old loop reported the steady-state mean ~2/lam instead —
    a 40x overshoot of anything observable within the epoch."""
    epoch = 100.0
    meas, tel = service.measure_mm1(
        np.array([1e-3]), np.array([50.0]), np.array([1.0]),
        np.array([0]), epoch_duration=epoch, frames_floor=200, seed=0)
    assert meas[0] == pytest.approx(epoch / 2, rel=0.15)
    # The loop oracle keeps the historical (simulated-horizon) semantics:
    # its answer cannot even be seen within the 100 s epoch.
    loop, _ = service.measure_mm1_loop(
        np.array([1e-3]), np.array([50.0]), np.array([1.0]),
        np.array([0]), epoch_duration=epoch, frames_floor=200, seed=0)
    assert loop[0] > epoch


def test_frames_cap_shrinks_horizon_instead_of_inflating_age():
    """When frames_cap cuts coverage short of the epoch, the engine
    measures over the covered window (unbiased) instead of counting the
    uncovered tail as pure age growth."""
    lam, mu, p = 500.0, 1500.0, 0.6
    meas, tel = service.measure_mm1(
        np.array([lam]), np.array([mu]), np.array([p]), np.array([0]),
        epoch_duration=400.0, frames_cap=100_000, seed=1)
    assert meas[0] == pytest.approx(
        float(aopi.aopi(lam, mu, p, 0)), rel=0.1)
    assert tel.lam_hat[0] == pytest.approx(lam, rel=0.05)


def test_telemetry_derives_from_batched_outputs():
    lam, mu, p = 6.0, 15.0, 0.7
    meas, tel = service.measure_mm1(
        np.array([lam, lam]), np.array([mu, mu]), np.array([p, p]),
        np.array([0, 1]), epoch_duration=5000.0, seed=3)
    assert tel.lam_hat == pytest.approx([lam, lam], rel=0.05)
    assert tel.acc_hat == pytest.approx([p, p], abs=0.03)
    np.testing.assert_allclose(tel.aopi_hat, meas)
    # LCFSP discards preempted frames: completion rate < arrival rate.
    assert tel.mu_hat[1] < tel.lam_hat[1]
    assert tel.mu_hat[0] == pytest.approx(lam, rel=0.05)


def test_unknown_delay_model_raises():
    with pytest.raises(ValueError, match="delay_model"):
        queues.gi_g1_window([1.0], [2.0], [0.5], [0], n_frames=256,
                            horizon=10.0, delay_model="pareto")
    with pytest.raises(ValueError, match="delay_model"):
        service.measure_mm1_loop(
            np.ones(1), np.ones(1), np.ones(1) * 0.5, np.zeros(1),
            delay_model="pareto")
