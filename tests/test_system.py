"""End-to-end system behaviour (the paper's Fig. 16 testbed analog)."""
import numpy as np
import pytest

from repro.core import baselines, lbcd, profiles
from repro.serving import AnalyticsService


def _system(seed=0):
    return profiles.EdgeSystem(
        n_cameras=12, n_servers=2, n_slots=20, seed=seed,
        mean_bandwidth_hz=12e6, mean_compute_flops=12e12)


def test_e2e_lbcd_service_beats_baselines_on_measured_aopi():
    """Measured (data-plane) AoPI: LBCD < DOS and JCAB, accuracy >= floor."""
    ctrl = lbcd.LBCDController(_system(), v=10.0, p_min=0.65)
    svc = AnalyticsService(ctrl, mode="mm1", epoch_duration=2000.0)
    reps = svc.run(8)
    lbcd_measured = np.mean([r.measured_aopi for r in reps])
    accs = np.mean([r.accuracy for r in reps])

    results = {}
    for name in ("DOS", "JCAB"):
        bl = baselines.make(name, _system())
        bsvc = AnalyticsService(bl, mode="mm1", epoch_duration=2000.0)
        breps = bsvc.run(8)
        results[name] = np.mean([r.measured_aopi for r in breps])

    assert lbcd_measured < results["DOS"]
    assert lbcd_measured < results["JCAB"]
    assert accs >= 0.55          # converging toward P_min from below


def test_e2e_closed_form_guides_real_queues():
    """The slot decisions' predicted ordering holds in the measured data
    plane across epochs (theory is a usable control signal)."""
    ctrl = lbcd.LBCDController(_system(seed=3), v=10.0, p_min=0.6)
    svc = AnalyticsService(ctrl, mode="mm1", epoch_duration=2000.0)
    reps = svc.run(6)
    pred = np.array([r.predicted_aopi for r in reps])
    meas = np.array([r.measured_aopi for r in reps])
    # predictions within 30% of measurements on average
    assert np.mean(np.abs(pred - meas) / np.maximum(meas, 1e-9)) < 0.3


def test_e2e_real_engine_service_runs():
    """Tiny real-model engine driven by LBCD for one epoch."""
    import jax

    from repro import configs
    from repro.models import build
    from repro.models.common import init_params
    from repro.serving import Engine

    cfg = configs.get("qwen2.5-3b").reduced()
    model = build(cfg)
    params = init_params(model.template(), jax.random.PRNGKey(0))
    eng = Engine(model, params, n_lanes=4, max_len=96, decode_tokens=2)
    system = profiles.EdgeSystem(n_cameras=4, n_servers=1, n_slots=4,
                                 seed=1)
    ctrl = lbcd.LBCDController(system, v=10.0, p_min=0.6)
    svc = AnalyticsService(ctrl, mode="engine", engine=eng,
                           epoch_duration=2.0)
    rep = svc.run_epoch(0)
    assert np.isfinite(rep.measured_aopi)
    assert rep.measured_aopi >= 0.0
