"""Checkpoint atomicity, integrity, and elastic resharding."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.training import checkpoint as ckpt


def _tree(key):
    k1, k2 = jax.random.split(key)
    return {"a": jax.random.normal(k1, (16, 8)),
            "b": {"c": jax.random.normal(k2, (4,)),
                  "step": jnp.asarray(3, jnp.int32)}}


def test_roundtrip(tmp_path):
    tree = _tree(jax.random.PRNGKey(0))
    ckpt.save(str(tmp_path), 7, tree)
    restored, step = ckpt.restore(str(tmp_path), tree)
    assert step == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_cleanup(tmp_path):
    tree = _tree(jax.random.PRNGKey(1))
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, tree, keep=3)
    assert ckpt.latest_step(str(tmp_path)) == 5
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(dirs) == 3


def test_corruption_detected(tmp_path):
    tree = _tree(jax.random.PRNGKey(2))
    d = ckpt.save(str(tmp_path), 1, tree)
    # flip a byte in one leaf
    target = os.path.join(d, "leaf_00000.npy")
    data = bytearray(open(target, "rb").read())
    data[-1] ^= 0xFF
    open(target, "wb").write(bytes(data))
    with pytest.raises(IOError):
        ckpt.restore(str(tmp_path), tree)


def test_orphan_tmp_dirs_cleaned(tmp_path):
    tree = _tree(jax.random.PRNGKey(3))
    orphan = tmp_path / "step_000000009.tmp-deadbeef"
    orphan.mkdir()
    ckpt.save(str(tmp_path), 1, tree)
    assert not orphan.exists()
    assert ckpt.latest_step(str(tmp_path)) == 1


def test_elastic_resharding(tmp_path):
    """Save under one mesh, restore under a different one."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    n = len(jax.devices())
    tree = {"w": jax.random.normal(jax.random.PRNGKey(4), (8 * n, 4))}
    from repro.launch.mesh import make_mesh
    mesh1 = make_mesh((n,), ("a",))
    x = jax.device_put(tree["w"], NamedSharding(mesh1, P("a", None)))
    ckpt.save(str(tmp_path), 1, {"w": x})
    # "new topology": same devices, different mesh axis layout
    mesh2 = make_mesh((1, n), ("r", "c"))
    sh2 = {"w": NamedSharding(mesh2, P(None, None))}
    restored, _ = ckpt.restore(str(tmp_path), tree, shardings=sh2)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))
    assert restored["w"].sharding == sh2["w"]


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        ckpt.restore(str(tmp_path / "nope"), {"a": jnp.zeros(1)})
