"""``repro.faults``: the declarative fault-injection plane.

Covers the tentpole acceptance criteria: per-kind deterministic RNG
streams, churn masks threaded exactly-zero through every rollout engine
and the water-fill, the ``faults=None`` bitwise no-op pin, the
graceful-degradation ladder (retry -> stale plan -> MIN fallback) with
obs counters reconciling against the legacy lists, telemetry-fault
gating, zero-rate guards in the queue/AoPI layers, and the suite-level
failure isolation of ``sweep``/``replay_suite``.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs, scenarios
from repro.core import allocate, aopi, baselines, lbcd, queues
from repro.faults import (FaultPlan, FaultSpec, InjectedSolverFault,
                          SOLVER_KINDS, apply_plan, storm_plan)
from repro.serving import replay
from repro.serving.replay import TableSystem, replay_suite, replay_tables

DIMS = dict(n_cameras=4, n_slots=12, n_servers=2,
            mean_bandwidth_hz=15e6, mean_compute_flops=20e12)


def _tables(name="steady_ar1", **kw):
    return scenarios.build(name, **{**DIMS, **kw})


@pytest.fixture(autouse=True)
def _isolated_obs():
    obs.reset()
    yield
    obs.reset()


# ---------------------------------------------------------------------------
# FaultSpec / FaultPlan units
# ---------------------------------------------------------------------------

def test_fault_spec_rejects_unknown_kind():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultSpec("cosmic_ray")


def test_fault_spec_window_clamps_to_horizon():
    assert FaultSpec("server_crash", t0=3, duration=4).window(5) == (3, 5)
    assert FaultSpec("server_crash", t0=2).window(10) == (2, 10)  # open end
    assert FaultSpec("server_crash", t0=2, duration=3).active_at(4)
    assert not FaultSpec("server_crash", t0=2, duration=3).active_at(5)


def test_per_kind_rng_streams_are_independent():
    churn_only = FaultPlan((FaultSpec("camera_churn", params={
        "fraction": 0.5}),), seed=7)
    with_fade = FaultPlan((FaultSpec("camera_churn", params={
        "fraction": 0.5}),
        FaultSpec("correlated_fade", params={"depth": 0.5}),), seed=7)
    a = churn_only.camera_active(20, 6)
    b = with_fade.camera_active(20, 6)
    # Adding a fade spec must not perturb the churn trajectory.
    np.testing.assert_array_equal(a, b)
    # Same (specs, seed) -> bitwise identical; different seed -> different.
    np.testing.assert_array_equal(a, churn_only.camera_active(20, 6))
    c = dataclasses.replace(churn_only, seed=8).camera_active(20, 6)
    assert not np.array_equal(a, c)


def test_camera_active_mask_shape_and_survivor_guarantee():
    plan = FaultPlan((FaultSpec("camera_churn", t0=2, params={
        "fraction": 0.9, "leave_prob": 0.5, "join_prob": 0.0}),), seed=0)
    act = plan.camera_active(30, 5)
    assert act.shape == (30, 5)
    assert set(np.unique(act)) <= {0.0, 1.0}
    np.testing.assert_array_equal(act[:2], 1.0)     # before t0: all live
    assert (act.sum(axis=1) >= 1.0).all()           # never an empty fleet
    assert act.min() == 0.0                         # churn actually bites


def test_camera_active_none_without_churn_specs():
    plan = FaultPlan((FaultSpec("server_crash"),), seed=0)
    assert plan.camera_active(10, 4) is None
    assert FaultPlan().camera_active(10, 4) is None


def test_capacity_factor_crash_and_fade():
    plan = FaultPlan((
        FaultSpec("server_crash", t0=3, duration=4,
                  params={"server": 1, "depth": 1.0}),
        FaultSpec("correlated_fade", t0=0, duration=None,
                  params={"fraction": 1.0, "depth": 0.6, "corr": 0.9}),
    ), seed=1)
    f = plan.capacity_factor(10, 2)
    assert f.shape == (10, 2)
    assert (f[3:7, 1] == 0.0).all()                 # crash zeroes server 1
    assert (f[:3, 1] > 0.0).all() and (f[7:, 1] > 0.0).all()
    # The fade squashes into (1 - depth, 1]; never negative, never > 1.
    assert (f >= 0.0).all() and (f <= 1.0).all()
    assert (f[:, 0] >= 1.0 - 0.6 - 1e-6).all()      # fade-only server


# ---------------------------------------------------------------------------
# apply_plan + the faults=None bitwise no-op pin
# ---------------------------------------------------------------------------

def test_apply_plan_none_returns_same_object():
    t = _tables()
    assert apply_plan(None, t) is t


def test_tables_without_active_carry_no_extra_leaf():
    # The parity mechanism: active=None adds NO pytree leaf, so every
    # maskless trace/jaxpr is structurally identical to a pre-fault-plane
    # build (6 leaves: acc, xi, size, eff, budgets_b, budgets_c).
    t = _tables()
    assert t.active is None
    assert len(jax.tree.leaves(t)) == 6
    assert len(jax.tree.leaves(_tables("camera_churn"))) == 7


def test_apply_plan_attaches_mask_and_floors_budgets():
    t = _tables()
    plan = FaultPlan((
        FaultSpec("camera_churn", t0=1, params={"fraction": 0.5}),
        FaultSpec("server_crash", t0=2, duration=4,
                  params={"server": 0, "depth": 1.0}),
    ), seed=0)
    out = apply_plan(plan, t)
    assert out is not t and t.active is None        # input untouched
    assert out.active is not None
    assert out.active.shape == (t.n_slots, t.n_cameras)
    # Crash scales budgets but the floor keeps every solver input finite
    # and positive.
    bb = np.asarray(out.budgets_b)
    assert (bb > 0.0).all()
    assert (bb[2:6, 0] < np.asarray(t.budgets_b)[2:6, 0]).all()


def test_apply_plan_intersects_existing_mask():
    t = _tables("camera_churn")
    assert t.active is not None
    plan = FaultPlan((FaultSpec("camera_churn", t0=0, params={
        "fraction": 0.5, "leave_prob": 0.3, "join_prob": 0.0}),), seed=3)
    out = apply_plan(plan, t)
    a0, a1 = np.asarray(t.active), np.asarray(out.active)
    assert (a1 <= a0 + 1e-9).all()                  # only ever removes


def test_replay_faults_none_bitwise_equals_omitted_kwarg():
    t = _tables()
    a = replay_tables(t, "lbcd", plan_window=4)
    b = replay_tables(t, "lbcd", plan_window=4, faults=None)
    np.testing.assert_array_equal(a.measured, b.measured)
    np.testing.assert_array_equal(a.predicted, b.predicted)
    np.testing.assert_array_equal(a.acc, b.acc)
    assert b.service.fallbacks == [] and b.service.degraded_epochs == []
    assert b.service.telemetry_gaps == [] and b.service.plan_failures == []


# ---------------------------------------------------------------------------
# Churn mask through the rollout engines and the water-fill
# ---------------------------------------------------------------------------

ROLLOUTS = {
    "lbcd": lambda t: lbcd.rollout(t, 10.0, 0.7),
    "min": baselines.rollout_min,
    "dos": baselines.rollout_dos,
    "jcab": baselines.rollout_jcab,
}


@pytest.mark.parametrize("policy", sorted(ROLLOUTS))
def test_rollouts_zero_inactive_cameras_exactly(policy):
    t = _tables("camera_churn", params={"churn_fraction": 0.5,
                                        "leave_prob": 0.2})
    res = ROLLOUTS[policy](t)
    dead = np.asarray(t.active) == 0.0
    assert dead.any(), "scenario must actually churn cameras out"
    for name in ("aopi", "acc"):
        arr = np.asarray(getattr(res, name))
        assert np.isfinite(arr).all()
        np.testing.assert_array_equal(arr[dead], 0.0)
    for name in ("b", "c", "lam"):
        arr = np.asarray(getattr(res.decision, name))
        assert np.isfinite(arr).all()
        np.testing.assert_array_equal(arr[dead], 0.0)


def test_waterfill_redistributes_churned_budget_to_survivors():
    n = 6
    k = jnp.full(n, 2e-7)
    p = jnp.full(n, 0.8)
    pol = jnp.full(n, aopi.LCFSP, jnp.int32)
    mu = jnp.full(n, 20.0)
    sid = jnp.zeros(n, jnp.int32)
    budgets = jnp.array([30e6])
    b_all = allocate.waterfill_bandwidth(k, p, pol, mu, sid, budgets, 1)
    act = jnp.array([1.0, 1.0, 1.0, 0.0, 0.0, 0.0])
    b_half = allocate.waterfill_bandwidth(k, p, pol, mu, sid, budgets, 1,
                                          active=act)
    b_all, b_half = np.asarray(b_all), np.asarray(b_half)
    np.testing.assert_array_equal(b_half[3:], 0.0)  # exact zero, not tiny
    # The whole budget still gets used: the survivors' share grows to
    # absorb what the churned cameras forfeited.
    assert b_half[:3].sum() == pytest.approx(float(budgets[0]), rel=5e-2)
    assert (b_half[:3] > b_all[:3]).all()


def test_waterfill_compute_masks_fcfs_floor():
    n = 4
    inv_xi = jnp.full(n, 1e-12)
    p = jnp.full(n, 0.8)
    pol = jnp.full(n, aopi.FCFS, jnp.int32)
    lam = jnp.full(n, 10.0)
    sid = jnp.zeros(n, jnp.int32)
    budgets = jnp.array([40e12])
    act = jnp.array([1.0, 0.0, 1.0, 0.0])
    c = np.asarray(allocate.waterfill_compute(inv_xi, p, pol, lam, sid,
                                              budgets, 1, active=act))
    np.testing.assert_array_equal(c[[1, 3]], 0.0)
    assert (c[[0, 2]] > 0.0).all()                  # FCFS floor survives


def test_masked_aopi_closed_form():
    lam = jnp.array([0.0, 5.0, 5.0])
    mu = jnp.array([0.0, 10.0, 10.0])
    p = jnp.array([0.9, 0.9, 0.9])
    pol = jnp.array([1, 1, 1], jnp.int32)
    out = np.asarray(aopi.aopi_masked(lam, mu, p, pol))
    ref = np.asarray(aopi.aopi(lam[1:], mu[1:], p[1:], pol[1:]))
    assert out[0] == 0.0                            # dead lane: exact zero
    np.testing.assert_array_equal(out[1:], ref)     # live lanes: bit-exact
    # Explicit active mask kills an otherwise-live lane too.
    out2 = np.asarray(aopi.aopi_masked(lam, mu, p, pol,
                                       active=jnp.array([1.0, 0.0, 1.0])))
    assert out2[1] == 0.0 and out2[2] == ref[1]


# ---------------------------------------------------------------------------
# Zero-rate guards in the queue layer
# ---------------------------------------------------------------------------

def test_simulate_zero_rate_returns_finite_empty_result():
    for lam, mu in ((0.0, 5.0), (5.0, 0.0), (0.0, 0.0)):
        s = queues.simulate(lam, mu, 0.9, 0, n_frames=64)
        assert s.mean_aopi == 0.0 and s.n_frames == 0


def test_gi_g1_window_masks_dead_streams_bitwise():
    lam = np.array([[4.0, 5.0, 0.0]])
    mu = np.array([[8.0, 0.0, 9.0]])
    p = np.full((1, 3), 0.9)
    pol = np.array([[1, 1, 1]])
    out = queues.gi_g1_window(lam, mu, p, pol, n_frames=128, horizon=30.0)
    for v in out.values():
        assert np.isfinite(v).all()
        np.testing.assert_array_equal(v[0, 1:], 0.0)
    # Live lanes are bitwise unchanged vs an all-live call on the same
    # rates (masking happens on output only).
    solo = queues.gi_g1_window(lam[:, :1], mu[:, :1], p[:, :1], pol[:, :1],
                               n_frames=128, horizon=30.0)
    assert out["aopi"][0, 0] == solo["aopi"][0, 0]
    # An explicit active mask zeroes an otherwise-live stream.
    out2 = queues.gi_g1_window(lam, mu, p, pol, n_frames=128, horizon=30.0,
                               active=np.array([[0.0, 1.0, 1.0]]))
    assert out2["aopi"][0, 0] == 0.0


# ---------------------------------------------------------------------------
# Graceful-degradation ladder + exact obs reconciliation (tentpole)
# ---------------------------------------------------------------------------

def _ladder_replay(plan, **kw):
    t = _tables("camera_churn", n_slots=16)
    return replay_tables(t, "lbcd", plan_window=4, faults=plan, **kw)


def test_storm_engages_every_ladder_rung_and_reconciles():
    obs.configure(enabled=True)
    rep = _ladder_replay(storm_plan(16, seed=3))
    svc = rep.service
    assert np.isfinite(rep.measured).all()
    reasons = [r for _, r in svc.fallbacks]
    assert "min_fallback" in reasons                # t=0: no good plan yet
    assert "stale_plan" in reasons                  # later: tile last plan
    assert len(svc.plan_failures) > len(svc.fallbacks)  # retries happened
    assert svc.degraded_epochs and svc.telemetry_gaps
    # Every degraded epoch belongs to a window opened by some fallback.
    assert set(t for t, _ in svc.fallbacks) <= set(svc.degraded_epochs)

    evs = obs.events()

    def count(name):
        return sum(1 for e in evs if e.get("name") == name)

    def ctr(name):
        c = 0.0
        for m in obs.registry():
            if m.name == name:
                c += m.value
        return c

    for name, lst in (("service.fallback", svc.fallbacks),
                      ("service.degraded_epoch", svc.degraded_epochs),
                      ("service.plan_retry", svc.plan_failures),
                      ("service.telemetry_gap", svc.telemetry_gaps)):
        assert count(name) == len(lst)
        assert ctr(name + ".count") == len(lst)
    # Event epochs match the lists in order.
    assert [e["args"]["t"] for e in evs
            if e["name"] == "service.fallback"] == \
        [t for t, _ in svc.fallbacks]


def test_solver_nonconverge_single_attempt_recovers_by_retry():
    plan = FaultPlan((FaultSpec("solver_nonconverge", t0=0, duration=1),),
                     seed=0)
    rep = _ladder_replay(plan)
    svc = rep.service
    assert svc.plan_failures and svc.fallbacks == []
    assert svc.plan_failures[0][2].startswith("InjectedSolverFault")
    assert svc.degraded_epochs == []
    assert np.isfinite(rep.measured).all()


def test_retry_exhaustion_without_prior_plan_hits_min_fallback():
    plan = FaultPlan((FaultSpec("solver_nan", t0=0, duration=1,
                                params={"attempts": 64}),), seed=0)
    rep = _ladder_replay(plan, plan_retries=1)
    svc = rep.service
    assert svc.fallbacks[0] == (0, "min_fallback")
    assert len([f for f in svc.plan_failures if f[0] == 0]) == 2  # retries+1
    assert np.isfinite(rep.measured).all()


def test_stale_plan_rung_masks_churned_cameras():
    # Fail every attempt in the SECOND plan window only: the service tiles
    # the first window's last slot and re-projects it on the live fleet.
    plan = FaultPlan((FaultSpec("solver_nonconverge", t0=4, duration=4,
                                params={"attempts": 64}),), seed=0)
    rep = _ladder_replay(plan)
    svc = rep.service
    assert (4, "stale_plan") in svc.fallbacks
    assert set(range(4, 8)) <= set(svc.degraded_epochs)
    assert np.isfinite(rep.measured).all()


def test_plan_deadline_watchdog_trips_ladder():
    rep = _ladder_replay(None, plan_deadline=0.0)
    svc = rep.service
    assert svc.fallbacks and all(f[0] is not None for f in svc.fallbacks)
    assert all("TimeoutError" in err for _, _, err in svc.plan_failures)
    assert np.isfinite(rep.measured).all()


# ---------------------------------------------------------------------------
# Telemetry faults: drop / delay / corrupt + threshold widening
# ---------------------------------------------------------------------------

def test_telemetry_drop_holds_ewma_and_records_gap():
    plan = FaultPlan((FaultSpec("telemetry_drop", t0=2, duration=3),),
                     seed=0)
    t = _tables(n_slots=10)
    clean = replay_tables(t, "lbcd", plan_window=5, telemetry_gain=0.3)
    rep = replay_tables(t, "lbcd", plan_window=5, telemetry_gain=0.3,
                        faults=plan)
    assert rep.service.telemetry_gaps == [2, 3, 4]
    assert clean.service.telemetry_gaps == []
    assert np.isfinite(rep.measured).all()


def test_telemetry_corrupt_is_rejected_not_ingested():
    plan = FaultPlan((FaultSpec("telemetry_corrupt", t0=1, duration=2),),
                     seed=0)
    rep = replay_tables(_tables(n_slots=8), "lbcd", plan_window=4,
                        telemetry_gain=0.5, faults=plan)
    assert rep.service.telemetry_gaps == [1, 2]
    # NaN never reached the filter: all downstream plans stayed finite.
    assert np.isfinite(rep.measured).all()
    assert rep.service.fallbacks == []


def test_telemetry_delay_arrives_later():
    plan = FaultPlan((FaultSpec("telemetry_delay", t0=2, duration=1,
                                params={"delay": 2}),), seed=0)
    rep = replay_tables(_tables(n_slots=8), "lbcd", plan_window=4,
                        telemetry_gain=0.5, faults=plan)
    assert rep.service.telemetry_gaps == [2]        # gap at origin epoch
    assert np.isfinite(rep.measured).all()


def test_gap_streak_widens_replan_threshold():
    svc = replay_tables(_tables(n_slots=6), "lbcd", plan_window=3,
                        telemetry_gain=0.3, replan_threshold=0.2).service
    base = svc.replan_threshold
    svc._gap_streak = 4
    assert svc._effective_replan_threshold() == pytest.approx(base * 3.0)
    svc._gap_streak = 0
    assert svc._effective_replan_threshold() == pytest.approx(base)


# ---------------------------------------------------------------------------
# Suite-level failure isolation (satellite: sweep / replay_suite)
# ---------------------------------------------------------------------------

def test_replay_suite_isolates_failing_cell(monkeypatch):
    suite = scenarios.suite(["steady_ar1", "server_outage"], **DIMS)
    real = replay.replay_tables
    calls = []

    def boom(tables, policy="lbcd", **kw):
        calls.append(policy)
        if len(calls) == 1:
            raise RuntimeError("injected cell failure")
        return real(tables, policy, **kw)

    monkeypatch.setattr(replay, "replay_tables", boom)
    res = replay.replay_suite(suite, policies=("lbcd", "min"), n_epochs=4)
    assert len(res.errors) == 1
    (key, msg), = res.errors.items()
    assert msg == "RuntimeError: injected cell failure"
    bad_name, bad_policy = key
    assert np.isnan(
        res.measured[bad_policy][res.names.index(bad_name)]).all()
    # Every other cell replayed fine.
    for p in ("lbcd", "min"):
        ok = [i for i in range(len(res.names))
              if (res.names[i], p) not in res.errors]
        assert np.isfinite(res.measured[p][ok]).all()


def test_sweep_isolates_failing_policy(monkeypatch):
    from repro.scenarios import runner
    suite = scenarios.suite(["steady_ar1"], **DIMS)
    real = runner._run_vmap

    def boom(name, *a, **kw):
        if name == "jcab":
            raise RuntimeError("solver exploded")
        return real(name, *a, **kw)

    monkeypatch.setattr(runner, "_run_vmap", boom)
    res = scenarios.sweep(suite, backend="vmap")
    assert "jcab" in res.errors
    assert "solver exploded" in res.errors["jcab"]
    assert np.isnan(res.aopi["jcab"]).all()
    for p in ("lbcd", "min", "dos"):
        assert np.isfinite(res.aopi[p]).all()


# ---------------------------------------------------------------------------
# Window / TableSystem edge cases (satellite)
# ---------------------------------------------------------------------------

def test_horizon_window_rejects_out_of_range_and_empty():
    t = _tables()
    with pytest.raises(ValueError, match="outside horizon"):
        t.window(0, t.n_slots + 1)
    with pytest.raises(ValueError, match="outside horizon"):
        t.window(-1, 2)
    with pytest.raises(ValueError, match="outside horizon"):
        t.window(3, 3)                              # empty window
    w = t.window(2, 5)
    assert w.n_slots == 3


def test_table_system_rejects_stacked_suite_and_long_horizon():
    suite = scenarios.suite(["steady_ar1"], **DIMS)
    with pytest.raises(ValueError, match="ONE scenario"):
        TableSystem(suite.tables)
    sys1 = TableSystem(_tables())
    with pytest.raises(ValueError, match="exceeds the scenario"):
        sys1.horizon(DIMS["n_slots"] + 1)


def test_replay_tables_short_n_epochs_and_overrun():
    t = _tables()
    rep = replay_tables(t, "lbcd", n_epochs=3, plan_window=8)
    assert rep.measured.shape == (3,)               # window clamps to 3
    with pytest.raises(ValueError, match="exceeds the scenario"):
        replay_tables(t, "lbcd", n_epochs=DIMS["n_slots"] + 1)


# ---------------------------------------------------------------------------
# New scenario families + degradation report
# ---------------------------------------------------------------------------

def test_churn_and_fade_families_registered():
    fams = scenarios.families()
    assert "camera_churn" in fams and "correlated_fade" in fams
    t = _tables("camera_churn")
    assert t.active is not None and 0.0 < float(t.active.mean()) < 1.0
    t2 = _tables("correlated_fade")
    assert t2.active is None                        # fades touch budgets
    ref = _tables("steady_ar1")
    assert float(t2.budgets_b.mean()) < float(ref.budgets_b.mean())
    assert (np.asarray(t2.budgets_b) > 0.0).all()


def test_degradation_report_rows_and_recovery():
    suite = scenarios.suite(["steady_ar1"], **DIMS)
    rep = scenarios.degradation(
        suite, fault_kinds=("camera_churn", "solver_nonconverge"),
        policies=("min",), n_epochs=8, plan_window=4)
    rows = rep.rows()
    assert len(rows) == 2
    for row in rows:
        policy, kind, clean, faulted, ratio, recov, fb, degr, errs = row
        assert policy == "min" and np.isfinite(clean) and clean > 0
        assert np.isfinite(faulted) and errs == 0
        assert 0.0 <= recov <= 8
    by_kind = {r[1]: r for r in rows}
    assert by_kind["solver_nonconverge"][6] > 0     # fallbacks engaged
    txt = str(rep)
    assert "camera_churn" in txt and "ratio" in txt


def test_storm_plan_covers_every_kind():
    plan = storm_plan(18)
    kinds = {s.kind for s in plan.specs}
    from repro.faults import FAULT_KINDS
    assert kinds == set(FAULT_KINDS)
    assert {s.kind for s in storm_plan(18, solver=False).specs} == \
        set(FAULT_KINDS) - set(SOLVER_KINDS)
