"""Dry-run accounting: scan-depth extrapolation + collective parser."""
import dataclasses

import pytest

from repro.launch.dryrun import collective_bytes


def test_collective_parser_on_synthetic_hlo():
    hlo = """
  ENTRY %main {
  %p0 = bf16[16,4096]{1,0} parameter(0)
  %ag = bf16[256,4096]{1,0} all-gather(bf16[16,4096]{1,0} %p0), dims={0}
  %ar = f32[8,128]{1,0} all-reduce(f32[8,128]{1,0} %x), to_apply=%sum
  %rs = f32[2,128]{1,0} reduce-scatter(f32[32,128]{1,0} %y), dims={0}
  %a2a = bf16[4,64]{1,0} all-to-all(bf16[4,64]{1,0} %z), dims={0}
  %cp = u32[7]{0} collective-permute(u32[7]{0} %w)
  %notacoll = f32[9] add(f32[9] %a, f32[9] %b)
}
"""
    out = collective_bytes(hlo)
    assert out["bytes"]["all-gather"] == 256 * 4096 * 2
    assert out["bytes"]["all-reduce"] == 8 * 128 * 4
    assert out["bytes"]["reduce-scatter"] == 2 * 128 * 4
    assert out["bytes"]["all-to-all"] == 4 * 64 * 2
    assert out["bytes"]["collective-permute"] == 7 * 4
    assert out["counts"]["all-gather"] == 1
    assert out["total_bytes"] == sum(out["bytes"].values())


def test_depth_extrapolation_matches_unrolled():
    """On a tiny config: extrapolated flops from depth 1/2 == actual flops
    of a fully-unrolled depth-4 model (within a small tolerance)."""
    import jax
    import jax.numpy as jnp

    from repro import configs
    from repro.models import build
    from repro.models.common import abstract_params

    cfg0 = configs.get("qwen2.5-3b").reduced()

    def flops_at(n_layers, force_unroll):
        cfg = dataclasses.replace(cfg0, n_layers=n_layers)
        model = build(cfg)
        tmpl = model.template()
        params = abstract_params(tmpl, jnp.float32)
        batch = {"tokens": jax.ShapeDtypeStruct((2, 32), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((2, 32), jnp.int32)}

        def loss(p, b):
            return model.loss(p, b)

        if force_unroll:
            # monkeypatch threshold: unroll everything by splitting params
            import repro.models.transformer as tr
            orig = tr.jax.lax.scan

            def fake_scan(f, init, xs, **kw):
                n = jax.tree.leaves(xs)[0].shape[0]
                carry = init
                ys = []
                for i in range(n):
                    carry, y = f(carry, jax.tree.map(lambda t: t[i], xs))
                    ys.append(y)
                ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
                return carry, ys
            tr.jax.lax.scan = fake_scan
            try:
                c = jax.jit(loss).lower(params, batch).compile()
            finally:
                tr.jax.lax.scan = orig
        else:
            c = jax.jit(loss).lower(params, batch).compile()
        from repro.launch.dryrun import cost_analysis
        return cost_analysis(c).get("flops", 0.0)

    f1 = flops_at(1, False)     # <=2 periods auto-unrolls
    f2 = flops_at(2, False)
    extrapolated = f1 + 3 * (f2 - f1)
    actual = flops_at(4, True)
    assert extrapolated == pytest.approx(actual, rel=0.05)


def test_fused_attention_memory_correction_positive():
    from repro.launch.roofline import attention_score_bytes
    from repro import configs
    from repro.configs.base import SHAPES
    cfg = configs.get("yi-6b")
    b = attention_score_bytes(cfg, SHAPES["prefill_32k"], n_devices=256)
    assert b > 0
