"""Fused slot-solver kernels vs the jnp backend: parity + dispatch shape.

Pallas runs in interpret mode on CPU (the ops layer auto-selects it
off-TPU), so everything here exercises the exact kernel code paths that
compile on device.
"""
import functools
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import core as jax_core

from repro.core import allocate, aopi, baselines, bcd, lbcd, profiles
from repro.kernels import slot_solver
from repro.kernels.slot_solver import ops as slot_ops


def _setup(n, s, seed=0, lcfsp_frac=0.5, budget_lo=2e7, budget_hi=5e7,
           server_id=None):
    rng = np.random.default_rng(seed)
    k = rng.uniform(1e-6, 5e-6, n)
    p = rng.uniform(0.3, 0.95, n)
    pol = (rng.random(n) < lcfsp_frac).astype(np.int32)
    mu = rng.uniform(5.0, 40.0, n)
    if server_id is None:
        server_id = rng.integers(0, s, n).astype(np.int32)
    budgets = rng.uniform(budget_lo, budget_hi, s)
    return (jnp.asarray(k, jnp.float32), jnp.asarray(p, jnp.float32),
            jnp.asarray(pol), jnp.asarray(mu, jnp.float32),
            jnp.asarray(server_id), jnp.asarray(budgets, jnp.float32))


# ---------------------------------------------------------------------------
# ServerLayout
# ---------------------------------------------------------------------------

def test_server_layout_roundtrip_and_padding():
    sid = jnp.asarray([2, 0, 2, 1, 0, 2, 0], jnp.int32)
    layout = slot_solver.server_layout(sid, 3)
    n = sid.shape[0]
    assert layout.capacity % 128 == 0 and layout.capacity >= n
    np.testing.assert_array_equal(np.asarray(layout.counts), [3, 1, 3])
    order = np.asarray(layout.order)
    mask = np.asarray(layout.mask)
    # Every camera appears exactly once, on its own server's row, in
    # ascending original order (stable sort); padding slots carry the
    # sentinel and zero mask.
    real = order[mask > 0]
    assert sorted(real.tolist()) == list(range(n))
    for s in range(3):
        row = order[s][mask[s] > 0]
        assert all(np.asarray(sid)[i] == s for i in row)
        assert list(row) == sorted(row)
    assert (order[mask == 0] == n).all()
    # gather -> scatter is the identity on per-camera vectors.
    x = jnp.arange(1.0, n + 1.0)
    np.testing.assert_allclose(
        np.asarray(layout.scatter(layout.gather(x), n)), np.asarray(x))


def test_server_layout_capacity_floor_and_overflow():
    # Sub-lane capacities round up to the 128-lane floor: nothing drops.
    sid = jnp.zeros((5,), jnp.int32)
    layout = slot_solver.server_layout(sid, 1, capacity=2)
    assert layout.capacity == 128
    assert int(layout.mask.sum()) == 5
    # A server loaded past the rounded capacity drops the overflow from
    # its row view; the flat view still carries every camera.
    sid = jnp.zeros((130,), jnp.int32)
    layout = slot_solver.server_layout(sid, 1, capacity=100)
    assert layout.capacity == 128
    assert int(layout.mask.sum()) == 128          # 2 dropped from the row
    assert int(layout.counts[0]) == 130
    assert int(layout.flat_mask.sum()) == 130     # flat view is complete
    x = jnp.arange(130.0)
    np.testing.assert_allclose(
        np.asarray(layout.scatter_flat(layout.gather_flat(x), 130)),
        np.asarray(x))


def test_server_layout_empty_server():
    sid = jnp.asarray([0, 0, 2, 2], jnp.int32)
    layout = slot_solver.server_layout(sid, 3)
    assert int(layout.counts[1]) == 0
    assert float(layout.mask[1].sum()) == 0.0


# ---------------------------------------------------------------------------
# Water-filling kernel vs jnp _waterfill
# ---------------------------------------------------------------------------

def _assert_bandwidth_parity(n, s, seed, lcfsp_frac, budget_lo=2e7,
                             budget_hi=5e7, server_id=None):
    k, p, pol, mu, sid, B = _setup(n, s, seed=seed, lcfsp_frac=lcfsp_frac,
                                   budget_lo=budget_lo, budget_hi=budget_hi,
                                   server_id=server_id)
    b_ref = np.asarray(allocate.waterfill_bandwidth(
        k, p, pol, mu, sid, B, n_servers=s))
    b_pl = np.asarray(slot_solver.waterfill_bandwidth(
        k, p, pol, mu, sid, B, n_servers=s))
    np.testing.assert_allclose(b_pl, b_ref, rtol=2e-4, atol=1e-2)
    return b_pl, np.asarray(sid), np.asarray(B)


def test_waterfill_bandwidth_parity_hypothesis():
    """Random FCFS/LCFSP mixes: pallas-interpret == jnp ``_waterfill``."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=15, deadline=None)
    @given(st.integers(0, 10_000), st.sampled_from([0.0, 0.3, 0.5, 1.0]))
    def inner(seed, frac):
        _assert_bandwidth_parity(10, 2, seed, frac)
    inner()


def test_waterfill_compute_parity_hypothesis():
    """Compute side (FCFS stability floors active) parity."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=15, deadline=None)
    @given(st.integers(0, 10_000), st.sampled_from([0.0, 0.5, 1.0]))
    def inner(seed, frac):
        rng = np.random.default_rng(seed)
        n, s = 10, 2
        inv_xi = jnp.asarray(rng.uniform(1e-12, 5e-12, n), jnp.float32)
        p = jnp.asarray(rng.uniform(0.3, 0.95, n), jnp.float32)
        pol = jnp.asarray((rng.random(n) < frac).astype(np.int32))
        lam = jnp.asarray(rng.uniform(1.0, 10.0, n), jnp.float32)
        sid = jnp.asarray(rng.integers(0, s, n).astype(np.int32))
        C = jnp.asarray(rng.uniform(3e13, 8e13, s), jnp.float32)
        c_ref = np.asarray(allocate.waterfill_compute(
            inv_xi, p, pol, lam, sid, C, n_servers=s))
        c_pl = np.asarray(slot_solver.waterfill_compute(
            inv_xi, p, pol, lam, sid, C, n_servers=s))
        np.testing.assert_allclose(c_pl, c_ref, rtol=2e-4, atol=1e4)
    inner()


def test_waterfill_slack_budget_keeps_caps():
    """When the FCFS caps sum below the budget the constraint is slack:
    both backends return the caps and stay (well) under budget."""
    # All-FCFS + huge budgets -> hi = lam*/(k*B) << 1 per camera.
    b, sid, B = _assert_bandwidth_parity(8, 2, seed=11, lcfsp_frac=0.0,
                                         budget_lo=5e9, budget_hi=9e9)
    for s in range(2):
        m = sid == s
        assert b[m].sum() < 0.9 * B[s]


def test_waterfill_degenerate_single_camera_servers():
    """One camera per server: the dual search degenerates to the
    per-camera cap; backends must still agree."""
    n = 6
    _assert_bandwidth_parity(n, n, seed=3, lcfsp_frac=0.5,
                             server_id=np.arange(n, dtype=np.int32))


def test_waterfill_budget_respected_and_positive():
    b, sid, B = _assert_bandwidth_parity(12, 3, seed=7, lcfsp_frac=0.5)
    assert (b > 0).all() and np.isfinite(b).all()
    for s in range(3):
        assert b[sid == s].sum() <= float(B[s]) * 1.001


# ---------------------------------------------------------------------------
# Streaming config argmin vs materialized reference
# ---------------------------------------------------------------------------

def _config_inputs(n, seed=0, m=5, r=6):
    rng = np.random.default_rng(seed)
    acc = jnp.asarray(rng.uniform(0.2, 0.95, (n, m, r)), jnp.float32)
    xi = jnp.asarray(np.sort(rng.uniform(1e9, 2e11, (m, r)), axis=1),
                     jnp.float32)
    size = jnp.asarray(1.2 * np.asarray(profiles.RESOLUTIONS)[:r] ** 2,
                       jnp.float32)
    eff = jnp.asarray(rng.uniform(4.0, 7.0, n), jnp.float32)
    b = jnp.asarray(rng.uniform(1e6, 1e7, n), jnp.float32)
    c = jnp.asarray(rng.uniform(1e12, 1e13, n), jnp.float32)
    return b, c, acc, xi, size, eff


@pytest.mark.parametrize("n,block_n", [(7, 1024), (40, 16), (64, 64)])
def test_config_argmin_matches_ref(n, block_n):
    """Streaming kernel == flat argmin (incl. non-divisible tiling)."""
    for seed in range(3):
        b, c, acc, xi, size, eff = _config_inputs(n, seed=seed)
        ref = slot_solver.config_argmin_ref(b, c, acc, xi, size, eff,
                                            1.3, 10.0, n)
        out = slot_solver.config_argmin(b, c, acc, xi, size, eff,
                                        1.3, 10.0, n, backend="pallas",
                                        block_n=block_n)
        for name, a, o in zip(("r", "m", "pol"), ref, out):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(o),
                                          err_msg=f"{name} seed={seed}")


# ---------------------------------------------------------------------------
# Full Algorithm-1 solve + rollout backend parity
# ---------------------------------------------------------------------------

def _slot_instance(seed, n=12, s=3):
    rng = np.random.default_rng(seed)
    sys = profiles.EdgeSystem(n_cameras=n, n_servers=s, n_slots=4,
                              seed=seed)
    tab = sys.horizon(1)
    sid = jnp.asarray(rng.integers(0, s, n).astype(np.int32))
    return (tab.acc[0], tab.xi, tab.size, tab.eff, sid, tab.budgets_b[0],
            tab.budgets_c[0], jnp.float32(rng.uniform(0.0, 3.0)),
            jnp.float32(rng.uniform(1.0, 30.0)))


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_solve_slot_pallas_matches_jnp(seed):
    args = _slot_instance(seed)
    d_jnp = bcd.solve_slot(*args, n_servers=3)
    d_pl = bcd.solve_slot(*args, n_servers=3, solver_backend="pallas")
    for f in ("r_idx", "m_idx", "pol"):
        np.testing.assert_array_equal(np.asarray(getattr(d_jnp, f)),
                                      np.asarray(getattr(d_pl, f)),
                                      err_msg=f"{f} seed={seed}")
    for f in ("b", "c", "lam", "mu", "acc", "aopi"):
        np.testing.assert_allclose(np.asarray(getattr(d_pl, f)),
                                   np.asarray(getattr(d_jnp, f)),
                                   rtol=5e-4, err_msg=f"{f} seed={seed}")
    assert float(d_pl.score) == pytest.approx(float(d_jnp.score), rel=1e-4)


def test_solve_slot_pallas_rejects_interior_point():
    args = _slot_instance(5)
    with pytest.raises(ValueError, match="interior"):
        bcd.solve_slot(*args, n_servers=3, method="interior",
                       solver_backend="pallas")
    with pytest.raises(ValueError, match="solver_backend"):
        bcd.solve_slot(*args, n_servers=3, solver_backend="cuda")


def test_rollout_backend_parity():
    """Whole-horizon scan (first-fit assignments traced through the
    layout build) agrees across backends.

    Contract: per-slot parity is float32-tight *given the assignment*,
    but the backends' different fp summation order can flip a knife-edge
    first-fit tie into a different (equally valid) placement on rare
    slots — same amplification the shard_map caveat documents. So slots
    with identical assignments must match tightly, tie-flip slots must be
    rare, and the fleet aggregate must agree closely either way."""
    sys = profiles.EdgeSystem(n_cameras=10, n_servers=3, n_slots=8,
                              mean_bandwidth_hz=15e6,
                              mean_compute_flops=20e12)
    tab = sys.horizon(8)
    r_jnp = lbcd.rollout(tab, 10.0, 0.7)
    r_pl = lbcd.rollout(tab, 10.0, 0.7, solver_backend="pallas")
    same = np.all(np.asarray(r_jnp.assign) == np.asarray(r_pl.assign),
                  axis=1)
    assert same.mean() >= 0.75, f"tie flips on {(~same).sum()}/8 slots"
    np.testing.assert_allclose(np.asarray(r_pl.aopi)[same],
                               np.asarray(r_jnp.aopi)[same], rtol=1e-3)
    np.testing.assert_allclose(np.asarray(r_pl.aopi).mean(axis=1),
                               np.asarray(r_jnp.aopi).mean(axis=1),
                               rtol=5e-3)
    np.testing.assert_allclose(np.asarray(r_pl.q), np.asarray(r_jnp.q),
                               rtol=1e-3, atol=1e-4)


def test_sweep_threads_solver_backend():
    """``scenarios.sweep(..., solver_backend="pallas")`` reproduces the jnp
    sweep. Strict parity is pinned on one device (vmap — no
    ``num_partitions > 1`` rewrite involved); with more devices visible
    (the CI kernel step's 4 virtual ones) the shard_map path must also run
    and agree statistically, per the documented first-fit tie caveat."""
    from repro import scenarios
    from repro.core import profiles as prof

    stacked = prof.stack_horizons(
        [prof.EdgeSystem(n_cameras=6, n_servers=2, n_slots=3,
                         seed=i).horizon(3) for i in range(2)])
    one = jax.devices()[:1]
    r_jnp = scenarios.sweep(stacked, policies=("lbcd", "min"), devices=one)
    r_pl = scenarios.sweep(stacked, policies=("lbcd", "min"), devices=one,
                           solver_backend="pallas")
    for pol in ("lbcd", "min"):
        np.testing.assert_allclose(r_pl.aopi[pol], r_jnp.aopi[pol],
                                   rtol=1e-3, err_msg=pol)
        np.testing.assert_allclose(r_pl.acc[pol], r_jnp.acc[pol],
                                   rtol=1e-3, err_msg=pol)
    if len(jax.devices()) > 1:
        r_sh = scenarios.sweep(stacked, policies=("lbcd",),
                               backend="shard_map",
                               solver_backend="pallas")
        assert r_sh.backend.startswith("shard_map")
        np.testing.assert_allclose(r_sh.aopi["lbcd"].mean(),
                                   r_jnp.aopi["lbcd"].mean(), rtol=0.05)


# ---------------------------------------------------------------------------
# Dispatch structure: one fused call per water-fill, no [N, M, R, 2] HBM
# tensor on the pallas path.
# ---------------------------------------------------------------------------

def _walk_eqns(jaxpr):
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            for sub in _subjaxprs(v):
                yield from _walk_eqns(sub)


def _subjaxprs(v):
    if isinstance(v, jax_core.ClosedJaxpr):
        return [v.jaxpr]
    if isinstance(v, jax_core.Jaxpr):
        return [v]
    if isinstance(v, (list, tuple)):
        return [j for x in v for j in _subjaxprs(x)]
    return []


def _prim_counts(jaxpr):
    counts = {}
    for eqn in _walk_eqns(jaxpr):
        counts[eqn.primitive.name] = counts.get(eqn.primitive.name, 0) + 1
    return counts


def _has_aval_shape(jaxpr, shape):
    return any(tuple(getattr(var.aval, "shape", ())) == tuple(shape)
               for eqn in _walk_eqns(jaxpr) for var in eqn.outvars)


def test_waterfill_pallas_is_single_dispatch():
    """The fused allocator is ONE pallas_call; the jnp allocator's outer
    loop re-dispatches segment_sum (scatter-add) every iteration."""
    k, p, pol, mu, sid, B = _setup(12, 3)
    fused = jax.make_jaxpr(functools.partial(
        slot_solver.waterfill_bandwidth, n_servers=3))(k, p, pol, mu, sid, B)
    counts = _prim_counts(fused.jaxpr)
    assert counts.get("pallas_call", 0) == 1
    # The whole dual search runs inside that one call: the only scatter-add
    # is the one-time per-server camera count of the layout build, and the
    # only scatters are the layout's gather table + the single allocation
    # write-back — nothing per outer iteration.
    assert counts.get("scatter-add", 0) <= 1
    assert counts.get("scatter", 0) <= 2

    ref = jax.make_jaxpr(functools.partial(
        allocate.waterfill_bandwidth, n_servers=3))(k, p, pol, mu, sid, B)
    ref_counts = _prim_counts(ref.jaxpr)
    assert ref_counts.get("pallas_call", 0) == 0
    assert ref_counts.get("scatter-add", 0) >= 3   # fill residual per phase


def test_config_argmin_pallas_never_materializes_score_tensor():
    n, m, r = 24, 5, 6
    b, c, acc, xi, size, eff = _config_inputs(n, m=m, r=r)
    args = (b, c, acc, xi, size, eff, 1.0, 10.0)

    ref = jax.make_jaxpr(
        lambda *a: slot_solver.config_argmin(*a, n_total=n,
                                             backend="jnp"))(*args)
    assert _has_aval_shape(ref.jaxpr, (n, m, r, 2))

    fused = jax.make_jaxpr(
        lambda *a: slot_solver.config_argmin(*a, n_total=n,
                                             backend="pallas",
                                             block_n=8))(*args)
    assert not _has_aval_shape(fused.jaxpr, (n, m, r, 2))
    assert _prim_counts(fused.jaxpr).get("pallas_call", 0) == 1


def test_solve_slot_pallas_dispatch_structure():
    """Whole Algorithm-1 solve: every BCD pass is 2 fused dispatches
    (config + one two-water-fill kernel) and the big score tensor never
    hits HBM; ``nofuse`` splits the pair back into two dispatches."""
    args = _slot_instance(0)
    n, n_m, n_r = args[0].shape
    fused = jax.make_jaxpr(functools.partial(
        bcd.solve_slot, n_servers=3, solver_backend="pallas"))(*args)
    counts = _prim_counts(fused.jaxpr)
    # 1 config + 1 fused pair in the BCD body + 1 fused polish pair.
    assert counts.get("pallas_call", 0) == 3
    assert not _has_aval_shape(fused.jaxpr, (n, n_m, n_r, 2))

    seq = jax.make_jaxpr(functools.partial(
        bcd.solve_slot, n_servers=3,
        solver_backend="pallas:nofuse"))(*args)
    # 1 config + 2 water-fills in the BCD body + 2 polish water-fills.
    assert _prim_counts(seq.jaxpr).get("pallas_call", 0) == 5

    ref = jax.make_jaxpr(functools.partial(
        bcd.solve_slot, n_servers=3))(*args)
    assert _has_aval_shape(ref.jaxpr, (n, n_m, n_r, 2))
    assert _prim_counts(ref.jaxpr).get("pallas_call", 0) == 0


# ---------------------------------------------------------------------------
# solver_backend="auto": fleet-size dispatch (BENCH_slot_solver.json shows
# N=30 jnp-favoured under 128-lane padding, N>=300 pallas-favoured).
# ---------------------------------------------------------------------------

def test_resolve_backend_switch_point():
    thr = bcd.AUTO_PALLAS_MIN_CAMERAS
    assert bcd.resolve_backend("auto", thr - 1) == "jnp"
    assert bcd.resolve_backend("auto", thr) == "pallas"
    assert bcd.resolve_backend("auto", 30) == "jnp"        # benched regime
    assert bcd.resolve_backend("auto", 3000) == "pallas"   # benched regime
    # interior-point is jnp-only: auto never hands it to pallas.
    assert bcd.resolve_backend("auto", 10 * thr, method="interior") == "jnp"
    # Explicit backends pass through regardless of fleet size.
    assert bcd.resolve_backend("jnp", 10 * thr) == "jnp"
    assert bcd.resolve_backend("pallas", 2) == "pallas"
    with pytest.raises(ValueError, match="unknown solver_backend"):
        bcd.resolve_backend("nope", 10)


def test_auto_backend_dispatch_choice_pinned():
    """Below the threshold an auto solve traces the pure-jnp program (no
    pallas_call); at the threshold it traces the fused kernels."""
    small = _slot_instance(0, n=bcd.AUTO_PALLAS_MIN_CAMERAS - 108)  # n=20
    jx = jax.make_jaxpr(functools.partial(
        bcd.solve_slot, n_servers=3, solver_backend="auto"))(*small)
    assert _prim_counts(jx.jaxpr).get("pallas_call", 0) == 0

    big = _slot_instance(0, n=bcd.AUTO_PALLAS_MIN_CAMERAS)
    jx = jax.make_jaxpr(functools.partial(
        bcd.solve_slot, n_servers=3, solver_backend="auto"))(*big)
    assert _prim_counts(jx.jaxpr).get("pallas_call", 0) == 3


def test_auto_backend_grid_path_switch():
    """The jnp fallback below the switch point also holds on the vmapped
    (V, P_min) grid path: an auto grid over a small fleet traces zero
    pallas_calls, and crosses over with the fleet like ``solve_slot``."""
    vs = jnp.linspace(1.0, 20.0, 2)
    p_mins = jnp.linspace(0.5, 0.8, 2)

    def trace(n):
        tab = profiles.EdgeSystem(n_cameras=n, n_servers=3,
                                  n_slots=2).horizon(2)
        jx = jax.make_jaxpr(lambda t: lbcd.rollout_grid(
            t, vs, p_mins, solver_backend="auto"))(tab)
        return _prim_counts(jx.jaxpr).get("pallas_call", 0)

    assert trace(bcd.AUTO_PALLAS_MIN_CAMERAS - 108) == 0
    assert trace(bcd.AUTO_PALLAS_MIN_CAMERAS) >= 1


# ---------------------------------------------------------------------------
# Spec strings: tiling/fusion knobs and the fleet-size tile policy.
# ---------------------------------------------------------------------------

def test_parse_backend_knobs():
    assert bcd.parse_backend("pallas") == bcd.SolverSpec("pallas", None,
                                                         True)
    assert bcd.parse_backend("pallas:tile=4096") == bcd.SolverSpec(
        "pallas", 4096, True)
    assert bcd.parse_backend("auto:tile=2048:nofuse") == bcd.SolverSpec(
        "auto", 2048, False)
    assert bcd.parse_backend("pallas:nofuse").fuse is False
    assert bcd.parse_backend("jnp:fuse").fuse is True
    # An already-parsed spec passes through untouched.
    spec = bcd.SolverSpec("pallas", 128, False)
    assert bcd.parse_backend(spec) is spec
    with pytest.raises(ValueError, match="unknown solver_backend knob"):
        bcd.parse_backend("pallas:block=4")
    with pytest.raises(ValueError, match="unknown solver_backend"):
        bcd.parse_backend("cuda:tile=2")


def test_resolve_spec_tile_policy():
    thr = bcd.AUTO_TILE_MIN_CAMERAS
    # Auto-tiling engages at the measured streaming-win threshold.
    assert bcd.resolve_spec("auto", thr).tile_n == bcd.DEFAULT_TILE_N
    assert bcd.resolve_spec("pallas", thr).tile_n == bcd.DEFAULT_TILE_N
    assert bcd.resolve_spec("pallas", thr - 1).tile_n is None
    # tile=0 pins the single-program kernel even at scale.
    assert bcd.resolve_spec("pallas:tile=0", 10 * thr).tile_n is None
    # A tile the whole fleet fits inside degenerates to untiled (keeps
    # the fused pair dispatch available).
    assert bcd.resolve_spec(f"pallas:tile={bcd.DEFAULT_TILE_N}",
                            3000).tile_n is None
    assert bcd.resolve_spec("pallas:tile=128", 300).tile_n == 128
    # jnp never tiles; a resolved spec never carries "auto".
    assert bcd.resolve_spec("jnp:tile=4096", 10 * thr).tile_n is None
    assert bcd.resolve_spec("auto", 30) == bcd.SolverSpec("jnp", None, True)
    assert bcd.resolve_spec("auto", 10 * thr).backend == "pallas"


# ---------------------------------------------------------------------------
# Camera-tiled streaming water-fill vs the whole-fleet kernel.
# ---------------------------------------------------------------------------

def _assert_tiled_parity(n, s, seed, lcfsp_frac, tile, budget_lo=2e7,
                         budget_hi=5e7, server_id=None):
    k, p, pol, mu, sid, B = _setup(n, s, seed=seed, lcfsp_frac=lcfsp_frac,
                                   budget_lo=budget_lo, budget_hi=budget_hi,
                                   server_id=server_id)
    b_whole = np.asarray(slot_solver.waterfill_bandwidth(
        k, p, pol, mu, sid, B, n_servers=s))
    b_tiled = np.asarray(slot_solver.waterfill_bandwidth(
        k, p, pol, mu, sid, B, n_servers=s, tile_n=tile))
    # Same Illinois math (deferred bracket update); only the per-server
    # fill-sum accumulation order differs (tile partial sums).
    np.testing.assert_allclose(b_tiled, b_whole, rtol=1e-4, atol=1e-3)
    b_ref = np.asarray(allocate.waterfill_bandwidth(
        k, p, pol, mu, sid, B, n_servers=s))
    np.testing.assert_allclose(b_tiled, b_ref, rtol=2e-4, atol=1e-2)
    return b_tiled, np.asarray(sid), np.asarray(B)


def test_waterfill_tiled_parity_hypothesis():
    """Ragged fleet sizes (not multiples of the tile), mixed policies:
    streamed tiles == whole-fleet kernel == jnp reference."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 10_000), st.sampled_from([0.0, 0.5, 1.0]),
           st.sampled_from([(37, 3), (130, 2), (300, 5)]),
           st.sampled_from([128, 256]))
    def inner(seed, frac, ns, tile):
        n, s = ns
        _assert_tiled_parity(n, s, seed, frac, tile)
    inner()


@pytest.mark.parametrize("n,s,tile", [(37, 3, 128), (130, 2, 128),
                                      (300, 5, 256)])
def test_waterfill_tiled_parity_ragged(n, s, tile):
    """Deterministic core of the hypothesis sweep (runs even without
    hypothesis installed): N not a multiple of the tile."""
    for seed in (0, 1):
        _assert_tiled_parity(n, s, seed, lcfsp_frac=0.5, tile=tile)


def test_waterfill_tiled_single_camera_servers():
    n = 6
    _assert_tiled_parity(n, n, seed=3, lcfsp_frac=0.5, tile=128,
                         server_id=np.arange(n, dtype=np.int32))


def test_waterfill_tiled_slack_budget():
    b, sid, B = _assert_tiled_parity(8, 2, seed=11, lcfsp_frac=0.0,
                                     tile=128, budget_lo=5e9,
                                     budget_hi=9e9)
    for s in range(2):
        assert b[sid == s].sum() < 0.9 * B[s]


def _pallas_call_operand_shapes(jaxpr):
    return {tuple(getattr(v.aval, "shape", ()))
            for eqn in _walk_eqns(jaxpr) if eqn.primitive.name ==
            "pallas_call" for v in eqn.invars}


def test_waterfill_tiled_streams_constant_vmem():
    """The whole-fleet kernel takes the f32 ``[S, cap]`` membership
    matrix (and every per-camera vector) as VMEM operands; the tiled
    kernel's only operand is the packed ``[8, Np]`` HBM block —
    membership is recomputed per ``[S, tile]`` window inside the kernel,
    so VMEM holds O(tile), not O(N)."""
    k, p, pol, mu, sid, B = _setup(300, 2)
    cap = slot_solver.server_layout(sid, 2).flat_order.shape[0]
    assert cap > 128
    whole = jax.make_jaxpr(functools.partial(
        slot_solver.waterfill_bandwidth, n_servers=2))(k, p, pol, mu,
                                                       sid, B)
    assert (2, cap) in _pallas_call_operand_shapes(whole.jaxpr)
    tiled = jax.make_jaxpr(functools.partial(
        slot_solver.waterfill_bandwidth, n_servers=2,
        tile_n=128))(k, p, pol, mu, sid, B)
    np_ = -(-cap // 128) * 128
    assert _pallas_call_operand_shapes(tiled.jaxpr) == {(8, np_)}
    assert _prim_counts(tiled.jaxpr).get("pallas_call", 0) == 1


def test_solve_slot_tiled_spec_matches_jnp():
    """A forced-streaming spec string agrees with the jnp solve end to
    end (config indices bitwise, allocations to float32 tolerance)."""
    args = _slot_instance(1, n=40)
    d_jnp = bcd.solve_slot(*args, n_servers=3)
    d_t = bcd.solve_slot(*args, n_servers=3,
                         solver_backend="pallas:tile=128")
    for f in ("r_idx", "m_idx", "pol"):
        np.testing.assert_array_equal(np.asarray(getattr(d_jnp, f)),
                                      np.asarray(getattr(d_t, f)),
                                      err_msg=f)
    for f in ("b", "c", "acc", "aopi"):
        np.testing.assert_allclose(np.asarray(getattr(d_t, f)),
                                   np.asarray(getattr(d_jnp, f)),
                                   rtol=5e-4, err_msg=f)


# ---------------------------------------------------------------------------
# Streaming DOS/JCAB config scans (core.baselines).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode,threshold",
                         [("dos", 0.3), ("dos", 3.0),
                          ("jcab", 0.5), ("jcab", 1e-6)])
def test_baseline_argmax_bitwise(mode, threshold):
    """Streaming kernel == materialized argmax, bitwise, incl. a
    non-divisible camera tile and the JCAB all-infeasible fallback
    (threshold=1e-6 makes every config miss the cap)."""
    for seed in range(3):
        b, c, acc, xi, size, eff = _config_inputs(29, seed=seed)
        ref = slot_solver.baseline_argmax_ref(
            b, c, acc, xi, size, eff, mode=mode, threshold=threshold)
        out = slot_solver.baseline_argmax(
            b, c, acc, xi, size, eff, mode=mode, threshold=threshold,
            backend="pallas", block_n=16)
        for name, a, o in zip(("m", "r"), ref, out):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(o),
                                          err_msg=f"{name} seed={seed}")


def test_baseline_rollout_backend_parity():
    """Whole-horizon DOS/JCAB rollouts are bitwise identical across the
    scan engines (the kernel reproduces the argmax exactly and everything
    downstream is index arithmetic)."""
    tab = profiles.EdgeSystem(n_cameras=40, n_servers=3,
                              n_slots=4).horizon(4)
    for name, fn in (("dos", baselines.rollout_dos),
                     ("jcab", baselines.rollout_jcab)):
        r_jnp = fn(tab)
        r_pl = fn(tab, solver_backend="pallas")
        for f in ("m_idx", "r_idx"):
            np.testing.assert_array_equal(
                np.asarray(getattr(r_jnp.decision, f)),
                np.asarray(getattr(r_pl.decision, f)),
                err_msg=f"{name} {f}")
        np.testing.assert_array_equal(np.asarray(r_jnp.aopi),
                                      np.asarray(r_pl.aopi),
                                      err_msg=name)


_STRUCTURAL_PRIMS = frozenset({
    "dynamic_slice", "slice", "squeeze", "reshape", "broadcast_in_dim",
    "transpose", "convert_element_type", "copy", "gather", "concatenate",
    "pad", "pjit", "scan", "while", "cond", "closed_call", "pallas_call",
    "custom_jvp_call", "custom_vjp_call_jaxpr",
})


def _arith_shape_count(jaxpr, shape):
    """Eqns computing (not merely moving) a value of ``shape``."""
    return sum(1 for eqn in _walk_eqns(jaxpr)
               if eqn.primitive.name not in _STRUCTURAL_PRIMS
               and any(tuple(getattr(v.aval, "shape", ())) == tuple(shape)
                       for v in eqn.outvars))


def test_baseline_rollouts_never_materialize_score_tensor():
    """On the pallas path no [N, M, R] value is ever *computed* — the
    only full-size avals are slices of the input accuracy table. The jnp
    path computes at least five (rates, latency, scores, masks)."""
    tab = profiles.EdgeSystem(n_cameras=24, n_servers=3,
                              n_slots=3).horizon(3)
    n, (n_m, n_r) = 24, tab.xi.shape
    for name, fn in (("dos", baselines.rollout_dos),
                     ("jcab", baselines.rollout_jcab)):
        jx = jax.make_jaxpr(functools.partial(
            fn, solver_backend="jnp"))(tab)
        assert _arith_shape_count(jx.jaxpr, (n, n_m, n_r)) >= 5, name
        px = jax.make_jaxpr(functools.partial(
            fn, solver_backend="pallas"))(tab)
        assert _arith_shape_count(px.jaxpr, (n, n_m, n_r)) == 0, name
        assert _prim_counts(px.jaxpr).get("pallas_call", 0) >= 1, name


# ---------------------------------------------------------------------------
# Large-fleet smoke (CI kernel step runs this with REPRO_SMOKE_10K=1).
# ---------------------------------------------------------------------------

@pytest.mark.skipif(os.environ.get("REPRO_SMOKE_10K") != "1",
                    reason="10^4-camera interpret smoke; set "
                           "REPRO_SMOKE_10K=1 (CI kernel step) to run")
def test_tiled_smoke_10k_cameras():
    """N=10^4 end-to-end solve through the streaming kernel (small tile
    so it actually streams ~5 tiles) against the whole-fleet kernel."""
    n = 10_000
    tab = profiles.EdgeSystem(n_cameras=n, n_servers=3,
                              n_slots=1).horizon(1)
    rng = np.random.default_rng(0)
    sid = jnp.asarray(rng.integers(0, 3, n).astype(np.int32))
    args = (tab.acc[0], tab.xi, tab.size, tab.eff, sid, tab.budgets_b[0],
            tab.budgets_c[0], jnp.float32(1.0), jnp.float32(10.0))
    d_t = bcd.solve_slot(*args, n_servers=3,
                         solver_backend="pallas:tile=2048")
    d_0 = bcd.solve_slot(*args, n_servers=3,
                         solver_backend="pallas:tile=0")
    b = np.asarray(d_t.b)
    assert np.isfinite(b).all() and (b > 0).all()
    B = np.asarray(tab.budgets_b[0])
    sid_np = np.asarray(sid)
    for s in range(3):
        assert b[sid_np == s].sum() <= B[s] * 1.001
    np.testing.assert_array_equal(np.asarray(d_t.m_idx),
                                  np.asarray(d_0.m_idx))
    np.testing.assert_array_equal(np.asarray(d_t.r_idx),
                                  np.asarray(d_0.r_idx))
    np.testing.assert_allclose(b, np.asarray(d_0.b), rtol=1e-3, atol=1e-2)
