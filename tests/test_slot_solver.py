"""Fused slot-solver kernels vs the jnp backend: parity + dispatch shape.

Pallas runs in interpret mode on CPU (the ops layer auto-selects it
off-TPU), so everything here exercises the exact kernel code paths that
compile on device.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import core as jax_core

from repro.core import allocate, aopi, bcd, lbcd, profiles
from repro.kernels import slot_solver
from repro.kernels.slot_solver import ops as slot_ops


def _setup(n, s, seed=0, lcfsp_frac=0.5, budget_lo=2e7, budget_hi=5e7,
           server_id=None):
    rng = np.random.default_rng(seed)
    k = rng.uniform(1e-6, 5e-6, n)
    p = rng.uniform(0.3, 0.95, n)
    pol = (rng.random(n) < lcfsp_frac).astype(np.int32)
    mu = rng.uniform(5.0, 40.0, n)
    if server_id is None:
        server_id = rng.integers(0, s, n).astype(np.int32)
    budgets = rng.uniform(budget_lo, budget_hi, s)
    return (jnp.asarray(k, jnp.float32), jnp.asarray(p, jnp.float32),
            jnp.asarray(pol), jnp.asarray(mu, jnp.float32),
            jnp.asarray(server_id), jnp.asarray(budgets, jnp.float32))


# ---------------------------------------------------------------------------
# ServerLayout
# ---------------------------------------------------------------------------

def test_server_layout_roundtrip_and_padding():
    sid = jnp.asarray([2, 0, 2, 1, 0, 2, 0], jnp.int32)
    layout = slot_solver.server_layout(sid, 3)
    n = sid.shape[0]
    assert layout.capacity % 128 == 0 and layout.capacity >= n
    np.testing.assert_array_equal(np.asarray(layout.counts), [3, 1, 3])
    order = np.asarray(layout.order)
    mask = np.asarray(layout.mask)
    # Every camera appears exactly once, on its own server's row, in
    # ascending original order (stable sort); padding slots carry the
    # sentinel and zero mask.
    real = order[mask > 0]
    assert sorted(real.tolist()) == list(range(n))
    for s in range(3):
        row = order[s][mask[s] > 0]
        assert all(np.asarray(sid)[i] == s for i in row)
        assert list(row) == sorted(row)
    assert (order[mask == 0] == n).all()
    # gather -> scatter is the identity on per-camera vectors.
    x = jnp.arange(1.0, n + 1.0)
    np.testing.assert_allclose(
        np.asarray(layout.scatter(layout.gather(x), n)), np.asarray(x))


def test_server_layout_capacity_floor_and_overflow():
    # Sub-lane capacities round up to the 128-lane floor: nothing drops.
    sid = jnp.zeros((5,), jnp.int32)
    layout = slot_solver.server_layout(sid, 1, capacity=2)
    assert layout.capacity == 128
    assert int(layout.mask.sum()) == 5
    # A server loaded past the rounded capacity drops the overflow from
    # its row view; the flat view still carries every camera.
    sid = jnp.zeros((130,), jnp.int32)
    layout = slot_solver.server_layout(sid, 1, capacity=100)
    assert layout.capacity == 128
    assert int(layout.mask.sum()) == 128          # 2 dropped from the row
    assert int(layout.counts[0]) == 130
    assert int(layout.flat_mask.sum()) == 130     # flat view is complete
    x = jnp.arange(130.0)
    np.testing.assert_allclose(
        np.asarray(layout.scatter_flat(layout.gather_flat(x), 130)),
        np.asarray(x))


def test_server_layout_empty_server():
    sid = jnp.asarray([0, 0, 2, 2], jnp.int32)
    layout = slot_solver.server_layout(sid, 3)
    assert int(layout.counts[1]) == 0
    assert float(layout.mask[1].sum()) == 0.0


# ---------------------------------------------------------------------------
# Water-filling kernel vs jnp _waterfill
# ---------------------------------------------------------------------------

def _assert_bandwidth_parity(n, s, seed, lcfsp_frac, budget_lo=2e7,
                             budget_hi=5e7, server_id=None):
    k, p, pol, mu, sid, B = _setup(n, s, seed=seed, lcfsp_frac=lcfsp_frac,
                                   budget_lo=budget_lo, budget_hi=budget_hi,
                                   server_id=server_id)
    b_ref = np.asarray(allocate.waterfill_bandwidth(
        k, p, pol, mu, sid, B, n_servers=s))
    b_pl = np.asarray(slot_solver.waterfill_bandwidth(
        k, p, pol, mu, sid, B, n_servers=s))
    np.testing.assert_allclose(b_pl, b_ref, rtol=2e-4, atol=1e-2)
    return b_pl, np.asarray(sid), np.asarray(B)


def test_waterfill_bandwidth_parity_hypothesis():
    """Random FCFS/LCFSP mixes: pallas-interpret == jnp ``_waterfill``."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=15, deadline=None)
    @given(st.integers(0, 10_000), st.sampled_from([0.0, 0.3, 0.5, 1.0]))
    def inner(seed, frac):
        _assert_bandwidth_parity(10, 2, seed, frac)
    inner()


def test_waterfill_compute_parity_hypothesis():
    """Compute side (FCFS stability floors active) parity."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=15, deadline=None)
    @given(st.integers(0, 10_000), st.sampled_from([0.0, 0.5, 1.0]))
    def inner(seed, frac):
        rng = np.random.default_rng(seed)
        n, s = 10, 2
        inv_xi = jnp.asarray(rng.uniform(1e-12, 5e-12, n), jnp.float32)
        p = jnp.asarray(rng.uniform(0.3, 0.95, n), jnp.float32)
        pol = jnp.asarray((rng.random(n) < frac).astype(np.int32))
        lam = jnp.asarray(rng.uniform(1.0, 10.0, n), jnp.float32)
        sid = jnp.asarray(rng.integers(0, s, n).astype(np.int32))
        C = jnp.asarray(rng.uniform(3e13, 8e13, s), jnp.float32)
        c_ref = np.asarray(allocate.waterfill_compute(
            inv_xi, p, pol, lam, sid, C, n_servers=s))
        c_pl = np.asarray(slot_solver.waterfill_compute(
            inv_xi, p, pol, lam, sid, C, n_servers=s))
        np.testing.assert_allclose(c_pl, c_ref, rtol=2e-4, atol=1e4)
    inner()


def test_waterfill_slack_budget_keeps_caps():
    """When the FCFS caps sum below the budget the constraint is slack:
    both backends return the caps and stay (well) under budget."""
    # All-FCFS + huge budgets -> hi = lam*/(k*B) << 1 per camera.
    b, sid, B = _assert_bandwidth_parity(8, 2, seed=11, lcfsp_frac=0.0,
                                         budget_lo=5e9, budget_hi=9e9)
    for s in range(2):
        m = sid == s
        assert b[m].sum() < 0.9 * B[s]


def test_waterfill_degenerate_single_camera_servers():
    """One camera per server: the dual search degenerates to the
    per-camera cap; backends must still agree."""
    n = 6
    _assert_bandwidth_parity(n, n, seed=3, lcfsp_frac=0.5,
                             server_id=np.arange(n, dtype=np.int32))


def test_waterfill_budget_respected_and_positive():
    b, sid, B = _assert_bandwidth_parity(12, 3, seed=7, lcfsp_frac=0.5)
    assert (b > 0).all() and np.isfinite(b).all()
    for s in range(3):
        assert b[sid == s].sum() <= float(B[s]) * 1.001


# ---------------------------------------------------------------------------
# Streaming config argmin vs materialized reference
# ---------------------------------------------------------------------------

def _config_inputs(n, seed=0, m=5, r=6):
    rng = np.random.default_rng(seed)
    acc = jnp.asarray(rng.uniform(0.2, 0.95, (n, m, r)), jnp.float32)
    xi = jnp.asarray(np.sort(rng.uniform(1e9, 2e11, (m, r)), axis=1),
                     jnp.float32)
    size = jnp.asarray(1.2 * np.asarray(profiles.RESOLUTIONS)[:r] ** 2,
                       jnp.float32)
    eff = jnp.asarray(rng.uniform(4.0, 7.0, n), jnp.float32)
    b = jnp.asarray(rng.uniform(1e6, 1e7, n), jnp.float32)
    c = jnp.asarray(rng.uniform(1e12, 1e13, n), jnp.float32)
    return b, c, acc, xi, size, eff


@pytest.mark.parametrize("n,block_n", [(7, 1024), (40, 16), (64, 64)])
def test_config_argmin_matches_ref(n, block_n):
    """Streaming kernel == flat argmin (incl. non-divisible tiling)."""
    for seed in range(3):
        b, c, acc, xi, size, eff = _config_inputs(n, seed=seed)
        ref = slot_solver.config_argmin_ref(b, c, acc, xi, size, eff,
                                            1.3, 10.0, n)
        out = slot_solver.config_argmin(b, c, acc, xi, size, eff,
                                        1.3, 10.0, n, backend="pallas",
                                        block_n=block_n)
        for name, a, o in zip(("r", "m", "pol"), ref, out):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(o),
                                          err_msg=f"{name} seed={seed}")


# ---------------------------------------------------------------------------
# Full Algorithm-1 solve + rollout backend parity
# ---------------------------------------------------------------------------

def _slot_instance(seed, n=12, s=3):
    rng = np.random.default_rng(seed)
    sys = profiles.EdgeSystem(n_cameras=n, n_servers=s, n_slots=4,
                              seed=seed)
    tab = sys.horizon(1)
    sid = jnp.asarray(rng.integers(0, s, n).astype(np.int32))
    return (tab.acc[0], tab.xi, tab.size, tab.eff, sid, tab.budgets_b[0],
            tab.budgets_c[0], jnp.float32(rng.uniform(0.0, 3.0)),
            jnp.float32(rng.uniform(1.0, 30.0)))


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_solve_slot_pallas_matches_jnp(seed):
    args = _slot_instance(seed)
    d_jnp = bcd.solve_slot(*args, n_servers=3)
    d_pl = bcd.solve_slot(*args, n_servers=3, solver_backend="pallas")
    for f in ("r_idx", "m_idx", "pol"):
        np.testing.assert_array_equal(np.asarray(getattr(d_jnp, f)),
                                      np.asarray(getattr(d_pl, f)),
                                      err_msg=f"{f} seed={seed}")
    for f in ("b", "c", "lam", "mu", "acc", "aopi"):
        np.testing.assert_allclose(np.asarray(getattr(d_pl, f)),
                                   np.asarray(getattr(d_jnp, f)),
                                   rtol=5e-4, err_msg=f"{f} seed={seed}")
    assert float(d_pl.score) == pytest.approx(float(d_jnp.score), rel=1e-4)


def test_solve_slot_pallas_rejects_interior_point():
    args = _slot_instance(5)
    with pytest.raises(ValueError, match="interior"):
        bcd.solve_slot(*args, n_servers=3, method="interior",
                       solver_backend="pallas")
    with pytest.raises(ValueError, match="solver_backend"):
        bcd.solve_slot(*args, n_servers=3, solver_backend="cuda")


def test_rollout_backend_parity():
    """Whole-horizon scan (first-fit assignments traced through the
    layout build) agrees across backends.

    Contract: per-slot parity is float32-tight *given the assignment*,
    but the backends' different fp summation order can flip a knife-edge
    first-fit tie into a different (equally valid) placement on rare
    slots — same amplification the shard_map caveat documents. So slots
    with identical assignments must match tightly, tie-flip slots must be
    rare, and the fleet aggregate must agree closely either way."""
    sys = profiles.EdgeSystem(n_cameras=10, n_servers=3, n_slots=8,
                              mean_bandwidth_hz=15e6,
                              mean_compute_flops=20e12)
    tab = sys.horizon(8)
    r_jnp = lbcd.rollout(tab, 10.0, 0.7)
    r_pl = lbcd.rollout(tab, 10.0, 0.7, solver_backend="pallas")
    same = np.all(np.asarray(r_jnp.assign) == np.asarray(r_pl.assign),
                  axis=1)
    assert same.mean() >= 0.75, f"tie flips on {(~same).sum()}/8 slots"
    np.testing.assert_allclose(np.asarray(r_pl.aopi)[same],
                               np.asarray(r_jnp.aopi)[same], rtol=1e-3)
    np.testing.assert_allclose(np.asarray(r_pl.aopi).mean(axis=1),
                               np.asarray(r_jnp.aopi).mean(axis=1),
                               rtol=5e-3)
    np.testing.assert_allclose(np.asarray(r_pl.q), np.asarray(r_jnp.q),
                               rtol=1e-3, atol=1e-4)


def test_sweep_threads_solver_backend():
    """``scenarios.sweep(..., solver_backend="pallas")`` reproduces the jnp
    sweep. Strict parity is pinned on one device (vmap — no
    ``num_partitions > 1`` rewrite involved); with more devices visible
    (the CI kernel step's 4 virtual ones) the shard_map path must also run
    and agree statistically, per the documented first-fit tie caveat."""
    from repro import scenarios
    from repro.core import profiles as prof

    stacked = prof.stack_horizons(
        [prof.EdgeSystem(n_cameras=6, n_servers=2, n_slots=3,
                         seed=i).horizon(3) for i in range(2)])
    one = jax.devices()[:1]
    r_jnp = scenarios.sweep(stacked, policies=("lbcd", "min"), devices=one)
    r_pl = scenarios.sweep(stacked, policies=("lbcd", "min"), devices=one,
                           solver_backend="pallas")
    for pol in ("lbcd", "min"):
        np.testing.assert_allclose(r_pl.aopi[pol], r_jnp.aopi[pol],
                                   rtol=1e-3, err_msg=pol)
        np.testing.assert_allclose(r_pl.acc[pol], r_jnp.acc[pol],
                                   rtol=1e-3, err_msg=pol)
    if len(jax.devices()) > 1:
        r_sh = scenarios.sweep(stacked, policies=("lbcd",),
                               backend="shard_map",
                               solver_backend="pallas")
        assert r_sh.backend.startswith("shard_map")
        np.testing.assert_allclose(r_sh.aopi["lbcd"].mean(),
                                   r_jnp.aopi["lbcd"].mean(), rtol=0.05)


# ---------------------------------------------------------------------------
# Dispatch structure: one fused call per water-fill, no [N, M, R, 2] HBM
# tensor on the pallas path.
# ---------------------------------------------------------------------------

def _walk_eqns(jaxpr):
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            for sub in _subjaxprs(v):
                yield from _walk_eqns(sub)


def _subjaxprs(v):
    if isinstance(v, jax_core.ClosedJaxpr):
        return [v.jaxpr]
    if isinstance(v, jax_core.Jaxpr):
        return [v]
    if isinstance(v, (list, tuple)):
        return [j for x in v for j in _subjaxprs(x)]
    return []


def _prim_counts(jaxpr):
    counts = {}
    for eqn in _walk_eqns(jaxpr):
        counts[eqn.primitive.name] = counts.get(eqn.primitive.name, 0) + 1
    return counts


def _has_aval_shape(jaxpr, shape):
    return any(tuple(getattr(var.aval, "shape", ())) == tuple(shape)
               for eqn in _walk_eqns(jaxpr) for var in eqn.outvars)


def test_waterfill_pallas_is_single_dispatch():
    """The fused allocator is ONE pallas_call; the jnp allocator's outer
    loop re-dispatches segment_sum (scatter-add) every iteration."""
    k, p, pol, mu, sid, B = _setup(12, 3)
    fused = jax.make_jaxpr(functools.partial(
        slot_solver.waterfill_bandwidth, n_servers=3))(k, p, pol, mu, sid, B)
    counts = _prim_counts(fused.jaxpr)
    assert counts.get("pallas_call", 0) == 1
    # The whole dual search runs inside that one call: the only scatter-add
    # is the one-time per-server camera count of the layout build, and the
    # only scatters are the layout's gather table + the single allocation
    # write-back — nothing per outer iteration.
    assert counts.get("scatter-add", 0) <= 1
    assert counts.get("scatter", 0) <= 2

    ref = jax.make_jaxpr(functools.partial(
        allocate.waterfill_bandwidth, n_servers=3))(k, p, pol, mu, sid, B)
    ref_counts = _prim_counts(ref.jaxpr)
    assert ref_counts.get("pallas_call", 0) == 0
    assert ref_counts.get("scatter-add", 0) >= 3   # fill residual per phase


def test_config_argmin_pallas_never_materializes_score_tensor():
    n, m, r = 24, 5, 6
    b, c, acc, xi, size, eff = _config_inputs(n, m=m, r=r)
    args = (b, c, acc, xi, size, eff, 1.0, 10.0)

    ref = jax.make_jaxpr(
        lambda *a: slot_solver.config_argmin(*a, n_total=n,
                                             backend="jnp"))(*args)
    assert _has_aval_shape(ref.jaxpr, (n, m, r, 2))

    fused = jax.make_jaxpr(
        lambda *a: slot_solver.config_argmin(*a, n_total=n,
                                             backend="pallas",
                                             block_n=8))(*args)
    assert not _has_aval_shape(fused.jaxpr, (n, m, r, 2))
    assert _prim_counts(fused.jaxpr).get("pallas_call", 0) == 1


def test_solve_slot_pallas_dispatch_structure():
    """Whole Algorithm-1 solve: every BCD pass is 3 fused dispatches
    (config + 2 water-fills) and the big score tensor never hits HBM."""
    args = _slot_instance(0)
    n, n_m, n_r = args[0].shape
    fused = jax.make_jaxpr(functools.partial(
        bcd.solve_slot, n_servers=3, solver_backend="pallas"))(*args)
    counts = _prim_counts(fused.jaxpr)
    # 1 config + 2 water-fills in the BCD body + 2 polish water-fills.
    assert counts.get("pallas_call", 0) == 5
    assert not _has_aval_shape(fused.jaxpr, (n, n_m, n_r, 2))

    ref = jax.make_jaxpr(functools.partial(
        bcd.solve_slot, n_servers=3))(*args)
    assert _has_aval_shape(ref.jaxpr, (n, n_m, n_r, 2))
    assert _prim_counts(ref.jaxpr).get("pallas_call", 0) == 0


# ---------------------------------------------------------------------------
# solver_backend="auto": fleet-size dispatch (BENCH_slot_solver.json shows
# N=30 jnp-favoured under 128-lane padding, N>=300 pallas-favoured).
# ---------------------------------------------------------------------------

def test_resolve_backend_switch_point():
    thr = bcd.AUTO_PALLAS_MIN_CAMERAS
    assert bcd.resolve_backend("auto", thr - 1) == "jnp"
    assert bcd.resolve_backend("auto", thr) == "pallas"
    assert bcd.resolve_backend("auto", 30) == "jnp"        # benched regime
    assert bcd.resolve_backend("auto", 3000) == "pallas"   # benched regime
    # interior-point is jnp-only: auto never hands it to pallas.
    assert bcd.resolve_backend("auto", 10 * thr, method="interior") == "jnp"
    # Explicit backends pass through regardless of fleet size.
    assert bcd.resolve_backend("jnp", 10 * thr) == "jnp"
    assert bcd.resolve_backend("pallas", 2) == "pallas"
    with pytest.raises(ValueError, match="unknown solver_backend"):
        bcd.resolve_backend("nope", 10)


def test_auto_backend_dispatch_choice_pinned():
    """Below the threshold an auto solve traces the pure-jnp program (no
    pallas_call); at the threshold it traces the fused kernels."""
    small = _slot_instance(0, n=bcd.AUTO_PALLAS_MIN_CAMERAS - 108)  # n=20
    jx = jax.make_jaxpr(functools.partial(
        bcd.solve_slot, n_servers=3, solver_backend="auto"))(*small)
    assert _prim_counts(jx.jaxpr).get("pallas_call", 0) == 0

    big = _slot_instance(0, n=bcd.AUTO_PALLAS_MIN_CAMERAS)
    jx = jax.make_jaxpr(functools.partial(
        bcd.solve_slot, n_servers=3, solver_backend="auto"))(*big)
    assert _prim_counts(jx.jaxpr).get("pallas_call", 0) == 5
