"""Per-arch smoke tests (deliverable f) + model-level invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import build
from repro.models.common import count_params, init_params
from repro.models.layers import apply_rope
from repro.training import optimizer as opt_mod
from repro.training.train_step import make_train_step

KEY = jax.random.PRNGKey(0)
ARCHS = sorted(configs.ARCHS)


def _batch(cfg, b=2, s=32, seed=7):
    toks = jax.random.randint(jax.random.PRNGKey(seed), (b, s + 1), 0,
                              cfg.vocab)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.random.normal(
            jax.random.PRNGKey(seed + 1),
            (b, cfg.n_vision_tokens, cfg.d_model)) * 0.1
    if cfg.family == "audio":
        batch["audio_embeds"] = jax.random.normal(
            jax.random.PRNGKey(seed + 2), (b, s, cfg.d_model)) * 0.1
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    """Reduced config: one forward + one train step, shapes + no NaNs."""
    cfg = configs.get(arch).reduced()
    model = build(cfg)
    params = init_params(model.template(), KEY)
    b, s = 2, 32
    batch = _batch(cfg, b, s)
    logits, aux = model.forward(params, batch)
    assert logits.shape == (b, s, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())
    step = make_train_step(model, opt_mod.AdamWConfig(lr=1e-3))
    opt_state = opt_mod.init(params, opt_mod.AdamWConfig())
    params2, opt2, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    diff = max(float(jnp.max(jnp.abs(a - b_))) for a, b_ in
               zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert diff > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    """prefill(s) + decode steps == full forward (teacher forcing)."""
    cfg = configs.get(arch).reduced()
    if cfg.is_moe:
        cfg = dataclasses.replace(cfg,
                                  capacity_factor=cfg.n_experts / cfg.top_k)
    model = build(cfg)
    params = init_params(model.template(), KEY)
    b, s = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(3), (b, s + 2), 0,
                              cfg.vocab)
    full = dict(_batch(cfg, b, s + 2), tokens=toks)
    full.pop("labels")
    pre = dict(full, tokens=toks[:, :s])
    if "audio_embeds" in full:
        pre["audio_embeds"] = full["audio_embeds"] = \
            full["audio_embeds"][:, :s]
    logits_full, _ = model.forward(params, full)
    cache = init_params(model.cache_template(b, s + 2), KEY)
    lg, cache = model.prefill(params, pre, cache)
    assert float(jnp.max(jnp.abs(lg[:, 0] - logits_full[:, s - 1]))) < 2e-3
    lg1, cache = model.decode_step(params, toks[:, s], cache)
    assert float(jnp.max(jnp.abs(lg1 - logits_full[:, s]))) < 2e-3
    lg2, cache = model.decode_step(params, toks[:, s + 1], cache)
    assert float(jnp.max(jnp.abs(lg2 - logits_full[:, s + 1]))) < 2e-3


def test_param_counts_match_public_scale():
    """Full configs land near their public parameter counts."""
    expect = {
        "yi-6b": (6.0e9, 0.2),
        "yi-34b": (34.4e9, 0.15),
        "qwen2.5-3b": (3.1e9, 0.25),
        "minicpm3-4b": (4.0e9, 0.4),
        "llama-3.2-vision-11b": (10.6e9, 0.25),
        "dbrx-132b": (132e9, 0.15),
        "qwen2-moe-a2.7b": (14.3e9, 0.3),
        "jamba-1.5-large-398b": (398e9, 0.15),
        # Spec dims (48L d2048 4H) with the official block layout land at
        # ~2B; the public "1.3b" name reflects a different depth/ff mix.
        "xlstm-1.3b": (2.0e9, 0.3),
        "seamless-m4t-large-v2": (2.3e9, 0.5),
    }
    for arch, (target, tol) in expect.items():
        cfg = configs.get(arch)
        model = build(cfg, ep_degree=16)
        n = model.param_count()
        assert abs(n - target) / target < tol, (arch, n, target)


def test_rope_relative_property():
    """Rotary: scores depend only on relative distance."""
    d = 64
    k1 = jax.random.normal(KEY, (1, 1, 1, d))
    q1 = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, d))
    def score(pq, pk):
        qq = apply_rope(q1, jnp.array([[pq]]))
        kk = apply_rope(k1, jnp.array([[pk]]))
        return float(jnp.sum(qq * kk))
    assert score(5, 3) == pytest.approx(score(105, 103), rel=1e-4)
    assert score(5, 3) != pytest.approx(score(5, 4), rel=1e-3)


def test_moe_capacity_drops_and_dropless():
    from repro.models import moe
    cfg = configs.get("dbrx-132b").reduced()
    model = build(cfg)
    params = init_params(model.template(), KEY)["blocks"]
    p0 = jax.tree.map(lambda x: x[0], params)["p0"]["ffn"]
    x = jax.random.normal(KEY, (2, 16, cfg.d_model))
    y_drop, _ = moe.moe_apply(p0, x, cfg, capacity_factor=0.25)
    y_free, _ = moe.moe_apply(p0, x, cfg,
                              capacity_factor=cfg.n_experts / cfg.top_k)
    # Heavy capacity pressure must change outputs (tokens dropped).
    assert float(jnp.max(jnp.abs(y_drop - y_free))) > 1e-4
    assert bool(jnp.isfinite(y_drop).all())


def test_vocab_padding_masked_in_loss():
    cfg = configs.get("seamless-m4t-large-v2").reduced()
    assert cfg.padded_vocab % 256 == 0 and cfg.padded_vocab >= cfg.vocab
    from repro.models.layers import softmax_xent
    logits = jnp.zeros((2, 4, cfg.padded_vocab))
    # Put huge mass on padded ids: loss must ignore them.
    logits = logits.at[..., cfg.vocab:].set(100.0)
    labels = jnp.zeros((2, 4), jnp.int32)
    loss = softmax_xent(logits, labels, cfg.vocab)
    assert float(loss) < 20.0


def test_long_context_applicability():
    from repro.configs.base import LONG_500K, shape_supported
    runs = {a for a in ARCHS
            if shape_supported(configs.get(a), LONG_500K)[0]}
    assert runs == {"xlstm-1.3b", "jamba-1.5-large-398b"}


def test_moe_group_limited_routing():
    """Group-limited routing (EXPERIMENTS §Perf MoE-4): long sequences are
    routed in 2048-token groups; outputs stay finite and shaped, and short
    sequences are bit-identical to the ungrouped path."""
    from repro.models import moe
    cfg = configs.get("dbrx-132b").reduced()
    model = build(cfg)
    params = jax.tree.map(lambda x: x[0],
                          init_params(model.template(), KEY)["blocks"])
    p0 = params["p0"]["ffn"]
    # long sequence -> grouped
    x_long = jax.random.normal(KEY, (1, 4096, cfg.d_model)) * 0.3
    y, aux = moe.moe_apply(p0, x_long, cfg)
    assert y.shape == x_long.shape
    assert bool(jnp.isfinite(y).all()) and np.isfinite(float(aux))
    # first group's tokens match a standalone 2048-token call (prefix
    # property of group-limited routing)
    y_head, _ = moe.moe_apply(p0, x_long[:, :moe.MOE_GROUP], cfg)
    np.testing.assert_allclose(np.asarray(y[:, :moe.MOE_GROUP]),
                               np.asarray(y_head), atol=1e-5)


def test_pad_heads_preserves_shapes_and_runs():
    """pad_heads_to (EXPERIMENTS §Perf A1): padded-head model still
    produces [b, s, vocab] logits and trains."""
    cfg = dataclasses.replace(configs.get("yi-6b").reduced(),
                              n_heads=6, n_kv_heads=2, pad_heads_to=8)
    model = build(cfg)
    params = init_params(model.template(), KEY)
    assert params["blocks"]["p0"]["mixer"]["wq"].shape[2] == 8
    batch = _batch(cfg, 2, 16)
    logits, _ = model.forward(params, batch)
    assert logits.shape == (2, 16, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())
