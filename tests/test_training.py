"""Optimizer, gradient accumulation, compression, end-to-end loss curve."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import build
from repro.models.common import init_params
from repro.training import compression, optimizer as opt_mod
from repro.training.train_step import make_train_step, split_microbatches

KEY = jax.random.PRNGKey(0)


def test_adamw_minimizes_quadratic():
    cfg = opt_mod.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                              schedule="constant", grad_clip=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt_mod.init(params, cfg)
    for _ in range(300):
        g = {"w": 2.0 * params["w"]}
        params, state, _ = opt_mod.update(params, g, state, cfg)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_grad_clip_bounds_update():
    cfg = opt_mod.AdamWConfig(lr=1.0, grad_clip=1.0, warmup_steps=0,
                              schedule="constant", weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    state = opt_mod.init(params, cfg)
    g = {"w": jnp.full(4, 1e6)}
    _, state2, metrics = opt_mod.update(params, g, state, cfg)
    assert float(metrics["grad_norm"]) > 1e5
    # m after one step is (1-b1)*clipped_g; clipped norm == 1.
    m_norm = float(jnp.linalg.norm(state2["m"]["w"])) / (1 - cfg.b1)
    assert m_norm == pytest.approx(1.0, rel=1e-3)


def test_bf16_state_dtype():
    cfg = opt_mod.AdamWConfig(state_dtype="bfloat16")
    params = {"w": jnp.zeros((8, 8))}
    state = opt_mod.init(params, cfg)
    assert state["m"]["w"].dtype == jnp.bfloat16


def test_microbatch_accumulation_matches_full_batch():
    cfg = configs.get("qwen2.5-3b").reduced()
    model = build(cfg)
    params = init_params(model.template(), KEY)
    ocfg = opt_mod.AdamWConfig(lr=1e-3)
    opt_state = opt_mod.init(params, ocfg)
    toks = jax.random.randint(KEY, (4, 33), 0, cfg.vocab)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    p1, _, m1 = make_train_step(model, ocfg, 1)(params, opt_state, batch)
    p4, _, m4 = make_train_step(model, ocfg, 4)(params, opt_state, batch)
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=1e-4)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_split_microbatches_shapes():
    batch = {"tokens": jnp.zeros((8, 16))}
    out = split_microbatches(batch, 4)
    assert out["tokens"].shape == (4, 2, 16)


def test_quantization_error_bound():
    """Blockwise int8: |x - dq(q(x))| <= scale/2 = max|block|/254."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 1000), st.sampled_from([64, 256]))
    def inner(seed, block):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(0, rng.uniform(0.1, 10), size=300),
                        jnp.float32)
        y = compression.roundtrip(x, block=block)
        blocks = np.asarray(x)
        err = np.abs(np.asarray(y) - blocks)
        # per-element bound: half an int8 step of its block scale
        pad = (-len(blocks)) % block
        bl = np.pad(blocks, (0, pad)).reshape(-1, block)
        scale = np.abs(bl).max(1, keepdims=True) / 127.0
        bound = np.repeat(scale / 2 + 1e-7, block, 1).reshape(-1)[:len(blocks)]
        assert (err <= bound + 1e-6).all()
    inner()


def test_compressed_psum_matches_mean():
    """shard_map compressed all-reduce ~= exact mean within int8 error."""
    import os
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    n = len(jax.devices())
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((n,), ("d",))
    x = jax.random.normal(KEY, (n, 64))

    f = shard_map(lambda v: compression.compressed_psum(v[0], "d")[None],
                  mesh=mesh, in_specs=P("d"), out_specs=P("d"))
    out = np.asarray(f(x))
    expect = np.asarray(jnp.mean(x, 0))
    scale = np.abs(np.asarray(x)).max() / 127.0
    np.testing.assert_allclose(out[0], expect, atol=scale)


def test_loss_decreases_end_to_end():
    """A ~100k-param model trains: loss drops over 30 steps."""
    from repro.launch.train import run
    cfg = configs.get("qwen2.5-3b").reduced()
    out = run(cfg, steps=30, batch=4, seq=64, log_every=0)
    first = np.mean(out["losses"][:5])
    last = np.mean(out["losses"][-5:])
    assert last < first - 0.1, (first, last)
