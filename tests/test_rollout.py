"""Scan rollout engine: first-fit parity, legacy reproduction, vmap grids."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines, binpack, lbcd, profiles


def _system(**kw):
    kw.setdefault("n_cameras", 12)
    kw.setdefault("n_servers", 3)
    kw.setdefault("n_slots", 40)
    kw.setdefault("mean_bandwidth_hz", 15e6)
    kw.setdefault("mean_compute_flops", 20e12)
    return profiles.EdgeSystem(**kw)


# ---------------------------------------------------------------------------
# first_fit_jax == first_fit
# ---------------------------------------------------------------------------

def test_first_fit_jax_matches_numpy_random_instances():
    """Property: the jit-safe first-fit reproduces the numpy assignment on
    random instances (feasible and overflowing)."""
    for seed in range(40):
        rng = np.random.default_rng(seed)
        n = int(rng.integers(3, 25))
        s = int(rng.integers(2, 5))
        b_hat = rng.uniform(0.1, 2.0, n)
        c_hat = rng.uniform(0.1, 2.0, n)
        # Mix of roomy and tight instances (tight ones hit the overflow
        # branch, lines 6-8 of Algorithm 2).
        scale = rng.uniform(0.3, 1.5)
        B = rng.uniform(0.5, 1.0, s) * b_hat.sum() * scale
        C = rng.uniform(0.5, 1.0, s) * c_hat.sum() * scale
        ref = binpack.first_fit(b_hat, c_hat, B, C)
        jit = np.asarray(binpack.first_fit_jax(
            jnp.asarray(b_hat), jnp.asarray(c_hat), jnp.asarray(B),
            jnp.asarray(C)))
        np.testing.assert_array_equal(ref, jit, err_msg=f"seed={seed}")


def test_first_fit_jax_under_jit_and_float32():
    rng = np.random.default_rng(7)
    b_hat = rng.uniform(0.5, 2.0, 16).astype(np.float32)
    c_hat = rng.uniform(0.5, 2.0, 16).astype(np.float32)
    B = np.full(2, 12.0, np.float32)
    C = np.full(2, 12.0, np.float32)
    a = np.asarray(jax.jit(binpack.first_fit_jax)(b_hat, c_hat, B, C))
    for s in range(2):
        m = a == s
        assert b_hat[m].sum() <= B[s] + 1e-5
        assert c_hat[m].sum() <= C[s] + 1e-5


# ---------------------------------------------------------------------------
# rollout() reproduces LBCDController.run()
# ---------------------------------------------------------------------------

def test_rollout_reproduces_legacy_run():
    """The scan engine must reproduce the per-slot python loop's records
    (AoPI / accuracy / q series) to float tolerance."""
    slots = 25
    legacy = lbcd.LBCDController(_system(), v=10.0, p_min=0.7)
    s_legacy = legacy.run(slots, engine="legacy")

    scan = lbcd.LBCDController(_system(), v=10.0, p_min=0.7)
    s_scan = scan.run(slots)                      # engine="scan"

    np.testing.assert_allclose(s_scan.acc_series, s_legacy.acc_series,
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(s_scan.aopi_series, s_legacy.aopi_series,
                               rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(s_scan.q_series, s_legacy.q_series,
                               rtol=1e-4, atol=1e-4)
    # Same server placements, slot by slot.
    for a, b in zip(s_legacy.records, s_scan.records):
        np.testing.assert_array_equal(a.assign, b.assign)
    # The stateful wrapper carries the queue across run() calls identically.
    assert scan.queue.q == pytest.approx(legacy.queue.q, abs=1e-4)


def test_rollout_result_summary_consistency():
    tables = _system().horizon(10)
    res = lbcd.rollout(tables, 10.0, 0.7)
    summary = lbcd.summarize(res, 10.0, 0.7)
    assert len(summary.records) == 10
    assert summary.mean_aopi == pytest.approx(res.mean_aopi, rel=1e-6)
    # Records expose full decisions (serving/energy consumers rely on it).
    dec = summary.records[0].decision
    assert dec.b.shape == (tables.n_cameras,)


def test_baseline_rollouts_match_legacy_steps():
    for name in ("MIN", "DOS", "JCAB"):
        legacy = baselines.make(name, _system(seed=2)).run(
            12, engine="legacy")
        scan = baselines.make(name, _system(seed=2)).run(12)
        np.testing.assert_allclose(scan.aopi_series, legacy.aopi_series,
                                   rtol=2e-4, atol=1e-6, err_msg=name)
        np.testing.assert_allclose(scan.acc_series, legacy.acc_series,
                                   rtol=2e-4, atol=1e-6, err_msg=name)


# ---------------------------------------------------------------------------
# vmap
# ---------------------------------------------------------------------------

def test_rollout_grid_matches_individual_rollouts():
    """One vmapped grid call == per-point rollouts."""
    tables = _system().horizon(8)
    vs = jnp.asarray([1.0, 10.0, 100.0])
    p_mins = jnp.asarray([0.5, 0.7, 0.9])
    grid = lbcd.rollout_grid(tables, vs, p_mins)
    assert grid.aopi.shape == (3, 8, tables.n_cameras)
    for g in range(3):
        single = lbcd.rollout(tables, float(vs[g]), float(p_mins[g]))
        np.testing.assert_allclose(np.asarray(grid.q[g]),
                                   np.asarray(single.q), rtol=1e-5,
                                   atol=1e-6)
        np.testing.assert_allclose(np.asarray(grid.aopi[g]),
                                   np.asarray(single.aopi), rtol=1e-4,
                                   atol=1e-6)


def test_rollout_scenarios_over_stacked_horizons():
    stacked = profiles.stack_horizons(
        [_system(seed=i).horizon(6) for i in range(3)])
    res = lbcd.rollout_scenarios(stacked, 10.0, 0.7)
    assert res.acc.shape[0] == 3
    # Scenarios differ (different seeds) but each meets basic sanity.
    assert np.isfinite(np.asarray(res.aopi)).all()
    assert (np.asarray(res.acc) > 0).all()


def test_time_varying_eff_matches_static_when_constant():
    """A broadcast eff[T, N] must reproduce the static eff[N] rollout
    exactly, for the LBCD engine and every baseline scan."""
    import dataclasses

    from repro.core import baselines as bl

    tables = _system().horizon(6)
    tv = dataclasses.replace(
        tables, eff=jnp.broadcast_to(tables.eff[None, :],
                                     (6, tables.n_cameras)))
    for name, fn in [("lbcd", lambda t: lbcd.rollout(t, 10.0, 0.7)),
                     ("min", bl.rollout_min),
                     ("dos", bl.rollout_dos),
                     ("jcab", bl.rollout_jcab)]:
        a, b = fn(tables), fn(tv)
        np.testing.assert_array_equal(np.asarray(a.aopi),
                                      np.asarray(b.aopi), err_msg=name)
        np.testing.assert_array_equal(np.asarray(a.assign),
                                      np.asarray(b.assign), err_msg=name)


def test_horizon_tables_match_legacy_tables():
    """horizon() pregenerates exactly what sequential tables(t) would."""
    sys_a = _system(seed=5)
    sys_b = _system(seed=5)
    hor = sys_a.horizon(4)
    for t in range(4):
        legacy = sys_b.tables(t)
        np.testing.assert_allclose(np.asarray(hor.acc[t]), legacy.acc,
                                   rtol=1e-6, atol=1e-7)
        np.testing.assert_allclose(np.asarray(hor.eff), legacy.eff,
                                   rtol=1e-6)
        bb, bc = sys_b.capacities(t)
        np.testing.assert_allclose(np.asarray(hor.budgets_b[t]), bb,
                                   rtol=1e-6)
        np.testing.assert_allclose(np.asarray(hor.budgets_c[t]), bc,
                                   rtol=1e-6)
