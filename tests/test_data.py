"""Data pipeline: determinism, host sharding, modality stubs."""
import numpy as np

from repro.data import PipelineConfig, TokenPipeline, batch_for
from repro import configs
from repro.configs.base import SHAPES


def test_deterministic_per_step():
    p1 = TokenPipeline(PipelineConfig(1000, 64, 8, seed=3))
    p2 = TokenPipeline(PipelineConfig(1000, 64, 8, seed=3))
    b1, b2 = p1.batch(5), p2.batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(p1.batch(5)["tokens"],
                              p1.batch(6)["tokens"])


def test_labels_are_shifted_tokens():
    p = TokenPipeline(PipelineConfig(1000, 64, 4))
    b = p.batch(0)
    assert b["tokens"].shape == (4, 64)
    assert b["labels"].shape == (4, 64)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_tokens_in_vocab():
    p = TokenPipeline(PipelineConfig(500, 32, 4))
    b = p.batch(1)
    assert b["tokens"].min() >= 1 and b["tokens"].max() < 500


def test_host_sharding_partitions_batch():
    cfgp = PipelineConfig(1000, 32, 8, seed=0)
    h0 = TokenPipeline(cfgp, host_id=0, n_hosts=2).batch(2)
    h1 = TokenPipeline(cfgp, host_id=1, n_hosts=2).batch(2)
    assert h0["tokens"].shape == (4, 32)
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_batch_for_modalities():
    cfg = configs.get("llama-3.2-vision-11b").reduced()
    b = batch_for(cfg, SHAPES["train_4k"], reduced_batch=2)
    assert "vision_embeds" in b
    assert b["vision_embeds"].shape == (2, cfg.n_vision_tokens,
                                        cfg.d_model)
    cfg = configs.get("seamless-m4t-large-v2").reduced()
    b = batch_for(cfg, SHAPES["train_4k"], reduced_batch=2)
    assert b["audio_embeds"].shape[0] == 2
