"""Test-wide config. NOTE: no XLA_FLAGS here — smoke tests and benches see
the real single CPU device; only launch/dryrun.py requests 512 fake
devices (per its first two lines)."""
import warnings

warnings.filterwarnings("ignore", category=DeprecationWarning)
