"""LBCD controller: constraint satisfaction, optimality gap, bin packing."""
import numpy as np
import pytest

from repro.core import baselines, binpack, lbcd, lyapunov, profiles


def _system(**kw):
    kw.setdefault("n_cameras", 18)
    kw.setdefault("n_servers", 3)
    kw.setdefault("n_slots", 60)
    return profiles.EdgeSystem(**kw)


def test_long_term_accuracy_constraint():
    # v=2: accuracy converges within ~40 slots (Fig. 8 regime); the large-V
    # transient is exercised by test_v_tradeoff below.
    ctrl = lbcd.LBCDController(_system(), v=2.0, p_min=0.7)
    summary = ctrl.run(100)
    tail = summary.acc_series[40:]
    assert tail.mean() >= 0.7 - 0.01
    # Virtual queue stays bounded (stability).
    assert summary.q_series[-1] < 5.0


def test_q_dynamics_match_eq44():
    ctrl = lbcd.LBCDController(_system(seed=4), v=10.0, p_min=0.75)
    q_prev = 0.0
    for t in range(5):
        rec = ctrl.step(t)
        expect = max(q_prev - rec.mean_acc + 0.75, 0.0)
        assert rec.q == pytest.approx(expect, abs=1e-6)
        q_prev = rec.q


def test_v_tradeoff():
    """Theorem 4: larger V -> lower AoPI (we check the drift-plus-penalty
    score improves), slower accuracy convergence."""
    base = dict(n_cameras=12, n_servers=2, n_slots=40,
                mean_bandwidth_hz=8e6, mean_compute_flops=8e12)
    lo = lbcd.LBCDController(_system(**base), v=1.0, p_min=0.7).run(40)
    hi = lbcd.LBCDController(_system(**base), v=100.0, p_min=0.7).run(40)
    assert hi.mean_aopi <= lo.mean_aopi * 1.05
    assert lo.acc_series[:10].mean() >= hi.acc_series[:10].mean() - 0.02


def test_min_is_lower_bound():
    sysk = dict(n_cameras=12, n_servers=3, n_slots=30, seed=2,
                mean_bandwidth_hz=10e6, mean_compute_flops=10e12)
    mn = baselines.MINController(_system(**sysk)).run(30)
    lb = lbcd.LBCDController(_system(**sysk), v=10.0, p_min=0.7).run(30)
    # MIN ignores the accuracy constraint on a pooled server: lower AoPI.
    assert mn.mean_aopi <= lb.mean_aopi * 1.02


def test_lbcd_beats_baselines_when_constrained():
    """Fig. 9-11 regime: resource-limited, LBCD wins on AoPI while meeting
    the accuracy floor."""
    sysk = dict(n_cameras=24, n_servers=3, n_slots=30, seed=1,
                mean_bandwidth_hz=10e6, mean_compute_flops=12e12)
    lb = lbcd.LBCDController(_system(**sysk), v=10.0, p_min=0.7).run(30)
    for name in ("DOS", "JCAB"):
        bl = baselines.make(name, _system(**sysk)).run(30)
        assert lb.mean_aopi < bl.mean_aopi, name


def test_first_fit_respects_capacity_when_feasible():
    b_hat = np.array([3.0, 2.0, 2.0, 1.0])
    c_hat = np.array([1.0, 2.0, 1.0, 1.0])
    B = np.array([5.0, 4.0])
    C = np.array([3.0, 3.0])
    a = binpack.first_fit(b_hat, c_hat, B, C)
    for s in range(2):
        m = a == s
        assert b_hat[m].sum() <= B[s] + 1e-9
        assert c_hat[m].sum() <= C[s] + 1e-9


def test_first_fit_overflow_goes_to_largest_remaining():
    b_hat = np.array([5.0, 5.0, 5.0])
    c_hat = np.array([1.0, 1.0, 1.0])
    B = np.array([6.0, 4.0])
    C = np.array([2.0, 2.0])
    a = binpack.first_fit(b_hat, c_hat, B, C)
    assert set(a.tolist()) <= {0, 1}


def test_hierarchical_first_fit():
    rng = np.random.default_rng(0)
    b_hat = rng.uniform(0.5, 2.0, 16)
    c_hat = rng.uniform(0.5, 2.0, 16)
    a = binpack.hierarchical_first_fit(b_hat, c_hat, [20.0, 20.0],
                                       [20.0, 20.0], islands_per_pod=4)
    assert a.min() >= 0 and a.max() < 8


def test_drift_lemma1_bound():
    """Empirical drift never exceeds the Lemma-1 bound."""
    rng = np.random.default_rng(0)
    q = 0.0
    for _ in range(200):
        p_bar = rng.uniform(0.0, 1.0)
        p_min = 0.7
        q_next = lyapunov.queue_update(q, p_bar, p_min)
        drift = 0.5 * (float(q_next)**2 - q**2)
        assert drift <= lyapunov.drift_bound(q, p_bar, p_min) + 1e-9
        q = float(q_next)


def test_interior_point_method_end_to_end():
    """The paper-faithful Algorithm-1 path (interior point) also satisfies
    the constraint and achieves similar score."""
    sysk = dict(n_cameras=10, n_servers=2, n_slots=12, seed=6)
    wf = lbcd.LBCDController(_system(**sysk), v=10.0, p_min=0.7,
                             method="waterfill").run(12)
    ip = lbcd.LBCDController(_system(**sysk), v=10.0, p_min=0.7,
                             method="interior").run(12)
    assert ip.mean_aopi == pytest.approx(wf.mean_aopi, rel=0.15)


def test_first_fit_property_never_overflows_when_feasible():
    """Property: whenever a feasible packing exists for first-fit's greedy
    order, no server exceeds capacity."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=30, deadline=None)
    @given(st.integers(0, 10_000))
    def inner(seed):
        rng = np.random.default_rng(seed)
        n, s = rng.integers(3, 12), rng.integers(2, 4)
        b_hat = rng.uniform(0.1, 1.0, n)
        c_hat = rng.uniform(0.1, 1.0, n)
        # generous capacity -> must fit without overflow
        B = np.full(s, b_hat.sum())
        C = np.full(s, c_hat.sum())
        a = binpack.first_fit(b_hat, c_hat, B, C)
        for j in range(s):
            m = a == j
            assert b_hat[m].sum() <= B[j] + 1e-9
            assert c_hat[m].sum() <= C[j] + 1e-9
    inner()
