"""Distribution-layer equivalence tests.

These need >1 device, so each runs a subprocess with
--xla_force_host_platform_device_count (the main pytest process keeps the
single real CPU device, per the dry-run isolation rule).
"""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_snippet(body: str, devices: int = 8) -> str:
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count={devices}"
        import sys
        sys.path.insert(0, {os.path.join(REPO, 'src')!r})
    """) + textwrap.dedent(body)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_moe_a2a_matches_einsum_path():
    out = run_snippet("""
        import dataclasses
        import jax, jax.numpy as jnp
        from repro import configs
        from repro.models import build
        from repro.models.common import init_params
        from repro.sharding import ctx, rules as rules_mod
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((4,2), ("data","model"))
        cfg = dataclasses.replace(configs.get("dbrx-132b").reduced(),
                                  n_experts=4, top_k=2,
                                  capacity_factor=2.0)
        model = build(cfg, ep_degree=4)
        params = init_params(model.template(), jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                  cfg.vocab)
        l0, _ = model.forward(params, {"tokens": toks})
        rules = rules_mod.make_rules(cfg, mesh)
        def f(p, b):
            with ctx.activation_rules(rules):
                return model.forward(p, b)
        with mesh:
            l1, _ = jax.jit(f)(params, {"tokens": toks})
        err = float(jnp.max(jnp.abs(l0 - l1)))
        assert err < 2e-3, err
        print("ERR", err)
    """)
    assert "ERR" in out


def test_hoisted_gather_matches_plain_step():
    out = run_snippet("""
        import jax, jax.numpy as jnp
        import numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro import configs
        from repro.models import build
        from repro.models.common import init_params, pspec_tree
        from repro.sharding import ctx, rules as rules_mod
        from repro.training import optimizer as opt_mod
        from repro.training.train_step import make_train_step
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((4,2), ("data","model"))
        cfg = configs.get("qwen2.5-3b").reduced()
        model = build(cfg)
        params = init_params(model.template(), jax.random.PRNGKey(0))
        ocfg = opt_mod.AdamWConfig(lr=1e-3)
        opt = opt_mod.init(params, ocfg)
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 33), 0,
                                  cfg.vocab)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        rules = rules_mod.make_rules(cfg, mesh)
        gr = dict(rules); gr["embed"] = None
        specs = pspec_tree(model.template(), gr)
        def pre(p, _s=specs):
            return jax.tree.map(jax.lax.with_sharding_constraint, p, _s)
        outs = []
        for pc in (None, pre):
            step = make_train_step(model, ocfg, n_microbatches=2,
                                   pre_constrain=pc)
            def f(p, o, b):
                with ctx.activation_rules(rules):
                    return step(p, o, b)
            with mesh:
                p2, _, m = jax.jit(f)(params, opt, batch)
            outs.append((p2, float(m["loss"])))
        assert abs(outs[0][1] - outs[1][1]) < 1e-5
        for a, b in zip(jax.tree.leaves(outs[0][0]),
                        jax.tree.leaves(outs[1][0])):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       atol=3e-5)
        print("HOIST-EQ OK")
    """)
    assert "HOIST-EQ OK" in out


def test_plan_cell_compiles_on_small_mesh():
    out = run_snippet("""
        import jax
        from repro import configs
        from repro.configs.base import SHAPES
        from repro.launch.specs import plan_cell
        from repro.launch.mesh import make_mesh
        from repro.launch.dryrun import cost_analysis
        mesh = make_mesh((2,4), ("data","model"))
        for shape in ("train_4k", "decode_32k"):
            plan = plan_cell(configs.get("qwen2.5-3b"), SHAPES[shape],
                             mesh)
            c = plan.compile()
            assert cost_analysis(c).get("flops", 0) > 0
        print("PLAN OK")
    """)
    assert "PLAN OK" in out
