"""Truth-ladder rung 3: the real continuous-batching engine driven by the
discrete-event replay plane (``serving.engine_plane``), its batched
device-resident twin (``serving.tick_plane``), the fitted delay-model
selector at service level, and the engine columns in the robustness
report."""
import jax
import numpy as np
import pytest

from repro import scenarios
from repro.core import aopi, lbcd, profiles, queues
from repro.serving import engine_plane, make_replay_engine, replay, tick_plane
from repro.serving.engine import FREE
from repro.serving.scheduler import Frame
from repro.serving.service import AnalyticsService

DIMS = dict(n_cameras=5, n_slots=12, n_servers=2,
            mean_bandwidth_hz=15e6, mean_compute_flops=20e12)


def _steady(n=6, lam=0.6, mu=2.0, p=0.8):
    pol = (np.arange(n) % 2).astype(np.int64)
    return (np.full(n, lam), np.full(n, mu), np.full(n, p), pol)


# ---------------------------------------------------------------------------
# Parity anchors: engine rung vs GI/G/1 rung vs closed forms
# ---------------------------------------------------------------------------

def test_engine_epoch_parity_with_closed_forms_and_gi_g1():
    """Steady family anchor: the three rungs of the truth ladder agree
    within statistical tolerance (same stochastic process, independent
    draws)."""
    lam, mu, p, pol = _steady()
    eng = make_replay_engine(len(lam))
    eng_means, gi_means = [], []
    for t in range(3):
        out = engine_plane.measure_engine_epoch(
            eng, lam, mu, p, pol, epoch_duration=300.0, seed=5, t=t)
        assert out["engine_steps"] > 0
        eng_means.append(out["aopi"])
        gi = queues.gi_g1_window([lam], [mu], [p], [pol], seed=6, t0=t,
                                 n_frames=4096, horizon=300.0)
        gi_means.append(gi["aopi"][0, 0])
    eng_aopi = np.mean(eng_means, axis=0)
    gi_aopi = np.mean(gi_means, axis=0)
    th = np.array([float(aopi.aopi(l, m, q, w))
                   for l, m, q, w in zip(lam, mu, p, pol)])
    # rung 3 vs rung 1 (closed forms) and rung 3 vs rung 2 (GI/G/1).
    assert eng_aopi.mean() == pytest.approx(th.mean(), rel=0.15)
    assert eng_aopi.mean() == pytest.approx(gi_aopi.mean(), rel=0.15)
    # LCFSP < FCFS ordering survives on the engine rung.
    assert eng_aopi[pol == 1].mean() < eng_aopi[pol == 0].mean()


def test_engine_epoch_bitwise_deterministic():
    """Fresh engines + fixed (seed, t) -> bitwise-identical replay."""
    lam, mu, p, pol = _steady(n=4)
    kw = dict(epoch_duration=120.0, seed=9, t=2, frames_cap=64)
    a = engine_plane.measure_engine_epoch(
        make_replay_engine(4), lam, mu, p, pol, **kw)
    b = engine_plane.measure_engine_epoch(
        make_replay_engine(4), lam, mu, p, pol, **kw)
    for k in ("aopi", "horizon", "n_frames", "n_completed", "n_accurate"):
        np.testing.assert_array_equal(a[k], b[k])
    c = engine_plane.measure_engine_epoch(
        make_replay_engine(4), lam, mu, p, pol,
        epoch_duration=120.0, seed=10, t=2, frames_cap=64)
    assert not np.array_equal(a["aopi"], c["aopi"])


def test_engine_epoch_heavy_tail_family():
    lam, mu, p, pol = _steady(n=4)
    out = engine_plane.measure_engine_epoch(
        make_replay_engine(4), lam, mu, p, pol, epoch_duration=120.0,
        seed=3, frames_cap=96, delay_model="weibull", collect_samples=16)
    assert np.isfinite(out["aopi"]).all() and (out["aopi"] > 0).all()
    assert out["delay_samples"].shape == (4, 16)
    assert (out["delay_samples"] > 0).all()


# ---------------------------------------------------------------------------
# Lane bookkeeping: churn-under-engine + preempt hygiene
# ---------------------------------------------------------------------------

def test_churned_out_stream_leaks_no_lane():
    """PR 8's ``active`` mask reaching the engine path: a stream that
    churns out mid-sequence gets zeroed outputs, and every lane is FREE
    after the epoch (the leaked-lane bug this plane fixes)."""
    lam, mu, p, pol = _steady(n=4)
    eng = make_replay_engine(4)
    kw = dict(epoch_duration=120.0, seed=1, frames_cap=64)
    out0 = engine_plane.measure_engine_epoch(eng, lam, mu, p, pol,
                                             t=0, **kw)
    assert (out0["n_completed"] > 0).all()
    active = np.array([1.0, 0.0, 1.0, 1.0])
    out1 = engine_plane.measure_engine_epoch(eng, lam, mu, p, pol, t=1,
                                             active=active, **kw)
    assert out1["aopi"][1] == 0.0 and out1["n_frames"][1] == 0.0
    assert (out1["n_completed"][active > 0] > 0).all()
    assert all(l.status == FREE for l in eng.lanes)
    # The stream rejoins cleanly on the same engine the next epoch.
    out2 = engine_plane.measure_engine_epoch(eng, lam, mu, p, pol,
                                             t=2, **kw)
    assert (out2["n_completed"] > 0).all()
    assert all(l.status == FREE for l in eng.lanes)


def test_preempt_releases_lane_with_no_stale_state():
    """``Engine.preempt_stream`` must return the lane to the pool with no
    leftover bookkeeping — a dirty freed lane poisons the next admit."""
    eng = make_replay_engine(2, decode_tokens=50)
    eng.admit(Frame(0, 0.0, 0.0), np.arange(6, dtype=np.int32), lane=0)
    eng.decode_tick()
    assert eng.preempt_stream(0) == 1
    lane = eng.lanes[0]
    assert lane.status == FREE and lane.stream_id == -1
    assert lane.frame is None and lane.remaining == 0 and lane.out == []
    assert eng.utilization == 0.0
    # Pinned admits respect busy lanes.
    assert eng.admit(Frame(1, 0.0, 0.0), np.arange(6, dtype=np.int32),
                     lane=1)
    assert not eng.admit(Frame(2, 0.0, 0.0), np.arange(6, dtype=np.int32),
                         lane=1)


def test_engine_plane_requires_one_lane_per_stream():
    lam, mu, p, pol = _steady(n=4)
    with pytest.raises(ValueError, match="lanes"):
        engine_plane.measure_engine_epoch(
            make_replay_engine(2), lam, mu, p, pol, epoch_duration=60.0)


# ---------------------------------------------------------------------------
# Tick-scan backend: bitwise DES parity, hygiene, compiled shape
# ---------------------------------------------------------------------------

_TRACE_KEYS = ("aopi", "horizon", "n_frames", "n_completed", "n_accurate",
               "preempts", "delay_samples")


@pytest.mark.parametrize("dm", queues.DELAY_MODELS)
def test_tick_scan_bitwise_matches_des_every_family(dm):
    """The tick-scan replays the DES *bitwise* on shared pre-drawn
    randomness — every stat, every delay sample, and the full completion
    trace, for every delay family."""
    lam, mu, p, pol = _steady()
    kw = dict(epoch_duration=120.0, seed=7, t=1, frames_cap=48,
              delay_model=dm, collect_samples=8, collect_trace=True)
    des = engine_plane.measure_engine_epoch(
        make_replay_engine(len(lam)), lam, mu, p, pol, **kw)
    scan = tick_plane.measure_engine_epoch_scan(lam, mu, p, pol, **kw)
    for k in _TRACE_KEYS:
        np.testing.assert_array_equal(des[k], scan[k], err_msg=k)
    assert des["trace"] == scan["trace"] and len(scan["trace"]) > 0
    # The epoch actually exercised the interesting paths.
    assert (scan["n_completed"] > 0).all()
    assert scan["preempts"][pol == 1].sum() > 0


def test_tick_scan_statistical_parity_with_gi_g1_and_closed_forms():
    """Same three-rung anchor as the DES test, on the scan backend."""
    lam, mu, p, pol = _steady()
    sc_means, gi_means = [], []
    for t in range(3):
        out = tick_plane.measure_engine_epoch_scan(
            lam, mu, p, pol, epoch_duration=300.0, seed=5, t=t)
        assert out["engine_steps"] > 0
        sc_means.append(out["aopi"])
        gi = queues.gi_g1_window([lam], [mu], [p], [pol], seed=6, t0=t,
                                 n_frames=4096, horizon=300.0)
        gi_means.append(gi["aopi"][0, 0])
    sc_aopi = np.mean(sc_means, axis=0)
    gi_aopi = np.mean(gi_means, axis=0)
    th = np.array([float(aopi.aopi(l, m, q, w))
                   for l, m, q, w in zip(lam, mu, p, pol)])
    assert sc_aopi.mean() == pytest.approx(th.mean(), rel=0.15)
    assert sc_aopi.mean() == pytest.approx(gi_aopi.mean(), rel=0.15)
    assert sc_aopi[pol == 1].mean() < sc_aopi[pol == 0].mean()


def test_tick_scan_churn_masks_lanes_bitwise():
    """A churned-out stream zeroes its lane; the surviving lanes are
    bitwise-unaffected by the mask (independent per-stream key streams),
    and the masked scan still matches the masked DES bitwise."""
    lam, mu, p, pol = _steady(n=4)
    kw = dict(epoch_duration=120.0, seed=1, t=1, frames_cap=64)
    full = tick_plane.measure_engine_epoch_scan(lam, mu, p, pol, **kw)
    active = np.array([1.0, 0.0, 1.0, 1.0])
    mask = tick_plane.measure_engine_epoch_scan(lam, mu, p, pol,
                                                active=active, **kw)
    dead, live = active == 0, active > 0
    for k in ("aopi", "horizon", "n_frames", "n_completed", "preempts"):
        assert (mask[k][dead] == 0.0).all(), k
        np.testing.assert_array_equal(mask[k][live], full[k][live],
                                      err_msg=k)
    des = engine_plane.measure_engine_epoch(
        make_replay_engine(4), lam, mu, p, pol, active=active, **kw)
    np.testing.assert_array_equal(mask["aopi"], des["aopi"])


def test_tick_scan_preempt_discipline():
    """Preemption is an LCFSP-only event on both backends, and the scan
    counts exactly the DES's preemptions."""
    lam, mu, p, pol = _steady(n=6, lam=1.2, mu=1.5)
    kw = dict(epoch_duration=120.0, seed=3, t=0, frames_cap=96)
    scan = tick_plane.measure_engine_epoch_scan(lam, mu, p, pol, **kw)
    des = engine_plane.measure_engine_epoch(
        make_replay_engine(6), lam, mu, p, pol, **kw)
    np.testing.assert_array_equal(scan["preempts"], des["preempts"])
    assert (scan["preempts"][pol == 0] == 0.0).all()    # FCFS never
    assert scan["preempts"][pol == 1].sum() > 0         # LCFSP does


def test_tick_scan_compiles_to_single_scan():
    """The whole epoch is ONE fused ``lax.scan`` over ticks — no
    per-stream Python loop, no ``while`` in the jaxpr."""
    s, f = 8, 16
    arr2 = np.ones((f, s))
    arr1 = np.ones(s)
    bools = np.zeros(s, dtype=bool)
    with np.errstate(all="ignore"):
        jaxpr = jax.make_jaxpr(
            lambda *a: tick_plane._tick_scan_impl(*a, collect_trace=False))(
                arr2, arr2, arr2, arr2, arr2, arr1, bools, arr1, ~bools)

    def prims(jp):
        for eqn in jp.eqns:
            yield eqn.primitive.name
            for v in eqn.params.values():
                for sub in jax.tree_util.tree_leaves(
                        v, is_leaf=lambda x: hasattr(x, "jaxpr")):
                    if hasattr(sub, "jaxpr"):
                        yield from prims(sub.jaxpr)

    names = list(prims(jaxpr.jaxpr))
    assert names.count("scan") == 1
    assert "while" not in names


def test_resolve_engine_backend_grammar():
    r = tick_plane.resolve_engine_backend
    assert r("des", n_streams=10_000, frames_cap=10_000) == "des"
    assert r("scan", n_streams=1, frames_cap=1) == "scan"
    # auto: frame volume at/below the DES budget stays on the DES.
    assert r("auto", n_streams=5, frames_cap=192) == "des"
    assert r("auto", n_streams=300, frames_cap=200_000) == "scan"
    with pytest.raises(ValueError, match="engine_backend"):
        r("vmap", n_streams=1, frames_cap=1)


def test_measure_epoch_dispatcher():
    lam, mu, p, pol = _steady(n=4)
    kw = dict(epoch_duration=90.0, seed=2, t=0, frames_cap=32)
    a = tick_plane.measure_epoch(lam, mu, p, pol, backend="scan", **kw)
    b = tick_plane.measure_epoch(lam, mu, p, pol, backend="des",
                                 engine=make_replay_engine(4), **kw)
    np.testing.assert_array_equal(a["aopi"], b["aopi"])
    with pytest.raises(ValueError, match="engine"):
        tick_plane.measure_epoch(lam, mu, p, pol, backend="des", **kw)


# ---------------------------------------------------------------------------
# Service-level fitted selector (delay_model="auto")
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dm", queues.DELAY_MODELS)
def test_service_auto_selects_generating_family(dm):
    """Synthetic telemetry generated under each family: the fitted
    selector recovers the generating family from the service's own
    delay-sample pool."""
    system = profiles.EdgeSystem(n_cameras=8, n_servers=2, n_slots=8,
                                 seed=4)
    ctrl = lbcd.LBCDController(system, v=10.0, p_min=0.6)
    svc = AnalyticsService(ctrl, mode="mm1", epoch_duration=1200.0,
                           delay_model="auto", true_delay_model=dm)
    reps = svc.run(3)
    assert svc.fitted_models and svc.fitted_models[-1][1] == dm
    assert reps[-1].fitted_model == dm
    assert svc.true_delay_model == dm


def test_service_auto_defaults_and_validation():
    system = profiles.EdgeSystem(n_cameras=4, n_servers=2, n_slots=6,
                                 seed=0)
    ctrl = lbcd.LBCDController(system, v=10.0, p_min=0.6)
    # auto with no explicit truth -> generates under mm1.
    svc = AnalyticsService(ctrl, delay_model="auto")
    assert svc.true_delay_model == "mm1"
    # concrete delay_model -> truth defaults to it; no fitting state.
    svc2 = AnalyticsService(ctrl, delay_model="gamma")
    assert svc2.true_delay_model == "gamma" and not svc2.fitted_models
    with pytest.raises(ValueError, match="delay_model"):
        AnalyticsService(ctrl, delay_model="auto", true_delay_model="auto")


# ---------------------------------------------------------------------------
# Replay + report: the engine rung rides the suite
# ---------------------------------------------------------------------------

def test_replay_tables_engine_mode_three_rungs():
    tab = scenarios.build("steady_ar1", {**DIMS, "n_slots": 4})
    rep = replay.replay_tables(tab, "lbcd", epoch_duration=90.0, seed=0,
                               mode="engine",
                               engine_params={"frames_cap": 24})
    assert rep.engine is not None
    assert rep.engine.shape == rep.measured.shape == rep.predicted.shape
    assert np.isfinite(rep.engine).all() and (rep.engine > 0).all()
    # measured stays the GI/G/1 rung: distinct series from the engine's.
    assert not np.array_equal(rep.engine, rep.measured)
    svc = rep.service
    assert svc.mode == "engine" and svc.engine_frames_cap == 24
    # 5 cameras x cap 24 frames sits under the auto budget -> real DES.
    assert svc.engine_backend == "des" and svc.engine is not None


def test_replay_tables_scan_backend_full_cap():
    """``engine_params={"backend": "scan"}`` rides the whole replay stack
    at the full GI/G/1-parity frames cap with no host Engine at all."""
    tab = scenarios.build("steady_ar1", {**DIMS, "n_slots": 4})
    rep = replay.replay_tables(tab, "lbcd", epoch_duration=90.0, seed=0,
                               mode="engine",
                               engine_params={"backend": "scan"})
    svc = rep.service
    assert svc.engine_backend == "scan" and svc.engine is None
    assert svc.engine_frames_cap == 200_000
    assert rep.engine is not None
    assert np.isfinite(rep.engine).all() and (rep.engine > 0).all()
    assert not np.array_equal(rep.engine, rep.measured)
    # Same cap, same seed -> the two backends are bitwise-identical
    # through the whole replay stack.
    des = replay.replay_tables(tab, "lbcd", epoch_duration=90.0, seed=0,
                               mode="engine",
                               engine_params={"backend": "des",
                                              "frames_cap": 24})
    scan = replay.replay_tables(tab, "lbcd", epoch_duration=90.0, seed=0,
                                mode="engine",
                                engine_params={"backend": "scan",
                                               "frames_cap": 24})
    np.testing.assert_array_equal(des.engine, scan.engine)


def test_sweep_engine_mode_report_columns():
    s = scenarios.suite(["steady_ar1"], **{**DIMS, "n_slots": 4})
    res = scenarios.sweep(
        s, policies=("lbcd", "min"), devices=jax.devices()[:1],
        dataplane=True,
        dataplane_params=dict(n_epochs=2, epoch_duration=90.0,
                              mode="engine",
                              engine_params={"frames_cap": 24}))
    assert res.engine_aopi is not None
    assert set(res.engine_aopi) == {"lbcd", "min"}
    for p in res.engine_aopi:
        assert res.engine_aopi[p].shape == res.measured_aopi[p].shape
        assert np.isfinite(res.engine_aopi[p]).all()
    rep = scenarios.robustness(res)
    assert rep.has_engine
    for p in rep.policies:
        st = rep.table[p]["steady"]
        assert st.engine_mean is not None and st.engine_mean > 0
        assert np.isfinite(st.engine_vs_gi)
        assert np.isfinite(st.engine_vs_predicted)
    txt = str(rep)
    assert "div:gi" in txt and "div:cf" in txt and "truth ladder" in txt
    # rows gain the 5 engine columns after the measured block.
    assert len(rep.rows()[0]) == 10 + 5


def test_replay_tables_auto_records_fitted_models():
    tab = scenarios.build("steady_ar1", {**DIMS, "n_slots": 4})
    rep = replay.replay_tables(tab, "lbcd", epoch_duration=900.0, seed=0,
                               delay_model="auto",
                               true_delay_model="uniform")
    assert rep.fitted is not None and len(rep.fitted) == 4
    assert rep.fitted[-1] == "uniform"
