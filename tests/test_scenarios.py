"""repro.scenarios: registry, determinism, generator properties, sweeps."""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro import scenarios
from repro.core import profiles

DIMS = dict(n_cameras=5, n_slots=16, n_servers=2,
            mean_bandwidth_hz=15e6, mean_compute_flops=20e12)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

def test_registry_has_at_least_five_families():
    fams = scenarios.families()
    assert len(fams) >= 5
    assert len(scenarios.names()) >= len(fams)
    for name in scenarios.names():
        assert scenarios.family_of(name) in fams


def test_unknown_scenario_raises_with_known_names():
    with pytest.raises(KeyError, match="steady_ar1"):
        scenarios.build("no_such_scenario")


def test_overrides_reach_spec_fields_and_params():
    spec = scenarios.spec_for("server_outage",
                              {"n_cameras": 3, "degrade": 0.5})
    assert spec.n_cameras == 3
    assert spec.param("degrade", None) == 0.5
    assert spec.family == "server_outage"


# ---------------------------------------------------------------------------
# Determinism (satellite: same name + seed -> bitwise-identical tables)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["steady_ar1", "gilbert_elliott",
                                  "snr_mobility", "content_burst"])
def test_build_is_bitwise_deterministic(name):
    a = scenarios.build(name, DIMS)
    b = scenarios.build(name, DIMS)
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_different_seed_changes_tables():
    a = scenarios.build("steady_ar1", DIMS)
    b = scenarios.build("steady_ar1", DIMS, seed=1)
    assert not np.array_equal(np.asarray(a.budgets_b),
                              np.asarray(b.budgets_b))


def test_horizon_is_deterministic_and_reset_replays():
    sys_a = profiles.EdgeSystem(n_cameras=4, n_servers=2, n_slots=10)
    h1 = sys_a.horizon(6)
    sys_a.advance_drift()              # perturb the stateful legacy RNG
    h2 = sys_a.horizon(6)              # horizon() must not care
    for la, lb in zip(jax.tree.leaves(h1), jax.tree.leaves(h2)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    # reset() replays the legacy per-slot drift stream from the top.
    sys_b = profiles.EdgeSystem(n_cameras=4, n_servers=2, n_slots=10)
    first = sys_b.advance_drift().copy()
    again = sys_b.reset().advance_drift()
    np.testing.assert_array_equal(first, again)


def test_vectorized_trace_matches_reference_loop():
    """ar1_scan path == the historical per-slot python recursion."""
    rho, sigma, mean, shape = 0.85, 0.25, 5e6, (300, 3)
    ref_rng = np.random.default_rng(9)
    x = np.zeros(shape)
    x[0] = ref_rng.normal(0, sigma, shape[1])
    for t in range(1, shape[0]):
        x[t] = rho * x[t - 1] + np.sqrt(1 - rho**2) * ref_rng.normal(
            0, sigma, shape[1])
    ref = mean * np.exp(x - 0.5 * sigma**2)
    got = profiles.lognormal_ar1_trace(np.random.default_rng(9), mean,
                                       shape, rho=rho, sigma=sigma)
    np.testing.assert_allclose(got, ref, rtol=1e-12)


# ---------------------------------------------------------------------------
# stack_horizons (satellite: error quality + slot round-trip)
# ---------------------------------------------------------------------------

def test_stack_horizons_shape_mismatch_raises_clear_error():
    a = profiles.EdgeSystem(n_cameras=4, n_servers=2, n_slots=8).horizon(6)
    b = profiles.EdgeSystem(n_cameras=5, n_servers=2, n_slots=8).horizon(6)
    with pytest.raises(ValueError, match="shape mismatch on field 'acc'"):
        profiles.stack_horizons([a, b])
    with pytest.raises(ValueError, match="at least one"):
        profiles.stack_horizons([])


def test_stack_horizons_slot_roundtrip():
    systems = [profiles.EdgeSystem(n_cameras=4, n_servers=2, n_slots=8,
                                   seed=s) for s in range(3)]
    horizons = [s.horizon(5) for s in systems]
    stacked = profiles.stack_horizons(horizons)
    for k, hor in enumerate(horizons):
        unstacked = jax.tree.map(lambda x: x[k], stacked)
        for t in range(5):
            want, got = hor.slot(t), unstacked.slot(t)
            np.testing.assert_array_equal(want.acc, got.acc)
            np.testing.assert_array_equal(want.eff, got.eff)


def test_slot_view_handles_time_varying_eff():
    tab = scenarios.build("snr_mobility", DIMS)
    assert tab.eff.ndim == 2
    s0, s5 = tab.slot(0), tab.slot(5)
    assert s0.eff.shape == (DIMS["n_cameras"],)
    assert not np.array_equal(s0.eff, s5.eff)


# ---------------------------------------------------------------------------
# Generator family properties
# ---------------------------------------------------------------------------

def test_gilbert_elliott_is_bimodal():
    tab = scenarios.build("gilbert_elliott", DIMS, n_slots=200)
    bw = np.asarray(tab.budgets_b)
    mean = DIMS["mean_bandwidth_hz"]
    assert bw.min() < 0.5 * mean          # deep-fade state visited
    assert bw.max() > 0.9 * mean          # good state visited


def test_gilbert_elliott_sojourn_lengths_match_transition_probs():
    """Mean bad-state sojourn must be ~1/p_bg (geometric), not 1/(1-p_bg) —
    guards against inverted transition logic."""
    from repro.scenarios.generators import _gilbert_elliott_states
    p_gb, p_bg = 0.05, 0.25
    spec = scenarios.spec_for("gilbert_elliott",
                              {**DIMS, "n_slots": 20000, "n_servers": 1})
    states = _gilbert_elliott_states(spec, p_gb, p_bg)[:, 0]
    bad = ~states
    # runs of consecutive bad slots
    edges = np.diff(bad.astype(int))
    starts = np.where(edges == 1)[0]
    ends = np.where(edges == -1)[0]
    n = min(len(starts), len(ends))
    lengths = ends[:n] - starts[:n]
    assert abs(lengths.mean() - 1.0 / p_bg) < 1.0    # ~4 +- sampling noise


def test_server_outage_degrades_one_server():
    tab = scenarios.build("server_outage",
                          {**DIMS, "degrade": 0.01, "n_outages": 2})
    steady = scenarios.build("steady_ar1", DIMS)
    mean = DIMS["mean_bandwidth_hz"]
    assert np.asarray(tab.budgets_b).min() < 0.02 * mean
    assert np.asarray(steady.budgets_b).min() > 0.1 * mean
    assert np.asarray(tab.budgets_b).min() > 0.0   # floored, never zero


def test_diurnal_flash_swings_more_than_steady():
    tab = scenarios.build("diurnal_flash", DIMS, n_slots=96)
    steady = scenarios.build("steady_ar1", DIMS, n_slots=96)
    swing = lambda x: float(np.asarray(x).max() / np.asarray(x).min())
    assert swing(tab.budgets_b) > swing(steady.budgets_b)


def test_snr_mobility_varies_eff_over_time():
    tab = scenarios.build("snr_mobility", DIMS)
    steady = scenarios.build("steady_ar1", DIMS)
    assert np.asarray(tab.eff).std(axis=0).min() > 1e-3
    # steady eff is constant per camera (up to f32 rounding in std)
    assert np.asarray(steady.eff).std(axis=0).max() < 1e-4


def test_content_burst_crushes_accuracy_below_steady():
    tab = scenarios.build("content_burst",
                          {**DIMS, "n_bursts": 10, "burst_depth": 0.6})
    steady = scenarios.build("steady_ar1", DIMS)
    assert float(np.asarray(tab.acc).min()) < \
        float(np.asarray(steady.acc).min())


# ---------------------------------------------------------------------------
# Suite + sweep (vmap fallback path, single device)
# ---------------------------------------------------------------------------

def test_suite_stacks_all_registered_scenarios():
    s = scenarios.suite(**DIMS)
    assert s.n_scenarios == len(scenarios.names())
    assert len(set(s.families)) >= 5
    assert s.tables.acc.shape[0] == s.n_scenarios
    assert s.tables.acc.shape[1] == DIMS["n_slots"]


def test_sweep_runs_all_policies_and_reports():
    s = scenarios.suite(["steady_ar1", "gilbert_elliott", "server_outage"],
                        n_cameras=4, n_slots=6, n_servers=2,
                        mean_bandwidth_hz=15e6, mean_compute_flops=20e12)
    # Pin one device: the suite may run with many virtual devices in the
    # process (e.g. after launch/dryrun forces 512), and this test is about
    # the vmap fallback semantics, not backend selection.
    res = scenarios.sweep(s, v=10.0, p_min=0.7, devices=jax.devices()[:1])
    assert res.backend == "vmap"
    assert set(res.policies) == set(scenarios.POLICIES)
    for p in res.policies:
        assert res.aopi[p].shape == (3, 6)
        assert np.isfinite(res.aopi[p]).all()
        assert (res.acc[p] > 0).all()
    rep = scenarios.robustness(res)
    assert set(rep.families) == set(s.families)
    fam, stats = rep.worst_family("lbcd")
    assert stats.worst_aopi >= rep.table["lbcd"][fam].mean_aopi - 1e-9
    assert "lbcd" in str(rep)
    assert len(rep.rows()) == len(rep.policies) * len(rep.families)


def test_sweep_unknown_policy_or_backend_raises():
    s = scenarios.suite(["steady_ar1"], n_cameras=3, n_slots=4,
                        n_servers=2)
    with pytest.raises(ValueError, match="unknown policy"):
        scenarios.sweep(s, policies=("nope",))
    with pytest.raises(ValueError, match="unknown backend"):
        scenarios.sweep(s, backend="nope")
    # An unstacked horizon (the thing rollout() takes) is rejected at the
    # API boundary instead of dying inside a jitted scan.
    single = profiles.EdgeSystem(n_cameras=3, n_servers=2,
                                 n_slots=4).horizon(4)
    with pytest.raises(ValueError, match="stacked"):
        scenarios.sweep(single)


# ---------------------------------------------------------------------------
# Sharded execution (4 virtual CPU devices in a subprocess — XLA_FLAGS must
# be set before jax initializes, hence the subprocess)
# ---------------------------------------------------------------------------

_SHARD_SCRIPT = textwrap.dedent("""
    import jax
    import numpy as np
    from repro import scenarios

    assert len(jax.devices()) == 4, jax.devices()
    s = scenarios.suite(n_cameras=4, n_slots=6, n_servers=2,
                        mean_bandwidth_hz=15e6, mean_compute_flops=20e12)
    vmap_ = scenarios.sweep(s, backend="vmap", devices=jax.devices()[:1])
    fleet = scenarios.sweep(s, backend="fleet")
    shard = scenarios.sweep(s, backend="shard_map")
    assert vmap_.backend == "vmap" and fleet.backend == "fleet[4]" \\
        and shard.backend == "shard_map[4]", \\
        (vmap_.backend, fleet.backend, shard.backend)
    for p in scenarios.POLICIES:
        # fleet runs the identical per-block executable as the vmap
        # fallback: summaries agree to float32 ulp, decisions exactly.
        np.testing.assert_allclose(fleet.aopi[p], vmap_.aopi[p],
                                   rtol=1e-6, atol=1e-8, err_msg=p)
        np.testing.assert_allclose(fleet.acc[p], vmap_.acc[p],
                                   rtol=1e-6, atol=1e-8, err_msg=p)
        np.testing.assert_allclose(fleet.q[p], vmap_.q[p],
                                   rtol=1e-6, atol=1e-7, err_msg=p)
        # shard_map compiles a distinct num_partitions>1 XLA module; fp
        # rounding may flip knife-edge discrete allocations, so parity is
        # statistical: per-scenario horizon means.
        np.testing.assert_allclose(shard.mean_aopi(p), vmap_.mean_aopi(p),
                                   rtol=0.08, atol=1e-6, err_msg=p)
        np.testing.assert_allclose(shard.mean_acc(p), vmap_.mean_acc(p),
                                   rtol=0.05, atol=1e-6, err_msg=p)
    # sharded runs are themselves deterministic.
    shard2 = scenarios.sweep(s, backend="shard_map")
    for p in scenarios.POLICIES:
        np.testing.assert_array_equal(shard.aopi[p], shard2.aopi[p])
    print("SHARD-OK")
""")


def test_shard_map_and_fleet_match_vmap_on_four_virtual_devices():
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=4").strip()
    env["JAX_PLATFORMS"] = "cpu"
    src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + \
        env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", _SHARD_SCRIPT],
                          capture_output=True, text=True, env=env,
                          timeout=1200)
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "SHARD-OK" in proc.stdout


# ---------------------------------------------------------------------------
# report.robustness aggregation math (hand-built inputs)
# ---------------------------------------------------------------------------

def _hand_sweep(measured=False):
    """Two families ('a' x2 scenarios, 'b' x1), one policy, known values."""
    aopi_ = np.array([[1.0, 3.0],     # family a, scenario 0
                      [2.0, 4.0],     # family a, scenario 1
                      [10.0, 30.0]])  # family b
    acc = np.array([[0.5, 0.7], [0.6, 0.8], [0.9, 0.9]])
    kw = {}
    if measured:
        kw = dict(measured_aopi={"lbcd": aopi_ * 1.5},
                  predicted_aopi={"lbcd": aopi_})
    from repro.scenarios.runner import SweepResult
    return SweepResult(
        names=["a0", "a1", "b0"], families=["a", "a", "b"],
        policies=["lbcd"], v=10.0, p_min=0.7, backend="vmap",
        aopi={"lbcd": aopi_}, acc={"lbcd": acc},
        q={"lbcd": np.zeros((3, 2))}, **kw)


def test_robustness_aggregation_math():
    rep = scenarios.robustness(_hand_sweep(), pct=50.0)
    a = rep.table["lbcd"]["a"]
    assert a.mean_aopi == pytest.approx(2.5)          # mean of 1,3,2,4
    assert a.pct_aopi == pytest.approx(2.5)           # median of 1,2,3,4
    assert a.worst_aopi == pytest.approx(4.0)
    assert a.mean_acc == pytest.approx(0.65)
    b = rep.table["lbcd"]["b"]
    assert b.mean_aopi == pytest.approx(20.0)
    assert b.worst_aopi == pytest.approx(30.0)
    assert rep.worst_family("lbcd")[0] == "b"
    assert a.measured_mean is None and a.divergence is None
    assert not rep.has_measured


def test_robustness_divergence_columns():
    rep = scenarios.robustness(_hand_sweep(measured=True), pct=50.0)
    assert rep.has_measured
    for fam, base_mean, base_worst in (("a", 2.5, 4.0), ("b", 20.0, 30.0)):
        s = rep.table["lbcd"][fam]
        assert s.measured_mean == pytest.approx(base_mean * 1.5)
        assert s.measured_worst == pytest.approx(base_worst * 1.5)
        assert s.mean_predicted == pytest.approx(base_mean)
        assert s.divergence == pytest.approx(0.5)     # measured = 1.5x
    fam, div = rep.worst_divergence("lbcd")
    assert div == pytest.approx(0.5)
    rows = rep.rows()
    assert len(rows) == 2 and len(rows[0]) == 10
    assert rows[0][:2] == ["lbcd", "a"]
    assert rows[0][9] == pytest.approx(0.5)           # divergence column
    txt = str(rep)
    assert "measured" in txt and "+50.00%" in txt
