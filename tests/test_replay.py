"""Data-plane replay: measured AoPI vs Theorems 1-2, determinism, and the
scan-engine serving planner (``AnalyticsService.plan_horizon``)."""
import jax
import numpy as np
import pytest

from repro import scenarios
from repro.core import aopi, binpack, lbcd, profiles
from repro.serving import replay, service
from repro.serving.service import AnalyticsService

DIMS = dict(n_cameras=5, n_slots=12, n_servers=2,
            mean_bandwidth_hz=15e6, mean_compute_flops=20e12)


# ---------------------------------------------------------------------------
# Statistical parity: the M/M/1 data plane converges to Theorems 1-2
# ---------------------------------------------------------------------------

def _measure_one(lam, mu, p, pol, seed, epoch_duration=40_000.0):
    meas, tel = service.measure_mm1(
        np.array([lam]), np.array([mu]), np.array([p]),
        np.array([pol], np.int32), epoch_duration=epoch_duration,
        frames_cap=400_000, seed=seed)
    return float(meas[0]), tel


@pytest.mark.parametrize("rho,pol,p", [
    (0.5, aopi.FCFS, 0.8), (0.5, aopi.LCFSP, 0.8),
    (0.75, aopi.FCFS, 0.6), (0.25, aopi.LCFSP, 0.9)])
def test_mm1_measurement_matches_closed_forms(rho, pol, p):
    """Always-run anchor points of the hypothesis sweep below (both
    policies, low/mid/high load)."""
    mu = 10.0
    meas, _ = _measure_one(rho * mu, mu, p, pol, seed=11)
    assert meas == pytest.approx(float(aopi.aopi(rho * mu, mu, p, pol)),
                                 rel=0.1)


def test_mm1_measurement_matches_closed_forms_hypothesis():
    """Measured AoPI from the event-driven plane == Theorem 1 (FCFS) /
    Theorem 2 (LCFSP) within CI bounds, over load factors and policies."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=10, deadline=None)
    @given(st.sampled_from([0.25, 0.5, 0.75]),
           st.sampled_from([aopi.FCFS, aopi.LCFSP]),
           st.sampled_from([0.45, 0.7, 0.9]),
           st.integers(0, 10_000))
    def inner(rho, pol, p, seed):
        mu = 10.0
        lam = rho * mu
        th = float(aopi.aopi(lam, mu, p, pol))
        meas, tel = _measure_one(lam, mu, p, pol, seed)
        # ~100-300k frames per draw: the sample mean's CI is a few percent.
        assert meas == pytest.approx(th, rel=0.1)
        # Telemetry sanity: unbiased plane, so measured rates track inputs.
        assert tel.acc_hat[0] == pytest.approx(p, abs=0.05)
        assert tel.lam_hat[0] == pytest.approx(lam, rel=0.05)

    inner()


def test_steady_replay_statistical_parity():
    """Fig. 14/15 at suite scale: replaying the steady AR(1) family, the
    plane's measured AoPI converges to the planner's closed form."""
    tab = scenarios.build("steady_ar1", DIMS)
    rep = replay.replay_tables(tab, "lbcd", epoch_duration=2000.0, seed=0)
    assert rep.measured.shape == rep.predicted.shape == (DIMS["n_slots"],)
    # Horizon mean within CI; every epoch individually close.
    assert rep.measured.mean() == pytest.approx(rep.predicted.mean(),
                                                rel=0.1)
    np.testing.assert_allclose(rep.measured, rep.predicted, rtol=0.3)
    # Per-stream agreement on average across epochs.
    ratio = np.concatenate(
        [r.per_stream_measured / np.maximum(r.per_stream_predicted, 1e-9)
         for r in rep.service.reports])
    assert np.median(ratio) == pytest.approx(1.0, abs=0.15)


# ---------------------------------------------------------------------------
# Determinism
# ---------------------------------------------------------------------------

def test_replay_is_bitwise_deterministic():
    tab = scenarios.build("gilbert_elliott", DIMS)
    kw = dict(n_epochs=6, epoch_duration=600.0, seed=3)
    a = replay.replay_tables(tab, "lbcd", **kw)
    b = replay.replay_tables(tab, "lbcd", **kw)
    np.testing.assert_array_equal(a.measured, b.measured)
    np.testing.assert_array_equal(a.predicted, b.predicted)
    c = replay.replay_tables(tab, "lbcd", n_epochs=6,
                             epoch_duration=600.0, seed=4)
    assert not np.array_equal(a.measured, c.measured)


# ---------------------------------------------------------------------------
# Scan-engine planner
# ---------------------------------------------------------------------------

def _service(plan_window=6, **kw):
    system = profiles.EdgeSystem(n_cameras=4, n_servers=2, n_slots=12,
                                 seed=7)
    ctrl = lbcd.LBCDController(system, v=10.0, p_min=0.6)
    return AnalyticsService(ctrl, mode="mm1", epoch_duration=400.0,
                            plan_window=plan_window, **kw), system, ctrl


def test_plan_horizon_matches_rollout():
    """The planner window IS one ``lbcd.rollout`` call on the horizon."""
    svc, system, ctrl = _service()
    res = svc.plan_horizon(6)
    direct = lbcd.rollout(system.horizon(6), ctrl.v, ctrl.queue.p_min,
                          q0=0.0)
    for got, want in zip(jax.tree.leaves(res), jax.tree.leaves(direct)):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=1e-7)


def _top_level_eqns(jaxpr):
    """Descend through single-eqn pjit wrappers to the body jaxpr."""
    while len(jaxpr.eqns) == 1 and jaxpr.eqns[0].primitive.name == "pjit":
        jaxpr = jaxpr.eqns[0].params["jaxpr"].jaxpr
    return jaxpr.eqns


def test_planner_is_single_scan_no_python_loop():
    """Jaxpr structure of the planner path: ONE lax.scan over the epochs
    at the top level, and an eqn count independent of the window length
    (a per-epoch Python loop would grow it linearly)."""
    system = profiles.EdgeSystem(n_cameras=4, n_servers=2, n_slots=12,
                                 seed=7)

    def plan(tables):
        return lbcd.rollout(tables, 10.0, 0.6)

    short = jax.make_jaxpr(plan)(system.horizon(4))
    long = jax.make_jaxpr(plan)(system.horizon(8))
    for jaxpr in (short.jaxpr, long.jaxpr):
        eqns = _top_level_eqns(jaxpr)
        scans = [e for e in eqns if e.primitive.name == "scan"]
        assert len(scans) == 1, [e.primitive.name for e in eqns]
    assert len(_top_level_eqns(short.jaxpr)) == \
        len(_top_level_eqns(long.jaxpr))


def test_service_scan_planner_commits_queue_and_windows():
    """Window boundaries replan; the virtual queue follows Eq. 44 from the
    consumed plan epochs; scan and step planners see the same horizon."""
    svc, system, ctrl = _service(plan_window=3)
    assert svc.planner == "scan"
    reps = svc.run(5)                      # spans two plan windows
    assert svc._plan_t0 == 3               # second window started at t=3
    assert ctrl.queue.q == pytest.approx(reps[-1].q)
    # Custom assignment functions are not scan-able -> legacy fallback.
    ctrl2 = lbcd.LBCDController(system, v=10.0, p_min=0.6,
                                assign_fn=lambda *a: binpack.first_fit(*a))
    svc2 = AnalyticsService(ctrl2, mode="mm1")
    assert svc2.planner == "step"


def test_step_only_controller_falls_back_to_step_planner():
    """A controller that only implements step(t) (no _rollout override)
    must get the legacy planner, not a NotImplementedError mid-run."""
    from repro.core import baselines
    system = profiles.EdgeSystem(n_cameras=3, n_servers=2, n_slots=6,
                                 seed=1)

    class StepOnly(baselines.BaselineController):
        def step(self, t, tables=None):
            return baselines.MINController(self.system).step(t, tables)

    svc = AnalyticsService(StepOnly(system), mode="mm1",
                           epoch_duration=300.0)
    assert svc.planner == "step"
    rep = svc.run_epoch(0)
    assert rep.measured_aopi > 0


def test_plane_rates_use_truth_on_short_bounded_horizons():
    """A bounded horizon shorter than the default plan window must still
    serve the data plane the unscaled truth (not silently degrade to the
    planner's beliefs), and epochs past it must fail loudly."""
    tab = scenarios.build("steady_ar1", {**DIMS, "n_slots": 4})
    system = replay.TableSystem(tab)
    ctrl = lbcd.LBCDController(system, v=10.0, p_min=0.6,
                               assign_fn=lambda *a: binpack.first_fit(*a))
    svc = AnalyticsService(ctrl, mode="mm1", epoch_duration=300.0)
    assert svc.planner == "step"          # custom assign_fn
    svc.run_epoch(0)
    assert svc._base_cache is not None    # truth horizon was built
    with pytest.raises(ValueError, match="exceeds"):
        svc.run_epoch(9)


def test_horizonless_system_falls_back_to_step_planner():
    """Duck-typed systems exposing only capacities/tables (the historical
    AnalyticsService contract) must keep the legacy planner, not crash
    mid-run inside the horizon cache."""
    base = profiles.EdgeSystem(n_cameras=3, n_servers=2, n_slots=6, seed=2)

    class NoHorizon:
        n_cameras = base.n_cameras
        capacities = base.capacities
        tables = base.tables

    svc = AnalyticsService(lbcd.LBCDController(NoHorizon(), v=10.0,
                                               p_min=0.6),
                           mode="mm1", epoch_duration=300.0)
    assert svc.planner == "step"
    assert svc.run_epoch(0).measured_aopi > 0


def test_sweep_rejects_unknown_dataplane_params():
    s = scenarios.suite(["steady_ar1"], **{**DIMS, "n_slots": 4})
    with pytest.raises(ValueError, match="unknown dataplane_params.*epochs"):
        scenarios.sweep(s, dataplane=True,
                        dataplane_params=dict(epochs=2),
                        devices=jax.devices()[:1])


def test_baseline_controllers_ride_the_scan_planner():
    tab = scenarios.build("steady_ar1", DIMS)
    for policy in ("min", "dos", "jcab"):
        rep = replay.replay_tables(tab, policy, n_epochs=4,
                                   epoch_duration=400.0)
        svc = rep.service
        assert svc.planner == "scan"
        assert np.isfinite(rep.measured).all() and (rep.measured > 0).all()


# ---------------------------------------------------------------------------
# Telemetry feedback into the next planning window
# ---------------------------------------------------------------------------

def test_telemetry_scales_are_applied_to_window():
    svc, system, ctrl = _service(telemetry_gain=0.5)
    svc._acc_scale[:] = 0.8
    base = svc._base_window(0, 4)
    win = svc._window_tables(0, 4)
    np.testing.assert_allclose(
        np.asarray(win.acc),
        np.clip(np.asarray(base.acc) * 0.8, 1e-3, 1.0), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(win.eff), np.asarray(base.eff),
                               rtol=1e-6)


def test_telemetry_pulls_biased_belief_back_to_truth():
    """Start the planner with a wrong link-efficiency belief; measured
    arrival rates must drag the scale back toward 1 (the truth)."""
    svc, system, ctrl = _service(plan_window=2, telemetry_gain=0.5)
    svc._eff_scale[:] = 0.6
    svc.run(8)
    assert (svc._eff_scale > 0.75).all()
    assert (svc._eff_scale < 1.4).all()
    # Gain 0 keeps beliefs frozen.
    svc0, *_ = _service(plan_window=2, telemetry_gain=0.0)
    svc0.run(4)
    np.testing.assert_array_equal(svc0._acc_scale, 1.0)


def test_replay_with_telemetry_replans_in_windows():
    """A feedback replay must replan so telemetry can re-enter: the
    default plan window shrinks below the horizon when gain > 0."""
    tab = scenarios.build("steady_ar1", DIMS)
    rep = replay.replay_tables(tab, "lbcd", epoch_duration=400.0,
                               telemetry_gain=0.5)
    assert rep.service.plan_window == min(8, DIMS["n_slots"])
    assert not np.array_equal(rep.service._acc_scale,
                              np.ones(DIMS["n_cameras"]))
    # Without feedback the whole horizon is one dispatch.
    rep0 = replay.replay_tables(tab, "lbcd", epoch_duration=400.0)
    assert rep0.service.plan_window == DIMS["n_slots"]


# ---------------------------------------------------------------------------
# Suite-level replay + the dataplane sweep (acceptance criterion)
# ---------------------------------------------------------------------------

def test_sweep_dataplane_reports_all_families():
    """sweep(dataplane=True) -> measured-vs-predicted robustness for every
    registered family."""
    s = scenarios.suite(**DIMS)
    n_replay = 4
    res = scenarios.sweep(
        s, v=10.0, p_min=0.7, devices=jax.devices()[:1], dataplane=True,
        dataplane_params=dict(n_epochs=n_replay, epoch_duration=400.0))
    k = s.n_scenarios
    for p in res.policies:
        assert res.measured_aopi[p].shape == (k, n_replay)
        assert res.predicted_aopi[p].shape == (k, n_replay)
        assert np.isfinite(res.measured_aopi[p]).all()
        assert (res.measured_aopi[p] > 0).all()
        assert np.isfinite(res.divergence(p)).all()
    rep = scenarios.robustness(res)
    assert rep.has_measured
    assert set(rep.families) == set(s.families)
    assert len(set(rep.families)) >= 6
    for p in res.policies:
        for f in rep.families:
            st = rep.table[p][f]
            assert st.measured_mean is not None and st.measured_mean > 0
            assert st.divergence is not None
        fam, div = rep.worst_divergence(p)
        assert fam in rep.families and np.isfinite(div)
    assert len(rep.rows()[0]) == 10
    txt = str(rep)
    assert "measured" in txt and "diverge" in txt
    # Truncated replay (4 of 12 slots) is flagged so the side-by-side
    # blocks are not read as covering the same epochs.
    assert rep.replay_slots == n_replay and rep.total_slots == 12
    assert f"first {n_replay}/12 slots" in txt


def test_sweep_without_dataplane_has_no_measured_columns():
    s = scenarios.suite(["steady_ar1"], **{**DIMS, "n_slots": 4})
    res = scenarios.sweep(s, devices=jax.devices()[:1])
    assert res.measured_aopi is None
    with pytest.raises(ValueError, match="dataplane"):
        res.divergence("lbcd")
    rep = scenarios.robustness(res)
    assert not rep.has_measured
    assert len(rep.rows()[0]) == 6
    with pytest.raises(ValueError, match="measured"):
        rep.worst_divergence("lbcd")


# ---------------------------------------------------------------------------
# Batched data plane on the hot path (acceptance criterion)
# ---------------------------------------------------------------------------

def test_replay_suite_hot_path_is_batched(monkeypatch):
    """``replay_suite`` over ALL registered families must never fall into
    per-stream Python-loop simulation: the numpy oracle is monkeypatched
    to explode, and the batched engine's dispatch counter must show
    exactly ONE device dispatch per (policy, scenario) plan window."""
    from repro.core import queues

    def _boom(*a, **k):
        raise AssertionError("per-stream loop simulation on the hot path")

    monkeypatch.setattr(queues, "simulate", _boom)
    monkeypatch.setattr(queues, "simulate_fcfs", _boom)
    monkeypatch.setattr(queues, "simulate_lcfsp", _boom)
    s = scenarios.suite(**DIMS)
    assert len(set(s.families)) >= 6
    before = queues.BATCH_DISPATCHES
    res = replay.replay_suite(s, n_epochs=3, epoch_duration=300.0)
    dispatches = queues.BATCH_DISPATCHES - before
    # telemetry_gain=0 -> one plan window per (policy, scenario), each
    # measured as one [E, N, F] dispatch.
    assert dispatches == s.n_scenarios * len(res.policies)
    for p in res.policies:
        assert np.isfinite(res.measured[p]).all()
        assert (res.measured[p] > 0).all()


# ---------------------------------------------------------------------------
# Non-exponential delay models: drift + telemetry closing the gap
# ---------------------------------------------------------------------------

def test_uniform_delays_drift_from_theorems_and_telemetry_closes_gap():
    """§III-B regime: uniform delays with M/M/1 means make measured AoPI
    diverge from the Theorem 1/2 predictions; with ``telemetry_gain > 0``
    the AoPI residual scale calibrates the next windows' predictions and
    shrinks the gap."""
    tab = scenarios.build("steady_ar1", DIMS)
    rep0 = replay.replay_tables(tab, "lbcd", epoch_duration=600.0, seed=0,
                                delay_model="uniform")
    div0 = rep0.service.divergences
    # mm1 replay of the same scenario stays unbiased...
    rep_mm1 = replay.replay_tables(tab, "lbcd", epoch_duration=600.0,
                                   seed=0)
    assert abs(np.mean(rep_mm1.service.divergences)) < 0.05
    # ...while the uniform plane visibly drifts from the closed forms.
    assert abs(np.mean(div0)) > 0.05
    # Telemetry feedback (replanning windows) calibrates the gap away.
    rep1 = replay.replay_tables(tab, "lbcd", epoch_duration=600.0, seed=0,
                                delay_model="uniform", telemetry_gain=0.7,
                                plan_window=2)
    tail0 = np.abs(div0[-4:]).mean()
    tail1 = np.abs(rep1.service.divergences[-4:]).mean()
    assert tail1 < tail0 * 0.6
    assert rep1.delay_model == "uniform"


def test_gamma_delays_drift_check():
    tab = scenarios.build("steady_ar1", {**DIMS, "n_slots": 6})
    rep = replay.replay_tables(tab, "lbcd", epoch_duration=600.0, seed=0,
                               delay_model="gamma")
    assert abs(np.mean(rep.service.divergences)) > 0.03
    assert np.isfinite(rep.measured).all()


def test_service_rejects_unknown_delay_model():
    system = profiles.EdgeSystem(n_cameras=3, n_servers=2, n_slots=6,
                                 seed=0)
    ctrl = lbcd.LBCDController(system, v=10.0, p_min=0.6)
    with pytest.raises(ValueError, match="delay_model"):
        AnalyticsService(ctrl, delay_model="pareto")
    # "auto" is a service-level sentinel, not a plane family: the service
    # accepts it (fitted selector), the plane does not.
    with pytest.raises(ValueError, match="delay_model"):
        service.measure_mm1(np.ones(1), np.ones(1), np.ones(1) * 0.5,
                            np.zeros(1), delay_model="auto")


# ---------------------------------------------------------------------------
# Divergence-triggered replanning
# ---------------------------------------------------------------------------

def test_divergence_triggered_replanning_cuts_windows():
    """With a hair-trigger threshold every epoch's (nonzero) divergence
    cuts the rest of the plan window, so the planner re-runs each epoch;
    without a threshold (or without remaining epochs) windows never cut."""
    svc, system, ctrl = _service(plan_window=6, telemetry_gain=0.3,
                                 replan_threshold=1e-9)
    svc.run(5)
    assert svc.early_replans == [1, 2, 3, 4, 5]
    assert svc._plan_t0 == 4               # replanned at every epoch
    # No threshold -> fixed windows (the PR-4 behaviour).
    svc0, *_ = _service(plan_window=6, telemetry_gain=0.3)
    svc0.run(5)
    assert svc0.early_replans == [] and svc0._plan_t0 == 0
    # A loose threshold on a well-modeled plane never triggers.
    svc1, *_ = _service(plan_window=6, replan_threshold=5.0)
    svc1.run(5)
    assert svc1.early_replans == []
    # A one-epoch window has nothing left to cut.
    svc2, *_ = _service(plan_window=1, replan_threshold=1e-9)
    svc2.run(3)
    assert svc2.early_replans == []


def test_replay_threads_replan_threshold():
    tab = scenarios.build("steady_ar1", {**DIMS, "n_slots": 6})
    rep = replay.replay_tables(tab, "lbcd", epoch_duration=400.0,
                               telemetry_gain=0.5, plan_window=4,
                               replan_threshold=1e-9)
    assert rep.service.early_replans != []
    assert np.isfinite(rep.measured).all()


# ---------------------------------------------------------------------------
# Per-delay-model divergence columns in the sweep/report
# ---------------------------------------------------------------------------

def test_sweep_dataplane_multi_delay_model():
    s = scenarios.suite(["steady_ar1", "server_outage"],
                        **{**DIMS, "n_slots": 4})
    res = scenarios.sweep(
        s, devices=jax.devices()[:1], dataplane=True,
        dataplane_params=dict(n_epochs=2, epoch_duration=300.0,
                              delay_model=("mm1", "uniform")))
    assert res.delay_models == ("mm1", "uniform")
    assert set(res.measured_by_model) == {"mm1", "uniform"}
    for p in res.policies:
        np.testing.assert_array_equal(res.measured_aopi[p],
                                      res.measured_by_model["mm1"][p])
        assert np.isfinite(res.divergence(p, "uniform")).all()
    with pytest.raises(ValueError, match="not replayed"):
        res.divergence("lbcd", "gamma")
    rep = scenarios.robustness(res)
    assert rep.delay_models == ("mm1", "uniform")
    # 6 closed-form + 4 measured + 1 extra divergence column.
    assert len(rep.rows()[0]) == 11
    for p in res.policies:
        for f in rep.families:
            dm = rep.table[p][f].divergence_models
            assert set(dm) == {"mm1", "uniform"}
            assert dm["mm1"] == pytest.approx(rep.table[p][f].divergence)
    txt = str(rep)
    assert "div:uniform" in txt and "delay model" in txt


def test_sweep_dataplane_single_uniform_model():
    s = scenarios.suite(["steady_ar1"], **{**DIMS, "n_slots": 4})
    res = scenarios.sweep(
        s, devices=jax.devices()[:1], dataplane=True,
        dataplane_params=dict(n_epochs=2, epoch_duration=300.0,
                              delay_model="uniform"))
    assert res.delay_models == ("uniform",)
    rep = scenarios.robustness(res)
    assert len(rep.rows()[0]) == 10        # no extra columns
    assert "delay model(s): uniform" in str(rep)


# ---------------------------------------------------------------------------
# TableSystem guard rails
# ---------------------------------------------------------------------------

def test_table_system_rejects_stacked_and_overlong():
    s = scenarios.suite(["steady_ar1", "server_outage"],
                        **{**DIMS, "n_slots": 4})
    with pytest.raises(ValueError, match="ONE scenario"):
        replay.TableSystem(s.tables)
    tab = scenarios.build("steady_ar1", {**DIMS, "n_slots": 4})
    sys_ = replay.TableSystem(tab)
    with pytest.raises(ValueError, match="exceeds"):
        sys_.horizon(9)
    with pytest.raises(ValueError, match="exceeds"):
        replay.replay_tables(tab, "lbcd", n_epochs=9)
    with pytest.raises(ValueError, match="unknown policy"):
        replay.replay_tables(tab, "nope")


def test_horizon_window_slices_time_axes():
    tab = scenarios.build("snr_mobility", DIMS)      # time-varying eff
    win = tab.window(3, 7)
    assert win.n_slots == 4
    np.testing.assert_array_equal(np.asarray(win.acc),
                                  np.asarray(tab.acc[3:7]))
    np.testing.assert_array_equal(np.asarray(win.eff),
                                  np.asarray(tab.eff[3:7]))
    np.testing.assert_array_equal(np.asarray(win.xi), np.asarray(tab.xi))
    static = scenarios.build("steady_ar1", DIMS)
    assert static.window(0, 5).eff.ndim == static.eff.ndim
    with pytest.raises(ValueError, match="window"):
        tab.window(8, 20)
