"""Water-filling vs interior-point allocators: KKT + properties."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import allocate, aopi


def _setup(n, s, seed=0, lcfsp_frac=0.5):
    rng = np.random.default_rng(seed)
    k = rng.uniform(1e-6, 5e-6, n)          # lam per Hz
    p = rng.uniform(0.3, 0.95, n)
    pol = (rng.random(n) < lcfsp_frac).astype(np.int32)
    mu = rng.uniform(5.0, 40.0, n)
    server_id = rng.integers(0, s, n).astype(np.int32)
    budgets = rng.uniform(2e7, 5e7, s)
    return (jnp.asarray(k, jnp.float32), jnp.asarray(p, jnp.float32),
            jnp.asarray(pol), jnp.asarray(mu, jnp.float32),
            jnp.asarray(server_id), jnp.asarray(budgets, jnp.float32))


def _obj_bandwidth(b, k, p, pol, mu):
    lam = np.maximum(np.asarray(b) * np.asarray(k), 1e-9)
    a = np.where(np.asarray(pol) == 1,
                 np.asarray(aopi.aopi_lcfsp(lam, mu, p)),
                 np.asarray(aopi.aopi_fcfs(
                     jnp.minimum(jnp.asarray(lam), 0.999 * mu), mu, p)))
    return a.sum()


def test_bandwidth_budget_respected():
    k, p, pol, mu, sid, B = _setup(12, 3)
    b = allocate.waterfill_bandwidth(k, p, pol, mu, sid, B, n_servers=3)
    b = np.asarray(b)
    assert (b > 0).all()
    for s in range(3):
        assert b[np.asarray(sid) == s].sum() <= float(B[s]) * 1.001


def test_compute_budget_respected_and_stability():
    rng = np.random.default_rng(1)
    n, s = 10, 2
    inv_xi = jnp.asarray(rng.uniform(1e-12, 5e-12, n), jnp.float32)
    p = jnp.asarray(rng.uniform(0.3, 0.95, n), jnp.float32)
    pol = jnp.asarray((rng.random(n) < 0.5).astype(np.int32))
    lam = jnp.asarray(rng.uniform(1.0, 10.0, n), jnp.float32)
    sid = jnp.asarray(rng.integers(0, s, n).astype(np.int32))
    # Budgets large enough that the FCFS stability floors are feasible
    # (the config-selection step guarantees this in the full controller;
    # infeasible instances get documented best-effort scaling instead).
    C = jnp.asarray(rng.uniform(3e13, 8e13, s), jnp.float32)
    c = np.asarray(allocate.waterfill_compute(inv_xi, p, pol, lam, sid, C,
                                              n_servers=s))
    assert (c > 0).all()
    for j in range(s):
        assert c[np.asarray(sid) == j].sum() <= float(C[j]) * 1.001
    mu = c * np.asarray(inv_xi)
    fcfs = np.asarray(pol) == 0
    assert (mu[fcfs] > np.asarray(lam)[fcfs]).all()   # constraint (10)


def test_waterfill_kkt_equal_marginals():
    """At the optimum, active (uncapped) cameras on one server share the
    same marginal -dA/db (the dual nu_s)."""
    k, p, pol, mu, sid, B = _setup(9, 1, seed=3, lcfsp_frac=1.0)
    b = allocate.waterfill_bandwidth(k, p, pol, mu, sid, B, n_servers=1)
    lam = np.asarray(b) * np.asarray(k)
    h = (1.0 + 1.0 / np.asarray(p)) / lam**2 * np.asarray(k)  # -dA/db
    assert h.std() / h.mean() < 0.02


def test_interior_point_matches_waterfill_bandwidth():
    k, p, pol, mu, sid, B = _setup(8, 2, seed=5)
    b_wf = np.asarray(allocate.waterfill_bandwidth(
        k, p, pol, mu, sid, B, n_servers=2))
    b_ip = np.asarray(allocate.interior_point_bandwidth(
        k, p, pol, mu, sid, B, n_servers=2))
    f_wf = _obj_bandwidth(b_wf, k, p, pol, mu)
    f_ip = _obj_bandwidth(b_ip, k, p, pol, mu)
    # Same optimum to <0.5% in objective value.
    assert f_ip == pytest.approx(f_wf, rel=5e-3)


def test_interior_point_matches_waterfill_compute():
    rng = np.random.default_rng(7)
    n, s = 8, 2
    inv_xi = jnp.asarray(rng.uniform(1e-12, 5e-12, n), jnp.float32)
    p = jnp.asarray(rng.uniform(0.3, 0.95, n), jnp.float32)
    pol = jnp.asarray((rng.random(n) < 0.5).astype(np.int32))
    lam = jnp.asarray(rng.uniform(1.0, 8.0, n), jnp.float32)
    sid = jnp.asarray(rng.integers(0, s, n).astype(np.int32))
    C = jnp.asarray(rng.uniform(3e13, 8e13, s), jnp.float32)

    def obj(c):
        mu = np.maximum(np.asarray(c) * np.asarray(inv_xi), 1e-9)
        a = np.where(np.asarray(pol) == 1,
                     np.asarray(aopi.aopi_lcfsp(lam, mu, p)),
                     np.asarray(aopi.aopi_fcfs(
                         lam, jnp.maximum(jnp.asarray(mu),
                                          np.asarray(lam) / 0.999), p)))
        return a.sum()

    c_wf = allocate.waterfill_compute(inv_xi, p, pol, lam, sid, C,
                                      n_servers=s)
    c_ip = allocate.interior_point_compute(inv_xi, p, pol, lam, sid, C,
                                           n_servers=s)
    assert obj(c_ip) == pytest.approx(obj(c_wf), rel=5e-3)


def test_waterfill_beats_equal_split():
    k, p, pol, mu, sid, B = _setup(10, 2, seed=11)
    b = allocate.waterfill_bandwidth(k, p, pol, mu, sid, B, n_servers=2)
    counts = np.bincount(np.asarray(sid), minlength=2)
    eq = np.asarray(B)[np.asarray(sid)] / counts[np.asarray(sid)]
    assert _obj_bandwidth(b, k, p, pol, mu) <= \
        _obj_bandwidth(eq, k, p, pol, mu) + 1e-6


def test_property_budget_and_positivity():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=20, deadline=None)
    @given(st.integers(2, 12), st.integers(0, 10_000))
    def inner(n, seed):
        k, p, pol, mu, sid, B = _setup(n, 2, seed=seed)
        b = np.asarray(allocate.waterfill_bandwidth(
            k, p, pol, mu, sid, B, n_servers=2))
        assert np.isfinite(b).all() and (b >= 0).all()
        for s in range(2):
            m = np.asarray(sid) == s
            if m.any():
                assert b[m].sum() <= float(B[s]) * 1.005
    inner()


def test_property_more_budget_never_hurts():
    """Objective is monotone non-increasing in the budget."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=15, deadline=None)
    @given(st.integers(0, 10_000))
    def inner(seed):
        k, p, pol, mu, sid, B = _setup(6, 1, seed=seed, lcfsp_frac=1.0)
        b1 = allocate.waterfill_bandwidth(k, p, pol, mu, sid, B, n_servers=1)
        b2 = allocate.waterfill_bandwidth(k, p, pol, mu, sid, B * 2.0,
                                          n_servers=1)
        assert _obj_bandwidth(b2, k, p, pol, mu) <= \
            _obj_bandwidth(b1, k, p, pol, mu) * 1.001
    inner()
