"""Serving: scheduler semantics, AoPI tracker, engine, LBCD-driven service."""
import dataclasses

import jax
import numpy as np
import pytest

from repro import configs
from repro.core import aopi, lbcd, profiles, queues
from repro.models import build
from repro.models.common import init_params
from repro.serving import (AnalyticsService, AoPITracker, Engine, Frame,
                           StreamQueue)
from repro.serving.scheduler import FCFS, LCFSP


def test_fcfs_queue_order():
    q = StreamQueue(0, FCFS)
    for i in range(3):
        assert not q.on_arrival(Frame(0, i * 1.0, i * 1.0 + 0.1, seq=i))
    assert [q.pop().seq for _ in range(3)] == [0, 1, 2]


def test_lcfsp_preempts_and_keeps_only_latest():
    q = StreamQueue(0, LCFSP)
    q.on_arrival(Frame(0, 0.0, 0.1, seq=0))
    preempt = q.on_arrival(Frame(0, 1.0, 1.1, seq=1))
    assert preempt
    assert len(q) == 1 and q.pop().seq == 1


def test_aopi_tracker_matches_offline_integration():
    """Online tracker == queues._integrate_age on an in-order trace
    (completions preserve generation order, as in FCFS/LCFSP queues —
    the offline integrator's domain)."""
    rng = np.random.default_rng(0)
    n_ev = 200
    gen = np.sort(rng.uniform(0, 100, n_ev))
    done = np.maximum.accumulate(gen + rng.uniform(0.1, 2.0, n_ev)) \
        + np.linspace(0, 1e-3, n_ev)
    acc = rng.random(n_ev) < 0.7
    horizon = float(done[-1] + 1.0)
    expect = queues._integrate_age(gen, done, acc, horizon)
    tr = AoPITracker(1)
    for g, d, a in zip(gen, done, acc):
        tr.on_result(0, g, bool(a), float(d))
    assert tr.mean_aopi(0, horizon) == pytest.approx(expect, rel=1e-9)


def _tiny_engine(n_lanes=4, decode_tokens=2):
    cfg = configs.get("qwen2.5-3b").reduced()
    model = build(cfg)
    params = init_params(model.template(), jax.random.PRNGKey(0))
    return Engine(model, params, n_lanes=n_lanes, max_len=64,
                  decode_tokens=decode_tokens), cfg


def test_engine_admit_decode_complete():
    eng, cfg = _tiny_engine()
    f = Frame(3, 0.0, 0.0)
    assert eng.admit(f, np.arange(2, 10, dtype=np.int32))
    assert eng.utilization == 0.25
    done = []
    for _ in range(5):
        done += eng.decode_tick()
        if done:
            break
    assert done and done[0].stream_id == 3
    assert len(done[0].tokens) == 3          # prefill token + 2 decode
    assert eng.utilization == 0.0


def test_engine_preemption_frees_lane():
    eng, cfg = _tiny_engine(n_lanes=2, decode_tokens=50)
    eng.admit(Frame(1, 0.0, 0.0), np.arange(2, 8, dtype=np.int32))
    eng.admit(Frame(2, 0.0, 0.0), np.arange(2, 8, dtype=np.int32))
    assert not eng.free_lanes()
    assert eng.preempt_stream(1) == 1
    assert len(eng.free_lanes()) == 1
    done = eng.decode_tick()                 # stream 2 still running
    assert done == []


def test_engine_batched_decode_matches_sequential():
    """Two lanes decoding together produce the same tokens as alone."""
    eng1, _ = _tiny_engine(n_lanes=1, decode_tokens=4)
    toks_a = np.arange(2, 12, dtype=np.int32)
    eng1.admit(Frame(0, 0, 0), toks_a)
    out_solo = None
    for _ in range(6):
        r = eng1.decode_tick()
        if r:
            out_solo = r[0].tokens
            break
    eng2, _ = _tiny_engine(n_lanes=2, decode_tokens=4)
    eng2.admit(Frame(0, 0, 0), toks_a)
    eng2.admit(Frame(1, 0, 0), np.arange(30, 45, dtype=np.int32))
    outs = {}
    for _ in range(6):
        for r in eng2.decode_tick():
            outs[r.stream_id] = r.tokens
    np.testing.assert_array_equal(outs[0], out_solo)


def test_service_measured_matches_closed_form():
    """Fig. 14/15 analog: data-plane AoPI ~= Theorems 1-2 prediction."""
    system = profiles.EdgeSystem(n_cameras=8, n_servers=2, n_slots=10,
                                 seed=3)
    ctrl = lbcd.LBCDController(system, v=10.0, p_min=0.6)
    svc = AnalyticsService(ctrl, mode="mm1", epoch_duration=3000.0)
    reps = svc.run(3)
    for r in reps:
        assert r.measured_aopi == pytest.approx(r.predicted_aopi, rel=0.25)
    # per-stream agreement on average
    ratio = np.concatenate([r.per_stream_measured /
                            np.maximum(r.per_stream_predicted, 1e-9)
                            for r in reps])
    assert np.median(ratio) == pytest.approx(1.0, abs=0.15)


def test_failover_reassigns_streams():
    from repro.training.failure import failover_assignment
    system = profiles.EdgeSystem(n_cameras=9, n_servers=3, n_slots=5,
                                 seed=5)
    ctrl = lbcd.LBCDController(system, v=10.0, p_min=0.6)
    dead = np.array([False, True, False])
    rec = failover_assignment(ctrl, 0, dead)
    assert not dead[rec.assign].any()


def test_straggler_monitor_flags_outlier():
    from repro.training.failure import StragglerMonitor
    mon = StragglerMonitor(n_workers=4, warmup=5)
    rng = np.random.default_rng(0)
    flagged = None
    for t in range(30):
        times = rng.normal(1.0, 0.02, 4)
        times[2] += 0.0 if t < 10 else 2.0      # worker 2 degrades
        flagged = mon.observe(times)
    assert flagged[2] and not flagged[[0, 1, 3]].any()
    w = mon.rebalance_weights()
    assert w[2] == w.min()
