"""Fault-storm demo: every fault kind at once, graceful degradation on.

1. Build the two fault scenario families (``camera_churn`` — a fleet
   mask threaded through every rollout engine, so churned-out cameras
   get exactly zero allocation — and ``correlated_fade`` — correlated
   multi-server backhaul fades), plus the steady AR(1) anchor.
2. Replay each through ``AnalyticsService`` under
   ``repro.faults.storm_plan``: camera churn, a server crash, a
   correlated fade, telemetry drop/delay/corruption, and solver faults
   staged to engage every rung of the graceful-degradation ladder
   (retry with backoff -> stale plan re-projected on the surviving
   fleet -> MIN fallback).
3. Verify the run the way CI does: measured AoPI finite everywhere, the
   fallback / degraded-epoch / retry / telemetry-gap counters nonzero,
   and each ``repro.obs`` counter exactly equal to its legacy service
   list (the reconciliation contract).
4. Print the degradation report: AoPI under faults vs fault-free, with
   recovery epochs, per (policy, fault kind).

    PYTHONPATH=src python examples/fault_storm.py [--smoke] [--policies lbcd,min]
"""
import argparse

import numpy as np

from repro import obs, scenarios
from repro.faults import storm_plan
from repro.serving.replay import replay_tables

COUNTERS = (
    ("service.fallback", lambda s: s.fallbacks),
    ("service.degraded_epoch", lambda s: s.degraded_epochs),
    ("service.plan_retry", lambda s: s.plan_failures),
    ("service.telemetry_gap", lambda s: s.telemetry_gaps),
)


def main(smoke: bool = False, policies: tuple = ("lbcd", "min")):
    obs.configure(enabled=True)
    dims = (dict(n_cameras=6, n_slots=16, n_servers=2,
                 mean_bandwidth_hz=15e6, mean_compute_flops=20e12)
            if smoke else dict(n_cameras=16, n_slots=32, n_servers=3))
    plan = storm_plan(dims["n_slots"], seed=0)
    print(f"storm plan: {len(plan.specs)} specs -> "
          f"{', '.join(s.kind for s in plan.specs)}\n")

    names = ["camera_churn", "correlated_fade", "steady_ar1"]
    totals = {name: 0 for name, _ in COUNTERS}
    for scen in names:
        tables = scenarios.build(scen, **dims)
        for policy in policies:
            rep = replay_tables(tables, policy, plan_window=4,
                                telemetry_gain=0.2, faults=plan)
            svc = rep.service
            assert np.isfinite(rep.measured).all(), \
                f"{scen}/{policy}: non-finite measured AoPI"
            counts = {name: len(get(svc)) for name, get in COUNTERS}
            for name in totals:
                totals[name] += counts[name]
            print(f"{scen:<16s} {policy:<5s} "
                  f"mean AoPI {float(rep.measured.mean()):.4f} | "
                  + " ".join(f"{n.split('.')[1]}={c}"
                             for n, c in counts.items()))

    # The reconciliation contract: every obs counter equals the summed
    # legacy lists, and the storm actually engaged the ladder.
    evs = obs.events()
    for name, total in totals.items():
        n_ev = sum(1 for e in evs if e.get("name") == name)
        n_ctr = sum(m.value for m in obs.registry()
                    if m.name == name + ".count")
        assert n_ev == n_ctr == total, \
            f"{name}: events={n_ev} counter={n_ctr} lists={total}"
    assert totals["service.fallback"] > 0, "storm engaged no fallback"
    assert totals["service.degraded_epoch"] > 0
    assert totals["service.telemetry_gap"] > 0
    print(f"\nreconciled: " + ", ".join(f"{n}={c}"
                                        for n, c in totals.items()))

    suite = scenarios.suite(names, **dims)
    n_epochs = 8 if smoke else 16
    print("\ndegradation report (faulted vs clean replay per kind):")
    print(scenarios.degradation(suite, policies=policies,
                                n_epochs=n_epochs, plan_window=4))


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny dimensions for CI smoke runs")
    ap.add_argument("--policies", default="lbcd,min",
                    help="comma-separated policies to storm")
    args = ap.parse_args()
    main(args.smoke, tuple(p for p in args.policies.split(",") if p))
