"""Failover demo: an island (model-parallel subgroup) dies mid-service and
LBCD's server-selection subproblem re-places its streams on the next epoch
(the paper's Algorithm 2 doubling as the fault-tolerance mechanism).

    PYTHONPATH=src python examples/failover_demo.py
"""
import numpy as np

from repro.core import lbcd, profiles
from repro.training.failure import failover_assignment


def main():
    system = profiles.EdgeSystem(n_cameras=16, n_servers=4, n_slots=12,
                                 seed=0)
    ctrl = lbcd.LBCDController(system, v=10.0, p_min=0.7)

    print("epoch 0-2: healthy islands")
    for t in range(3):
        rec = ctrl.step(t)
        load = np.bincount(rec.assign, minlength=4)
        print(f"  t={t} AoPI={rec.mean_aopi:.4f} island-load={load}")

    print("\nepoch 3: island 1 fails -> LBCD re-solves placement")
    dead = np.array([False, True, False, False])
    rec = failover_assignment(ctrl, 3, dead)
    load = np.bincount(rec.assign, minlength=4)
    print(f"  t=3 AoPI={rec.mean_aopi:.4f} island-load={load} "
          f"(island 1 drained)")
    assert load[1] == 0

    print("\nepoch 4: island restored")
    rec = ctrl.step(4)
    load = np.bincount(rec.assign, minlength=4)
    print(f"  t=4 AoPI={rec.mean_aopi:.4f} island-load={load}")


if __name__ == "__main__":
    main()
