"""End-to-end serving driver (the paper's kind): a small LM serves batched
frame-analysis requests from multiple streams while the LBCD controller
adapts per-stream configuration (model/fidelity/policy) each epoch.

Two data planes:
  * default      — M/M/1 event-driven plane at the controller's chosen
                   rates (validates the closed forms at service scale);
  * --engine     — a REAL continuous-batching engine running a reduced
                   qwen2.5 on CPU with LCFSP preemption at step boundaries.

    PYTHONPATH=src python examples/serve_e2e.py [--engine] [--epochs 6]
"""
import argparse

import numpy as np

from repro.core import lbcd, profiles
from repro.serving import AnalyticsService, Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", action="store_true")
    ap.add_argument("--epochs", type=int, default=6)
    ap.add_argument("--streams", type=int, default=12)
    args = ap.parse_args()

    system = profiles.EdgeSystem(
        n_cameras=args.streams, n_servers=2, n_slots=max(args.epochs, 8),
        mean_bandwidth_hz=12e6, mean_compute_flops=15e12, seed=0)
    ctrl = lbcd.LBCDController(system, v=10.0, p_min=0.7)

    if args.engine:
        import jax

        from repro import configs
        from repro.models import build
        from repro.models.common import init_params

        cfg = configs.get("qwen2.5-3b").reduced()
        model = build(cfg)
        params = init_params(model.template(), jax.random.PRNGKey(0))
        # The engine replay plane pins one lane per stream.
        eng = Engine(model, params, n_lanes=args.streams, max_len=96,
                     decode_tokens=2)
        svc = AnalyticsService(ctrl, mode="engine", engine=eng,
                               epoch_duration=3.0, engine_frames_cap=32)
    else:
        svc = AnalyticsService(ctrl, mode="mm1", epoch_duration=1500.0)

    print("epoch  predicted-AoPI  measured-AoPI  accuracy     q")
    for t in range(args.epochs):
        r = svc.run_epoch(t)
        print(f"{t:>5d}  {r.predicted_aopi:13.4f}  {r.measured_aopi:12.4f}"
              f"  {r.accuracy:8.3f}  {r.q:6.3f}")
    print(f"\nmean predicted {svc.mean_predicted:.4f} s | "
          f"mean measured {svc.mean_measured:.4f} s | "
          f"deviation {abs(svc.mean_predicted - svc.mean_measured) / max(svc.mean_measured, 1e-9):.1%}")


if __name__ == "__main__":
    main()
