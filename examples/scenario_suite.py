"""Scenario-suite quickstart: adversarial dynamics x every policy.

1. Build the full registered scenario suite (Gilbert-Elliott bursty
   channels, diurnal + flash-crowd load, server outages, camera SNR
   mobility, content bursts, plus the steady AR(1) anchor) as one stacked
   ``HorizonTables``.
2. Sweep LBCD and the MIN/DOS/JCAB baselines over the whole suite in one
   device-resident call per policy — shard_map-partitioned across every
   visible device (run with
   ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` to watch the
   sharded path on CPU), vmapped on one.
3. With ``--dataplane``, additionally replay every (policy, scenario)
   pair through the batched device-resident GI/G/1 data plane
   (``repro.serving.replay``) so the report shows *measured* AoPI next to
   the closed-form prediction, plus their divergence. ``--delay-model``
   picks the delay family from ``queues.DELAY_MODELS`` (``mm1`` is the
   paper's exponential model; ``uniform``/``gamma``/``lognormal``/
   ``weibull`` are the §III-B regimes where Theorems 1-2 visibly drift),
   or ``auto`` to let the service fit the family from its own telemetry.
4. With ``--engine`` (implies ``--dataplane``), climb to the truth
   ladder's third rung: every cell is also driven through the real
   continuous-batching ``serving.Engine``, and the report grows
   engine columns with per-rung divergences (engine vs GI/G/1 vs
   closed form).
5. Print the per-family robustness report and each policy's worst family
   (and, with ``--dataplane``, its worst model-vs-measurement gap).

6. With ``--obs DIR`` (or ``REPRO_OBS_DIR``), stream spans/metrics from
   the whole run into ``DIR`` (``trace.jsonl``, ``metrics.prom``,
   ``metrics.jsonl``, Perfetto-loadable ``trace.json``) and print where
   they landed — ``python -m repro.obs.report DIR`` then shows
   plans/sec and p99 plan/replan latency per policy x family.

    PYTHONPATH=src python examples/scenario_suite.py \
        [--smoke] [--dataplane] [--engine] \
        [--engine-backend des|scan|auto] \
        [--delay-model mm1|uniform|gamma|lognormal|weibull|auto] \
        [--obs DIR]
"""
import argparse

import jax

from repro import obs, scenarios, serving
from repro.core import queues


def main(smoke: bool = False, dataplane: bool = False,
         delay_model: str = "mm1", engine: bool = False,
         engine_backend: str = "scan", obs_dir: str | None = None):
    if obs_dir:
        obs.configure(run_dir=obs_dir)
    dataplane = dataplane or engine
    dims = (dict(n_cameras=6, n_slots=16, n_servers=2) if smoke
            else dict(n_cameras=16, n_slots=60, n_servers=3))
    s = scenarios.suite(**dims)
    print(f"suite: {s.n_scenarios} scenarios / "
          f"{len(set(s.families))} families -> {', '.join(s.names)}")

    dp_params = (dict(n_epochs=6, epoch_duration=400.0) if smoke
                 else dict(n_epochs=16, epoch_duration=600.0))
    dp_params["delay_model"] = delay_model
    if engine:
        dp_params["mode"] = "engine"
        if engine_backend == "des":
            # The DES pins one lane per stream and replays real decode
            # steps in Python, so bound its per-epoch work tightly.
            dp_params["engine_params"] = {"backend": "des",
                                          "frames_cap": 24 if smoke else 96}
        else:
            # The tick-scan backend replays the same engine as one
            # jitted lax.scan, so it runs at the full frames cap — the
            # effective per-epoch frame count is still sized by
            # queues.frames_budget from the offered load.
            dp_params["engine_params"] = {"backend": engine_backend}
        if smoke:
            dp_params["n_epochs"] = 3
            dp_params["epoch_duration"] = 120.0
    res = scenarios.sweep(s, v=10.0, p_min=0.7, dataplane=dataplane,
                          dataplane_params=dp_params)
    print(f"sweep backend: {res.backend} "
          f"({len(jax.devices())} visible device(s))"
          + (f"; data plane: {delay_model} x {dp_params['n_epochs']} "
             f"epochs" if dataplane else "")
          + (f"; rung 3: engine backend={engine_backend}" if engine
             else "") + "\n")

    rep = scenarios.robustness(res)
    print(rep)
    print()
    for policy in res.policies:
        fam, stats = rep.worst_family(policy)
        line = (f"{policy:<5s} worst family: {fam} "
                f"(worst-slot AoPI {stats.worst_aopi:.4f}, "
                f"p95 {stats.pct_aopi:.4f})")
        if dataplane:
            dfam, div = rep.worst_divergence(policy)
            line += f"; worst model-vs-measured gap: {dfam} ({div:+.2%})"
        print(line)
    if engine and rep.has_engine:
        print("\nengine rung present for all families:",
              all(rep.table[p][f].engine_mean is not None
                  for p in res.policies for f in rep.families))

    if obs_dir:
        paths = obs.write_artifacts(obs_dir)
        print(f"\nobs artifacts: {', '.join(sorted(paths.values()))}")
        print(f"dashboard: python -m repro.obs.report {obs_dir}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny dimensions for CI smoke runs")
    ap.add_argument("--dataplane", action="store_true",
                    help="replay each (policy, scenario) through the "
                         "batched data plane for measured-vs-predicted "
                         "AoPI")
    ap.add_argument("--engine", action="store_true",
                    help="also drive every cell through the real "
                         "continuous-batching engine (truth ladder rung "
                         "3; implies --dataplane)")
    ap.add_argument("--engine-backend", default="scan",
                    choices=serving.ENGINE_BACKENDS,
                    help="engine-rung executor: 'scan' (default) is the "
                         "device-resident tick-scan at the full frames "
                         "cap; 'des' replays the real host Engine at a "
                         "tightly-bounded cap; 'auto' picks by epoch "
                         "frame volume")
    ap.add_argument("--delay-model", default="mm1",
                    choices=queues.DELAY_MODELS + (queues.AUTO_DELAY_MODEL,),
                    help="data-plane delay family (non-exponential models "
                         "show how far Theorems 1-2 drift); 'auto' fits "
                         "the family from service telemetry")
    ap.add_argument("--obs", default=None, metavar="DIR",
                    help="write repro.obs artifacts (trace.jsonl, "
                         "metrics.prom/jsonl, Perfetto trace.json) here")
    args = ap.parse_args()
    main(args.smoke, args.dataplane, args.delay_model, args.engine,
         args.engine_backend, args.obs)
