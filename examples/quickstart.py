"""Quickstart: the paper's core loop in ~60 lines.

1. Validate the AoPI closed forms (Theorems 1-2) against the discrete-event
   oracle for one configuration.
2. Run the LBCD controller (device-resident scan rollout engine) on a small
   edge system and compare against the DOS / JCAB / MIN baselines.
3. Sweep the whole (V, P_min) hyperparameter grid as one vmapped call.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import aopi, baselines, lbcd, profiles, queues


def main():
    # --- 1. AoPI theory vs simulation --------------------------------
    lam, mu, p = 5.0, 10.0, 0.8
    print("Theorem 1 (FCFS):   A_F =",
          f"{float(aopi.aopi_fcfs(lam, mu, p)):.4f} s "
          f"(sim: {queues.simulate_fcfs(lam, mu, p, 200_000).mean_aopi:.4f})")
    print("Theorem 2 (LCFSP):  A_L =",
          f"{float(aopi.aopi_lcfsp(lam, mu, p)):.4f} s "
          f"(sim: {queues.simulate_lcfsp(lam, mu, p, 200_000).mean_aopi:.4f})")
    rho = lam / mu
    print(f"Theorem 3 threshold at rho={rho}: p* ="
          f" {float(aopi.policy_threshold(rho)):.3f} -> optimal policy for"
          f" p={p}: {'LCFSP' if aopi.optimal_policy(lam, mu, p) else 'FCFS'}")

    # --- 2. LBCD vs baselines ----------------------------------------
    def system():
        return profiles.EdgeSystem(n_cameras=20, n_servers=3, n_slots=25,
                                   mean_bandwidth_hz=15e6,
                                   mean_compute_flops=25e12, seed=0)

    print("\ncontroller     mean AoPI   mean accuracy")
    s = lbcd.LBCDController(system(), v=10.0, p_min=0.7).run(25)
    print(f"LBCD           {s.mean_aopi:9.4f}   {s.mean_acc:.3f}")
    for name in ("MIN", "DOS", "JCAB"):
        b = baselines.make(name, system()).run(25)
        print(f"{name:<14s} {b.mean_aopi:9.4f}   {b.mean_acc:.3f}")

    # --- 3. (V, P_min) grid: one vmapped device-resident rollout ------
    tables = system().horizon(25)
    vs = jnp.asarray([1.0, 10.0, 100.0])
    p_mins = jnp.asarray([0.7, 0.7, 0.7])
    grid = lbcd.rollout_grid(tables, vs, p_mins)
    print("\nV sweep (one vmapped rollout_grid call):")
    for g, v in enumerate(vs):
        print(f"  V={float(v):6.1f}  mean AoPI {float(grid.aopi[g].mean()):.4f}"
              f"  mean acc {float(grid.acc[g].mean()):.3f}")


if __name__ == "__main__":
    main()
