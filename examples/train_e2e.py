"""End-to-end training driver: train a ~100M-param qwen2.5-family model for
a few hundred steps on CPU, with checkpoint/restart.

    PYTHONPATH=src python examples/train_e2e.py [--steps 200] [--d-model 256]

A crash mid-run resumes from the last atomic checkpoint:
    PYTHONPATH=src python examples/train_e2e.py --resume
"""
import argparse
import dataclasses

from repro import configs
from repro.launch.train import run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--vocab", type=int, default=32000)
    ap.add_argument("--ckpt", default="/tmp/repro_train_e2e")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    # ~100M-param decoder-only (qwen family: GQA + qkv bias + SwiGLU).
    n_heads = max(args.d_model // 64, 2)
    cfg = dataclasses.replace(
        configs.get("qwen2.5-3b"),
        n_layers=args.layers, d_model=args.d_model,
        n_heads=n_heads, n_kv_heads=2 if n_heads % 2 == 0 else 1,
        d_ff=args.d_model * 4, vocab=args.vocab, head_dim=64,
        remat="none", fsdp=False, dtype="float32")
    from repro.models import build
    n = build(cfg).param_count()
    print(f"model: {n/1e6:.1f}M params, {args.layers}L d{args.d_model}")

    out = run(cfg, steps=args.steps, batch=args.batch, seq=args.seq,
              ckpt_dir=args.ckpt, ckpt_every=50, log_every=10,
              resume=args.resume, lr=1e-3)
    first = sum(out["losses"][:10]) / min(len(out["losses"]), 10)
    last = sum(out["losses"][-10:]) / min(len(out["losses"]), 10)
    print(f"\nloss {first:.3f} -> {last:.3f} over {args.steps} steps "
          f"({out['wall_s']:.0f}s)")


if __name__ == "__main__":
    main()
