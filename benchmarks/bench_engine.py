"""Engine-rung throughput (BENCH_engine): DES vs tick-scan.

Measures streams/sec of one engine-rung epoch at fleet sizes
N in {30, 300, 3000} for the two ``engine_backend`` implementations:

  * ``des``  — the PR-9 host discrete-event replay of the real
    continuous-batching ``serving.Engine`` (one Python heap event per
    arrival/completion/preemption);
  * ``scan`` — the PR-10 tick-scan (``serving.tick_plane``), the same
    epoch on the same pre-drawn randomness as ONE jitted ``lax.scan``
    over decode ticks (compile excluded: the dispatch shape is warmed
    up before timing).

Both backends see the *same* ``frames_cap`` per fleet size so the
comparison is event-for-event — the two replays are bitwise-identical,
only the executor differs. The cap shrinks with N to keep the DES arm
affordable; the scan's own full-cap regime (frames_cap=200_000) is what
``replay_suite(mode="engine", engine_backend="scan")`` runs in
production and is reported here as the extra ``scan_full_cap`` row per
N (no DES column — the DES cannot reach that regime).

The acceptance bar of PR 10 is >= 25x scan/des at N=3000.

The occupancy columns summarize the scan's per-lane busy fraction
(service time inside the horizon / horizon), the same statistic the
``engine.occupancy`` obs histogram tracks.
"""
import numpy as np

from repro.core import queues
from repro.serving import engine_plane, tick_plane
from repro.serving.engine import make_replay_engine

from .common import best_of, emit

EPOCH = 300.0          # the paper's 5-minute slot (seconds)

#: (n_streams, shared frames_cap for the des-vs-scan pair).
ARMS = ((30, 192), (300, 96), (3000, 24))


def _workload(n: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    lam = rng.uniform(0.2, 0.7, n)               # frames/s
    mu = np.full(n, 1.5)                         # rho in [0.13, 0.47]
    p = rng.uniform(0.6, 0.9, n)
    pol = (np.arange(n) % 2).astype(np.int64)    # half FCFS, half LCFSP
    return lam, mu, p, pol


def run(full: bool = False):
    repeats = 3 if full else 2
    rows = []
    for n, cap in ARMS:
        lam, mu, p, pol = _workload(n)
        kw = dict(epoch_duration=EPOCH, seed=0, t=0, frames_cap=cap)
        des_s = best_of(
            lambda: engine_plane.measure_engine_epoch(
                make_replay_engine(n), lam, mu, p, pol, **kw),
            repeats, block=False)
        out = tick_plane.measure_engine_epoch_scan(lam, mu, p, pol, **kw)
        scan_s = best_of(
            lambda: tick_plane.measure_engine_epoch_scan(
                lam, mu, p, pol, **kw),
            repeats, block=False)
        occ = out["occupancy"]
        rows.append([n, cap, n / des_s, n / scan_s, des_s / scan_s,
                     float(occ.mean()), float(np.percentile(occ, 95))])
        print(f"# N={n:<5d} cap={cap:<4d} des {n / des_s:9.0f} str/s | "
              f"scan {n / scan_s:9.0f} str/s | {des_s / scan_s:6.1f}x | "
              f"occ {occ.mean():.3f}", flush=True)
        # The production regime: full GI/G/1-parity cap, scan only —
        # queues.frames_budget sizes the effective tick count from the
        # offered load, exactly as AnalyticsService does per epoch.
        fcap = queues.frames_budget(float(lam.max()), EPOCH, 200_000)
        fkw = dict(epoch_duration=EPOCH, seed=0, t=0, frames_cap=fcap)
        fout = tick_plane.measure_engine_epoch_scan(lam, mu, p, pol, **fkw)
        fscan_s = best_of(
            lambda: tick_plane.measure_engine_epoch_scan(
                lam, mu, p, pol, **fkw),
            repeats, block=False)
        focc = fout["occupancy"]
        rows.append([n, fcap, None, n / fscan_s, None,
                     float(focc.mean()), float(np.percentile(focc, 95))])
        print(f"# N={n:<5d} cap={fcap:<4d} scan-only "
              f"{n / fscan_s:9.0f} str/s", flush=True)
    emit("BENCH_engine", rows,
         ["n_streams", "frames_cap", "des_streams_per_sec",
          "scan_streams_per_sec", "speedup", "occ_mean", "occ_p95"])
    return rows
