"""Fig. 12: controller execution time + memory vs camera count.

Also demonstrates §Scale-out: the vectorized per-slot solve stays in
milliseconds for thousands of streams.
"""
import tracemalloc

from repro.core import baselines, lbcd, profiles

from .common import emit, timer


def run(full: bool = False):
    counts = (10, 20, 50, 200, 1000, 10000) if full else (10, 20, 100, 1000)
    rows = []
    for n in counts:
        system = profiles.EdgeSystem(n_cameras=n, n_servers=3, n_slots=4)
        for name in ("LBCD", "DOS", "JCAB"):
            if name == "LBCD":
                ctrl = lbcd.LBCDController(system, v=10.0, p_min=0.7)
            else:
                ctrl = baselines.make(name, system)
            ctrl.step(0)                     # jit warmup
            tracemalloc.start()
            with timer() as t:
                ctrl.step(1)
            _, peak = tracemalloc.get_traced_memory()
            tracemalloc.stop()
            rows.append([n, name, t.elapsed, peak / 2**20])
    emit("fig12_overhead", rows,
         ["n_cameras", "method", "seconds_per_slot", "peak_mib"])
    return rows
