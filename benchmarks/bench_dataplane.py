"""Data-plane engine throughput (BENCH_dataplane): loop vs batched.

Measures streams/sec of one data-plane epoch at fleet sizes
N in {30, 300, 3000} for every delay family in ``queues.DELAY_MODELS``:

  * ``loop``    — the PR-4 per-stream numpy path
    (``service.measure_mm1_loop``), one ``queues.simulate`` per stream;
  * ``batched`` — the device-resident GI/G/1 engine
    (``service.measure_mm1`` -> ``queues.gi_g1_window``), all N streams
    in ONE jitted dispatch (compile excluded: the dispatch shape is
    warmed up before timing).

The workload is the service's low-rate fleet regime — event-triggered
cameras at 0.2-0.7 frames/s over the paper's 5-minute epochs, where the
PR-4 loop's cost is per-stream Python/RNG overhead (each stream is a
~200-frame numpy sim behind ~100 us of interpreter and generator setup)
while the batched engine amortizes the whole fleet into one scan.

The acceptance bar of PR 5 is >= 5x batched/loop at N=3000 (mm1).
"""
import numpy as np

from repro.core import queues
from repro.serving import service

from .common import best_of, emit

EPOCH = 300.0          # the paper's 5-minute slot (seconds)


def _workload(n: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    lam = rng.uniform(0.2, 0.7, n)               # frames/s
    mu = np.full(n, 1.5)                         # rho in [0.13, 0.47]
    p = rng.uniform(0.6, 0.9, n)
    pol = (np.arange(n) % 2).astype(np.int64)    # half FCFS, half LCFSP
    return lam, mu, p, pol


def run(full: bool = False):
    sizes = (30, 300, 3000)
    repeats = 3 if full else 2
    rows = []
    for n in sizes:
        lam, mu, p, pol = _workload(n)
        for dm in queues.DELAY_MODELS:
            kw = dict(epoch_duration=EPOCH, seed=0, t=0, delay_model=dm)
            loop_s = best_of(
                lambda: service.measure_mm1_loop(lam, mu, p, pol, **kw),
                repeats, block=False)
            service.measure_mm1(lam, mu, p, pol, **kw)     # compile
            bat_s = best_of(
                lambda: service.measure_mm1(lam, mu, p, pol, **kw),
                repeats, block=False)
            rows.append([n, dm, n / loop_s, n / bat_s, loop_s / bat_s])
            print(f"# N={n:<5d} {dm:<8s} loop {n / loop_s:9.0f} str/s | "
                  f"batched {n / bat_s:9.0f} str/s | "
                  f"{loop_s / bat_s:5.1f}x", flush=True)
    emit("BENCH_dataplane", rows,
         ["n_streams", "delay_model", "loop_streams_per_sec",
          "batched_streams_per_sec", "speedup"])
    return rows
