"""Fig. 9: AoPI + accuracy vs wireless bandwidth, all methods.

``sweep`` is the shared grid driver (also used by Figs. 10-11): it
pregenerates one ``HorizonTables`` per swept value and runs each method's
device-resident scan rollout. When every scenario has the same shapes (the
bandwidth/compute sweeps), the stack rolls out as **one vmapped call per
method**; shape-changing sweeps (camera count, Fig. 11) fall back to one
scan per value — still no per-slot host loop.
"""
import jax

from repro.core import baselines, lbcd, profiles

from .common import emit

METHODS = ("LBCD", "MIN", "DOS", "JCAB")

_ROLLOUTS = {
    "LBCD": lambda tables: lbcd.rollout(tables, 10.0, 0.7),
    "MIN": lambda tables: baselines.rollout_min(tables, 10.0),
    "DOS": lambda tables: baselines.rollout_dos(tables, 1.0),
    "JCAB": lambda tables: baselines.rollout_jcab(tables, 0.5),
}


def sweep(param_name, values, sys_kw_fn, slots):
    tables = [profiles.EdgeSystem(**sys_kw_fn(v)).horizon(slots)
              for v in values]
    shapes = {tuple(x.shape for x in jax.tree.leaves(t)) for t in tables}
    stacked = profiles.stack_horizons(tables) if len(shapes) == 1 else None

    results = {}
    for m in METHODS:
        fn = _ROLLOUTS[m]
        if stacked is not None:
            results[m] = jax.vmap(fn)(stacked)   # one call, all values
        else:
            results[m] = [fn(t) for t in tables]

    rows = []
    for val_i, val in enumerate(values):
        for m in METHODS:
            if stacked is not None:
                res = jax.tree.map(lambda x, i=val_i: x[i], results[m])
            else:
                res = results[m][val_i]
            rows.append([param_name, float(val), m, res.mean_aopi,
                         res.mean_acc])
    return rows


def run(full: bool = False):
    slots = 30 if full else 15
    vals = (10e6, 20e6, 30e6, 40e6, 50e6) if full else (10e6, 30e6, 50e6)
    rows = sweep(
        "bandwidth_hz", vals,
        lambda v: dict(n_cameras=30, n_servers=3, n_slots=slots,
                       mean_bandwidth_hz=v, mean_compute_flops=50e12),
        slots)
    emit("fig9_bandwidth", rows,
         ["param", "value", "method", "mean_aopi", "mean_acc"])
    return rows
