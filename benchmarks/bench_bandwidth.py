"""Fig. 9: AoPI + accuracy vs wireless bandwidth, all methods."""
from repro.core import baselines, lbcd, profiles

from .common import emit

METHODS = ("LBCD", "MIN", "DOS", "JCAB")


def _run_method(name, system, slots):
    if name == "LBCD":
        return lbcd.LBCDController(system, v=10.0, p_min=0.7).run(slots)
    return baselines.make(name, system).run(slots)


def sweep(param_name, values, sys_kw_fn, slots):
    rows = []
    for val in values:
        for m in METHODS:
            system = profiles.EdgeSystem(**sys_kw_fn(val))
            s = _run_method(m, system, slots)
            rows.append([param_name, float(val), m, s.mean_aopi,
                         s.mean_acc])
    return rows


def run(full: bool = False):
    slots = 30 if full else 15
    vals = (10e6, 20e6, 30e6, 40e6, 50e6) if full else (10e6, 30e6, 50e6)
    rows = sweep(
        "bandwidth_hz", vals,
        lambda v: dict(n_cameras=30, n_servers=3, n_slots=slots,
                       mean_bandwidth_hz=v, mean_compute_flops=50e12),
        slots)
    emit("fig9_bandwidth", rows,
         ["param", "value", "method", "mean_aopi", "mean_acc"])
    return rows
