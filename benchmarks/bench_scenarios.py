"""Scenario-suite robustness + throughput (BENCH_scenarios).

Runs the full registered scenario suite through every policy (LBCD + the
MIN/DOS/JCAB baselines) with ``repro.scenarios.sweep`` — shard_map across
devices when more than one is visible, vmap otherwise — and emits one row
per (scenario, policy): mean / p95 / worst-slot AoPI, mean accuracy, the
policy's sweep throughput in scenario-slots/sec (K * T / wall-clock,
compile excluded), plus the data-plane columns: measured AoPI from the
M/M/1 replay (``repro.serving.replay``) over the first ``n_replay``
epochs and the relative measured-vs-predicted divergence on those epochs.
"""
import jax

from repro import scenarios

from .common import emit, timer


def run(full: bool = False):
    n_cameras = 24 if full else 10
    n_slots = 96 if full else 24
    n_replay = 24 if full else 8          # data-plane epochs (host-bound)
    suite = scenarios.suite(n_cameras=n_cameras, n_slots=n_slots,
                            n_servers=3)
    k = suite.n_scenarios
    rows = []
    sps = {}
    for policy in scenarios.POLICIES:
        scenarios.sweep(suite, policies=(policy,))           # compile
        with timer() as t:
            res = scenarios.sweep(suite, policies=(policy,))
        sps[policy] = k * n_slots / t.elapsed
    # One replayed sweep for every policy: closed-form series + measured
    # M/M/1 data plane + matched predictions for the divergence column.
    res = scenarios.sweep(suite, dataplane=True,
                          dataplane_params=dict(n_epochs=n_replay,
                                                epoch_duration=600.0))
    for policy in scenarios.POLICIES:
        mean = res.mean_aopi(policy)
        p95 = res.pct_aopi(policy, 95.0)
        worst = res.worst_aopi(policy)
        acc = res.mean_acc(policy)
        measured = res.measured_aopi[policy].mean(axis=1)
        div = res.divergence(policy)
        for i, name in enumerate(suite.names):
            rows.append([name, suite.families[i], policy,
                         float(mean[i]), float(p95[i]), float(worst[i]),
                         float(acc[i]), sps[policy],
                         float(measured[i]), float(div[i])])
    print(f"# suite: {k} scenarios x {n_slots} slots x {n_cameras} cameras"
          f" on {len(jax.devices())} device(s) ({res.backend}); data plane"
          f" replay: {n_replay} epochs/scenario")
    emit("BENCH_scenarios", rows,
         ["scenario", "family", "policy", "mean_aopi", "p95_aopi",
          "worst_aopi", "mean_acc", "slots_per_sec", "measured_aopi",
          "divergence"])
    return rows
