"""Figs. 14-15: theoretical vs simulated AoPI, FCFS/LCFSP, exp + testbed
(uniform) delay regimes, CPU-like (slow mu) and GPU-like (fast mu) servers."""
from repro.core import aopi, queues

from .common import emit


def run(full: bool = False):
    n = 400_000 if full else 120_000
    rows = []
    # (regime, mu): CPU-like edge server vs GPU-like (paper §VI-C1).
    for server, mu in (("cpu", 8.0), ("gpu", 40.0)):
        for lam in (2.0, 5.0, 7.0) if mu == 8.0 else (5.0, 15.0, 30.0):
            for p in (0.6, 0.8):
                for pol, name in ((0, "fcfs"), (1, "lcfsp")):
                    if pol == 0 and lam >= mu:
                        continue
                    th = float(aopi.aopi(lam, mu, p, pol))
                    s_exp = queues.simulate(lam, mu, p, pol,
                                            n_frames=n).mean_aopi
                    s_uni = queues.simulate(
                        lam, mu, p, pol, n_frames=n,
                        t_sampler=queues.uniform_sampler(1 / lam),
                        o_sampler=queues.uniform_sampler(1 / mu)).mean_aopi
                    rows.append([server, name, lam, mu, p, th, s_exp,
                                 abs(s_exp - th) / th, s_uni])
    emit("fig14_15_validation", rows,
         ["server", "policy", "lam", "mu", "p", "theory", "sim_exp",
          "rel_err_exp", "sim_uniform"])
    return rows
