"""Fig. 10: AoPI + accuracy vs computation capacity, all methods."""
from .bench_bandwidth import sweep
from .common import emit


def run(full: bool = False):
    slots = 30 if full else 15
    vals = (20e12, 30e12, 40e12, 50e12, 60e12) if full else \
        (20e12, 40e12, 60e12)
    rows = sweep(
        "compute_flops", vals,
        lambda v: dict(n_cameras=30, n_servers=3, n_slots=slots,
                       mean_bandwidth_hz=30e6, mean_compute_flops=v),
        slots)
    emit("fig10_compute", rows,
         ["param", "value", "method", "mean_aopi", "mean_acc"])
    return rows
