"""Fused slot solver: jnp vs pallas-interpret Algorithm-1 throughput.

Measures, at N in {30, 300, 3000, 10^4, 10^5} cameras:

  * one-slot ``bcd.solve_slot`` latency (ms) for the jnp backend, the
    single-program pallas kernel (``pallas:tile=0``) and the camera-tiled
    streaming pallas kernel (``pallas:tile=<DEFAULT_TILE_N>``);
  * scan-rollout slots/sec per backend (N <= 10^4);
  * slots/sec of an 8-point vmapped ``(V, P_min)`` grid
    (``lbcd.rollout_grid``) per backend (N <= 3000), in
    grid-point-slots/sec.

On CPU the pallas backends run in interpret mode (the same kernel code
path that compiles on TPU), so the comparison is interpret-comparable:
both arms execute XLA CPU programs of the same algorithm, differing only
in dispatch structure — the pallas arm fuses both water-fills into one
call per BCD pass and never materializes the [N, M, R, 2] config-score
tensor (see ``tests/test_slot_solver.py`` for the op-count assertions).
Compiled-mode device wins ride the same structure for free; the json
header's ``meta.pallas_interpret`` records which mode produced each file.

The two large-N rows are the tentpole story: the single-program kernel
holds the whole fleet plus the [S, Np] membership matrix in VMEM (its
ceiling), while the tiled kernel streams [2, 8, tile] windows and is the
only pallas arm whose VMEM footprint is O(tile) rather than O(N).

The tiled arm runs the production spec ``pallas:tile=<DEFAULT_TILE_N>``.
Below one tile's worth of cameras that spec *resolves to the identical
untiled dispatch* (``bcd.resolve_spec`` drops a tile the fleet fits
inside), so those cells share the untiled measurement by construction
(same jitted executable) and ``tiled_speedup`` is exactly 1; the tiled
kernel only streams — and only pays or earns its DMA structure — on the
rows past the tile size (measured crossover ~1.3x at 32k cameras, ~2x at
100k in interpret mode). Rollout/grid cells that would take minutes per
repeat in interpret mode are left null.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bcd, lbcd, profiles

from .common import best_of, emit

COUNTS = (30, 300, 3000, 10_000, 100_000)
GRID_POINTS = 8
ROLLOUT_MAX_N = 10_000
GRID_MAX_N = 3000


def run(full: bool = False):
    rows = []
    vs = jnp.linspace(1.0, 50.0, GRID_POINTS)
    p_mins = jnp.linspace(0.5, 0.85, GRID_POINTS)
    for n in COUNTS:
        slots = (20 if n <= 300 else 6) if full else (8 if n <= 300 else 2)
        repeats = 3 if n <= 300 else 2
        sys = profiles.EdgeSystem(n_cameras=n, n_servers=3, n_slots=slots)
        tab = sys.horizon(slots)
        rng = np.random.default_rng(0)
        sid = jnp.asarray(rng.integers(0, 3, n).astype(np.int32))
        slot_args = (tab.acc[0], tab.xi, tab.size, tab.eff, sid,
                     tab.budgets_b[0], tab.budgets_c[0],
                     jnp.float32(1.0), jnp.float32(10.0))

        tiled_spec = f"pallas:tile={bcd.DEFAULT_TILE_N}"
        solve_backends = ["jnp", "pallas:tile=0"]
        if bcd.resolve_spec(tiled_spec, n).tile_n is not None:
            solve_backends.append(tiled_spec)
        row = [n, slots]
        for backend in solve_backends:
            solve = functools.partial(bcd.solve_slot, n_servers=3,
                                      solver_backend=backend)
            jax.block_until_ready(solve(*slot_args))          # warmup
            row.append(best_of(lambda: solve(*slot_args), repeats) * 1e3)
        if len(row) == 4:       # fleet fits one tile: same executable
            row.append(row[3])

        for backend in ("jnp", "pallas"):
            if n > ROLLOUT_MAX_N:
                row.append(None)
                continue
            roll = functools.partial(lbcd.rollout, tab, 10.0, 0.7,
                                     solver_backend=backend)
            jax.block_until_ready(roll())                      # warmup
            row.append(slots / best_of(roll, repeats))

        for backend in ("jnp", "pallas"):
            if n > GRID_MAX_N:
                row.append(None)
                continue
            grid = functools.partial(lbcd.rollout_grid, tab, vs, p_mins,
                                     solver_backend=backend)
            jax.block_until_ready(grid())                      # warmup
            row.append(GRID_POINTS * slots / best_of(grid, repeats))

        row += [row[2] / row[3],            # solve speedup pallas vs jnp
                row[3] / row[4],            # tiled vs single-program
                None if row[5] is None else row[6] / row[5],
                None if row[7] is None else row[8] / row[7]]
        rows.append(row)
    emit("BENCH_slot_solver", rows,
         ["n_cameras", "slots", "solve_ms_jnp", "solve_ms_pallas",
          "solve_ms_pallas_tiled", "rollout_sps_jnp", "rollout_sps_pallas",
          "grid8_sps_jnp", "grid8_sps_pallas",
          "solve_speedup", "tiled_speedup", "rollout_speedup",
          "grid_speedup"])
    return rows
