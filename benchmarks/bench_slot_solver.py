"""Fused slot solver: jnp vs pallas-interpret Algorithm-1 throughput.

Measures, at N in {30, 300, 3000} cameras:

  * one-slot ``bcd.solve_slot`` latency (ms) per backend;
  * scan-rollout slots/sec per backend;
  * slots/sec of an 8-point vmapped ``(V, P_min)`` grid
    (``lbcd.rollout_grid``) per backend, in grid-point-slots/sec.

On CPU the pallas backend runs in interpret mode (the same kernel code
path that compiles on TPU), so the comparison is interpret-comparable:
both arms execute XLA CPU programs of the same algorithm, differing only
in dispatch structure — the pallas arm fuses each water-fill into one
call and never materializes the [N, M, R, 2] config-score tensor (see
``tests/test_slot_solver.py`` for the op-count assertions). Compiled-mode
device wins ride the same structure for free. Compile/warmup excluded.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bcd, lbcd, profiles

from .common import emit, timer

COUNTS = (30, 300, 3000)
GRID_POINTS = 8


def _best(fn, repeats):
    best = np.inf
    for _ in range(repeats):
        with timer() as t:
            jax.block_until_ready(fn())
        best = min(best, t.elapsed)
    return best


def run(full: bool = False):
    rows = []
    vs = jnp.linspace(1.0, 50.0, GRID_POINTS)
    p_mins = jnp.linspace(0.5, 0.85, GRID_POINTS)
    for n in COUNTS:
        slots = (20 if n <= 300 else 6) if full else (8 if n <= 300 else 2)
        repeats = 3 if n <= 300 else 1
        sys = profiles.EdgeSystem(n_cameras=n, n_servers=3, n_slots=slots)
        tab = sys.horizon(slots)
        rng = np.random.default_rng(0)
        sid = jnp.asarray(rng.integers(0, 3, n).astype(np.int32))
        slot_args = (tab.acc[0], tab.xi, tab.size, tab.eff, sid,
                     tab.budgets_b[0], tab.budgets_c[0],
                     jnp.float32(1.0), jnp.float32(10.0))

        row = [n, slots]
        for backend in ("jnp", "pallas"):
            solve = functools.partial(bcd.solve_slot, n_servers=3,
                                      solver_backend=backend)
            jax.block_until_ready(solve(*slot_args))          # warmup
            row.append(_best(lambda: solve(*slot_args), repeats) * 1e3)

        for backend in ("jnp", "pallas"):
            roll = functools.partial(lbcd.rollout, tab, 10.0, 0.7,
                                     solver_backend=backend)
            jax.block_until_ready(roll())                      # warmup
            row.append(slots / _best(roll, repeats))

        for backend in ("jnp", "pallas"):
            grid = functools.partial(lbcd.rollout_grid, tab, vs, p_mins,
                                     solver_backend=backend)
            jax.block_until_ready(grid())                      # warmup
            row.append(GRID_POINTS * slots / _best(grid, repeats))

        row += [row[2] / row[3],            # solve speedup pallas vs jnp
                row[5] / row[4],            # rollout speedup
                row[7] / row[6]]            # grid speedup
        rows.append(row)
    emit("BENCH_slot_solver", rows,
         ["n_cameras", "slots", "solve_ms_jnp", "solve_ms_pallas",
          "rollout_sps_jnp", "rollout_sps_pallas",
          "grid8_sps_jnp", "grid8_sps_pallas",
          "solve_speedup", "rollout_speedup", "grid_speedup"])
    return rows
