"""Figs. 7-8: P_min and V sweeps for LBCD.

The whole (V, P_min) grid runs as **one vmapped scan-engine call**
(``lbcd.rollout_grid``): the horizon is pregenerated once and every grid
point rolls out on device in parallel.
"""
import jax.numpy as jnp

from repro.core import lbcd, profiles

from .common import emit

P_MINS = (0.3, 0.5, 0.7, 0.9)
VS = (1.0, 10.0, 100.0)


def _sys(seed=0):
    return profiles.EdgeSystem(n_cameras=18, n_servers=3, n_slots=40,
                               seed=seed, mean_bandwidth_hz=15e6,
                               mean_compute_flops=20e12)


def run(full: bool = False):
    slots = 60 if full else 30
    tables = _sys().horizon(slots)
    # Grid rows: the P_min sweep at V=10, then the V sweep at P_min=0.7.
    grid_v = jnp.asarray([10.0] * len(P_MINS) + list(VS))
    grid_p = jnp.asarray(list(P_MINS) + [0.7] * len(VS))
    res = lbcd.rollout_grid(tables, grid_v, grid_p)   # [G, T, ...]

    rows = []
    params = [("p_min", p) for p in P_MINS] + [("V", v) for v in VS]
    for g, (param, value) in enumerate(params):
        aopi = res.aopi[g]
        acc = res.acc[g]
        rows.append([param, value, float(aopi.mean()), float(acc.mean()),
                     float(acc.mean(axis=1)[-5:].mean()),
                     float(res.q[g, -1])])
    emit("fig7_8_hyperparams", rows,
         ["param", "value", "mean_aopi", "mean_acc", "tail_acc", "q_end"])
    return rows
