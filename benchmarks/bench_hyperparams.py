"""Figs. 7-8: P_min and V sweeps for LBCD."""
from repro.core import lbcd, profiles

from .common import emit


def _sys(seed=0):
    return profiles.EdgeSystem(n_cameras=18, n_servers=3, n_slots=40,
                               seed=seed, mean_bandwidth_hz=15e6,
                               mean_compute_flops=20e12)


def run(full: bool = False):
    slots = 60 if full else 30
    rows = []
    for p_min in (0.3, 0.5, 0.7, 0.9):
        s = lbcd.LBCDController(_sys(), v=10.0, p_min=p_min).run(slots)
        rows.append(["p_min", p_min, s.mean_aopi, s.mean_acc,
                     float(s.acc_series[-5:].mean()),
                     float(s.q_series[-1])])
    for v in (1.0, 10.0, 100.0):
        s = lbcd.LBCDController(_sys(), v=v, p_min=0.7).run(slots)
        rows.append(["V", v, s.mean_aopi, s.mean_acc,
                     float(s.acc_series[-5:].mean()),
                     float(s.q_series[-1])])
    emit("fig7_8_hyperparams", rows,
         ["param", "value", "mean_aopi", "mean_acc", "tail_acc", "q_end"])
    return rows
