"""Shared benchmark plumbing: timing, CSV rows, result persistence."""
from __future__ import annotations

import json
import os
import time

RESULTS_DIR = os.environ.get("REPRO_BENCH_DIR", "results/bench")


def run_metadata() -> dict:
    """Environment stamp for every ``BENCH_*.json`` header: jax/device
    identity, whether pallas kernels ran in interpret mode (CPU/CI) or
    compiled (real TPU), and the ``repro.obs`` snapshot accumulated so
    far (counter totals, histogram counts/sums) — so every emitted table
    carries the timing provenance of the run that produced it."""
    import jax
    backend = jax.default_backend()
    meta = {
        "jax_version": jax.__version__,
        "backend": backend,
        "device_kind": jax.devices()[0].device_kind,
        "n_devices": jax.device_count(),
        "pallas_interpret": backend != "tpu",
    }
    try:
        from repro import obs
        meta["obs"] = obs.snapshot_summary()
    except ImportError:
        pass
    return meta


def emit(name: str, rows: list, header: list):
    """Print CSV to stdout and persist JSON under results/bench."""
    os.makedirs(RESULTS_DIR, exist_ok=True)
    print(f"## {name}")
    print(",".join(header))
    for r in rows:
        print(",".join("" if v is None else
                       f"{v:.6g}" if isinstance(v, float) else str(v)
                       for v in r))
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump({"meta": run_metadata(), "header": header, "rows": rows},
                  f, indent=1, default=float)


class timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.elapsed = time.perf_counter() - self.t0


def best_of(fn, repeats: int = 3, block: bool = True) -> float:
    """Best-of-``repeats`` wall time of ``fn()`` in seconds.

    ``block=True`` waits on the returned jax arrays
    (``jax.block_until_ready``) so async dispatch doesn't flatter the
    number; pass ``block=False`` for host-side (numpy/legacy) callables.
    """
    import jax
    best = float("inf")
    for _ in range(repeats):
        with timer() as t:
            out = fn()
            if block:
                jax.block_until_ready(out)
        best = min(best, t.elapsed)
    return best
