"""Shared benchmark plumbing: timing, CSV rows, result persistence."""
from __future__ import annotations

import json
import os
import time

RESULTS_DIR = os.environ.get("REPRO_BENCH_DIR", "results/bench")


def emit(name: str, rows: list, header: list):
    """Print CSV to stdout and persist JSON under results/bench."""
    os.makedirs(RESULTS_DIR, exist_ok=True)
    print(f"## {name}")
    print(",".join(header))
    for r in rows:
        print(",".join(f"{v:.6g}" if isinstance(v, float) else str(v)
                       for v in r))
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump({"header": header, "rows": rows}, f, indent=1,
                  default=float)


class timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.elapsed = time.perf_counter() - self.t0
