"""Fault-plane overhead and degraded-mode cost (BENCH_faults).

Three replay modes over the same churn scenario, per policy:

  * ``clean``       — no ``faults`` kwarg at all (the pre-fault-plane
    call shape);
  * ``faults_none`` — ``faults=None`` explicitly: the bitwise no-op path
    whose cost must match ``clean`` (the fault plane is free when off);
  * ``storm``       — ``repro.faults.storm_plan``: every fault kind at
    once, exercising the churn-masked rollouts, the telemetry gating,
    and every rung of the graceful-degradation ladder.

Rows carry wall seconds, the storm/clean slowdown, and the storm run's
fallback / degraded-epoch / telemetry-gap counts, so the trajectory
shows both the off-path staying free and the degraded-mode cost staying
bounded.
"""
import numpy as np

from repro import scenarios
from repro.faults import storm_plan
from repro.serving.replay import replay_tables

from .common import best_of, emit

DIMS = dict(n_cameras=8, n_slots=16, n_servers=2,
            mean_bandwidth_hz=15e6, mean_compute_flops=20e12)


def run(full: bool = False):
    policies = ("lbcd", "min", "dos", "jcab") if full else ("lbcd", "min")
    repeats = 3 if full else 2
    tables = scenarios.build("camera_churn", **DIMS)
    plan = storm_plan(DIMS["n_slots"], seed=0)
    rows = []
    for policy in policies:
        kw = dict(plan_window=4, telemetry_gain=0.2)
        # Warm the compiled planner/data-plane executables once so the
        # timed repeats measure execution, not compilation.
        replay_tables(tables, policy, **kw)
        clean_s = best_of(
            lambda: replay_tables(tables, policy, **kw), repeats)
        none_s = best_of(
            lambda: replay_tables(tables, policy, faults=None, **kw),
            repeats)
        replay_tables(tables, policy, faults=plan, **kw)   # warm fallback
        storm_s = best_of(
            lambda: replay_tables(tables, policy, faults=plan, **kw),
            repeats)
        rep = replay_tables(tables, policy, faults=plan, **kw)
        svc = rep.service
        assert np.isfinite(rep.measured).all()
        rows.append([policy, clean_s, none_s, storm_s, storm_s / clean_s,
                     len(svc.fallbacks), len(svc.degraded_epochs),
                     len(svc.telemetry_gaps)])
        print(f"# {policy:<5s} clean {clean_s * 1e3:8.1f} ms | "
              f"faults=None {none_s * 1e3:8.1f} ms | "
              f"storm {storm_s * 1e3:8.1f} ms ({storm_s / clean_s:4.2f}x) "
              f"| fb={len(svc.fallbacks)} degr={len(svc.degraded_epochs)} "
              f"gaps={len(svc.telemetry_gaps)}", flush=True)
    emit("BENCH_faults", rows,
         ["policy", "clean_s", "faults_none_s", "storm_s",
          "storm_over_clean", "fallbacks", "degraded_epochs",
          "telemetry_gaps"])
    return rows
