"""Figs. 3 + 5: minimum transmission/computation rate for AoPI <= target."""
import numpy as np

from repro.core import aopi

from .common import emit


def run(full: bool = False):
    rows = []
    target, p = 0.5, 0.8
    pts = 16 if full else 8
    for pol, name in ((0, "fcfs"), (1, "lcfsp")):
        for mu in np.linspace(4.0, 40.0, pts):
            lam_min = float(aopi.min_lam_for_target(target, mu, p, pol))
            rows.append([name, "min_lam", float(mu), lam_min])
        for lam in np.linspace(3.0, 30.0, pts):
            mu_min = float(aopi.min_mu_for_target(target, lam, p, pol))
            rows.append([name, "min_mu", float(lam), mu_min])
    emit("fig3_5_frontier", rows, ["policy", "kind", "given_rate",
                                   "min_rate"])
    return rows
