"""Beyond-paper: energy-aware LBCD (§VII future work) — power/AoPI trade."""
import numpy as np

from repro.core import profiles
from repro.core.energy import EnergyAwareLBCD, EnergyModel
from repro.core.lbcd import LBCDController

from .common import emit


def _sys():
    return profiles.EdgeSystem(n_cameras=12, n_servers=2, n_slots=40,
                               seed=0, mean_bandwidth_hz=15e6,
                               mean_compute_flops=15e12)


def run(full: bool = False):
    slots = 80 if full else 40
    rows = []
    em_probe = EnergyModel()
    base = LBCDController(_sys(), v=10.0, p_min=0.6).run(slots)
    base_p = float(np.mean([em_probe.power(r.decision.b,
                                           r.decision.c).mean()
                            for r in base.records]))
    rows.append(["none", float("inf"), base.mean_aopi, base.mean_acc,
                 base_p])
    for e_max in (1.0, 0.5, 0.25):
        em = EnergyModel(e_max=e_max)
        ea = EnergyAwareLBCD(_sys(), energy=em, v=10.0, p_min=0.6)
        recs = [ea.step(t) for t in range(slots)]
        rows.append(["energy_lbcd", e_max,
                     float(np.mean([r.mean_aopi for r in recs])),
                     float(np.mean([r.mean_acc for r in recs])),
                     float(np.mean([r.power for r in recs[slots // 2:]]))])
    emit("beyond_energy", rows,
         ["controller", "e_max_w", "mean_aopi", "mean_acc",
          "tail_power_w"])
    return rows
