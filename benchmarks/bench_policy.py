"""Fig. 6: optimal-policy phase diagram over (load rho, accuracy p)."""
import numpy as np

from repro.core import aopi

from .common import emit


def run(full: bool = False):
    rows = []
    mu = 10.0
    grid = 17 if full else 9
    for rho in np.linspace(0.1, 1.5, grid):
        thr = float(aopi.policy_threshold(rho))
        for p in np.linspace(0.1, 0.95, grid):
            pol = int(aopi.optimal_policy(rho * mu, mu, p))
            # cross-check against direct evaluation
            af = float(aopi.aopi_fcfs(rho * mu, mu, p))
            al = float(aopi.aopi_lcfsp(rho * mu, mu, p))
            direct = int(al <= af)
            assert pol == direct, (rho, p)
            rows.append([float(rho), float(p), pol, thr])
    emit("fig6_policy_phase", rows, ["rho", "p", "optimal_policy",
                                     "threshold_p"])
    return rows
