"""Scale-out: scan-engine vs legacy per-slot-loop rollout throughput.

Measures steady-state slots/sec of the device-resident ``lbcd.rollout``
(one jitted ``lax.scan``, horizon pregenerated) at N in {30, 300, 3000}
cameras against two legacy arms:

  * ``legacy_seed``   — the pre-refactor rollout stack this PR replaced:
    per-slot python loop (per-slot profiling, two dispatches, numpy
    first-fit, device<->host round trips each slot) with its original
    flat high-iteration water-filling (``solver_effort="seed"``);
  * ``legacy_shared`` — the same per-slot loop but sharing the reworked
    fast allocator, isolating what the loop->scan move alone buys.

Compile/warmup time is excluded everywhere. At N=3000 the scan engine
still runs entirely on device — no host-loop fallback.

Past the legacy-comparison arms, two scale-out rows push the scan
engine to N in {3x10^4, 10^5} cameras on the camera-tiled pallas slot
solver (``pallas:tile=<DEFAULT_TILE_N>``, the only backend whose VMEM
footprint is O(tile) rather than O(N) — see ``BENCH_slot_solver``).
The per-slot-loop arms are unaffordable there and emit null cells; the
``solver_backend`` column records which spec produced each row.

Migration note: this bench previously emitted ``scaleout_rollout.json``;
it now writes ``BENCH_rollout.json`` so the BENCH_* trajectory tracking
picks it up (old files are not rewritten).
"""
import jax

from repro.core import bcd, lbcd, profiles

from .common import best_of, emit

COUNTS = (30, 300, 3000)
SCALEOUT_COUNTS = (30_000, 100_000)


def _system(n, slots):
    return profiles.EdgeSystem(n_cameras=n, n_servers=3, n_slots=slots)


def _time_legacy(n, slots, legacy_slots, repeats, effort):
    ctrl = lbcd.LBCDController(_system(n, slots), v=10.0, p_min=0.7,
                               solver_effort=effort)
    ctrl.step(0)                                             # warmup

    def run_window():
        for tt in range(1, legacy_slots + 1):
            ctrl.step(tt)

    return legacy_slots / best_of(run_window, repeats, block=False)


def run(full: bool = False):
    rows = []
    for n in COUNTS:
        slots = (40 if n <= 300 else 12) if full else \
            (20 if n <= 300 else 6)
        legacy_slots = slots if n <= 300 else 3
        repeats = 1 if n >= 3000 else 3

        # --- scan engine: compile once, then time whole-horizon calls.
        tables = _system(n, slots).horizon(slots)
        jax.block_until_ready(lbcd.rollout(tables, 10.0, 0.7))   # warmup
        scan_sps = slots / best_of(lambda: lbcd.rollout(tables, 10.0, 0.7),
                                   repeats)

        seed_sps = _time_legacy(n, slots, legacy_slots, repeats, "seed")
        shared_sps = _time_legacy(n, slots, legacy_slots, repeats, "fast")

        rows.append([n, slots, scan_sps, seed_sps, shared_sps,
                     scan_sps / seed_sps, scan_sps / shared_sps, "auto"])

    # --- scale-out: tiled-pallas scan engine only, no legacy arms.
    tiled_spec = f"pallas:tile={bcd.DEFAULT_TILE_N}"
    for n in SCALEOUT_COUNTS:
        slots = 2
        tables = _system(n, slots).horizon(slots)
        roll = lambda: lbcd.rollout(tables, 10.0, 0.7,
                                    solver_backend=tiled_spec)
        jax.block_until_ready(roll())                            # warmup
        scan_sps = slots / best_of(roll, 1)
        rows.append([n, slots, scan_sps, None, None, None, None,
                     tiled_spec])
        print(f"# N={n:<7d} tiled scan {scan_sps:8.3f} slots/s",
              flush=True)
    emit("BENCH_rollout", rows,
         ["n_cameras", "slots", "scan_slots_per_sec",
          "legacy_seed_slots_per_sec", "legacy_shared_slots_per_sec",
          "speedup_vs_seed", "speedup_vs_shared", "solver_backend"])
    return rows
