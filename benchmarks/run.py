"""Benchmark harness entry point — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME[,NAME...]]

Emits CSV blocks per figure and persists JSON under results/bench/ —
every table (fig reproductions and BENCH_* trajectory benches alike)
carries the ``common.run_metadata()`` provenance stamp, including the
``repro.obs`` metric snapshot accumulated by the run.

``--only`` takes one or more comma-separated names; each is matched as a
substring against the table keys, and a token that matches nothing
aborts with the list of valid keys.
"""
import argparse
import json
import sys
import time

from . import (bench_bandwidth, bench_cameras, bench_compute,
               bench_dataplane, bench_energy, bench_engine, bench_faults,
               bench_frontier, bench_hyperparams, bench_overhead,
               bench_policy, bench_rollout, bench_scenarios,
               bench_slot_solver, bench_validation, common)

ALL = {
    "fig14_15_validation": bench_validation.run,
    "fig6_policy_phase": bench_policy.run,
    "fig3_5_frontier": bench_frontier.run,
    "fig7_8_hyperparams": bench_hyperparams.run,
    "fig9_bandwidth": bench_bandwidth.run,
    "fig10_compute": bench_compute.run,
    "fig11_cameras": bench_cameras.run,
    "fig12_overhead": bench_overhead.run,
    "beyond_energy": bench_energy.run,
    "BENCH_rollout": bench_rollout.run,
    "BENCH_scenarios": bench_scenarios.run,
    "BENCH_slot_solver": bench_slot_solver.run,
    "BENCH_dataplane": bench_dataplane.run,
    "BENCH_engine": bench_engine.run,
    "BENCH_faults": bench_faults.run,
}


def select(only: str | None) -> list[str]:
    """Resolve ``--only`` (comma-separated substrings) to table keys,
    erroring per-token so a typo names itself AND the valid keys."""
    if not only:
        return list(ALL)
    selected: list[str] = []
    for token in (t.strip() for t in only.split(",")):
        if not token:
            continue
        hits = [name for name in ALL if token in name]
        if not hits:
            sys.exit(f"--only token {token!r} matched no benchmark; "
                     f"known: {', '.join(ALL)}")
        selected += [h for h in hits if h not in selected]
    return selected


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark name substrings")
    args = ap.parse_args()
    t0 = time.time()
    print(f"# meta: {json.dumps(common.run_metadata(), default=float)}\n",
          flush=True)
    for name in select(args.only):
        t = time.time()
        ALL[name](full=args.full)
        print(f"[{name}: {time.time()-t:.1f}s]\n", flush=True)
    print(f"total {time.time()-t0:.1f}s")


if __name__ == '__main__':
    main()
