"""Benchmark harness entry point — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME]

Emits CSV blocks per figure and persists JSON under results/bench/.
"""
import argparse
import sys
import time

from . import (bench_bandwidth, bench_cameras, bench_compute,
               bench_dataplane, bench_energy, bench_frontier,
               bench_hyperparams, bench_overhead, bench_policy,
               bench_rollout, bench_scenarios, bench_slot_solver,
               bench_validation)

ALL = {
    "fig14_15_validation": bench_validation.run,
    "fig6_policy_phase": bench_policy.run,
    "fig3_5_frontier": bench_frontier.run,
    "fig7_8_hyperparams": bench_hyperparams.run,
    "fig9_bandwidth": bench_bandwidth.run,
    "fig10_compute": bench_compute.run,
    "fig11_cameras": bench_cameras.run,
    "fig12_overhead": bench_overhead.run,
    "beyond_energy": bench_energy.run,
    "BENCH_rollout": bench_rollout.run,
    "BENCH_scenarios": bench_scenarios.run,
    "BENCH_slot_solver": bench_slot_solver.run,
    "BENCH_dataplane": bench_dataplane.run,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    t0 = time.time()
    matched = False
    for name, fn in ALL.items():
        if args.only and args.only not in name:
            continue
        matched = True
        t = time.time()
        fn(full=args.full)
        print(f"[{name}: {time.time()-t:.1f}s]\n", flush=True)
    if args.only and not matched:
        sys.exit(f"--only {args.only!r} matched no benchmark; "
                 f"known: {', '.join(ALL)}")
    print(f"total {time.time()-t0:.1f}s")


if __name__ == '__main__':
    main()
