"""Fig. 11: AoPI + accuracy vs camera count, all methods."""
from .bench_bandwidth import sweep
from .common import emit


def run(full: bool = False):
    slots = 30 if full else 15
    vals = (10, 20, 30, 40, 50) if full else (10, 30, 50)
    rows = sweep(
        "n_cameras", vals,
        lambda v: dict(n_cameras=int(v), n_servers=3, n_slots=slots,
                       mean_bandwidth_hz=30e6, mean_compute_flops=50e12),
        slots)
    emit("fig11_cameras", rows,
         ["param", "value", "method", "mean_aopi", "mean_acc"])
    return rows
